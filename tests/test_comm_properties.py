"""Property-based tests (hypothesis) on the comm-layer invariants.

Pinned properties:

* int8 quantization error is bounded by half an ulp of the per-row scale;
* top-k encode conservation is *bitwise* — ``sent + residual == x`` exactly
  in fp32 for arbitrary payloads (the EF-SGD algebra depends on it);
* dense ledger bytes are exact arithmetic: ``events * payload_elems * 4``
  for any (tau, schedule, update-count) combination, partial periods
  included.
"""
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.comm import dequantize_int8, qint8, quantize_int8, topk
from repro.core import make_strategy, uniform_taus
from repro.core.accounting import CostLedger

SETTINGS = settings(max_examples=40, deadline=None)

# fp32 payload matrices: finite, wide magnitude range, no -0.0 (negative
# zero survives top-k selection asymmetrically at the bit level, which is
# irrelevant to the arithmetic conservation under test)
_signed_f32 = st.builds(
    lambda mag, sign: np.float32(mag) * np.float32(sign),
    st.floats(min_value=1e-20, max_value=1e20, allow_nan=False,
              allow_infinity=False, width=32),
    st.sampled_from([1.0, -1.0, 0.0]),
)
_payloads = hnp.arrays(
    np.float32,
    st.tuples(st.integers(1, 6), st.integers(1, 40)),
    elements=_signed_f32,
)


@SETTINGS
@given(x=_payloads)
def test_int8_error_bounded_by_half_ulp_of_the_row_scale(x):
    q, scale = quantize_int8(jnp.asarray(x))
    err = np.abs(np.asarray(dequantize_int8(q, scale)) - x)
    # half an ulp of the row scale, with fp32 slack on the division/round
    bound = np.asarray(scale)[:, None] * (0.5 + 1e-5) + 1e-30
    assert np.all(err <= bound), (err.max(), np.asarray(scale))


@SETTINGS
@given(x=_payloads, k=st.integers(1, 40))
def test_topk_encode_conservation_is_bitwise(x, k):
    k = min(k, x.shape[1])
    sent, residual = topk(k).encode(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(sent) + np.asarray(residual), x)
    # selection is a partition: every entry lands wholly on one side
    assert np.all((np.asarray(sent) == 0) | (np.asarray(residual) == 0))


@SETTINGS
@given(x=_payloads)
def test_int8_encode_conservation_is_exact_in_fp32(x):
    sent, residual = qint8().encode(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(sent) + np.asarray(residual), x)


@SETTINGS
@given(tau=st.integers(1, 10), m=st.integers(1, 10),
       n_updates=st.integers(0, 50), n=st.integers(1, 10_000),
       seed=st.integers(0, 99))
def test_dense_ledger_bytes_are_events_times_4n(tau, m, n_updates, n, seed):
    strat = make_strategy("periodic", tau=tau,
                          taus=uniform_taus(1, tau, m, seed=seed))
    full, rem = divmod(n_updates, tau)
    ledger = CostLedger()
    ledger.add_periods(strat, full, payload_elems=n)
    ledger.add_partial_period(strat, rem, payload_elems=n)
    assert ledger.c1_bytes == ledger.c1_events * n * 4
    assert ledger.w1_bytes == ledger.w1_events * n * 4 == 0
    assert ledger.total_bytes() == ledger.c1_bytes
