"""Federated MARL driver (Algorithms 1 & 2) integration tests."""

import jax
import numpy as np
import pytest

from repro.core.strategies import make_strategy
from repro.core import topology as T
from repro.core import uniform_taus
from repro.rl import FIGURE_EIGHT, FedRLConfig, run_fedrl


def _run(strategy, n_epochs=4, algo="ppo", seed=0):
    cfg = FedRLConfig(env=FIGURE_EIGHT, strategy=strategy, n_epochs=n_epochs,
                      epoch_len=60, minibatch=20, eta=3e-3, algo=algo)
    return run_fedrl(cfg, jax.random.key(seed))


def test_periodic_runs_and_reports_metrics():
    strat = make_strategy("periodic", tau=3, m=7)
    server, metrics, ledger = _run(strat)
    assert metrics["nas"].shape == (4,)
    assert np.all(np.isfinite(metrics["server_grad_sq_norm"]))
    row = ledger.table_row()
    assert row["communication_overheads_C1"] == 7 * 4  # m * periods
    assert row["computation_overheads_C2"] == 7 * 3 * 4


def test_variation_aware_counts_fewer_updates():
    taus = uniform_taus(1, 3, 7, seed=0)
    strat = make_strategy("periodic", tau=3, taus=taus)
    _, _, ledger = _run(strat)
    assert ledger.c2_events == int(taus.sum()) * 4 < 7 * 3 * 4


def test_consensus_strategy_runs_and_bills_gossip():
    topo = T.random_regularish(7, 3, 4, seed=0)
    strat = make_strategy("consensus", tau=3, topo=topo, eps=0.1, rounds=1, m=7)
    _, metrics, ledger = _run(strat)
    assert ledger.w1_events > 0 and ledger.w1_events == ledger.w2_events
    assert np.all(np.isfinite(metrics["nas"]))


@pytest.mark.parametrize("algo", ["ppo", "trpo", "tac"])
def test_all_three_optimizers_run(algo):
    strat = make_strategy("periodic", tau=2, m=7)
    _, metrics, _ = _run(strat, n_epochs=2, algo=algo)
    assert np.all(np.isfinite(metrics["loss"]))


def test_same_seed_reproducible():
    strat = make_strategy("periodic", tau=2, m=7)
    _, m1, _ = _run(strat, n_epochs=2, seed=3)
    _, m2, _ = _run(strat, n_epochs=2, seed=3)
    np.testing.assert_allclose(m1["nas"], m2["nas"])


def test_strategy_m_must_match_env():
    strat = make_strategy("periodic", tau=2, m=5)  # env has 7 RL vehicles
    with pytest.raises(ValueError):
        FedRLConfig(env=FIGURE_EIGHT, strategy=strat)
