"""Hypothesis properties for the async federation layer (ISSUE PR-9 §4).

Three contracts, randomised over schedule families, fleet sizes and run
lengths:

* K-of-m arrival masks always select exactly the K freshest replicas
  (stable index tie-break), per period.
* A zero-delay schedule is bitwise-identical to synchronous VPA on the
  eager jnp path — the DESIGN.md §15 sync-equivalence contract.
* Ledger bytes under async equal ``arrivals x payload_bytes(n)``: the
  arrival-aware accounting never bills a replica that did not uplink.
"""
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.accounting import CostLedger
from repro.core.async_fed import (
    AsyncStrategy,
    kofm_schedule,
    make_schedule,
    renewal_arrivals,
)
from repro.core.fmarl import FmarlConfig, run_fmarl
from repro.core.strategies import PeriodicStrategy
from repro.utils.pytree import tree_l2_norm

SETTINGS = settings(max_examples=40, deadline=None)
SETTINGS_SLOW = settings(max_examples=10, deadline=None)

DISTS = st.sampled_from(
    [("deterministic", st.floats(0.0, 3.0)),
     ("geometric", st.floats(0.05, 0.95)),
     ("heavytail", st.floats(0.5, 3.0))]
).flatmap(lambda d: st.tuples(st.just(d[0]), d[1]))


@SETTINGS
@given(
    dist_param=DISTS,
    m=st.integers(2, 9),
    n_periods=st.integers(1, 10),
    k=st.data(),
    seed=st.integers(0, 2**20),
)
def test_kofm_selects_exactly_k_freshest(dist_param, m, n_periods, k, seed):
    dist, param = dist_param
    k = k.draw(st.integers(1, m), label="k")
    s = kofm_schedule(m, n_periods, k, dist=dist, param=param, seed=seed)
    arrive = np.asarray(s.arrive)
    age = np.asarray(s.age)
    # exactly k arrivals every period, never more or fewer
    np.testing.assert_array_equal(arrive.sum(axis=0), np.full(n_periods, k))
    for t in range(n_periods):
        sel = arrive[:, t] > 0
        if sel.all():
            continue
        # the selected k are the freshest: every unselected replica's
        # effective staleness is >= the worst selected one...
        assert age[sel, t].max() <= age[~sel, t].min() + 1e-6
        # ...and ties break by agent index (lexsort stability): among
        # replicas at the boundary staleness, selected indices come first
        boundary = age[sel, t].max()
        sel_ties = np.flatnonzero(sel & np.isclose(age[:, t], boundary))
        unsel_ties = np.flatnonzero(~sel & np.isclose(age[:, t], boundary))
        if len(unsel_ties):
            assert sel_ties.max() < unsel_ties.min()


@SETTINGS
@given(
    dist_param=DISTS,
    m=st.integers(1, 8),
    n_periods=st.integers(1, 12),
    seed=st.integers(0, 2**20),
)
def test_renewal_invariants(dist_param, m, n_periods, seed):
    """Arrivals are a renewal process: every boundary's age counts boundaries
    since the agent's last sync (pending staleness on non-arrivals — the sync
    weights gate it by ``arrive``), and an age-a arrival at period t implies
    silence over (t-a, t)."""
    dist, param = dist_param
    s = make_schedule(dist, param, m, n_periods, seed=seed)
    arrive = np.asarray(s.arrive)
    age = np.asarray(s.age)
    assert set(np.unique(arrive)) <= {0.0, 1.0}
    assert np.all(age >= 0) and np.all(age <= n_periods)
    for i in range(m):
        last = -1
        for t in range(n_periods):
            assert age[i, t] == t - last - 1  # boundaries since last sync
            if arrive[i, t]:
                last = t
    assert s.total_arrivals() == int(arrive.sum())


@SETTINGS_SLOW
@given(
    m=st.integers(2, 6),
    tau=st.integers(1, 4),
    n_periods=st.integers(1, 4),
    seed=st.integers(0, 2**10),
)
def test_zero_delay_bitwise_equals_sync_vpa(m, tau, n_periods, seed):
    """Zero delay => every replica arrives every boundary with weight exactly
    1.0, so the masked FedBuff step IS vanilla periodic averaging, executed
    op-for-op on the eager jnp path. Bitwise, not approximately."""

    def grad_fn(params, key, agent_idx, step):
        g = jax.tree.map(
            lambda leaf: leaf
            + 0.1 * jax.random.normal(jax.random.fold_in(key, 0), leaf.shape),
            params,
        )
        return g, {"loss": tree_l2_norm(params) ** 2}

    init = {"w": jnp.ones((5,)), "b": jnp.ones((2,))}
    sched = make_schedule("deterministic", 0.0, m, n_periods, seed=seed)
    cfg_a = FmarlConfig(
        strategy=AsyncStrategy(tau=tau, schedule=sched, backend="jnp"),
        eta=0.05, n_periods=n_periods,
    )
    cfg_s = FmarlConfig(
        strategy=PeriodicStrategy(tau=tau, m=m, backend="jnp"),
        eta=0.05, n_periods=n_periods,
    )
    key = jax.random.key(seed)
    with jax.disable_jit():
        st_a, m_a, _ = run_fmarl(cfg_a, init, grad_fn, key, lambda p, k: p)
        st_s, m_s, _ = run_fmarl(cfg_s, init, grad_fn, key, lambda p, k: p)
    for a, b in zip(jax.tree.leaves(st_a.server_params),
                    jax.tree.leaves(st_s.server_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(m_a["server_grad_sq_norm"]),
        np.asarray(m_s["server_grad_sq_norm"]),
    )


@SETTINGS
@given(
    dist_param=DISTS,
    m=st.integers(1, 10),
    tau=st.integers(1, 6),
    n_periods=st.integers(1, 12),
    payload=st.integers(1, 10_000),
    split=st.data(),
    seed=st.integers(0, 2**20),
)
def test_ledger_bytes_equal_arrivals_times_payload(
    dist_param, m, tau, n_periods, payload, split, seed
):
    dist, param = dist_param
    sched = make_schedule(dist, param, m, n_periods, seed=seed)
    strat = AsyncStrategy(tau=tau, schedule=sched)
    cut = split.draw(st.integers(0, n_periods), label="cut")
    offsets = split.draw(st.integers(0, tau - 1), label="offsets")

    ledger = CostLedger()
    if cut:
        ledger.add_periods(strat, cut, payload)
    if n_periods - cut:
        ledger.add_periods(strat, n_periods - cut, payload)
    ledger.add_partial_period(strat, offsets, payload)

    arrivals = sched.total_arrivals()
    assert ledger.c1_events == arrivals
    assert ledger.c1_bytes == arrivals * payload * 4
    assert ledger.total_bytes() == arrivals * payload * 4
    # local work is billed in full regardless of arrivals
    assert ledger.c2_events == m * (tau * n_periods + offsets)


@SETTINGS
@given(
    delays=st.lists(
        st.lists(st.integers(0, 5), min_size=1, max_size=8),
        min_size=1, max_size=6,
    ).filter(lambda rows: len({len(r) for r in rows}) == 1),
)
def test_renewal_arrivals_matches_python_reference(delays):
    """The scanned renewal recurrence agrees with a direct Python loop."""
    d = np.asarray(delays, np.float32)
    arrive, age = renewal_arrivals(d)
    m, T = d.shape
    for i in range(m):
        since = 0
        for t in range(T):
            since += 1
            assert age[i, t] == since - 1
            if since > d[i, t]:
                assert arrive[i, t] == 1.0
                since = 0
            else:
                assert arrive[i, t] == 0.0
