"""Backend-dispatch layer: kernel (interpret) vs pure-jnp parity + validation.

The kernel path on CPU runs the Pallas bodies in interpret mode, so these
tests prove the exact code the TPU compiles agrees with the jnp reference.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology as T
from repro.core.strategies import (
    ConsensusStrategy,
    DecayStrategy,
    PeriodicStrategy,
    make_strategy,
)
from repro.core.decay import exponential_decay
from repro.kernels import dispatch
from repro.kernels.consensus_step import consensus_step_pallas
from repro.kernels.decay_accum import decay_accum_pallas

TAUS = np.array([4, 2, 1])  # heterogeneous -> variation masks are non-trivial


def _grads(m=3, seed=0):
    k1, k2 = jax.random.split(jax.random.key(seed))
    # leaf sizes chosen so n = 5*7 + 11 = 46: not a multiple of any block_n
    return {
        "w": jax.random.normal(k1, (m, 5, 7)),
        "b": jax.random.normal(k2, (m, 11)),
    }


# --- backend resolution -------------------------------------------------------

def test_resolve_backend():
    assert dispatch.resolve_backend("jnp") == "jnp"
    assert dispatch.resolve_backend("interpret") == "interpret"
    expected = "pallas" if jax.default_backend() == "tpu" else "jnp"
    assert dispatch.resolve_backend("auto") == expected
    with pytest.raises(ValueError):
        dispatch.resolve_backend("cuda")


def test_strategy_rejects_unknown_backend():
    with pytest.raises(ValueError):
        PeriodicStrategy(tau=2, m=3, backend="nope")


def test_make_strategy_passes_backend_through():
    s = make_strategy("periodic", tau=4, m=3, backend="interpret")
    assert s.backend == "interpret"
    s = make_strategy("decay", tau=4, m=3, backend="jnp")
    assert s.backend == "jnp"


# --- flat <-> tree plumbing ---------------------------------------------------

def test_stacked_ravel_spec_views():
    g = _grads()
    flat, spec = dispatch.stacked_ravel_spec(g)
    assert flat.shape == (3, 5 * 7 + 11)
    one = spec.unravel_one(flat[1])
    np.testing.assert_array_equal(one["w"], g["w"][1])
    np.testing.assert_array_equal(one["b"], g["b"][1])
    np.testing.assert_array_equal(spec.ravel_one(one), flat[1])
    back = spec.unravel(flat)
    np.testing.assert_array_equal(back["w"], g["w"])


def test_unravel_cache_is_bounded_lru():
    dispatch.clear_caches()
    assert len(dispatch._UNRAVEL_CACHE) == 0
    dispatch.stacked_ravel(_grads())
    assert len(dispatch._UNRAVEL_CACHE) == 1
    dispatch.stacked_ravel(_grads(seed=1))  # same structure -> cache hit
    assert len(dispatch._UNRAVEL_CACHE) == 1
    for i in range(dispatch._UNRAVEL_CACHE_MAXSIZE + 5):
        dispatch.stacked_ravel({"x": jnp.zeros((2, i + 1))})
    assert len(dispatch._UNRAVEL_CACHE) <= dispatch._UNRAVEL_CACHE_MAXSIZE
    dispatch.clear_caches()
    assert len(dispatch._UNRAVEL_CACHE) == 0


def test_stacked_ravel_roundtrip():
    g = _grads()
    flat, unravel = dispatch.stacked_ravel(g)
    assert flat.shape == (3, 5 * 7 + 11)
    back = unravel(flat)
    np.testing.assert_array_equal(back["w"], g["w"])
    np.testing.assert_array_equal(back["b"], g["b"])


def test_stacked_ravel_rejects_mismatched_leading_axis():
    bad = {"w": jnp.ones((3, 2)), "b": jnp.ones((4, 2))}
    with pytest.raises(ValueError):
        dispatch.stacked_ravel(bad)


# --- dispatched primitive parity ---------------------------------------------

@pytest.mark.parametrize("n", [100, 46, 4096])  # includes non-multiple-of-block
def test_decay_accum_interpret_matches_jnp_1d(n):
    ks = jax.random.split(jax.random.key(n), 2)
    acc = jax.random.normal(ks[0], (n,))
    g = jax.random.normal(ks[1], (n,))
    a = dispatch.decay_accum(acc, g, 0.7, backend="jnp")
    b = dispatch.decay_accum(acc, g, 0.7, backend="interpret", block_n=64)
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_decay_accum_per_agent_coefficients():
    m, n = 5, 37  # n deliberately not a multiple of block_n
    ks = jax.random.split(jax.random.key(0), 3)
    acc = jax.random.normal(ks[0], (m, n))
    g = jax.random.normal(ks[1], (m, n))
    d = jax.random.uniform(ks[2], (m,))
    a = dispatch.decay_accum(acc, g, d, backend="jnp")
    b = dispatch.decay_accum(acc, g, d, backend="interpret", block_n=16)
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_scale_rows_parity():
    g = jax.random.normal(jax.random.key(1), (4, 53))
    w = jnp.asarray([1.0, 0.5, 0.0, 2.0])
    a = dispatch.scale_rows(g, w, backend="jnp")
    b = dispatch.scale_rows(g, w, backend="interpret", block_n=32)
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_scale_rows_rejects_bad_shapes():
    with pytest.raises(ValueError):
        dispatch.scale_rows(jnp.zeros(6), jnp.ones(3), backend="jnp")  # 1-D g
    with pytest.raises(ValueError):
        dispatch.scale_rows(jnp.zeros((3, 6)), jnp.ones(4), backend="jnp")


def test_consensus_mix_parity():
    m, n = 6, 101  # non-multiple of block_n
    topo = T.ring(m)
    p = jnp.asarray(T.mixing_matrix(topo, 0.25), jnp.float32)
    g = jax.random.normal(jax.random.key(2), (m, n))
    a = dispatch.consensus_mix(g, p, backend="jnp")
    b = dispatch.consensus_mix(g, p, backend="interpret", block_n=32)
    np.testing.assert_allclose(a, b, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16])
def test_consensus_mix_low_precision_parity(dtype):
    """Kernel path must accumulate the gossip matmul in fp32 like the jnp
    reference — bf16/fp16 gradient buffers must not drift between backends."""
    m, n = 6, 101
    topo = T.ring(m)
    p = jnp.asarray(T.mixing_matrix(topo, 0.25), jnp.float32)
    g = jax.random.normal(jax.random.key(11), (m, n)).astype(dtype)
    a = dispatch.consensus_mix(g, p, backend="jnp")
    b = dispatch.consensus_mix(g, p, backend="interpret", block_n=32)
    np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decay_accum_low_precision_parity(dtype):
    acc = jax.random.normal(jax.random.key(0), (77,)).astype(dtype)
    g = jax.random.normal(jax.random.key(1), (77,)).astype(dtype)
    a = dispatch.decay_accum(acc, g, 0.3, backend="jnp")
    b = dispatch.decay_accum(acc, g, 0.3, backend="interpret", block_n=16)
    if dtype == jnp.bfloat16:
        # fp32 accumulation then one bf16 rounding: bit-identical paths
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )
    else:
        # fp32: XLA may fuse the FMA differently between paths (1-ulp)
        np.testing.assert_allclose(a, b, atol=1e-6)


# --- row_mean (server averaging, eq. 11) --------------------------------------

@pytest.mark.parametrize("n", [46, 128, 1000])  # includes non-multiple-of-block
def test_row_mean_parity(n):
    g = jax.random.normal(jax.random.key(n), (5, n))
    a = dispatch.row_mean(g, backend="jnp")
    b = dispatch.row_mean(g, backend="interpret", block_n=32)
    assert a.shape == (n,)
    np.testing.assert_allclose(a, b, atol=1e-6)
    np.testing.assert_allclose(a, jnp.mean(g, axis=0), atol=1e-6)


def test_row_mean_bf16_accumulates_fp32():
    # 33 agents at values that round badly in bf16: an fp32 accumulation of
    # the mean is exact here, a bf16 one is not.
    g = jnp.full((33, 40), 0.1, jnp.bfloat16)
    a = dispatch.row_mean(g, backend="jnp")
    b = dispatch.row_mean(g, backend="interpret", block_n=16)
    np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)
    )


def test_row_mean_rejects_1d():
    with pytest.raises(ValueError):
        dispatch.row_mean(jnp.zeros(8), backend="jnp")


# --- flat_opt_update (fused optimizer pass) -----------------------------------

def _opt_buffers(m=4, n=53, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    p = jax.random.normal(ks[0], (m, n))
    g = jax.random.normal(ks[1], (m, n))
    w = jax.random.uniform(ks[2], (m,))
    return p, g, w


def test_flat_opt_update_sgd_matches_decay_accum():
    p, g, w = _opt_buffers()
    out, state = dispatch.flat_opt_update(p, g, w, {}, kind="sgd", lr=0.1,
                                          backend="jnp")
    ref = dispatch.decay_accum(p, g, -0.1 * w, backend="jnp")
    np.testing.assert_allclose(out, ref, atol=1e-7)
    assert state == {}


@pytest.mark.parametrize("nesterov", [False, True])
def test_flat_opt_update_momentum_parity(nesterov):
    p, g, w = _opt_buffers(seed=1)
    state = {"mu": jnp.zeros(p.shape, jnp.float32)}
    pa, pb, sa, sb = p, p, dict(state), dict(state)
    for _ in range(3):
        pa, sa = dispatch.flat_opt_update(
            pa, g, w, sa, kind="momentum", lr=0.05, beta=0.9,
            nesterov=nesterov, backend="jnp")
        pb, sb = dispatch.flat_opt_update(
            pb, g, w, sb, kind="momentum", lr=0.05, beta=0.9,
            nesterov=nesterov, backend="interpret", block_n=16)
    np.testing.assert_allclose(pa, pb, atol=1e-5)
    np.testing.assert_allclose(sa["mu"], sb["mu"], atol=1e-5)


def test_flat_opt_update_momentum_matches_tree_optimizer():
    """The flat momentum rule must equal repro.optim.optimizers.momentum
    applied leaf-wise (with w folded into the grads first)."""
    from repro.optim.optimizers import momentum as tree_momentum

    p, g, w = _opt_buffers(seed=2)
    opt = tree_momentum(0.9)
    tree_state = opt.init(p)
    flat_state = {"mu": jnp.zeros(p.shape, jnp.float32)}
    pt, pf = p, p
    for _ in range(3):
        wg = g * w[:, None]
        pt, tree_state = opt.apply(wg, tree_state, pt, 0.05)
        pf, flat_state = dispatch.flat_opt_update(
            pf, g, w, flat_state, kind="momentum", lr=0.05, beta=0.9,
            backend="jnp")
    np.testing.assert_allclose(pt, pf, atol=1e-6)


def test_flat_opt_update_adam_parity():
    p, g, w = _opt_buffers(seed=3)
    z = jnp.zeros(p.shape, jnp.float32)
    sa = {"mu": z, "nu": z, "t": jnp.zeros((), jnp.int32)}
    sb = {"mu": z, "nu": z, "t": jnp.zeros((), jnp.int32)}
    pa, pb = p, p
    for _ in range(3):
        pa, sa = dispatch.flat_opt_update(pa, g, w, sa, kind="adam", lr=0.01,
                                          backend="jnp")
        pb, sb = dispatch.flat_opt_update(pb, g, w, sb, kind="adam", lr=0.01,
                                          backend="interpret", block_n=16)
    assert int(sa["t"]) == int(sb["t"]) == 3
    np.testing.assert_allclose(pa, pb, atol=1e-5)
    np.testing.assert_allclose(sa["nu"], sb["nu"], atol=1e-5)


def test_flat_opt_update_adam_matches_tree_adamw():
    from repro.optim.optimizers import adamw

    p, g, w = _opt_buffers(seed=4)
    opt = adamw(b1=0.9, b2=0.95, eps=1e-8)
    tree_state = opt.init(p)
    z = jnp.zeros(p.shape, jnp.float32)
    flat_state = {"mu": z, "nu": z, "t": jnp.zeros((), jnp.int32)}
    pt, pf = p, p
    for _ in range(3):
        wg = g * w[:, None]
        pt, tree_state = opt.apply(wg, tree_state, pt, 0.01)
        pf, flat_state = dispatch.flat_opt_update(
            pf, g, w, flat_state, kind="adam", lr=0.01, b1=0.9, b2=0.95,
            backend="jnp")
    np.testing.assert_allclose(pt, pf, atol=1e-6)


def test_flat_opt_update_validation():
    p = jnp.zeros((3, 8))
    with pytest.raises(ValueError):
        dispatch.flat_opt_update(p, p, 1.0, {}, kind="rmsprop", lr=0.1)
    with pytest.raises(ValueError):  # missing state buffer
        dispatch.flat_opt_update(p, p, 1.0, {}, kind="momentum", lr=0.1,
                                 backend="jnp")
    with pytest.raises(ValueError):  # non-fp32 accumulator
        dispatch.flat_opt_update(
            p, p, 1.0, {"mu": jnp.zeros((3, 8), jnp.bfloat16)},
            kind="momentum", lr=0.1, backend="jnp")
    with pytest.raises(ValueError):  # shape mismatch
        dispatch.flat_opt_update(p, jnp.zeros((3, 9)), 1.0, {}, kind="sgd",
                                 lr=0.1, backend="jnp")


# --- strategy-level parity (the load-bearing contract) ------------------------

def _strategy_pairs():
    topo = T.ring(3)
    builders = {
        "masked": lambda b: PeriodicStrategy(tau=4, taus=TAUS, backend=b),
        "decay": lambda b: DecayStrategy(
            tau=4, taus=TAUS, decay=exponential_decay(0.9), backend=b
        ),
        "consensus": lambda b: ConsensusStrategy(
            tau=4, topo=topo, eps=0.3, rounds=2, taus=TAUS, backend=b
        ),
        "consensus-unfused": lambda b: ConsensusStrategy(
            tau=4, topo=topo, eps=0.3, rounds=2, taus=TAUS, fused=False, backend=b
        ),
    }
    return [(k, mk("jnp"), mk("interpret")) for k, mk in builders.items()]


@pytest.mark.parametrize("name,s_jnp,s_kern", _strategy_pairs(),
                         ids=lambda v: v if isinstance(v, str) else "")
def test_transform_kernel_matches_jnp(name, s_jnp, s_kern):
    g = _grads()
    for offset in range(4):
        a = s_jnp.transform(g, offset)
        b = s_kern.transform(g, offset)
        np.testing.assert_allclose(a["w"], b["w"], atol=1e-5, err_msg=f"{name}@{offset}")
        np.testing.assert_allclose(a["b"], b["b"], atol=1e-5, err_msg=f"{name}@{offset}")


@pytest.mark.parametrize("name,s_jnp,s_kern", _strategy_pairs(),
                         ids=lambda v: v if isinstance(v, str) else "")
def test_flat_update_kernel_matches_jnp(name, s_jnp, s_kern):
    g = _grads(seed=3)
    g_flat, _ = dispatch.stacked_ravel(g)
    params = jax.random.normal(jax.random.key(4), g_flat.shape)
    for offset in range(4):
        a = s_jnp.flat_update(params, g_flat, offset, 0.05)
        b = s_kern.flat_update(params, g_flat, offset, 0.05)
        np.testing.assert_allclose(a, b, atol=1e-5, err_msg=f"{name}@{offset}")


def test_flat_update_matches_tree_reference():
    """Fused flat path == transform-then-SGD in tree space (same semantics)."""
    s = DecayStrategy(tau=4, taus=TAUS, decay=exponential_decay(0.8), backend="jnp")
    g = _grads(seed=5)
    params = _grads(seed=6)
    eta = 0.1
    p_flat, unravel = dispatch.stacked_ravel(params)
    g_flat, _ = dispatch.stacked_ravel(g)
    for offset in range(4):
        tg = s.transform(g, offset)
        ref = jax.tree.map(lambda p, gg: p - eta * gg, params, tg)
        out = unravel(s.flat_update(p_flat, g_flat, offset, eta, backend="interpret"))
        np.testing.assert_allclose(ref["w"], out["w"], atol=1e-5)
        np.testing.assert_allclose(ref["b"], out["b"], atol=1e-5)


@pytest.mark.parametrize("name,s_jnp,s_kern", _strategy_pairs(),
                         ids=lambda v: v if isinstance(v, str) else "")
def test_local_update_kernel_matches_jnp(name, s_jnp, s_kern):
    """The driver entry point: tree-space local step, both backends agree."""
    g = _grads(seed=8)
    params = _grads(seed=9)
    for offset in range(4):
        a = s_jnp.local_update(params, g, offset, 0.05)
        b = s_kern.local_update(params, g, offset, 0.05)
        np.testing.assert_allclose(a["w"], b["w"], atol=1e-5, err_msg=f"{name}@{offset}")
        np.testing.assert_allclose(a["b"], b["b"], atol=1e-5, err_msg=f"{name}@{offset}")


def test_transform_inside_scan_traced_offset():
    """Kernel path must trace under lax.scan with a traced period offset."""
    s = DecayStrategy(tau=4, taus=TAUS, decay=exponential_decay(0.9),
                      backend="interpret")
    s_ref = DecayStrategy(tau=4, taus=TAUS, decay=exponential_decay(0.9),
                          backend="jnp")
    g = _grads(seed=7)
    g_flat, _ = dispatch.stacked_ravel(g)

    def run(strat):
        def body(carry, offset):
            return strat.flat_update(carry, g_flat, offset, 0.1), None
        out, _ = jax.lax.scan(body, jnp.zeros_like(g_flat), jnp.arange(4))
        return out

    np.testing.assert_allclose(run(s_ref), run(s), atol=1e-5)


# --- traced-mask strategy copies (the variation axis, with_mask) ---------------

def _mask_pairs():
    """(name, static strategy, with_mask copy holding a jnp mask) triples.

    The copy's mask is the traced-constructor output (``mask_from_taus`` fed
    a float32 schedule, exactly what the sweep's taus axis produces) — the
    static strategy keeps its numpy-at-init mask.
    """
    from repro.core.variation import mask_from_taus

    topo = T.ring(3)
    builders = {
        "masked": lambda: PeriodicStrategy(tau=4, taus=TAUS, backend="jnp"),
        "decay": lambda: DecayStrategy(
            tau=4, taus=TAUS, decay=exponential_decay(0.9), backend="jnp"
        ),
        "consensus": lambda: ConsensusStrategy(
            tau=4, topo=topo, eps=0.3, rounds=2, taus=TAUS, backend="jnp"
        ),
    }
    out = []
    for name, mk in builders.items():
        s = mk()
        mask = mask_from_taus(jnp.asarray(TAUS, jnp.float32), 4)
        out.append((name, s, s.with_mask(mask)))
    return out


@pytest.mark.parametrize("name,s_static,s_traced", _mask_pairs(),
                         ids=lambda v: v if isinstance(v, str) else "")
def test_with_mask_bitwise_on_jnp(name, s_static, s_traced):
    """Traced-mask copy == static-numpy-mask strategy, BIT-identical on the
    jnp reference path (same ops on the same values, op by op)."""
    g = _grads(seed=12)
    params = _grads(seed=13)
    g_flat, _ = dispatch.stacked_ravel(g)
    p_flat, _ = dispatch.stacked_ravel(params)
    for offset in range(4):
        a = s_static.transform(g, offset)
        b = s_traced.transform(g, offset)
        np.testing.assert_array_equal(
            np.asarray(a["w"]), np.asarray(b["w"]), err_msg=f"{name}@{offset}"
        )
        a = s_static.local_update(params, g, offset, 0.05)
        b = s_traced.local_update(params, g, offset, 0.05)
        np.testing.assert_array_equal(
            np.asarray(a["b"]), np.asarray(b["b"]), err_msg=f"{name}@{offset}"
        )
        a = s_static.flat_update(p_flat, g_flat, offset, 0.05)
        b = s_traced.flat_update(p_flat, g_flat, offset, 0.05)
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"{name}@{offset}"
        )


@pytest.mark.parametrize("name,s_static,s_traced", _mask_pairs(),
                         ids=lambda v: v if isinstance(v, str) else "")
def test_with_mask_interpret_parity(name, s_static, s_traced):
    """The same traced-mask copies through the interpret kernels stay within
    ulp tolerance of the static kernels (weights are kernel operands either
    way, so only harness-level fusion may differ)."""
    import copy as _copy

    g = _grads(seed=14)
    g_flat, _ = dispatch.stacked_ravel(g)
    params = jax.random.normal(jax.random.key(15), g_flat.shape)
    s_static_k = _copy.copy(s_static)
    s_traced_k = _copy.copy(s_traced)
    object.__setattr__(s_static_k, "backend", "interpret")
    object.__setattr__(s_traced_k, "backend", "interpret")
    for offset in range(4):
        a = s_static_k.flat_update(params, g_flat, offset, 0.05)
        b = s_traced_k.flat_update(params, g_flat, offset, 0.05)
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-6, err_msg=f"{name}@{offset}"
        )


def test_consensus_with_mask_refolds_tables():
    """with_mask must refold the per-offset masked mixing tables against the
    new mask — matching what the constructor builds for the same schedule."""
    topo = T.ring(3)
    base = ConsensusStrategy(tau=4, topo=topo, eps=0.3, rounds=2, m=3)
    ref = ConsensusStrategy(tau=4, topo=topo, eps=0.3, rounds=2, taus=TAUS)
    copy_ = base.with_mask(
        jnp.asarray(ref.mask), taus=TAUS
    )
    np.testing.assert_array_equal(np.asarray(copy_.mask), ref.mask)
    np.testing.assert_allclose(np.asarray(copy_.p_e_masked), ref.p_e_masked,
                               atol=0)
    np.testing.assert_allclose(np.asarray(copy_.p_masked), ref.p_masked,
                               atol=0)
    # untouched statics survive the copy
    np.testing.assert_array_equal(copy_.p_e, base.p_e)
    assert copy_.rounds == base.rounds and copy_.backend == base.backend


def test_with_mask_refreshes_host_accounting():
    """A with_mask copy given the concrete schedule keeps the comm
    accounting consistent (c2 = sum(taus), truncated variant included)."""
    base = PeriodicStrategy(tau=4, m=3)
    copy_ = base.with_mask(
        jnp.asarray(PeriodicStrategy._build_mask(TAUS, 4)), taus=TAUS
    )
    ref = PeriodicStrategy(tau=4, taus=TAUS)
    assert copy_.comm_events_per_period() == ref.comm_events_per_period()
    for n in range(4):
        assert (copy_.comm_events_partial_period(n)
                == ref.comm_events_partial_period(n))
    # without a schedule the copy keeps the previous static accounting
    assert (base.with_mask(jnp.asarray(base.mask)).comm_events_per_period()
            == base.comm_events_per_period())


# --- kernel shape/dtype validation (no silent mis-tiling) ---------------------

def test_decay_accum_rejects_shape_mismatch():
    with pytest.raises(ValueError):
        decay_accum_pallas(jnp.zeros(8), jnp.zeros(9), 1.0, interpret=True)
    with pytest.raises(ValueError):
        decay_accum_pallas(jnp.zeros((2, 4)), jnp.zeros((2, 4)), 1.0, interpret=True)


def test_decay_accum_rejects_dtype_mismatch():
    with pytest.raises(ValueError):
        decay_accum_pallas(jnp.zeros(8, jnp.float32), jnp.zeros(8, jnp.bfloat16),
                           1.0, interpret=True)


def test_decay_accum_rejects_nonscalar_d():
    with pytest.raises(ValueError):
        decay_accum_pallas(jnp.zeros(8), jnp.zeros(8), jnp.ones(2), interpret=True)


def test_consensus_rejects_bad_mixing_shape():
    g = jnp.zeros((4, 16))
    with pytest.raises(ValueError):
        consensus_step_pallas(g, jnp.eye(5), interpret=True)  # would mis-tile
    with pytest.raises(ValueError):
        consensus_step_pallas(g, jnp.eye(3), interpret=True)
    with pytest.raises(ValueError):
        consensus_step_pallas(jnp.zeros(16), jnp.eye(4), interpret=True)


def test_consensus_rejects_integer_mixing():
    with pytest.raises(ValueError):
        consensus_step_pallas(jnp.zeros((4, 16)), jnp.eye(4, dtype=jnp.int32),
                              interpret=True)


def test_dispatch_decay_accum_rejects_bad_d_rank():
    with pytest.raises(ValueError):
        dispatch.decay_accum(jnp.zeros(8), jnp.zeros(8), jnp.ones(3), backend="jnp")


def test_dispatch_consensus_mix_rejects_bad_shapes():
    with pytest.raises(ValueError):
        dispatch.consensus_mix(jnp.zeros((4, 8)), jnp.eye(6), backend="jnp")


# --- consensus_gather (sparse neighbor-list gossip) ---------------------------


def _knn_inputs(m=12, k=4, n=101, eps_frac=0.5, seed=4):
    topo = T.knn_ring(m, k)
    nl = T.neighbor_list(topo)
    p = T.mixing_matrix(topo, eps_frac / topo.max_degree)
    w = T.neighbor_weights_from_matrix(nl, p)
    g = jax.random.normal(jax.random.key(seed), (m, n))
    return topo, nl, p, w, g


def test_consensus_gather_interpret_matches_jnp():
    _, nl, _, w, g = _knn_inputs(n=101)  # non-multiple of block_n
    a = dispatch.consensus_gather(g, nl.idx, w, backend="jnp")
    b = dispatch.consensus_gather(g, nl.idx, w, backend="interpret", block_n=32)
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_consensus_gather_bitwise_vs_full_list_reference():
    """The parity contract: the k-sparse sequential FMA chain is bit-identical
    (eager) to evaluating the full (k_max = m) list in index order — padding
    adds 0.0 * row, a floating-point no-op."""
    topo, nl, p, w, g = _knn_inputs()
    full = T.neighbor_list(topo, k_max=topo.m)
    w_full = T.neighbor_weights_from_matrix(full, p)
    with jax.disable_jit():
        sparse = dispatch.consensus_gather(g, nl.idx, w, backend="jnp")
        ref = dispatch.consensus_gather(g, full.idx, w_full, backend="jnp")
    np.testing.assert_array_equal(np.asarray(sparse), np.asarray(ref))


def test_consensus_gather_matches_dense_mix():
    topo, nl, p, w, g = _knn_inputs()
    sparse = dispatch.consensus_gather(g, nl.idx, w, backend="jnp")
    dense = dispatch.consensus_mix(g, jnp.asarray(p, jnp.float32), backend="jnp")
    np.testing.assert_allclose(sparse, dense, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16])
def test_consensus_gather_low_precision_accumulates_fp32(dtype):
    _, nl, _, w, g = _knn_inputs(n=64)
    g = g.astype(dtype)
    a = dispatch.consensus_gather(g, nl.idx, w, backend="jnp")
    b = dispatch.consensus_gather(g, nl.idx, w, backend="interpret", block_n=32)
    assert a.dtype == dtype
    np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)
    )


def test_consensus_gather_vmaps_shared_and_per_run_weights():
    _, nl, p, w, g = _knn_inputs(n=33)
    gs = jnp.stack([g, 2.0 * g, -g])
    shared = dispatch.consensus_gather(gs, nl.idx, w, backend="jnp")
    for s in range(3):
        np.testing.assert_array_equal(
            np.asarray(shared[s]),
            np.asarray(dispatch.consensus_gather(gs[s], nl.idx, w, backend="jnp")),
        )
    ws = jnp.stack([w, 0.5 * w, jnp.zeros_like(w)])
    per_run = dispatch.consensus_gather(gs, nl.idx, ws, backend="jnp")
    for s in range(3):
        np.testing.assert_array_equal(
            np.asarray(per_run[s]),
            np.asarray(
                dispatch.consensus_gather(gs[s], nl.idx, ws[s], backend="jnp")
            ),
        )


def test_consensus_gather_padded_rows_contribute_nothing():
    topo, nl, p, w, g = _knn_inputs()
    wide = T.neighbor_list(topo, k_max=nl.k_max + 3)
    w_wide = T.neighbor_weights_from_matrix(wide, p)
    with jax.disable_jit():
        tight = dispatch.consensus_gather(g, nl.idx, w, backend="jnp")
        padded = dispatch.consensus_gather(g, wide.idx, w_wide, backend="jnp")
    np.testing.assert_array_equal(np.asarray(tight), np.asarray(padded))


def test_consensus_gather_rejects_bad_shapes():
    _, nl, _, w, g = _knn_inputs()
    with pytest.raises(ValueError):
        dispatch.consensus_gather(g, nl.idx[:-1], w[:-1], backend="jnp")
    with pytest.raises(ValueError):
        dispatch.consensus_gather(g, nl.idx, w[:, :-1], backend="jnp")
    with pytest.raises(ValueError):
        dispatch.consensus_gather(g, nl.idx.astype(jnp.float32), w, backend="jnp")
    with pytest.raises(ValueError):
        dispatch.consensus_gather(g[0], nl.idx, w, backend="jnp")
    from repro.kernels.consensus_gather import consensus_gather_pallas

    with pytest.raises(ValueError):
        consensus_gather_pallas(g, jnp.asarray(nl.idx), jnp.asarray(w), block_n=0)
