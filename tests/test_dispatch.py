"""Backend-dispatch layer: kernel (interpret) vs pure-jnp parity + validation.

The kernel path on CPU runs the Pallas bodies in interpret mode, so these
tests prove the exact code the TPU compiles agrees with the jnp reference.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology as T
from repro.core.strategies import (
    ConsensusStrategy,
    DecayStrategy,
    PeriodicStrategy,
    make_strategy,
)
from repro.core.decay import exponential_decay
from repro.kernels import dispatch
from repro.kernels.consensus_step import consensus_step_pallas
from repro.kernels.decay_accum import decay_accum_pallas

TAUS = np.array([4, 2, 1])  # heterogeneous -> variation masks are non-trivial


def _grads(m=3, seed=0):
    k1, k2 = jax.random.split(jax.random.key(seed))
    # leaf sizes chosen so n = 5*7 + 11 = 46: not a multiple of any block_n
    return {
        "w": jax.random.normal(k1, (m, 5, 7)),
        "b": jax.random.normal(k2, (m, 11)),
    }


# --- backend resolution -------------------------------------------------------

def test_resolve_backend():
    assert dispatch.resolve_backend("jnp") == "jnp"
    assert dispatch.resolve_backend("interpret") == "interpret"
    expected = "pallas" if jax.default_backend() == "tpu" else "jnp"
    assert dispatch.resolve_backend("auto") == expected
    with pytest.raises(ValueError):
        dispatch.resolve_backend("cuda")


def test_strategy_rejects_unknown_backend():
    with pytest.raises(ValueError):
        PeriodicStrategy(tau=2, m=3, backend="nope")


def test_make_strategy_passes_backend_through():
    s = make_strategy("periodic", tau=4, m=3, backend="interpret")
    assert s.backend == "interpret"
    s = make_strategy("decay", tau=4, m=3, backend="jnp")
    assert s.backend == "jnp"


# --- flat <-> tree plumbing ---------------------------------------------------

def test_stacked_ravel_roundtrip():
    g = _grads()
    flat, unravel = dispatch.stacked_ravel(g)
    assert flat.shape == (3, 5 * 7 + 11)
    back = unravel(flat)
    np.testing.assert_array_equal(back["w"], g["w"])
    np.testing.assert_array_equal(back["b"], g["b"])


def test_stacked_ravel_rejects_mismatched_leading_axis():
    bad = {"w": jnp.ones((3, 2)), "b": jnp.ones((4, 2))}
    with pytest.raises(ValueError):
        dispatch.stacked_ravel(bad)


# --- dispatched primitive parity ---------------------------------------------

@pytest.mark.parametrize("n", [100, 46, 4096])  # includes non-multiple-of-block
def test_decay_accum_interpret_matches_jnp_1d(n):
    ks = jax.random.split(jax.random.key(n), 2)
    acc = jax.random.normal(ks[0], (n,))
    g = jax.random.normal(ks[1], (n,))
    a = dispatch.decay_accum(acc, g, 0.7, backend="jnp")
    b = dispatch.decay_accum(acc, g, 0.7, backend="interpret", block_n=64)
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_decay_accum_per_agent_coefficients():
    m, n = 5, 37  # n deliberately not a multiple of block_n
    ks = jax.random.split(jax.random.key(0), 3)
    acc = jax.random.normal(ks[0], (m, n))
    g = jax.random.normal(ks[1], (m, n))
    d = jax.random.uniform(ks[2], (m,))
    a = dispatch.decay_accum(acc, g, d, backend="jnp")
    b = dispatch.decay_accum(acc, g, d, backend="interpret", block_n=16)
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_scale_rows_parity():
    g = jax.random.normal(jax.random.key(1), (4, 53))
    w = jnp.asarray([1.0, 0.5, 0.0, 2.0])
    a = dispatch.scale_rows(g, w, backend="jnp")
    b = dispatch.scale_rows(g, w, backend="interpret", block_n=32)
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_scale_rows_rejects_bad_shapes():
    with pytest.raises(ValueError):
        dispatch.scale_rows(jnp.zeros(6), jnp.ones(3), backend="jnp")  # 1-D g
    with pytest.raises(ValueError):
        dispatch.scale_rows(jnp.zeros((3, 6)), jnp.ones(4), backend="jnp")


def test_consensus_mix_parity():
    m, n = 6, 101  # non-multiple of block_n
    topo = T.ring(m)
    p = jnp.asarray(T.mixing_matrix(topo, 0.25), jnp.float32)
    g = jax.random.normal(jax.random.key(2), (m, n))
    a = dispatch.consensus_mix(g, p, backend="jnp")
    b = dispatch.consensus_mix(g, p, backend="interpret", block_n=32)
    np.testing.assert_allclose(a, b, atol=1e-5)


# --- strategy-level parity (the load-bearing contract) ------------------------

def _strategy_pairs():
    topo = T.ring(3)
    builders = {
        "masked": lambda b: PeriodicStrategy(tau=4, taus=TAUS, backend=b),
        "decay": lambda b: DecayStrategy(
            tau=4, taus=TAUS, decay=exponential_decay(0.9), backend=b
        ),
        "consensus": lambda b: ConsensusStrategy(
            tau=4, topo=topo, eps=0.3, rounds=2, taus=TAUS, backend=b
        ),
        "consensus-unfused": lambda b: ConsensusStrategy(
            tau=4, topo=topo, eps=0.3, rounds=2, taus=TAUS, fused=False, backend=b
        ),
    }
    return [(k, mk("jnp"), mk("interpret")) for k, mk in builders.items()]


@pytest.mark.parametrize("name,s_jnp,s_kern", _strategy_pairs(),
                         ids=lambda v: v if isinstance(v, str) else "")
def test_transform_kernel_matches_jnp(name, s_jnp, s_kern):
    g = _grads()
    for offset in range(4):
        a = s_jnp.transform(g, offset)
        b = s_kern.transform(g, offset)
        np.testing.assert_allclose(a["w"], b["w"], atol=1e-5, err_msg=f"{name}@{offset}")
        np.testing.assert_allclose(a["b"], b["b"], atol=1e-5, err_msg=f"{name}@{offset}")


@pytest.mark.parametrize("name,s_jnp,s_kern", _strategy_pairs(),
                         ids=lambda v: v if isinstance(v, str) else "")
def test_flat_update_kernel_matches_jnp(name, s_jnp, s_kern):
    g = _grads(seed=3)
    g_flat, _ = dispatch.stacked_ravel(g)
    params = jax.random.normal(jax.random.key(4), g_flat.shape)
    for offset in range(4):
        a = s_jnp.flat_update(params, g_flat, offset, 0.05)
        b = s_kern.flat_update(params, g_flat, offset, 0.05)
        np.testing.assert_allclose(a, b, atol=1e-5, err_msg=f"{name}@{offset}")


def test_flat_update_matches_tree_reference():
    """Fused flat path == transform-then-SGD in tree space (same semantics)."""
    s = DecayStrategy(tau=4, taus=TAUS, decay=exponential_decay(0.8), backend="jnp")
    g = _grads(seed=5)
    params = _grads(seed=6)
    eta = 0.1
    p_flat, unravel = dispatch.stacked_ravel(params)
    g_flat, _ = dispatch.stacked_ravel(g)
    for offset in range(4):
        tg = s.transform(g, offset)
        ref = jax.tree.map(lambda p, gg: p - eta * gg, params, tg)
        out = unravel(s.flat_update(p_flat, g_flat, offset, eta, backend="interpret"))
        np.testing.assert_allclose(ref["w"], out["w"], atol=1e-5)
        np.testing.assert_allclose(ref["b"], out["b"], atol=1e-5)


@pytest.mark.parametrize("name,s_jnp,s_kern", _strategy_pairs(),
                         ids=lambda v: v if isinstance(v, str) else "")
def test_local_update_kernel_matches_jnp(name, s_jnp, s_kern):
    """The driver entry point: tree-space local step, both backends agree."""
    g = _grads(seed=8)
    params = _grads(seed=9)
    for offset in range(4):
        a = s_jnp.local_update(params, g, offset, 0.05)
        b = s_kern.local_update(params, g, offset, 0.05)
        np.testing.assert_allclose(a["w"], b["w"], atol=1e-5, err_msg=f"{name}@{offset}")
        np.testing.assert_allclose(a["b"], b["b"], atol=1e-5, err_msg=f"{name}@{offset}")


def test_transform_inside_scan_traced_offset():
    """Kernel path must trace under lax.scan with a traced period offset."""
    s = DecayStrategy(tau=4, taus=TAUS, decay=exponential_decay(0.9),
                      backend="interpret")
    s_ref = DecayStrategy(tau=4, taus=TAUS, decay=exponential_decay(0.9),
                          backend="jnp")
    g = _grads(seed=7)
    g_flat, _ = dispatch.stacked_ravel(g)

    def run(strat):
        def body(carry, offset):
            return strat.flat_update(carry, g_flat, offset, 0.1), None
        out, _ = jax.lax.scan(body, jnp.zeros_like(g_flat), jnp.arange(4))
        return out

    np.testing.assert_allclose(run(s_ref), run(s), atol=1e-5)


# --- kernel shape/dtype validation (no silent mis-tiling) ---------------------

def test_decay_accum_rejects_shape_mismatch():
    with pytest.raises(ValueError):
        decay_accum_pallas(jnp.zeros(8), jnp.zeros(9), 1.0, interpret=True)
    with pytest.raises(ValueError):
        decay_accum_pallas(jnp.zeros((2, 4)), jnp.zeros((2, 4)), 1.0, interpret=True)


def test_decay_accum_rejects_dtype_mismatch():
    with pytest.raises(ValueError):
        decay_accum_pallas(jnp.zeros(8, jnp.float32), jnp.zeros(8, jnp.bfloat16),
                           1.0, interpret=True)


def test_decay_accum_rejects_nonscalar_d():
    with pytest.raises(ValueError):
        decay_accum_pallas(jnp.zeros(8), jnp.zeros(8), jnp.ones(2), interpret=True)


def test_consensus_rejects_bad_mixing_shape():
    g = jnp.zeros((4, 16))
    with pytest.raises(ValueError):
        consensus_step_pallas(g, jnp.eye(5), interpret=True)  # would mis-tile
    with pytest.raises(ValueError):
        consensus_step_pallas(g, jnp.eye(3), interpret=True)
    with pytest.raises(ValueError):
        consensus_step_pallas(jnp.zeros(16), jnp.eye(4), interpret=True)


def test_consensus_rejects_integer_mixing():
    with pytest.raises(ValueError):
        consensus_step_pallas(jnp.zeros((4, 16)), jnp.eye(4, dtype=jnp.int32),
                              interpret=True)


def test_dispatch_decay_accum_rejects_bad_d_rank():
    with pytest.raises(ValueError):
        dispatch.decay_accum(jnp.zeros(8), jnp.zeros(8), jnp.ones(3), backend="jnp")


def test_dispatch_consensus_mix_rejects_bad_shapes():
    with pytest.raises(ValueError):
        dispatch.consensus_mix(jnp.zeros((4, 8)), jnp.eye(6), backend="jnp")
