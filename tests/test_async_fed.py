"""Unit tests for the async federation layer (repro.core.async_fed).

Covers the delay-schedule generators, the masked FedBuff server step, the
AsyncStrategy driver seams on both flat drivers, the arrival-aware ledger
accounting (including the partial-period undercount fix), the ``delay``
sweep axis with its one-compile retrace pin, and the zero-delay bitwise
sync-equivalence contract (DESIGN.md §15).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.accounting import CostLedger
from repro.core.async_fed import (
    DELAY_DISTRIBUTIONS,
    AsyncStrategy,
    DelaySchedule,
    delay_axis_key,
    delay_draws,
    kofm_schedule,
    make_schedule,
    masked_server_step,
    renewal_arrivals,
    stale_weight_table,
    sync_weight_table,
)
from repro.core.decay import exponential_decay
from repro.core.fmarl import FmarlConfig, run_fmarl
from repro.core.strategies import PeriodicStrategy, make_strategy
from repro.kernels import dispatch
from repro.rl.env import FIGURE_EIGHT
from repro.rl.fedrl import FedRLConfig, fedrl_ledger, run_fedrl_core
from repro.utils.pytree import tree_l2_norm


# --- delay schedules -----------------------------------------------------------

def test_zero_delay_schedule_is_synchronous():
    s = make_schedule("deterministic", 0.0, 5, 7, seed=3)
    np.testing.assert_array_equal(s.arrive, np.ones((5, 7), np.float32))
    np.testing.assert_array_equal(s.age, np.zeros((5, 7), np.float32))
    assert s.total_arrivals() == 35


def test_deterministic_lag_skips_exactly_d_boundaries():
    s = make_schedule("deterministic", 2.0, 3, 9, seed=0)
    # delay 2: arrive once `since > 2`, i.e. every third boundary (t=2,5,8)
    expect = np.zeros((3, 9), np.float32)
    expect[:, 2::3] = 1.0
    np.testing.assert_array_equal(s.arrive, expect)
    # the arriving column carries age since-1 = 2
    assert np.all(s.age[:, 2::3] == 2.0)


def test_renewal_arrivals_age_counts_boundaries_since_last_sync():
    delays = np.array([[0.0, 2.0, 0.0, 0.0]], np.float32)
    arrive, age = renewal_arrivals(delays)
    np.testing.assert_array_equal(arrive, [[1.0, 0.0, 1.0, 1.0]])
    np.testing.assert_array_equal(age, [[0.0, 0.0, 1.0, 0.0]])


def test_delay_draws_distributions_differ_and_clip():
    key = delay_axis_key(0)
    for name, dist_id in DELAY_DISTRIBUTIONS.items():
        d = np.asarray(delay_draws(dist_id, 1.5, 4, 6, key))
        assert d.shape == (4, 6)
        assert np.all(d >= 0) and np.all(d <= 6), name
    det = np.asarray(delay_draws(0, 1.5, 4, 6, key))
    assert np.all(det == 2.0)  # round(1.5 + eps)


def test_make_schedule_unknown_distribution():
    with pytest.raises(KeyError, match="unknown delay distribution"):
        make_schedule("poisson", 1.0, 3, 4)


def test_schedule_matches_delay_axis_stream():
    """Host schedules and the traced delay axis share the same uniforms."""
    seed, m, T = 1234, 5, 6
    s = make_schedule("geometric", 0.5, m, T, seed=seed)
    d = delay_draws(DELAY_DISTRIBUTIONS["geometric"], 0.5, m, T,
                    delay_axis_key(seed))
    arrive, age = renewal_arrivals(d)
    np.testing.assert_array_equal(s.arrive, np.asarray(arrive))
    np.testing.assert_array_equal(s.age, np.asarray(age))


def test_kofm_schedule_exact_k_arrivals():
    s = kofm_schedule(6, 8, 4, seed=2)
    assert s.k == 4
    np.testing.assert_array_equal(s.arrivals_per_period(),
                                  np.full(8, 4, int))


# --- weights -------------------------------------------------------------------

def test_stale_weight_table_validates_a3_over_ages():
    t = stale_weight_table(exponential_decay(0.9), 4)
    assert t.shape == (5,)
    assert t[0] == 1.0 and np.all(np.diff(t) <= 1e-7)
    with pytest.raises(ValueError, match="staleness decay"):
        stale_weight_table(lambda j: jnp.asarray(j, jnp.float32) + 2.0, 4)


def test_sync_weight_table_zero_delay_is_exactly_one():
    s = make_schedule("deterministic", 0.0, 4, 5, seed=0)
    t = stale_weight_table(exponential_decay(0.7), 5)
    w = np.asarray(sync_weight_table(s.arrive, s.age, t))
    np.testing.assert_array_equal(w, np.ones((4, 5), np.float32))


def test_sync_weight_table_decays_with_age():
    arrive = np.ones((1, 3), np.float32)
    age = np.array([[0.0, 1.0, 2.0]], np.float32)
    t = stale_weight_table(exponential_decay(0.81), 3)
    w = np.asarray(sync_weight_table(arrive, age, t))
    np.testing.assert_allclose(w, t[None, :3])


# --- masked server step --------------------------------------------------------

def test_masked_server_step_is_the_weighted_mean():
    flat = np.arange(12, dtype=np.float32).reshape(3, 4)
    w = np.array([1.0, 0.0, 0.5], np.float32)
    row, denom = masked_server_step(jnp.asarray(flat), jnp.asarray(w),
                                    backend="jnp")
    assert float(denom) == 1.5
    np.testing.assert_allclose(
        np.asarray(row), (flat * w[:, None]).sum(0) / 1.5, rtol=1e-6
    )


def test_masked_server_step_all_ones_bitwise_row_mean():
    flat = jax.random.normal(jax.random.key(1), (7, 129), jnp.float32)
    row, denom = masked_server_step(flat, jnp.ones(7), backend="jnp")
    ref = dispatch.row_mean(flat, backend="jnp")
    np.testing.assert_array_equal(np.asarray(row), np.asarray(ref))
    assert float(denom) == 7.0


def test_flat_sync_no_arrivals_keeps_reference_and_replicas():
    sched = DelaySchedule(
        arrive=np.zeros((3, 2), np.float32),
        age=np.zeros((3, 2), np.float32),
        n_periods=2, label="none",
    )
    strat = AsyncStrategy(tau=2, schedule=sched, backend="jnp")
    flat = jax.random.normal(jax.random.key(0), (3, 8), jnp.float32)
    cs = strat.init_comm_state(flat)
    out, cs2 = strat.flat_sync(flat, cs, period=0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(flat))
    np.testing.assert_array_equal(np.asarray(cs2["ref"]),
                                  np.asarray(cs["ref"]))


def test_flat_sync_rebases_only_arrivals():
    arrive = np.array([[1.0], [0.0]], np.float32)
    sched = DelaySchedule(arrive=arrive, age=np.zeros((2, 1), np.float32),
                          n_periods=1, label="half")
    strat = AsyncStrategy(tau=1, schedule=sched, backend="jnp")
    flat = jnp.asarray([[2.0, 4.0], [10.0, 20.0]], jnp.float32)
    cs = strat.init_comm_state(flat)
    out, cs2 = strat.flat_sync(flat, cs, period=0)
    # only agent 0 arrived: the server row is its contribution alone
    np.testing.assert_allclose(np.asarray(cs2["ref"]), [2.0, 4.0])
    np.testing.assert_allclose(np.asarray(out)[0], [2.0, 4.0])
    np.testing.assert_allclose(np.asarray(out)[1], [10.0, 20.0])
    # server reads come from the reference, not the divergent replicas
    np.testing.assert_allclose(np.asarray(strat.server_row(out, cs2)),
                               [2.0, 4.0])


def test_flat_sync_requires_period_index():
    sched = make_schedule("deterministic", 0.0, 3, 2, seed=0)
    strat = AsyncStrategy(tau=2, schedule=sched, backend="jnp")
    flat = jnp.zeros((3, 4))
    with pytest.raises(ValueError, match="period index"):
        strat.flat_sync(flat, strat.init_comm_state(flat))


# --- strategy construction / validation ----------------------------------------

def test_async_strategy_validation():
    sched = make_schedule("geometric", 0.5, 4, 3, seed=0)
    with pytest.raises(TypeError, match="DelaySchedule"):
        AsyncStrategy(tau=2, schedule="nope")
    with pytest.raises(ValueError, match="m=7"):
        AsyncStrategy(tau=2, schedule=sched, m=7)
    with pytest.raises(ValueError, match="taus carries"):
        AsyncStrategy(tau=2, schedule=sched, taus=np.ones(3, int))
    strat = AsyncStrategy(tau=2, schedule=sched)
    assert strat.is_async and not strat.uniform_sync
    assert strat.m == 4
    with pytest.raises(NotImplementedError, match="per_period|span"):
        strat.comm_events_per_period()
    with pytest.raises(ValueError, match="schedule covers"):
        strat.validate_horizon(4)


def test_async_strategy_rejects_compressed_comm():
    from repro.comm import identity, topk

    sched = make_schedule("deterministic", 0.0, 3, 2, seed=0)
    strat = AsyncStrategy(tau=2, schedule=sched)
    strat.with_comm(identity())  # dense pass-through is fine
    with pytest.raises(NotImplementedError, match="compressed"):
        strat.with_comm(topk(4))


def test_make_strategy_async_kind():
    sched = make_schedule("heavytail", 1.5, 5, 4, seed=0)
    strat = make_strategy("async", tau=3, schedule=sched,
                          stale_decay=exponential_decay(0.9), backend="jnp")
    assert isinstance(strat, AsyncStrategy)
    assert strat.name.startswith("async(heavytail(1.5)")
    assert strat.sync_weights.shape == (5, 4)


# --- ledger accounting (the partial-period undercount fix) ---------------------

def _payload(n=10):
    return n


def test_async_ledger_bills_exact_arrivals():
    sched = make_schedule("geometric", 0.5, 5, 6, seed=11)
    strat = AsyncStrategy(tau=3, schedule=sched)
    ledger = CostLedger()
    ledger.add_periods(strat, 6, _payload())
    assert ledger.c1_events == sched.total_arrivals()
    assert ledger.c1_bytes == sched.total_arrivals() * 10 * 4
    assert ledger.c2_events == 5 * 3 * 6


def test_async_ledger_sequential_spans_are_disjoint():
    sched = make_schedule("heavytail", 1.5, 4, 8, seed=5)
    strat = AsyncStrategy(tau=2, schedule=sched)
    split = CostLedger()
    split.add_periods(strat, 3, _payload())
    split.add_periods(strat, 5, _payload())
    whole = CostLedger()
    whole.add_periods(strat, 8, _payload())
    assert split.c1_events == whole.c1_events == sched.total_arrivals()
    assert split.c1_bytes == whole.c1_bytes
    assert split.periods_billed == 8


def test_async_partial_period_bills_no_uplinks():
    """The undercount fix: a buffered partial tail reaches no boundary, so
    it must bill zero C1 events — the uniform base class billed m here."""
    sched = make_schedule("geometric", 0.5, 5, 4, seed=7)
    strat = AsyncStrategy(tau=3, schedule=sched)
    ledger = CostLedger()
    ledger.add_periods(strat, 4, _payload())
    before = ledger.c1_events
    ledger.add_partial_period(strat, 2, _payload())
    assert ledger.c1_events == before            # no uplinks mid-period
    assert ledger.c2_events == 5 * 3 * 4 + 5 * 2  # local updates still billed
    assert ledger.total_bytes() == sched.total_arrivals() * 10 * 4


def test_async_span_outside_schedule_raises():
    sched = make_schedule("deterministic", 1.0, 3, 4, seed=0)
    strat = AsyncStrategy(tau=2, schedule=sched)
    ledger = CostLedger()
    ledger.add_periods(strat, 4, _payload())
    with pytest.raises(ValueError, match="outside the schedule"):
        ledger.add_periods(strat, 1, _payload())


def test_uniform_strategy_accounting_unchanged():
    """The cursor must not perturb the closed-form uniform arithmetic."""
    strat = PeriodicStrategy(tau=4, m=6)
    ledger = CostLedger()
    ledger.add_periods(strat, 3, _payload())
    ledger.add_periods(strat, 2, _payload())
    assert ledger.c1_events == 6 * 5
    assert ledger.c2_events == 6 * 4 * 5
    assert ledger.periods_billed == 5
    ledger.add_partial_period(strat, 2, _payload())
    assert ledger.c1_events == 6 * 6  # uniform tail still polls every agent


def test_fedrl_ledger_async_end_to_end():
    tau, epochs, elen, mb = 3, 2, 12, 4
    n_periods = (epochs * (elen // mb)) // tau
    sched = make_schedule("geometric", 0.5, 7, n_periods, seed=1234)
    cfg = FedRLConfig(
        env=FIGURE_EIGHT,
        strategy=AsyncStrategy(tau=tau, schedule=sched, backend="jnp"),
        n_epochs=epochs, epoch_len=elen, minibatch=mb,
    )
    from repro.rl.fedrl import policy_payload_elems

    ledger = fedrl_ledger(cfg)
    assert ledger.c1_events == sched.total_arrivals(0, n_periods)
    assert ledger.total_bytes() == (
        sched.total_arrivals(0, n_periods) * policy_payload_elems() * 4
    )


# --- drivers -------------------------------------------------------------------

def _toy_grad_fn(params, key, agent_idx, step):
    g = jax.tree.map(
        lambda leaf: leaf + 0.1 * jax.random.normal(
            jax.random.fold_in(key, 0), leaf.shape
        ),
        params,
    )
    return g, {"loss": tree_l2_norm(params) ** 2}


_TOY_INIT = {"w": jnp.ones((6,)), "b": jnp.ones((2,))}


def test_fmarl_async_zero_delay_bitwise_vs_sync():
    sched = make_schedule("deterministic", 0.0, 4, 3, seed=9)
    cfg_a = FmarlConfig(
        strategy=AsyncStrategy(tau=2, schedule=sched, backend="jnp"),
        eta=0.05, n_periods=3,
    )
    cfg_s = FmarlConfig(
        strategy=PeriodicStrategy(tau=2, m=4, backend="jnp"),
        eta=0.05, n_periods=3,
    )
    key = jax.random.key(0)
    st_a, m_a, _ = run_fmarl(cfg_a, _TOY_INIT, _toy_grad_fn, key,
                             lambda p, k: p)
    st_s, m_s, _ = run_fmarl(cfg_s, _TOY_INIT, _toy_grad_fn, key,
                             lambda p, k: p)
    for a, b in zip(jax.tree.leaves(st_a.server_params),
                    jax.tree.leaves(st_s.server_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(m_a["server_grad_sq_norm"]),
        np.asarray(m_s["server_grad_sq_norm"]),
    )


def test_fmarl_async_delayed_runs_and_diverges_replicas():
    sched = make_schedule("geometric", 0.5, 4, 3, seed=9)
    cfg = FmarlConfig(
        strategy=AsyncStrategy(tau=2, schedule=sched, backend="jnp"),
        eta=0.05, n_periods=3,
    )
    state, metrics, ledger = run_fmarl(cfg, _TOY_INIT, _toy_grad_fn,
                                       jax.random.key(0), lambda p, k: p)
    assert metrics["server_grad_sq_norm"].shape == (3,)
    assert np.all(np.isfinite(np.asarray(metrics["server_grad_sq_norm"])))
    assert ledger.c1_events == sched.total_arrivals()


def test_fmarl_async_horizon_guard():
    sched = make_schedule("deterministic", 0.0, 4, 2, seed=0)
    cfg = FmarlConfig(
        strategy=AsyncStrategy(tau=2, schedule=sched, backend="jnp"),
        eta=0.05, n_periods=5,
    )
    with pytest.raises(ValueError, match="schedule covers 2"):
        run_fmarl(cfg, _TOY_INIT, _toy_grad_fn, jax.random.key(0))


def _tiny_fedrl_pair(tau=3, epochs=2, elen=12, mb=4):
    n_periods = (epochs * (elen // mb)) // tau
    sched = make_schedule("deterministic", 0.0, 7, n_periods, seed=1234)
    cfg_a = FedRLConfig(
        env=FIGURE_EIGHT,
        strategy=AsyncStrategy(tau=tau, schedule=sched, backend="jnp"),
        n_epochs=epochs, epoch_len=elen, minibatch=mb,
    )
    cfg_s = FedRLConfig(
        env=FIGURE_EIGHT,
        strategy=PeriodicStrategy(tau=tau, m=7, backend="jnp"),
        n_epochs=epochs, epoch_len=elen, minibatch=mb,
    )
    return cfg_a, cfg_s


def test_fedrl_async_zero_delay_bitwise_vs_sync_eager():
    """The DESIGN.md §15 contract on the real driver: eager op-by-op, the
    zero-delay async flat carry and the synchronous tree driver must agree
    bit for bit (weights exactly 1.0, correction factor exactly 1.0)."""
    cfg_a, cfg_s = _tiny_fedrl_pair()
    key = jax.random.key(0)
    sa, ma = run_fedrl_core(cfg_a, key)
    ss, ms = run_fedrl_core(cfg_s, key)
    for a, b in zip(jax.tree.leaves(sa), jax.tree.leaves(ss)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(ma["server_grad_sq_norm"]),
        np.asarray(ms["server_grad_sq_norm"]),
    )


def test_fedrl_async_delayed_jits():
    tau, epochs, elen, mb = 3, 2, 12, 4
    n_periods = (epochs * (elen // mb)) // tau
    sched = make_schedule("heavytail", 1.5, 7, n_periods, seed=1234)
    cfg = FedRLConfig(
        env=FIGURE_EIGHT,
        strategy=AsyncStrategy(tau=tau, schedule=sched, backend="jnp"),
        n_epochs=epochs, epoch_len=elen, minibatch=mb,
    )
    _, metrics = jax.jit(lambda k: run_fedrl_core(cfg, k))(jax.random.key(0))
    assert np.all(np.isfinite(np.asarray(metrics["server_grad_sq_norm"])))


# --- sweep axis ----------------------------------------------------------------

def _delay_spec(points, seeds=(0,)):
    from repro.sweep import SweepAxis, SweepSpec

    tau, epochs, elen, mb = 3, 2, 12, 4
    n_periods = (epochs * (elen // mb)) // tau
    sched = make_schedule("deterministic", 0.0, 7, n_periods, seed=1234)
    base = FedRLConfig(
        env=FIGURE_EIGHT,
        strategy=AsyncStrategy(tau=tau, schedule=sched, backend="jnp"),
        n_epochs=epochs, epoch_len=elen, minibatch=mb,
    )
    return SweepSpec(
        name="test-delay", base=base, seeds=seeds,
        vmapped=(SweepAxis(name="delay", values=points),),
    )


def test_delay_axis_requires_async_strategy():
    from repro.sweep.overrides import override_delay

    cfg = FedRLConfig(env=FIGURE_EIGHT,
                      strategy=PeriodicStrategy(tau=2, m=7),
                      n_epochs=1, epoch_len=4, minibatch=2)
    with pytest.raises(TypeError, match="AsyncStrategy"):
        override_delay(cfg, jnp.asarray([0.0, 1.0]))
    sched = make_schedule("deterministic", 0.0, 7, 1, seed=0)
    acfg = dataclasses.replace(
        cfg, strategy=AsyncStrategy(tau=2, schedule=sched)
    )
    with pytest.raises(ValueError, match="2-vector"):
        override_delay(acfg, jnp.asarray(1.0))


def test_delay_axis_matches_concrete_schedules():
    """One vmapped sweep over three delay families reproduces each family's
    standalone (concretely scheduled) run — arrivals and numerics agree."""
    from repro.sweep import run_sweep

    points = ((0.0, 1.0), (1.0, 0.5), (2.0, 1.5))
    spec = _delay_spec(points)
    res = run_sweep(spec)
    swept = res.metrics["base"]["server_grad_sq_norm"]  # (3, 1, epochs)

    names = {0: "deterministic", 1: "geometric", 2: "heavytail"}
    base = spec.base
    for d, (dist_id, param) in enumerate(points):
        sched = make_schedule(names[int(dist_id)], param, 7,
                              base.strategy.schedule.n_periods,
                              seed=base.eval_seed)
        cfg = dataclasses.replace(
            base, strategy=AsyncStrategy(tau=base.strategy.tau,
                                         schedule=sched, backend="jnp")
        )
        _, m = jax.jit(lambda k, c=cfg: run_fedrl_core(c, k))(
            jax.random.key(0)
        )
        np.testing.assert_allclose(
            np.asarray(swept[d, 0]),
            np.asarray(m["server_grad_sq_norm"]),
            rtol=1e-5, atol=1e-7,
        )


def test_delay_sweep_compiles_exactly_once(assert_max_compiles):
    """Retrace pin: one compile per delay-distribution *static point* — the
    whole distribution axis is value-traced, so three families share one."""
    from repro.sweep import run_sweep

    spec = _delay_spec(((0.0, 1.0), (1.0, 0.5), (2.0, 1.5)), seeds=(0, 1))
    _, n = assert_max_compiles(1, run_sweep, spec)
    assert n == 1


# --- K-of-m buffer-size axis ---------------------------------------------------

def test_kofm_arrivals_matches_host_schedule_bitwise():
    """The traced selection scan replays the numpy constructor exactly —
    arrivals AND recorded ages, including index tie-breaks."""
    from repro.core.async_fed import kofm_arrivals

    for dist, param, m, T, k, seed in (
        ("geometric", 0.5, 7, 9, 3, 0),
        ("heavytail", 1.5, 11, 6, 5, 42),
        ("deterministic", 2.0, 5, 8, 2, 7),
        ("deterministic", 0.0, 4, 5, 4, 0),   # k = m, zero lag: synchronous
    ):
        host = kofm_schedule(m, T, k, dist=dist, param=param, seed=seed)
        lag = delay_draws(
            DELAY_DISTRIBUTIONS[dist], param, m, T, delay_axis_key(seed)
        )
        arrive, age = jax.jit(kofm_arrivals)(lag, float(k))
        np.testing.assert_array_equal(np.asarray(arrive), host.arrive)
        np.testing.assert_array_equal(np.asarray(age), host.age)


def test_kofm_arrivals_traced_k_vmaps():
    """K enters only a rank comparison: one trace serves every buffer size,
    and each period admits exactly k agents."""
    from repro.core.async_fed import kofm_arrivals

    lag = delay_draws(1, 0.5, 7, 5, delay_axis_key(0))
    arr = jax.jit(jax.vmap(lambda k: kofm_arrivals(lag, k)[0]))(
        jnp.asarray([1.0, 3.0, 7.0])
    )
    np.testing.assert_array_equal(
        np.asarray(arr).sum(axis=1), np.tile([[1.0], [3.0], [7.0]], (1, 5))
    )


def _k_spec(points, seeds=(0,)):
    from repro.sweep import SweepAxis, SweepSpec

    tau, epochs, elen, mb = 3, 2, 12, 4
    n_periods = (epochs * (elen // mb)) // tau
    sched = kofm_schedule(7, n_periods, 3, dist="geometric", param=0.5,
                          seed=1234)
    base = FedRLConfig(
        env=FIGURE_EIGHT,
        strategy=AsyncStrategy(tau=tau, schedule=sched, backend="jnp"),
        n_epochs=epochs, epoch_len=elen, minibatch=mb,
    )
    return SweepSpec(
        name="test-k", base=base, seeds=seeds,
        vmapped=(SweepAxis(name="k", values=points),),
    )


def test_k_axis_requires_kofm_base():
    from repro.sweep.overrides import override_k

    cfg = FedRLConfig(env=FIGURE_EIGHT,
                      strategy=PeriodicStrategy(tau=2, m=7),
                      n_epochs=1, epoch_len=4, minibatch=2)
    with pytest.raises(TypeError, match="AsyncStrategy"):
        override_k(cfg, jnp.asarray(3.0))
    # renewal schedules don't record a buffer size: reject
    sched = make_schedule("geometric", 0.5, 7, 1, seed=0)
    acfg = dataclasses.replace(
        cfg, strategy=AsyncStrategy(tau=2, schedule=sched)
    )
    with pytest.raises(ValueError, match="K-of-m"):
        override_k(acfg, jnp.asarray(3.0))


def test_k_axis_matches_concrete_schedules():
    """One vmapped sweep over three buffer sizes reproduces each size's
    standalone (concretely scheduled) run — selection and numerics agree."""
    from repro.sweep import run_sweep

    points = (1.0, 3.0, 7.0)
    spec = _k_spec(points)
    res = run_sweep(spec)
    swept = res.metrics["base"]["server_grad_sq_norm"]  # (3, 1, epochs)

    base = spec.base
    for d, k in enumerate(points):
        sched = kofm_schedule(7, base.strategy.schedule.n_periods, int(k),
                              dist="geometric", param=0.5,
                              seed=base.eval_seed)
        cfg = dataclasses.replace(
            base, strategy=AsyncStrategy(tau=base.strategy.tau,
                                         schedule=sched, backend="jnp")
        )
        _, m = jax.jit(lambda key, c=cfg: run_fedrl_core(c, key))(
            jax.random.key(0)
        )
        np.testing.assert_allclose(
            np.asarray(swept[d, 0]),
            np.asarray(m["server_grad_sq_norm"]),
            rtol=1e-5, atol=1e-7,
        )


def test_k_sweep_compiles_exactly_once(assert_max_compiles):
    """Retrace pin: the buffer-size axis is value-only — every K (and every
    seed) shares one compile."""
    from repro.sweep import run_sweep

    spec = _k_spec((1.0, 3.0, 7.0), seeds=(0, 1))
    _, n = assert_max_compiles(1, run_sweep, spec)
    assert n == 1
