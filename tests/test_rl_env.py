"""Traffic MARL environment invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.rl.env import FIGURE_EIGHT, MERGE, OBS_DIM, env_reset, env_step, get_obs


@pytest.mark.parametrize("cfg", [FIGURE_EIGHT, MERGE])
def test_reset_and_step_invariants(cfg):
    state = env_reset(cfg, jax.random.key(0))
    assert state.x.shape == (cfg.n_vehicles,)
    for i in range(50):
        act = jnp.sin(jnp.arange(cfg.n_rl) + i * 0.1)
        state, r, _ = env_step(cfg, state, act)
        assert bool(jnp.all((state.x >= 0) & (state.x < cfg.length)))
        assert bool(jnp.all(state.v >= 0)) and bool(jnp.all(state.v <= cfg.v_max))
        assert -cfg.crash_penalty <= float(r) <= 1.0


@pytest.mark.parametrize("cfg", [FIGURE_EIGHT, MERGE])
def test_obs_shape_and_range(cfg):
    state = env_reset(cfg, jax.random.key(1))
    obs = get_obs(cfg, state)
    assert obs.shape == (cfg.n_rl, OBS_DIM)
    assert bool(jnp.all(jnp.isfinite(obs)))
    assert bool(jnp.all((obs >= -0.01) & (obs <= 1.5)))


def test_idm_background_flow_is_stable_without_rl():
    """Pure-IDM traffic (zero RL accel clamps to IDM braking zone) keeps moving."""
    cfg = FIGURE_EIGHT
    state = env_reset(cfg, jax.random.key(2))
    speeds = []
    for _ in range(400):
        state, r, _ = env_step(cfg, state, jnp.zeros(cfg.n_rl))
        speeds.append(float(state.v.mean()))
    assert speeds[-1] > 0.3, "traffic should reach a moving steady state"
    assert not bool(state.crashed)


def test_full_brake_causes_slowdown():
    cfg = FIGURE_EIGHT
    state = env_reset(cfg, jax.random.key(3))
    for _ in range(100):
        state, _, _ = env_step(cfg, state, jnp.zeros(cfg.n_rl))
    v_free = float(state.v.mean())
    for _ in range(60):
        state, _, _ = env_step(cfg, state, -jnp.ones(cfg.n_rl))
    assert float(state.v.mean()) < v_free


def test_env_is_jittable_and_deterministic():
    cfg = FIGURE_EIGHT
    step = jax.jit(lambda s, a: env_step(cfg, s, a))
    s1 = env_reset(cfg, jax.random.key(4))
    s2 = env_reset(cfg, jax.random.key(4))
    for i in range(20):
        a = jnp.cos(jnp.arange(cfg.n_rl) * (i + 1.0))
        s1, r1, _ = step(s1, a)
        s2, r2, _ = step(s2, a)
    np.testing.assert_allclose(s1.x, s2.x)
    assert float(r1) == float(r2)
