"""The paper's theory as executable checks: T1-T5 + utility (eqs. 13-27)."""
import numpy as np
import pytest

from repro.core.bounds import (
    SgdConstants,
    consensus_bound_t5,
    decay_bound_numeric,
    decay_bound_t4,
    eta_condition,
    max_feasible_eta,
    periodic_bound_t1,
    resource_cost_consensus,
    resource_cost_periodic,
    utility,
    variation_bound_t2,
    variation_bound_t2_empirical,
)
from repro.core.decay import exponential_decay, no_decay
from repro.core import topology as T

C = SgdConstants(L=1.0, sigma2=2.0, beta=0.5, eta=0.01, K=100_000, m=7,
                 f0_minus_finf=10.0)


def test_t1_increases_with_tau():
    """Remark after T1: periodic averaging enlarges the bound with tau."""
    vals = [periodic_bound_t1(C, t) for t in (1, 5, 10, 20)]
    assert all(a < b for a, b in zip(vals, vals[1:]))


def test_t2_increases_with_nu():
    """Remark after T2: bound grows monotonically with the mean nu."""
    vals = [variation_bound_t2(C, 10, nu, 0.0) for nu in (1, 3, 5, 8, 10)]
    assert all(a < b for a, b in zip(vals, vals[1:]))


def test_t2_decreases_with_omega2():
    """Remark after T2: larger variance omega^2 REDUCES the bound."""
    vals = [variation_bound_t2(C, 10, 5.0, w2) for w2 in (0.0, 2.0, 6.0)]
    assert all(a > b for a, b in zip(vals, vals[1:]))


def test_t2_reduces_to_t1_when_no_variation():
    """nu = tau, omega = 0 -> classical periodic averaging (paper remark)."""
    assert np.isclose(variation_bound_t2(C, 8, 8.0, 0.0),
                      periodic_bound_t1(C, 8), rtol=1e-12)


def test_t2_closed_form_matches_empirical_uniform():
    tau = 12
    taus = np.arange(1, tau + 1)  # exactly uniform support
    nu, w2 = taus.mean(), taus.var()
    assert np.isclose(
        variation_bound_t2(C, tau, nu, w2),
        variation_bound_t2_empirical(C, tau, taus),
        rtol=1e-12,
    )


def test_t3_decay_never_worse_than_t2():
    """T3: psi_3 <= psi_1 for any A3 decay function."""
    tau = 10
    taus = np.arange(1, tau + 1)
    base = decay_bound_numeric(C, tau, taus, no_decay())
    for lam in (0.99, 0.95, 0.9, 0.7):
        dec = decay_bound_numeric(C, tau, taus, exponential_decay(lam))
        assert dec <= base + 1e-12, lam


def test_t4_bracket_decreasing_in_lambda():
    """Remark after T4: the bound decreases as lambda decreases."""
    vals = [decay_bound_t4(C, 10, lam) for lam in (0.98, 0.9, 0.7, 0.4)]
    assert all(a > b for a, b in zip(vals, vals[1:]))


def test_t4_approaches_t2_as_lambda_to_1():
    """lambda->1 limit of (22) equals (17) with nu=(1+tau)/2, omega^2 =
    (tau^2-1)/12 (discrete uniform moments). Verified analytically:
    lim bracket = 1 + 3(tau-1)/2 + (tau-1)(tau-2)/3, and 2*lim equals T2's
    bracket. lambda=1-1e-9 is numerically catastrophic (1/(1-lambda)^3), so
    we test at 0.9999 with a matching tolerance."""
    from repro.core.bounds import _common_terms
    tau = 10
    base = _common_terms(C)
    t2 = variation_bound_t2(C, tau, (1 + tau) / 2, (tau**2 - 1) / 12)
    t4 = decay_bound_t4(C, tau, 1 - 1e-4)
    assert np.isclose(t4 - base, t2 - base, rtol=2e-2)
    # analytic limit check
    lim_bracket = 1 + 3 * (tau - 1) / 2 + (tau - 1) * (tau - 2) / 3
    t2_bracket = (-((1 + tau) / 2) ** 2 + (2 * tau + 1) * (1 + tau) / 2
                  - (tau**2 - 1) / 12)
    assert np.isclose(2 * lim_bracket, t2_bracket, rtol=1e-12)


def test_t5_consensus_reduces_third_term():
    topo = T.random_regularish(7, 3, 4, seed=0)
    eps = 0.9 / topo.max_degree
    t1 = periodic_bound_t1(C, 10)
    prev = t1
    for rounds in (1, 2, 4):
        t5 = consensus_bound_t5(C, 10, topo, eps, rounds)
        assert t5 < prev
        prev = t5


def test_t5_larger_mu2_smaller_bound():
    """Paper Fig. 6: mu2=2.5188-style denser nets beat mu2=1.4384-style."""
    sparse = T.random_regularish(9, 3, 4, seed=0)
    dense = T.random_regularish(9, 5, 6, seed=0)
    eps = 0.9 / max(sparse.max_degree, dense.max_degree)
    assert (consensus_bound_t5(C, 10, dense, eps, 1)
            < consensus_bound_t5(C, 10, sparse, eps, 1))


def test_eta_condition_and_max_eta():
    tau = 10
    eta = max_feasible_eta(C, tau)
    c_ok = SgdConstants(**{**C.__dict__, "eta": eta * 0.999})
    c_bad = SgdConstants(**{**C.__dict__, "eta": eta * 1.01})
    assert eta_condition(c_ok, tau) <= 0
    assert eta_condition(c_bad, tau) > 0


def test_resource_cost_eq7_matches_table2_structure():
    """Table II row 'tau=10': m TU/(tau P) uploads, m*tau_i*TU/(tau P) updates.

    With T=1500, U=500, P=250, m=7, tau=10: 2100 C1 and 21000 C2."""
    taus = np.full(7, 10)
    psi0 = resource_cost_periodic(m=7, taus=taus, tau=10, T=1500, U=500, P=250,
                                  c1=1.0, c2=0.0)
    assert np.isclose(psi0, 2100)
    psi0c = resource_cost_periodic(m=7, taus=taus, tau=10, T=1500, U=500, P=250,
                                   c1=0.0, c2=1.0)
    assert np.isclose(psi0c, 21000)


def test_resource_cost_eq27_adds_gossip():
    topo = T.chain(7)
    taus = np.full(7, 10)
    base = resource_cost_periodic(m=7, taus=taus, tau=10, T=1500, U=500, P=250,
                                  c1=1.0, c2=1.0)
    full = resource_cost_consensus(m=7, taus=taus, tau=10, T=1500, U=500, P=250,
                                   c1=1.0, c2=1.0, topo=topo, rounds=1,
                                   w1=1.0, w2=1.0)
    gossip = topo.degrees.sum() * 2 * 1 * 1500 * 500 / 250
    assert np.isclose(full - base, gossip)


def test_utility_prefers_cheap_convergence():
    u_good = utility(psi1=1.0, psi2=10.0, psi0=100.0)
    u_costly = utility(psi1=1.0, psi2=10.0, psi0=1000.0)
    u_worse_conv = utility(psi1=5.0, psi2=10.0, psi0=100.0)
    assert u_good > u_costly and u_good > u_worse_conv
    with pytest.raises(ValueError):
        utility(psi1=1.0, psi2=2.0, psi0=0.0)
