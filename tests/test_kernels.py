"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.ops as ops
import repro.kernels.ref as ref
from repro.core import topology as T
from repro.core.topology import mixing_matrix


def _rand(key, shape, dtype=jnp.float32, scale=0.5):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


@pytest.mark.parametrize("b,t,h,d,chunk", [
    (1, 8, 1, 8, 4),
    (2, 32, 3, 16, 8),
    (2, 64, 2, 64, 16),
    (1, 24, 4, 32, 24),   # single chunk
    (3, 20, 2, 16, 8),    # t not divisible by chunk -> degenerate single chunk
])
def test_wkv6_matches_oracle(b, t, h, d, chunk):
    ks = jax.random.split(jax.random.key(b * t + h), 6)
    r, k, v = (_rand(ks[i], (b, t, h, d)) for i in range(3))
    w = jax.nn.sigmoid(_rand(ks[3], (b, t, h, d))) * 0.5 + 0.45
    u = _rand(ks[4], (h, d))
    s0 = _rand(ks[5], (b, h, d, d), scale=0.1)
    y1, s1 = ops.wkv6(r, k, v, w, u, s0, chunk=chunk)
    y2, s2 = ref.wkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(y1, y2, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(s1, s2, atol=1e-5, rtol=1e-5)


def test_wkv6_state_chaining():
    """Running two halves with carried state == one full run (chunk boundary)."""
    b, t, h, d = 1, 32, 2, 16
    ks = jax.random.split(jax.random.key(7), 5)
    r, k, v = (_rand(ks[i], (b, t, h, d)) for i in range(3))
    w = jax.nn.sigmoid(_rand(ks[3], (b, t, h, d))) * 0.5 + 0.45
    u = _rand(ks[4], (h, d))
    s0 = jnp.zeros((b, h, d, d))
    y_full, s_full = ops.wkv6(r, k, v, w, u, s0, chunk=8)
    y1, s_mid = ops.wkv6(r[:, :16], k[:, :16], v[:, :16], w[:, :16], u, s0, chunk=8)
    y2, s_end = ops.wkv6(r[:, 16:], k[:, 16:], v[:, 16:], w[:, 16:], u, s_mid, chunk=8)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full, atol=1e-5)
    np.testing.assert_allclose(s_end, s_full, atol=1e-5)


@pytest.mark.parametrize("sq,sk,window,bq,bk", [
    (32, 32, None, 16, 16),
    (64, 64, 24, 16, 16),
    (64, 64, 8, 32, 16),    # window smaller than a block
    (128, 128, 48, 32, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_attention_matches_oracle(sq, sk, window, bq, bk, dtype):
    b, h, d = 2, 2, 32
    ks = jax.random.split(jax.random.key(sq + sk + (window or 0)), 3)
    q, k, v = (_rand(ks[i], (b, sq, h, d), dtype) for i in range(3))
    o1 = ops.swa_attention(q, k, v, window=window, block_q=bq, block_kv=bk)
    o2 = ref.swa_attention_ref(q, k, v, window=window)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-6
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=atol)


def test_swa_block_skipping_correct_at_boundaries():
    """Every (window, block) alignment near edges must agree with the oracle."""
    b, h, d = 1, 1, 16
    for window in (16, 17, 31, 33):
        ks = jax.random.split(jax.random.key(window), 3)
        q, k, v = (_rand(ks[i], (b, 64, h, d)) for i in range(3))
        o1 = ops.swa_attention(q, k, v, window=window, block_q=16, block_kv=16)
        o2 = ref.swa_attention_ref(q, k, v, window=window)
        np.testing.assert_allclose(o1, o2, atol=2e-6), window


@pytest.mark.parametrize("m,n,block", [(4, 64, 32), (7, 1000, 128), (16, 4096, 2048)])
def test_consensus_step_matches_oracle(m, n, block):
    topo = T.ring(m)
    p = jnp.asarray(mixing_matrix(topo, 0.9 / topo.max_degree), jnp.float32)
    g = _rand(jax.random.key(m * n), (m, n))
    out = ops.consensus_step(g, p, block_n=block)
    np.testing.assert_allclose(out, ref.consensus_step_ref(g, p), atol=1e-5)


@pytest.mark.parametrize("n,block,d", [(100, 64, 0.5), (4096, 512, 0.98),
                                        (5000, 4096, 0.0), (64, 64, 1.0)])
def test_decay_accum_matches_oracle(n, block, d):
    ks = jax.random.split(jax.random.key(n), 2)
    acc, g = _rand(ks[0], (n,)), _rand(ks[1], (n,))
    out = ops.decay_accum(acc, g, d, block_n=block)
    np.testing.assert_allclose(out, ref.decay_accum_ref(acc, g, d), atol=1e-6)


def test_consensus_step_tree_roundtrip():
    topo = T.ring(5)
    p = jnp.asarray(mixing_matrix(topo, 0.3), jnp.float32)
    g = {"a": _rand(jax.random.key(0), (5, 3, 4)),
         "b": _rand(jax.random.key(1), (5, 7))}
    out = ops.consensus_step_tree(g, p)
    expect = jax.tree.map(lambda l: jnp.tensordot(p, l, axes=1), g)
    np.testing.assert_allclose(out["a"], expect["a"], atol=1e-5)
    np.testing.assert_allclose(out["b"], expect["b"], atol=1e-5)


def test_wkv6_kernel_inside_time_mix():
    """The Pallas wkv6 plugs into the model's time_mix as wkv_impl."""
    import repro.configs as C
    from repro.models import rwkv6 as rw
    cfg = C.get_arch("rwkv6-1.6b").reduced()
    p = rw.init_time_mix(jax.random.key(0), cfg)
    p = jax.tree.map(lambda l: l.value, p, is_leaf=lambda x: hasattr(x, "axes"))
    x = _rand(jax.random.key(1), (2, 16, cfg.d_model))
    st = rw.init_wkv_state(cfg, 2)["tm"]
    y_ref, st_ref = rw.time_mix(p, x, cfg, st)
    y_k, st_k = rw.time_mix(p, x, cfg, st,
                            wkv_impl=lambda *a: ops.wkv6(*a, chunk=8))
    np.testing.assert_allclose(y_k, y_ref, atol=1e-4)
    np.testing.assert_allclose(st_k["wkv"], st_ref["wkv"], atol=1e-4)
