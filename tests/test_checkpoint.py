"""Checkpoint flat-key namespace: escaping, rejection, round-trip property.

Regression suite for the ``_flatten`` separator bug: dict keys containing
``/`` (or spelled like the reserved ``d:``/``l:``/``t:``/``a``/``#`` tags)
used to collide with the flat namespace's structure markers and silently
round-trip wrong. Keys are now percent-escaped (``%`` then ``/``), non-str
and empty keys are rejected, and safe keys keep their exact legacy flat
spelling (old checkpoints still restore).
"""
import numpy as np
import pytest

from repro.checkpoint import restore, save
from repro.checkpoint.io import _escape, _flatten, _unescape

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _tree_equal(a, b):
    if isinstance(a, dict):
        return (isinstance(b, dict) and a.keys() == b.keys()
                and all(_tree_equal(a[k], b[k]) for k in a))
    if isinstance(a, (list, tuple)):
        return (type(a) is type(b) and len(a) == len(b)
                and all(_tree_equal(x, y) for x, y in zip(a, b)))
    a, b = np.asarray(a), np.asarray(b)
    return a.dtype == b.dtype and np.array_equal(a, b)


def _roundtrip(tmp_path, tree):
    save(str(tmp_path), 0, tree)
    back, _ = restore(str(tmp_path))
    assert _tree_equal(tree, back), f"{tree!r} != {back!r}"


def test_slash_key_no_longer_collides_with_nesting(tmp_path):
    # the original corruption: {"a/b": ...} flattened to the same namespace
    # as {"a": {"b": ...}} — now they coexist and both come back intact
    _roundtrip(tmp_path, {"a/b": np.arange(3), "a": {"b": np.ones(2)}})


def test_reserved_looking_keys_roundtrip(tmp_path):
    _roundtrip(tmp_path, {
        "d:x": np.float32(1.0),
        "#l": [np.zeros(2)],
        "t:0": (np.ones(1),),
        "a": np.arange(2),
        "%2F": np.float32(2.0),       # pre-escaped spelling stays distinct
        "100%": {"a/b/c": np.float32(2.5)},
    })


def test_escape_is_injective_on_the_corruption_pairs():
    # the pairs that used to alias: raw '/' vs literal '%2F', '%' vs '%25'
    for a, b in (("a/b", "a%2Fb"), ("x%", "x%25"), ("/", "%2F")):
        assert _escape(a) != _escape(b)
        assert _unescape(_escape(a)) == a
        assert _unescape(_escape(b)) == b


def test_safe_keys_keep_legacy_flat_spelling():
    # identity on '/'-free, '%'-free keys: existing checkpoints' flat keys
    # are byte-identical, so old .npz files still restore
    flat = _flatten({"pi": {"w1": np.zeros(2)}, "step": np.int64(3)})
    assert "/d:pi/d:w1/a" in flat
    assert "/d:step/a" in flat


def test_non_string_keys_rejected(tmp_path):
    with pytest.raises(TypeError, match="keys must be str"):
        save(str(tmp_path), 0, {1: np.zeros(2)})


def test_empty_keys_rejected(tmp_path):
    with pytest.raises(ValueError, match="empty dict keys"):
        save(str(tmp_path), 0, {"": np.zeros(2)})


if HAVE_HYPOTHESIS:
    # printable-ish keys weighted toward the metacharacters the escaper
    # must handle; values/structure drawn recursively
    _keys = st.text(
        alphabet=st.sampled_from(list("ab/%:#.dlt0123456789")),
        min_size=1, max_size=8,
    )
    _leaves = st.one_of(
        st.integers(-100, 100).map(np.int64),
        st.floats(-1e3, 1e3, allow_nan=False).map(np.float32),
        st.just(np.arange(3, dtype=np.float32)),
    )
    _trees = st.recursive(
        _leaves,
        lambda kids: st.one_of(
            st.dictionaries(_keys, kids, min_size=1, max_size=3),
            st.lists(kids, min_size=1, max_size=3),
            st.lists(kids, min_size=1, max_size=3).map(tuple),
        ),
        max_leaves=8,
    )

    @settings(max_examples=40, deadline=None)
    @given(tree=_trees)
    def test_arbitrary_key_roundtrip_property(tmp_path, tree):
        # hypothesis reuses tmp_path across examples: isolate per example
        import tempfile
        with tempfile.TemporaryDirectory(dir=str(tmp_path)) as d:
            _roundtrip(d, {"root": tree})
