"""Fleet rollout engine: loop-reference parity, shape contracts, driver parity.

The contracts pinned here:

* the vmapped heterogeneous-params engine reproduces a per-agent Python-loop
  reference bit-close (same key discipline: one subkey per step split into
  m*B env keys row-major, each env key split into n_rl action keys);
* trajectory buffers come out shaped (m, B, P, ...);
* the flat-carry driver matches the tree-space reference on a heterogeneous
  fleet for decay and consensus strategies;
* the bf16 gradient-buffer mode stays within parity tolerance of fp32.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology as T
from repro.core.decay import exponential_decay
from repro.core.fmarl import FmarlConfig, run_fmarl
from repro.core.strategies import make_strategy
from repro.optim.flat import flat_adam
from repro.rl import (
    FedRLConfig,
    FIGURE_EIGHT,
    fleet_reset,
    fleet_rollout,
    get_scenario,
    init_policy,
    make_fleet,
    minibatch_epoch_grad,
    perturb_params,
    run_fedrl,
)
from repro.rl.env import OBS_DIM, env_step, get_obs
from repro.rl.policy import policy_value, sample_action
from repro.rl.ppo import ppo_loss
from repro.rl.scenarios import SCENARIOS

M, B, P = 5, 4, 6


def _fleet(m=M, scale=0.3, seed=0):
    cfg, params_m = make_fleet("figure_eight", m, jax.random.key(seed),
                               hetero=scale)
    return cfg, params_m


def _policy_m(m=M, seed=2):
    pol = init_policy(jax.random.key(seed), OBS_DIM)
    return jax.tree.map(lambda l: jnp.broadcast_to(l, (m,) + l.shape), pol)


# --- engine vs per-agent Python-loop reference ---------------------------------

def test_fleet_rollout_matches_python_loop_reference():
    cfg, params_m = _fleet()
    pol_m = _policy_m()
    state0 = fleet_reset(cfg, params_m, jax.random.key(1), B)
    state, traj = fleet_rollout(cfg, params_m, pol_m, state0,
                                jax.random.key(3), P)

    # reference: independent per-(agent, env) stepping, same key discipline
    take = lambda tree, *idx: jax.tree.map(lambda l: l[idx], tree)
    ref = {k: np.zeros_like(np.asarray(v)) for k, v in traj.items()}
    final_x = np.zeros_like(np.asarray(state.x))
    for i in range(M):
        pe = take(params_m, i)
        pol = take(pol_m, i)
        for b in range(B):
            st = take(state0, i, b)
            key = jax.random.key(3)
            for t in range(P):
                key, sub = jax.random.split(key)
                k = jax.random.split(sub, M * B)[i * B + b]
                obs = get_obs(cfg, st, params=pe)
                ks = jax.random.split(k, cfg.n_rl)
                acts, logps = jax.vmap(
                    sample_action, in_axes=(None, 0, 0))(pol, obs, ks)
                vals = policy_value(pol, obs)
                st, rew, _ = env_step(cfg, st, acts[:, 0], params=pe)
                ref["obs"][i, b, t] = obs
                ref["act"][i, b, t] = acts
                ref["logp_old"][i, b, t] = logps
                ref["val"][i, b, t] = vals
                ref["rew"][i, b, t] = rew
            final_x[i, b] = st.x
    for name in traj:
        np.testing.assert_allclose(np.asarray(traj[name]), ref[name],
                                   rtol=1e-6, atol=1e-6, err_msg=name)
    np.testing.assert_allclose(np.asarray(state.x), final_x,
                               rtol=1e-6, atol=1e-6)


# --- shape contracts -----------------------------------------------------------

def test_trajectory_shape_contracts():
    cfg, params_m = _fleet()
    pol_m = _policy_m()
    state = fleet_reset(cfg, params_m, jax.random.key(1), B)
    assert state.x.shape == (M, B, cfg.n_vehicles)
    assert state.crashed.shape == (M, B)
    state, traj = fleet_rollout(cfg, params_m, pol_m, state,
                                jax.random.key(3), P)
    n_rl = cfg.n_rl
    assert traj["obs"].shape == (M, B, P, n_rl, OBS_DIM)
    assert traj["act"].shape == (M, B, P, n_rl, 1)
    assert traj["logp_old"].shape == (M, B, P, n_rl)
    assert traj["val"].shape == (M, B, P, n_rl)
    assert traj["rew"].shape == (M, B, P)


def test_heterogeneity_actually_diversifies_the_envs():
    """Distinct per-agent params must yield distinct trajectories; scale=0
    with identical resets would not."""
    cfg, params_m = _fleet(scale=0.4)
    pol_m = _policy_m()
    state = fleet_reset(cfg, params_m, jax.random.key(1), B)
    _, traj = fleet_rollout(cfg, params_m, pol_m, state, jax.random.key(3), P)
    rew = np.asarray(traj["rew"])  # (m, B, P)
    # every pair of agents sees different reward streams
    for i in range(M):
        for j in range(i + 1, M):
            assert not np.allclose(rew[i], rew[j])


# --- scenario registry ---------------------------------------------------------

def test_scenario_registry_presets():
    assert {"figure_eight", "merge", "ring_attenuation", "mixed_vmax"} <= set(
        SCENARIOS
    )
    for name in SCENARIOS:
        sc = get_scenario(name)
        assert sc.cfg.n_rl >= 1
        cfg, params = make_fleet(name, 6, jax.random.key(0))
        assert jax.tree.leaves(params)[0].shape == (6,)
    with pytest.raises(ValueError):
        get_scenario("nope")


def test_perturb_params_scale_and_determinism():
    p0 = perturb_params(FIGURE_EIGHT, jax.random.key(0), 5, 0.0)
    base = FIGURE_EIGHT.default_params()
    for f, leaf in zip(p0._fields, p0):
        np.testing.assert_allclose(leaf, np.full(5, getattr(base, f)))
    p1 = perturb_params(FIGURE_EIGHT, jax.random.key(0), 5, 0.3)
    p2 = perturb_params(FIGURE_EIGHT, jax.random.key(0), 5, 0.3)
    np.testing.assert_allclose(p1.dt, p2.dt)
    assert len(np.unique(np.asarray(p1.dt))) == 5  # genuinely per-agent
    with pytest.raises(ValueError):
        perturb_params(FIGURE_EIGHT, jax.random.key(0), 5, 0.3,
                       fields=("not_a_field",))


# --- minibatch-epoch PPO update ------------------------------------------------

def _fake_batch(key, d=24):
    ks = jax.random.split(key, 5)
    return {
        "obs": jax.random.normal(ks[0], (d, OBS_DIM)),
        "act": 0.1 * jax.random.normal(ks[1], (d, 1)),
        "logp_old": 0.1 * jax.random.normal(ks[2], (d,)),
        "adv": jax.random.normal(ks[3], (d,)),
        "ret": jax.random.normal(ks[4], (d,)),
    }


def test_minibatch_epoch_grad_degenerates_to_value_and_grad():
    params = init_policy(jax.random.key(0), OBS_DIM)
    data = _fake_batch(jax.random.key(1))
    g1, l1 = minibatch_epoch_grad(ppo_loss, params, data, jax.random.key(2),
                                  epochs=1, n_minibatches=1, lr=1e-2)
    l2, g2 = jax.value_and_grad(ppo_loss)(params, data)
    assert float(l1) == float(l2)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_array_equal(a, b)


def test_minibatch_epoch_grad_is_the_sgd_displacement():
    """p - lr * g must equal the endpoint of the inner minibatch SGD loop."""
    lr = 1e-2
    params = init_policy(jax.random.key(0), OBS_DIM)
    data = _fake_batch(jax.random.key(1))
    g, _ = minibatch_epoch_grad(ppo_loss, params, data, jax.random.key(2),
                                epochs=2, n_minibatches=3, lr=lr)
    applied = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
    # replay the inner loop by hand
    p = params
    for k in jax.random.split(jax.random.key(2), 2):
        perm = jax.random.permutation(k, 24)
        shuf = jax.tree.map(lambda x: x[perm], data)
        for mb in range(3):
            batch = jax.tree.map(lambda x: x[mb * 8:(mb + 1) * 8], shuf)
            gg = jax.grad(ppo_loss)(p, batch)
            p = jax.tree.map(lambda a, b: a - lr * b, p, gg)
    for a, b in zip(jax.tree.leaves(applied), jax.tree.leaves(p)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
    with pytest.raises(ValueError):
        minibatch_epoch_grad(ppo_loss, params, data, jax.random.key(2),
                             epochs=1, n_minibatches=7, lr=lr)


# --- federated drivers on a heterogeneous fleet --------------------------------

def _fleet_cfg(strategy, **kw):
    cfg, params_m = _fleet(m=strategy.m)
    base = dict(env=cfg, strategy=strategy, n_epochs=2, epoch_len=40,
                minibatch=20, eta=3e-3, num_envs=B, env_params=params_m)
    base.update(kw)
    return FedRLConfig(**base)


@pytest.mark.parametrize("name", ["decay", "consensus"])
def test_fedrl_fleet_flat_matches_tree_reference(name):
    topo = T.random_regularish(M, 3, 4, seed=0)
    builders = {
        "decay": lambda b: make_strategy(
            "decay", tau=3, m=M, decay=exponential_decay(0.9), backend=b
        ),
        "consensus": lambda b: make_strategy(
            "consensus", tau=3, topo=topo, eps=0.1, rounds=1, m=M, backend=b
        ),
    }
    outs = {}
    for b in ("jnp", "interpret"):
        cfg = _fleet_cfg(builders[name](b))
        _, metrics, _ = run_fedrl(cfg, jax.random.key(0))
        outs[b] = metrics
    np.testing.assert_allclose(outs["jnp"]["nas"], outs["interpret"]["nas"],
                               rtol=1e-4)
    np.testing.assert_allclose(
        outs["jnp"]["server_grad_sq_norm"],
        outs["interpret"]["server_grad_sq_norm"],
        rtol=1e-3,
    )


def test_fedrl_fleet_minibatch_epochs_run_finite():
    strat = make_strategy("periodic", tau=2, m=M)
    cfg = _fleet_cfg(strat, ppo_epochs=2, n_minibatches=4)
    _, metrics, ledger = run_fedrl(cfg, jax.random.key(0))
    assert np.all(np.isfinite(metrics["nas"]))
    assert np.all(np.isfinite(metrics["server_grad_sq_norm"]))
    assert ledger.c1_events > 0


def test_fleet_config_validation():
    strat = make_strategy("periodic", tau=2, m=M)
    cfg_env, params_m = _fleet(m=M + 1)  # wrong agent count
    with pytest.raises(ValueError):
        FedRLConfig(env=cfg_env, strategy=strat, env_params=params_m)
    cfg_env, params_m = _fleet(m=M)
    with pytest.raises(ValueError):  # B*P*n_rl not divisible by minibatches
        FedRLConfig(env=cfg_env, strategy=strat, num_envs=B,
                    env_params=params_m, minibatch=20, n_minibatches=9)
    # legacy validation unchanged: env has 7 RL vehicles, strategy m=5
    with pytest.raises(ValueError):
        FedRLConfig(env=FIGURE_EIGHT, strategy=strat)


# --- bf16 gradient-buffer mode -------------------------------------------------

def test_fmarl_bf16_buffer_parity_tolerance():
    init = {"w": jnp.ones((8, 9)), "b": jnp.ones(7)}

    def grad_fn(p, k, i, step):
        g = jax.tree.map(lambda x: x + 0.05 * jax.random.normal(k, x.shape), p)
        return g, {"loss": sum(jnp.sum(x**2) for x in jax.tree.leaves(p))}

    outs = {}
    for dt in (None, "bfloat16"):
        strat = make_strategy("periodic", tau=3, m=6, backend="jnp")
        cfg = FmarlConfig(strategy=strat, eta=0.05, n_periods=4,
                          optimizer=flat_adam(), buffer_dtype=dt)
        state, metrics, _ = run_fmarl(cfg, init, grad_fn, jax.random.key(0),
                                      lambda p, k: p)
        outs[dt] = np.asarray(metrics["server_grad_sq_norm"])
        # bf16 is storage-only: the returned trees are fp32 views
        assert all(l.dtype == jnp.float32
                   for l in jax.tree.leaves(state.params_m))
    assert np.all(np.isfinite(outs["bfloat16"]))
    np.testing.assert_allclose(outs["bfloat16"], outs[None], rtol=0.05)


def test_fedrl_bf16_buffer_parity_tolerance():
    strat = make_strategy("periodic", tau=2, m=M)
    ref = run_fedrl(_fleet_cfg(strat), jax.random.key(0))[1]
    b16 = run_fedrl(_fleet_cfg(strat, buffer_dtype="bfloat16"),
                    jax.random.key(0))[1]
    assert np.all(np.isfinite(b16["nas"]))
    np.testing.assert_allclose(b16["nas"], ref["nas"], rtol=0.05, atol=5e-3)
    with pytest.raises(TypeError):
        _fleet_cfg(strat, buffer_dtype="not_a_dtype")


# --- opt-in agent-axis sharding ------------------------------------------------

def test_fleet_rollout_under_agent_sharding_rules():
    from repro import sharding

    cfg, params_m = _fleet()
    pol_m = _policy_m()
    state = fleet_reset(cfg, params_m, jax.random.key(1), B)
    _, traj_plain = fleet_rollout(cfg, params_m, pol_m, state,
                                  jax.random.key(3), P)
    mesh = sharding.fleet_mesh(1)  # single-device CI mesh
    rules = sharding.fleet_rules(mesh)
    assert rules.spec(("agents", None), (M, 3)) == jax.sharding.PartitionSpec(
        "agents", None
    )
    with sharding.use_rules(rules):
        _, traj_sharded = fleet_rollout(cfg, params_m, pol_m, state,
                                        jax.random.key(3), P)
    for a, b in zip(jax.tree.leaves(traj_plain), jax.tree.leaves(traj_sharded)):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_fedrl_flat_driver_under_agent_sharding_rules():
    from repro import sharding

    strat = make_strategy("periodic", tau=2, m=M, backend="jnp")
    cfg = _fleet_cfg(strat, optimizer=flat_adam())
    ref = run_fedrl(cfg, jax.random.key(0))[1]
    with sharding.use_rules(sharding.fleet_rules(sharding.fleet_mesh(1))):
        sharded = run_fedrl(cfg, jax.random.key(0))[1]
    np.testing.assert_allclose(ref["nas"], sharded["nas"], rtol=1e-5)
