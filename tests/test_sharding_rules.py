"""Sharding rule engine: spec construction, divisibility drops, dedup."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_test_mesh
from repro.sharding.rules import DEFAULT_RULES, MeshRules, shard, use_rules

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 4, reason="needs 4 local devices"
)


def _rules():
    mesh = make_test_mesh((2, 2), ("data", "model"))
    return MeshRules(mesh=mesh, rules=dict(DEFAULT_RULES))


def test_spec_basic_mapping():
    r = _rules()
    assert r.spec(("batch", "seq", "embed")) == P("data", None, None)
    assert r.spec(("embed_fsdp", "heads")) == P("data", "model")


def test_spec_drops_non_divisible():
    r = _rules()
    # 3 not divisible by the 2-way model axis -> constraint dropped + recorded
    assert r.spec(("heads",), shape=(3,)) == P(None)
    assert ("heads", 3, 2) in r.dropped
    assert r.spec(("heads",), shape=(4,)) == P("model")


def test_spec_dedups_mesh_axes():
    r = _rules()
    # both 'heads' and 'ff' map to model; second use must be dropped
    assert r.spec(("heads", "ff"), shape=(4, 4)) == P("model", None)


def test_missing_pod_axis_ignored():
    r = _rules()  # mesh has no 'pod'
    assert r.spec(("agents", "batch")) == P(None, "data")


def test_shard_outside_context_is_identity():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert shard(x, "batch", "embed") is x


def test_shard_applies_constraint_in_context():
    import jax.numpy as jnp
    r = _rules()
    with use_rules(r):
        y = jax.jit(lambda x: shard(x, "batch", "embed"))(jnp.ones((4, 8)))
    assert y.sharding.spec == P("data", None) or y.shape == (4, 8)
