"""Integration: prefill + decode_step must reproduce the full forward pass."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

import repro.configs as C
from repro.models import decode_step, forward, init_params, prefill

FAMS = ["phi4-mini-3.8b", "h2o-danube-3-4b", "rwkv6-1.6b", "recurrentgemma-9b",
        "kimi-k2-1t-a32b"]


@pytest.mark.parametrize("arch", FAMS)
def test_prefill_then_decode_matches_forward(arch):
    cfg = dataclasses.replace(C.get_arch(arch).reduced(), attn_impl="einsum")
    params = init_params(cfg, jax.random.key(0))
    s = 12
    toks = jax.random.randint(jax.random.key(1), (2, s + 1), 0, cfg.vocab_size)
    full, _, _ = forward(cfg, params, toks, mode="train")
    lg, st = prefill(cfg, params, toks[:, :s], cache_len=s + 2)
    assert jnp.allclose(full[:, :s], lg, atol=2e-4), "prefill logits mismatch"
    lg2, _ = decode_step(cfg, params, toks[:, s:s + 1], st, jnp.full((2,), s))
    assert jnp.allclose(full[:, s], lg2[:, 0], atol=2e-4), "decode logits mismatch"


@pytest.mark.parametrize("arch", ["h2o-danube-3-4b", "recurrentgemma-9b"])
def test_ring_buffer_decode_beyond_window(arch):
    """SWA ring cache: decode with S > window still matches the oracle."""
    cfg = dataclasses.replace(C.get_arch(arch).reduced(), attn_impl="einsum")
    assert cfg.sliding_window is not None
    s = cfg.sliding_window * 2 - 2
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, s + 1), 0, cfg.vocab_size)
    full, _, _ = forward(cfg, params, toks, mode="train")
    _, st = prefill(cfg, params, toks[:, :s], cache_len=s + 2)
    lg2, _ = decode_step(cfg, params, toks[:, s:s + 1], st, jnp.full((2,), s))
    assert jnp.allclose(full[:, s], lg2[:, 0], atol=3e-4)


def test_multi_token_decode_chain():
    """Decode 4 tokens sequentially; each must match the full forward."""
    cfg = dataclasses.replace(C.get_arch("rwkv6-1.6b").reduced(), attn_impl="einsum")
    params = init_params(cfg, jax.random.key(0))
    s, extra = 8, 4
    toks = jax.random.randint(jax.random.key(1), (1, s + extra), 0, cfg.vocab_size)
    full, _, _ = forward(cfg, params, toks, mode="train")
    _, st = prefill(cfg, params, toks[:, :s], cache_len=s + extra + 1)
    for i in range(extra):
        lg, st = decode_step(cfg, params, toks[:, s + i:s + i + 1], st,
                             jnp.full((1,), s + i))
        assert jnp.allclose(full[:, s + i], lg[:, 0], atol=3e-4), f"token {i}"


def test_whisper_decode_matches_forward():
    cfg = dataclasses.replace(C.get_arch("whisper-small").reduced(),
                              attn_impl="einsum")
    from repro.models.encdec import (
        encdec_decode_step,
        encdec_forward,
        init_encdec_decode_state,
    )
    params = init_params(cfg, jax.random.key(0))
    s = 10
    toks = jax.random.randint(jax.random.key(1), (2, s + 1), 0, cfg.vocab_size)
    frames = 0.1 * jax.random.normal(jax.random.key(2),
                                     (2, cfg.n_frontend_tokens, cfg.d_model))
    full, _ = encdec_forward(cfg, params, toks, frames)
    _, sts = encdec_forward(cfg, params, toks[:, :s], frames, mode="prefill",
                            cache_len=s + 2)
    state = init_encdec_decode_state(cfg, 2, max_seq=s + 2,
                                     n_frames=cfg.n_frontend_tokens,
                                     dtype=jnp.float32)
    state["self"] = sts["cache"]
    state["cross_k"], state["cross_v"] = sts["cross"]["k"], sts["cross"]["v"]
    lg, _ = encdec_decode_step(cfg, params, toks[:, s:s + 1], state,
                               jnp.full((2,), s))
    assert jnp.allclose(full[:, s], lg[:, 0], atol=3e-4)
