"""Sweep engine: determinism vs independent runs, vmapped-axis fidelity,
(S, m, n) dispatch parity, result reduction/IO.

Determinism contract: ``run_sweep_loop`` (the Python seed-loop over one
jitted single-run function) is BIT-identical to S independent ``run_fedrl``
calls — the grid semantics add nothing. The single vmapped computation
(``run_sweep``) is the same program batched over the leading sweep axis;
XLA lowers batched dot_generals to a different GEMM schedule, so it is
pinned to the loop at ulp-scale tolerance rather than bitwise (DESIGN.md
§10).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_strategy
from repro.core import topology as T
from repro.core.decay import exponential_decay
from repro.kernels import dispatch
from repro.rl import FIGURE_EIGHT, FedRLConfig, run_fedrl
from repro.sweep import (
    StaticAxis,
    SweepAxis,
    SweepSpec,
    mean_ci,
    run_sweep,
    run_sweep_loop,
    t_critical,
)

SEEDS = (0, 1, 2, 3)


def _cfg(backend="jnp", strategy=None, **kw):
    strat = strategy or make_strategy(
        "decay", tau=3, m=7, decay=exponential_decay(0.95), backend=backend
    )
    kw.setdefault("n_epochs", 2)
    kw.setdefault("epoch_len", 40)
    kw.setdefault("minibatch", 20)
    kw.setdefault("eta", 3e-3)
    return FedRLConfig(env=FIGURE_EIGHT, strategy=strat, **kw)


# --- determinism ---------------------------------------------------------------

def test_loop_sweep_bit_identical_to_independent_runs():
    """The seed-loop reference IS S independent jitted single-run calls:
    bitwise equal — including building the typed key from the seed *inside*
    the trace vs handing in a concrete key. (The eager ``run_fedrl`` wrapper
    compiles op-by-op, so jit-level fusion makes it a ulp-tolerance
    comparison instead, below.)"""
    from repro.rl.fedrl import run_fedrl_core

    cfg = _cfg()
    res = run_sweep_loop(SweepSpec(name="det", base=cfg, seeds=SEEDS))
    jitted = jax.jit(lambda k: run_fedrl_core(cfg, k)[1])
    for i, seed in enumerate(SEEDS):
        metrics = jax.device_get(jitted(jax.random.key(seed)))
        for k, arr in metrics.items():
            np.testing.assert_array_equal(
                res.metrics["base"][k][i], np.asarray(arr),
                err_msg=f"seed={seed} metric={k}",
            )
        _, eager, _ = run_fedrl(cfg, jax.random.key(seed))
        for k, arr in eager.items():
            np.testing.assert_allclose(
                res.metrics["base"][k][i], arr, rtol=1e-4, atol=1e-5,
                err_msg=f"eager seed={seed} metric={k}",
            )


def test_vmapped_sweep_matches_loop_reference():
    """One vmapped computation vs the Python seed-loop: same program batched;
    only XLA's batched-GEMM reduction order may differ (ulp scale)."""
    spec = SweepSpec(name="det", base=_cfg(), seeds=SEEDS)
    rv = run_sweep(spec)
    rl = run_sweep_loop(spec)
    assert rv.mode == "vmapped" and rl.mode == "loop"
    for k in rv.metrics["base"]:
        np.testing.assert_allclose(
            rv.metrics["base"][k], rl.metrics["base"][k],
            rtol=1e-4, atol=1e-5, err_msg=k,
        )


# --- vmapped hyperparameter axes ----------------------------------------------

def test_lam_axis_matches_per_lam_strategies():
    """Sweeping lambda through the traced override == rebuilding the
    DecayStrategy per lambda and running individually."""
    lams = (0.98, 0.9)
    spec = SweepSpec(
        name="lam", base=_cfg(), seeds=(0, 1),
        vmapped=(SweepAxis("lam", lams),),
    )
    res = run_sweep(spec)
    for i, lam in enumerate(lams):
        for j, seed in enumerate((0, 1)):
            strat = make_strategy(
                "decay", tau=3, m=7, decay=exponential_decay(lam), backend="jnp"
            )
            _, metrics, _ = run_fedrl(_cfg(strategy=strat), jax.random.key(seed))
            for k, arr in metrics.items():
                np.testing.assert_allclose(
                    res.metrics["base"][k][i, j], arr, rtol=1e-4, atol=1e-5,
                    err_msg=f"lam={lam} seed={seed} {k}",
                )


def test_eta_axis_matches_replaced_configs():
    etas = (3e-3, 1e-3)
    spec = SweepSpec(
        name="eta", base=_cfg(), seeds=(0,),
        vmapped=(SweepAxis("eta", etas),),
    )
    res = run_sweep(spec)
    for i, eta in enumerate(etas):
        _, metrics, _ = run_fedrl(_cfg(eta=eta), jax.random.key(0))
        for k, arr in metrics.items():
            np.testing.assert_allclose(
                res.metrics["base"][k][i, 0], arr, rtol=1e-4, atol=1e-5,
                err_msg=f"eta={eta} {k}",
            )


def test_eps_axis_matches_per_eps_strategies():
    """The traced mixing-matrix rebuild (P = I - eps*La, fused powers and
    mask-folded tables) tracks per-eps strategy construction."""
    topo = T.random_regularish(7, 3, 4, seed=0)
    epss = (0.05, 0.15)  # inside (0, 1/Delta) for this topology

    def strat_for(eps):
        return make_strategy(
            "consensus", tau=3, topo=topo, eps=eps, rounds=2, m=7, backend="jnp"
        )

    spec = SweepSpec(
        name="eps", base=_cfg(strategy=strat_for(epss[0])), seeds=(0,),
        vmapped=(SweepAxis("eps", epss),),
    )
    res = run_sweep(spec)
    for i, eps in enumerate(epss):
        _, metrics, _ = run_fedrl(
            _cfg(strategy=strat_for(eps)), jax.random.key(0)
        )
        for k, arr in metrics.items():
            np.testing.assert_allclose(
                res.metrics["base"][k][i, 0], arr, rtol=1e-4, atol=1e-5,
                err_msg=f"eps={eps} {k}",
            )


def test_taus_axis_matches_per_schedule_strategies():
    """The traced variation axis: each vmapped (schedule, seed) cell matches
    an independent run with the schedule baked into a static strategy."""
    from repro.rl.fedrl import run_fedrl_core

    m, tau = 7, 4
    scheds = ((4.0,) * m, (4.0, 4.0, 3.0, 3.0, 2.0, 2.0, 1.0))
    base = _cfg(strategy=make_strategy("periodic", tau=tau, m=m, backend="jnp"))
    spec = SweepSpec(name="taus", base=base, seeds=(0, 1),
                     vmapped=(SweepAxis("taus", scheds),))
    res = run_sweep(spec)
    for i, sched in enumerate(scheds):
        strat = make_strategy("periodic", tau=tau, m=m,
                              taus=np.asarray(sched, int), backend="jnp")
        jitted = jax.jit(
            lambda k, c=_cfg(strategy=strat): run_fedrl_core(c, k)[1]
        )
        for j, seed in enumerate((0, 1)):
            ref = jax.device_get(jitted(jax.random.key(seed)))
            for k, arr in ref.items():
                np.testing.assert_allclose(
                    res.metrics["base"][k][i, j], np.asarray(arr),
                    rtol=1e-4, atol=1e-5,
                    err_msg=f"sched={sched} seed={seed} {k}",
                )


def test_taus_axis_through_decay_and_consensus():
    """The mask retabulation must also refold the decay weighting and the
    consensus strategies' mask-folded mixing tables per schedule."""
    from repro.rl.fedrl import run_fedrl_core

    m, tau = 7, 3
    topo = T.random_regularish(m, 3, 4, seed=0)
    scheds = ((3.0, 3.0, 2.0, 2.0, 2.0, 1.0, 1.0),)
    bases = {
        "decay": lambda taus=None: make_strategy(
            "decay", tau=tau, m=m, taus=taus,
            decay=exponential_decay(0.95), backend="jnp",
        ),
        "consensus": lambda taus=None: make_strategy(
            "consensus", tau=tau, topo=topo, eps=0.1, m=m, taus=taus,
            backend="jnp",
        ),
    }
    for name, mk in bases.items():
        spec = SweepSpec(name=f"taus-{name}", base=_cfg(strategy=mk()),
                         seeds=(0,), vmapped=(SweepAxis("taus", scheds),))
        res = run_sweep(spec)
        strat = mk(taus=np.asarray(scheds[0], int))
        ref = jax.device_get(
            jax.jit(lambda k, c=_cfg(strategy=strat): run_fedrl_core(c, k)[1])(
                jax.random.key(0)
            )
        )
        for k, arr in ref.items():
            np.testing.assert_allclose(
                res.metrics["base"][k][0, 0], np.asarray(arr),
                rtol=1e-4, atol=1e-5, err_msg=f"{name} {k}",
            )


def test_hetero_scale_axis_matches_independent_runs():
    """Fleet-heterogeneity axis: each vmapped scale matches an independent
    run with the same override applied eagerly (perturbation directions are
    pinned by eval_seed, only the magnitude sweeps) — and the scale actually
    changes the dynamics."""
    from repro.rl.fedrl import run_fedrl_core
    from repro.sweep import override_hetero_scale

    def base():
        return _cfg(strategy=make_strategy("periodic", tau=3, m=7,
                                           backend="jnp"),
                    num_envs=1)

    scales = (0.0, 0.3)
    spec = SweepSpec(name="het", base=base(), seeds=(0, 1),
                     vmapped=(SweepAxis("hetero_scale", scales),))
    res = run_sweep(spec)
    for i, sc in enumerate(scales):
        cfg_i = override_hetero_scale(base(), sc)
        jitted = jax.jit(lambda k, c=cfg_i: run_fedrl_core(c, k)[1])
        for j, seed in enumerate((0, 1)):
            ref = jax.device_get(jitted(jax.random.key(seed)))
            for k, arr in ref.items():
                np.testing.assert_allclose(
                    res.metrics["base"][k][i, j], np.asarray(arr),
                    rtol=1e-4, atol=1e-5, err_msg=f"scale={sc} {k}",
                )
    # the heterogeneity magnitude is a real knob, not a no-op
    assert float(np.max(np.abs(res.metrics["base"]["nas"][0]
                               - res.metrics["base"]["nas"][1]))) > 0


def test_hetero_scale_axis_takes_per_cell_direction_draws():
    """(scale, dir_seed) 2-vector points: each cell perturbs along its own
    directions — same scale, different dir_seed gives different dynamics,
    and each vmapped cell matches the override applied eagerly."""
    from repro.rl.fedrl import run_fedrl_core
    from repro.sweep import override_hetero_scale

    def base():
        return _cfg(strategy=make_strategy("periodic", tau=3, m=7,
                                           backend="jnp"),
                    num_envs=1)

    points = ((0.3, 0), (0.3, 1))
    spec = SweepSpec(name="het2", base=base(), seeds=(0,),
                     vmapped=(SweepAxis("hetero_scale", points),))
    res = run_sweep(spec)
    for i, pt in enumerate(points):
        cfg_i = override_hetero_scale(base(), jnp.asarray(pt, jnp.float32))
        ref = jax.device_get(
            jax.jit(lambda k, c=cfg_i: run_fedrl_core(c, k)[1])(
                jax.random.key(0)
            )
        )
        for k, arr in ref.items():
            np.testing.assert_allclose(
                res.metrics["base"][k][i, 0], np.asarray(arr),
                rtol=1e-4, atol=1e-5, err_msg=f"point={pt} {k}",
            )
    # equal scales, distinct direction draws: a real distribution over
    # perturbations, not one arbitrary draw shared across the axis
    assert float(np.max(np.abs(res.metrics["base"]["nas"][0]
                               - res.metrics["base"]["nas"][1]))) > 0
    with pytest.raises(ValueError, match="2-vector"):
        override_hetero_scale(base(), jnp.zeros(3))


def test_lam_vector_axis_applies_per_agent_decay():
    """Vector-valued lam points give each agent its own decay table; the
    vmapped cell matches the override applied eagerly, and the (m, tau)
    table holds lam_i^{j/2} folded with the variation mask."""
    from repro.rl.fedrl import run_fedrl_core
    from repro.sweep import override_lam

    lam_vec = (0.98, 0.96, 0.94, 0.92, 0.9, 0.88, 0.86)
    base = _cfg()  # decay strategy, tau=3, m=7
    cfg_ref = override_lam(base, jnp.asarray(lam_vec, jnp.float32))
    w = np.asarray(cfg_ref.strategy.decay_weights)
    assert w.shape == (7, 3)
    offs = np.arange(3, dtype=np.float32)
    np.testing.assert_allclose(
        w, np.power(np.asarray(lam_vec, np.float32)[:, None], offs / 2.0),
        rtol=1e-6,
    )
    wt = np.asarray(cfg_ref.strategy.weight(1))
    np.testing.assert_allclose(
        wt, np.asarray(cfg_ref.strategy.mask)[:, 1] * w[:, 1], rtol=1e-6
    )
    spec = SweepSpec(name="lam-m", base=base, seeds=(0,),
                     vmapped=(SweepAxis("lam", (lam_vec,)),))
    res = run_sweep(spec)
    ref = jax.device_get(
        jax.jit(lambda k: run_fedrl_core(cfg_ref, k)[1])(jax.random.key(0))
    )
    for k, arr in ref.items():
        np.testing.assert_allclose(
            res.metrics["base"][k][0, 0], np.asarray(arr),
            rtol=1e-4, atol=1e-5, err_msg=k,
        )


def test_vector_axis_validation():
    ax = SweepAxis("taus", ((3.0, 2.0), (2.0, 1.0)))
    assert ax.point_len == 2
    assert SweepAxis("eta", (0.1, 0.2)).point_len is None
    with pytest.raises(ValueError, match="one shape"):
        SweepAxis("taus", ((3.0, 2.0), 1.0))
    with pytest.raises(ValueError, match="one shape"):
        SweepAxis("taus", ((3.0, 2.0), (3.0, 2.0, 1.0)))
    with pytest.raises(ValueError, match="scalars or"):
        SweepAxis("taus", (((1.0,),),))
    from repro.sweep import override_lam, override_taus

    with pytest.raises(ValueError, match="taus"):
        override_taus(_cfg(), jnp.ones(3))  # m=7 strategy, length-3 point
    with pytest.raises(ValueError, match="A2.3"):
        # concrete points are A2-validated eagerly: no pacing agent here
        override_taus(_cfg(), jnp.full(7, 2.0))  # tau=3 strategy
    with pytest.raises(ValueError, match="lam"):
        override_lam(_cfg(), jnp.ones(3))  # m=7 strategy, length-3 vector


def test_unknown_vmapped_axis_raises():
    spec = SweepSpec(
        name="bad", base=_cfg(), seeds=(0,),
        vmapped=(SweepAxis("nope", (1.0,)),),
    )
    with pytest.raises(KeyError, match="nope"):
        run_sweep(spec)


def test_lam_axis_requires_decay_strategy():
    strat = make_strategy("periodic", tau=3, m=7, backend="jnp")
    spec = SweepSpec(
        name="bad", base=_cfg(strategy=strat), seeds=(0,),
        vmapped=(SweepAxis("lam", (0.9,)),),
    )
    with pytest.raises(TypeError, match="DecayStrategy"):
        run_sweep(spec)


def test_custom_run_fn_sweeps_fmarl_driver():
    """The run_fn hook vmaps run_fmarl_core (the task-generic driver) over
    seeds just like the RL driver."""
    from repro.core.fmarl import FmarlConfig, run_fmarl, run_fmarl_core

    init = {"w": jnp.ones((4, 5)), "b": jnp.ones(3)}

    def grad_fn(p, k, i, step):
        g = jax.tree.map(lambda x: x + 0.1 * jax.random.normal(k, x.shape), p)
        return g, {"loss": sum(jnp.sum(x ** 2) for x in jax.tree.leaves(p))}

    def eval_fn(p, k):
        return p

    cfg = FmarlConfig(
        strategy=make_strategy("periodic", tau=3, m=5, backend="jnp"),
        eta=0.05, n_periods=4,
    )

    def run_fn(c, key):
        _, metrics = run_fmarl_core(c, init, grad_fn, key, eval_fn)
        return {"grad_sq": metrics["server_grad_sq_norm"]}

    res = run_sweep(SweepSpec(name="fmarl", base=cfg, seeds=(0, 1, 2),
                              run_fn=run_fn))
    assert res.metrics["base"]["grad_sq"].shape == (3, 4)
    for i, seed in enumerate((0, 1, 2)):
        _, metrics, _ = run_fmarl(cfg, init, grad_fn, jax.random.key(seed),
                                  eval_fn)
        np.testing.assert_allclose(
            res.metrics["base"]["grad_sq"][i],
            np.asarray(metrics["server_grad_sq_norm"]), rtol=1e-5, atol=1e-6,
        )


# --- static axes ---------------------------------------------------------------

def test_static_axes_cartesian_product_composes():
    """Two static axes -> product of labelled transforms, composed in order."""
    strat_a = make_strategy("periodic", tau=2, m=7, backend="jnp")
    strat_b = make_strategy("periodic", tau=4, m=7, backend="jnp")
    spec = SweepSpec(
        name="grid", base=_cfg(), seeds=(0, 1),
        static=(
            StaticAxis("tau", (
                ("tau=2", lambda c: dataclasses.replace(c, strategy=strat_a)),
                ("tau=4", lambda c: dataclasses.replace(c, strategy=strat_b)),
            )),
            StaticAxis("eta", (
                ("eta=lo", lambda c: dataclasses.replace(c, eta=1e-3)),
                ("eta=hi", lambda c: dataclasses.replace(c, eta=5e-3)),
            )),
        ),
    )
    res = run_sweep(spec)
    assert sorted(res.labels) == [
        "tau=2/eta=hi", "tau=2/eta=lo", "tau=4/eta=hi", "tau=4/eta=lo"
    ]
    ref_cfg = _cfg(strategy=strat_b, eta=5e-3)
    _, metrics, _ = run_fedrl(ref_cfg, jax.random.key(1))
    np.testing.assert_allclose(
        res.metrics["tau=4/eta=hi"]["nas"][1], metrics["nas"],
        rtol=1e-4, atol=1e-5,
    )


# --- (S, m, n) dispatch path ---------------------------------------------------

def test_dispatch_sweep_axis_interpret_parity():
    """Direct (S, m, n) primitive calls: interpret kernels == jnp reference."""
    S, m, n = 3, 5, 37  # n deliberately not a block multiple
    acc = jax.random.normal(jax.random.key(0), (S, m, n))
    g = jax.random.normal(jax.random.key(1), (S, m, n))
    d_sm = jax.random.normal(jax.random.key(2), (S, m))
    mix = jax.random.normal(jax.random.key(3), (S, m, m))
    cases = {
        "decay_accum scalar": lambda b: dispatch.decay_accum(acc, g, 0.3, backend=b),
        "decay_accum (S,m)": lambda b: dispatch.decay_accum(acc, g, d_sm, backend=b),
        "scale_rows (S,m)": lambda b: dispatch.scale_rows(g, d_sm, backend=b),
        "consensus_mix shared": lambda b: dispatch.consensus_mix(g, mix[0], backend=b),
        "consensus_mix per-run": lambda b: dispatch.consensus_mix(g, mix, backend=b),
        "row_mean": lambda b: dispatch.row_mean(g, backend=b),
    }
    for name, fn in cases.items():
        a, b = fn("jnp"), fn("interpret")
        assert a.shape[0] == S, name
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6, err_msg=name)


def test_dispatch_sweep_axis_matches_per_run_calls():
    """(S, m, n) batching == stacking S independent (m, n) calls."""
    S, m, n = 4, 6, 23
    acc = jax.random.normal(jax.random.key(0), (S, m, n))
    g = jax.random.normal(jax.random.key(1), (S, m, n))
    d = jax.random.normal(jax.random.key(2), (S, m))
    batched = dispatch.decay_accum(acc, g, d, backend="jnp")
    stacked = jnp.stack([
        dispatch.decay_accum(acc[i], g[i], d[i], backend="jnp")
        for i in range(S)
    ])
    np.testing.assert_array_equal(np.asarray(batched), np.asarray(stacked))


def test_dispatch_sweep_axis_ambiguous_coefficients_raise():
    """1-D d with S == m could mean per-run or per-agent — must refuse."""
    S = m = 4
    acc = jax.random.normal(jax.random.key(0), (S, m, 9))
    g = jax.random.normal(jax.random.key(1), (S, m, 9))
    with pytest.raises(ValueError, match="ambiguous"):
        dispatch.decay_accum(acc, g, jnp.ones(S), backend="jnp")
    # the explicit forms still work
    out = dispatch.decay_accum(acc, g, jnp.ones((S, m)), backend="jnp")
    assert out.shape == acc.shape
    out = dispatch.decay_accum(acc, g, 0.5, backend="jnp")
    assert out.shape == acc.shape


def test_batched_variation_masks_through_dispatch():
    """(S, m, tau) mask batching: per-run mask columns drive decay_accum /
    scale_rows as (S, m) coefficients and mask-folded (S, m, m) mixing
    through consensus_mix — batched == stacked per-run calls, and the
    interpret kernels agree with the jnp reference."""
    from repro.core.variation import mask_from_taus

    S, m, tau, n = 3, 5, 4, 37
    scheds = jnp.asarray([[4, 3, 2, 2, 1], [4, 4, 4, 3, 3], [4, 1, 1, 1, 1]],
                         jnp.float32)
    masks = jax.vmap(lambda t: mask_from_taus(t, tau))(scheds)  # (S, m, tau)
    assert masks.shape == (S, m, tau)
    acc = jax.random.normal(jax.random.key(0), (S, m, n))
    g = jax.random.normal(jax.random.key(1), (S, m, n))
    p = jnp.asarray(T.mixing_matrix(T.ring(m), 0.25), jnp.float32)
    for offset in range(tau):
        w = masks[:, :, offset]                                 # (S, m)
        batched = dispatch.decay_accum(acc, g, -0.05 * w, backend="jnp")
        stacked = jnp.stack([
            dispatch.decay_accum(acc[i], g[i], -0.05 * w[i], backend="jnp")
            for i in range(S)
        ])
        np.testing.assert_array_equal(np.asarray(batched), np.asarray(stacked))
        np.testing.assert_allclose(
            np.asarray(dispatch.decay_accum(acc, g, -0.05 * w,
                                            backend="interpret")),
            np.asarray(batched), atol=1e-6, err_msg=f"decay@{offset}",
        )
        sb = dispatch.scale_rows(g, w, backend="jnp")
        ss = jnp.stack([
            dispatch.scale_rows(g[i], w[i], backend="jnp") for i in range(S)
        ])
        np.testing.assert_array_equal(np.asarray(sb), np.asarray(ss))
        # mask folded into the mixing matrix per run: (S, m, m)
        mix = p[None, :, :] * w[:, None, :]
        cb = dispatch.consensus_mix(g, mix, backend="jnp")
        cs = jnp.stack([
            dispatch.consensus_mix(g[i], mix[i], backend="jnp")
            for i in range(S)
        ])
        np.testing.assert_allclose(np.asarray(cb), np.asarray(cs),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(dispatch.consensus_mix(g, mix, backend="interpret")),
            np.asarray(cb), rtol=1e-5, atol=1e-6, err_msg=f"mix@{offset}",
        )


def test_interpret_backend_sweep_matches_jnp_backend():
    """The vmapped flat-carry driver dispatches on (S, m, n) through the
    interpret kernels and stays on-parity with the jnp reference sweep."""
    outs = {}
    for backend in ("jnp", "interpret"):
        spec = SweepSpec(name="b", base=_cfg(backend=backend), seeds=(0, 1))
        outs[backend] = run_sweep(spec).metrics["base"]
    for k in outs["jnp"]:
        np.testing.assert_allclose(
            outs["jnp"][k], outs["interpret"][k], rtol=1e-3, atol=1e-5,
            err_msg=k,
        )


# --- results: reduction + versioned artifacts ----------------------------------

def test_mean_ci_t_interval():
    x = np.array([[1.0, 2.0, 3.0, 4.0], [2.0, 2.0, 2.0, 2.0]]).T  # (4, 2)
    mean, hw = mean_ci(x, axis=0, confidence=0.95)
    np.testing.assert_allclose(mean, [2.5, 2.0])
    sd = np.std(x[:, 0], ddof=1)
    np.testing.assert_allclose(hw[0], t_critical(3) * sd / 2.0, rtol=1e-6)
    assert hw[1] == 0.0
    # single sample: zero half-width, no NaNs
    m1, h1 = mean_ci(x[:1], axis=0)
    np.testing.assert_allclose(m1, x[0])
    assert not np.any(h1)


def test_t_critical_values_and_validation():
    np.testing.assert_allclose(t_critical(3, 0.95), 3.182)
    np.testing.assert_allclose(t_critical(100, 0.95), 1.960)  # normal fallback
    with pytest.raises(ValueError):
        t_critical(3, 0.5)
    with pytest.raises(ValueError):
        t_critical(0)


def test_sweep_result_saves_versioned_artifacts(tmp_path):
    spec = SweepSpec(
        name="arts", base=_cfg(), seeds=(0, 1),
        vmapped=(SweepAxis("lam", (0.98, 0.9)),),
    )
    res = run_sweep(spec)
    j1, c1 = res.save(str(tmp_path))
    j2, c2 = res.save(str(tmp_path))
    assert j1.endswith("arts.v1.json") and j2.endswith("arts.v2.json")
    assert c1.endswith("arts.v1.csv")
    import json

    payload = json.loads(open(j1).read())
    assert payload["schema_version"] == 1
    assert payload["axes"] == {"lam": [0.98, 0.9]}
    assert payload["n_seeds"] == 2
    curve = payload["labels"]["base"]["nas"]
    assert np.asarray(curve["mean"]).shape == (2, 2)  # (lam, epochs)
    rows = res.rows()
    assert {r["label"] for r in rows} == {"base"}
    assert {r["lam"] for r in rows} == {0.98, 0.9}
    # grid bookkeeping
    assert spec.grid_shape == (2, 2) and spec.n_runs == 4


def test_vector_axis_artifacts_roundtrip(tmp_path):
    """A vector-valued axis survives the artifact pipeline: JSON keeps the
    whole schedules, CSV rows get one compact cell per point."""
    import json

    scheds = ((3.0, 3.0, 2.0, 2.0, 2.0, 1.0, 1.0), (3.0,) * 7)
    strat = make_strategy("periodic", tau=3, m=7, backend="jnp")
    spec = SweepSpec(name="vec", base=_cfg(strategy=strat), seeds=(0,),
                     vmapped=(SweepAxis("taus", scheds),))
    res = run_sweep(spec)
    jpath, cpath = res.save(str(tmp_path))
    payload = json.loads(open(jpath).read())
    assert payload["axes"]["taus"] == [list(s) for s in scheds]
    rows = res.rows()
    assert {r["taus"] for r in rows} == {"[3,3,2,2,2,1,1]", "[3,3,3,3,3,3,3]"}


def test_spec_validation():
    with pytest.raises(ValueError, match="seed"):
        SweepSpec(name="x", base=None, seeds=())
    with pytest.raises(ValueError, match="value"):
        SweepAxis("lam", ())
    with pytest.raises(ValueError, match="duplicate"):
        SweepSpec(name="x", base=None, seeds=(0,),
                  vmapped=(SweepAxis("a", (1.0,)), SweepAxis("a", (2.0,))))


def test_sweep_compiles_exactly_once_per_static_point(assert_max_compiles):
    """The retrace guard on the PR-4 speedup: re-running a sweep performs
    exactly ONE XLA compile per static point (the per-point AOT
    lower+compile) — the batched execution never retraces across the
    (axes x seeds) grid, and traced axes add zero compiles."""
    from repro.sweep.runner import static_points

    def tau_point(tau):
        def t(cfg, tau=tau):
            return dataclasses.replace(
                cfg, strategy=make_strategy("decay", tau=tau, m=7, backend="jnp")
            )
        return (f"tau{tau}", t)

    spec = SweepSpec(
        name="retrace",
        base=_cfg(n_epochs=1, epoch_len=4, minibatch=2),
        seeds=(0, 1),
        vmapped=(SweepAxis("eta", (1e-3, 3e-3)),),
        static=(StaticAxis("tau", (tau_point(2), tau_point(3))),),
    )
    run_sweep(spec)  # warm-up: absorbs one-time tiny-op compiles (asarray &c)
    n_points = len(list(static_points(spec)))
    _, n = assert_max_compiles(n_points, run_sweep, spec)
    assert n == n_points
