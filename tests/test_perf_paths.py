"""Correctness of the §Perf alternative implementations (hillclimb paths)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import decode_step, forward, init_params, prefill
from repro.models.rwkv6 import wkv_chunked, wkv_scan


@pytest.mark.parametrize("chunk", [8, 16, 32, 48])
def test_wkv_chunked_matches_scan(chunk):
    B, T, H, D = 2, 48, 3, 16
    ks = jax.random.split(jax.random.key(chunk), 6)
    r, k, v = (0.5 * jax.random.normal(ks[i], (B, T, H, D)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, D))) * 0.5 + 0.45
    u = 0.3 * jax.random.normal(ks[4], (H, D))
    s0 = 0.1 * jax.random.normal(ks[5], (B, H, D, D))
    y1, s1 = wkv_scan(r, k, v, w, u, s0)
    y2, s2 = wkv_chunked(r, k, v, w, u, s0, chunk=chunk)
    np.testing.assert_allclose(y1, y2, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(s1, s2, atol=1e-5, rtol=1e-5)


def test_wkv_chunked_gradients_match_scan():
    B, T, H, D = 1, 24, 2, 8
    ks = jax.random.split(jax.random.key(0), 6)
    r, k, v = (0.5 * jax.random.normal(ks[i], (B, T, H, D)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, D))) * 0.5 + 0.45
    u = 0.3 * jax.random.normal(ks[4], (H, D))
    s0 = jnp.zeros((B, H, D, D))

    def loss(fn, r, k, v, w):
        y, _ = fn(r, k, v, w, u, s0)
        return jnp.sum(y**2)

    g1 = jax.grad(lambda *a: loss(wkv_scan, *a), argnums=(0, 1, 2, 3))(r, k, v, w)
    g2 = jax.grad(lambda *a: loss(lambda *b: wkv_chunked(*b, chunk=8), *a),
                  argnums=(0, 1, 2, 3))(r, k, v, w)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-4)


def test_rwkv_model_with_chunked_impl_matches_scan_impl():
    cfg = C.get_arch("rwkv6-1.6b").reduced()
    cfg_c = dataclasses.replace(cfg, wkv_impl="chunked", wkv_chunk=8)
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    l1, _, _ = forward(cfg, params, toks, mode="train")
    l2, _, _ = forward(cfg_c, params, toks, mode="train")
    np.testing.assert_allclose(l1, l2, atol=1e-3)


@pytest.mark.parametrize("impl", ["scatter", "onehot"])
def test_cache_update_impls_decode_exact(impl):
    cfg = dataclasses.replace(C.get_arch("phi4-mini-3.8b").reduced(),
                              attn_impl="einsum", cache_update=impl)
    params = init_params(cfg, jax.random.key(0))
    s = 10
    toks = jax.random.randint(jax.random.key(1), (2, s + 1), 0, cfg.vocab_size)
    full, _, _ = forward(cfg, params, toks, mode="train")
    _, st = prefill(cfg, params, toks[:, :s], cache_len=s + 2)
    lg, _ = decode_step(cfg, params, toks[:, s:s + 1], st, jnp.full((2,), s))
    np.testing.assert_allclose(np.asarray(full[:, s]), np.asarray(lg[:, 0]),
                               atol=3e-4)


def test_bf16_adam_state_dtype_preserved_and_converges():
    from repro.optim import adamw
    opt = adamw(state_dtype=jnp.bfloat16)
    p = {"x": jnp.asarray([3.0, -2.0])}
    s = opt.init(p)
    for _ in range(150):
        g = jax.grad(lambda q: jnp.sum(q["x"] ** 2))(p)
        p, s = opt.apply(g, s, p, 0.1)
    assert s["m"]["x"].dtype == jnp.bfloat16  # no silent fp32 promotion
    assert float(jnp.sum(p["x"] ** 2)) < 1e-3


def test_moe_group_size_does_not_change_output_in_nodrop_regime():
    cfg = C.get_arch("kimi-k2-1t-a32b").reduced()
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    outs = []
    for g in (4, 8, 4096):
        c = dataclasses.replace(cfg, moe_group_size=g)
        l, _, _ = forward(c, params, toks, mode="train")
        outs.append(np.asarray(l))
    np.testing.assert_allclose(outs[0], outs[1], atol=2e-4)
    np.testing.assert_allclose(outs[0], outs[2], atol=2e-4)


def test_tau_schedule_fp_rounding_regression():
    """floor(7 * 0.1/0.1) must be 7 (was 6 before the epsilon guard)."""
    from repro.core.variation import tau_schedule
    taus = tau_schedule(7, np.asarray([0.1, 0.1]))
    assert taus[0] == 7
