"""Optimizers, data pipeline, checkpointing, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.data import SyntheticLM, make_batch_iterator
from repro.optim import adamw, clip_by_global_norm, momentum, sgd
from repro.optim.schedules import constant_lr, cosine_lr, warmup_cosine_lr


@pytest.mark.parametrize("opt_name", ["sgd", "momentum", "adamw"])
def test_optimizers_minimize_quadratic(opt_name):
    opt = {"sgd": sgd, "momentum": momentum, "adamw": adamw}[opt_name]()
    params = {"x": jnp.asarray([3.0, -2.0]), "y": jnp.asarray(5.0)}
    state = opt.init(params)
    loss_fn = lambda p: jnp.sum(p["x"] ** 2) + p["y"] ** 2
    lr = 0.1
    for _ in range(200):
        grads = jax.grad(loss_fn)(params)
        params, state = opt.apply(grads, state, params, lr)
    assert float(loss_fn(params)) < 1e-2


def test_adamw_states_fp32_even_for_bf16_params():
    opt = adamw()
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    st = opt.init(params)
    assert st["m"]["w"].dtype == jnp.float32


def test_grad_clip_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert np.isclose(float(norm), 5.0)
    assert np.isclose(float(jnp.linalg.norm(clipped["a"])), 1.0)
    # under the threshold: untouched
    clipped2, _ = clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(clipped2["a"], g["a"])


def test_schedules():
    assert float(constant_lr(3e-4)(100)) == pytest.approx(3e-4)
    c = cosine_lr(1.0, 100, final_frac=0.1)
    assert float(c(0)) == pytest.approx(1.0)
    assert float(c(100)) == pytest.approx(0.1)
    w = warmup_cosine_lr(1.0, 10, 110)
    assert float(w(5)) == pytest.approx(0.5)
    assert float(w(10)) == pytest.approx(1.0, abs=1e-3)


def test_synthetic_data_deterministic_and_agent_disjoint():
    src = SyntheticLM(vocab_size=1000, seed=42)
    a = src.batch(step=3, batch=4, seq=32, agent=0)
    b = src.batch(step=3, batch=4, seq=32, agent=0)
    c = src.batch(step=3, batch=4, seq=32, agent=1)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.min() >= 0 and a.max() < 1000


def test_batch_iterator_host_sharding():
    src = SyntheticLM(vocab_size=100, seed=0)
    full = next(make_batch_iterator(src, 8, 16))["tokens"]
    p0 = next(make_batch_iterator(src, 8, 16, process_index=0, process_count=2))
    p1 = next(make_batch_iterator(src, 8, 16, process_index=1, process_count=2))
    np.testing.assert_array_equal(np.concatenate([p0["tokens"], p1["tokens"]]), full)


def test_checkpoint_roundtrip_nested(tmp_path):
    tree = {
        "params": {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
                   "scale": np.float32(2.5)},
        "opt": [np.zeros(3, np.int32), (np.ones(2), np.asarray(7))],
        "step": 13,
    }
    path = save(str(tmp_path), 13, tree, metadata={"note": "x"})
    assert os.path.exists(path)
    restored, meta = restore(str(tmp_path))
    assert meta["step"] == 13 and meta["note"] == "x"
    np.testing.assert_array_equal(restored["params"]["w"], tree["params"]["w"])
    assert isinstance(restored["opt"], list)
    assert isinstance(restored["opt"][1], tuple)
    np.testing.assert_array_equal(restored["opt"][1][0], np.ones(2))


def test_checkpoint_latest_step(tmp_path):
    assert latest_step(str(tmp_path)) is None
    save(str(tmp_path), 5, {"a": np.zeros(1)})
    save(str(tmp_path), 17, {"a": np.ones(1)})
    assert latest_step(str(tmp_path)) == 17
    tree, _ = restore(str(tmp_path))
    np.testing.assert_array_equal(tree["a"], np.ones(1))
