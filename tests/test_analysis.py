"""Trace-safety analyzer suite: RPR lint rules, jaxpr audit, baseline
workflow, CLI gating, and the retrace guard (DESIGN.md §12).

Each RPR rule has a fixture snippet that must trigger it *exactly once* (and
no other rule); the jaxpr audit is exercised on deliberately-broken toy
entries (bf16 dot, callback-in-scan, constant folding, dead donation); the
CI gate is demonstrated end to end by running ``python -m repro.analysis
--check`` as a subprocess against a file with a fresh violation.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.findings import (
    Finding,
    diff_baseline,
    fingerprint_counts,
    load_baseline,
    save_baseline,
)
from repro.analysis.lint import lint_paths, lint_source
from repro.analysis.retrace import RetraceError, count_compiles

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _rules(src: str):
    return [f.rule for f in lint_source(textwrap.dedent(src), "snippet.py")]


# --- RPR rule fixtures: each fires exactly once --------------------------------

def test_rpr001_key_reuse_fires_exactly_once():
    src = """
    import jax

    def f(key, x):
        a = jax.random.normal(key, (3,))
        b = jax.random.uniform(key, (3,))
        return a + b + x
    """
    assert _rules(src) == ["RPR001"]


def test_rpr002_python_loop_in_scan_body_fires_exactly_once():
    src = """
    import jax

    def body(carry, x):
        for _ in range(3):
            carry = carry + x
        return carry, None

    def run(xs):
        return jax.lax.scan(body, 0.0, xs)
    """
    assert _rules(src) == ["RPR002"]


def test_rpr003_host_numpy_on_traced_value_fires_exactly_once():
    src = """
    import numpy as np
    import jax

    def cell(p, x):
        y = p * x
        return np.mean(y)

    def run(p, xs):
        return jax.vmap(cell)(p, xs)
    """
    assert _rules(src) == ["RPR003"]


def test_rpr004_concretization_fires_exactly_once():
    src = """
    import jax

    @jax.jit
    def g(x):
        s = x.sum()
        return float(s)
    """
    assert _rules(src) == ["RPR004"]


def test_rpr005_mutable_jit_default_fires_exactly_once():
    src = """
    import jax

    @jax.jit
    def h(x, opts={}):
        return x
    """
    assert _rules(src) == ["RPR005"]


def test_rpr005_jit_in_loop_fires():
    src = """
    import jax

    def bench(fns, x):
        outs = []
        for f in fns:
            outs.append(jax.jit(f)(x))
        return outs
    """
    assert _rules(src) == ["RPR005"]


# --- RPR001 dataflow corners ---------------------------------------------------

def test_rpr001_split_rebind_is_clean():
    src = """
    import jax

    def f(key):
        key, sub = jax.random.split(key)
        a = jax.random.normal(sub, (3,))
        key, sub = jax.random.split(key)
        return a + jax.random.normal(sub, (3,))
    """
    assert _rules(src) == []


def test_rpr001_early_return_branches_are_exclusive():
    # `if c: return f(key)` / `return g(key)` consumes the key once.
    src = """
    import jax

    def f(key, flag):
        if flag:
            return jax.random.normal(key, (3,))
        return jax.random.uniform(key, (3,))
    """
    assert _rules(src) == []


def test_rpr001_double_split_of_same_key_flagged():
    # Splitting one key twice yields identical streams.
    src = """
    import jax

    def f(key):
        a = jax.random.split(key, 2)
        b = jax.random.split(key, 2)
        return a, b
    """
    assert _rules(src) == ["RPR001"]


def test_rpr001_captured_key_in_tree_map_lambda_flagged():
    # The quickstart bug: same key drawn once per leaf.
    src = """
    import jax

    def noisy(params, key):
        return jax.tree.map(
            lambda x: x + jax.random.normal(key, x.shape), params
        )
    """
    assert _rules(src) == ["RPR001"]


def test_rpr001_loop_reuse_flagged_and_noqa_suppresses():
    src = """
    import jax

    def f(key, n):
        out = 0.0
        for _ in range(n):
            out = out + jax.random.normal(key, ())
        return out
    """
    assert _rules(src) == ["RPR001"]
    suppressed = src.replace(
        "jax.random.normal(key, ())",
        "jax.random.normal(key, ())  # noqa: RPR001",
    )
    assert _rules(suppressed) == []


# --- the satellite regression: the hot-path RL modules stay RPR001-clean -------

def test_rl_modules_have_no_prng_reuse():
    """rl/rollout.py + rl/fedrl.py + core/fmarl.py + the quickstart example
    carry zero RPR001 findings (the `_eval_grad_norm` bug class, PR 2, and
    the per-leaf quickstart noise fix stay fixed)."""
    paths = [
        os.path.join(ROOT, "src", "repro", "rl"),
        os.path.join(ROOT, "src", "repro", "core", "fmarl.py"),
        os.path.join(ROOT, "examples", "quickstart.py"),
    ]
    findings = [f for f in lint_paths(paths, root=ROOT) if f.rule == "RPR001"]
    assert findings == [], [f.render() for f in findings]


# --- baseline bookkeeping ------------------------------------------------------

def _finding(rule="RPR001", path="a.py", scope="f", snippet="key=k"):
    return Finding(rule=rule, path=path, scope=scope,
                   message="m", snippet=snippet, line=3)


def test_fingerprint_ignores_line_numbers():
    a = _finding()
    b = Finding(**{**a.__dict__, "line": 99})
    assert a.fingerprint == b.fingerprint


def test_baseline_roundtrip_and_diff(tmp_path):
    f1, f2 = _finding(), _finding(scope="g")
    p = str(tmp_path / "baseline.json")
    save_baseline([f1, f2], p)
    base = load_baseline(p)
    assert base == fingerprint_counts([f1, f2])

    # same findings -> nothing new; one extra duplicate -> exactly it is new
    new, resolved = diff_baseline([f1, f2], base)
    assert (new, resolved) == ([], [])
    new, resolved = diff_baseline([f1, f1, f2], base)
    assert new == [f1] and resolved == []
    # a baselined finding disappearing is reported as resolved
    new, resolved = diff_baseline([f2], base)
    assert new == [] and resolved == [f1.fingerprint]


def test_committed_baseline_is_schema_valid_and_current():
    """The checked-in baseline matches what the lint produces today — a
    stale baseline would hide rot in either direction."""
    from repro.analysis.findings import BASELINE_PATH

    base = load_baseline(BASELINE_PATH)
    findings = lint_paths(
        [os.path.join(ROOT, d) for d in ("src/repro", "benchmarks", "examples")],
        root=ROOT,
    )
    new, resolved = diff_baseline(findings, base)
    assert new == [], [f.render() for f in new]
    assert resolved == []


# --- jaxpr audit ---------------------------------------------------------------

def _audit(fn, *args, donate=()):
    from repro.analysis.jaxpr_audit import audit_entry
    from repro.kernels.dispatch import HotPathEntry

    return audit_entry(
        "toy", HotPathEntry(fn=fn, args=args, donate_argnums=tuple(donate))
    )


def test_jxa001_flags_bf16_accumulating_dot():
    """A bf16 dot without preferred_element_type accumulates below fp32."""
    bf = jax.ShapeDtypeStruct((8, 8), jnp.bfloat16)

    def bad(a, b):
        return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())))

    rules = [f.rule for f in _audit(bad, bf, bf)]
    assert rules == ["JXA001"]

    def good(a, b):
        return jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(jnp.bfloat16)

    assert [f.rule for f in _audit(good, bf, bf)] == []


def test_jxa001_flags_bf16_reduce_sum():
    bf = jax.ShapeDtypeStruct((16,), jnp.bfloat16)

    def bad(x):
        # keep the reduction in bf16 explicitly (jnp.sum would upcast)
        return jax.lax.reduce_sum_p.bind(x, axes=(0,))

    assert [f.rule for f in _audit(bad, bf)] == ["JXA001"]


def test_jxa002_flags_callback_inside_scan_only():
    xs = jax.ShapeDtypeStruct((4,), jnp.float32)

    def with_print(xs):
        def body(c, x):
            jax.debug.print("x={x}", x=x)
            return c + x, x
        return jax.lax.scan(body, 0.0, xs)

    rules = [f.rule for f in _audit(with_print, xs)]
    assert "JXA002" in rules

    def outside(xs):
        jax.debug.print("sum={s}", s=xs.sum())
        return xs * 2

    assert "JXA002" not in [f.rule for f in _audit(outside, xs)]


def test_jxa003_flags_large_constant_folded_literal():
    big = jnp.ones((256, 256))  # 65536 elements > LARGE_CONST_ELEMS
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    assert [f.rule for f in _audit(lambda v: v + big, x)] == ["JXA003"]

    small = jnp.ones((8, 8))
    y = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    assert [f.rule for f in _audit(lambda v: v + small, y)] == []


@pytest.mark.filterwarnings("ignore:Some donated buffers were not usable")
def test_jxa004_flags_declared_but_unused_donation():
    x = jax.ShapeDtypeStruct((64,), jnp.float32)

    # output shape differs -> the donated buffer cannot be reused
    rules = [f.rule for f in _audit(lambda v: v.sum(), x, donate=(0,))]
    assert rules == ["JXA004"]

    # same-shape output -> XLA aliases the donated input, no finding
    assert [f.rule for f in _audit(lambda v: v + 1.0, x, donate=(0,))] == []


def test_audit_registry_covers_the_whole_hot_path():
    """All five dispatch primitives on both CPU-executable backends, the
    compressed comm reductions, both driver cores, and the sweep engine's
    static-point fn are registered."""
    from repro.analysis.jaxpr_audit import collect_entries

    factories, import_findings = collect_entries()
    assert import_findings == []
    names = set(factories)
    for prim in ("decay_accum", "scale_rows", "consensus_mix", "row_mean",
                 "topk_scatter"):
        for backend in ("jnp", "interpret"):
            assert f"dispatch.{prim}[{backend}]" in names
    # the compressed server reductions register their own entries: the
    # fp32-accumulation contract holds even when the wire format is not fp32
    for kind in ("topk", "int8"):
        for backend in ("jnp", "interpret"):
            assert f"comm.{kind}_reduce[{backend}]" in names
    assert {"rl.run_fedrl_core", "core.run_fmarl_core",
            "sweep.static_point_fn"} <= names
    # async federation layer: the masked FedBuff server step on both
    # CPU-executable backends, plus the delay sweep axis's static-point fn
    for backend in ("jnp", "interpret"):
        assert f"async_fed.masked_server_step[{backend}]" in names
    assert "async_fed.delay_axis_fn" in names


@pytest.mark.slow
def test_full_audit_is_clean():
    """Zero sub-fp32 / callback / const / donation findings across every
    registered entry (the acceptance bar for the jnp + interpret backends)."""
    from repro.analysis.jaxpr_audit import run_audit

    findings = run_audit()
    assert findings == [], [f.render() for f in findings]


def test_audit_on_dispatch_primitives_is_clean_and_fast():
    """The tier-1 subset of the audit: the four primitives on both backends
    accumulate in fp32 (the docstring contract, now machine-checked)."""
    from repro.analysis.jaxpr_audit import run_audit
    from repro.kernels.dispatch import DISPATCH_PRIMITIVES

    names = [
        f"dispatch.{p}[{b}]"
        for p in DISPATCH_PRIMITIVES for b in ("jnp", "interpret")
    ]
    findings = run_audit(only=names)
    assert findings == [], [f.render() for f in findings]


# --- the CI gate, end to end ---------------------------------------------------

def _run_cli(args, cwd):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(os.path.join(ROOT, "src"))
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=cwd, env=env, capture_output=True, text=True, timeout=120,
    )


def test_cli_check_fails_on_new_finding_and_passes_when_clean(tmp_path):
    bad = tmp_path / "fresh_violation.py"
    bad.write_text(textwrap.dedent("""
        import jax

        def f(key):
            a = jax.random.normal(key, (2,))
            return a + jax.random.uniform(key, (2,))
    """))
    empty_baseline = tmp_path / "baseline.json"
    empty_baseline.write_text(json.dumps(
        {"schema_version": 1, "findings": {}}
    ))

    r = _run_cli(
        ["--check", "--skip-jaxpr", "--baseline", str(empty_baseline),
         str(bad)],
        cwd=str(tmp_path),
    )
    assert r.returncode == 1, r.stdout + r.stderr
    assert "RPR001" in r.stdout

    good = tmp_path / "clean.py"
    good.write_text("import jax\n\ndef f(key):\n"
                    "    return jax.random.normal(key, (2,))\n")
    r = _run_cli(
        ["--check", "--skip-jaxpr", "--baseline", str(empty_baseline),
         str(good)],
        cwd=str(tmp_path),
    )
    assert r.returncode == 0, r.stdout + r.stderr


def test_cli_update_baseline_then_check_passes(tmp_path):
    bad = tmp_path / "legacy.py"
    bad.write_text(textwrap.dedent("""
        import jax

        def f(key):
            a = jax.random.normal(key, (2,))
            return a + jax.random.uniform(key, (2,))
    """))
    baseline = tmp_path / "baseline.json"
    r = _run_cli(
        ["--update-baseline", "--skip-jaxpr", "--baseline", str(baseline),
         str(bad)],
        cwd=str(tmp_path),
    )
    assert r.returncode == 0, r.stdout + r.stderr
    r = _run_cli(
        ["--check", "--skip-jaxpr", "--baseline", str(baseline), str(bad)],
        cwd=str(tmp_path),
    )
    assert r.returncode == 0, r.stdout + r.stderr


# --- retrace guard -------------------------------------------------------------

def test_count_compiles_sees_fresh_jit_and_not_cache_hits():
    f = jax.jit(lambda x: x * 2.0 + 1.0)
    x = jnp.arange(4.0)
    with count_compiles() as c:
        jax.block_until_ready(f(x))
    assert c.count >= 1
    with count_compiles() as c2:
        jax.block_until_ready(f(x))
    assert c2.count == 0


def test_count_compiles_nests():
    g = jax.jit(lambda x: x - 3.0)
    x = jnp.arange(8.0)
    with count_compiles() as outer:
        with count_compiles() as inner:
            jax.block_until_ready(g(x))
    assert inner.count >= 1
    assert outer.count >= inner.count


def test_assert_max_compiles_fixture_enforces_budget(assert_max_compiles):
    h = jax.jit(lambda x: x ** 2 + 7.0)
    x = jnp.arange(16.0)
    jax.block_until_ready(h(x))  # warm
    _, n = assert_max_compiles(0, lambda: jax.block_until_ready(h(x)))
    assert n == 0

    h2 = jax.jit(lambda x: x ** 3 - 11.0)
    with pytest.raises(RetraceError):
        assert_max_compiles(0, lambda: jax.block_until_ready(h2(x)))
