"""Consensus algorithm: T5's contraction rate, verified empirically."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consensus_rounds_dense, consensus_rounds_matrix
from repro.core.consensus import disagreement
from repro.core import topology as T


def test_dense_equals_matrix_power():
    topo = T.ring(8)
    g = {"x": jax.random.normal(jax.random.key(0), (8, 5, 3))}
    a = consensus_rounds_dense(g, topo, 0.25, 4)
    b = consensus_rounds_matrix(g, topo, 0.25, 4)
    assert jnp.allclose(a["x"], b["x"], atol=1e-5)


@pytest.mark.parametrize("maker,kw", [
    (T.ring, dict(m=8)),
    (T.chain, dict(m=5)),
    (T.fully_connected, dict(m=6)),
    (T.torus2d, dict(rows=3, cols=3)),
])
def test_disagreement_contracts_at_spectral_rate(maker, kw):
    """||G(I-J)||_F^2 after E rounds <= (1 - eps*mu2)^{2E} * initial (T5 core)."""
    topo = maker(**kw)
    eps = 0.9 / topo.max_degree
    g = {"x": jax.random.normal(jax.random.key(1), (topo.m, 16))}
    d0 = float(disagreement(g))
    for rounds in (1, 2, 4):
        out = consensus_rounds_dense(g, topo, eps, rounds)
        dE = float(disagreement(out))
        bound = (1.0 - eps * T.mu2(topo)) ** (2 * rounds) * d0
        # fully-connected graphs attain the bound exactly (all nonzero
        # Laplacian eigenvalues equal) -> allow fp32 mixing roundoff.
        assert dE <= bound * (1 + 1e-3) + 1e-6 * d0, (topo.name, rounds, dE, bound)


def test_consensus_converges_to_mean():
    topo = T.ring(6)
    g = {"x": jax.random.normal(jax.random.key(2), (6, 4))}
    out = consensus_rounds_dense(g, topo, 0.3, 200)
    mean = g["x"].mean(axis=0, keepdims=True)
    assert jnp.allclose(out["x"], jnp.broadcast_to(mean, out["x"].shape), atol=1e-4)


def test_denser_graph_contracts_faster():
    """Paper Fig. 6: larger mu2 (denser network) improves convergence."""
    sparse = T.random_regularish(9, 3, 4, seed=0)
    dense = T.random_regularish(9, 5, 6, seed=0)
    assert T.mu2(dense) > T.mu2(sparse)
    g = {"x": jax.random.normal(jax.random.key(3), (9, 32))}
    eps = 0.9 / max(sparse.max_degree, dense.max_degree)
    ds = float(disagreement(consensus_rounds_dense(g, sparse, eps, 2)))
    dd = float(disagreement(consensus_rounds_dense(g, dense, eps, 2)))
    assert dd < ds
