"""Sparse neighbor-list consensus path: parity, selection rule, power cache.

Pins the DESIGN.md §14 contracts at strategy level: the sparse O(m*k) gossip
realisation is bit-identical (eager jnp) to the full-list sequential
reference and ulp-close to the fused dense tables; the density auto-rule
never flips existing small-m configs; the mixing-power cache returns
identical arrays (no retrace fodder) and stays lazy about P^E on the sparse
path. The hypothesis section re-states the parity/padding contracts as
properties over every registered graph family (skips when hypothesis is
absent — the pinned 0.4.37 CI leg and the container).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology as T
from repro.core.strategies import (
    _POWER_CACHE,
    SPARSE_DENSITY_THRESHOLD,
    SPARSE_MIN_AGENTS,
    ConsensusStrategy,
    _topology_digest,
    clear_power_cache,
    make_strategy,
    mixing_powers,
)
from repro.kernels import dispatch

try:
    import hypothesis  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed"
)


def _pair(topo, *, tau=3, eps_frac=0.5, rounds=1):
    """The same consensus config realised dense and sparse."""
    eps = eps_frac / topo.max_degree
    dense = ConsensusStrategy(tau=tau, topo=topo, eps=eps, rounds=rounds,
                              sparse=False)
    sp = ConsensusStrategy(tau=tau, topo=topo, eps=eps, rounds=rounds,
                           sparse=True)
    return dense, sp


def _g(m, n=37, seed=0):
    return jax.random.normal(jax.random.key(seed), (m, n))


# --- dense/sparse parity ------------------------------------------------------


@pytest.mark.parametrize("rounds", [1, 2, 3])
def test_sparse_flat_transform_close_to_dense(rounds):
    topo = T.knn_ring(16, 4)
    dense, sp = _pair(topo, rounds=rounds)
    g = _g(16)
    for offset in (0, 2):
        a = dense.flat_transform(g, offset, backend="jnp")
        b = sp.flat_transform(g, offset, backend="jnp")
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_sparse_flat_transform_bitwise_vs_full_list_reference():
    """Eager contract: mask then E full-list sequential gossip rounds is the
    'dense P @ x evaluated in index order' reference — the sparse path must
    reproduce it bit-for-bit, not just closely."""
    topo = T.knn_ring(16, 4)
    _, sp = _pair(topo, rounds=2)
    full = T.neighbor_list(topo, k_max=topo.m)
    p64, _, _ = mixing_powers(topo, sp.eps, 2, need_power=False)
    w_full = T.neighbor_weights_from_matrix(full, p64)
    g = _g(16)
    with jax.disable_jit():
        got = sp.flat_transform(g, 1, backend="jnp")
        ref = dispatch.scale_rows(g, sp.weight(1), backend="jnp")
        for _ in range(2):
            ref = dispatch.consensus_gather(
                ref, full.idx, w_full, backend="jnp"
            )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_sparse_interpret_leg_matches_eager_jnp():
    topo = T.knn_ring(16, 4)
    _, sp = _pair(topo, rounds=2)
    g = _g(16)
    with jax.disable_jit():
        eager = sp.flat_transform(g, 0, backend="jnp")
    kern = sp.flat_transform(g, 0, backend="interpret")
    np.testing.assert_allclose(
        np.asarray(eager), np.asarray(kern), atol=1e-6
    )


def test_sparse_with_mask_matches_dense_masked():
    topo = T.knn_ring(16, 4)
    dense, sp = _pair(topo, tau=4)
    mask = np.ones((16, 4), bool)
    mask[3, 1:] = False  # agent 3 goes quiet after offset 0
    mask[8, 2:] = False
    g = _g(16)
    dm, sm = dense.with_mask(mask), sp.with_mask(mask)
    assert sm.sparse
    for offset in range(4):
        np.testing.assert_allclose(
            dm.flat_transform(g, offset, backend="jnp"),
            sm.flat_transform(g, offset, backend="jnp"),
            atol=1e-5,
        )


def test_sparse_tree_transform_matches_flat():
    topo = T.knn_ring(16, 4)
    _, sp = _pair(topo)
    g = _g(16, n=12)
    tree = {"w": g.reshape(16, 3, 4)}
    out = sp.transform(tree, 0)
    flat = sp.flat_transform(g, 0, backend="jnp")
    np.testing.assert_allclose(
        np.asarray(out["w"]).reshape(16, 12), np.asarray(flat), atol=1e-6
    )


def test_sparse_preserves_mean():
    """P doubly stochastic: the sparse realisation keeps the fleet mean too."""
    topo = T.knn_ring(16, 4)
    _, sp = _pair(topo, rounds=3)
    g = _g(16)
    out = sp.flat_transform(g, 0, backend="jnp")
    np.testing.assert_allclose(
        np.asarray(out).mean(0), np.asarray(g).mean(0), atol=1e-5
    )


# --- auto-selection rule ------------------------------------------------------


def test_sparse_auto_selection_rule():
    eps = 0.1
    # sparse: low density AND m >= floor
    big_sparse = ConsensusStrategy(tau=2, topo=T.knn_ring(64, 4), eps=eps)
    assert big_sparse.sparse and "sparse" in big_sparse.name
    # small fleets stay dense regardless of density (existing configs)
    small = ConsensusStrategy(tau=2, topo=T.knn_ring(48, 4), eps=eps)
    assert not small.sparse
    # dense graphs stay dense regardless of m
    full = ConsensusStrategy(tau=2, topo=T.fully_connected(70), eps=1e-3)
    assert not full.sparse
    # explicit override beats the rule both ways
    assert ConsensusStrategy(tau=2, topo=T.knn_ring(48, 4), eps=eps,
                             sparse=True).sparse
    assert not ConsensusStrategy(tau=2, topo=T.knn_ring(64, 4), eps=eps,
                                 sparse=False).sparse
    assert T.density(T.knn_ring(64, 4)) <= SPARSE_DENSITY_THRESHOLD
    assert 48 < SPARSE_MIN_AGENTS <= 64


def test_make_strategy_passes_sparse_through():
    s = make_strategy("consensus", tau=2, topo=T.knn_ring(12, 4), eps=0.1,
                      rounds=1, m=12, sparse=True)
    assert s.sparse
    assert s.nl is not None and s.nl_w is not None
    assert s.p_e_masked is None  # dense folded tables never built


# --- mixing-power cache -------------------------------------------------------


def test_power_cache_returns_identical_arrays():
    clear_power_cache()
    topo = T.knn_ring(16, 4)
    p64_a, p_a, pe_a = mixing_powers(topo, 0.1, 2)
    p64_b, p_b, pe_b = mixing_powers(topo, 0.1, 2)
    assert p64_a is p64_b and p_a is p_b and pe_a is pe_b
    # a different eps or round count is a different entry
    p64_c, _, _ = mixing_powers(topo, 0.05, 2)
    assert p64_c is not p64_a
    _, _, pe_d = mixing_powers(topo, 0.1, 3)
    assert pe_d is not pe_a


def test_power_cache_lazy_p_e_on_sparse_path():
    clear_power_cache()
    topo = T.knn_ring(64, 4)
    sp = ConsensusStrategy(tau=2, topo=topo, eps=0.1, rounds=2)
    assert sp.sparse
    key = (_topology_digest(topo), topo.m, 0.1, 2)
    assert _POWER_CACHE[key]["p_e"] is None  # never powered for sparse
    # a dense request on the same key fills it in place
    _, _, pe = mixing_powers(topo, 0.1, 2)
    assert pe is not None and _POWER_CACHE[key]["p_e"] is pe


def test_power_cache_is_bounded_lru():
    clear_power_cache()
    topo = T.ring(6)
    for i in range(40):
        mixing_powers(topo, 0.01 + 0.002 * i, 1, need_power=False)
    from repro.core.strategies import _POWER_CACHE_MAXSIZE

    assert len(_POWER_CACHE) == _POWER_CACHE_MAXSIZE


def test_power_cache_no_retrace_across_strategy_rebuilds():
    """Rebuilding the same consensus config must not retrace the jitted step:
    the cache hands back the *same* weight arrays each time."""
    from repro.analysis.retrace import assert_max_compiles, warmup_jax

    clear_power_cache()
    topo = T.knn_ring(64, 4)
    g = _g(64, n=16)
    warmup_jax(g)

    @jax.jit
    def step(g_, idx, w):
        return dispatch.consensus_gather(g_, idx, w, backend="jnp")

    def run_twice():
        outs = []
        for _ in range(2):
            s = ConsensusStrategy(tau=2, topo=topo, eps=0.1, rounds=1,
                                  sparse=True)
            outs.append(step(g_=g, idx=jnp.asarray(s.nl.idx),
                             w=jnp.asarray(s.nl_w)))
        return outs

    outs, n = assert_max_compiles(1, run_twice)
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[1]))


# --- sweep integration --------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _Cfg:
    strategy: object


def test_override_eps_sparse_rebuilds_only_weights():
    from repro.sweep.overrides import override_eps

    topo = T.knn_ring(64, 4)
    _, sp = _pair(topo)
    cfg = override_eps(_Cfg(sp), jnp.float32(0.08))
    new = cfg.strategy
    assert new.sparse and new.nl is sp.nl
    ref = np.asarray(T.neighbor_weights(sp.nl, 0.08))
    np.testing.assert_array_equal(np.asarray(new.nl_w), ref)
    g = _g(64, n=8)
    out = new.flat_transform(g, 0, backend="jnp")
    dense_eq = ConsensusStrategy(tau=3, topo=topo, eps=0.08, rounds=1,
                                 sparse=False)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(dense_eq.flat_transform(g, 0, backend="jnp")),
        atol=1e-5,
    )


def test_algebraic_connectivity_axis_swaps_topology():
    from repro.sweep.overrides import algebraic_connectivity_axis

    axis = algebraic_connectivity_axis(12, families=("chain", "knn4", "full"))
    assert [lbl.split("(")[0] for lbl, _ in axis.points] == [
        "chain", "knn4", "full"
    ]
    base = _Cfg(ConsensusStrategy(tau=2, topo=T.ring(12), eps=0.1, rounds=2))
    for (label, swap), family in zip(axis.points, ("chain", "knn4", "full")):
        cfg = swap(base)
        s = cfg.strategy
        assert s.topo.name.startswith(family[:4]) or family == "knn4"
        assert s.m == 12 and s.rounds == 2 and s.tau == 2
        assert np.isclose(s.eps, 0.5 / s.topo.max_degree)
        assert f"mu2={T.mu2(s.topo):.3f}" in label
    with pytest.raises(KeyError):
        algebraic_connectivity_axis(12, families=("nope",))
    with pytest.raises(ValueError):
        algebraic_connectivity_axis(12, eps_frac=1.5)


def test_algebraic_connectivity_axis_mismatched_m_raises():
    from repro.sweep.overrides import algebraic_connectivity_axis

    axis = algebraic_connectivity_axis(12, families=("ring",))
    base = _Cfg(ConsensusStrategy(tau=2, topo=T.ring(7), eps=0.1, rounds=1))
    with pytest.raises(ValueError, match="m=12"):
        axis.points[0][1](base)


# --- hypothesis properties (skip-if-absent) -----------------------------------

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    SETTINGS = settings(max_examples=25, deadline=None)

    @needs_hypothesis
    @SETTINGS
    @given(
        family=st.sampled_from(sorted(T.GRAPH_FAMILIES)),
        m=st.integers(10, 20),  # >= 10 so knn8 (k=8 < m) is always valid
        seed=st.integers(0, 3),
        eps_frac=st.floats(0.05, 0.95),
    )
    def test_property_sparse_bitwise_equals_full_list(family, m, seed, eps_frac):
        """For every registered family: the k-sparse gossip step equals the
        full-list (k_max = m) sequential evaluation of P @ x bit-for-bit on
        the eager jnp path."""
        topo = T.GRAPH_FAMILIES[family](m, seed)
        eps = eps_frac / topo.max_degree
        p = T.mixing_matrix(topo, eps)
        nl = T.neighbor_list(topo)
        full = T.neighbor_list(topo, k_max=m)
        w = T.neighbor_weights_from_matrix(nl, p)
        w_full = T.neighbor_weights_from_matrix(full, p)
        g = jax.random.normal(jax.random.key(seed), (m, 23))
        with jax.disable_jit():
            sparse = dispatch.consensus_gather(g, nl.idx, w, backend="jnp")
            ref = dispatch.consensus_gather(g, full.idx, w_full, backend="jnp")
        np.testing.assert_array_equal(np.asarray(sparse), np.asarray(ref))

    @needs_hypothesis
    @SETTINGS
    @given(
        family=st.sampled_from(sorted(T.GRAPH_FAMILIES)),
        m=st.integers(10, 20),  # >= 10 so knn8 (k=8 < m) is always valid
        seed=st.integers(0, 3),
        extra=st.integers(1, 5),
    )
    def test_property_padding_contributes_exactly_zero(family, m, seed, extra):
        """Widening k_max with pure padding never changes a single bit."""
        topo = T.GRAPH_FAMILIES[family](m, seed)
        p = T.mixing_matrix(topo, 0.3 / topo.max_degree)
        nl = T.neighbor_list(topo)
        wide = T.neighbor_list(topo, k_max=nl.k_max + extra)
        w = T.neighbor_weights_from_matrix(nl, p)
        w_wide = T.neighbor_weights_from_matrix(wide, p)
        assert np.all(w_wide[~wide.valid] == 0.0)
        g = jax.random.normal(jax.random.key(seed + 100), (m, 17))
        with jax.disable_jit():
            tight = dispatch.consensus_gather(g, nl.idx, w, backend="jnp")
            padded = dispatch.consensus_gather(
                g, wide.idx, w_wide, backend="jnp"
            )
        np.testing.assert_array_equal(np.asarray(tight), np.asarray(padded))
