"""Dry-run integration: lower+compile on a small forced-host-device mesh.

XLA locks the device count at first init, so these run in subprocesses with
their own XLA_FLAGS (the main test process keeps 1 device, per the rules).
"""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # subprocess lower+compile; minutes, not ms

ROOT = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.pop("JAX_PLATFORMS", None)
import dataclasses, json, sys
import jax
from repro.utils.compat import default_axis_types, make_mesh
from repro.configs import get_arch, SHAPE_REGISTRY, InputShape
from repro.launch.mesh import make_rules
from repro.launch.fedtrain import (FedTrainConfig, init_train_state,
                                   make_local_step, make_sync_step,
                                   train_state_axes)
from repro.launch.serve import make_serve_step, make_prefill_step
from repro.launch.specs import attach, input_specs
from repro.models import param_logical_axes, init_params
from repro.optim import adamw
from repro.analysis.hlo_stats import collective_stats

arch, kind = sys.argv[1], sys.argv[2]
cfg = get_arch(arch).reduced()
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"),
                 axis_types=default_axis_types(3))
rules = make_rules(mesh, {"seq": ("model",)})
shape = InputShape("t", 32, 8, kind)
fed = FedTrainConfig(strategy="consensus", tau=4)
out = {}
if kind == "train":
    batch = input_specs(cfg, shape, rules, n_agents=2)
    state = attach(jax.eval_shape(
        lambda: init_train_state(cfg, jax.random.key(0), 2, adamw(), fed)),
        train_state_axes(cfg, fed), rules)
    with mesh:
        local = jax.jit(make_local_step(cfg, adamw(), fed, rules, 2)).lower(state, batch).compile()
        sync = jax.jit(make_sync_step(cfg, fed, rules, 2)).lower(state).compile()
    out["local_colls"] = collective_stats(local.as_text()).counts
    out["sync_colls"] = collective_stats(sync.as_text()).counts
    # the paper's claim, structurally: sync_step must carry the cross-pod
    # collective; local_step must not reduce anything over the pod axis.
    out["ok"] = True
else:
    token, states, pos = input_specs(cfg, shape, rules)
    params = attach(jax.eval_shape(lambda: init_params(cfg, jax.random.key(0))),
                    param_logical_axes(cfg), rules)
    with mesh:
        c = jax.jit(make_serve_step(cfg, rules)).lower(params, token, states, pos).compile()
    out["colls"] = collective_stats(c.as_text()).counts
    out["ok"] = True
print(json.dumps(out))
"""


def _run(arch, kind):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT, arch, kind],
                       capture_output=True, text=True, env=env, timeout=1800)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "kimi-k2-1t-a32b",
                                  "rwkv6-1.6b"])
def test_small_mesh_train_lowering(arch):
    out = _run(arch, "train")
    assert out["ok"]
    # consensus sync must communicate across pods
    assert sum(out["sync_colls"].values()) >= 1


@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "recurrentgemma-9b"])
def test_small_mesh_serve_lowering(arch):
    out = _run(arch, "decode")
    assert out["ok"]
