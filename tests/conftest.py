import os
import sys

import pytest

# Tests run on the default single CPU device (the dry-run subprocess sets its
# own XLA_FLAGS); keep compilation deterministic and quiet.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture
def assert_max_compiles():
    """Run a callable under an XLA compile budget (repro.analysis.retrace).

    Usage::

        def test_no_retrace(assert_max_compiles):
            result, n = assert_max_compiles(2, run_sweep, spec)

    Fails the test (RetraceError is an AssertionError) when the call
    compiles more than the budget allows.
    """
    from repro.analysis.retrace import assert_max_compiles as _amc

    return _amc


@pytest.fixture(autouse=True)
def _clear_dispatch_caches():
    """Drop the cached ravel specs between tests.

    The dispatch LRU is keyed on (treedef, shapes, dtypes) but not on
    backend/dtype *config*, so a spec cached under one parametrization could
    leak stale closures into the next test that changes backend or buffer
    dtype. Clearing after every test keeps parametrized backend/dtype suites
    hermetic.
    """
    yield
    from repro.kernels import dispatch

    dispatch.clear_caches()
