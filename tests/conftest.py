import os
import sys

# Tests run on the default single CPU device (the dry-run subprocess sets its
# own XLA_FLAGS); keep compilation deterministic and quiet.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
