"""Serving engine + queue + fused inference kernel (repro.serve, policy_infer).

Pins the serving contracts from DESIGN.md §16:

* the fused kernel's jnp dispatch path is *bitwise* eager
  ``rl.policy.policy_apply`` on normalized observations (and interpret mode
  matches it to fp32 tolerance);
* bucket padding never changes a real row's decision (bitwise, same bucket);
* engine construction compiles exactly once per bucket and serving never
  retraces (PR-6 retrace guard);
* the micro-batching queue is deterministic under a seeded client schedule;
* the restore path goes through ``checkpoint.restore`` and reproduces the
  source engine's decisions exactly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.retrace import count_compiles
from repro.kernels import dispatch
from repro.rl.policy import init_policy, policy_apply
from repro.serve import (
    MicroBatchQueue,
    ObsNorm,
    ObsRequest,
    ServeEngine,
    poisson_arrivals,
    save_for_serving,
    simulate_clients,
)

OBS_DIM, HIDDEN, ACT_DIM = 6, 16, 2


@pytest.fixture(scope="module")
def params():
    return init_policy(jax.random.key(0), OBS_DIM, hidden=HIDDEN,
                       act_dim=ACT_DIM)


@pytest.fixture(scope="module")
def norm():
    return ObsNorm(np.linspace(-1, 1, OBS_DIM).astype(np.float32),
                   np.full(OBS_DIM, 1.5, np.float32))


def _obs(n, seed=0):
    return np.random.default_rng(seed).standard_normal(
        (n, OBS_DIM)).astype(np.float32)


def _eager_mean(params, norm, obs):
    """The serving reference: eager policy_apply on normalized obs."""
    with jax.disable_jit():
        obsn = (jnp.asarray(obs, jnp.float32) - jnp.asarray(norm.mean)) \
            / jnp.asarray(norm.std)
        mean, _ = policy_apply({"pi": params["pi"]}, obsn)
    return np.asarray(mean)


# --- fused kernel parity -------------------------------------------------------

def test_policy_infer_jnp_is_bitwise_eager_policy_apply(params, norm):
    obs = _obs(37, seed=1)
    noise = np.zeros((37, ACT_DIM), np.float32)
    got = dispatch.policy_infer(
        jnp.asarray(obs), params["pi"], norm.mean, norm.std,
        jnp.asarray(noise), sample=False, backend="jnp",
    )
    np.testing.assert_array_equal(
        np.asarray(got), _eager_mean(params, norm, obs)
    )


def test_policy_infer_interpret_matches_jnp(params, norm):
    obs = _obs(37, seed=2)
    noise = np.random.default_rng(3).standard_normal(
        (37, ACT_DIM)).astype(np.float32)
    for sample in (False, True):
        a = dispatch.policy_infer(
            jnp.asarray(obs), params["pi"], norm.mean, norm.std,
            jnp.asarray(noise), sample=sample, backend="jnp",
        )
        # block_b 16 forces padding (37 -> 48) and a multi-block grid
        b = dispatch.policy_infer(
            jnp.asarray(obs), params["pi"], norm.mean, norm.std,
            jnp.asarray(noise), sample=sample, backend="interpret",
            block_b=16,
        )
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_policy_infer_sample_adds_scaled_noise(params, norm):
    obs = _obs(8, seed=4)
    noise = np.random.default_rng(5).standard_normal(
        (8, ACT_DIM)).astype(np.float32)
    mean = dispatch.policy_infer(
        jnp.asarray(obs), params["pi"], norm.mean, norm.std,
        jnp.zeros((8, ACT_DIM), jnp.float32), sample=False, backend="jnp",
    )
    sampled = dispatch.policy_infer(
        jnp.asarray(obs), params["pi"], norm.mean, norm.std,
        jnp.asarray(noise), sample=True, backend="jnp",
    )
    std = np.exp(np.asarray(params["pi"]["log_std"], np.float32))
    np.testing.assert_allclose(
        np.asarray(sampled), np.asarray(mean) + std * noise, rtol=1e-6
    )


def test_policy_infer_rejects_bad_shapes(params, norm):
    with pytest.raises(ValueError):
        dispatch.policy_infer(
            jnp.zeros((4, OBS_DIM + 1)), params["pi"], norm.mean, norm.std,
            jnp.zeros((4, ACT_DIM)), backend="jnp",
        )
    with pytest.raises(ValueError):
        dispatch.policy_infer(
            jnp.zeros((4, OBS_DIM)), params["pi"], norm.mean, norm.std,
            jnp.zeros((3, ACT_DIM)), backend="jnp",  # noise batch mismatch
        )


# --- engine: buckets, padding, retrace pin -------------------------------------

def test_engine_decide_matches_eager(params, norm):
    eng = ServeEngine(params, norm=norm, buckets=(8, 32), backend="jnp")
    obs = _obs(5, seed=6)
    np.testing.assert_array_equal(
        eng.decide(obs), _eager_mean(params, norm, obs)
    )


def test_bucket_padding_never_changes_a_decision(params, norm):
    """Same bucket, different padding: 5 real rows padded 5->8 must decide
    exactly like the same 5 rows arriving alongside 3 other real rows."""
    eng = ServeEngine(params, norm=norm, buckets=(8,), backend="jnp")
    obs5 = _obs(5, seed=7)
    extra = _obs(3, seed=8)
    alone = eng.decide(obs5)                                # padded 5 -> 8
    together = eng.decide(np.concatenate([obs5, extra]))    # full bucket
    np.testing.assert_array_equal(alone, together[:5])
    # and across buckets of one engine the same row still decides the same
    eng2 = ServeEngine(params, norm=norm, buckets=(8, 64), backend="jnp")
    np.testing.assert_array_equal(eng2.decide(obs5), eng2.decide(obs5))


def test_engine_compiles_exactly_once_per_bucket(params, norm):
    from repro.analysis.retrace import warmup_jax

    warmup_jax()
    buckets = (8, 32, 128)
    with count_compiles() as c:
        eng = ServeEngine(params, norm=norm, buckets=buckets, backend="jnp")
    assert c.count == len(buckets)
    # the hot path itself never compiles: hit every bucket, including sizes
    # that pad, twice
    with count_compiles() as c:
        for n in (1, 8, 9, 32, 33, 128, 1, 9, 33):
            eng.decide(_obs(n, seed=n))
    assert c.count == 0


def test_engine_rejects_oversized_batch_and_bad_obs(params):
    eng = ServeEngine(params, buckets=(8,))
    with pytest.raises(ValueError, match="largest bucket"):
        eng.decide(_obs(9))
    with pytest.raises(ValueError, match="obs must be"):
        eng.decide(np.zeros((4, OBS_DIM + 2), np.float32))


def test_engine_sample_mode_is_seed_deterministic(params, norm):
    obs = _obs(12, seed=9)
    a = ServeEngine(params, norm=norm, buckets=(16,), mode="sample",
                    seed=3).decide(obs)
    b = ServeEngine(params, norm=norm, buckets=(16,), mode="sample",
                    seed=3).decide(obs)
    c = ServeEngine(params, norm=norm, buckets=(16,), mode="sample",
                    seed=4).decide(obs)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


def test_engine_load_params_hot_swaps_without_recompile(params, norm):
    eng = ServeEngine(params, norm=norm, buckets=(8,), backend="jnp")
    obs = _obs(4, seed=10)
    before = eng.decide(obs)
    new = init_policy(jax.random.key(1), OBS_DIM, hidden=HIDDEN,
                      act_dim=ACT_DIM)
    with count_compiles() as c:
        eng.load_params(new)
        after = eng.decide(obs)
    assert c.count == 0
    assert not np.array_equal(before, after)
    np.testing.assert_array_equal(after, _eager_mean(new, norm, obs))
    bad = {"pi": {k: v for k, v in new["pi"].items() if k != "w2"}}
    with pytest.raises(ValueError, match="structure"):
        eng.load_params(bad)


# --- queue: coalescing determinism ---------------------------------------------

def test_queue_coalesces_fifo_up_to_max_batch():
    q = MicroBatchQueue(max_batch=4, obs_dim=OBS_DIM)
    for i in range(6):
        q.push(ObsRequest(client_id=i, t_arrival=float(i),
                          obs=np.full(OBS_DIM, i, np.float32)))
    obs, reqs = q.next_batch()
    assert obs.shape == (4, OBS_DIM)
    assert [r.client_id for r in reqs] == [0, 1, 2, 3]
    obs, reqs = q.next_batch()
    assert [r.client_id for r in reqs] == [4, 5]
    assert q.next_batch() is None


def test_queue_coalescing_deterministic_under_seeded_schedule():
    """Same seeded client fleet -> identical arrival order, identical batch
    compositions, identical decisions (with the engine's seeded noise)."""
    def run():
        reqs = simulate_clients(20, 3.0, 2.0, obs_dim=OBS_DIM, seed=11)
        q = MicroBatchQueue(max_batch=8, obs_dim=OBS_DIM)
        q.push_all(reqs)
        batches = []
        while (nxt := q.next_batch()) is not None:
            obs, rs = nxt
            batches.append((obs, [r.client_id for r in rs]))
        return batches

    a, b = run(), run()
    assert len(a) == len(b) and len(a) > 1
    for (obs_a, ids_a), (obs_b, ids_b) in zip(a, b):
        assert ids_a == ids_b
        np.testing.assert_array_equal(obs_a, obs_b)
    # arrival order is (t_arrival, then enqueue seq): non-decreasing times
    reqs = simulate_clients(20, 3.0, 2.0, obs_dim=OBS_DIM, seed=11)
    times = [r.t_arrival for r in reqs]
    assert times == sorted(times)


def test_poisson_arrivals_seeded_and_bounded():
    a = poisson_arrivals(5.0, 3.0, seed=2)
    b = poisson_arrivals(5.0, 3.0, seed=2)
    np.testing.assert_array_equal(a, b)
    assert np.all(a >= 0.0) and np.all(a < 3.0)
    assert np.all(np.diff(a) >= 0.0)
    with pytest.raises(ValueError):
        poisson_arrivals(0.0, 1.0)


def test_queue_rejects_bad_obs():
    q = MicroBatchQueue(max_batch=4, obs_dim=OBS_DIM)
    with pytest.raises(ValueError):
        q.push(ObsRequest(0, 0.0, np.zeros(OBS_DIM + 1, np.float32)))


# --- checkpoint seam -----------------------------------------------------------

def test_from_checkpoint_reproduces_decisions(params, norm, tmp_path):
    save_for_serving(str(tmp_path), 7, params, norm=norm,
                     metadata={"note": "test"})
    eng = ServeEngine.from_checkpoint(str(tmp_path), buckets=(8,),
                                      backend="jnp")
    np.testing.assert_array_equal(eng.norm.mean, norm.mean)
    np.testing.assert_array_equal(eng.norm.std, norm.std)
    obs = _obs(6, seed=12)
    src = ServeEngine(params, norm=norm, buckets=(8,), backend="jnp")
    np.testing.assert_array_equal(eng.decide(obs), src.decide(obs))


def test_from_checkpoint_accepts_bare_policy_tree(params, tmp_path):
    from repro.checkpoint import save

    save(str(tmp_path), 0, params)
    eng = ServeEngine.from_checkpoint(str(tmp_path), buckets=(8,))
    assert eng.obs_dim == OBS_DIM and eng.act_dim == ACT_DIM
    np.testing.assert_array_equal(eng.norm.mean,
                                  np.zeros(OBS_DIM, np.float32))


# --- end-to-end: clients -> queue -> engine ------------------------------------

def test_serving_pipeline_end_to_end_deterministic(params, norm):
    def serve_run():
        eng = ServeEngine(params, norm=norm, buckets=(8, 32),
                          mode="sample", backend="jnp", seed=5)
        q = MicroBatchQueue(max_batch=eng.max_batch(), obs_dim=OBS_DIM)
        q.push_all(simulate_clients(16, 2.0, 2.0, obs_dim=OBS_DIM, seed=13))
        out = {}
        while (nxt := q.next_batch()) is not None:
            obs, reqs = nxt
            act = eng.decide(obs)
            for r, a in zip(reqs, act):
                out.setdefault(r.client_id, []).append(a)
        return out

    a, b = serve_run(), serve_run()
    assert a.keys() == b.keys() and len(a) > 0
    for cid in a:
        np.testing.assert_array_equal(np.stack(a[cid]), np.stack(b[cid]))
