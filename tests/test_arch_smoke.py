"""Deliverable (f): per-architecture smoke tests on REDUCED variants.

Each assigned arch instantiates a reduced config (<=2 layers-ish, d<=512,
<=4 experts) and runs one forward + one train step on CPU, asserting output
shapes and the absence of NaNs.
"""
import jax
import jax.numpy as jnp
import pytest

import repro.configs as C
from repro.models import forward, init_params, lm_loss
from repro.models.transformer import padded_vocab
from repro.optim import adamw

ARCHS = C.list_archs()


def _batch(cfg, key, b=2, s=17):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            ks[1], (b, cfg.n_frontend_tokens, cfg.d_model)
        )
    if cfg.frontend == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            ks[2], (b, cfg.n_frontend_tokens, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_shapes_and_finite(arch):
    cfg = C.get_arch(arch).reduced()
    params = init_params(cfg, jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    if cfg.is_encoder_decoder:
        from repro.models.encdec import encdec_forward
        logits, _ = encdec_forward(cfg, params, batch["tokens"], batch["frames"])
        assert logits.shape == (2, 17, padded_vocab(cfg))
    else:
        logits, _, aux = forward(cfg, params, batch["tokens"],
                                 embeds=batch.get("patch_embeds"), mode="train")
        total = 17 + (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
        assert logits.shape == (2, total, padded_vocab(cfg))
        assert jnp.isfinite(aux)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step_decreases_loss(arch):
    cfg = C.get_arch(arch).reduced()
    params = init_params(cfg, jax.random.key(0))
    opt = adamw()
    opt_state = opt.init(params)
    batch = _batch(cfg, jax.random.key(1))

    @jax.jit
    def step(params, opt_state):
        loss, grads = jax.value_and_grad(lambda p: lm_loss(cfg, p, batch))(params)
        params, opt_state = opt.apply(grads, opt_state, params, 1e-3)
        return params, opt_state, loss

    losses = []
    for _ in range(5):
        params, opt_state, loss = step(params, opt_state)
        losses.append(float(loss))
    assert all(jnp.isfinite(jnp.asarray(losses))), losses
    # training on a fixed batch must reduce the loss
    assert losses[-1] < losses[0], losses


def test_exactly_ten_archs_registered():
    assert len(ARCHS) == 10
    families = {C.get_arch(a).family for a in ARCHS}
    assert families == {"dense", "moe", "ssm", "hybrid", "vlm", "audio"}


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_close_to_nameplate(arch):
    """Analytic n_params should be in the right ballpark of the arch's name."""
    cfg = C.get_arch(arch)
    n = cfg.n_params()
    nameplate = {
        "qwen2-72b": 72e9, "rwkv6-1.6b": 1.6e9, "h2o-danube-3-4b": 4e9,
        "recurrentgemma-9b": 9e9, "kimi-k2-1t-a32b": 1.0e12, "gemma-7b": 8.5e9,
        "internvl2-26b": 20e9, "phi4-mini-3.8b": 3.8e9, "arctic-480b": 480e9,
        "whisper-small": 0.24e9,
    }[arch]
    assert 0.5 * nameplate <= n <= 1.6 * nameplate, (arch, n, nameplate)
