"""Property-based tests (hypothesis) pinning the variation layer.

The traced variation axis rests on structural invariants of the tau_i
schedules and their indicator masks — A2 validity of the generators, mask
monotonicity, the traced/static mask construction agreeing bit-for-bit, and
the comm-accounting closed forms (``c2 == sum(taus)`` per full period and
the ``min(tau_i, n)`` truncation for partial periods). Random m/tau/seed
draws keep those pinned across the whole parameter space, not just the
hand-picked fixtures of the unit suites.

Skips cleanly when hypothesis is absent (the pinned-JAX CI leg and the
container exercise that path; the latest-JAX leg installs hypothesis).
"""
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.strategies import PeriodicStrategy, make_strategy
from repro.core.variation import (
    indicator_mask,
    mask_from_taus,
    masked_update_counts,
    tau_schedule,
    uniform_taus,
    validate_a2,
)

SETTINGS = settings(max_examples=40, deadline=None)


def _random_valid_taus(tau: int, m: int, seed: int) -> np.ndarray:
    return uniform_taus(1, tau, m, seed)


# --- schedule generators always satisfy A2 -------------------------------------

@SETTINGS
@given(tau=st.integers(1, 30), m=st.integers(1, 20), seed=st.integers(0, 99),
       lo_frac=st.floats(0.0, 1.0))
def test_uniform_taus_any_lo_satisfies_a2(tau, m, seed, lo_frac):
    lo = max(1, int(round(lo_frac * tau)))
    taus = uniform_taus(lo, tau, m, seed)
    validate_a2(taus, tau)


@SETTINGS
@given(tau=st.integers(1, 25),
       times=st.lists(st.floats(0.1, 10.0), min_size=2, max_size=12))
def test_tau_schedule_satisfies_a2(tau, times):
    """Eq. (6) schedules are valid A2 schedules at their own period length:
    the fastest agent paces (tau_1 = tau), everyone stays in {1..tau},
    sorted non-increasing."""
    taus = tau_schedule(tau, np.sort(np.asarray(times)))
    validate_a2(taus, tau)


# --- indicator mask structure --------------------------------------------------

@SETTINGS
@given(tau=st.integers(1, 30), m=st.integers(1, 16), seed=st.integers(0, 99))
def test_indicator_mask_monotone(tau, m, seed):
    """Rows are prefixes of ones (agent i runs its first tau_i offsets);
    columns are non-increasing down the A2-sorted agents and column sums are
    non-increasing across offsets (later offsets keep fewer agents active)."""
    taus = _random_valid_taus(tau, m, seed)
    mask = np.asarray(indicator_mask(taus, jnp.arange(tau)))
    assert mask.shape == (m, tau)
    assert set(np.unique(mask)) <= {0.0, 1.0}
    # row i == prefix of exactly tau_i ones
    np.testing.assert_array_equal(mask.sum(1), taus)
    assert np.all(np.diff(mask, axis=1) <= 0)      # prefix property per row
    # columns: sorted taus => within a column, active agents are a prefix
    assert np.all(np.diff(mask, axis=0) <= 0)
    # column sums decrease as the period progresses
    col = mask.sum(0)
    assert np.all(np.diff(col) <= 0)


@SETTINGS
@given(tau=st.integers(1, 30), m=st.integers(1, 16), seed=st.integers(0, 99))
def test_traced_mask_matches_static_constructor(tau, m, seed):
    """``mask_from_taus`` (the traced constructor, fed float32 schedules like
    the sweep's taus axis) is bit-identical to the static numpy
    ``AggregationStrategy._build_mask``."""
    taus = _random_valid_taus(tau, m, seed)
    static = PeriodicStrategy._build_mask(taus, tau)
    traced = np.asarray(mask_from_taus(jnp.asarray(taus, jnp.float32), tau))
    np.testing.assert_array_equal(static, traced)


# --- comm accounting closed forms ----------------------------------------------

@SETTINGS
@given(tau=st.integers(1, 25), m=st.integers(1, 12), seed=st.integers(0, 99))
def test_full_period_c2_equals_sum_taus(tau, m, seed):
    """One period bills exactly sum(taus) local updates (C2) and m uploads
    (C1) — and C2 equals the mask's total active-cell count."""
    taus = _random_valid_taus(tau, m, seed)
    strat = make_strategy("periodic", tau=tau, taus=taus, m=m)
    events = strat.comm_events_per_period()
    assert events["c2"] == int(taus.sum())
    assert events["c1"] == m
    assert events["c2"] == int(np.asarray(strat.mask).sum())


@SETTINGS
@given(tau=st.integers(2, 25), m=st.integers(1, 12), seed=st.integers(0, 99),
       frac=st.floats(0.0, 1.0))
def test_partial_period_c2_truncates_per_agent(tau, m, seed, frac):
    """A trailing partial period of n offsets bills sum_i min(tau_i, n) —
    the closed form equals the mask-column sum it replaced, and full+partial
    accounting is monotone in n."""
    taus = _random_valid_taus(tau, m, seed)
    strat = make_strategy("periodic", tau=tau, taus=taus, m=m)
    n = int(round(frac * (tau - 1)))
    events = strat.comm_events_partial_period(n)
    expect = int(masked_update_counts(taus, n).sum())
    assert events["c2"] == expect
    assert expect == int(np.asarray(strat.mask)[:, :n].sum())
    assert events["c1"] == (m if n else 0)
    # truncation bounds: never more than a full period, never negative
    assert 0 <= events["c2"] <= strat.comm_events_per_period()["c2"]
