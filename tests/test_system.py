"""End-to-end behaviour tests for the paper's system (deliverable c).

The headline check: on the paper's own task structure (federated MARL on the
ring-road env) the qualitative orderings the theory predicts hold end to end:
  * consensus reduces the measured expected gradient norm vs plain periodic;
  * the host FMARL driver (generic, supervised) converges on a quadratic and
    respects the tau/communication accounting of eq. (7).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_strategy, uniform_taus
from repro.core.fmarl import FmarlConfig, run_fmarl
from repro.core import topology as T
from repro.rl import FIGURE_EIGHT, FedRLConfig, run_fedrl
from repro.rl.fedrl import expected_gradient_norm


def _quadratic_grad(p, k, i, step):
    g = jax.tree.map(lambda x: x + 0.05 * jax.random.normal(k, x.shape), p)
    return g, {"loss": sum(jnp.sum(x**2) for x in jax.tree.leaves(p))}


def _eval_grad(p, k):
    return p


def test_fmarl_driver_converges_on_quadratic():
    strat = make_strategy("periodic", tau=5, m=6)
    cfg = FmarlConfig(strategy=strat, eta=0.1, n_periods=30)
    init = {"w": jnp.ones((8, 8)), "b": jnp.ones(8)}
    state, metrics, ledger = run_fmarl(cfg, init, _quadratic_grad,
                                       jax.random.key(0), _eval_grad)
    norms = np.asarray(metrics["server_grad_sq_norm"])
    assert norms[-1] < norms[0] * 1e-2
    assert ledger.c1_events == 6 * 30
    assert ledger.c2_events == 6 * 5 * 30


def test_decay_strategy_tracks_periodic_on_quadratic():
    from repro.core.decay import exponential_decay
    init = {"w": jnp.full((4, 4), 3.0)}
    outs = {}
    for name, strat in [
        ("periodic", make_strategy("periodic", tau=6, m=6)),
        ("decay", make_strategy("decay", tau=6, m=6,
                                decay=exponential_decay(0.9))),
    ]:
        cfg = FmarlConfig(strategy=strat, eta=0.08, n_periods=25)
        _, metrics, _ = run_fmarl(cfg, init, _quadratic_grad,
                                  jax.random.key(1), _eval_grad)
        outs[name] = np.asarray(metrics["server_grad_sq_norm"])[-1]
    assert np.isfinite(outs["periodic"]) and np.isfinite(outs["decay"])


def test_consensus_reduces_expected_gradient_norm_end_to_end():
    """Paper Table II: consensus rows show lower expected gradient norm than
    the plain periodic row at the same tau. Small-scale but end-to-end."""
    topo = T.random_regularish(7, 3, 4, seed=0)
    runs = {}
    for name, strat in [
        ("periodic", make_strategy("periodic", tau=4, m=7)),
        ("consensus", make_strategy("consensus", tau=4, topo=topo,
                                    eps=0.9 / topo.max_degree, rounds=2, m=7)),
    ]:
        cfg = FedRLConfig(env=FIGURE_EIGHT, strategy=strat, n_epochs=6,
                          epoch_len=80, minibatch=20, eta=5e-3)
        _, metrics, _ = run_fedrl(cfg, jax.random.key(0))
        runs[name] = expected_gradient_norm(metrics)
    assert runs["consensus"] < runs["periodic"] * 1.05, runs


def test_variation_aware_run_matches_a2_accounting():
    taus = uniform_taus(1, 4, 7, seed=1)
    strat = make_strategy("periodic", tau=4, taus=taus)
    cfg = FedRLConfig(env=FIGURE_EIGHT, strategy=strat, n_epochs=2,
                      epoch_len=40, minibatch=20, eta=3e-3)
    _, metrics, ledger = run_fedrl(cfg, jax.random.key(0))
    periods = (2 * (40 // 20)) // 4
    assert ledger.c2_events == int(taus.sum()) * periods
    assert np.all(np.isfinite(metrics["nas"]))
