"""Flat-carry federated loop: driver-level parity, jaxpr shape, accounting.

The PR-2 contract: on kernel backends both drivers keep the replica state as
one flat (m, n) matrix across the whole scan — ravel once at run start,
per-agent tree views only where user closures need them — and the result
matches the tree-space jnp reference. The jaxpr test pins the structural
claim: the inner scan body carries no per-step ravel of the *parameters*
(the gradients the user closure returns are the only thing flattened).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology as T
from repro.core.decay import exponential_decay
from repro.core.fmarl import FmarlConfig, run_fmarl
from repro.core.strategies import ConsensusStrategy, DecayStrategy, make_strategy
from repro.kernels import dispatch
from repro.optim.flat import flat_adam, flat_momentum, flat_sgd
from repro.rl import FIGURE_EIGHT, FedRLConfig, run_fedrl

TAUS = np.array([4, 4, 3, 2, 2, 1])  # A2: non-increasing, heterogeneous


def _quadratic_grad(p, k, i, step):
    g = jax.tree.map(lambda x: x + 0.05 * jax.random.normal(k, x.shape), p)
    return g, {"loss": sum(jnp.sum(x**2) for x in jax.tree.leaves(p))}


def _eval_grad(p, k):
    return p


# n = 8*9 + 7 = 79: deliberately not a multiple of any kernel block_n
INIT = {"w": jnp.ones((8, 9)), "b": jnp.ones(7)}


def _fmarl_strategies():
    topo = T.ring(6)
    return {
        "decay": lambda b: DecayStrategy(
            tau=4, taus=TAUS, decay=exponential_decay(0.9), backend=b
        ),
        "consensus": lambda b: ConsensusStrategy(
            tau=4, topo=topo, eps=0.3, rounds=2, taus=TAUS, backend=b
        ),
    }


@pytest.mark.parametrize("name", ["decay", "consensus"])
def test_fmarl_flat_scan_matches_tree_reference(name):
    mk = _fmarl_strategies()[name]
    outs, states = {}, {}
    for b in ("jnp", "interpret"):
        cfg = FmarlConfig(strategy=mk(b), eta=0.05, n_periods=5)
        state, metrics, ledger = run_fmarl(
            cfg, INIT, _quadratic_grad, jax.random.key(0), _eval_grad
        )
        outs[b] = np.asarray(metrics["server_grad_sq_norm"])
        states[b] = state
    np.testing.assert_allclose(outs["jnp"], outs["interpret"], rtol=1e-4)
    # the final replica/server pytrees agree too (flat carry unravels cleanly)
    for leaf_a, leaf_b in zip(
        jax.tree.leaves(states["jnp"].params_m),
        jax.tree.leaves(states["interpret"].params_m),
    ):
        np.testing.assert_allclose(leaf_a, leaf_b, atol=1e-5)
    for leaf_a, leaf_b in zip(
        jax.tree.leaves(states["jnp"].server_params),
        jax.tree.leaves(states["interpret"].server_params),
    ):
        np.testing.assert_allclose(leaf_a, leaf_b, atol=1e-5)


@pytest.mark.parametrize("name", ["decay", "consensus"])
def test_fedrl_flat_scan_matches_tree_reference(name):
    topo = T.random_regularish(7, 3, 4, seed=0)
    builders = {
        "decay": lambda b: make_strategy(
            "decay", tau=3, m=7, decay=exponential_decay(0.9), backend=b
        ),
        "consensus": lambda b: make_strategy(
            "consensus", tau=3, topo=topo, eps=0.1, rounds=1, m=7, backend=b
        ),
    }
    outs = {}
    for b in ("jnp", "interpret"):
        cfg = FedRLConfig(env=FIGURE_EIGHT, strategy=builders[name](b),
                          n_epochs=2, epoch_len=60, minibatch=20, eta=3e-3)
        _, metrics, _ = run_fedrl(cfg, jax.random.key(0))
        outs[b] = metrics
    np.testing.assert_allclose(outs["jnp"]["nas"], outs["interpret"]["nas"],
                               rtol=1e-4)
    np.testing.assert_allclose(
        outs["jnp"]["server_grad_sq_norm"],
        outs["interpret"]["server_grad_sq_norm"],
        rtol=1e-3,
    )


@pytest.mark.parametrize("opt", [flat_sgd(), flat_momentum(0.9), flat_adam()],
                         ids=lambda o: o.kind)
def test_fmarl_optimizer_backends_agree(opt):
    """The flat optimizer path (momentum/adam fp32 accumulators) is the same
    on the jnp reference and the interpret kernel path."""
    outs = {}
    for b in ("jnp", "interpret"):
        strat = make_strategy("periodic", tau=3, m=6, backend=b)
        cfg = FmarlConfig(strategy=strat, eta=0.05, n_periods=4, optimizer=opt)
        _, metrics, _ = run_fmarl(cfg, INIT, _quadratic_grad,
                                  jax.random.key(0), _eval_grad)
        outs[b] = np.asarray(metrics["server_grad_sq_norm"])
        assert np.all(np.isfinite(outs[b]))
    np.testing.assert_allclose(outs["jnp"], outs["interpret"], rtol=1e-4)


def test_fedrl_optimizer_runs_finite():
    strat = make_strategy("periodic", tau=3, m=7, backend="jnp")
    cfg = FedRLConfig(env=FIGURE_EIGHT, strategy=strat, n_epochs=2,
                      epoch_len=60, minibatch=20, eta=1e-3,
                      optimizer=flat_adam())
    _, metrics, _ = run_fedrl(cfg, jax.random.key(0))
    assert np.all(np.isfinite(metrics["server_grad_sq_norm"]))
    assert np.all(np.isfinite(metrics["nas"]))


# --- structural claim: no per-step params ravel in the scan body ---------------

def test_flat_scan_body_drops_params_ravel():
    """Count concatenate ops in the scanned step jaxpr: the flat carry keeps
    exactly the gradient ravel (1 concatenate for a 2-leaf tree), while the
    PR-1 ravel-per-step form also re-flattened the params every step (2)."""
    strat = DecayStrategy(tau=4, taus=np.array([4, 2, 1]),
                          decay=exponential_decay(0.9), backend="interpret")
    tree = {
        "w": jax.random.normal(jax.random.key(0), (3, 8, 8)),
        "b": jax.random.normal(jax.random.key(1), (3, 16)),
    }
    flat, spec = dispatch.stacked_ravel_spec(tree)

    def grad_fn(p):
        return jax.tree.map(lambda x: 0.1 * x + 1.0, p)

    def flat_step(f, offset):
        g = jax.vmap(lambda row: spec.ravel_one(grad_fn(spec.unravel_one(row))))(f)
        return strat.flat_update(f, g, offset, 0.1), None

    def ravel_per_step(t, offset):   # the PR-1 hot path, for comparison
        g = jax.vmap(grad_fn)(t)
        return strat.local_update(t, g, offset, 0.1), None

    jaxpr_flat = str(jax.make_jaxpr(
        lambda f: jax.lax.scan(flat_step, f, jnp.arange(4)))(flat))
    jaxpr_tree = str(jax.make_jaxpr(
        lambda t: jax.lax.scan(ravel_per_step, t, jnp.arange(4)))(tree))
    n_flat = jaxpr_flat.count("concatenate")
    n_tree = jaxpr_tree.count("concatenate")
    assert n_flat == 1, f"flat scan body should only ravel grads, saw {n_flat}"
    assert n_tree == 2, f"ravel-per-step comparison changed shape, saw {n_tree}"


# --- traced-mask drivers (the variation axis end to end) -----------------------

def _variation_builders(m=7, tau=3):
    topo = T.random_regularish(m, 3, 4, seed=0)
    return {
        "masked-sgd": lambda taus=None, b="jnp": make_strategy(
            "periodic", tau=tau, m=m, taus=taus, backend=b
        ),
        "decay": lambda taus=None, b="jnp": make_strategy(
            "decay", tau=tau, m=m, taus=taus, decay=exponential_decay(0.9),
            backend=b,
        ),
        "consensus": lambda taus=None, b="jnp": make_strategy(
            "consensus", tau=tau, topo=topo, eps=0.1, rounds=1, m=m,
            taus=taus, backend=b,
        ),
    }


VARIATION_TAUS = np.array([3, 3, 2, 2, 2, 1, 1])  # A2 at tau=3, m=7


@pytest.mark.parametrize("name", ["masked-sgd", "decay", "consensus"])
def test_fedrl_traced_mask_bitwise_on_jnp(name):
    """Driver-level bit-identity: the eager jnp reference driver with a
    traced-mask strategy copy (override_taus on a concrete schedule) equals
    the static-numpy-mask driver exactly — metrics AND comm ledger."""
    from repro.sweep.overrides import override_taus

    mk = _variation_builders()[name]
    cfg_static = FedRLConfig(env=FIGURE_EIGHT, strategy=mk(taus=VARIATION_TAUS),
                             n_epochs=2, epoch_len=40, minibatch=20, eta=3e-3)
    cfg_traced = override_taus(
        FedRLConfig(env=FIGURE_EIGHT, strategy=mk(), n_epochs=2,
                    epoch_len=40, minibatch=20, eta=3e-3),
        jnp.asarray(VARIATION_TAUS, jnp.float32),
    )
    _, m_s, l_s = run_fedrl(cfg_static, jax.random.key(0))
    _, m_t, l_t = run_fedrl(cfg_traced, jax.random.key(0))
    for k in m_s:
        np.testing.assert_array_equal(m_t[k], m_s[k], err_msg=k)
    assert l_t.table_row() == l_s.table_row()


@pytest.mark.parametrize("name", ["masked-sgd", "decay", "consensus"])
def test_fedrl_traced_mask_jit_operand_parity(name):
    """Under jit with the schedule as an *operand* (the sweep's traced taus
    axis), the driver stays within ulp tolerance of the constant-mask static
    program — XLA may fold literal masks differently, nothing more."""
    from repro.sweep.overrides import override_taus

    from repro.rl.fedrl import run_fedrl_core

    mk = _variation_builders()[name]
    # strategies are built EAGERLY (their A2/A3 validation cannot run on
    # tracers); only the override runs inside the trace, like the sweep does
    cfg_base = FedRLConfig(env=FIGURE_EIGHT, strategy=mk(), n_epochs=2,
                           epoch_len=40, minibatch=20, eta=3e-3)
    cfg_static = dataclasses_replace(cfg_base, strategy=mk(taus=VARIATION_TAUS))

    traced = jax.device_get(
        jax.jit(
            lambda t: run_fedrl_core(override_taus(cfg_base, t),
                                     jax.random.key(0))[1]
        )(jnp.asarray(VARIATION_TAUS, jnp.float32))
    )
    static = jax.device_get(
        jax.jit(lambda: run_fedrl_core(cfg_static, jax.random.key(0))[1])()
    )
    for k in static:
        np.testing.assert_allclose(traced[k], static[k], rtol=1e-4, atol=1e-5,
                                   err_msg=k)


@pytest.mark.parametrize("backend", ["jnp", "interpret"])
def test_fmarl_traced_mask_matches_static(backend):
    """Task-generic driver: traced-mask copies track the static strategies on
    both the tree reference (bitwise) and the interpret kernel path (ulp)."""
    strat_static = DecayStrategy(tau=4, taus=TAUS, decay=exponential_decay(0.9),
                                 backend=backend)
    base = DecayStrategy(tau=4, m=6, decay=exponential_decay(0.9),
                         backend=backend)
    strat_traced = base.with_mask(
        jnp.asarray(DecayStrategy._build_mask(TAUS, 4)), taus=TAUS
    )
    outs = {}
    for tag, strat in (("static", strat_static), ("traced", strat_traced)):
        cfg = FmarlConfig(strategy=strat, eta=0.05, n_periods=4)
        _, metrics, ledger = run_fmarl(cfg, INIT, _quadratic_grad,
                                       jax.random.key(0), _eval_grad)
        outs[tag] = (np.asarray(metrics["server_grad_sq_norm"]), ledger)
    if backend == "jnp":
        np.testing.assert_array_equal(outs["traced"][0], outs["static"][0])
    else:
        np.testing.assert_allclose(outs["traced"][0], outs["static"][0],
                                   rtol=1e-6)
    assert outs["traced"][1].table_row() == outs["static"][1].table_row()


# --- communication-cost accounting (trailing partial period) -------------------

def test_fedrl_ledger_counts_trailing_partial_period():
    """6 epochs x 1 update with tau=4 = one full period + 2 trailing local
    steps; the old ``n_updates // tau`` dropped the trailing C2 events."""
    strat = make_strategy("periodic", tau=4, m=7)
    cfg = FedRLConfig(env=FIGURE_EIGHT, strategy=strat, n_epochs=6,
                      epoch_len=20, minibatch=20, eta=1e-3)
    _, _, ledger = run_fedrl(cfg, jax.random.key(0))
    # 6 updates = 1 full period (m uploads, m*tau local steps) + partial of 2
    assert ledger.c2_events == 7 * 4 + 7 * 2
    assert ledger.c1_events == 7 + 7  # final aggregation read bills uploads


def test_fedrl_ledger_exact_periods_unchanged():
    strat = make_strategy("periodic", tau=3, m=7)
    cfg = FedRLConfig(env=FIGURE_EIGHT, strategy=strat, n_epochs=4,
                      epoch_len=60, minibatch=20, eta=1e-3)
    _, _, ledger = run_fedrl(cfg, jax.random.key(0))
    assert ledger.c1_events == 7 * 4
    assert ledger.c2_events == 7 * 3 * 4


def test_fedrl_consensus_partial_period_bills_gossip():
    topo = T.random_regularish(7, 3, 4, seed=0)
    strat = make_strategy("consensus", tau=4, topo=topo, eps=0.1, rounds=2, m=7)
    cfg = FedRLConfig(env=FIGURE_EIGHT, strategy=strat, n_epochs=6,
                      epoch_len=20, minibatch=20, eta=1e-3)
    _, _, ledger = run_fedrl(cfg, jax.random.key(0))
    gossip_per_step = int(topo.degrees.sum()) * 2
    assert ledger.w1_events == gossip_per_step * 6  # all 6 local steps billed
    assert ledger.w1_events == ledger.w2_events


def test_fmarl_ledger_stays_exact():
    strat = make_strategy("periodic", tau=5, m=6, backend="interpret")
    cfg = FmarlConfig(strategy=strat, eta=0.1, n_periods=3)
    _, _, ledger = run_fmarl(cfg, INIT, _quadratic_grad, jax.random.key(0),
                             _eval_grad)
    assert ledger.c1_events == 6 * 3
    assert ledger.c2_events == 6 * 5 * 3


def test_partial_period_accounting_validation():
    strat = make_strategy("periodic", tau=4, m=3)
    with pytest.raises(ValueError):
        strat.comm_events_partial_period(4)  # must be < tau
    with pytest.raises(ValueError):
        strat.comm_events_partial_period(-1)
    assert strat.comm_events_partial_period(0) == {
        "c1": 0, "c2": 0, "w1": 0, "w2": 0
    }


# --- eval stream decorrelation (the PRNG-key reuse fix) ------------------------

def test_eval_grad_norm_uses_decorrelated_streams():
    """_eval_grad_norm must split the eval seed: reset and rollout streams
    were previously the same key, correlating the eval trajectory's action
    noise with the initial env state."""
    import repro.rl.fedrl as fedrl_mod

    strat = make_strategy("periodic", tau=2, m=7)
    cfg = FedRLConfig(env=FIGURE_EIGHT, strategy=strat)
    server = fedrl_mod.init_policy(jax.random.key(5), fedrl_mod.OBS_DIM)
    a = fedrl_mod._eval_grad_norm(cfg, server)
    b = fedrl_mod._eval_grad_norm(cfg, server)
    np.testing.assert_allclose(a, b)  # still deterministic in eval_seed
    c = fedrl_mod._eval_grad_norm(
        dataclasses_replace(cfg, eval_seed=999), server
    )
    assert not np.allclose(a, c)  # and actually seed-dependent


def dataclasses_replace(cfg, **kw):
    import dataclasses

    return dataclasses.replace(cfg, **kw)
