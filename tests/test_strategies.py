"""Unit tests for the paper's aggregation strategies (core contribution)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ConsensusStrategy,
    DecayStrategy,
    PeriodicStrategy,
    SyncStrategy,
    exponential_decay,
    make_strategy,
    uniform_taus,
)
from repro.core import topology as T


def _grads(m=5, seed=0):
    key = jax.random.key(seed)
    return {
        "w": jax.random.normal(key, (m, 3, 4)),
        "b": jax.random.normal(jax.random.split(key)[0], (m, 2)),
    }


def test_sync_strategy_is_tau_1():
    s = SyncStrategy(m=4)
    assert s.tau == 1 and np.all(s.taus == 1)


def test_periodic_mask_matches_indicator():
    taus = np.array([5, 3, 1])
    s = PeriodicStrategy(tau=5, taus=taus)
    for j in range(5):
        w = np.asarray(s.weight(j))
        assert np.array_equal(w, (taus > j).astype(np.float32))


def test_variation_mask_zeroes_exhausted_agents():
    taus = np.array([4, 2, 1])
    s = PeriodicStrategy(tau=4, taus=taus)
    g = _grads(m=3)
    out = s.transform(g, 3)  # offset 3: only agent 0 still active
    assert np.allclose(np.asarray(out["w"])[1:], 0.0)
    assert np.allclose(np.asarray(out["w"])[0], np.asarray(g["w"])[0])


def test_server_average_is_mean():
    s = PeriodicStrategy(tau=2, m=4)
    g = _grads(m=4)
    avg = s.server_average(g)
    assert np.allclose(np.asarray(avg["w"]), np.asarray(g["w"]).mean(0), atol=1e-6)


def test_decay_weights_follow_eq21():
    lam = 0.9
    s = DecayStrategy(tau=6, m=3, decay=exponential_decay(lam))
    for j in range(6):
        w = np.asarray(s.weight(j))
        assert np.allclose(w, lam ** (j / 2), atol=1e-6)


def test_decay_rejects_non_a3_function():
    increasing = lambda j: 1.0 + j  # violates D <= 1 monotone
    with pytest.raises(ValueError):
        DecayStrategy(tau=4, m=2, decay=increasing)


def test_consensus_fused_equals_explicit_rounds():
    topo = T.ring(6)
    g = _grads(m=6)
    for rounds in (1, 2, 3):
        fused = ConsensusStrategy(tau=3, topo=topo, eps=0.3, rounds=rounds,
                                  fused=True)
        loop = ConsensusStrategy(tau=3, topo=topo, eps=0.3, rounds=rounds,
                                 fused=False)
        a = fused.transform(g, 0)
        b = loop.transform(g, 0)
        assert jnp.allclose(a["w"], b["w"], atol=1e-5)


def test_consensus_preserves_mean():
    """P is doubly stochastic: gossip never changes the across-agent mean."""
    topo = T.random_regularish(7, 3, 4, seed=1)
    s = ConsensusStrategy(tau=2, topo=topo, eps=0.1, rounds=3)
    g = _grads(m=7)
    out = s.transform(g, 0)
    assert jnp.allclose(out["w"].mean(0), g["w"].mean(0), atol=1e-5)


def test_consensus_comm_events_match_eq27():
    topo = T.random_regularish(7, 3, 4, seed=0)
    s = ConsensusStrategy(tau=10, topo=topo, eps=0.1, rounds=2)
    ev = s.comm_events_per_period()
    assert ev["c1"] == 7
    assert ev["c2"] == 70
    assert ev["w1"] == int(topo.degrees.sum()) * 2 * 10
    assert ev["w1"] == ev["w2"]


def test_make_strategy_dispatch():
    assert make_strategy("sync", m=3).name == "sync"
    assert make_strategy("periodic", tau=4, m=3).tau == 4
    taus = uniform_taus(1, 8, 5, seed=0)
    assert make_strategy("periodic", tau=8, taus=taus).m == 5
    with pytest.raises(ValueError):
        make_strategy("nope", m=2)
