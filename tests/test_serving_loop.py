"""Batched serving loop: lockstep slot decoding must match single-request
greedy decoding exactly."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.launch.serving_loop import Request, ServingLoop
from repro.models import decode_step, init_params, prefill


def _greedy_reference(cfg, params, prompt, n_new, max_seq=64):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, st = prefill(cfg, params, toks, cache_len=max_seq)
    out = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out.append(int(tok[0, 0]))
    pos = len(prompt)
    for _ in range(n_new - 1):
        logits, st = decode_step(cfg, params, tok, st, jnp.asarray([pos]))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(int(tok[0, 0]))
        pos += 1
    return out


def test_serving_loop_matches_single_request_decode():
    cfg = dataclasses.replace(C.get_arch("phi4-mini-3.8b").reduced(),
                              attn_impl="einsum")
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 3, 7)]
    n_new = 4

    loop = ServingLoop(cfg, params, n_slots=2, max_seq=64)
    done = loop.run([Request(i, p, n_new) for i, p in enumerate(prompts)])
    got = {c.rid: c.tokens for c in done}
    assert set(got) == {0, 1, 2}

    for i, p in enumerate(prompts):
        ref = _greedy_reference(cfg, params, p, n_new)
        assert got[i] == ref, f"request {i}: {got[i]} != {ref}"


def test_serving_loop_recycles_slots():
    cfg = dataclasses.replace(C.get_arch("rwkv6-1.6b").reduced(),
                              attn_impl="einsum")
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(1)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=4).astype(np.int32), 3)
            for i in range(5)]
    loop = ServingLoop(cfg, params, n_slots=2, max_seq=48)
    done = loop.run(reqs)
    assert sorted(c.rid for c in done) == [0, 1, 2, 3, 4]
    assert all(len(c.tokens) == 3 for c in done)


def test_ssm_slot_recycling_resets_recurrent_state():
    """A recycled slot must produce the same tokens as a fresh run — the
    WKV state from the previous occupant must not leak."""
    cfg = dataclasses.replace(C.get_arch("rwkv6-1.6b").reduced(),
                              attn_impl="einsum")
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(2)
    p1 = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
    # one slot => request 1 then request 2 recycle the same slot
    loop = ServingLoop(cfg, params, n_slots=1, max_seq=48)
    done = loop.run([Request(0, p1, 3), Request(1, p2, 3)])
    got = {c.rid: c.tokens for c in done}
    assert got[1] == _greedy_reference(cfg, params, p2, 3), "state leaked"
