"""Comm layer: payload transforms, error feedback, the fused top-k scatter
reduction, byte-exact ledger accounting, and the compressed drivers.

The load-bearing contracts:

* ``encode`` conservation — ``sent + residual == x`` exactly in fp32, for
  every transform kind (the error-feedback algebra depends on it);
* jnp-vs-interpret parity of ``dispatch.topk_scatter`` under the shared
  threshold selection rule;
* identity comm is a bitwise no-op: the flat drivers with ``IDENTITY``
  reproduce the legacy path exactly;
* the ledger's wire bytes are exact arithmetic — dense fp32 is
  ``events * payload_elems * 4`` including partial trailing periods.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (
    IDENTITY,
    PayloadTransform,
    dequantize_int8,
    identity,
    qbf16,
    qint8,
    quantize_int8,
    topk,
    topk_threshold,
)
from repro.core import make_strategy, uniform_taus
from repro.core import topology as T
from repro.core.decay import exponential_decay
from repro.kernels import dispatch
from repro.rl import FIGURE_EIGHT, FedRLConfig
from repro.rl.fedrl import (
    fedrl_bytes_curve,
    fedrl_ledger,
    policy_payload_elems,
    run_fedrl_core,
)

ALL_TRANSFORMS = (identity(), topk(5), qint8(), qbf16())


def _x(m=7, n=33, seed=0):
    return jax.random.normal(jax.random.key(seed), (m, n), jnp.float32)


# --- transform specs -----------------------------------------------------------

def test_payload_transform_validation():
    with pytest.raises(ValueError, match="unknown payload transform"):
        PayloadTransform("fp8")
    with pytest.raises(ValueError, match="k >= 1"):
        PayloadTransform("topk", k=0)
    with pytest.raises(ValueError, match="k only applies"):
        PayloadTransform("int8", k=3)
    strat = make_strategy("periodic", tau=2, m=7)
    with pytest.raises(TypeError, match="PayloadTransform"):
        strat.with_comm("topk")


def test_labels_and_payload_bytes():
    assert identity().label == "dense" and not identity().enabled
    assert topk(8).label == "topk8" and topk(8).enabled
    assert qint8().label == "int8" and qbf16().label == "bf16"
    n = 100
    assert identity().payload_bytes(n) == 4 * n
    assert topk(8).payload_bytes(n) == 8 * 8
    assert topk(8).payload_bytes(4) == 8 * 4      # k clips to n
    assert qint8().payload_bytes(n) == n + 4
    assert qbf16().payload_bytes(n) == 2 * n
    with pytest.raises(ValueError):
        identity().payload_bytes(-1)


def test_transforms_are_hashable_statics():
    """jit-closable like FlatOptimizer: equal specs hash equal."""
    assert topk(8) == topk(8) and hash(topk(8)) == hash(topk(8))
    assert topk(8) != topk(9) and qint8() != qint8(error_feedback=False)
    assert IDENTITY is identity()


# --- selection / quantization primitives ---------------------------------------

def test_topk_threshold_keeps_ties():
    x = jnp.asarray([[3.0, -3.0, 1.0, 0.5], [4.0, 0.1, 0.2, 0.3]])
    th = topk_threshold(x, 2)
    np.testing.assert_array_equal(
        np.asarray(th), np.asarray([3.0, 0.3], np.float32)
    )
    # both magnitude-3 entries survive the k=2 threshold (ties included)
    keep = np.abs(np.asarray(x)) >= np.asarray(th)[:, None]
    assert keep[0].sum() == 2 and keep[1].sum() == 2
    with pytest.raises(ValueError):
        topk_threshold(x, 0)
    with pytest.raises(ValueError):
        topk_threshold(x, 5)


def test_int8_roundtrip_error_is_half_ulp_of_the_row_scale():
    x = _x(5, 64, seed=1) * jnp.asarray([1e-3, 1.0, 1e3, 1e-6, 42.0])[:, None]
    q, scale = quantize_int8(x)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(dequantize_int8(q, scale)) - np.asarray(x))
    bound = np.asarray(scale)[:, None] * (0.5 + 1e-6)
    assert np.all(err <= bound)


def test_int8_all_zero_row_is_safe():
    q, scale = quantize_int8(jnp.zeros((2, 8)))
    assert np.all(np.asarray(q) == 0) and np.all(np.asarray(scale) == 0.0)
    np.testing.assert_array_equal(np.asarray(dequantize_int8(q, scale)), 0.0)


# --- encode / reduce_mean ------------------------------------------------------

@pytest.mark.parametrize("tr", ALL_TRANSFORMS, ids=lambda t: t.label)
def test_encode_conservation_is_exact(tr):
    """sent + residual == x bitwise in fp32 — the EF-SGD invariant."""
    x = _x()
    sent, residual = tr.encode(x)
    np.testing.assert_array_equal(np.asarray(sent + residual), np.asarray(x))
    if tr.enabled:
        assert float(jnp.sum(residual != 0)) > 0  # actually lossy
    else:
        np.testing.assert_array_equal(np.asarray(residual), 0.0)


@pytest.mark.parametrize("tr", ALL_TRANSFORMS, ids=lambda t: t.label)
def test_reduce_mean_matches_encode_reference(tr):
    """The fused server reduction == mean over agents of the encoded rows."""
    x = _x(seed=2)
    mean, residual = tr.reduce_mean(x, backend="jnp")
    sent_ref, resid_ref = tr.encode(x)
    np.testing.assert_allclose(
        np.asarray(mean), np.asarray(sent_ref.mean(axis=0)), rtol=1e-6,
        atol=1e-7,
    )
    np.testing.assert_array_equal(np.asarray(residual), np.asarray(resid_ref))


def test_topk_scatter_jnp_interpret_parity():
    """Shared threshold selection rule: both backends pick identical entries
    (residual bitwise-equal), sums agree to fp32 reduction tolerance."""
    for m, n in ((7, 33), (3, 4096 + 17)):  # odd n exercises the tail block
        x = _x(m, n, seed=3)
        th = topk_threshold(x, max(1, n // 8))
        s_j, r_j = dispatch.topk_scatter(x, th, backend="jnp")
        s_i, r_i = dispatch.topk_scatter(x, th, backend="interpret")
        np.testing.assert_array_equal(np.asarray(r_j), np.asarray(r_i))
        np.testing.assert_allclose(
            np.asarray(s_j), np.asarray(s_i), rtol=1e-6, atol=1e-6
        )
        # residual is exactly the unselected remainder (sent + residual == x)
        kept = jnp.where(jnp.abs(x) >= th[:, None], x, 0.0)
        np.testing.assert_array_equal(np.asarray(r_j), np.asarray(x - kept))


def test_topk_scatter_sweep_axis_self_vmaps():
    S, m, n = 3, 5, 40
    x = jax.random.normal(jax.random.key(4), (S, m, n), jnp.float32)
    th = topk_threshold(x, 6)
    assert th.shape == (S, m)
    ssum, resid = dispatch.topk_scatter(x, th, backend="jnp")
    assert ssum.shape == (S, n) and resid.shape == (S, m, n)
    for s in range(S):
        ref_sum, ref_res = dispatch.topk_scatter(x[s], th[s], backend="jnp")
        np.testing.assert_array_equal(np.asarray(resid[s]), np.asarray(ref_res))
        np.testing.assert_allclose(
            np.asarray(ssum[s]), np.asarray(ref_sum), rtol=1e-6
        )


def test_topk_scatter_shape_validation():
    x = _x(4, 8)
    with pytest.raises(ValueError, match="thresh"):
        dispatch.topk_scatter(x, jnp.zeros(3), backend="jnp")
    with pytest.raises(ValueError, match="x must be"):
        dispatch.topk_scatter(jnp.zeros(8), jnp.zeros(1), backend="jnp")


# --- strategy seam: comm state + flat_sync -------------------------------------

def test_init_comm_state_structure():
    flat = _x(7, 20)
    base = make_strategy("periodic", tau=3, m=7)
    assert base.init_comm_state(flat) == {}
    ef = base.with_comm(topk(4))
    assert set(ef.init_comm_state(flat)) == {"ref", "err_up"}
    no_ef = base.with_comm(topk(4, error_feedback=False))
    assert set(no_ef.init_comm_state(flat)) == {"ref"}
    topo = T.random_regularish(7, 3, 4, seed=0)
    cons = make_strategy("consensus", tau=3, topo=topo, eps=0.1, m=7,
                         comm=qint8())
    assert set(cons.init_comm_state(flat)) == {"ref", "err_up", "err_gossip"}
    state = ef.init_comm_state(flat)
    np.testing.assert_array_equal(np.asarray(state["ref"]), np.asarray(flat[0]))
    np.testing.assert_array_equal(np.asarray(state["err_up"]), 0.0)


def test_flat_sync_identity_is_bitwise_legacy():
    strat = make_strategy("periodic", tau=3, m=7, backend="jnp")
    flat = _x(7, 31, seed=5)
    synced, state = strat.flat_sync(flat, {})
    assert state == {}
    row = dispatch.row_mean(flat, backend="jnp")
    np.testing.assert_array_equal(
        np.asarray(synced), np.asarray(jnp.broadcast_to(row[None], flat.shape))
    )


def test_flat_sync_compressed_advances_ref_and_banks_residual():
    """One compressed sync == encode the per-agent deltas (+ prior EF),
    move the shared reference by the mean reconstruction, bank the rest."""
    tr = topk(6)
    strat = make_strategy("periodic", tau=3, m=7, backend="jnp", comm=tr)
    row0 = jax.random.normal(jax.random.key(6), (29,), jnp.float32)
    flat = jnp.broadcast_to(row0[None], (7, 29)) + 0.1 * _x(7, 29, seed=7)
    err0 = 0.01 * _x(7, 29, seed=8)
    state = {"ref": row0, "err_up": err0}
    synced, new_state = strat.flat_sync(flat, state)

    delta = flat - row0[None, :] + err0
    sent, resid = tr.encode(delta)
    row_ref = row0 + sent.mean(axis=0)
    np.testing.assert_allclose(
        np.asarray(new_state["ref"]), np.asarray(row_ref), rtol=1e-6, atol=1e-7
    )
    np.testing.assert_array_equal(
        np.asarray(new_state["err_up"]), np.asarray(resid)
    )
    np.testing.assert_array_equal(
        np.asarray(synced),
        np.asarray(jnp.broadcast_to(new_state["ref"][None], flat.shape)),
    )


# --- drivers -------------------------------------------------------------------

def _cfg(comm=None, strategy=None, **kw):
    strat = strategy or make_strategy(
        "decay", tau=3, m=7, decay=exponential_decay(0.95), backend="jnp"
    )
    if comm is not None:
        strat = strat.with_comm(comm)
    # 3 updates/epoch with tau=3: the period sync fires once per epoch, so
    # the compressed uplink actually runs (2 updates would never sync).
    kw.setdefault("n_epochs", 2)
    kw.setdefault("epoch_len", 12)
    kw.setdefault("minibatch", 4)
    kw.setdefault("eta", 3e-3)
    return FedRLConfig(env=FIGURE_EIGHT, strategy=strat, **kw)


def _metrics(cfg, seed=0):
    return jax.device_get(
        jax.jit(lambda k: run_fedrl_core(cfg, k)[1])(jax.random.key(seed))
    )


def test_fedrl_flat_identity_comm_is_bitwise_legacy():
    """IDENTITY comm through the flat carry reproduces the tree-space
    reference exactly — the comm-state threading is a no-op when dense."""
    tree = _metrics(_cfg())                              # legacy tree path
    flat = _metrics(_cfg(buffer_dtype="float32"))        # flat carry, dense
    for k, arr in tree.items():
        np.testing.assert_array_equal(flat[k], np.asarray(arr), err_msg=k)


@pytest.mark.parametrize("tr", (topk(64), qint8()), ids=lambda t: t.label)
def test_fedrl_compressed_runs_and_is_a_real_knob(tr):
    dense = _metrics(_cfg())
    comp = _metrics(_cfg(comm=tr))
    assert np.all(np.isfinite(comp["server_grad_sq_norm"]))
    assert np.all(np.isfinite(comp["nas"]))
    assert float(np.max(np.abs(comp["nas"] - dense["nas"]))) > 0


def test_error_feedback_changes_the_trajectory():
    """The first sync's residual is zero, so EF first bites at the second
    sync — visible in the epoch-end server grad norm."""
    with_ef = _metrics(_cfg(comm=topk(64)))
    without = _metrics(_cfg(comm=topk(64, error_feedback=False)))
    diff = np.abs(with_ef["server_grad_sq_norm"]
                  - without["server_grad_sq_norm"])
    assert float(np.max(diff)) > 0


def test_consensus_compressed_gossip_runs():
    topo = T.random_regularish(7, 3, 4, seed=0)

    def run(comm):
        strat = make_strategy("consensus", tau=3, topo=topo, eps=0.1, m=7,
                              backend="jnp")
        return _metrics(_cfg(comm=comm, strategy=strat))

    dense, comp = run(None), run(qint8())
    assert np.all(np.isfinite(comp["nas"]))
    assert float(np.max(np.abs(comp["nas"] - dense["nas"]))) > 0


# --- ledger bytes --------------------------------------------------------------

def test_dense_ledger_bytes_are_events_times_4n():
    """The pinned dense contract, including a partial trailing period:
    c1_bytes == c1_events * payload_elems * 4 exactly."""
    n = policy_payload_elems()
    # 2 updates/epoch * 3 epochs = 6 updates; tau=4 -> 1 full + 2 partial
    cfg = _cfg(strategy=make_strategy("periodic", tau=4, m=7),
               n_epochs=3, epoch_len=8, minibatch=4)
    ledger = fedrl_ledger(cfg)
    assert ledger.c1_events == 7 * 2           # full-period + partial read
    assert ledger.c1_bytes == ledger.c1_events * n * 4
    assert ledger.w1_bytes == 0
    assert ledger.total_bytes() == ledger.c1_bytes
    row = ledger.table_row()
    assert row["uplink_bytes_C1"] == ledger.c1_bytes
    assert row["total_bytes"] == ledger.total_bytes()


@pytest.mark.parametrize(
    "tr,per_event",
    [
        (topk(50), 8 * 50),
        (qint8(), policy_payload_elems() + 4),
        (qbf16(), 2 * policy_payload_elems()),
    ],
    ids=lambda v: v.label if isinstance(v, PayloadTransform) else str(v),
)
def test_compressed_ledger_bytes_are_exact(tr, per_event):
    cfg = _cfg(comm=tr)
    ledger = fedrl_ledger(cfg)
    assert ledger.c1_bytes == ledger.c1_events * per_event
    dense = fedrl_ledger(_cfg())
    assert dense.c1_events == ledger.c1_events  # same event count, fewer bytes
    assert ledger.total_bytes() < dense.total_bytes()


def test_consensus_ledger_bills_gossip_bytes():
    topo = T.random_regularish(7, 3, 4, seed=0)
    strat = make_strategy("consensus", tau=3, topo=topo, eps=0.1, m=7,
                          comm=topk(50))
    cfg = _cfg(strategy=strat)
    ledger = fedrl_ledger(cfg)
    assert ledger.w1_events > 0
    assert ledger.w1_bytes == ledger.w1_events * 8 * 50
    assert ledger.total_bytes() == ledger.c1_bytes + ledger.w1_bytes


def test_bytes_curve_is_cumulative_and_matches_the_ledger():
    cfg = _cfg(comm=topk(50), n_epochs=4)
    curve = fedrl_bytes_curve(cfg)
    assert curve.shape == (4,)
    assert np.all(np.diff(curve) >= 0) and curve[0] > 0
    assert float(curve[-1]) == float(fedrl_ledger(cfg).total_bytes())


# --- sweep integration ---------------------------------------------------------

def test_compression_axis_labels_and_per_point_results():
    from repro.sweep import SweepSpec, compression_axis, run_sweep

    transforms = (identity(), topk(50), qint8())
    spec = SweepSpec(
        name="comm", base=_cfg(), seeds=(0,),
        static=(compression_axis(transforms),),
    )
    res = run_sweep(spec)
    assert set(res.metrics) == {"dense", "topk50", "int8"}
    nas = {lbl: np.asarray(m["nas"]) for lbl, m in res.metrics.items()}
    assert all(np.all(np.isfinite(v)) for v in nas.values())
    assert float(np.max(np.abs(nas["dense"] - nas["topk50"]))) > 0


def test_compression_axis_validates_points():
    from repro.sweep import compression_axis

    with pytest.raises(TypeError, match="PayloadTransform"):
        compression_axis((("bad", "topk"),))
    axis = compression_axis((("sparse", topk(3)),))
    assert axis.points[0][0] == "sparse"


def test_compression_sweep_compiles_once_per_point(assert_max_compiles):
    """The compression axis is static by design (kind/k change the trace):
    the runner compiles exactly once per transform, never inside a point."""
    from repro.sweep import SweepSpec, compression_axis, run_sweep
    from repro.sweep.runner import static_points

    spec = SweepSpec(
        name="comm-retrace", base=_cfg(n_epochs=1), seeds=(0, 1),
        static=(compression_axis((identity(), topk(50))),),
    )
    run_sweep(spec)  # warm the caches outside the counted window
    n_points = len(list(static_points(spec)))
    _, n = assert_max_compiles(n_points, run_sweep, spec)
    assert n == n_points


def test_transform_swap_keeps_training_statics():
    """with_comm is a pure comm swap: masks, taus and backend untouched."""
    strat = make_strategy("decay", tau=5, m=7,
                          taus=uniform_taus(1, 5, 7, seed=0),
                          decay=exponential_decay(0.9))
    swapped = strat.with_comm(qint8())
    assert swapped.comm == qint8() and strat.comm is IDENTITY
    np.testing.assert_array_equal(swapped.mask, strat.mask)
    np.testing.assert_array_equal(swapped.taus, strat.taus)
    assert swapped.tau == strat.tau and swapped.backend == strat.backend
