"""Beyond-paper strategies: hierarchical FL (the paper's future work),
quantized sync with error feedback, elastic averaging."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.extensions import (
    ElasticAveragingStrategy,
    HierarchicalStrategy,
    QuantizedSyncStrategy,
)


def _params(m=6, seed=0):
    k = jax.random.key(seed)
    return {"w": jax.random.normal(k, (m, 4, 3)),
            "b": jax.random.normal(jax.random.split(k)[0], (m, 5))}


def test_hierarchical_local_then_global():
    s = HierarchicalStrategy(tau=4, clusters=((0, 1, 2), (3, 4, 5)),
                             global_every=2)
    p = _params(6)
    # period 0: intra-cluster average only
    loc = s.server_average(p, period_idx=jnp.asarray(0))
    w = np.asarray(loc["w"])
    np.testing.assert_allclose(w[0], w[1], atol=1e-6)
    np.testing.assert_allclose(w[3], w[5], atol=1e-6)
    assert not np.allclose(w[0], w[3])  # clusters still differ
    np.testing.assert_allclose(w[0], np.asarray(p["w"])[:3].mean(0), atol=1e-6)
    # period 1 (global): everyone equal to the full mean
    glob = s.server_average(p, period_idx=jnp.asarray(1))
    wg = np.asarray(glob["w"])
    np.testing.assert_allclose(wg[0], wg[5], atol=1e-6)
    np.testing.assert_allclose(wg[0], np.asarray(p["w"]).mean(0), atol=1e-6)


def test_hierarchical_requires_partition():
    with pytest.raises(ValueError):
        HierarchicalStrategy(tau=2, clusters=((0, 1), (1, 2)))


def test_hierarchical_comm_accounting():
    s = HierarchicalStrategy(tau=4, clusters=((0, 1, 2), (3, 4, 5)),
                             global_every=3)
    ev = s.comm_events_per_period()
    assert ev["c1"] == 2          # amortized global uploads
    assert ev["w1"] == 4          # the rest go over the cheap local link


def test_quantized_sync_with_error_feedback_converges_to_mean():
    s = QuantizedSyncStrategy(tau=2, m=4)
    p = _params(4, seed=1)
    anchor = jax.tree.map(lambda l: l[0] * 0.0, p)
    errors = jax.tree.map(lambda l: jnp.zeros_like(l), p)
    new_p, new_e = s.server_average(p, anchor=anchor, errors=errors)
    mean = np.asarray(p["w"]).mean(0)
    got = np.asarray(new_p["w"])[0]
    # int8 quantization error is bounded by scale/2 per element
    scale = np.abs(np.asarray(p["w"])).max() / 127.0
    assert np.max(np.abs(got - mean)) <= scale * 1.01
    # the residual equals what was lost (error feedback invariant)
    resid = np.asarray(new_e["w"])
    assert np.all(np.abs(resid) <= scale * 0.51)


def test_quantized_comm_accounting_reports_byte_factor():
    s = QuantizedSyncStrategy(tau=2, m=4, bits=8)
    assert s.comm_events_per_period()["c1_bytes_factor"] == 0.25


def test_elastic_averaging_contracts_toward_anchor():
    s = ElasticAveragingStrategy(tau=2, m=4, alpha=0.5)
    p = _params(4, seed=2)
    anchor = jax.tree.map(lambda l: jnp.zeros(l.shape[1:]), p)
    new_p, new_anchor = s.server_average(p, anchor=anchor)
    # agents move halfway to anchor; anchor moves halfway to the agent mean
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               0.5 * np.asarray(p["w"]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(new_anchor["w"]),
                               0.5 * np.asarray(p["w"]).mean(0), atol=1e-6)


def test_elastic_repeated_rounds_reach_consensus():
    s = ElasticAveragingStrategy(tau=2, m=4, alpha=0.5)
    p = _params(4, seed=3)
    anchor = jax.tree.map(lambda l: jnp.zeros(l.shape[1:]), p)
    for _ in range(40):
        p, anchor = s.server_average(p, anchor=anchor)
    spread = float(jnp.max(jnp.abs(p["w"] - p["w"].mean(0, keepdims=True))))
    assert spread < 1e-4
