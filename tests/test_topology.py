"""Graph topology layer: Laplacian (eq. 55), mu2, mixing matrix validity."""
import numpy as np
import pytest

from repro.core import topology as T


def test_chain5_mu2_matches_paper_merge_value():
    """The paper's 'Merge' topology (adjacent vehicles, m=5) has mu2=0.3820."""
    assert np.isclose(T.mu2(T.chain(5)), 0.3820, atol=1e-4)


def test_full_graph_mu2_equals_m():
    topo = T.fully_connected(6)
    assert np.isclose(T.mu2(topo), 6.0, atol=1e-9)
    assert topo.max_degree == 6


def test_laplacian_rows_sum_to_zero():
    for topo in (T.ring(7), T.star(5), T.torus2d(3, 4)):
        la = T.laplacian(topo)
        assert np.allclose(la.sum(1), 0)
        assert np.array_equal(la, la.T)


def test_mixing_matrix_doubly_stochastic():
    topo = T.random_regularish(8, 3, 4, seed=2)
    p = T.mixing_matrix(topo, 0.9 / topo.max_degree)
    assert np.allclose(p.sum(0), 1) and np.allclose(p.sum(1), 1)


def test_mixing_matrix_eps_bounds():
    topo = T.ring(5)
    with pytest.raises(ValueError):
        T.mixing_matrix(topo, 1.0 / topo.max_degree)  # eps must be < 1/Delta
    with pytest.raises(ValueError):
        T.mixing_matrix(topo, 0.0)


def test_random_graph_connected_and_degree_range():
    topo = T.random_regularish(12, 3, 4, seed=5)
    assert topo.is_connected()
    assert topo.degrees.min() >= 3


def test_a4_rejects_directed_graph():
    adj = np.zeros((3, 3), int)
    adj[0, 1] = 1  # asymmetric
    with pytest.raises(ValueError):
        T.Topology("bad", adj)


def test_spectral_gap_factor_in_unit_interval():
    topo = T.ring(9)
    eps = 0.9 / topo.max_degree
    f = T.spectral_gap_factor(topo, eps, 2)
    assert 0.0 < f < 1.0


# --- sparse graph families (lambda_2 axis) -----------------------------------


def test_knn_ring_structure_and_closed_form_mu2():
    topo = T.knn_ring(12, 4)
    assert topo.is_connected()
    assert np.all(topo.degrees == 4)
    assert np.isclose(T.mu2_knn_ring(12, 4), T.mu2(topo), atol=1e-9)


def test_knn_ring_rejects_bad_k():
    for m, k in ((10, 3), (10, 0), (6, 6), (6, 8)):
        with pytest.raises(ValueError):
            T.knn_ring(m, k)
        with pytest.raises(ValueError):
            T.knn_ring_neighbors(m, k)


def test_watts_strogatz_preserves_edge_budget():
    topo = T.watts_strogatz(20, 4, 0.3, seed=1)
    assert topo.is_connected()
    # rewiring moves edges, never adds or removes them
    assert topo.adj.sum() == T.knn_ring(20, 4).adj.sum()
    with pytest.raises(ValueError):
        T.watts_strogatz(20, 4, 1.5)


def test_erdos_renyi_connected_and_p1_is_full():
    topo = T.erdos_renyi(14, 0.5, seed=0)
    assert topo.is_connected()
    assert np.array_equal(
        T.erdos_renyi(9, 1.0).adj, T.fully_connected(9).adj
    )
    with pytest.raises(ValueError):
        T.erdos_renyi(9, 0.0)


def test_random_families_exhaust_retries_with_clear_error():
    """Satellite regression: bounded reseed-retry raises, never hangs or
    silently hands a disconnected graph to the consensus layer (A4)."""
    with pytest.raises(RuntimeError, match="connected"):
        T.erdos_renyi(40, 0.01, seed=0)  # far below ln(m)/m threshold
    try:
        T.erdos_renyi(40, 0.01, seed=0)
    except RuntimeError as e:
        msg = str(e)
        assert "reseed" in msg and "m=40" in msg and "A4" in msg


def test_graph_families_registry_spans_connectivity():
    m = 12
    mu2s = {}
    for label, build in T.GRAPH_FAMILIES.items():
        topo = build(m, 0)
        assert topo.is_connected(), label
        assert topo.m == m, label
        mu2s[label] = T.mu2(topo)
    assert mu2s["chain"] < mu2s["knn4"] < mu2s["full"]
    assert np.isclose(mu2s["full"], m, atol=1e-9)


def test_density_extremes():
    assert np.isclose(T.density(T.fully_connected(8)), 1.0)
    assert T.density(T.chain(8)) == pytest.approx(2 * 7 / (8 * 7))


# --- NeighborList layout contract --------------------------------------------


def test_neighbor_list_reconstructs_mixing_matrix():
    topo = T.random_regularish(10, 3, 4, seed=3)
    eps = 0.9 / topo.max_degree
    p = T.mixing_matrix(topo, eps)
    nl = T.neighbor_list(topo)
    w = T.neighbor_weights_from_matrix(nl, p)
    dense = np.zeros((10, 10), np.float32)
    np.add.at(dense, (np.arange(10)[:, None], nl.idx), w)
    assert np.array_equal(dense, p.astype(np.float32))


def test_neighbor_list_padding_contract():
    nl = T.neighbor_list(T.chain(6), k_max=5)
    assert nl.k_max == 5
    rows = np.arange(6)[:, None]
    # padding gathers the agent's own row...
    assert np.all(nl.idx[~nl.valid] == np.broadcast_to(rows, nl.idx.shape)[~nl.valid])
    # ...with weight exactly 0.0
    p = T.mixing_matrix(T.chain(6), 0.3)
    w = T.neighbor_weights_from_matrix(nl, p)
    assert np.all(w[~nl.valid] == 0.0)
    # valid prefix is strictly ascending and includes self
    for i in range(6):
        v = nl.idx[i, nl.valid[i]]
        assert np.all(np.diff(v) > 0) and i in v
    with pytest.raises(ValueError):
        T.neighbor_list(T.chain(6), k_max=1)  # below max closed neighborhood


def test_neighbor_list_invariants_enforced():
    good = T.neighbor_list(T.chain(5), k_max=4)  # padded layout
    bad_idx = good.idx.copy()
    assert not good.valid[0, -1]
    bad_idx[0, -1] = 2  # padding no longer points at own row
    with pytest.raises(ValueError, match="own row"):
        T.NeighborList("bad", bad_idx, good.valid, good.degrees)
    bad_valid = good.valid.copy()
    # idx[0] = [0, 1, 0, 0]: dropping slot 0 leaves a hole before slot 1
    # while every invalid slot still points at row 0 (own row)
    bad_valid[0] = [False, True, False, False]
    with pytest.raises(ValueError, match="prefix"):
        T.NeighborList("bad", good.idx, bad_valid, good.degrees)
    with pytest.raises(ValueError, match="degree"):
        T.NeighborList("bad", good.idx, good.valid, good.degrees + 1)


def test_knn_ring_neighbors_matches_dense_export():
    dense = T.neighbor_list(T.knn_ring(16, 4))
    analytic = T.knn_ring_neighbors(16, 4)
    assert np.array_equal(dense.idx, analytic.idx)
    assert np.array_equal(dense.valid, analytic.valid)
    assert np.array_equal(dense.degrees, analytic.degrees)


def test_neighbor_weights_traced_matches_matrix_gather():
    import jax.numpy as jnp

    topo = T.knn_ring(9, 4)
    eps = 0.5 / topo.max_degree
    nl = T.neighbor_list(topo)
    from_matrix = T.neighbor_weights_from_matrix(nl, T.mixing_matrix(topo, eps))
    traced = np.asarray(T.neighbor_weights(nl, jnp.float32(eps)))
    assert np.array_equal(traced, from_matrix)
