"""Graph topology layer: Laplacian (eq. 55), mu2, mixing matrix validity."""
import numpy as np
import pytest

from repro.core import topology as T


def test_chain5_mu2_matches_paper_merge_value():
    """The paper's 'Merge' topology (adjacent vehicles, m=5) has mu2=0.3820."""
    assert np.isclose(T.mu2(T.chain(5)), 0.3820, atol=1e-4)


def test_full_graph_mu2_equals_m():
    topo = T.fully_connected(6)
    assert np.isclose(T.mu2(topo), 6.0, atol=1e-9)
    assert topo.max_degree == 6


def test_laplacian_rows_sum_to_zero():
    for topo in (T.ring(7), T.star(5), T.torus2d(3, 4)):
        la = T.laplacian(topo)
        assert np.allclose(la.sum(1), 0)
        assert np.array_equal(la, la.T)


def test_mixing_matrix_doubly_stochastic():
    topo = T.random_regularish(8, 3, 4, seed=2)
    p = T.mixing_matrix(topo, 0.9 / topo.max_degree)
    assert np.allclose(p.sum(0), 1) and np.allclose(p.sum(1), 1)


def test_mixing_matrix_eps_bounds():
    topo = T.ring(5)
    with pytest.raises(ValueError):
        T.mixing_matrix(topo, 1.0 / topo.max_degree)  # eps must be < 1/Delta
    with pytest.raises(ValueError):
        T.mixing_matrix(topo, 0.0)


def test_random_graph_connected_and_degree_range():
    topo = T.random_regularish(12, 3, 4, seed=5)
    assert topo.is_connected()
    assert topo.degrees.min() >= 3


def test_a4_rejects_directed_graph():
    adj = np.zeros((3, 3), int)
    adj[0, 1] = 1  # asymmetric
    with pytest.raises(ValueError):
        T.Topology("bad", adj)


def test_spectral_gap_factor_in_unit_interval():
    topo = T.ring(9)
    eps = 0.9 / topo.max_degree
    f = T.spectral_gap_factor(topo, eps, 2)
    assert 0.0 < f < 1.0
