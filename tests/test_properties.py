"""Property-based tests (hypothesis) on system invariants."""
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.bounds import SgdConstants, periodic_bound_t1, variation_bound_t2
from repro.core.decay import (
    cosine_decay,
    exponential_decay,
    linear_decay,
    step_decay,
)
from repro.core import topology as T
from repro.core.variation import tau_schedule, uniform_taus, validate_a2
from repro.utils.pytree import tree_axpy, tree_dot, tree_l2_norm, tree_scale

SETTINGS = settings(max_examples=40, deadline=None)


@SETTINGS
@given(lam=st.floats(0.05, 1.0), tau=st.integers(1, 40))
def test_exponential_decay_satisfies_a3(lam, tau):
    d = exponential_decay(lam)
    vals = np.asarray(d(jnp.arange(tau)))
    assert np.isclose(vals[0], 1.0)
    assert np.all(np.diff(vals) <= 1e-7)
    assert np.all((vals >= -1e-7) & (vals <= 1.0 + 1e-7))


@SETTINGS
@given(tau=st.integers(1, 30), floor=st.floats(0.0, 0.9),
       kind=st.sampled_from(["linear", "cosine", "step"]))
def test_other_decays_satisfy_a3(tau, floor, kind):
    if kind == "linear":
        d = linear_decay(tau, floor)
    elif kind == "cosine":
        d = cosine_decay(tau, floor)
    else:
        d = step_decay(max(tau // 2, 1), floor)
    vals = np.asarray(d(jnp.arange(tau)))
    assert np.isclose(vals[0], 1.0, atol=1e-6)
    assert np.all(np.diff(vals) <= 1e-6)
    assert np.all(vals >= -1e-6)


@SETTINGS
@given(tau=st.integers(1, 30), m=st.integers(1, 20), seed=st.integers(0, 99))
def test_uniform_taus_satisfy_a2(tau, m, seed):
    taus = uniform_taus(1, tau, m, seed)
    validate_a2(taus, tau)


@SETTINGS
@given(tau=st.integers(1, 20),
       times=st.lists(st.floats(0.1, 10.0), min_size=2, max_size=10))
def test_tau_schedule_eq6_properties(tau, times):
    t = np.sort(np.asarray(times))
    taus = tau_schedule(tau, t)
    assert taus[0] == max(tau, 1)          # fastest agent paces the period
    assert np.all(np.diff(taus) <= 0)      # slower agents do fewer updates
    assert np.all(taus >= 1)


@SETTINGS
@given(tau=st.integers(1, 25),
       nu_frac=st.floats(0.0, 1.0), w2=st.floats(0.0, 5.0),
       eta=st.floats(1e-4, 0.05), sigma2=st.floats(0.01, 5.0))
def test_t2_never_exceeds_t1_at_same_tau(tau, nu_frac, w2, eta, sigma2):
    """nu <= tau and omega^2 >= 0 imply the variation-aware bound <= T1's
    bound with nu=tau (heterogeneity can only help, per the paper)."""
    c = SgdConstants(L=1.0, sigma2=sigma2, beta=0.1, eta=eta, K=10_000, m=5,
                     f0_minus_finf=1.0)
    nu = 1.0 + nu_frac * (tau - 1.0)
    w2 = min(w2, (tau - nu) * (nu - 1.0)) if tau > 1 else 0.0
    t2 = variation_bound_t2(c, tau, nu, max(w2, 0.0))
    t1 = periodic_bound_t1(c, tau)
    assert t2 <= t1 + 1e-12


@SETTINGS
@given(m=st.integers(3, 12), seed=st.integers(0, 50))
def test_mixing_matrix_spectral_radius(m, seed):
    topo = T.random_regularish(m, 2, min(3, m - 1), seed=seed)
    eps = 0.9 / topo.max_degree
    p = T.mixing_matrix(topo, eps)
    eig = np.linalg.eigvalsh(p)
    assert np.all(eig <= 1.0 + 1e-9)
    assert np.all(eig >= -1.0 + 1e-9)
    assert np.isclose(np.max(eig), 1.0)


@SETTINGS
@given(a=st.floats(-3, 3), n=st.integers(1, 6))
def test_pytree_algebra(a, n):
    key = jax.random.key(n)
    x = {"w": jax.random.normal(key, (n, 2)), "b": jnp.ones(n)}
    y = tree_scale(2.0, x)
    np.testing.assert_allclose(tree_dot(x, y), 2 * tree_dot(x, x), rtol=1e-5)
    z = tree_axpy(a, x, y)
    np.testing.assert_allclose(
        np.asarray(z["w"]), a * np.asarray(x["w"]) + 2 * np.asarray(x["w"]),
        rtol=1e-5, atol=1e-6)
    assert float(tree_l2_norm(x)) >= 0


@SETTINGS
@given(b=st.integers(1, 3), t=st.integers(1, 24), h=st.integers(1, 3),
       d=st.sampled_from([4, 8, 16]))
def test_wkv6_kernel_property_sweep(b, t, h, d):
    """Random-shape sweep: Pallas wkv6 == oracle for every drawn shape."""
    import repro.kernels.ops as ops
    import repro.kernels.ref as ref
    ks = jax.random.split(jax.random.key(b * 131 + t * 7 + h * 3 + d), 6)
    r, k, v = (0.5 * jax.random.normal(ks[i], (b, t, h, d)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, d))) * 0.5 + 0.4
    u = 0.3 * jax.random.normal(ks[4], (h, d))
    s0 = 0.1 * jax.random.normal(ks[5], (b, h, d, d))
    chunk = max(1, t // 2)
    y1, s1 = ops.wkv6(r, k, v, w, u, s0, chunk=chunk)
    y2, s2 = ref.wkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(s1, s2, atol=1e-4, rtol=1e-4)
