PYTHON ?= python
export PYTHONPATH := src
export JAX_PLATFORMS ?= cpu

.PHONY: lint lint-update test test-slow bench-smoke

# Trace-safety analyzer (jaxpr audit + RPR lint, baseline-gated) plus stock
# ruff when it is installed (CI installs it; the dev container may not).
lint:
	$(PYTHON) -m repro.analysis --check
	@if $(PYTHON) -m ruff --version >/dev/null 2>&1; then \
		$(PYTHON) -m ruff check src benchmarks examples tests; \
	else \
		echo "ruff not installed; skipping stock lint (CI runs it)"; \
	fi

# Re-baseline the custom analyzer after triaging findings.
lint-update:
	$(PYTHON) -m repro.analysis --update-baseline

# Tier-1 (pytest.ini already deselects the slow marker by default).
test:
	$(PYTHON) -m pytest -x -q

test-slow:
	$(PYTHON) -m pytest -x -q -m slow

bench-smoke:
	$(PYTHON) -m benchmarks.run --quick
