"""Async federation bench: utility-vs-bytes, sync VPA vs async FedBuff.

Runs the fig4 geometry (m=7, tau=15) under the ``repro.core.async_fed``
staleness layer: one vmapped ``delay`` axis sweeps the arrival-delay
distributions (zero-delay / deterministic lag / geometric / heavy-tail) in a
single compile, against the synchronous VPA baseline. Every async point's
wire bytes come from the arrival-aware ledger — only arrived replicas
uplink — so the figure reads "how much convergence does each byte buy once
the server stops waiting for stragglers".

Tracked by the CI bench-regression gate (both JAX legs):

* ``total_bytes`` / ``arrivals`` per point — exact host-side ledger
  arithmetic (rtol 0), independent of device numerics;
* ``async/zero_delay_bitwise_dev`` — the sync-equivalence contract, pinned
  at exactly 0.0: the zero-delay ``AsyncStrategy`` must execute the
  synchronous driver bit-for-bit on the eager jnp path (same contract as
  fig4's traced-mask gate, see DESIGN.md §15);
* ``expected_grad_norm_mean`` per point — loose utility ceilings, catching
  a convergence collapse without gating timing or cross-version noise.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import (
    emit,
    seed_tuple,
    sweep_config_rows,
    write_bench_json,
    write_csv,
)
from benchmarks.fmarl_bench import make_cfg
from repro.core import make_strategy
from repro.core.async_fed import (
    DELAY_DISTRIBUTIONS,
    AsyncStrategy,
    make_schedule,
)
from repro.rl.fedrl import fedrl_bytes_curve, fedrl_ledger, policy_payload_elems
from repro.sweep import SweepAxis, SweepSpec, mean_ci, run_sweep

M = 7
TAU = 15
# (label, distribution, param): the delay families of the vmapped axis.
# det0 is the zero-delay anchor (arrivals == sync), det1 a one-period lag,
# geom0.5 a mean-one-period geometric, heavy1.5 an infinite-variance tail.
DELAY_POINTS = (
    ("det0", "deterministic", 0.0),
    ("det1", "deterministic", 1.0),
    ("geom0.5", "geometric", 0.5),
    ("heavy1.5", "heavytail", 1.5),
)


def _zero_delay_bitwise() -> float:
    """Bit-identity of the zero-delay async path vs the synchronous driver.

    A deliberately tiny run (2 epochs, tau=3 so boundaries actually fire)
    executed op-by-op: at zero delay every weight is exactly 1.0 and the
    masked mean's ``m / sum(w)`` correction exactly 1.0, so the async flat
    carry executes the same ops on the same values as the synchronous
    driver — the deviation must be exactly 0.0, the record the CI gate pins
    at max 0.0.
    """
    from repro.rl import run_fedrl

    tau, epochs = 3, 2
    cfg_sync = make_cfg(make_strategy("periodic", tau=tau, m=M), epochs=epochs)
    n_periods = (epochs * (cfg_sync.epoch_len // cfg_sync.minibatch)) // tau
    sched = make_schedule("deterministic", 0.0, M, n_periods,
                          seed=cfg_sync.eval_seed)
    cfg_async = dataclasses.replace(
        cfg_sync, strategy=make_strategy("async", tau=tau, schedule=sched)
    )
    _, m_s, _ = run_fedrl(cfg_sync, jax.random.key(0))
    _, m_a, _ = run_fedrl(cfg_async, jax.random.key(0))
    return max(float(np.max(np.abs(m_a[k] - m_s[k]))) for k in m_s)


def run(quick: bool = False, seeds=None) -> list[dict]:
    seeds = seed_tuple(seeds)
    epochs = 8 if quick else None
    n = policy_payload_elems()

    sync_cfg = make_cfg(make_strategy("periodic", tau=TAU, m=M), epochs=epochs)
    n_updates = sync_cfg.n_epochs * (sync_cfg.epoch_len // sync_cfg.minibatch)
    n_periods = n_updates // TAU

    # The async base carries the zero-delay schedule; the delay axis redraws
    # arrivals per point inside the trace from the same eval_seed stream, so
    # the concrete per-point schedules rebuilt below for the ledger see the
    # axis's exact arrival counts.
    base_sched = make_schedule("deterministic", 0.0, M, n_periods,
                               seed=sync_cfg.eval_seed)
    async_cfg = dataclasses.replace(
        sync_cfg, strategy=make_strategy("async", tau=TAU, schedule=base_sched)
    )

    res_sync = run_sweep(SweepSpec(name="fig_async_sync", base=sync_cfg,
                                   seeds=seeds))
    res_async = run_sweep(SweepSpec(
        name="fig_async", base=async_cfg, seeds=seeds,
        vmapped=(SweepAxis(
            name="delay",
            values=tuple(
                (float(DELAY_DISTRIBUTIONS[dist]), float(param))
                for _, dist, param in DELAY_POINTS
            ),
        ),),
    ))

    out = {
        "schema_version": 1,
        "quick": bool(quick),
        "seeds": list(seeds),
        "n_seeds": len(seeds),
        "m": M,
        "tau": TAU,
        "n_periods": n_periods,
        "payload_elems": n,
        "points": {},
        "curves": {},
    }
    rows = []

    def add_point(label, cfg, metrics, idx=None):
        entry, rws = sweep_config_rows(label, metrics, len(seeds), idx=idx)
        bytes_curve = fedrl_bytes_curve(cfg)
        entry["bytes"] = bytes_curve.tolist()
        for ep, row in enumerate(rws):
            row["bytes"] = float(bytes_curve[ep])
        out["curves"][label] = entry
        rows.extend(rws)

        sel = metrics["server_grad_sq_norm"]
        if idx is not None:
            sel = sel[idx]
        egn_m, egn_h = mean_ci(sel.mean(-1), 0)
        ledger = fedrl_ledger(cfg)
        total = ledger.total_bytes()
        point = {
            "expected_grad_norm_mean": float(egn_m),
            "expected_grad_norm_ci_hw": float(egn_h),
            "total_bytes": float(total),
            "arrivals": int(ledger.c1_events),
            # lower = fewer wire bytes per unit of achieved 1/grad-norm
            "bytes_per_utility": float(total * egn_m),
        }
        out["points"][label] = point
        emit(f"fig_async/{label}", 0.0,
             f"grad_norm={egn_m:.4f}+-{egn_h:.4f} bytes={total:.0f} "
             f"arrivals={ledger.c1_events}")
        return point

    sync_point = add_point("sync", sync_cfg, res_sync.metrics["base"])
    for d, (label, dist, param) in enumerate(DELAY_POINTS):
        sched = make_schedule(dist, param, M, n_periods,
                              seed=sync_cfg.eval_seed)
        cfg_pt = dataclasses.replace(
            async_cfg,
            strategy=AsyncStrategy(tau=TAU, schedule=sched),
        )
        point = add_point(label, cfg_pt, res_async.metrics["base"], idx=d)
        point["bytes_vs_sync"] = point["total_bytes"] / sync_point["total_bytes"]

    dev = _zero_delay_bitwise()
    out["async"] = {"zero_delay_bitwise_dev": dev}
    emit("fig_async/zero_delay_bitwise", 0.0, f"dev={dev:.2g}")

    write_bench_json("fig_async", out)
    res_async.save("experiments/sweeps")
    write_csv("fig_async", rows)
    return rows


if __name__ == "__main__":
    run()
