"""Paper Table II: expected gradient norm per method, with C1/C2/W1/W2 costs."""
from __future__ import annotations

import time

from benchmarks.common import emit, write_csv
from benchmarks.fmarl_bench import run_config, strategies_table2


def run(quick: bool = False) -> list[dict]:
    rows = []
    configs = strategies_table2()
    if quick:
        configs = configs[:4]
    for name, strat in configs:
        t0 = time.perf_counter()
        row, _ = run_config(name, strat)
        dt = (time.perf_counter() - t0) * 1e6
        rows.append(row)
        emit(f"table2/{name}", dt,
             f"grad_norm={row['expected_grad_norm']:.4f};"
             f"C1={row['communication_overheads_C1']};"
             f"C2={row['computation_overheads_C2']};"
             f"W1={row['inter_communication_W1']}")
    write_csv("table2", rows)
    return rows


if __name__ == "__main__":
    run()
