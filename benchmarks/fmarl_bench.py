"""Shared FMARL experiment machinery for the paper-table benchmarks.

Scaled-down from the paper's T=1500, U=500, P=250 (SUMO-scale) to CPU-budget
sizes; the *structure* (m=7 agents, tau schedules, topologies with the paper's
mu2 regimes) is preserved. REPRO_BENCH_FULL=1 enlarges toward paper scale.
"""
from __future__ import annotations

import os

import jax
import numpy as np

from repro.core import make_strategy, uniform_taus
from repro.core.decay import exponential_decay
from repro.core import topology as T
from repro.rl import FIGURE_EIGHT, FedRLConfig, run_fedrl
from repro.rl.fedrl import expected_gradient_norm

FULL = bool(int(os.environ.get("REPRO_BENCH_FULL", "0")))

# scaled-down run geometry (paper: T=1500, U=500, P=250)
T_LEN = 300 if FULL else 150
U_EPOCHS = 80 if FULL else 24
P_BATCH = 25 if FULL else 25
ETA = 5e-3


def topo_sparse(m=7):
    """~3-4 connections/agent (paper Fig. 6 'mu2=1.4384' regime)."""
    return T.random_regularish(m, 3, 4, seed=0)


def topo_dense(m=7):
    """~4-6 connections/agent (paper Fig. 6 'mu2=2.5188' regime)."""
    return T.random_regularish(m, 5, 6, seed=0)


def make_cfg(strategy, *, env=FIGURE_EIGHT, algo="ppo", epochs=None):
    """The shared scaled-down run geometry as a FedRLConfig (sweep base)."""
    return FedRLConfig(
        env=env, strategy=strategy, eta=ETA, algo=algo,
        n_epochs=epochs or U_EPOCHS, epoch_len=T_LEN, minibatch=P_BATCH,
    )


def run_config(name: str, strategy, *, env=FIGURE_EIGHT, algo="ppo", seed=0,
               epochs=None):
    cfg = make_cfg(strategy, env=env, algo=algo, epochs=epochs)
    server, metrics, ledger = run_fedrl(cfg, jax.random.key(seed))
    row = {
        "config": name,
        "expected_grad_norm": expected_gradient_norm(metrics),
        "final_nas": float(np.mean(metrics["nas"][-3:])),
        "first_nas": float(np.mean(metrics["nas"][:3])),
        **ledger.table_row(),
    }
    return row, metrics


def strategies_table2(m=7, tau=10):
    """The Table II configuration set (scaled tau levels preserved)."""
    sp, dn = topo_sparse(m), topo_dense(m)
    eps_s = 0.9 / sp.max_degree
    eps_d = 0.9 / dn.max_degree
    rows = [
        ("tau=1", make_strategy("sync", m=m)),
        ("tau=10", make_strategy("periodic", tau=10, m=m)),
        ("tau=15", make_strategy("periodic", tau=15, m=m)),
        ("tau=10~15", make_strategy("periodic", tau=15,
                                    taus=uniform_taus(10, 15, m, seed=0))),
        ("tau=5~15", make_strategy("periodic", tau=15,
                                   taus=uniform_taus(5, 15, m, seed=0))),
        ("tau=1~15", make_strategy("periodic", tau=15,
                                   taus=uniform_taus(1, 15, m, seed=0))),
        ("tau=1~15 decay l=0.98",
         make_strategy("decay", tau=15, taus=uniform_taus(1, 15, m, seed=0),
                       decay=exponential_decay(0.98))),
        ("tau=1~15 decay l=0.95",
         make_strategy("decay", tau=15, taus=uniform_taus(1, 15, m, seed=0),
                       decay=exponential_decay(0.95))),
        ("tau=1~15 decay l=0.92",
         make_strategy("decay", tau=15, taus=uniform_taus(1, 15, m, seed=0),
                       decay=exponential_decay(0.92))),
        ("tau=10 consensus e=1 mu2=%.3f" % T.mu2(sp),
         make_strategy("consensus", tau=10, topo=sp, eps=eps_s, rounds=1, m=m)),
        ("tau=10 consensus e=1 mu2=%.3f" % T.mu2(dn),
         make_strategy("consensus", tau=10, topo=dn, eps=eps_d, rounds=1, m=m)),
        ("tau=10 consensus e=2 mu2=%.3f" % T.mu2(sp),
         make_strategy("consensus", tau=10, topo=sp, eps=eps_s, rounds=2, m=m)),
        ("tau=1~10 consensus e=1 mu2=%.3f" % T.mu2(sp),
         make_strategy("consensus", tau=10, topo=sp, eps=eps_s, rounds=1,
                       taus=uniform_taus(1, 10, m, seed=0), m=m)),
    ]
    return rows
