"""Beyond-paper figure: algebraic connectivity (lambda_2) vs utility vs bytes.

Sweeps the ``algebraic_connectivity`` static axis — the registered sparse
graph families of ``repro.core.topology.GRAPH_FAMILIES`` at fixed m, each
labelled with its exact mu2 and run with ``eps = eps_frac/Delta`` so the
paper's step-size bound stays valid as the degree changes — through the
consensus-based method, seeds vmapped inside each point. The figure (rendered
from the versioned ``experiments/sweeps/fig_lambda2.v<N>.json`` artifact by
``benchmarks.plot_sweeps``) reads: how much convergence does a unit of
algebraic connectivity buy, and at what wire cost? This is the tradeoff the
companion paper (arXiv 2201.12718) studies, instrumented byte-exactly.

Not part of the CI bench gate (the scale bench owns the sparse-path gating);
run it via ``python -m benchmarks.run --only lambda2``.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    emit,
    seed_tuple,
    sweep_config_rows,
    write_bench_json,
    write_csv,
)
from benchmarks.fmarl_bench import make_cfg
from repro.core import make_strategy, mu2
from repro.core import topology as T
from repro.rl.fedrl import fedrl_bytes_curve
from repro.sweep import SweepSpec, mean_ci, run_sweep
from repro.sweep.overrides import algebraic_connectivity_axis

# m=7 matches the env's n_rl on the legacy shared-env path (same geometry as
# fig6); the axis itself takes any m — large-m sparse-path behaviour is the
# scale bench's job. chain -> full still spans mu2 ~0.2 -> 7.0 at m=7.
M_AGENTS = 7
TAU = 10
EPS_FRAC = 0.5
FAMILIES = ("chain", "ring", "knn4", "ws4", "er25", "full")
FAMILIES_QUICK = ("chain", "knn4", "full")


def run(quick: bool = False, seeds=None) -> list[dict]:
    m, tau = M_AGENTS, TAU
    seeds = seed_tuple(seeds)
    epochs = 8 if quick else None
    families = FAMILIES_QUICK if quick else FAMILIES

    axis = algebraic_connectivity_axis(
        m, families=families, seed=0, eps_frac=EPS_FRAC
    )
    base = make_cfg(
        make_strategy(
            "consensus", tau=tau, topo=T.ring(m),
            eps=EPS_FRAC / T.ring(m).max_degree, rounds=1, m=m,
        ),
        epochs=epochs,
    )
    spec = SweepSpec(
        name="fig_lambda2", base=base, seeds=seeds, static=(axis,)
    )
    res = run_sweep(spec)

    out = {
        "schema_version": 1,
        "quick": bool(quick),
        "seeds": list(seeds),
        "n_seeds": len(seeds),
        "m": m,
        "eps_frac": EPS_FRAC,
        "families": list(families),
        "curves": {},
        "summary": {},
    }
    rows = []
    for family, (label, transform) in zip(families, axis.points):
        cfg = transform(base)  # the per-point config: topology + eps swapped
        lam2 = mu2(cfg.strategy.topo)
        entry, fam_rows = sweep_config_rows(
            label, res.metrics[label], len(seeds)
        )
        bytes_curve = fedrl_bytes_curve(cfg)
        entry["bytes"] = bytes_curve.tolist()
        for ep, row in enumerate(fam_rows):
            row["bytes"] = float(bytes_curve[ep])
            row["mu2"] = lam2
            row["family"] = family
        out["curves"][label] = entry
        rows += fam_rows
        egn_m, egn_h = mean_ci(
            res.metrics[label]["server_grad_sq_norm"].mean(-1), 0
        )
        total = float(bytes_curve[-1])
        out["summary"][label] = {
            "family": family,
            "mu2": lam2,
            "expected_grad_norm_mean": float(egn_m),
            "expected_grad_norm_ci_hw": float(egn_h),
            "final_nas_mean": float(np.asarray(entry["nas_mean"])[-3:].mean()),
            "total_bytes": total,
            # lower = fewer wire bytes per unit of achieved 1/grad-norm
            # (same convention as compression_bench)
            "bytes_per_utility": float(total * float(egn_m)),
        }
        emit(
            f"lambda2/{label}",
            res.wall_s[label] / len(seeds) * 1e6,
            f"grad_norm={float(egn_m):.4f}+-{float(egn_h):.4f} bytes={total:.0f}",
        )

    write_bench_json("lambda2_sweep", out)
    res.save("experiments/sweeps")
    write_csv("fig_lambda2", rows)
    return rows


if __name__ == "__main__":
    run()
