"""Paper Fig. 4: convergence (NAS) of variation-aware periodic averaging."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, write_csv
from benchmarks.fmarl_bench import run_config
from repro.core import make_strategy, uniform_taus


def run(quick: bool = False) -> list[dict]:
    m = 7
    configs = [
        ("tau=1", make_strategy("sync", m=m)),
        ("tau=10", make_strategy("periodic", tau=10, m=m)),
        ("tau=15", make_strategy("periodic", tau=15, m=m)),
        ("tau=10~15", make_strategy("periodic", tau=15,
                                    taus=uniform_taus(10, 15, m, seed=0))),
    ]
    if quick:
        configs = configs[:2]
    rows = []
    for name, strat in configs:
        t0 = time.perf_counter()
        row, metrics = run_config(name, strat)
        nas = np.asarray(metrics["nas"])
        for ep, v in enumerate(nas):
            rows.append({"config": name, "epoch": ep, "nas": float(v)})
        emit(f"fig4/{name}", (time.perf_counter() - t0) * 1e6,
             f"final_nas={row['final_nas']:.4f}")
    write_csv("fig4_variation", rows)
    return rows


if __name__ == "__main__":
    run()
