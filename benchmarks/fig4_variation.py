"""Paper Fig. 4: convergence (NAS) of variation-aware periodic averaging.

Rebuilt on the traced variation axis: at fixed period length tau=15 the
per-agent tau_i schedules are a *vmapped* ``taus`` axis — every schedule's
``(m, tau)`` indicator mask is retabulated inside the trace
(``repro.sweep.overrides.override_taus``), so the whole (schedules x seeds)
variation grid runs as ONE jitted computation with the mask batched as an
``(S, m, tau)`` operand. Only genuinely shape-changing points (tau=1 sync,
tau=10 — different period length = different mask shape and inner scan
length) remain static-axis re-traces.

The emitted ``experiments/bench/fig4_sweep.json`` records, for the CI
regression gate (``benchmarks/check_regression.py``):

* ``timings`` — the vmapped variation sweep vs the equivalent Python
  seed-loop over the same grid (wall-clock + speedup + numeric deviation);
* ``variation`` — traced-mask vs static-numpy-mask parity:
  ``max_abs_dev_vs_static`` (jitted; ulp-scale XLA literal-folding drift is
  allowed, same contract as vmapped-vs-loop) and ``eager_bitwise_dev``
  (the op-by-op jnp reference path, gated at exactly 0.0).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (
    emit,
    seed_tuple,
    strategy_axis,
    sweep_config_rows,
    write_bench_json,
    write_csv,
)
from benchmarks.fmarl_bench import make_cfg
from repro.core import make_strategy, uniform_taus
from repro.core.variation import validate_a2
from repro.sweep import SweepAxis, SweepSpec, mean_ci, run_sweep, run_sweep_loop
from repro.sweep.overrides import override_taus

TAU = 15


def _summarize(out, label, metrics, idx=None):
    """Seed-reduced curves + run-level summary for one plotted config."""
    entry, rows = sweep_config_rows(label, metrics, out["n_seeds"], idx=idx)
    out["curves"][label] = entry
    sel = (lambda a: a) if idx is None else (lambda a: a[idx])
    egn_m, egn_h = mean_ci(sel(metrics["server_grad_sq_norm"]).mean(-1), 0)
    out["summary"][label] = {
        "expected_grad_norm_mean": float(egn_m),
        "expected_grad_norm_ci_hw": float(egn_h),
        "final_nas_mean": float(np.asarray(entry["nas_mean"])[-3:].mean()),
    }
    return rows


def _static_parity(sched_spec, res_loop, schedules, seeds):
    """Traced-mask loop vs per-schedule static-numpy-mask runs (seed 0)."""
    from repro.rl.fedrl import run_fedrl_core

    max_dev = 0.0
    for i, (_, sched) in enumerate(schedules):
        strat = make_strategy("periodic", tau=TAU, taus=np.asarray(sched, int))
        cfg = make_cfg(strat, epochs=sched_spec.base.n_epochs)
        ref = jax.device_get(
            # Each tau_i schedule is a distinct static point: a fresh trace
            # per iteration is the point of this parity check.
            jax.jit(lambda k, c=cfg: run_fedrl_core(c, k)[1])(  # noqa: RPR005
                jax.random.key(seeds[0])
            )
        )
        for k, arr in ref.items():
            dev = float(
                np.max(np.abs(res_loop.metrics["base"][k][i, 0] - np.asarray(arr)))
            )
            max_dev = max(max_dev, dev)
    return max_dev


def _eager_bitwise(m):
    """Bit-identity of the traced-mask copy on the eager jnp reference path.

    A deliberately tiny run (2 epochs) executed op-by-op: the traced-mask
    strategy copy and the static-numpy-mask strategy execute the *same* ops
    on the same values, so the deviation must be exactly 0.0 — this is the
    bit-identity record the CI gate pins at max 0.0.
    """
    from repro.rl import run_fedrl

    sched = uniform_taus(10, TAU, m, seed=0)
    cfg_static = make_cfg(
        make_strategy("periodic", tau=TAU, taus=sched), epochs=2
    )
    cfg_traced = override_taus(
        make_cfg(make_strategy("periodic", tau=TAU, m=m), epochs=2),
        np.asarray(sched, np.float32),
    )
    _, m_s, _ = run_fedrl(cfg_static, jax.random.key(0))
    _, m_t, _ = run_fedrl(cfg_traced, jax.random.key(0))
    return max(float(np.max(np.abs(m_t[k] - m_s[k]))) for k in m_s)


def run(quick: bool = False, seeds=None) -> list[dict]:
    m = 7
    seeds = seed_tuple(seeds)
    epochs = 8 if quick else None

    # shape-changing period lengths: static axis (one re-trace each)
    statics = [
        ("tau=1", make_strategy("sync", m=m)),
        ("tau=10", make_strategy("periodic", tau=10, m=m)),
    ]
    # the variation axis proper: tau_i schedules at fixed tau=15, vmapped
    schedules = [
        ("tau=15", tuple(float(TAU) for _ in range(m))),
        ("tau=10~15", tuple(map(float, uniform_taus(10, TAU, m, seed=0)))),
        ("tau=5~15", tuple(map(float, uniform_taus(5, TAU, m, seed=0)))),
        ("tau=1~15", tuple(map(float, uniform_taus(1, TAU, m, seed=0)))),
    ]
    if quick:
        statics = statics[:1]
        schedules = schedules[:2]
    for _, sched in schedules:
        validate_a2(np.asarray(sched, int), TAU)

    static_spec = SweepSpec(
        name="fig4_static_taus",
        base=make_cfg(statics[0][1], epochs=epochs),
        seeds=seeds,
        static=(strategy_axis("tau", statics),),
    )
    sched_spec = SweepSpec(
        name="fig4_variation",
        base=make_cfg(make_strategy("periodic", tau=TAU, m=m), epochs=epochs),
        seeds=seeds,
        vmapped=(SweepAxis("taus", tuple(s for _, s in schedules)),),
    )

    res_static = run_sweep(static_spec)         # seeds-only vmap per tau point
    res_sched = run_sweep(sched_spec)           # (schedules x seeds) in ONE jit
    res_loop = run_sweep_loop(sched_spec)       # same grid, Python seed-loop

    out = {
        "schema_version": 2,
        "quick": bool(quick),
        "seeds": list(seeds),
        "n_seeds": len(seeds),
        "tau": TAU,
        "schedules": {lab: list(map(int, s)) for lab, s in schedules},
        "curves": {},
        "summary": {},
    }
    rows = []
    for label, _ in statics:
        rows += _summarize(out, label, res_static.metrics[label])
        emit(f"fig4/{label}", res_static.wall_s[label] / len(seeds) * 1e6,
             f"final_nas={out['summary'][label]['final_nas_mean']:.4f}")
    per_run_us = res_sched.wall_s["base"] / sched_spec.n_runs * 1e6
    for i, (label, _) in enumerate(schedules):
        rows += _summarize(out, label, res_sched.metrics["base"], idx=i)
        emit(f"fig4/{label}", per_run_us,
             f"final_nas={out['summary'][label]['final_nas_mean']:.4f}")

    max_dev_loop = max(
        float(np.max(np.abs(res_sched.metrics["base"][k]
                            - res_loop.metrics["base"][k])))
        for k in res_sched.metrics["base"]
    )
    out["timings"] = {
        "n_runs": sched_spec.n_runs,
        "vmapped_exec_s": res_sched.wall_s["base"],
        "vmapped_compile_s": res_sched.compile_s["base"],
        "loop_exec_s": res_loop.wall_s["base"],
        "loop_compile_s": res_loop.compile_s["base"],
        # > 1 means the single vmapped variation sweep beats the seed-loop
        "vmapped_speedup": res_loop.wall_s["base"] / res_sched.wall_s["base"],
        "max_abs_dev_vs_loop": max_dev_loop,
    }
    emit("fig4/sweep_vs_loop", res_sched.wall_s["base"] * 1e6,
         f"loop={res_loop.wall_s['base'] * 1e6:.0f}us "
         f"x{out['timings']['vmapped_speedup']:.2f}")

    out["variation"] = {
        "max_abs_dev_vs_static": _static_parity(
            sched_spec, res_loop, schedules, seeds
        ),
        "eager_bitwise_dev": _eager_bitwise(m),
    }
    emit("fig4/traced_vs_static", 0.0,
         f"jit_dev={out['variation']['max_abs_dev_vs_static']:.2g} "
         f"eager_dev={out['variation']['eager_bitwise_dev']:.2g}")

    write_bench_json("fig4_sweep", out)
    res_sched.save("experiments/sweeps")
    write_csv("fig4_variation", rows)
    return rows


if __name__ == "__main__":
    run()
