"""Paper Fig. 4: convergence (NAS) of variation-aware periodic averaging.

Runs on ``repro.sweep``: the four tau configurations are a *static* axis
(tau changes the variation-mask shape and the inner scan length, so each
re-traces), while the seed axis vmaps — every config's S seeds run as one
jitted batched computation, and the curves carry t-based CIs.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    emit,
    seed_tuple,
    strategy_axis,
    sweep_config_rows,
    write_bench_json,
    write_csv,
)
from benchmarks.fmarl_bench import make_cfg
from repro.core import make_strategy, uniform_taus
from repro.sweep import SweepSpec, run_sweep


def run(quick: bool = False, seeds=None) -> list[dict]:
    m = 7
    seeds = seed_tuple(seeds)
    epochs = 8 if quick else None
    strategies = [
        ("tau=1", make_strategy("sync", m=m)),
        ("tau=10", make_strategy("periodic", tau=10, m=m)),
        ("tau=15", make_strategy("periodic", tau=15, m=m)),
        ("tau=10~15", make_strategy("periodic", tau=15,
                                    taus=uniform_taus(10, 15, m, seed=0))),
    ]
    if quick:
        strategies = strategies[:2]

    spec = SweepSpec(
        name="fig4_variation",
        base=make_cfg(strategies[0][1], epochs=epochs),
        seeds=seeds,
        static=(strategy_axis("tau", strategies),),
    )
    res = run_sweep(spec)

    rows, curves = [], {}
    for name, _ in strategies:
        entry, rws = sweep_config_rows(name, res.metrics[name], len(seeds),
                                       include_grad=False)
        curves[name] = entry
        rows += rws
        nas_m = np.asarray(entry["nas_mean"])
        nas_h = np.asarray(entry["nas_ci_hw"])
        emit(f"fig4/{name}", res.wall_s[name] / len(seeds) * 1e6,
             f"final_nas={nas_m[-3:].mean():.4f}+-{nas_h[-3:].mean():.4f}")

    write_bench_json("fig4_sweep", {
        "schema_version": 1, "quick": bool(quick),
        "seeds": list(seeds), "n_seeds": len(seeds),
        "curves": curves, "wall_s": dict(res.wall_s),
    })
    write_csv("fig4_variation", rows)
    return rows


if __name__ == "__main__":
    run()
