"""Benchmark harness entrypoint (deliverable d): one module per paper
table/figure + the roofline/kernel system benches.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Prints ``name,us_per_call,derived`` CSV lines; full per-row CSVs land in
experiments/bench/.
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced configs (CI-speed)")
    ap.add_argument("--only", default=None,
                    help="run a single bench: table2|fig4|fig5|fig6|fig789|"
                         "bounds|roofline|kernels|dispatch|rollout_fleet|comm|"
                         "consensus_scale|lambda2|async|serving")
    ap.add_argument("--seeds", type=int, default=None,
                    help="seed count for the sweep-based figure benches "
                         "(fig4/fig5/fig6; default 4)")
    args = ap.parse_args()

    from benchmarks import (  # imported lazily so --only is cheap
        bounds_bench,
        compression_bench,
        consensus_scale_bench,
        fig4_variation,
        fig5_decay,
        fig6_consensus,
        fig789_optimizers,
        fig_async,
        fig_lambda2,
        kernel_bench,
        rollout_fleet_bench,
        roofline_bench,
        serving_bench,
        strategy_dispatch_bench,
        table2,
    )

    benches = {
        "bounds": bounds_bench.run,          # paper §V analysis
        "kernels": kernel_bench.run,         # kernel layer
        "dispatch": strategy_dispatch_bench.run,  # jnp vs kernel strategy step
        "rollout_fleet": rollout_fleet_bench.run,  # batched fleet vs single env
        "roofline": roofline_bench.run,      # §Roofline from dry-run artifacts
        "comm": compression_bench.run,       # payload transforms: bytes/utility
        "consensus_scale": consensus_scale_bench.run,  # sparse O(m*k) gossip
        "lambda2": fig_lambda2.run,          # beyond-paper mu2 tradeoff figure
        "async": fig_async.run,              # async FedBuff vs sync VPA
        "serving": serving_bench.run,        # AOT policy serving under load
        "table2": table2.run,                # paper Table II
        "fig4": fig4_variation.run,          # paper Fig. 4
        "fig5": fig5_decay.run,              # paper Fig. 5
        "fig6": fig6_consensus.run,          # paper Fig. 6
        "fig789": fig789_optimizers.run,     # paper Figs. 7-9
    }
    names = [args.only] if args.only else list(benches)
    t0 = time.time()
    for name in names:
        if name not in benches:
            sys.exit(f"unknown bench {name!r}; have {list(benches)}")
        print(f"# --- {name} ---", flush=True)
        kw = {"quick": args.quick}
        if args.seeds is not None:
            if "seeds" in inspect.signature(benches[name]).parameters:
                kw["seeds"] = args.seeds
            elif args.only:
                sys.exit(f"bench {name!r} does not take --seeds")
            # full-suite run: non-sweep benches just ignore the flag
        # XLA compile count per bench: a jump here means a bench started
        # retracing inside its timed region (see repro.analysis.retrace).
        from repro.analysis.retrace import count_compiles

        with count_compiles() as compiles:
            benches[name](**kw)
        print(f"# {name}: {compiles.count} XLA compiles", flush=True)
    print(f"# all benches done in {time.time() - t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
