"""Fleet rollout engine bench: env steps/sec vs (m, B) against the old path.

Times the batched heterogeneous-fleet engine (``repro.rl.rollout``, vmapped
over m agents x B parallel envs) against the legacy single-shared-env rollout
(``repro.rl.fedrl._rollout``: one env, m RL vehicles, un-batched) on the
figure-eight scenario. Throughput is counted in *env steps per second* —
each of the fleet's m*B environments advancing one tick is one env step, the
single path advances exactly one env per tick — so the ratio is the real
experience-collection speedup the batched engine buys on this host.

Measurement: this box is heavily cpu-share-throttled, so the two sides of
each comparison are timed *interleaved* (alternating rounds, best-of) —
sequential blocks land in different throttling windows and skew the ratio
either way by 30%+.

Emits the run.py ``name,us_per_call,derived`` CSV lines and writes
``experiments/bench/rollout_fleet.json``.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import OUT_DIR, emit
from repro.core.strategies import make_strategy
from repro.rl import FIGURE_EIGHT, FedRLConfig, fleet_reset, fleet_rollout
from repro.rl.env import OBS_DIM, env_reset, perturb_params
from repro.rl.fedrl import _rollout
from repro.rl.policy import init_policy

M_SWEEP = (5, 7)
B_SWEEP = (1, 4, 8)
N_STEPS = 256  # long enough that per-call dispatch overhead is noise
HETERO = 0.2
REPEATS = 4   # interleaved best-of rounds


def _policy_m(m):
    pol = init_policy(jax.random.key(2), OBS_DIM)
    return jax.tree.map(lambda l: jnp.broadcast_to(l, (m,) + l.shape), pol)


def _single_fn():
    """Legacy path: one shared env, m = n_rl agents, no batching."""
    env = FIGURE_EIGHT
    cfg = FedRLConfig(env=env, strategy=make_strategy("sync", m=env.n_rl,
                                                      backend="jnp"))
    params_m = _policy_m(env.n_rl)
    state = env_reset(env, jax.random.key(1))

    @jax.jit
    def roll(state, key):
        state, traj = _rollout(cfg, params_m, state, key, N_STEPS)
        return state, traj["rew"]

    return roll, state


def _fleet_fn(m, b):
    env = FIGURE_EIGHT
    params_m = perturb_params(env, jax.random.key(0), m, HETERO)
    pol_m = _policy_m(m)
    state = fleet_reset(env, params_m, jax.random.key(1), b)

    @jax.jit
    def roll(state, key):
        state, traj = fleet_rollout(env, params_m, pol_m, state, key, N_STEPS)
        return state, traj["rew"]

    return roll, state


def _interleaved_best_us(sides, iters):
    """Best per-call us for each (fn, arg) side, alternating rounds."""
    # Replaying one fixed key is deliberate: every timed call must run the
    # identical computation, not a fresh random stream.
    key = jax.random.key(3)
    for fn, arg in sides:
        jax.block_until_ready(fn(arg, key))  # compile  # noqa: RPR001
    best = [float("inf")] * len(sides)
    for _ in range(REPEATS):
        for i, (fn, arg) in enumerate(sides):
            t0 = time.perf_counter()
            for _ in range(iters):
                out = fn(arg, key)
            jax.block_until_ready(out)
            best[i] = min(best[i], (time.perf_counter() - t0) / iters * 1e6)
    return best


def run(quick: bool = False) -> None:
    iters = 2 if quick else 4
    single = _single_fn()
    rows = []
    for m in M_SWEEP[:1] if quick else M_SWEEP:
        for b in B_SWEEP[:2] if quick else B_SWEEP:
            us_single, us_fleet = _interleaved_best_us(
                [single, _fleet_fn(m, b)], iters
            )
            single_sps = N_STEPS / (us_single * 1e-6)
            sps = N_STEPS * m * b / (us_fleet * 1e-6)
            row = {
                "m": m,
                "B": b,
                "hetero": HETERO,
                "steps_per_sec_fleet": sps,
                "steps_per_sec_single": single_sps,
                # > 1 means the batched engine collects experience faster
                "speedup_vs_single": sps / single_sps,
            }
            rows.append(row)
            emit(f"rollout_fleet/m{m}/B{b}", us_fleet,
                 f"{sps:.0f} steps/s x{row['speedup_vs_single']:.1f} "
                 f"(single {single_sps:.0f})")
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "rollout_fleet.json")
    with open(path, "w") as f:
        json.dump(
            {
                "device_backend": jax.default_backend(),
                "scenario": "figure_eight",
                "n_steps": N_STEPS,
                "rows": rows,
            },
            f,
            indent=2,
        )
    print(f"# wrote {path}")


if __name__ == "__main__":
    run(quick=True)
