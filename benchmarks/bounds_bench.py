"""Theory benchmark: T1/T2/T4/T5 closed forms vs tau / lambda / E sweeps.

This is the executable version of the paper's analysis sections — the numbers
EXPERIMENTS.md §Repro cross-references against the measured Table II analogs.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, write_csv
from repro.core.bounds import (
    SgdConstants,
    consensus_bound_t5,
    decay_bound_t4,
    max_feasible_eta,
    periodic_bound_t1,
    utility,
    resource_cost_periodic,
    variation_bound_t2,
)
from repro.core import topology as T

C = SgdConstants(L=1.0, sigma2=2.0, beta=0.5, eta=1e-4, K=300_000, m=7,
                 f0_minus_finf=10.0)


def run(quick: bool = False) -> list[dict]:
    t0 = time.perf_counter()
    rows = []
    topo = T.random_regularish(7, 3, 4, seed=0)
    eps = 0.9 / topo.max_degree
    taus = [1, 2, 5, 10, 15] if not quick else [1, 10]
    for tau in taus:
        psi1_t1 = periodic_bound_t1(C, tau)
        nu, w2 = (1 + tau) / 2, (tau**2 - 1) / 12
        psi1_t2 = variation_bound_t2(C, tau, nu, w2) if tau > 1 else psi1_t1
        psi3 = decay_bound_t4(C, tau, 0.95) if tau > 1 else psi1_t1
        psi5 = consensus_bound_t5(C, tau, topo, eps, 1)
        psi0 = resource_cost_periodic(m=7, taus=np.full(7, tau), tau=tau,
                                      T=1500, U=500, P=250, c1=1.0, c2=0.1)
        psi2 = 2 * psi1_t1  # initial-model bound proxy
        rows.append({
            "tau": tau,
            "psi1_T1": psi1_t1, "psi1_T2_uniform": psi1_t2,
            "psi3_T4_lam095": psi3, "psi1_T5_E1": psi5,
            "max_eta": max_feasible_eta(C, tau),
            "utility_T1": utility(psi1=psi1_t1, psi2=psi2, psi0=psi0),
            "utility_T5": utility(psi1=psi5, psi2=psi2, psi0=psi0),
        })
    write_csv("bounds_theory", rows)
    emit("bounds/sweep", (time.perf_counter() - t0) * 1e6,
         f"taus={len(rows)};T5<T1={all(r['psi1_T5_E1'] <= r['psi1_T1'] for r in rows)}")
    return rows


if __name__ == "__main__":
    run()
