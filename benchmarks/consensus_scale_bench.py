"""Scale benchmark: sparse O(m*k) gossip step vs dense O(m^2) mixing.

Times one jitted ``dispatch.consensus_gather`` round on analytic k-NN rings
(``knn_ring_neighbors`` — O(m*k) memory, no dense adjacency ever built) at
fleet sizes up to m=10k, fits the scaling exponent of time vs m on the sparse
path, and contrasts the dense ``consensus_mix`` twin up to its m=1k cap. The
exponent is the headline gate: the sparse step must stay ~O(m*k), i.e. the
fitted log-log slope over the measured sizes is <= 1.2 (a quadratic path
would fit ~2). A parity section pins the numerics at m=64 alongside the
timings — sparse vs full-list (k_max=m) sequential reference bitwise on the
eager jnp path, and the Pallas kernel in interpret mode vs eager jnp.

Gated keys (stable across --quick/full, see bench_baseline.json):
``scaling/sparse_exponent``, ``scaling/n_points``, ``parity/jnp_bitwise_dev``,
``parity/interpret_dev``.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_us, write_bench_json, write_csv
from repro.core import topology as T
from repro.core.strategies import mixing_powers
from repro.kernels import dispatch

import jax
import jax.numpy as jnp

K_NEIGHBORS = 8
N_PARAMS = 4096
EPS_FRAC = 0.5
SIZES_QUICK = (256, 1024)
SIZES_FULL = (1024, 2048, 4096, 10000)  # shares m=1024 with quick (gated key)
# The exponent must be fitted within one memory-hierarchy regime: at n=4096
# the m=1024 working set (~16 MB) is L3-resident (~3.4 us/row measured) while
# m>=2048 streams from DRAM at a flat ~11 us/row — fitting across that cliff
# inflates the slope to ~1.5 for constant-factor reasons, not algorithmic
# ones. Quick fits the cache-resident pair; full fits the streaming sizes.
FIT_SIZES_QUICK = SIZES_QUICK
FIT_SIZES_FULL = (2048, 4096, 10000)
DENSE_CAP = 1024  # dense (m, m) mixing contrast stops here
PARITY_M = 64


@jax.jit
def _sparse_step(g, idx, w):
    return dispatch.consensus_gather(g, idx, w, backend="jnp")


@jax.jit
def _dense_step(g, p):
    return dispatch.consensus_mix(g, p, backend="jnp")


def _sparse_inputs(m: int, key):
    """Analytic k-NN ring neighbor list + weights + a random (m, n) buffer."""
    nl = T.knn_ring_neighbors(m, K_NEIGHBORS)
    eps = EPS_FRAC / K_NEIGHBORS
    w = np.asarray(T.neighbor_weights(nl, eps))
    g = jax.random.normal(key, (m, N_PARAMS), jnp.float32)
    return nl, w, g, eps


def _parity() -> dict:
    """m=64 numerics pin: sparse vs full-list reference, interpret vs jnp."""
    topo = T.knn_ring(PARITY_M, K_NEIGHBORS)
    eps = EPS_FRAC / topo.max_degree
    p64, _, _ = mixing_powers(topo, eps, 1, need_power=False)
    nl = T.neighbor_list(topo)
    w = T.neighbor_weights_from_matrix(nl, p64)
    full = T.neighbor_list(topo, k_max=PARITY_M)
    w_full = T.neighbor_weights_from_matrix(full, p64)
    g = jax.random.normal(jax.random.PRNGKey(7), (PARITY_M, 257), jnp.float32)

    with jax.disable_jit():  # eager: the bitwise sequential-FMA contract
        sparse = dispatch.consensus_gather(g, nl.idx, w, backend="jnp")
        ref = dispatch.consensus_gather(g, full.idx, w_full, backend="jnp")
    jnp_dev = float(jnp.max(jnp.abs(sparse - ref)))
    interp = dispatch.consensus_gather(
        g, nl.idx, w, backend="interpret", block_n=128
    )
    interp_dev = float(jnp.max(jnp.abs(interp - sparse)))
    emit(
        "consensus_scale/parity", 0.0,
        f"jnp_bitwise_dev={jnp_dev:.1e} interpret_dev={interp_dev:.1e}"
    )
    return {
        "m": PARITY_M,
        "k": K_NEIGHBORS,
        "jnp_bitwise_dev": jnp_dev,
        "interpret_dev": interp_dev,
    }


def run(quick: bool = False, seeds=None) -> list[dict]:
    sizes = SIZES_QUICK if quick else SIZES_FULL
    fit_sizes = FIT_SIZES_QUICK if quick else FIT_SIZES_FULL
    iters = 5 if quick else 3
    rows = []
    sparse_t = {}
    dense_t = {}
    n_devices = jax.device_count()
    # The fleet-mesh probe only measures on multi-device hosts; record the
    # skip explicitly so the CI gate can surface it as a warning instead of
    # silently passing an unmeasured probe (check_regression "probe" entry).
    sharded = {
        "status": "skipped",
        "n_devices": n_devices,
        "reason": f"single-device host (n_devices={n_devices})",
    }

    for m in sizes:
        key = jax.random.PRNGKey(m)
        nl, w, g, eps = _sparse_inputs(m, key)
        us = time_us(_sparse_step, g, nl.idx, w, iters=iters)
        sparse_t[m] = us
        mu2 = T.mu2_knn_ring(m, K_NEIGHBORS)
        emit(
            f"consensus_scale/sparse_m{m}", us,
            f"k={K_NEIGHBORS} n={N_PARAMS} mu2={mu2:.4f}"
        )
        rows.append({
            "path": "sparse", "m": m, "k": K_NEIGHBORS, "n": N_PARAMS,
            "us_per_step": us, "mu2": mu2,
        })
        if m <= DENSE_CAP:
            topo = T.knn_ring(m, K_NEIGHBORS)
            _, p, _ = mixing_powers(topo, eps, 1, need_power=False)
            us_d = time_us(_dense_step, g, jnp.asarray(p), iters=iters)
            dense_t[m] = us_d
            emit(
                f"consensus_scale/dense_m{m}", us_d,
                f"speedup={us_d / us:.1f}x"
            )
            rows.append({
                "path": "dense", "m": m, "k": m, "n": N_PARAMS,
                "us_per_step": us_d, "mu2": mu2,
            })
        if n_devices > 1 and m == sizes[-1]:
            # shard_map agent-axis probe (ROADMAP): same step with g laid out
            # over the fleet mesh; inert on single-device hosts.
            from repro.sharding import fleet_mesh

            mesh = fleet_mesh()
            gs = jax.device_put(
                g, jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec("agents")
                )
            )
            us_s = time_us(_sparse_step, gs, nl.idx, w, iters=iters)
            emit(f"consensus_scale/sharded_m{m}", us_s,
                 f"n_devices={n_devices}")
            rows.append({
                "path": "sharded", "m": m, "k": K_NEIGHBORS, "n": N_PARAMS,
                "us_per_step": us_s, "mu2": mu2,
            })
            sharded = {
                "status": "measured",
                "n_devices": n_devices,
                "m": m,
                "us_per_step": us_s,
            }

    ms = np.array(sorted(fit_sizes), float)
    ts = np.array([sparse_t[int(v)] for v in ms], float)
    exponent = float(np.polyfit(np.log(ms), np.log(ts), 1)[0])
    emit(
        "consensus_scale/exponent", 0.0,
        f"sparse t ~ m^{exponent:.3f} over m={[int(v) for v in ms]}"
    )

    out = {
        "schema_version": 1,
        "quick": bool(quick),
        "k": K_NEIGHBORS,
        "n_params": N_PARAMS,
        "sizes": list(sizes),
        "n_devices": n_devices,
        "timings": {
            str(m): {
                "sparse_us": sparse_t[m],
                "dense_us": dense_t.get(m),
                "dense_speedup": (
                    dense_t[m] / sparse_t[m] if m in dense_t else None
                ),
            }
            for m in sizes
        },
        "scaling": {
            "sparse_exponent": exponent,
            "fit_sizes": list(fit_sizes),
            "n_points": len(fit_sizes),
            "dense_capped_at": DENSE_CAP,
        },
        "parity": _parity(),
        "sharded": sharded,
    }
    write_bench_json("consensus_scale", out)
    write_csv("consensus_scale", rows)
    return rows


if __name__ == "__main__":
    run()
