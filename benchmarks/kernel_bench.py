"""Kernel micro-bench: Pallas (interpret) vs jnp oracle wall time + bytes.

Interpret-mode timings do NOT reflect TPU performance (the kernel body runs
as traced Python); what this bench establishes is (a) correctness at bench
shapes and (b) the analytic bytes/FLOPs each kernel moves, which feed the
roofline discussion of the kernel layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro.kernels.ops as ops
import repro.kernels.ref as ref
from benchmarks.common import emit, time_us, write_csv
from repro.core import topology as T
from repro.core.topology import mixing_matrix


def _time(fn, *args):
    return time_us(fn, *args, iters=3)


def run(quick: bool = False) -> list[dict]:
    rows = []
    # wkv6: rwkv6-1.6b-like head (B=1, T=256, D=64)
    b, t, h, d = 1, (64 if quick else 256), 2, 64
    ks = jax.random.split(jax.random.key(0), 6)
    r, k, v = (0.3 * jax.random.normal(ks[i], (b, t, h, d)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, d))) * 0.5 + 0.45
    u = 0.3 * jax.random.normal(ks[4], (h, d))
    s0 = jnp.zeros((b, h, d, d))
    us_ref = _time(lambda: ref.wkv6_ref(r, k, v, w, u, s0))
    y1, _ = ops.wkv6(r, k, v, w, u, s0, chunk=64)
    y2, _ = ref.wkv6_ref(r, k, v, w, u, s0)
    err = float(jnp.abs(y1 - y2).max())
    bytes_moved = (5 * b * t * h * d + 2 * b * h * d * d) * 4
    rows.append({"kernel": "wkv6", "shape": f"{b}x{t}x{h}x{d}",
                 "ref_us": us_ref, "max_err": err, "bytes": bytes_moved})
    emit("kernels/wkv6", us_ref, f"err={err:.2e};bytes={bytes_moved}")

    # swa attention
    s = 128 if quick else 256
    q, kk, vv = (0.5 * jax.random.normal(ks[i], (1, s, 2, 64)) for i in range(3))
    us_ref = _time(lambda: ref.swa_attention_ref(q, kk, vv, window=64))
    o1 = ops.swa_attention(q, kk, vv, window=64, block_q=64, block_kv=64)
    o2 = ref.swa_attention_ref(q, kk, vv, window=64)
    err = float(jnp.abs(o1 - o2).max())
    rows.append({"kernel": "swa_attention", "shape": f"1x{s}x2x64",
                 "ref_us": us_ref, "max_err": err,
                 "bytes": 4 * s * 2 * 64 * 4})
    emit("kernels/swa_attention", us_ref, f"err={err:.2e}")

    # consensus step
    topo = T.ring(8)
    p = jnp.asarray(mixing_matrix(topo, 0.3), jnp.float32)
    g = jax.random.normal(ks[5], (8, 1 << (12 if quick else 16)))
    us_ref = _time(lambda: ref.consensus_step_ref(g, p))
    err = float(jnp.abs(ops.consensus_step(g, p) - ref.consensus_step_ref(g, p)).max())
    rows.append({"kernel": "consensus_step", "shape": str(g.shape),
                 "ref_us": us_ref, "max_err": err, "bytes": g.size * 4 * 2})
    emit("kernels/consensus_step", us_ref, f"err={err:.2e}")

    # decay accum
    n = 1 << (12 if quick else 18)
    acc = jax.random.normal(ks[0], (n,))
    gg = jax.random.normal(ks[1], (n,))
    us_ref = _time(lambda: ref.decay_accum_ref(acc, gg, 0.97))
    err = float(jnp.abs(ops.decay_accum(acc, gg, 0.97)
                        - ref.decay_accum_ref(acc, gg, 0.97)).max())
    rows.append({"kernel": "decay_accum", "shape": str(n),
                 "ref_us": us_ref, "max_err": err, "bytes": n * 4 * 3})
    emit("kernels/decay_accum", us_ref, f"err={err:.2e}")

    write_csv("kernels", rows)
    return rows


if __name__ == "__main__":
    run()
