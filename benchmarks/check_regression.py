"""CI bench-regression gate: compare tracked metrics in the freshly emitted
``experiments/bench/*.json`` against the committed baselines.

  PYTHONPATH=src python -m benchmarks.check_regression \
      [--baseline benchmarks/baselines/bench_baseline.json] \
      [--bench-dir experiments/bench] [--select FILE ...] [--update]

The baseline file lists tracked metrics, each addressed by a bench JSON file
plus a '/'-separated path into it (integer segments index lists, negative
indices allowed). Check kinds:

* ``value`` + ``rtol`` (+ optional ``atol``) — numeric equivalence band for
  statistics that should be stable across runs (seed-averaged grad norms).
* ``min`` — lower bound, for ratios that must not collapse (the vmapped
  sweep's speedup over the Python seed-loop; the flat-carry speedup). Kept
  loose: CI machines are noisy, the gate is for regressions, not records.
* ``max`` — upper bound (e.g. vmapped-vs-loop numeric deviation).
* ``probe`` — a hardware-dependent probe's status string: ``measured``
  passes, ``skipped`` is a WARNING (printed, and appended to the GitHub job
  summary when ``GITHUB_STEP_SUMMARY`` is set) rather than a silent pass or
  a failure — anything else fails.

``--select`` restricts the run to entries of the named bench file(s) — how
the second CI matrix leg gates only the benches it ran. Exit status 1 if any
tracked metric is missing or out of band — this is what fails the
``bench-smoke`` CI job. ``--update`` rewrites the baseline's ``value``
fields from the current bench output (bounds are left alone; incompatible
with ``--select`` — a partial refresh would mix stale and fresh values).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_BASELINE = os.path.join(
    os.path.dirname(__file__), "baselines", "bench_baseline.json"
)


def resolve(doc, path: str):
    """Walk a '/'-separated path; int segments index lists."""
    node = doc
    for seg in path.split("/"):
        if isinstance(node, list):
            node = node[int(seg)]
        elif isinstance(node, dict):
            node = node[seg]
        else:
            raise KeyError(seg)
    return node


def check_metric(entry: dict, cur: float):
    """Returns (ok, detail) for one tracked metric."""
    if "value" in entry:
        ref = float(entry["value"])
        rtol = float(entry.get("rtol", 0.1))
        atol = float(entry.get("atol", 0.0))
        band = rtol * abs(ref) + atol
        ok = abs(cur - ref) <= band
        return ok, f"ref={ref:.6g} band=+-{band:.3g}"
    if "min" in entry:
        return cur >= float(entry["min"]), f">= {entry['min']}"
    if "max" in entry:
        return cur <= float(entry["max"]), f"<= {entry['max']}"
    return False, "baseline entry has no value/min/max"


def check_probe(status: str):
    """Probe entries: (ok, warn, detail) from the recorded status string."""
    if status == "measured":
        return True, False, "probe measured"
    if status == "skipped":
        return True, True, "probe skipped on this runner"
    return False, False, f"unexpected probe status {status!r}"


def append_job_summary(lines) -> None:
    """Surface warnings in the GitHub Actions job summary, when available."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path or not lines:
        return
    with open(path, "a") as f:
        f.write("### bench probe warnings\n\n")
        for line in lines:
            f.write(f"- {line}\n")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--bench-dir", default="experiments/bench")
    ap.add_argument("--select", action="append", default=None,
                    metavar="FILE",
                    help="only check entries of this bench JSON file "
                         "(repeatable); default: every tracked entry")
    ap.add_argument("--update", action="store_true",
                    help="rewrite baseline 'value' fields from current output")
    args = ap.parse_args()

    if args.update and args.select:
        print("# --update is incompatible with --select: a partial refresh "
              "would mix stale and fresh baseline values")
        return 1

    with open(args.baseline) as f:
        baseline = json.load(f)

    entries = baseline["metrics"]
    if args.select:
        known = {e["file"] for e in entries}
        unknown = [f for f in args.select if f not in known]
        if unknown:
            print(f"# --select names no tracked entries: {unknown} "
                  f"(have {sorted(known)})")
            return 1
        entries = [e for e in entries if e["file"] in set(args.select)]

    docs = {}
    failures = 0
    missing = 0
    checked = 0
    warnings = []
    print(f"{'status':8s} {'metric':60s} {'current':>12s}  constraint")
    for entry in entries:
        name = f"{entry['file']}:{entry['path']}"
        is_probe = bool(entry.get("probe"))
        try:
            if entry["file"] not in docs:
                with open(os.path.join(args.bench_dir, entry["file"])) as f:
                    docs[entry["file"]] = json.load(f)
            raw = resolve(docs[entry["file"]], entry["path"])
            cur = str(raw) if is_probe else float(raw)
        except (OSError, KeyError, IndexError, ValueError, TypeError) as e:
            print(f"{'MISSING':8s} {name:60s} {'-':>12s}  ({e!r})")
            failures += 1
            missing += 1
            continue
        checked += 1
        if is_probe:
            ok, warn, detail = check_probe(cur)
            status = "SKIP" if warn else ("ok" if ok else "FAIL")
            print(f"{status:8s} {name:60s} {cur:>12s}  {detail}")
            if warn:
                warnings.append(f"{name}: {detail}")
            failures += 0 if ok else 1
            continue
        if args.update and "value" in entry:
            entry["value"] = cur
        ok, detail = check_metric(entry, cur)
        status = "ok" if ok else "FAIL"
        print(f"{status:8s} {name:60s} {cur:12.6g}  {detail}")
        failures += 0 if ok else 1

    for line in warnings:
        print(f"# WARNING {line}")
    append_job_summary(warnings)

    if args.update:
        if missing:
            # refuse a partial refresh: stale values would masquerade as
            # freshly measured (run every bench the baseline tracks first)
            print(f"# NOT rewriting {args.baseline}: {missing} tracked "
                  f"metric(s) missing from {args.bench_dir}")
            return 1
        with open(args.baseline, "w") as f:
            json.dump(baseline, f, indent=2)
            f.write("\n")
        print(f"# baseline values rewritten: {args.baseline}")
        return 0
    if failures:
        print(f"# {failures} tracked metric(s) out of band vs {args.baseline}")
        return 1
    print(f"# all {checked} tracked metrics within tolerance"
          + (f" ({len(warnings)} probe warning(s))" if warnings else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
