"""Strategy dispatch bench: pure-jnp vs kernel-backed federated step time.

Times one fused ``flat_update`` (within-period transform + local SGD step) for
the decay- and consensus-based strategies across agent counts m in {5, 20, 100}
and flat parameter sizes n. Emits the run.py ``name,us_per_call,derived`` CSV
lines and writes a JSON comparison to ``experiments/bench/strategy_dispatch.json``
so the speedup lands in the bench trajectory.

On a TPU host the kernel side is compiled Pallas (backend ``pallas``); on CPU
it falls back to interpret mode, where the numbers track harness overhead and
correctness rather than hardware speedup — the JSON records which mode ran.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import OUT_DIR, emit, time_us
from repro.core import topology as T
from repro.core.decay import exponential_decay
from repro.core.strategies import ConsensusStrategy, DecayStrategy

M_SWEEP = (5, 20, 100)
N_FULL = (4096, 65536)
N_QUICK = (1024,)


def run(quick: bool = False) -> None:
    ns = N_QUICK if quick else N_FULL
    iters = 5 if quick else 20
    kernel_backend = "pallas" if jax.default_backend() == "tpu" else "interpret"
    tau = 4
    rows = []
    for m in M_SWEEP:
        topo = T.ring(m)
        strategies = {
            "decay": lambda b, m=m: DecayStrategy(
                tau=tau, m=m, decay=exponential_decay(0.9), backend=b
            ),
            "consensus": lambda b, topo=topo: ConsensusStrategy(
                tau=tau, topo=topo, eps=0.3, rounds=2, backend=b
            ),
        }
        for n in ns:
            params = jax.random.normal(jax.random.key(0), (m, n))
            grads = jax.random.normal(jax.random.key(1), (m, n))
            offset = jnp.asarray(1)
            for sname, make in strategies.items():
                us = {}
                for backend in ("jnp", kernel_backend):
                    strat = make(backend)
                    step = jax.jit(
                        lambda p, g, off, s=strat: s.flat_update(p, g, off, 1e-2)
                    )
                    us[backend] = time_us(step, params, grads, offset, iters=iters)
                row = {
                    "strategy": sname,
                    "m": m,
                    "n": n,
                    "kernel_backend": kernel_backend,
                    "us_jnp": us["jnp"],
                    "us_kernel": us[kernel_backend],
                    # > 1 means the kernel path is faster than the jnp path
                    "kernel_speedup_vs_jnp": us["jnp"] / us[kernel_backend],
                }
                rows.append(row)
                emit(
                    f"dispatch/{sname}/m{m}/n{n}",
                    row["us_kernel"],
                    f"jnp={row['us_jnp']:.1f}us x{row['kernel_speedup_vs_jnp']:.2f}",
                )
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "strategy_dispatch.json")
    with open(path, "w") as f:
        json.dump(
            {
                "device_backend": jax.default_backend(),
                "kernel_backend": kernel_backend,
                "rows": rows,
            },
            f,
            indent=2,
        )
    print(f"# wrote {path}")


if __name__ == "__main__":
    run(quick=True)
