"""Strategy dispatch bench: pure-jnp vs kernel-backed federated step time.

Times one fused ``flat_update`` (within-period transform + local SGD step) for
the decay- and consensus-based strategies across agent counts m in {5, 20, 100}
and flat parameter sizes n. Emits the run.py ``name,us_per_call,derived`` CSV
lines and writes a JSON comparison to ``experiments/bench/strategy_dispatch.json``
so the speedup lands in the bench trajectory.

The ``flat_carry`` section times the PR-2 driver architecture directly: a
tau-step inner scan + server average where the carry is the flat (m, n)
matrix (ravel once, per-agent tree views only inside the grad closure)
against the PR-1 ravel-per-step form (tree carry, ``local_update`` re-ravels
params+grads every step). Both run the same dispatch backend, so the delta
isolates the carry layout.

On a TPU host the kernel side is compiled Pallas (backend ``pallas``); on CPU
it falls back to interpret mode, where the numbers track harness overhead and
correctness rather than hardware speedup — the JSON records which mode ran.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import OUT_DIR, emit, time_us
from repro.core import topology as T
from repro.core.decay import exponential_decay
from repro.core.strategies import ConsensusStrategy, DecayStrategy
from repro.kernels import dispatch

M_SWEEP = (5, 20, 100)
N_FULL = (4096, 65536)
N_QUICK = (1024,)


def _bench_flat_carry(ns, iters, kernel_backend, tau):
    """Flat-carry scan vs PR-1 ravel-per-step scan, same kernel backend."""
    eta = 1e-2
    rows = []

    def grad_fn(p):
        # cheap stand-in for the user grad closure: forces the per-agent
        # tree view to actually materialise
        return jax.tree.map(lambda x: 0.1 * x + 1.0, p)

    for m in M_SWEEP:
        strat = DecayStrategy(
            tau=tau, m=m, decay=exponential_decay(0.9), backend=kernel_backend
        )
        for n in ns:
            half = n // 2
            tree = {
                "w": jax.random.normal(jax.random.key(0), (m, half)),
                "b": jax.random.normal(jax.random.key(1), (m, n - half)),
            }
            flat, spec = dispatch.stacked_ravel_spec(tree)

            @jax.jit
            def flat_carry(f, s=strat):
                def body(f, off):
                    g = jax.vmap(
                        lambda row: spec.ravel_one(grad_fn(spec.unravel_one(row)))
                    )(f)
                    return s.flat_update(f, g, off, eta), None

                out, _ = jax.lax.scan(body, f, jnp.arange(tau))
                row = s.flat_server_average(out)
                return jnp.broadcast_to(row[None, :], out.shape)

            @jax.jit
            def ravel_per_step(t, s=strat):
                def body(t, off):
                    g = jax.vmap(grad_fn)(t)
                    return s.local_update(t, g, off, eta), None

                out, _ = jax.lax.scan(body, t, jnp.arange(tau))
                avg = s.server_average(out)
                return jax.tree.map(
                    lambda l: jnp.broadcast_to(l, (m,) + l.shape), avg
                )

            us_flat = time_us(flat_carry, flat, iters=iters)
            us_ravel = time_us(ravel_per_step, tree, iters=iters)
            row = {
                "m": m,
                "n": n,
                "tau": tau,
                "kernel_backend": kernel_backend,
                "us_flat_carry": us_flat,
                "us_ravel_per_step": us_ravel,
                # > 1 means the flat carry beats the PR-1 ravel-per-step form
                "flat_carry_speedup": us_ravel / us_flat,
            }
            rows.append(row)
            emit(
                f"dispatch/flat_carry/m{m}/n{n}",
                us_flat,
                f"ravel={us_ravel:.1f}us x{row['flat_carry_speedup']:.2f}",
            )
    return rows


def run(quick: bool = False) -> None:
    ns = N_QUICK if quick else N_FULL
    iters = 5 if quick else 20
    kernel_backend = "pallas" if jax.default_backend() == "tpu" else "interpret"
    tau = 4
    rows = []
    for m in M_SWEEP:
        topo = T.ring(m)
        strategies = {
            "decay": lambda b, m=m: DecayStrategy(
                tau=tau, m=m, decay=exponential_decay(0.9), backend=b
            ),
            "consensus": lambda b, topo=topo: ConsensusStrategy(
                tau=tau, topo=topo, eps=0.3, rounds=2, backend=b
            ),
        }
        for n in ns:
            params = jax.random.normal(jax.random.key(0), (m, n))
            grads = jax.random.normal(jax.random.key(1), (m, n))
            offset = jnp.asarray(1)
            for sname, make in strategies.items():
                us = {}
                for backend in ("jnp", kernel_backend):
                    strat = make(backend)
                    # One jit per (strategy, backend) cell is deliberate —
                    # the bench times each compiled variant separately.
                    step = jax.jit(  # noqa: RPR005
                        lambda p, g, off, s=strat: s.flat_update(p, g, off, 1e-2)
                    )
                    us[backend] = time_us(step, params, grads, offset, iters=iters)
                row = {
                    "strategy": sname,
                    "m": m,
                    "n": n,
                    "kernel_backend": kernel_backend,
                    "us_jnp": us["jnp"],
                    "us_kernel": us[kernel_backend],
                    # > 1 means the kernel path is faster than the jnp path
                    "kernel_speedup_vs_jnp": us["jnp"] / us[kernel_backend],
                }
                rows.append(row)
                emit(
                    f"dispatch/{sname}/m{m}/n{n}",
                    row["us_kernel"],
                    f"jnp={row['us_jnp']:.1f}us x{row['kernel_speedup_vs_jnp']:.2f}",
                )
    flat_rows = _bench_flat_carry(ns, iters, kernel_backend, tau)
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "strategy_dispatch.json")
    with open(path, "w") as f:
        json.dump(
            {
                "device_backend": jax.default_backend(),
                "kernel_backend": kernel_backend,
                "rows": rows,
                "flat_carry": flat_rows,
            },
            f,
            indent=2,
        )
    print(f"# wrote {path}")


if __name__ == "__main__":
    run(quick=True)
