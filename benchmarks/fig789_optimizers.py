"""Paper Figs. 7-9: consensus is optimizer-agnostic (PPO / TRPO / TAC) on the
'Merge' scenario with the adjacent-vehicle chain topology (mu2 = 0.3820)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, write_csv
from benchmarks.fmarl_bench import run_config
from repro.core import make_strategy
from repro.core import topology as T
from repro.rl import MERGE


def run(quick: bool = False) -> list[dict]:
    m, tau = MERGE.n_rl, 10
    chain = T.chain(m)  # mu2 = 0.3820 at m=5, as in the paper
    eps = 0.9 / chain.max_degree
    algos = ["ppo"] if quick else ["ppo", "trpo", "tac"]
    rows = []
    for algo in algos:
        for name, strat in [
            (f"{algo}/periodic", make_strategy("periodic", tau=tau, m=m)),
            (f"{algo}/consensus", make_strategy("consensus", tau=tau,
                                                topo=chain, eps=eps,
                                                rounds=1, m=m)),
        ]:
            t0 = time.perf_counter()
            row, metrics = run_config(name, strat, env=MERGE, algo=algo)
            for ep, v in enumerate(np.asarray(metrics["nas"])):
                rows.append({"config": name, "epoch": ep, "nas": float(v)})
            emit(f"fig789/{name}", (time.perf_counter() - t0) * 1e6,
                 f"final_nas={row['final_nas']:.4f};"
                 f"grad_norm={row['expected_grad_norm']:.4f}")
    write_csv("fig789_optimizers", rows)
    return rows


if __name__ == "__main__":
    run()
