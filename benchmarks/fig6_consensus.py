"""Paper Fig. 6: consensus-based method (CIRL), topology/round/eps sweep.

Runs on ``repro.sweep``: topologies and gossip round counts are *static*
axis points (the adjacency fixes the (m, m) sparsity and E the trace), while
the seed axis — and for the sparse E=1 topology also the consensus step size
eps — vmap into single jitted computations. The eps axis exercises the
traced-mixing-matrix override: P = I - eps*La rebuilds inside the trace, so
every eps value shares one compilation.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    emit,
    seed_tuple,
    strategy_axis,
    sweep_config_rows,
    write_bench_json,
    write_csv,
)
from benchmarks.fmarl_bench import make_cfg, topo_dense, topo_sparse
from repro.core import make_strategy
from repro.core import topology as T
from repro.rl.fedrl import fedrl_bytes_curve
from repro.sweep import SweepAxis, SweepSpec, run_sweep


def _config_rows(rows, curves, name, metrics, n_seeds, cfg, lam_idx=None):
    entry, rws = sweep_config_rows(name, metrics, n_seeds, idx=lam_idx)
    # cumulative wire-bytes x-axis (uplink + gossip W1 for consensus configs)
    bytes_curve = fedrl_bytes_curve(cfg)
    entry["bytes"] = bytes_curve.tolist()
    for ep, row in enumerate(rws):
        row["bytes"] = float(bytes_curve[ep])
    curves[name] = entry
    rows += rws
    gn_m = np.asarray(entry["grad_norm_mean"])
    gn_h = np.asarray(entry["grad_norm_ci_hw"])
    return float(gn_m.mean()), float(gn_h.mean())


def run(quick: bool = False, seeds=None) -> list[dict]:
    m, tau = 7, 10
    seeds = seed_tuple(seeds)
    epochs = 8 if quick else None
    sp, dn = topo_sparse(m), topo_dense(m)
    configs = [
        ("periodic", make_strategy("periodic", tau=tau, m=m)),
        (f"consensus e=1 mu2={T.mu2(sp):.3f}",
         make_strategy("consensus", tau=tau, topo=sp, eps=0.9 / sp.max_degree,
                       rounds=1, m=m)),
        (f"consensus e=1 mu2={T.mu2(dn):.3f}",
         make_strategy("consensus", tau=tau, topo=dn, eps=0.9 / dn.max_degree,
                       rounds=1, m=m)),
        (f"consensus e=2 mu2={T.mu2(sp):.3f}",
         make_strategy("consensus", tau=tau, topo=sp, eps=0.9 / sp.max_degree,
                       rounds=2, m=m)),
    ]
    if quick:
        configs = configs[:2]

    spec = SweepSpec(
        name="fig6_consensus",
        base=make_cfg(configs[0][1], epochs=epochs),
        seeds=seeds,
        static=(strategy_axis("topology", configs),),
    )
    res = run_sweep(spec)

    rows, curves = [], {}
    for name, strat in configs:
        gm, gh = _config_rows(rows, curves, name, res.metrics[name],
                              len(seeds), make_cfg(strat, epochs=epochs))
        emit(f"fig6/{name}", res.wall_s[name] / len(seeds) * 1e6,
             f"grad_norm={gm:.4f}+-{gh:.4f}")

    # vmapped eps axis on the sparse E=1 topology: fractions of 1/Delta
    fracs = (0.45, 0.9) if quick else (0.3, 0.6, 0.9)
    eps_vals = tuple(f / sp.max_degree for f in fracs)
    eps_spec = SweepSpec(
        name="fig6_eps",
        base=make_cfg(
            make_strategy("consensus", tau=tau, topo=sp,
                          eps=eps_vals[0], rounds=1, m=m),
            epochs=epochs,
        ),
        seeds=seeds,
        vmapped=(SweepAxis("eps", eps_vals),),
    )
    eps_res = run_sweep(eps_spec)
    per_run_us = eps_res.wall_s["base"] / eps_spec.n_runs * 1e6
    for i, (frac, eps) in enumerate(zip(fracs, eps_vals)):
        name = f"consensus e=1 eps={frac:.2f}/max_deg"
        gm, gh = _config_rows(rows, curves, name, eps_res.metrics["base"],
                              len(seeds), eps_spec.base, lam_idx=i)
        emit(f"fig6/{name}", per_run_us, f"grad_norm={gm:.4f}+-{gh:.4f}")

    write_bench_json("fig6_sweep", {
        "schema_version": 1, "quick": bool(quick),
        "seeds": list(seeds), "n_seeds": len(seeds),
        "eps_values": list(eps_vals), "eps_fracs": list(fracs),
        "curves": curves,
        "wall_s": {**res.wall_s, "eps_axis": eps_res.wall_s["base"]},
    })
    write_csv("fig6_consensus", rows)
    return rows


if __name__ == "__main__":
    run()
