"""Paper Fig. 6: consensus-based method (CIRL), topology/round sweep."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, write_csv
from benchmarks.fmarl_bench import run_config, topo_dense, topo_sparse
from repro.core import make_strategy
from repro.core import topology as T


def run(quick: bool = False) -> list[dict]:
    m, tau = 7, 10
    sp, dn = topo_sparse(m), topo_dense(m)
    configs = [
        ("periodic", make_strategy("periodic", tau=tau, m=m)),
        (f"consensus e=1 mu2={T.mu2(sp):.3f}",
         make_strategy("consensus", tau=tau, topo=sp, eps=0.9 / sp.max_degree,
                       rounds=1, m=m)),
        (f"consensus e=1 mu2={T.mu2(dn):.3f}",
         make_strategy("consensus", tau=tau, topo=dn, eps=0.9 / dn.max_degree,
                       rounds=1, m=m)),
        (f"consensus e=2 mu2={T.mu2(sp):.3f}",
         make_strategy("consensus", tau=tau, topo=sp, eps=0.9 / sp.max_degree,
                       rounds=2, m=m)),
    ]
    if quick:
        configs = configs[:2]
    rows = []
    for name, strat in configs:
        t0 = time.perf_counter()
        row, metrics = run_config(name, strat)
        for ep, v in enumerate(np.asarray(metrics["nas"])):
            rows.append({"config": name, "epoch": ep, "nas": float(v),
                         "grad_norm": float(metrics["server_grad_sq_norm"][ep])})
        emit(f"fig6/{name}", (time.perf_counter() - t0) * 1e6,
             f"grad_norm={row['expected_grad_norm']:.4f}")
    write_csv("fig6_consensus", rows)
    return rows


if __name__ == "__main__":
    run()
