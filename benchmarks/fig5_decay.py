"""Paper Fig. 5: decay-based method (DIRL), lambda sweep at tau=1~15.

Runs on ``repro.sweep``: the decay constant lambda and the seed axis vmap
into ONE jitted computation (lambda x seeds full federated runs batched on a
leading sweep axis), replacing the old one-config-at-a-time single-seed
loop. Curves are seed-averaged with t-based confidence intervals and carry
the ledger's cumulative wire-bytes axis (``fedrl_bytes_curve``) so the
figure plots convergence against bytes communicated, not just epochs.

The emitted ``experiments/bench/fig5_sweep.json`` also records the
wall-clock of the equivalent Python seed-loop over the same grid (one jitted
single-run function, compiled once, called per cell) — the ``timings``
section shows the vmapped sweep beating it on CPU and is a tracked metric of
the CI bench-regression gate (``benchmarks/check_regression.py``).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    emit,
    seed_tuple,
    sweep_config_rows,
    write_bench_json,
    write_csv,
)
from benchmarks.fmarl_bench import make_cfg
from repro.core import make_strategy, uniform_taus
from repro.core.decay import exponential_decay
from repro.rl.fedrl import fedrl_bytes_curve
from repro.sweep import SweepAxis, SweepSpec, mean_ci, run_sweep, run_sweep_loop


def _curves(out, metrics, config, cfg, lam_idx=None):
    """Seed-reduced curves + run-level summary for one plotted config.

    ``cfg`` is the config the curves were run with: its host-side ledger
    supplies the cumulative wire-bytes x-axis (``fedrl_bytes_curve``), so
    the figure reads "convergence bought per byte on the wire".
    """
    entry, rows = sweep_config_rows(config, metrics, out["n_seeds"],
                                    idx=lam_idx)
    bytes_curve = fedrl_bytes_curve(cfg)
    entry["bytes"] = bytes_curve.tolist()
    for ep, row in enumerate(rows):
        row["bytes"] = float(bytes_curve[ep])
    out["curves"][config] = entry
    # Table II style run-level metric: per-seed mean over epochs, then CI
    sel = (lambda a: a) if lam_idx is None else (lambda a: a[lam_idx])
    egn_m, egn_h = mean_ci(sel(metrics["server_grad_sq_norm"]).mean(-1), 0)
    out["summary"][config] = {
        "expected_grad_norm_mean": float(egn_m),
        "expected_grad_norm_ci_hw": float(egn_h),
        "final_nas_mean": float(np.asarray(entry["nas_mean"])[-3:].mean()),
        "total_bytes": float(bytes_curve[-1]),
    }
    return rows


def run(quick: bool = False, seeds=None) -> list[dict]:
    m, tau = 7, 15
    seeds = seed_tuple(seeds)
    taus = uniform_taus(1, tau, m, seed=0)
    epochs = 8 if quick else None
    lams = (0.98, 0.92) if quick else (0.98, 0.95, 0.92)

    base_spec = SweepSpec(
        name="fig5_no_decay",
        base=make_cfg(make_strategy("periodic", tau=tau, taus=taus),
                      epochs=epochs),
        seeds=seeds,
    )
    decay_spec = SweepSpec(
        name="fig5_decay",
        base=make_cfg(
            make_strategy("decay", tau=tau, taus=taus,
                          decay=exponential_decay(lams[0])),
            epochs=epochs,
        ),
        seeds=seeds,
        vmapped=(SweepAxis("lam", lams),),
    )

    res_base = run_sweep(base_spec)          # seeds-only vmap
    res_decay = run_sweep(decay_spec)        # (lam x seeds) in one computation
    res_loop = run_sweep_loop(decay_spec)    # same grid, Python seed-loop

    out = {
        "schema_version": 1,
        "quick": bool(quick),
        "seeds": list(seeds),
        "n_seeds": len(seeds),
        "lams": list(lams),
        "curves": {},
        "summary": {},
    }
    rows = _curves(out, res_base.metrics["base"], "no-decay", base_spec.base)
    emit("fig5/no-decay", res_base.wall_s["base"] / len(seeds) * 1e6,
         f"grad_norm={out['summary']['no-decay']['expected_grad_norm_mean']:.4f}"
         f"+-{out['summary']['no-decay']['expected_grad_norm_ci_hw']:.4f}")
    per_run_us = res_decay.wall_s["base"] / decay_spec.n_runs * 1e6
    for i, lam in enumerate(lams):
        config = f"lambda={lam}"
        rows += _curves(out, res_decay.metrics["base"], config,
                        decay_spec.base, lam_idx=i)
        s = out["summary"][config]
        emit(f"fig5/{config}", per_run_us,
             f"grad_norm={s['expected_grad_norm_mean']:.4f}"
             f"+-{s['expected_grad_norm_ci_hw']:.4f}")

    # Parity guard: the vmapped grid tracks the loop reference (same grid,
    # same jnp backend; XLA batching is allowed ~ulp-level drift only).
    max_dev = max(
        float(np.max(np.abs(res_decay.metrics["base"][k]
                            - res_loop.metrics["base"][k])))
        for k in res_decay.metrics["base"]
    )
    out["timings"] = {
        "n_runs": decay_spec.n_runs,
        "vmapped_exec_s": res_decay.wall_s["base"],
        "vmapped_compile_s": res_decay.compile_s["base"],
        "loop_exec_s": res_loop.wall_s["base"],
        "loop_compile_s": res_loop.compile_s["base"],
        # > 1 means the single vmapped computation beats the Python seed-loop
        "vmapped_speedup": res_loop.wall_s["base"] / res_decay.wall_s["base"],
        "max_abs_dev_vs_loop": max_dev,
    }
    emit("fig5/sweep_vs_loop", res_decay.wall_s["base"] * 1e6,
         f"loop={res_loop.wall_s['base'] * 1e6:.0f}us "
         f"x{out['timings']['vmapped_speedup']:.2f}")

    write_bench_json("fig5_sweep", out)
    res_decay.save("experiments/sweeps")
    write_csv("fig5_decay", rows)
    return rows


if __name__ == "__main__":
    run()
