"""Paper Fig. 5: decay-based method (DIRL), lambda sweep at tau=1~15."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, write_csv
from benchmarks.fmarl_bench import run_config
from repro.core import make_strategy, uniform_taus
from repro.core.decay import exponential_decay


def run(quick: bool = False) -> list[dict]:
    m = 7
    taus = uniform_taus(1, 15, m, seed=0)
    configs = [("no-decay", make_strategy("periodic", tau=15, taus=taus))]
    lams = [0.98, 0.92] if quick else [0.98, 0.95, 0.92]
    for lam in lams:
        configs.append((f"lambda={lam}", make_strategy(
            "decay", tau=15, taus=taus, decay=exponential_decay(lam))))
    rows = []
    for name, strat in configs:
        t0 = time.perf_counter()
        row, metrics = run_config(name, strat)
        for ep, v in enumerate(np.asarray(metrics["nas"])):
            rows.append({"config": name, "epoch": ep, "nas": float(v),
                         "grad_norm": float(metrics["server_grad_sq_norm"][ep])})
        emit(f"fig5/{name}", (time.perf_counter() - t0) * 1e6,
             f"grad_norm={row['expected_grad_norm']:.4f}")
    write_csv("fig5_decay", rows)
    return rows


if __name__ == "__main__":
    run()
