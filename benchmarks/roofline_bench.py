"""Roofline table from the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json and emits the 3-term roofline per
(arch x shape x mesh): compute / memory / collective seconds, the dominant
term, MODEL_FLOPS/HLO_FLOPs, and HBM fit. Also writes the markdown table
consumed by EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit, write_csv

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load_records(mesh: str | None = "pod16x16") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def summarize(recs: list[dict]) -> list[dict]:
    rows = []
    for r in recs:
        base = {"arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"]}
        if "skipped" in r:
            rows.append({**base, "status": "skip", "note": r["skipped"]})
            continue
        if not r.get("ok"):
            rows.append({**base, "status": "FAIL", "note": r.get("error", "")})
            continue
        rf = r["roofline"]
        prog = r.get("local") or r.get("prefill") or r.get("serve")
        rows.append({
            **base,
            "status": "ok",
            "t_compute_s": rf["t_compute_s"],
            "t_memory_s": rf["t_memory_s"],
            "t_collective_s": rf["t_collective_s"],
            "dominant": rf["dominant"],
            "useful_flops_ratio": r.get("useful_flops_ratio", float("nan")),
            "peak_gib": prog["peak_bytes_est"] / 2**30,
            "fits_hbm": prog["fits_hbm"],
            "note": "",
        })
    return rows


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dominant "
           "| useful/HLO | peak GiB | fits |\n|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"{r['status']}: {r['note'][:60]} | — | — | — |\n")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_flops_ratio']:.2f} | "
            f"{r['peak_gib']:.1f} | {'✓' if r['fits_hbm'] else '✗'} |\n"
        )
    return "".join(out)


def run(quick: bool = False) -> list[dict]:
    rows = summarize(load_records("pod16x16"))
    for r in rows:
        if r["status"] == "ok":
            emit(f"roofline/{r['arch']}/{r['shape']}",
                 max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]) * 1e6,
                 f"dominant={r['dominant']};useful={r['useful_flops_ratio']:.2f};"
                 f"peak_gib={r['peak_gib']:.1f}")
        else:
            emit(f"roofline/{r['arch']}/{r['shape']}", 0.0, r["status"])
    write_csv("roofline", rows)
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline_table.md", "w") as f:
        f.write(to_markdown(rows))
    return rows


if __name__ == "__main__":
    run()
