"""Serving benchmark: Poisson open-loop load over the AOT bucketed engine.

Drives ``repro.serve`` exactly as a deployment would (DESIGN.md §16): a
seeded fleet of open-loop Poisson clients pushes observation requests through
the micro-batching queue into the engine's precompiled bucket executables.
Two measurement phases per fleet size m ∈ {64, 1024, 10000}:

* **throughput** — drain a full-fleet backlog (every agent has one pending
  observation) and report sustained decisions/sec;
* **latency** — an open-loop arrival schedule at ~50% of the measured
  capacity, served on a virtual clock that advances by each engine call's
  *measured* wall time: latency = (virtual) completion - arrival, reported
  as p50/p99 ms. Open loop is the honest protocol — arrivals are drawn up
  front and never slow down when the server lags.

Correctness is pinned alongside the timings, same pattern as the other
benches: the engine's decisions are *bitwise* eager ``policy_apply`` on the
jnp path, interpret-mode (Pallas body) decisions match to fp32 tolerance,
bucket padding never changes a real decision, and engine construction
compiles exactly once per bucket with zero compiles on the serving hot path
(retrace guard).

Gated keys (stable across --quick/full, see bench_baseline.json):
``compiles/per_bucket``, ``compiles/hot_path``, ``parity/jnp_bitwise_dev``,
``parity/interpret_dev``, ``padding/max_abs_dev``,
``fleets/<m>/decisions_per_sec`` (min), ``fleets/10000/p99_ms`` (max).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, write_bench_json, write_csv
from repro.analysis.retrace import count_compiles, warmup_jax
from repro.serve import MicroBatchQueue, ObsNorm, ServeEngine, simulate_clients

import jax
import jax.numpy as jnp

OBS_DIM, HIDDEN, ACT_DIM = 6, 64, 1
BUCKETS = (8, 64, 256, 1024)
FLEET_SIZES = (64, 1024, 10000)
LOAD_FRACTION = 0.5       # latency phase offers 50% of measured capacity
SEED = 0


def _make_inputs():
    from repro.rl.policy import init_policy

    params = init_policy(jax.random.key(SEED), OBS_DIM, hidden=HIDDEN,
                         act_dim=ACT_DIM)
    norm = ObsNorm(np.linspace(-0.5, 0.5, OBS_DIM).astype(np.float32),
                   np.full(OBS_DIM, 1.25, np.float32))
    return params, norm


def _compile_section() -> tuple[ServeEngine, dict]:
    """Retrace pin: one AOT compile per bucket, zero on the hot path."""
    params, norm = _make_inputs()   # init_policy's own compiles don't count
    warmup_jax()
    with count_compiles() as c:
        eng = ServeEngine(params, norm=norm, buckets=BUCKETS, mode="mean",
                          backend="jnp", seed=SEED)
    build_compiles = c.count
    with count_compiles() as c:
        for n in (1, 8, 9, 64, 65, 256, 1024, 3, 100):
            eng.decide(np.zeros((n, OBS_DIM), np.float32))
    hot = c.count
    per_bucket = build_compiles / len(BUCKETS)
    emit("serving/compiles", 0.0,
         f"per_bucket={per_bucket:g} hot_path={hot}")
    return eng, {
        "buckets": list(BUCKETS),
        "build_compiles": build_compiles,
        "per_bucket": per_bucket,
        "hot_path": hot,
    }


def _parity_section(eng: ServeEngine) -> dict:
    """Bitwise pin vs eager policy_apply + interpret-mode kernel parity.

    ``jnp_bitwise_dev`` is the op-for-op identity of the kernel's jnp
    reference path with eager ``policy_apply`` on normalized observations —
    gated at exactly 0.0 (same pattern as the async zero-delay pin). The AOT
    engine executable is additionally compared against the eager reference
    (``engine_vs_eager_dev``): that one crosses an XLA compile boundary, so
    the whole-graph dot emitter may differ from the eager op-by-op one at
    large batch shapes — fp32-ulp tolerance, not bitwise.
    """
    from repro.kernels import dispatch
    from repro.rl.policy import policy_apply

    obs = np.random.default_rng(1).standard_normal(
        (137, OBS_DIM)).astype(np.float32)
    noise = np.random.default_rng(2).standard_normal(
        (obs.shape[0], ACT_DIM)).astype(np.float32)
    pi = {k: jnp.asarray(v) for k, v in eng._pi.items()}
    with jax.disable_jit():
        got = dispatch.policy_infer(
            jnp.asarray(obs), pi, eng.norm.mean, eng.norm.std,
            jnp.asarray(noise), sample=False, backend="jnp",
        )
        obsn = (jnp.asarray(obs, jnp.float32) - jnp.asarray(eng.norm.mean)) \
            / jnp.asarray(eng.norm.std)
        mean, _ = policy_apply({"pi": pi}, obsn)
    jnp_dev = float(np.max(np.abs(np.asarray(got) - np.asarray(mean))))
    engine_dev = float(np.max(np.abs(eng.decide(obs) - np.asarray(mean))))

    a = dispatch.policy_infer(
        jnp.asarray(obs), pi, eng.norm.mean, eng.norm.std,
        jnp.asarray(noise), sample=True, backend="jnp",
    )
    b = dispatch.policy_infer(
        jnp.asarray(obs), pi, eng.norm.mean, eng.norm.std,
        jnp.asarray(noise), sample=True, backend="interpret", block_b=64,
    )
    interp_dev = float(jnp.max(jnp.abs(a - b)))
    emit("serving/parity", 0.0,
         f"jnp_bitwise_dev={jnp_dev:.1e} interpret_dev={interp_dev:.1e} "
         f"engine_vs_eager_dev={engine_dev:.1e}")
    return {"jnp_bitwise_dev": jnp_dev, "interpret_dev": interp_dev,
            "engine_vs_eager_dev": engine_dev}


def _padding_section(eng: ServeEngine) -> dict:
    """Same bucket, different padding: real rows decide identically."""
    obs5 = np.random.default_rng(3).standard_normal(
        (5, OBS_DIM)).astype(np.float32)
    extra = np.random.default_rng(4).standard_normal(
        (3, OBS_DIM)).astype(np.float32)
    alone = eng.decide(obs5)                              # padded 5 -> 8
    together = eng.decide(np.concatenate([obs5, extra]))  # full bucket
    dev = float(np.max(np.abs(alone - together[:5])))
    emit("serving/padding", 0.0, f"max_abs_dev={dev:.1e}")
    return {"bucket": BUCKETS[0], "real_rows": 5, "max_abs_dev": dev}


def _drain_backlog(eng: ServeEngine, q: MicroBatchQueue) -> int:
    n = 0
    while (nxt := q.next_batch()) is not None:
        obs, reqs = nxt
        eng.decide(obs)
        n += len(reqs)
    return n


def _throughput(eng: ServeEngine, m: int, repeats: int) -> float:
    """Sustained decisions/sec draining a full-fleet backlog."""
    best = 0.0
    rng = np.random.default_rng(SEED + m)
    for _ in range(repeats):
        q = MicroBatchQueue(max_batch=eng.max_batch(), obs_dim=OBS_DIM)
        from repro.serve import ObsRequest

        obs = rng.standard_normal((m, OBS_DIM)).astype(np.float32)
        q.push_all([ObsRequest(i, 0.0, obs[i]) for i in range(m)])
        t0 = time.perf_counter()
        n = _drain_backlog(eng, q)
        dt = time.perf_counter() - t0
        assert n == m
        best = max(best, m / dt)
    return best


def _latency(eng: ServeEngine, m: int, rate_total: float,
             horizon: float) -> tuple[np.ndarray, int]:
    """Open-loop latency: virtual arrival clock + measured service times.

    Requests arrive on the seeded Poisson schedule; the server coalesces
    everything that has arrived by the current virtual clock (up to the
    largest bucket), serves it with a real engine call, and advances the
    clock by the call's measured wall time. Latency = completion - arrival.
    """
    reqs = simulate_clients(m, rate_total / m, horizon, obs_dim=OBS_DIM,
                            seed=SEED + m)
    lat = np.empty(len(reqs))
    clock, i = 0.0, 0
    while i < len(reqs):
        clock = max(clock, reqs[i].t_arrival)
        j = i
        cap = i + eng.max_batch()
        while j < len(reqs) and reqs[j].t_arrival <= clock and j < cap:
            j += 1
        obs = np.stack([r.obs for r in reqs[i:j]])
        t0 = time.perf_counter()
        eng.decide(obs)
        clock += time.perf_counter() - t0
        for r_i in range(i, j):
            lat[r_i] = clock - reqs[r_i].t_arrival
        i = j
    return lat, len(reqs)


def run(quick: bool = False, seeds=None) -> list[dict]:
    del seeds
    eng, compiles = _compile_section()
    parity = _parity_section(eng)
    padding = _padding_section(eng)

    repeats = 2 if quick else 5
    horizon = 0.25 if quick else 1.0
    rows = []
    fleets = {}
    for m in FLEET_SIZES:
        dps = _throughput(eng, m, repeats)
        lat, n_reqs = _latency(eng, m, LOAD_FRACTION * dps, horizon)
        p50 = float(np.percentile(lat, 50) * 1e3)
        p99 = float(np.percentile(lat, 99) * 1e3)
        emit(f"serving/fleet_m{m}", 1e6 / dps,
             f"decisions_per_sec={dps:.0f} p50_ms={p50:.3f} "
             f"p99_ms={p99:.3f} n_reqs={n_reqs}")
        fleets[str(m)] = {
            "decisions_per_sec": dps,
            "p50_ms": p50,
            "p99_ms": p99,
            "offered_rate": LOAD_FRACTION * dps,
            "n_requests": n_reqs,
        }
        rows.append({"m": m, "decisions_per_sec": dps, "p50_ms": p50,
                     "p99_ms": p99, "n_requests": n_reqs})

    out = {
        "schema_version": 1,
        "quick": bool(quick),
        "obs_dim": OBS_DIM,
        "hidden": HIDDEN,
        "act_dim": ACT_DIM,
        "buckets": list(BUCKETS),
        "load_fraction": LOAD_FRACTION,
        "compiles": compiles,
        "parity": parity,
        "padding": padding,
        "fleets": fleets,
    }
    write_bench_json("serving", out)
    write_csv("serving", rows)
    return rows


if __name__ == "__main__":
    run()
