"""Render grid figures from versioned sweep artifacts — no data collection.

Reads the ``experiments/sweeps/<name>.v<N>.json`` artifacts that
``SweepResult.save`` emits (seed-reduced mean + CI half-width per
label/metric, nested over the vmapped axis grid) and renders one PNG per
sweep: a subplot per metric, one line + CI band per (static label x vmapped
axis point). Strictly artifact-driven — rerunning it never launches a sweep,
so figures regenerate byte-for-byte from committed JSON.

  PYTHONPATH=src python -m benchmarks.plot_sweeps [names ...]
      [--dir experiments/sweeps] [--out experiments/figures]

With no names, every sweep found in --dir is rendered at its latest version.
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
import re
import sys

import numpy as np

_VERSIONED = re.compile(r"^(?P<name>.+)\.v(?P<version>\d+)\.json$")


def latest_artifacts(sweep_dir: str) -> dict:
    """Map sweep name -> path of its highest-version JSON artifact."""
    latest: dict = {}
    if not os.path.isdir(sweep_dir):
        return latest
    for fname in os.listdir(sweep_dir):
        m = _VERSIONED.match(fname)
        if not m:
            continue
        name, version = m.group("name"), int(m.group("version"))
        if name not in latest or version > latest[name][0]:
            latest[name] = (version, os.path.join(sweep_dir, fname))
    return {name: path for name, (_, path) in latest.items()}


def _grid_curves(payload: dict):
    """Yield ``(metric, line_label, mean_1d, hw_1d)`` for every grid cell.

    The artifact's per-metric arrays are shaped ``(*axis_lens, *per_run)``;
    one line per (static label x vmapped coordinate), the trailing per-run
    axis (usually per-epoch) as the curve. Scalar per-run metrics come out
    as length-1 curves.
    """
    axis_names = list(payload.get("axes", {}))
    axis_lens = tuple(len(payload["axes"][a]) for a in axis_names)
    for label, metrics in payload.get("labels", {}).items():
        for metric, entry in metrics.items():
            mean = np.asarray(entry["mean"], dtype=np.float64)
            hw = np.asarray(entry["ci_hw"], dtype=np.float64)
            if mean.shape[: len(axis_lens)] != axis_lens:
                # metric not resolved over the axis grid; plot as one curve
                yield metric, label, mean.reshape(-1), hw.reshape(-1)
                continue
            for idx in itertools.product(*(range(s) for s in axis_lens)):
                coords = ", ".join(
                    f"{a}={payload['axes'][a][i]:g}"
                    if np.isscalar(payload["axes"][a][i])
                    else f"{a}[{i}]"
                    for a, i in zip(axis_names, idx)
                )
                line = label if not coords else f"{label} ({coords})"
                yield metric, line, mean[idx].reshape(-1), hw[idx].reshape(-1)


def render(path: str, out_dir: str) -> str:
    """Render one sweep artifact to ``<out_dir>/<name>.v<N>.png``."""
    import matplotlib

    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    with open(path) as f:
        payload = json.load(f)

    by_metric: dict = {}
    for metric, line, mean, hw in _grid_curves(payload):
        by_metric.setdefault(metric, []).append((line, mean, hw))

    n = max(len(by_metric), 1)
    fig, axes = plt.subplots(1, n, figsize=(5.5 * n, 4.0), squeeze=False)
    for ax, (metric, lines) in zip(axes[0], sorted(by_metric.items())):
        for line, mean, hw in lines:
            x = np.arange(mean.size)
            ax.plot(x, mean, label=line, linewidth=1.2)
            if np.any(hw > 0):
                ax.fill_between(x, mean - hw, mean + hw, alpha=0.2)
        ax.set_title(metric)
        ax.set_xlabel("epoch")
        ax.grid(True, alpha=0.3)
        ax.legend(fontsize=7)
    name = payload.get("name", os.path.basename(path))
    version = payload.get("version", 0)
    fig.suptitle(f"{name} (v{version}, {payload.get('n_seeds', '?')} seeds)")
    fig.tight_layout()

    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, f"{name}.v{version}.png")
    fig.savefig(out_path, dpi=120)
    plt.close(fig)
    print(f"# wrote {out_path}")
    return out_path


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("names", nargs="*",
                    help="sweep names to render (default: all found)")
    ap.add_argument("--dir", default="experiments/sweeps",
                    help="artifact directory (SweepResult.save output)")
    ap.add_argument("--out", default="experiments/figures",
                    help="PNG output directory")
    args = ap.parse_args(argv)

    artifacts = latest_artifacts(args.dir)
    if not artifacts:
        sys.exit(f"no versioned sweep artifacts under {args.dir!r}")
    names = args.names or sorted(artifacts)
    for name in names:
        if name not in artifacts:
            sys.exit(f"no artifact for sweep {name!r}; have {sorted(artifacts)}")
        render(artifacts[name], args.out)


if __name__ == "__main__":
    main()
