"""Payload-compression bench: bytes-per-utility across the comm transforms.

Runs the fig5 decay configuration under the ``repro.comm`` payload
transforms — dense fp32, top-k sparsification (k = n/16) and int8
quantization, both with error feedback — as one ``compression`` static axis
(one compile per transform, seeds vmapped inside each point). Tracked by the
CI bench-regression gate:

* ``total_bytes`` per transform — exact ledger arithmetic (rtol 0), so any
  drift in the byte accounting fails the gate;
* ``bytes_per_utility`` — total wire bytes x expected ||grad F||^2 (lower is
  better: fewer bytes paid per unit of achieved convergence, with utility
  read as 1/grad-norm); compression should beat dense by an order of
  magnitude here;
* the fused top-k select+scatter kernel wall-clock (loose max bound — CI
  only catches a collapse, not timing noise).
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import (
    emit,
    seed_tuple,
    sweep_config_rows,
    time_us,
    write_bench_json,
    write_csv,
)
from benchmarks.fmarl_bench import make_cfg
from repro.comm import identity, qint8, topk
from repro.comm.transforms import topk_threshold
from repro.core import make_strategy, uniform_taus
from repro.core.decay import exponential_decay
from repro.kernels import dispatch
from repro.rl.fedrl import fedrl_bytes_curve, fedrl_ledger, policy_payload_elems
from repro.sweep import SweepSpec, compression_axis, mean_ci, run_sweep


def _kernel_timings(n: int, k: int) -> dict:
    """Microbench the fused top-k select + scatter-accumulate reduction."""
    import jax

    m = 7
    x = jax.random.normal(jax.random.key(0), (m, n))
    thresh = topk_threshold(x, k)
    out = {"m": m, "n": n, "k": k}
    for backend in ("jnp", "interpret"):
        us = time_us(
            lambda b=backend: dispatch.topk_scatter(x, thresh, backend=b),
            iters=5 if backend == "interpret" else 20,
        )
        out[f"topk_scatter_{backend}_us"] = us
        emit(f"comm/topk_scatter[{backend}]", us, f"m={m} n={n} k={k}")
    return out


def run(quick: bool = False, seeds=None) -> list[dict]:
    m, tau = 7, 15
    seeds = seed_tuple(seeds)
    epochs = 8 if quick else None
    n = policy_payload_elems()
    k = max(1, n // 16)
    transforms = (identity(), topk(k), qint8())

    base = make_cfg(
        make_strategy("decay", tau=tau, taus=uniform_taus(1, tau, m, seed=0),
                      decay=exponential_decay(0.98)),
        epochs=epochs,
    )
    spec = SweepSpec(
        name="compression",
        base=base,
        seeds=seeds,
        static=(compression_axis(transforms),),
    )
    res = run_sweep(spec)

    out = {
        "schema_version": 1,
        "quick": bool(quick),
        "seeds": list(seeds),
        "n_seeds": len(seeds),
        "payload_elems": n,
        "topk_k": k,
        "points": {},
        "curves": {},
    }
    rows = []
    for tr in transforms:
        label = tr.label
        cfg = dataclasses.replace(base, strategy=base.strategy.with_comm(tr))
        metrics = res.metrics[label]
        entry, rws = sweep_config_rows(label, metrics, len(seeds))
        bytes_curve = fedrl_bytes_curve(cfg)
        entry["bytes"] = bytes_curve.tolist()
        for ep, row in enumerate(rws):
            row["bytes"] = float(bytes_curve[ep])
        out["curves"][label] = entry
        rows += rws

        egn_m, egn_h = mean_ci(metrics["server_grad_sq_norm"].mean(-1), 0)
        total = fedrl_ledger(cfg).total_bytes()
        point = {
            "expected_grad_norm_mean": float(egn_m),
            "expected_grad_norm_ci_hw": float(egn_h),
            "total_bytes": float(total),
            # lower = fewer wire bytes per unit of achieved 1/grad-norm
            "bytes_per_utility": float(total * egn_m),
        }
        out["points"][label] = point
        emit(f"comm/{label}", res.wall_s[label] / len(seeds) * 1e6,
             f"grad_norm={egn_m:.4f}+-{egn_h:.4f} bytes={total}")

    out["kernel"] = _kernel_timings(n, k)
    write_bench_json("compression_bench", out)
    write_csv("compression_bench", rows)
    return rows


if __name__ == "__main__":
    run()
