"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import csv
import os
import sys
import time

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")


def write_csv(name: str, rows: list[dict]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.csv")
    if rows:
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
    return path


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """The run.py contract: ``name,us_per_call,derived`` CSV lines on stdout."""
    print(f"{name},{us_per_call:.3f},{derived}")
    sys.stdout.flush()


def time_us(fn, *args, iters: int = 20) -> float:
    """Microbench timer: one warm-up call (compile), then mean us over iters."""
    import jax

    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0


# --- sweep-based figure benches (fig4/fig5/fig6) ------------------------------

DEFAULT_SEEDS = (0, 1, 2, 3)


def seed_tuple(seeds) -> tuple:
    """Normalise a --seeds value: int count, iterable of seeds, or None."""
    if seeds is None:
        return DEFAULT_SEEDS
    if isinstance(seeds, int):
        if seeds < 1:
            raise SystemExit("--seeds must be >= 1")
        return tuple(range(seeds))
    out = tuple(int(s) for s in seeds)
    if not out:
        raise SystemExit("--seeds must be >= 1")
    return out


def strategy_axis(name, configs):
    """A StaticAxis whose points swap the strategy of the base config."""
    import dataclasses

    from repro.sweep import StaticAxis

    return StaticAxis(name, tuple(
        (label, lambda cfg, s=strat: dataclasses.replace(cfg, strategy=s))
        for label, strat in configs
    ))


def write_bench_json(name: str, payload: dict) -> str:
    """Write one bench's JSON artifact to OUT_DIR and announce it."""
    import json

    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {path}")
    return path


def sweep_config_rows(config, metrics, n_seeds, *, idx=None, include_grad=True):
    """Seed-reduce one plotted config's curves from raw sweep metric arrays.

    ``metrics`` is a SweepResult per-label dict (arrays ``(*axes, S,
    epochs)``); ``idx`` selects a vmapped-axis index, after which the seed
    axis leads. Returns ``(curve_entry, rows)``: the JSON curve payload
    (mean + 95% CI half-width lists) and the per-epoch CSV row dicts —
    the one reduction shared by the fig4/fig5/fig6 benches.
    """
    from repro.sweep import mean_ci

    sel = (lambda a: a) if idx is None else (lambda a: a[idx])
    nas_m, nas_h = mean_ci(sel(metrics["nas"]), 0)
    entry = {"nas_mean": nas_m.tolist(), "nas_ci_hw": nas_h.tolist()}
    if include_grad:
        gn_m, gn_h = mean_ci(sel(metrics["server_grad_sq_norm"]), 0)
        entry["grad_norm_mean"] = gn_m.tolist()
        entry["grad_norm_ci_hw"] = gn_h.tolist()
    rows = []
    for ep in range(len(nas_m)):
        row = {"config": config, "epoch": ep,
               "nas": float(nas_m[ep]), "nas_ci_hw": float(nas_h[ep])}
        if include_grad:
            row["grad_norm"] = float(gn_m[ep])
            row["grad_norm_ci_hw"] = float(gn_h[ep])
        row["n_seeds"] = n_seeds
        rows.append(row)
    return entry, rows
