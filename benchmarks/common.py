"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import csv
import os
import sys
import time

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")


def write_csv(name: str, rows: list[dict]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.csv")
    if rows:
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
    return path


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """The run.py contract: ``name,us_per_call,derived`` CSV lines on stdout."""
    print(f"{name},{us_per_call:.3f},{derived}")
    sys.stdout.flush()


def time_us(fn, *args, iters: int = 20) -> float:
    """Microbench timer: one warm-up call (compile), then mean us over iters."""
    import jax

    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
