"""Train a ~100M-param LM for a few hundred steps with the paper's federated
aggregation as the cross-agent gradient-sync strategy (the mesh-level
integration, run for real on CPU at reduced width).

  PYTHONPATH=src python examples/train_lm_federated.py [--steps 300]
"""
import argparse

import repro.configs  # noqa: F401  (register archs)
from repro.configs import register_arch
from repro.configs.base import ModelConfig
from repro.launch.fedtrain import FedTrainConfig
from repro.launch.train import train

# ~100M-param llama-style config sized for CPU end-to-end training
LM100M = register_arch(ModelConfig(
    name="lm-100m",
    family="dense",
    n_layers=16,
    d_model=512,
    n_heads=8,
    n_kv_heads=4,
    head_dim=64,
    d_ff=2048,
    vocab_size=65536,          # ~33M embed (tied) + ~67M blocks ≈ 100M
    activation="swiglu",
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
    remat=False,
    ce_chunks=0,
    source="example",
))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--agents", type=int, default=2)
    ap.add_argument("--tau", type=int, default=8)
    ap.add_argument("--strategy", default="periodic",
                    choices=["sync", "periodic", "decay", "consensus"])
    ap.add_argument("--outer-momentum", type=float, default=0.0)
    args = ap.parse_args()
    n = LM100M.n_params()
    print(f"lm-100m: {n/1e6:.1f}M params, strategy={args.strategy} "
          f"tau={args.tau} agents={args.agents}")
    fed = FedTrainConfig(strategy=args.strategy, tau=args.tau, lr=3e-4,
                         outer_momentum=args.outer_momentum)
    _, losses = train("lm-100m", reduced=False, steps=args.steps, fed=fed,
                      n_agents=args.agents, batch=4, seq=128,
                      log_every=20)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'improved' if losses[-1] < losses[0] else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
