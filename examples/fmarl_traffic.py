"""End-to-end driver (paper's own experiment): federated MARL on the traffic
scenarios — train shared policies for a few hundred periods with periodic /
decay / consensus aggregation and compare expected gradient norm + NAS (the
Table II/Fig. 4-6 quantities).

  PYTHONPATH=src python examples/fmarl_traffic.py [--epochs 60] [--scenario merge]

The heterogeneous-fleet path (the paper's asynchronous-MDP setting) switches
on with ``--num-envs``:

  # 7 agents, each owning 8 parallel copies of its own perturbed MDP,
  # kernel-dispatch path forced through interpret mode:
  PYTHONPATH=src python examples/fmarl_traffic.py \
      --num-envs 8 --hetero 0.2 --backend interpret

Multi-seed sweep mode (``--seeds S``, S >= 2): every method runs S full
training runs batched in ONE jitted vmapped computation (``repro.sweep``)
and the table reports seed means with 95% t-interval half-widths:

  PYTHONPATH=src python examples/fmarl_traffic.py --seeds 4
"""
import argparse

import jax
import numpy as np

from repro.core import make_strategy, uniform_taus
from repro.core.decay import exponential_decay
from repro.core import topology as T
from repro.rl import FedRLConfig, get_scenario, make_fleet, run_fedrl
from repro.rl.fedrl import expected_gradient_norm, fedrl_ledger
from repro.rl.scenarios import SCENARIOS
from repro.sweep import SweepSpec, mean_ci, run_sweep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=40)
    ap.add_argument("--scenario", default="figure_eight",
                    choices=sorted(SCENARIOS))
    ap.add_argument("--algo", default="ppo", choices=["ppo", "trpo", "tac"])
    ap.add_argument("--num-envs", type=int, default=0,
                    help="B parallel envs per agent; 0 = legacy shared env")
    ap.add_argument("--hetero", type=float, default=None,
                    help="per-agent param perturbation scale (fleet mode; "
                         "default: the scenario's preset)")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "jnp", "pallas", "interpret"],
                    help="dispatch backend for the federated hot path")
    ap.add_argument("--agents", type=int, default=0,
                    help="fleet size m (fleet mode; default: the scenario's "
                         "RL-vehicle count, matching the paper's Table II)")
    ap.add_argument("--seeds", type=int, default=1,
                    help="seed count; >= 2 runs each method as one vmapped "
                         "multi-seed sweep (repro.sweep) and reports "
                         "mean +- 95%% CI")
    args = ap.parse_args()
    if args.seeds < 1:
        ap.error("--seeds must be >= 1")

    env = get_scenario(args.scenario).cfg
    fleet = args.num_envs > 0
    if not fleet and (args.hetero is not None or args.agents):
        ap.error("--hetero/--agents only apply to the fleet path; "
                 "add --num-envs >= 1")
    m = (args.agents or env.n_rl) if fleet else env.n_rl
    env_params = None
    if fleet:
        env, env_params = make_fleet(args.scenario, m, jax.random.key(42),
                                     hetero=args.hetero)
    tau = 10
    runs = {
        "IRL tau=1": make_strategy("sync", m=m, backend=args.backend),
        "IRL tau=10": make_strategy("periodic", tau=tau, m=m,
                                    backend=args.backend),
        "IRL tau=1~10 (variation)": make_strategy(
            "periodic", tau=tau, taus=uniform_taus(1, tau, m, seed=0),
            backend=args.backend),
        "DIRL lam=0.95": make_strategy(
            "decay", tau=tau, taus=uniform_taus(1, tau, m, seed=0),
            decay=exponential_decay(0.95), backend=args.backend),
    }
    if m >= 2:  # gossip needs a topology (ring_attenuation has n_rl=1)
        topo = (T.random_regularish(m, 3, min(4, m - 1), seed=0)
                if m > 4 else T.chain(m))
        eps = 0.9 / topo.max_degree
        runs[f"CIRL E=1 mu2={T.mu2(topo):.2f}"] = make_strategy(
            "consensus", tau=tau, topo=topo, eps=eps, rounds=1, m=m,
            backend=args.backend)
    mode = (f"fleet m={m} B={args.num_envs} hetero="
            f"{args.hetero if args.hetero is not None else 'preset'}"
            if fleet else f"shared-env m={m}")
    sweep = args.seeds >= 2
    print(f"scenario={env.name} {mode} algo={args.algo} "
          f"backend={args.backend} epochs={args.epochs}"
          + (f" seeds={args.seeds} (vmapped sweep, mean +- 95% CI)"
             if sweep else ""))
    print(f"{'method':28s} {'E||gradF||^2':>22s} {'NAS(start->end)':>18s} "
          f"{'C1':>7s} {'W1':>8s}")
    for name, strat in runs.items():
        cfg = FedRLConfig(env=env, strategy=strat, eta=3e-3,
                          n_epochs=args.epochs, epoch_len=100, minibatch=20,
                          algo=args.algo, num_envs=args.num_envs,
                          env_params=env_params)
        if sweep:
            spec = SweepSpec(name="traffic", base=cfg,
                             seeds=tuple(range(args.seeds)))
            met = run_sweep(spec).metrics["base"]
            # per-seed run-level grad norm, then mean/CI over the seed axis
            egn_m, egn_h = mean_ci(met["server_grad_sq_norm"].mean(-1), 0)
            nas0 = float(met["nas"][:, :3].mean())
            nas1 = float(met["nas"][:, -3:].mean())
            ledger = fedrl_ledger(cfg)
            egn_s = f"{float(egn_m):9.4f} +- {float(egn_h):7.4f}"
        else:
            _, metrics, ledger = run_fedrl(cfg, jax.random.key(0))
            nas0 = float(np.mean(metrics["nas"][:3]))
            nas1 = float(np.mean(metrics["nas"][-3:]))
            egn_s = f"{expected_gradient_norm(metrics):22.4f}"
        row = ledger.table_row()
        print(f"{name:28s} {egn_s:>22s} "
              f"{nas0:8.3f} -> {nas1:5.3f} "
              f"{row['communication_overheads_C1']:>7d} "
              f"{row['inter_communication_W1']:>8d}")


if __name__ == "__main__":
    main()
