"""End-to-end driver (paper's own experiment): federated MARL on the
figure-eight traffic env — train shared policies for a few hundred periods
with periodic / decay / consensus aggregation and compare expected gradient
norm + NAS (the Table II/Fig. 4-6 quantities).

  PYTHONPATH=src python examples/fmarl_traffic.py [--epochs 60] [--scenario merge]
"""
import argparse

import jax
import numpy as np

from repro.core import make_strategy, uniform_taus
from repro.core.decay import exponential_decay
from repro.core import topology as T
from repro.rl import FIGURE_EIGHT, MERGE, FedRLConfig, run_fedrl
from repro.rl.fedrl import expected_gradient_norm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=40)
    ap.add_argument("--scenario", default="figure_eight",
                    choices=["figure_eight", "merge"])
    ap.add_argument("--algo", default="ppo", choices=["ppo", "trpo", "tac"])
    args = ap.parse_args()

    env = FIGURE_EIGHT if args.scenario == "figure_eight" else MERGE
    m, tau = env.n_rl, 10
    topo = (T.random_regularish(m, 3, min(4, m - 1), seed=0)
            if m > 4 else T.chain(m))
    eps = 0.9 / topo.max_degree
    runs = {
        "IRL tau=1": make_strategy("sync", m=m),
        "IRL tau=10": make_strategy("periodic", tau=tau, m=m),
        "IRL tau=1~10 (variation)": make_strategy(
            "periodic", tau=tau, taus=uniform_taus(1, tau, m, seed=0)),
        "DIRL lam=0.95": make_strategy(
            "decay", tau=tau, taus=uniform_taus(1, tau, m, seed=0),
            decay=exponential_decay(0.95)),
        f"CIRL E=1 mu2={T.mu2(topo):.2f}": make_strategy(
            "consensus", tau=tau, topo=topo, eps=eps, rounds=1, m=m),
    }
    print(f"scenario={env.name} agents={m} algo={args.algo} "
          f"epochs={args.epochs}")
    print(f"{'method':28s} {'E||gradF||^2':>12s} {'NAS(start->end)':>18s} "
          f"{'C1':>7s} {'W1':>8s}")
    for name, strat in runs.items():
        cfg = FedRLConfig(env=env, strategy=strat, eta=3e-3,
                          n_epochs=args.epochs, epoch_len=100, minibatch=20,
                          algo=args.algo)
        _, metrics, ledger = run_fedrl(cfg, jax.random.key(0))
        nas0 = float(np.mean(metrics["nas"][:3]))
        nas1 = float(np.mean(metrics["nas"][-3:]))
        row = ledger.table_row()
        print(f"{name:28s} {expected_gradient_norm(metrics):12.4f} "
              f"{nas0:8.3f} -> {nas1:5.3f} "
              f"{row['communication_overheads_C1']:>7d} "
              f"{row['inter_communication_W1']:>8d}")


if __name__ == "__main__":
    main()
