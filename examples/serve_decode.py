"""Serve a small model with batched requests: prefill contexts, then decode
greedily with the ring-buffer KV cache (the decode_32k/long_500k code path).

  PYTHONPATH=src python examples/serve_decode.py [--arch rwkv6-1.6b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import decode_step, init_params, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    if cfg.is_encoder_decoder:
        raise SystemExit("use whisper-specific serving for enc-dec archs")
    params = init_params(cfg, jax.random.key(0))
    b, s = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)

    t0 = time.time()
    logits, states = jax.jit(
        lambda p, t: prefill(cfg, p, t, cache_len=s + args.new_tokens + 1)
    )(params, prompts)
    print(f"prefill {b}x{s}: {time.time()-t0:.2f}s")

    step = jax.jit(lambda p, t, st, pos: decode_step(cfg, p, t, st, pos))
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        logits, states = step(params, tok, states, jnp.full((b,), s + i))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {args.new_tokens} tokens/seq x {b} seqs in {dt:.2f}s "
          f"({args.new_tokens * b / dt:.1f} tok/s)")
    print("sample token ids:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
