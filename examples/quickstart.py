"""Quickstart: the paper's three methods on a toy federated problem, plus the
closed-form bounds that predict their ordering.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    FmarlConfig,
    make_strategy,
    run_fmarl,
    uniform_taus,
)
from repro.core.bounds import (
    SgdConstants,
    consensus_bound_t5,
    periodic_bound_t1,
    variation_bound_t2,
)
from repro.core.decay import exponential_decay
from repro.core import topology as T


def noisy_quadratic(params, key, agent_idx, step):
    """Each agent sees grad(F) + noise, F(x) = 0.5||x||^2.

    One independent key per leaf: reusing ``key`` across leaves would draw
    the *same* noise for every same-shaped leaf (RPR001).
    """
    leaves, treedef = jax.tree.flatten(params)
    g = treedef.unflatten([
        x + 0.3 * jax.random.normal(jax.random.fold_in(key, j), x.shape)
        for j, x in enumerate(leaves)
    ])
    loss = sum(jnp.sum(x**2) for x in leaves)
    return g, {"loss": loss}


def main():
    m, tau = 7, 8
    topo = T.random_regularish(m, 3, 4, seed=0)
    init = {"w": jnp.full((16, 16), 2.0)}
    strategies = {
        "sync (tau=1)": make_strategy("sync", m=m),
        "periodic": make_strategy("periodic", tau=tau, m=m),
        "variation-aware": make_strategy(
            "periodic", tau=tau, taus=uniform_taus(1, tau, m, seed=0)),
        "decay (lam=0.9)": make_strategy(
            "decay", tau=tau, m=m, decay=exponential_decay(0.9)),
        "consensus (E=2)": make_strategy(
            "consensus", tau=tau, topo=topo, eps=0.9 / topo.max_degree,
            rounds=2, m=m),
    }
    print(f"{'strategy':20s} {'final ||gradF||^2':>18s} {'C1 events':>10s} "
          f"{'W1 events':>10s}")
    for name, strat in strategies.items():
        cfg = FmarlConfig(strategy=strat, eta=0.05,
                          n_periods=40 * tau // strat.tau)
        _, metrics, ledger = run_fmarl(cfg, init, noisy_quadratic,
                                       jax.random.key(0),
                                       eval_grad_fn=lambda p, k: p)
        final = float(np.asarray(metrics["server_grad_sq_norm"])[-1])
        row = ledger.table_row()
        print(f"{name:20s} {final:18.5f} "
              f"{row['communication_overheads_C1']:>10d} "
              f"{row['inter_communication_W1']:>10d}")

    print("\nClosed-form bounds (paper T1/T2/T5) at matching settings:")
    c = SgdConstants(L=1.0, sigma2=0.09, beta=0.0, eta=0.05, K=40 * tau, m=m,
                     f0_minus_finf=float(jnp.sum(init["w"] ** 2) / 2))
    print(f"  T1 periodic: {periodic_bound_t1(c, tau):.4f}")
    print(f"  T2 variation-aware (uniform): "
          f"{variation_bound_t2(c, tau, (1 + tau) / 2, (tau**2 - 1) / 12):.4f}")
    print(f"  T5 consensus E=2: "
          f"{consensus_bound_t5(c, tau, topo, 0.9 / topo.max_degree, 2):.4f}")


if __name__ == "__main__":
    main()
