"""Whisper-small [arXiv:2212.04356] — enc-dec audio; conv/mel frontend is a stub."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="whisper-small",
        family="audio",
        n_layers=12,            # decoder layers
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab_size=51865,
        activation="gelu",
        norm="layernorm",
        qkv_bias=True,
        pos_emb="sinusoidal",
        is_encoder_decoder=True,
        n_encoder_layers=12,
        frontend="audio",
        n_frontend_tokens=1500,  # mel frames after the conv stub (30 s @ 50 Hz)
        source="arXiv:2212.04356",
    )
)
