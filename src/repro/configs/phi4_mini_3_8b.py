"""Phi-4-mini 3.8B [arXiv:2412.08905] — RoPE + SwiGLU + GQA, tied embeddings."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="phi4-mini-3.8b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=200064,
        activation="swiglu",
        tie_embeddings=True,
        rope_theta=10_000.0,
        source="arXiv:2412.08905",
    )
)
