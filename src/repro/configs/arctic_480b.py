"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base] — 128e top-2 MoE + dense residual."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="arctic-480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=4864,              # dense-residual MLP width
        vocab_size=32000,
        activation="swiglu",
        n_experts=128,
        top_k=2,
        expert_d_ff=4864,
        dense_residual=True,    # dense MLP in parallel with the MoE FFN
        capacity_factor=1.25,
        rope_theta=10_000.0,
        source="hf:Snowflake/snowflake-arctic-base",
    )
)
