"""Unified architecture config schema + registries for archs and input shapes."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    source: str = ""               # paper / model-card citation

    activation: str = "swiglu"     # swiglu | geglu | gelu
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    pos_emb: str = "rope"          # rope | sinusoidal | none
    tie_embeddings: bool = False
    sliding_window: Optional[int] = None   # SWA window for 'local' layers
    layer_pattern: Tuple[str, ...] = ("attn",)  # cycled: attn|local|rglru|wkv

    # MoE
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    n_shared_experts: int = 0
    first_k_dense: int = 0         # leading dense FFN layers (e.g. kimi-k2)
    dense_residual: bool = False   # parallel dense MLP next to MoE (arctic)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_group_size: int = 4096     # dispatch-einsum group size (perf knob:
                                   # dispatch FLOPs/token scale linearly with it)

    # SSM / recurrent
    wkv_impl: str = "scan"         # scan (baseline) | chunked (matmul-form, §Perf)
    wkv_chunk: int = 64
    wkv_head_dim: int = 64
    decay_lora_rank: int = 64      # rwkv6 data-dependent decay low-rank
    lru_width: int = 0             # rg-lru recurrence width (0 -> d_model)
    conv_width: int = 4

    # Modality frontend stubs (vlm/audio): input_specs() provides embeddings
    frontend: Optional[str] = None  # vision | audio
    n_frontend_tokens: int = 0

    # Encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0

    # Implementation knobs
    attn_impl: str = "flash"       # flash (custom-vjp) | chunked | einsum (oracle)
    attn_chunk: int = 512
    ce_chunks: int = 16            # chunked-CE batch chunks (0 = materialize logits)
    cache_update: str = "scatter"  # scatter | onehot (sharded-window-friendly)
    scan_layers: bool = True
    remat: bool = True
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    def __post_init__(self):
        if self.n_heads % max(self.n_kv_heads, 1) != 0:
            raise ValueError("n_heads must be divisible by n_kv_heads")
        if self.family == "moe" and (self.n_experts < 2 or self.top_k < 1):
            raise ValueError("moe family needs n_experts>=2, top_k>=1")
        for blk in self.layer_pattern:
            if blk not in ("attn", "local", "rglru", "wkv"):
                raise ValueError(f"unknown block kind {blk}")
        if "local" in self.layer_pattern and not self.sliding_window:
            raise ValueError("'local' blocks need a sliding_window")

    # ------------------------------------------------------------------
    @property
    def lru_dim(self) -> int:
        return self.lru_width or self.d_model

    @property
    def is_subquadratic(self) -> bool:
        """True if no block attends to unbounded context (long_500k eligible)."""
        return all(b != "attn" for b in self.layer_pattern)

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs are decoders or enc-dec

    def block_kind(self, layer_idx: int) -> str:
        return self.layer_pattern[layer_idx % len(self.layer_pattern)]

    def n_params(self) -> int:
        """Analytic parameter count (embeddings + blocks), for 6ND roofline."""
        d, hd = self.d_model, self.head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        n_dec = self.n_layers
        for i in range(n_dec):
            kind = self.block_kind(i)
            if kind in ("attn", "local"):
                attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
                if self.qkv_bias:
                    attn += hd * (self.n_heads + 2 * self.n_kv_heads)
            elif kind == "rglru":
                w = self.lru_dim
                attn = 2 * d * w + w * d + self.conv_width * w + 3 * w
            else:  # wkv
                attn = 4 * d * d + 2 * d * self.decay_lora_rank + 2 * d
            total += attn
            # FFN
            n_in = 2 if self.activation in ("swiglu", "geglu") else 1
            if self.family == "moe" and i >= self.first_k_dense:
                ff = self.n_experts * (n_in * d * self.expert_d_ff + self.expert_d_ff * d)
                ff += d * self.n_experts  # router
                ff += self.n_shared_experts * (n_in * d * self.expert_d_ff + self.expert_d_ff * d)
                if self.dense_residual:
                    ff += n_in * d * self.d_ff + self.d_ff * d
            else:
                ff = n_in * d * self.d_ff + self.d_ff * d
            total += ff + 2 * d  # norms
        if self.is_encoder_decoder:
            for _ in range(self.n_encoder_layers):
                attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
                ff = d * self.d_ff + self.d_ff * d
                total += attn + ff + 2 * d
            # decoder cross-attention
            total += n_dec * (d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d + d)
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k instead of all experts)."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        n_in = 2 if self.activation in ("swiglu", "geglu") else 1
        per_expert = n_in * d * self.expert_d_ff + self.expert_d_ff * d
        n_moe_layers = self.n_layers - self.first_k_dense
        inactive = n_moe_layers * (self.n_experts - self.top_k) * per_expert
        return self.n_params() - inactive

    def reduced(self) -> "ModelConfig":
        """CPU smoke variant: same family/pattern, tiny dims."""
        d = min(self.d_model, 128)
        heads = min(self.n_heads, 4)
        kv = max(1, min(self.n_kv_heads, heads))
        while heads % kv:
            kv -= 1
        pat = self.layer_pattern
        n_layers = max(2, len(pat)) if len(pat) > 1 else 2
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=32,
            d_ff=min(self.d_ff, 256),
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            # no-drop regime for correctness tests: capacity drops make
            # prefill(S) vs forward(S+1) legitimately diverge (capacity binds
            # per sequence length); production keeps the real factor.
            capacity_factor=max(self.capacity_factor, 4.0),
            expert_d_ff=min(self.expert_d_ff, 128) if self.expert_d_ff else 0,
            first_k_dense=min(self.first_k_dense, 1),
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else None,
            lru_width=min(self.lru_dim, 128) if self.lru_width else 0,
            decay_lora_rank=16,
            n_frontend_tokens=min(self.n_frontend_tokens, 8) if self.n_frontend_tokens else 0,
            n_encoder_layers=min(self.n_encoder_layers, 2) if self.n_encoder_layers else 0,
            attn_chunk=8,
            param_dtype="float32",
            compute_dtype="float32",
            remat=False,
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPE_REGISTRY = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

ARCH_REGISTRY: dict[str, ModelConfig] = {}


def register_arch(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in ARCH_REGISTRY:
        raise ValueError(f"duplicate arch {cfg.name}")
    ARCH_REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ModelConfig:
    if name not in ARCH_REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCH_REGISTRY)}")
    return ARCH_REGISTRY[name]


def get_shape(name: str) -> InputShape:
    return SHAPE_REGISTRY[name]


def list_archs() -> list[str]:
    return sorted(ARCH_REGISTRY)
