"""H2O-Danube3-4B [arXiv:2401.16818] — llama+mistral mix with sliding-window attention."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="h2o-danube-3-4b",
        family="dense",
        n_layers=24,
        d_model=3840,
        n_heads=32,
        n_kv_heads=8,
        head_dim=120,
        d_ff=10240,
        vocab_size=32000,
        activation="swiglu",
        sliding_window=4096,
        layer_pattern=("local",),   # mistral-style SWA everywhere -> sub-quadratic
        rope_theta=10_000.0,
        source="arXiv:2401.16818",
    )
)
