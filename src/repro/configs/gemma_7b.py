"""Gemma-7B [arXiv:2403.08295] — GeGLU, head_dim=256, tied embeddings."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="gemma-7b",
        family="dense",
        n_layers=28,
        d_model=3072,
        n_heads=16,
        n_kv_heads=16,
        head_dim=256,
        d_ff=24576,
        vocab_size=256000,
        activation="geglu",
        tie_embeddings=True,
        rope_theta=10_000.0,
        source="arXiv:2403.08295",
    )
)
