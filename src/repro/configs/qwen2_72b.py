"""Qwen2-72B [arXiv:2407.10671] — dense GQA with QKV bias."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="qwen2-72b",
        family="dense",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        activation="swiglu",
        qkv_bias=True,
        rope_theta=1_000_000.0,
        source="arXiv:2407.10671",
    )
)
