"""RWKV6 'Finch' 1.6B [arXiv:2404.05892] — attention-free, data-dependent decay."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="rwkv6-1.6b",
        family="ssm",
        n_layers=24,
        d_model=2048,
        n_heads=32,           # wkv heads = d_model / wkv_head_dim
        n_kv_heads=32,
        head_dim=64,
        d_ff=7168,
        vocab_size=65536,
        layer_pattern=("wkv",),
        wkv_head_dim=64,
        decay_lora_rank=64,
        pos_emb="none",
        norm="layernorm",
        source="arXiv:2404.05892",
    )
)
