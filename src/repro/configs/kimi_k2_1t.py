"""Kimi K2 1T-A32B [arXiv:2501.kimi2] — trillion-param MoE, 384 experts top-8."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=18432,            # dense (first_k_dense) FFN width
        vocab_size=163840,
        activation="swiglu",
        n_experts=384,
        top_k=8,
        expert_d_ff=2048,
        n_shared_experts=1,
        first_k_dense=1,
        capacity_factor=1.25,
        rope_theta=50_000.0,
        source="arXiv:2501.kimi2 (paper-table)",
    )
)
