"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427] — RG-LRU + local attention, 2:1."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,          # MQA on the local-attention blocks
        head_dim=256,
        d_ff=12288,
        vocab_size=256000,
        activation="geglu",
        layer_pattern=("rglru", "rglru", "local"),  # 1 attn : 2 recurrent
        sliding_window=2048,
        lru_width=4096,
        conv_width=4,
        tie_embeddings=True,
        source="arXiv:2402.19427",
    )
)
