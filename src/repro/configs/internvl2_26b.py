"""InternVL2-26B [arXiv:2404.16821] — InternViT (stub frontend) + InternLM2 backbone."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="internvl2-26b",
        family="vlm",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=92553,       # padded to model-axis multiple by sharding rules
        activation="swiglu",
        frontend="vision",
        n_frontend_tokens=256,  # IMG context tokens per image (pixel-shuffled ViT patches)
        rope_theta=1_000_000.0,
        source="arXiv:2404.16821",
    )
)
