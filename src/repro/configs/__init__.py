"""Architecture configs: the 10 assigned architectures + the paper's own MARL setup.

Each module defines ``CONFIG`` (exact assigned spec) and registers it; every
config also provides ``.reduced()`` — the CPU-smoke variant (<=2 layers,
d_model<=512, <=4 experts) exercised by tests. Full configs are only ever
lowered via launch/dryrun.py (ShapeDtypeStruct, no allocation).
"""
from repro.configs.base import (
    ARCH_REGISTRY,
    InputShape,
    ModelConfig,
    SHAPE_REGISTRY,
    get_arch,
    get_shape,
    list_archs,
    register_arch,
)

# Import for registration side effects.
from repro.configs import (  # noqa: F401
    arctic_480b,
    gemma_7b,
    h2o_danube3_4b,
    internvl2_26b,
    kimi_k2_1t,
    phi4_mini_3_8b,
    qwen2_72b,
    recurrentgemma_9b,
    rwkv6_1_6b,
    whisper_small,
)

__all__ = [
    "ARCH_REGISTRY",
    "InputShape",
    "ModelConfig",
    "SHAPE_REGISTRY",
    "get_arch",
    "get_shape",
    "list_archs",
    "register_arch",
]
