"""Payload transforms: what actually crosses the federated links, in bytes.

A :class:`PayloadTransform` is a frozen hashable spec (like
``repro.optim.flat.FlatOptimizer``) describing the lossy encoding applied to
a flat ``(m, n)`` payload matrix before it is communicated — uplink deltas at
the period sync, gossip payloads on the consensus path. Four kinds:

* ``identity`` — dense fp32; 4n bytes per event. The default; strategies
  with this transform keep their exact pre-comm-layer behaviour.
* ``topk``     — per-agent top-k magnitude sparsification. The selection rule
  is *threshold* form: keep every entry with ``|x| >= kth largest |x|`` of
  its row (magnitude ties at the threshold are all kept, so the jnp
  ``segment_sum`` reference and the fused Pallas kernel agree exactly).
  Wire size: k (value, index) pairs = 8k bytes per event.
* ``int8``     — symmetric per-row quantization, ``s = max|x| / 127``,
  ``q = round(x/s)`` in [-127, 127]; n + 4 bytes per event (payload + fp32
  row scale). The dequantized error is bounded by s/2 — half an ulp of the
  row scale.
* ``bf16``     — round-trip through bfloat16; 2n bytes per event.

Error feedback (EF-SGD style): ``encode`` returns ``(sent, residual)`` with
``sent + residual == x`` exactly in fp32 arithmetic — for top-k the kept
entries pass through bitwise and the dropped entries land in the residual
whole. The caller folds the previous residual into the next payload
(``encode(x + err)``) and stores the new one; the strategies keep those
``(m, n)`` fp32 accumulators in the drivers' flat scan carry next to the
optimizer moments (the PR-2 fp32-moments pattern).

``reduce_mean`` is the compressed server reduction: the mean over the agent
axis of the encoded payloads, accumulated in fp32 on every backend. The
top-k path routes through ``dispatch.topk_scatter`` — the fused
select + scatter-accumulate kernel — so the dense ``sent`` matrix is never
materialised on kernel backends.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels import dispatch

KINDS = ("identity", "topk", "int8", "bf16")


def topk_threshold(x, k: int):
    """Per-row top-k magnitude threshold: the k-th largest ``|x|`` per row.

    ``x``: ``(..., n)``. Returns the ``(...,)`` thresholds; an entry is kept
    iff ``|x| >= threshold`` (ties included — the one selection rule shared
    by the jnp reference and the Pallas kernel, so parity is exact).
    """
    n = x.shape[-1]
    if not 1 <= k <= n:
        raise ValueError(f"topk_threshold: need 1 <= k <= {n}, got k={k}")
    return jax.lax.top_k(jnp.abs(x), k)[0][..., -1]


def quantize_int8(x):
    """Symmetric per-row int8 quantization: ``(q, scale)``.

    ``scale = max|x| / 127`` per row; ``q = round(x / scale)`` clipped to
    [-127, 127] (all-zero rows quantize through a safe unit scale to q=0).
    """
    x = jnp.asarray(x, jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = amax / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe[..., None]), -127.0, 127.0).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    """fp32 reconstruction of a per-row-quantized payload."""
    return q.astype(jnp.float32) * jnp.asarray(scale, jnp.float32)[..., None]


@dataclasses.dataclass(frozen=True)
class PayloadTransform:
    """Frozen spec of one link compression scheme (hashable, jit-closable).

    ``k`` is static (it fixes the top-k wire size and the kernel trace);
    sweeping it is a *static* axis (``repro.sweep.overrides.compression_axis``).
    ``error_feedback`` adds the per-agent fp32 residual accumulators to the
    strategy's comm state.
    """

    kind: str = "identity"
    k: int = 0
    error_feedback: bool = True

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown payload transform kind {self.kind!r}; expected one "
                f"of {KINDS}"
            )
        if self.kind == "topk":
            if self.k < 1:
                raise ValueError(f"topk transform needs k >= 1, got {self.k}")
        elif self.k:
            raise ValueError(f"k only applies to the topk kind, got k={self.k}")

    @property
    def enabled(self) -> bool:
        """True when the transform actually changes the payload."""
        return self.kind != "identity"

    @property
    def label(self) -> str:
        if self.kind == "identity":
            return "dense"
        if self.kind == "topk":
            return f"topk{self.k}"
        return self.kind

    # --- bytes accounting ------------------------------------------------------
    def payload_bytes(self, n: int) -> int:
        """Wire bytes of ONE encoded n-element payload (one comm event).

        identity: 4n (dense fp32); topk: 8k nominal ((fp32 value, int32
        index) per kept element — threshold ties may send a few extra, the
        accounting uses the nominal k); int8: n + 4 (int8 payload + fp32 row
        scale); bf16: 2n.
        """
        n = int(n)
        if n < 0:
            raise ValueError(f"payload_bytes: n must be >= 0, got {n}")
        if self.kind == "identity":
            return 4 * n
        if self.kind == "topk":
            return 8 * min(self.k, n)
        if self.kind == "int8":
            return n + 4
        return 2 * n

    # --- encoding --------------------------------------------------------------
    def encode(self, x, *, backend: str = "auto"):
        """Encode/decode round-trip of a payload matrix: ``(sent, residual)``.

        ``x``: ``(m, n)`` (or ``(S, m, n)``) fp32 payloads — callers fold the
        previous error-feedback residual in *before* encoding. ``sent`` is
        the receiver-visible fp32 reconstruction, ``residual = x - sent``
        (exact in fp32: kept/dequantized values subtract out bitwise for
        top-k). The ``backend`` is accepted for interface symmetry with
        :meth:`reduce_mean`; the dense encodes are elementwise jnp on every
        backend.
        """
        del backend  # elementwise encodes have no kernel variant
        x = jnp.asarray(x, jnp.float32)
        if self.kind == "identity":
            return x, jnp.zeros_like(x)
        if self.kind == "topk":
            thresh = topk_threshold(x, self.k)
            keep = jnp.abs(x) >= thresh[..., None]
            sent = jnp.where(keep, x, 0.0)
        elif self.kind == "int8":
            sent = dequantize_int8(*quantize_int8(x))
        else:  # bf16
            sent = x.astype(jnp.bfloat16).astype(jnp.float32)
        return sent, x - sent

    def reduce_mean(self, x, *, backend: str = "auto"):
        """Compressed server reduction: ``(mean over agents, residual)``.

        The uplink sync primitive: each agent's row of ``x`` is encoded and
        the server averages the reconstructions, accumulating in fp32 on
        every backend. Top-k runs the fused ``dispatch.topk_scatter``
        select + scatter-accumulate (dense ``sent`` never materialises on
        kernel backends); int8/bf16 dequantize and ``row_mean``.
        """
        x = jnp.asarray(x, jnp.float32)
        m = x.shape[-2]
        if self.kind == "topk":
            thresh = topk_threshold(x, self.k)
            ssum, residual = dispatch.topk_scatter(x, thresh, backend=backend)
            return ssum / m, residual
        sent, residual = self.encode(x, backend=backend)
        return dispatch.row_mean(sent, backend=backend), residual


IDENTITY = PayloadTransform("identity", error_feedback=False)


def identity() -> PayloadTransform:
    """The dense fp32 no-op transform (byte accounting still applies)."""
    return IDENTITY


def topk(k: int, error_feedback: bool = True) -> PayloadTransform:
    """Top-k magnitude sparsification of each agent's payload row."""
    return PayloadTransform("topk", k=int(k), error_feedback=error_feedback)


def qint8(error_feedback: bool = True) -> PayloadTransform:
    """Symmetric per-row int8 quantization (n + 4 bytes per event)."""
    return PayloadTransform("int8", error_feedback=error_feedback)


def qbf16(error_feedback: bool = True) -> PayloadTransform:
    """bfloat16 round-trip (2n bytes per event)."""
    return PayloadTransform("bf16", error_feedback=error_feedback)


# --- trace-safety audit registration (repro.analysis.jaxpr_audit) -------------

def _reduce_hot_path(kind: str, backend: str):
    """Audit entry for the compressed server reduction on one backend.

    The contract under audit: the reduction over the agent axis accumulates
    in fp32 even when the wire format is int8/sparse — JXA001 would flag a
    sub-fp32 accumulation the moment one appeared in the lowered jaxpr.
    """

    def factory() -> dispatch.HotPathEntry:
        m, n = 4, 96
        tr = topk(8) if kind == "topk" else PayloadTransform(kind)
        return dispatch.HotPathEntry(
            fn=lambda x: tr.reduce_mean(x, backend=backend),
            args=(jax.ShapeDtypeStruct((m, n), jnp.float32),),
        )

    return factory


for _kind in ("topk", "int8"):
    for _backend in ("jnp", "interpret"):
        dispatch.register_hot_path(
            f"comm.{_kind}_reduce[{_backend}]",
            _reduce_hot_path(_kind, _backend),
        )
