"""repro.comm — byte-accurate payload transforms for the federated links.

What is *communicated* (dense fp32, top-k sparsified, int8/bf16 quantized
payloads, each with optional error feedback) is a separate concern from how
it is *aggregated* (periodic averaging, decay weighting, consensus gossip).
This package owns the former: :class:`PayloadTransform` encodes a flat
``(m, n)`` payload matrix, reports its wire size in bytes, and carries the
per-agent error-feedback residuals that live in the drivers' flat scan carry
next to the optimizer moments. ``AggregationStrategy`` composes one in via
its ``comm`` field; ``CostLedger`` prices every event with
``payload_bytes``. See DESIGN.md §13.
"""
from repro.comm.transforms import (
    IDENTITY,
    PayloadTransform,
    dequantize_int8,
    identity,
    qbf16,
    qint8,
    quantize_int8,
    topk,
    topk_threshold,
)

__all__ = [
    "IDENTITY",
    "PayloadTransform",
    "dequantize_int8",
    "identity",
    "qbf16",
    "qint8",
    "quantize_int8",
    "topk",
    "topk_threshold",
]
