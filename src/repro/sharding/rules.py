"""Logical-axis sharding rules (MaxText-style) with a context-local rule set.

Model code names array axes logically ("batch", "heads", "ff", ...). A
MeshRules maps logical names -> mesh axis names (or None). Outside a rules
context, `shard()` is the identity, so the same model code runs unsharded on
CPU smoke tests and fully sharded in the dry-run / trainer.

Divisibility: if a logical dimension is not divisible by its mesh axis size,
the rule engine *drops* that constraint (GSPMD would reject it). Dropped
constraints are recorded on the rules object so the dry-run can report them
(e.g. 8 kv heads on a 16-way model axis -> replicated KV, noted in
EXPERIMENTS.md rather than silently mis-sharded).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_LOCAL = threading.local()

# Default logical->mesh mapping used by the production mesh (data, model).
DEFAULT_RULES: dict[str, Optional[tuple]] = {
    "batch": ("data",),
    "seq": None,               # sequence parallelism off by default (perf knob)
    "embed": None,
    "embed_fsdp": ("data",),   # FSDP shard axis on params
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "experts": ("model",),
    "tokens": ("data", "model"),   # MoE group axis (batch x seq-chunks)
    "expert_ff": None,
    "layers": None,
    "lru": ("model",),
    "window": None,
    "head_dim": None,
    "agents": ("pod",),        # federated replica axis (multi-pod only)
}


@dataclasses.dataclass
class MeshRules:
    mesh: Mesh
    rules: dict
    dropped: set = dataclasses.field(default_factory=set)

    def mesh_axes_for(self, logical: Optional[str]) -> Optional[tuple]:
        if logical is None:
            return None
        axes = self.rules.get(logical)
        if axes is None:
            return None
        present = tuple(a for a in axes if a in self.mesh.axis_names)
        return present or None

    def spec(self, logical_axes: Tuple[Optional[str], ...], shape=None) -> P:
        entries = []
        used: set = set()
        for i, name in enumerate(logical_axes):
            axes = self.mesh_axes_for(name)
            if axes is None:
                entries.append(None)
                continue
            axes = tuple(a for a in axes if a not in used)
            if not axes:
                entries.append(None)
                continue
            if shape is not None:
                size = 1
                for a in axes:
                    size *= self.mesh.shape[a]
                if shape[i] % size != 0:
                    self.dropped.add((name, shape[i], size))
                    entries.append(None)
                    continue
            used.update(axes)
            entries.append(axes if len(axes) > 1 else axes[0])
        return P(*entries)

    def named_sharding(self, logical_axes, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))


def current_rules() -> Optional[MeshRules]:
    return getattr(_LOCAL, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[MeshRules]):
    prev = current_rules()
    _LOCAL.rules = rules
    try:
        yield rules
    finally:
        _LOCAL.rules = prev


def axes_to_spec(logical_axes, shape=None) -> Optional[P]:
    r = current_rules()
    if r is None:
        return None
    return r.spec(logical_axes, shape)


def shard(x, *logical_axes):
    """with_sharding_constraint by logical axes; identity outside a rules ctx."""
    r = current_rules()
    if r is None:
        return x
    spec = r.spec(tuple(logical_axes), x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))


# --- federated fleet sharding (opt-in) ----------------------------------------
#
# The fleet engine (repro.rl.rollout) and the flat-carry drivers tag the
# leading replica/agent axis of their buffers with the logical name "agents".
# Activating `use_rules(fleet_rules())` shards that axis across devices —
# rollout and local updates for different agents then run on different
# devices, and only the server average / gossip mix communicates. Outside a
# rules context every tag is the identity, so the default CPU path is
# untouched.

FLEET_RULES: dict[str, Optional[tuple]] = {
    "agents": ("agents",),
    "envs": None,              # B parallel envs per agent stay local
}


def fleet_mesh(n_agents_shards: Optional[int] = None) -> Mesh:
    """1-D device mesh over the federated agent axis (all devices by default)."""
    from repro.utils import compat

    n = n_agents_shards or len(jax.devices())
    return compat.make_mesh((n,), ("agents",))


def fleet_rules(mesh: Optional[Mesh] = None) -> MeshRules:
    """MeshRules sharding the federated agent axis; pair with ``use_rules``."""
    return MeshRules(mesh=mesh if mesh is not None else fleet_mesh(),
                     rules=dict(FLEET_RULES))


def shard_agents(tree):
    """Constrain the leading (m, ...) axis of every leaf to the "agents" rule.

    Identity outside a rules context (and for scalar leaves), so it is safe
    to leave in the hot path unconditionally.
    """
    if current_rules() is None:
        return tree
    return jax.tree.map(
        lambda l: l if getattr(l, "ndim", 0) == 0
        else shard(l, "agents", *((None,) * (l.ndim - 1))),
        tree,
    )


def logical_axis_size(name: str) -> int:
    """Mesh extent a logical axis would shard over (1 outside a rules ctx)."""
    r = current_rules()
    if r is None:
        return 1
    axes = r.mesh_axes_for(name)
    if not axes:
        return 1
    size = 1
    for a in axes:
        size *= r.mesh.shape[a]
    return size
