from repro.sharding.rules import (
    MeshRules,
    axes_to_spec,
    current_rules,
    shard,
    use_rules,
)

__all__ = ["MeshRules", "axes_to_spec", "current_rules", "shard", "use_rules"]
