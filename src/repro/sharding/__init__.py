from repro.sharding.rules import (
    FLEET_RULES,
    MeshRules,
    axes_to_spec,
    current_rules,
    fleet_mesh,
    fleet_rules,
    shard,
    shard_agents,
    use_rules,
)

__all__ = [
    "FLEET_RULES",
    "MeshRules",
    "axes_to_spec",
    "current_rules",
    "fleet_mesh",
    "fleet_rules",
    "shard",
    "shard_agents",
    "use_rules",
]
