"""Mixture-of-Experts FFN: top-k routing with capacity-based GSPMD dispatch.

Switch/GShard-style: tokens are split into groups; within a group each expert
accepts at most C = top_k * S / E * capacity_factor tokens (overflow drops to
the residual path). Dispatch/combine are one-hot einsums so GSPMD can lower
the group->expert exchange to an all-to-all when groups are sharded over
'data'+'model' and experts over 'model'. Variants:

  * shared experts (kimi-k2): always-on expert(s) added to the routed output.
  * dense residual (arctic): a parallel dense MLP added to the routed output.

An auxiliary load-balance loss (Switch eq. 4) is returned for training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_mlp, apply_mlp, mk
from repro.sharding.rules import logical_axis_size, shard


def init_moe(key, cfg):
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    ks = jax.random.split(key, 6)
    glu = cfg.activation in ("swiglu", "geglu")
    p = {
        "router": mk(ks[0], (d, e), ("embed", "experts"), std=0.02),
        "w_down": mk(ks[3], (e, ff, d), ("experts", "expert_ff", "embed_fsdp"),
                     std=0.02 / max(1, ff) ** 0.5),
    }
    if glu:
        p["w_gate"] = mk(ks[1], (e, d, ff), ("experts", "embed_fsdp", "expert_ff"))
        p["w_up"] = mk(ks[2], (e, d, ff), ("experts", "embed_fsdp", "expert_ff"))
    else:
        p["w_in"] = mk(ks[1], (e, d, ff), ("experts", "embed_fsdp", "expert_ff"))
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, ff * cfg.n_shared_experts, cfg.activation)
    if cfg.dense_residual:
        p["residual"] = init_mlp(ks[5], d, cfg.d_ff, cfg.activation)
    return p


def _group_tokens(x, group_size):
    """(B,S,d) -> (G, S_g, d) with S_g <= group_size, padding if needed."""
    b, s, d = x.shape
    tokens = b * s
    g_sz = min(group_size, tokens)
    pad = (-tokens) % g_sz
    flat = x.reshape(tokens, d)
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    return flat.reshape(-1, g_sz, d), tokens, pad


def apply_moe(p, x, cfg, group_size: int = 0):
    """Returns (out (B,S,d), aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    group_size = group_size or cfg.moe_group_size
    # SP compatibility: when the seq axis is model-sharded, cap the group at
    # the per-shard sequence so the (B,S,d)->(G,Sg,d) reshape never crosses a
    # shard boundary (otherwise GSPMD gathers the full activation + fp32
    # cotangent all-reduces per MoE layer — measured dominant for kimi-k2).
    seq_shards = max(logical_axis_size("seq"), 1)
    if s % seq_shards == 0 and (s // seq_shards) < group_size:
        group_size = s // seq_shards
    xg, tokens, _pad = _group_tokens(x, group_size)
    g, sg, _ = xg.shape
    xg = shard(xg, "tokens", None, "embed")

    logits = (xg @ p["router"]).astype(jnp.float32)          # (G,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                   # (G,S,k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    cap = max(1, int(sg * k / e * cfg.capacity_factor))

    # position-in-expert for each (token, slot): cumulative count of prior
    # assignments to the same expert within the group.
    onehot = jax.nn.one_hot(top_i, e, dtype=jnp.float32)     # (G,S,k,E)
    flat_oh = onehot.reshape(g, sg * k, e)
    pos_in_e = (jnp.cumsum(flat_oh, axis=1) - flat_oh).reshape(g, sg, k, e)
    pos = jnp.sum(pos_in_e * onehot, axis=-1)                # (G,S,k)
    keep = pos < cap
    w = top_w * keep

    # dispatch: (G,S,E,C) one-hot combine of expert id and capacity slot
    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32)     # (G,S,k,C)
    dispatch = jnp.einsum("gske,gskc->gsec", onehot * keep[..., None], pos_oh)
    combine = jnp.einsum("gske,gskc,gsk->gsec", onehot, pos_oh, w)
    # keep the (G,S,E,C) one-hots resident: G on data, E on model — without
    # this GSPMD reshards the full dispatch tensor across the mesh (measured
    # as the dominant collective term for kimi-k2, §Perf-a).
    dispatch = shard(dispatch.astype(xg.dtype), "tokens", None, None, None)
    combine = shard(combine.astype(xg.dtype), "tokens", None, None, None)

    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg)          # (G,E,C,d)
    xe = shard(xe, "batch", "experts", None, "embed")

    glu = cfg.activation in ("swiglu", "geglu")
    if glu:
        gate = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])
        up = jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
        act = jax.nn.silu(gate) if cfg.activation == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", xe, p["w_in"]))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])        # (G,E,C,d)
    ye = shard(ye, "batch", "experts", None, "embed")

    yg = jnp.einsum("gsec,gecd->gsd", combine, ye)           # (G,S,d)
    yg = shard(yg, "tokens", None, "embed")
    out = yg.reshape(-1, d)[:tokens].reshape(b, s, d)

    # Switch-style load-balance aux: E * sum_e f_e * P_e
    frac_tokens = jnp.mean(onehot[..., 0, :], axis=(0, 1))   # top-1 routing frac
    frac_probs = jnp.mean(probs.reshape(-1, e), axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)

    if cfg.n_shared_experts:
        out = out + apply_mlp(p["shared"], x, cfg.activation)
    if cfg.dense_residual:
        out = out + apply_mlp(p["residual"], x, cfg.activation)
    return out, aux
