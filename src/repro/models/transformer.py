"""Unified decoder LM covering every assigned architecture family.

Layer plan: layers are grouped into [head (unrolled)] + [cycles (lax.scan over
stacked params, one cycle = one repetition of cfg.layer_pattern)] + [tail
(unrolled remainder)]. Scan-over-layers keeps the HLO small regardless of
depth; remat wraps the cycle body when cfg.remat.

Modes:
  * train    — full sequence, recurrent states zero-initialized, caches unused.
  * prefill  — full sequence; returns populated KV caches / recurrent states.
  * decode   — one token against caches/states (serve_step).

Encoder-decoder (whisper) and VLM prefix handling live in
repro.models.encdec / the `embeds` argument here respectively.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import rglru as rg
from repro.models import rwkv6 as rw
from repro.models.attention import (
    attention,
    attention_decode,
    attention_prefill,
    cache_logical_axes,
    init_attention,
    init_kv_cache,
)
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    embed,
    init_embedding,
    init_mlp,
    init_norm,
    is_leaf,
    mk,
    sinusoidal_for_positions,
    sinusoidal_positions,
    split_leaves,
    unembed,
)
from repro.models.moe import apply_moe, init_moe
from repro.sharding.rules import shard


# ----------------------------------------------------------------------------
# Layer plan
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerPlan:
    head: tuple          # absolute layer indices, unrolled
    cycle_kinds: tuple   # block kinds within one scanned cycle
    n_cycles: int
    tail: tuple          # absolute layer indices, unrolled


def layer_plan(cfg) -> LayerPlan:
    head = tuple(range(cfg.first_k_dense)) if cfg.family == "moe" else ()
    start = len(head)
    cyc = len(cfg.layer_pattern)
    remaining = cfg.n_layers - start
    n_cycles = remaining // cyc if cfg.scan_layers else 0
    tail_start = start + n_cycles * cyc
    tail = tuple(range(tail_start, cfg.n_layers))
    return LayerPlan(head, cfg.layer_pattern, n_cycles, tail)


def _ffn_kind(cfg, layer_idx: int) -> str:
    if cfg.family == "moe" and layer_idx >= cfg.first_k_dense:
        return "moe"
    return "dense"


# ----------------------------------------------------------------------------
# Per-block init / apply
# ----------------------------------------------------------------------------

def _init_block(key, cfg, kind: str, ffn: str):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.d_model
    p: dict = {"ln1": init_norm(k1, d, cfg.norm)}
    if kind in ("attn", "local"):
        p["attn"] = init_attention(k2, cfg)
    elif kind == "rglru":
        p["rec"] = rg.init_rglru_block(k2, cfg)
    elif kind == "wkv":
        p["tm"] = rw.init_time_mix(k2, cfg)
        p["ln2"] = init_norm(k3, d, cfg.norm)
        p["cm"] = rw.init_channel_mix(k4, cfg)
        return p
    p["ln2"] = init_norm(k3, d, cfg.norm)
    if ffn == "moe":
        p["moe"] = init_moe(k4, cfg)
    else:
        p["mlp"] = init_mlp(k4, d, cfg.d_ff, cfg.activation)
    return p


def _init_block_state(cfg, kind: str, batch: int, mode: str, max_seq: int, dtype):
    if kind in ("attn", "local"):
        if mode == "train":
            return {}
        return {"cache": init_kv_cache(cfg, batch, kind, max_seq, dtype)}
    if kind == "rglru":
        return {"rec": rg.init_rglru_state(cfg, batch, dtype)}
    return {"wkv": rw.init_wkv_state(cfg, batch, dtype)}


def _block_state_axes(cfg, kind: str, mode: str):
    if kind in ("attn", "local"):
        return {} if mode == "train" else {"cache": cache_logical_axes()}
    if kind == "rglru":
        return {"rec": rg.rglru_state_logical_axes()}
    return {"wkv": rw.wkv_state_logical_axes()}


def _apply_block(p, x, cfg, kind: str, ffn: str, *, positions, state, mode, pos):
    """Returns (x, new_state, aux)."""
    aux = jnp.zeros((), jnp.float32)
    xa = apply_norm(p["ln1"], x, cfg.norm)
    if kind in ("attn", "local"):
        if mode == "decode":
            y, cache = attention_decode(p["attn"], xa, state["cache"], cfg,
                                        kind=kind, pos=pos)
            new_state = {"cache": cache}
        elif mode == "prefill":
            cache_len = state["cache"]["k"].shape[1]
            y, cache = attention_prefill(p["attn"], xa, cfg, kind=kind,
                                         positions=positions, cache_len=cache_len)
            new_state = {"cache": cache}
        else:
            y = attention(p["attn"], xa, cfg, kind=kind, positions=positions)
            new_state = {}
        x = x + y
    elif kind == "rglru":
        y, rec = rg.apply_rglru_block(p["rec"], xa, cfg, state["rec"])
        new_state = {"rec": rec}
        x = x + y
    else:  # wkv: carries its own channel-mix as the FFN
        st = state["wkv"]
        impl = None
        if cfg.wkv_impl == "chunked" and xa.shape[1] > 1:
            import functools
            impl = functools.partial(rw.wkv_chunked, chunk=cfg.wkv_chunk)
        y, tm = rw.time_mix(p["tm"], xa, cfg, st["tm"], wkv_impl=impl)
        x = x + y
        xb = apply_norm(p["ln2"], x, cfg.norm)
        y2, cm_shift = rw.channel_mix(p["cm"], xb, cfg, st["cm_shift"])
        x = x + y2
        return x, {"wkv": {"tm": tm, "cm_shift": cm_shift}}, aux

    xb = apply_norm(p["ln2"], x, cfg.norm)
    if ffn == "moe":
        y, aux = apply_moe(p["moe"], xb, cfg)
    else:
        y = apply_mlp(p["mlp"], xb, cfg.activation)
    x = x + y
    x = shard(x, "batch", "seq", "embed")
    return x, new_state, aux


# ----------------------------------------------------------------------------
# Model init
# ----------------------------------------------------------------------------

def padded_vocab(cfg) -> int:
    return -(-cfg.vocab_size // 128) * 128


def _build_leaf_tree(cfg, key):
    if cfg.is_encoder_decoder:
        from repro.models.encdec import build_encdec_leaf_tree
        return build_encdec_leaf_tree(cfg, key)
    plan = layer_plan(cfg)
    keys = jax.random.split(key, 8)
    p: dict = {"embed": init_embedding(keys[0], padded_vocab(cfg), cfg.d_model)}
    p["final_norm"] = init_norm(keys[1], cfg.d_model, cfg.norm)
    if not cfg.tie_embeddings:
        p["unembed"] = {
            "w": mk(keys[2], (cfg.d_model, padded_vocab(cfg)),
                    ("embed_fsdp", "vocab"), std=0.02)
        }
    if cfg.family == "ssm":
        p["ln0"] = init_norm(keys[3], cfg.d_model, cfg.norm)
    if cfg.frontend == "vision":
        # projector stub: identity-shaped linear from frontend embed space
        p["projector"] = {
            "w": mk(keys[4], (cfg.d_model, cfg.d_model), ("embed_fsdp", "embed"),
                    std=0.02)
        }

    hkeys = jax.random.split(keys[5], max(len(plan.head), 1))
    p["head_blocks"] = [
        _init_block(hkeys[i], cfg, cfg.block_kind(li), _ffn_kind(cfg, li))
        for i, li in enumerate(plan.head)
    ]

    if plan.n_cycles:
        ckeys = jax.random.split(keys[6], plan.n_cycles)
        base = len(plan.head)

        def init_cycle(k):
            bk = jax.random.split(k, len(plan.cycle_kinds))
            return [
                _init_block(bk[j], cfg, kind, _ffn_kind(cfg, base + j))
                for j, kind in enumerate(plan.cycle_kinds)
            ]

        stacked = jax.vmap(init_cycle)(ckeys)
        stacked = jax.tree.map(lambda l: l.with_prefix("layers"), stacked,
                               is_leaf=is_leaf)
        p["cycles"] = stacked
    else:
        p["cycles"] = []

    tkeys = jax.random.split(keys[7], max(len(plan.tail), 1))
    p["tail_blocks"] = [
        _init_block(tkeys[i], cfg, cfg.block_kind(li), _ffn_kind(cfg, li))
        for i, li in enumerate(plan.tail)
    ]
    return p


def init_params(cfg, key):
    leafs = _build_leaf_tree(cfg, key)
    params, _ = split_leaves(leafs)
    dtype = jnp.dtype(cfg.param_dtype)
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        params,
    )


def param_logical_axes(cfg):
    leafs = jax.eval_shape(lambda: _build_leaf_tree(cfg, jax.random.key(0)))
    _, axes = split_leaves(leafs)
    return axes


# ----------------------------------------------------------------------------
# Stream state (caches + recurrent states)
# ----------------------------------------------------------------------------

def init_decode_state(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16,
                      mode: str = "decode"):
    plan = layer_plan(cfg)

    def blk(kind):
        return _init_block_state(cfg, kind, batch, mode, max_seq, dtype)

    state = {
        "head": [blk(cfg.block_kind(i)) for i in plan.head],
        "tail": [blk(cfg.block_kind(i)) for i in plan.tail],
    }
    if plan.n_cycles:
        cyc = [blk(k) for k in plan.cycle_kinds]
        state["cycles"] = jax.tree.map(
            lambda leaf: jnp.broadcast_to(leaf, (plan.n_cycles,) + leaf.shape).copy()
            if hasattr(leaf, "shape") else leaf,
            cyc,
        )
    else:
        state["cycles"] = []
    return state


def decode_state_logical_axes(cfg, mode: str = "decode"):
    plan = layer_plan(cfg)

    def blk(kind):
        return _block_state_axes(cfg, kind, mode)

    axes = {
        "head": [blk(cfg.block_kind(i)) for i in plan.head],
        "tail": [blk(cfg.block_kind(i)) for i in plan.tail],
    }
    if plan.n_cycles:
        cyc = [blk(k) for k in plan.cycle_kinds]
        axes["cycles"] = jax.tree.map(
            lambda a: ("layers",) + tuple(a), cyc,
            is_leaf=lambda x: isinstance(x, tuple),
        )
    else:
        axes["cycles"] = []
    return axes


# ----------------------------------------------------------------------------
# Forward
# ----------------------------------------------------------------------------

import functools as _ft


@_ft.lru_cache(maxsize=32)
def _cycle_axes(cfg, base):
    """Logical axes of one cycle's (unstacked) block params."""
    plan = layer_plan(cfg)

    def one():
        k = jax.random.key(0)
        return [
            _init_block(k, cfg, kind, _ffn_kind(cfg, base + j))
            for j, kind in enumerate(plan.cycle_kinds)
        ]

    leafs = jax.eval_shape(one)
    _, axes = split_leaves(leafs)
    return axes


def _gather_cycle_params(cfg, p_c, base):
    """Constrain a sliced cycle's params to their gathered (FSDP axes dropped)
    sharding so the all-gather stays inside the scan loop."""
    from repro.sharding.rules import current_rules, shard as _shard
    if current_rules() is None:
        return p_c
    axes = _cycle_axes(cfg, base)

    leaves, treedef = jax.tree.flatten(p_c)
    axes_leaves = jax.tree.flatten(axes, is_leaf=lambda x: isinstance(x, tuple))[0]
    out = [
        _shard(leaf, *(None if a == "embed_fsdp" else a for a in ax))
        for leaf, ax in zip(leaves, axes_leaves)
    ]
    return jax.tree.unflatten(treedef, out)


def _run_layers(cfg, params, x, *, positions, states, mode, pos):
    plan = layer_plan(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_head, new_tail = [], []

    for i, li in enumerate(plan.head):
        x, st, aux = _apply_block(
            params["head_blocks"][i], x, cfg, cfg.block_kind(li),
            _ffn_kind(cfg, li), positions=positions,
            state=states["head"][i], mode=mode, pos=pos,
        )
        new_head.append(st)
        aux_total += aux

    if plan.n_cycles:
        base = len(plan.head)

        def cycle_body(x_c, inputs):
            p_c, st_c = inputs
            # FSDP: force the weight all-gather of THIS layer slice inside the
            # scan body (otherwise GSPMD hoists a whole-stack fp32 all-gather
            # out of the loop — measured 3 GiB per stacked matrix).
            p_c = _gather_cycle_params(cfg, p_c, base)
            aux_c = jnp.zeros((), jnp.float32)
            new_sts = []
            for j, kind in enumerate(plan.cycle_kinds):
                x_c, st, aux = _apply_block(
                    p_c[j], x_c, cfg, kind, _ffn_kind(cfg, base + j),
                    positions=positions, state=st_c[j], mode=mode, pos=pos,
                )
                new_sts.append(st)
                aux_c += aux
            return x_c, (new_sts, aux_c)

        body = cycle_body
        if cfg.remat and mode == "train":
            body = jax.checkpoint(cycle_body)

        x, (new_cycle_states, aux_c) = jax.lax.scan(
            body, x, (params["cycles"], states["cycles"])
        )
        aux_total += aux_c.sum()
    else:
        new_cycle_states = []

    for i, li in enumerate(plan.tail):
        x, st, aux = _apply_block(
            params["tail_blocks"][i], x, cfg, cfg.block_kind(li),
            _ffn_kind(cfg, li), positions=positions,
            state=states["tail"][i], mode=mode, pos=pos,
        )
        new_tail.append(st)
        aux_total += aux

    new_states = {"head": new_head, "cycles": new_cycle_states, "tail": new_tail}
    return x, new_states, aux_total


def forward(cfg, params, tokens, *, embeds=None, mode="train", states=None,
            pos0: int = 0, unembed_out: bool = True):
    """tokens: (B,S) int32. embeds: optional (B,F,d) prefix (VLM stub).

    Returns (logits (B, S_total, V) — or final hidden states when
    unembed_out=False — plus new_states, aux_loss).
    """
    b, s = tokens.shape
    dtype = jnp.dtype(cfg.compute_dtype)
    x = embed(params["embed"], tokens,
              scale=cfg.d_model**0.5 if cfg.tie_embeddings else None)
    x = x.astype(dtype)
    if embeds is not None:
        prefix = embeds.astype(dtype)
        if "projector" in params:
            prefix = prefix @ params["projector"]["w"].astype(dtype)
        x = jnp.concatenate([prefix, x], axis=1)
    s_total = x.shape[1]
    if cfg.pos_emb == "sinusoidal":
        x = x + sinusoidal_positions(pos0 + s_total, cfg.d_model)[pos0:].astype(dtype)
    if "ln0" in params:
        x = apply_norm(params["ln0"], x, cfg.norm)
    x = shard(x, "batch", "seq", "embed")

    positions = pos0 + jnp.broadcast_to(jnp.arange(s_total), (b, s_total))
    if states is None:
        states = init_decode_state(cfg, b, max_seq=s_total, dtype=dtype, mode=mode)

    x, new_states, aux = _run_layers(
        cfg, params, x, positions=positions, states=states, mode=mode,
        pos=positions[:, -1],
    )
    x = apply_norm(params["final_norm"], x, cfg.norm)
    if not unembed_out:
        return x, new_states, aux
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = x @ params["unembed"]["w"]
    logits = shard(logits, "batch", "seq", "vocab")
    return logits.astype(jnp.float32), new_states, aux


def prefill(cfg, params, tokens, *, embeds=None, cache_len: Optional[int] = None):
    b, s = tokens.shape
    total = s + (embeds.shape[1] if embeds is not None else 0)
    dtype = jnp.dtype(cfg.compute_dtype)
    states = init_decode_state(cfg, b, max_seq=cache_len or total, dtype=dtype,
                               mode="prefill")
    logits, states, _ = forward(cfg, params, tokens, embeds=embeds,
                                mode="prefill", states=states)
    return logits, states


def decode_step(cfg, params, token, states, pos):
    """token: (B,1) int32; pos: (B,) absolute positions. One serve step."""
    b = token.shape[0]
    dtype = jnp.dtype(cfg.compute_dtype)
    x = embed(params["embed"], token,
              scale=cfg.d_model**0.5 if cfg.tie_embeddings else None).astype(dtype)
    if cfg.pos_emb == "sinusoidal":
        x = x + sinusoidal_for_positions(pos[:, None], cfg.d_model).astype(dtype)
    if "ln0" in params:
        x = apply_norm(params["ln0"], x, cfg.norm)

    positions = pos[:, None]
    x, new_states, _ = _run_layers(
        cfg, params, x, positions=positions, states=states, mode="decode", pos=pos,
    )
    x = apply_norm(params["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = x @ params["unembed"]["w"]
    return logits.astype(jnp.float32), new_states


# ----------------------------------------------------------------------------
# Loss
# ----------------------------------------------------------------------------

def lm_loss(cfg, params, batch, *, ce_chunks: Optional[int] = None):
    """Next-token CE. batch: {'tokens': (B,S)} (+ 'patch_embeds' for VLM,
    'frames' for audio enc-dec)."""
    if cfg.is_encoder_decoder:
        from repro.models.encdec import encdec_loss
        return encdec_loss(cfg, params, batch)
    tokens = batch["tokens"]
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    embeds = batch.get("patch_embeds")
    hidden, _, aux = forward(cfg, params, inputs, embeds=embeds, mode="train",
                             unembed_out=False)
    if embeds is not None:
        hidden = hidden[:, embeds.shape[1]:]
    w = (params["embed"]["table"].T if cfg.tie_embeddings
         else params["unembed"]["w"])
    loss = chunked_cross_entropy(hidden, w, targets,
                                 n_chunks=ce_chunks or cfg.ce_chunks)
    if cfg.family == "moe":
        loss = loss + cfg.router_aux_coef * aux
    return loss


def sharded_cross_entropy(logits, targets):
    """CE that stays sharded over a model-parallel vocab axis.

    take_along_axis on a sharded vocab axis would all-gather the logits; the
    logsumexp + one-hot contraction both partition cleanly (the one-hot is a
    fused iota comparison, never materialized at full precision)."""
    logits = shard(logits.astype(jnp.float32), "batch", "seq", "vocab")
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    tgt = jnp.einsum("bsv,bsv->bs", logits, onehot)
    return (lse - tgt).mean()


def chunked_cross_entropy(hidden, w_unembed, targets, n_chunks: int = 0):
    """CE without ever materializing full (B,S,V) logits: scan over batch
    chunks with per-chunk remat, so the backward recomputes each chunk's
    logits instead of saving them (Liger-style, pure JAX).

    n_chunks=0 disables chunking (baseline path for §Perf comparisons)."""
    w_unembed = shard(w_unembed, "embed", "vocab")
    if not n_chunks or hidden.shape[0] % n_chunks:
        logits = hidden @ w_unembed
        return sharded_cross_entropy(logits, targets)
    b = hidden.shape[0]
    hb = hidden.reshape(n_chunks, b // n_chunks, *hidden.shape[1:])
    tb = targets.reshape(n_chunks, b // n_chunks, *targets.shape[1:])

    @jax.checkpoint
    def step(acc, inp):
        h_c, t_c = inp
        logits = shard((h_c @ w_unembed).astype(jnp.float32),
                       "batch", "seq", "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(t_c, logits.shape[-1], dtype=logits.dtype)
        tgt = jnp.einsum("bsv,bsv->bs", logits, onehot)
        return acc + jnp.sum(lse - tgt), None

    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (hb, tb))
    return total / (targets.size)
