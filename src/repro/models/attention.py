"""Attention: full/causal and sliding-window, train + prefill + decode paths.

Three implementations of the same math (tested against each other):
  * einsum  — O(S^2) materialized scores; the oracle for small shapes.
  * chunked — lax.scan over KV chunks with online softmax (flash-style in
              pure JAX); the production default, memory O(S * chunk).
  * Pallas  — repro.kernels.swa_attention, TPU target (interpret-tested).

GQA is handled by repeating KV to the full head count in the S^2 paths (the
repeat is sharded over the 'heads' model axis so per-device memory is
unchanged); the decode path keeps the cache un-repeated (grouped einsum).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, mk
from repro.sharding.rules import logical_axis_size, shard


def _shard_attn_act(t):
    """(B,S,H,hd) activation constraint: prefer head (tensor-parallel) sharding,
    fall back to q-sequence (context-parallel) sharding when the head count
    does not divide the model axis (e.g. 24 heads on a 16-way axis)."""
    if t.shape[2] % max(logical_axis_size("heads"), 1) == 0:
        return shard(t, "batch", None, "heads", "head_dim")
    return shard(t, "batch", "seq", None, None)


def _shard_attn_kv(t):
    """KV stays head-sharded when divisible; otherwise replicated (the
    context-parallel fallback needs full KV per device for the chunk scan)."""
    if t.shape[2] % max(logical_axis_size("heads"), 1) == 0:
        return shard(t, "batch", None, "heads", "head_dim")
    return t

NEG_INF = -1e30


def init_attention(key, cfg, *, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    std = 0.02
    p = {
        "wq": mk(ks[0], (d, h * hd), ("embed_fsdp", "heads"), std=std),
        "wk": mk(ks[1], (d, kv * hd), ("embed_fsdp", "kv_heads"), std=std),
        "wv": mk(ks[2], (d, kv * hd), ("embed_fsdp", "kv_heads"), std=std),
        "wo": mk(ks[3], (h * hd, d), ("heads", "embed_fsdp"), std=std / max(cfg.n_layers, 1) ** 0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = mk(ks[0], (h * hd,), ("heads",), zeros=True)
        p["bk"] = mk(ks[1], (kv * hd,), ("kv_heads",), zeros=True)
        p["bv"] = mk(ks[2], (kv * hd,), ("kv_heads",), zeros=True)
    return p


def _project_qkv(p, x, cfg, positions, *, rope: bool = True):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if rope and cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(k, n_heads):
    b, s, kv, hd = k.shape
    reps = n_heads // kv
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, reps, hd))
    return k.reshape(b, s, n_heads, hd)


def _mask_bias(q_pos, k_pos, *, causal: bool, window: Optional[int]):
    """(.., Sq, Sk) additive bias from position tensors."""
    ok = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]), bool)
    if causal:
        ok &= k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        ok &= k_pos[..., None, :] > q_pos[..., :, None] - window
    return jnp.where(ok, 0.0, NEG_INF)


def attn_einsum(q, k, v, q_pos, k_pos, *, causal=True, window=None):
    """Oracle: q (B,S,H,hd), k/v (B,T,KV,hd); returns (B,S,H,hd)."""
    h = q.shape[2]
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * scale
    scores = scores + _mask_bias(q_pos, k_pos, causal=causal, window=window)[:, None]
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthd->bshd", w, v)


def attn_chunked(q, k, v, q_pos, k_pos, *, causal=True, window=None, chunk=512):
    """Online-softmax over KV chunks: memory O(S*chunk) instead of O(S^2)."""
    b, s, h, hd = q.shape
    t = k.shape[1]
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    if t % chunk:
        pad = chunk - t % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=jnp.iinfo(jnp.int32).max)
        t += pad
    n_chunks = t // chunk
    kc = k.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    scale = hd**-0.5

    def step(carry, inputs):
        m, l, acc = carry
        k_i, v_i, p_i = inputs
        s_i = jnp.einsum("bshd,bthd->bhst", q, k_i).astype(jnp.float32) * scale
        bias = _mask_bias(q_pos, p_i, causal=causal, window=window)[:, None]
        s_i = s_i + bias
        m_new = jnp.maximum(m, s_i.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s_i - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhst,bthd->bhsd", p.astype(q.dtype), v_i
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, s), jnp.float32)
    a0 = jnp.zeros((b, h, s, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


# ----------------------------------------------------------------------------
# Flash attention (pure-JAX, custom VJP): memory O(S * chunk) in fwd AND bwd.
# The naive chunked scan saves per-chunk score tensors for autodiff; this
# recomputes them in the backward pass (standard flash backward), which is
# what makes train_4k/prefill_32k fit HBM.
# Contiguous positions only (q_pos = q_offset + arange, k_pos = arange).
# ----------------------------------------------------------------------------

import functools as _ft


@_ft.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal, window, chunk, q_offset):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, chunk, q_offset)
    return out


def _flash_positions(b, s, t, q_offset):
    qp = q_offset + jnp.arange(s)
    kp = jnp.arange(t)
    return qp, kp


def _flash_chunk_bias(qp, kp_c, causal, window):
    ok = jnp.ones((qp.shape[0], kp_c.shape[0]), bool)
    if causal:
        ok &= kp_c[None, :] <= qp[:, None]
    if window is not None:
        ok &= kp_c[None, :] > qp[:, None] - window
    return jnp.where(ok, 0.0, NEG_INF)


def _flash_fwd_impl(q, k, v, causal, window, chunk, q_offset):
    b, s, h, d = q.shape
    t = k.shape[1]
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qp, kp = _flash_positions(b, s, t + pad, q_offset)
    kp = jnp.where(jnp.arange(t + pad) < t, kp, jnp.iinfo(jnp.int32).max // 2)
    n_chunks = (t + pad) // chunk
    kc = k.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    pc = kp.reshape(n_chunks, chunk)
    scale = d**-0.5

    def step(carry, inp):
        m, l, acc = carry
        k_i, v_i, p_i = inp
        s_i = jnp.einsum("bshd,bthd->bhst", q, k_i).astype(jnp.float32) * scale
        s_i = s_i + _flash_chunk_bias(qp, p_i, causal, window)[None, None]
        m_new = jnp.maximum(m, s_i.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s_i - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhst,bthd->bhsd", p.astype(q.dtype), v_i
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, s), jnp.float32)
    a0 = jnp.zeros((b, h, s, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None]).transpose(0, 2, 1, 3).astype(q.dtype)
    lse = m + jnp.log(l_safe)
    return out, lse


def _flash_fwd(q, k, v, causal, window, chunk, q_offset):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, chunk, q_offset)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, chunk, q_offset, res, dout):
    q, k, v, out, lse = res
    b, s, h, d = q.shape
    t = k.shape[1]
    chunk = min(chunk, t)
    pad = (-t) % chunk
    kq = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
    vq = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v
    qp, kp = _flash_positions(b, s, t + pad, q_offset)
    kp = jnp.where(jnp.arange(t + pad) < t, kp, jnp.iinfo(jnp.int32).max // 2)
    n_chunks = (t + pad) // chunk
    kc = kq.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    vc = vq.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    pc = kp.reshape(n_chunks, chunk)
    scale = d**-0.5

    do32 = dout.astype(jnp.float32)
    delta = jnp.einsum("bshd,bshd->bhs", do32, out.astype(jnp.float32))

    def step(dq, inp):
        k_i, v_i, p_i = inp
        s_i = jnp.einsum("bshd,bthd->bhst", q, k_i).astype(jnp.float32) * scale
        s_i = s_i + _flash_chunk_bias(qp, p_i, causal, window)[None, None]
        p = jnp.exp(s_i - lse[..., None])                       # (b,h,s,c)
        dv_i = jnp.einsum("bhst,bshd->bthd", p, do32)
        dp = jnp.einsum("bshd,bthd->bhst", do32, v_i.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bhst,bthd->bshd", ds, k_i.astype(jnp.float32))
        dk_i = jnp.einsum("bhst,bshd->bthd", ds, q.astype(jnp.float32))
        return dq, (dk_i, dv_i)

    dq0 = jnp.zeros((b, s, h, d), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(step, dq0, (kc, vc, pc))
    dk = dk_c.transpose(1, 0, 2, 3, 4).reshape(b, t + pad, h, d)[:, :t]
    dv = dv_c.transpose(1, 0, 2, 3, 4).reshape(b, t + pad, h, d)[:, :t]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def attention(p, x, cfg, *, kind: str, positions, impl: Optional[str] = None):
    """Full-sequence causal attention (train / prefill). x: (B,S,d)."""
    b, s, _ = x.shape
    window = cfg.sliding_window if kind == "local" else None
    q, k, v = _project_qkv(p, x, cfg, positions)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    impl = impl or cfg.attn_impl
    o = _attn_dispatch(q, k, v, positions, window, impl, cfg)
    o = o.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return o @ p["wo"]


def _attn_dispatch(q, k, v, positions, window, impl, cfg):
    if impl == "einsum":
        return attn_einsum(q, k, v, positions, positions, causal=True, window=window)
    if impl == "chunked":
        return attn_chunked(q, k, v, positions, positions, causal=True,
                            window=window, chunk=cfg.attn_chunk)
    # flash (default): contiguous positions starting at 0
    kf = _repeat_kv(k, q.shape[2])
    vf = _repeat_kv(v, q.shape[2])
    q = _shard_attn_act(q)
    kf = _shard_attn_kv(kf)
    vf = _shard_attn_kv(vf)
    return _shard_attn_act(flash_attention(q, kf, vf, True, window, cfg.attn_chunk, 0))


def build_cache(k, v, positions, cache_len):
    """Arrange full-sequence K/V (B,S,KV,hd) into a ring-buffer cache of
    length W=cache_len where token at position p lives at slot p % W."""
    b, s, kv, hd = k.shape
    w = cache_len
    if w >= s:
        pad = ((0, 0), (0, w - s), (0, 0), (0, 0))
        return {
            "k": jnp.pad(k, pad),
            "v": jnp.pad(v, pad),
            "pos": jnp.pad(positions, ((0, 0), (0, w - s)), constant_values=-1),
        }
    k_t, v_t, p_t = k[:, -w:], v[:, -w:], positions[:, -w:]
    slots = p_t % w                                       # (B, W)
    def scatter(buf_last, slot_row):
        out = jnp.zeros_like(buf_last)
        return out.at[slot_row].set(buf_last)
    return {
        "k": jax.vmap(scatter)(k_t, slots),
        "v": jax.vmap(scatter)(v_t, slots),
        "pos": jax.vmap(lambda pr, sr: jnp.full_like(pr, -1).at[sr].set(pr))(p_t, slots),
    }


def attention_prefill(p, x, cfg, *, kind: str, positions, cache_len: int,
                      impl: Optional[str] = None):
    """Full-sequence attention that also returns the populated KV cache."""
    b, s, _ = x.shape
    window = cfg.sliding_window if kind == "local" else None
    q, k, v = _project_qkv(p, x, cfg, positions)
    impl = impl or cfg.attn_impl
    o = _attn_dispatch(q, k, v, positions, window, impl, cfg)
    o = o.reshape(b, s, cfg.n_heads * cfg.head_dim) @ p["wo"]
    w = min(window, cache_len) if window else cache_len
    cache = build_cache(k, v, positions, w)
    return o, cache


# ----------------------------------------------------------------------------
# Decode path with (optionally ring-buffered) KV cache
# ----------------------------------------------------------------------------

def init_kv_cache(cfg, batch, kind: str, max_seq: int, dtype):
    window = cfg.sliding_window if kind == "local" else None
    w = min(window, max_seq) if window else max_seq
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, w, kv, hd), dtype),
        "v": jnp.zeros((batch, w, kv, hd), dtype),
        "pos": jnp.full((batch, w), -1, jnp.int32),
    }


def cache_logical_axes():
    return {
        "k": ("batch", "window", "kv_heads", "head_dim"),
        "v": ("batch", "window", "kv_heads", "head_dim"),
        "pos": ("batch", "window"),
    }


def write_cache(cache, k_new, v_new, pos, impl: str = "onehot"):
    """k_new/v_new: (B,KV,hd); pos: (B,) absolute position. Ring-buffer write.

    impl='onehot' (default) writes via arithmetic masking
    cache*(1-onehot)+new*onehot, which partitions cleanly when the window
    axis is model-sharded (a scatter on a sharded axis makes GSPMD gather
    the whole cache — measured as the decode-peak dominator, §Perf-b).
    impl='scatter' keeps the dynamic_update_slice baseline for comparison.
    """
    w = cache["k"].shape[1]
    slot = pos % w

    if impl == "onehot":
        oh = jax.nn.one_hot(slot, w, dtype=cache["k"].dtype)      # (B, W)
        ohk = oh[:, :, None, None]

        def upd(buf, new):
            return buf * (1 - ohk) + new[:, None] * ohk

        pos_upd = jnp.where(oh > 0, pos[:, None], cache["pos"]).astype(
            cache["pos"].dtype)
        return {
            "k": upd(cache["k"], k_new),
            "v": upd(cache["v"], v_new),
            "pos": pos_upd,
        }

    def upd(buf, new):
        return jax.vmap(lambda b_row, n, s_: jax.lax.dynamic_update_slice(
            b_row, n[None], (s_,) + (0,) * (b_row.ndim - 1)
        ))(buf, new, slot)

    return {
        "k": upd(cache["k"], k_new),
        "v": upd(cache["v"], v_new),
        "pos": jax.vmap(lambda r, s_, p_: r.at[s_].set(p_))(cache["pos"], slot, pos),
    }


def attention_decode(p, x, cache, cfg, *, kind: str, pos):
    """One-token decode. x: (B,1,d); pos: (B,) absolute position of the new token."""
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kv
    window = cfg.sliding_window if kind == "local" else None

    q, k, v = _project_qkv(p, x, cfg, pos[:, None])
    cache = write_cache(cache, k[:, 0], v[:, 0], pos, impl=cfg.cache_update)

    qh = q[:, 0].reshape(b, kv, g, hd)
    scale = hd**-0.5
    scores = jnp.einsum("bngh,btnh->bngt", qh, cache["k"]).astype(jnp.float32) * scale
    kp = cache["pos"]
    ok = (kp >= 0) & (kp <= pos[:, None])
    if window is not None:
        ok &= kp > (pos[:, None] - window)
    scores = scores + jnp.where(ok, 0.0, NEG_INF)[:, None, None, :]
    wgt = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = jnp.einsum("bngt,btnh->bngh", wgt, cache["v"]).reshape(b, 1, h * hd)
    return o @ p["wo"], cache


# ----------------------------------------------------------------------------
# Cross-attention (whisper decoder); KV precomputed from encoder output
# ----------------------------------------------------------------------------

def cross_kv(p, enc_out, cfg):
    b, t, _ = enc_out.shape
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    k = enc_out @ p["wk"]
    v = enc_out @ p["wv"]
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    return k.reshape(b, t, kv, hd), v.reshape(b, t, kv, hd)


def cross_attention(p, x, k, v, cfg):
    """x: (B,S,d) queries; k/v: (B,T,KV,hd) precomputed from encoder."""
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(b, s, h, hd)
    t = k.shape[1]
    zeros = jnp.zeros((b, s), jnp.int32)
    o = attn_einsum(
        q, k, v,
        q_pos=zeros, k_pos=jnp.zeros((b, t), jnp.int32),
        causal=False, window=None,
    ) if s * t <= 1 << 22 else attn_chunked(
        q, k, v, q_pos=zeros, k_pos=jnp.zeros((b, t), jnp.int32),
        causal=False, window=None, chunk=cfg.attn_chunk,
    )
    return o.reshape(b, s, h * hd) @ p["wo"]
