"""Primitive layers shared by every architecture (pure JAX, no flax).

Param convention: nested dicts of Leaf(value, axes) during init; split into
(params, axes) trees by `split_leaves`. `axes` are logical axis names consumed
by repro.sharding.rules.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding.rules import shard


@jax.tree_util.register_pytree_node_class
class Leaf:
    """A parameter leaf carrying static logical axes (pytree aux data), so
    Leaf trees survive jax.eval_shape / vmap while axes stay metadata."""

    __slots__ = ("value", "axes")

    def __init__(self, value, axes):
        self.value = value
        self.axes = tuple(axes)

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)

    def with_prefix(self, name):
        return Leaf(self.value, (name,) + self.axes)


def mk(key, shape, axes, std: float = 0.02, dtype=jnp.float32, zeros=False, ones=False):
    if ones:
        v = jnp.ones(shape, dtype)
    elif zeros:
        v = jnp.zeros(shape, dtype)
    else:
        v = std * jax.random.normal(key, shape, dtype)
    return Leaf(v, tuple(axes))


def is_leaf(x):
    return isinstance(x, Leaf)


def split_leaves(tree):
    params = jax.tree.map(lambda l: l.value, tree, is_leaf=is_leaf)
    axes = jax.tree.map(lambda l: l.axes, tree, is_leaf=is_leaf)
    return params, axes


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )


# ----------------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------------

def rmsnorm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def init_norm(key, d, kind: str):
    if kind == "rmsnorm":
        return {"scale": mk(key, (d,), ("embed",), zeros=True)}
    return {
        "scale": mk(key, (d,), ("embed",), ones=True),
        "bias": mk(key, (d,), ("embed",), zeros=True),
    }


def apply_norm(p, x, kind: str):
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


# ----------------------------------------------------------------------------
# Rotary embeddings
# ----------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd) or (..., S, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                     # (hd/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, hd/2)
    if x.ndim == angles.ndim + 1:                           # head axis present
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos: int, d: int):
    return sinusoidal_for_positions(jnp.arange(n_pos), d)


def sinusoidal_for_positions(pos, d: int):
    """pos: any int array; returns (..., d) sinusoidal embeddings."""
    pos = pos.astype(jnp.float32)[..., None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    angle = pos / jnp.power(10000.0, 2.0 * dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# ----------------------------------------------------------------------------
# MLP
# ----------------------------------------------------------------------------

def init_mlp(key, d, d_ff, activation: str):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_down": mk(k2, (d_ff, d), ("ff", "embed_fsdp"), std=0.02 / max(1, d_ff) ** 0.5)}
    if activation in ("swiglu", "geglu"):
        p["w_gate"] = mk(k1, (d, d_ff), ("embed_fsdp", "ff"))
        p["w_up"] = mk(k3, (d, d_ff), ("embed_fsdp", "ff"))
    else:
        p["w_in"] = mk(k1, (d, d_ff), ("embed_fsdp", "ff"))
    return p


def apply_mlp(p, x, activation: str):
    if activation in ("swiglu", "geglu"):
        gate = x @ p["w_gate"]
        up = x @ p["w_up"]
        act = jax.nn.silu(gate) if activation == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    else:
        h = jax.nn.gelu(x @ p["w_in"])
    h = shard(h, "batch", "seq", "ff")
    return h @ p["w_down"]


# ----------------------------------------------------------------------------
# Embedding / unembedding
# ----------------------------------------------------------------------------

def init_embedding(key, vocab, d):
    return {"table": mk(key, (vocab, d), ("vocab", "embed_fsdp"), std=0.02)}


def embed(p, tokens, scale: Optional[float] = None):
    out = jnp.take(p["table"], tokens, axis=0)
    if scale is not None:
        out = out * scale
    return out


def unembed(p, x):
    return x @ p["table"].T
