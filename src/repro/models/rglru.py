"""Griffin recurrent block [arXiv:2402.19427]: conv1d + RG-LRU (RecurrentGemma).

Block: x -> (gate branch: Linear+GeLU) * (rec branch: Linear -> temporal
Conv1D(width 4) -> RG-LRU) -> Linear out.

RG-LRU: r_t = sigmoid(W_a x_t + b_a)        (recurrence gate)
        i_t = sigmoid(W_x x_t + b_x)        (input gate)
        a_t = exp(-c * softplus(Lambda) * r_t)          c = 8
        h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import mk
from repro.sharding.rules import shard

_C = 8.0


def init_rglru_block(key, cfg):
    d, w = cfg.d_model, cfg.lru_dim
    ks = jax.random.split(key, 8)
    return {
        "w_gate": mk(ks[0], (d, w), ("embed_fsdp", "lru"), std=0.02),
        "w_rec_in": mk(ks[1], (d, w), ("embed_fsdp", "lru"), std=0.02),
        "conv_w": mk(ks[2], (cfg.conv_width, w), (None, "lru"), std=0.2),
        "conv_b": mk(ks[2], (w,), ("lru",), zeros=True),
        "wa": mk(ks[3], (w, w), ("lru", None), std=0.02),
        "ba": mk(ks[3], (w,), ("lru",), zeros=True),
        "wx": mk(ks[4], (w, w), ("lru", None), std=0.02),
        "bx": mk(ks[4], (w,), ("lru",), zeros=True),
        "lam": mk(ks[5], (w,), ("lru",), std=0.5),
        "w_out": mk(ks[6], (w, d), ("lru", "embed_fsdp"),
                    std=0.02 / max(cfg.n_layers, 1) ** 0.5),
    }


def _causal_conv1d(x, w, b, conv_state):
    """x: (B,S,W); w: (K,W); conv_state: (B,K-1,W) trailing inputs of prev call."""
    k = w.shape[0]
    xp = jnp.concatenate([conv_state, x], axis=1)          # (B, S+K-1, W)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, x.shape[1] :][:, -(k - 1):] if k > 1 else conv_state
    return out + b, new_state


def rglru_scan(a_log, gate_in, h0):
    """h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t * x_t) via associative scan.

    a_log (log a_t, <=0), gate_in = i_t * x_t: (B,S,W); h0: (B,W).
    Uses the linear-recurrence associative combine for O(log S) depth.
    """
    a = jnp.exp(a_log)
    inp = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * a_log), 1e-12, 1.0)) * gate_in
    # incorporate h0 into the first input
    inp = inp.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_c, h = jax.lax.associative_scan(combine, (a, inp), axis=1)
    return h, h[:, -1]


def apply_rglru_block(p, x, cfg, state):
    """x: (B,S,d); state: {'h': (B,W), 'conv': (B,K-1,W)}."""
    gate = jax.nn.gelu(x @ p["w_gate"])
    rec = x @ p["w_rec_in"]
    rec = shard(rec, "batch", "seq", "lru")
    rec, conv_state = _causal_conv1d(rec, p["conv_w"], p["conv_b"], state["conv"])

    r = jax.nn.sigmoid(rec @ p["wa"] + p["ba"])
    i = jax.nn.sigmoid(rec @ p["wx"] + p["bx"])
    a_log = -_C * jax.nn.softplus(p["lam"]) * r            # log a_t <= 0
    h, h_last = rglru_scan(
        a_log.astype(jnp.float32),
        (i * rec).astype(jnp.float32),
        state["h"],
    )
    out = (gate * h.astype(x.dtype)) @ p["w_out"]
    return out, {"h": h_last, "conv": conv_state}


def init_rglru_state(cfg, batch, dtype=jnp.float32):
    w = cfg.lru_dim
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    }


def rglru_state_logical_axes():
    return {"h": ("batch", "lru"), "conv": ("batch", None, "lru")}
