"""Whisper-style encoder-decoder (audio) [arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is a STUB per the assignment
carve-out: input_specs() provides frame embeddings (B, n_frames, d) directly.
Encoder: bidirectional self-attention blocks. Decoder: causal self-attention +
cross-attention + MLP. Decode path: self-attn KV cache (ring) + cross K/V
precomputed once from the encoder output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import (
    attention_decode,
    cache_logical_axes,
    cross_attention,
    cross_kv,
    flash_attention,
    init_attention,
    init_kv_cache,
    attention,
    attention_prefill,
    _repeat_kv,
)


def cross_attention_flash(p, x, k, v, cfg):
    """Cross-attention via the custom-VJP flash path (train mode)."""
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(b, s, h, hd)
    kf = _repeat_kv(k, h)
    vf = _repeat_kv(v, h)
    o = flash_attention(q, kf, vf, False, None, cfg.attn_chunk, 0)
    return o.reshape(b, s, h * hd) @ p["wo"]
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    embed,
    init_embedding,
    init_mlp,
    init_norm,
    is_leaf,
    sinusoidal_for_positions,
)
from repro.sharding.rules import shard


def _enc_block_init(key, cfg):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "ln1": init_norm(k1, cfg.d_model, cfg.norm),
        "attn": init_attention(k2, cfg),
        "ln2": init_norm(k3, cfg.d_model, cfg.norm),
        "mlp": init_mlp(k4, cfg.d_model, cfg.d_ff, cfg.activation),
    }


def _dec_block_init(key, cfg):
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "ln1": init_norm(k1, cfg.d_model, cfg.norm),
        "attn": init_attention(k2, cfg),
        "ln_x": init_norm(k3, cfg.d_model, cfg.norm),
        "xattn": init_attention(k4, cfg, cross=True),
        "ln2": init_norm(k5, cfg.d_model, cfg.norm),
        "mlp": init_mlp(k6, cfg.d_model, cfg.d_ff, cfg.activation),
    }


def build_encdec_leaf_tree(cfg, key):
    ks = jax.random.split(key, 5)
    ek = jax.random.split(ks[0], cfg.n_encoder_layers)
    dk = jax.random.split(ks[1], cfg.n_layers)
    enc = jax.vmap(lambda k: _enc_block_init(k, cfg))(ek)
    dec = jax.vmap(lambda k: _dec_block_init(k, cfg))(dk)
    enc = jax.tree.map(lambda l: l.with_prefix("layers"), enc, is_leaf=is_leaf)
    dec = jax.tree.map(lambda l: l.with_prefix("layers"), dec, is_leaf=is_leaf)
    from repro.models.transformer import padded_vocab  # local to avoid cycle
    return {
        "embed": init_embedding(ks[2], padded_vocab(cfg), cfg.d_model),
        "enc_blocks": enc,
        "enc_norm": init_norm(ks[3], cfg.d_model, cfg.norm),
        "dec_blocks": dec,
        "final_norm": init_norm(ks[4], cfg.d_model, cfg.norm),
    }


def encode(cfg, params, frames):
    """frames: (B, F, d) stub embeddings -> encoder output (B, F, d)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    x = frames.astype(dtype)
    x = x + sinusoidal_for_positions(jnp.arange(x.shape[1]), cfg.d_model).astype(dtype)
    x = shard(x, "batch", "seq", "embed")
    b, f, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(f), (b, f))

    def body(x_c, p_c):
        xa = apply_norm(p_c["ln1"], x_c, cfg.norm)
        from repro.models.attention import attn_einsum, _project_qkv
        q, k, v = _project_qkv(p_c["attn"], xa, cfg, positions, rope=False)
        if cfg.attn_impl == "einsum":
            o = attn_einsum(q, k, v, positions, positions, causal=False, window=None)
        else:
            kf, vf = _repeat_kv(k, cfg.n_heads), _repeat_kv(v, cfg.n_heads)
            o = flash_attention(q, kf, vf, False, None, cfg.attn_chunk, 0)
        o = o.reshape(b, f, cfg.n_heads * cfg.head_dim) @ p_c["attn"]["wo"]
        x_c = x_c + o
        xb = apply_norm(p_c["ln2"], x_c, cfg.norm)
        x_c = x_c + apply_mlp(p_c["mlp"], xb, cfg.activation)
        return shard(x_c, "batch", "seq", "embed"), None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(fn, x, params["enc_blocks"])
    return apply_norm(params["enc_norm"], x, cfg.norm)


def _decoder_layers(cfg, params, x, positions, *, enc_out=None, cross_caches=None,
                    states=None, mode="train", pos=None, cache_len=None):
    """Shared decoder stack. cross_caches: per-layer (k,v) when decoding."""
    b = x.shape[0]

    def body(carry, inputs):
        x_c = carry
        if mode == "decode":
            p_c, st_c, xkv = inputs
        else:
            p_c = inputs
        xa = apply_norm(p_c["ln1"], x_c, cfg.norm)
        if mode == "decode":
            y, cache = attention_decode(p_c["attn"], xa, st_c["cache"], cfg,
                                        kind="attn", pos=pos)
            new_st = {"cache": cache}
        elif mode == "prefill":
            y, cache = attention_prefill(p_c["attn"], xa, cfg, kind="attn",
                                         positions=positions,
                                         cache_len=cache_len or x.shape[1])
            new_st = {"cache": cache}
        else:
            y = attention(p_c["attn"], xa, cfg, kind="attn", positions=positions)
            new_st = {}
        x_c = x_c + y

        xx = apply_norm(p_c["ln_x"], x_c, cfg.norm)
        if mode == "decode":
            xk, xv = xkv
        else:
            xk, xv = cross_kv(p_c["xattn"], enc_out, cfg)
            if mode == "prefill":
                new_st["cross"] = {"k": xk, "v": xv}
        if mode == "train":
            y = cross_attention_flash(p_c["xattn"], xx, xk, xv, cfg)
        else:
            y = cross_attention(p_c["xattn"], xx, xk, xv, cfg)
        x_c = x_c + y
        x_c = shard(x_c, "batch", "seq", "embed")

        xb = apply_norm(p_c["ln2"], x_c, cfg.norm)
        x_c = x_c + apply_mlp(p_c["mlp"], xb, cfg.activation)
        return x_c, new_st

    if mode == "decode":
        x, new_states = jax.lax.scan(
            body, x, (params["dec_blocks"], states, cross_caches)
        )
    else:
        fn = jax.checkpoint(body) if (cfg.remat and mode == "train") else body
        x, new_states = jax.lax.scan(fn, x, params["dec_blocks"])
    return apply_norm(params["final_norm"], x, cfg.norm), new_states


def encdec_forward(cfg, params, tokens, frames, mode="train", cache_len=None,
                   unembed_out: bool = True):
    """Teacher-forced decoder over full token sequence. Returns (logits, states)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    enc_out = encode(cfg, params, frames)
    b, s = tokens.shape
    x = embed(params["embed"], tokens).astype(dtype)
    x = x + sinusoidal_for_positions(jnp.arange(s), cfg.d_model).astype(dtype)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x, states = _decoder_layers(cfg, params, x, positions, enc_out=enc_out,
                                mode=mode, cache_len=cache_len)
    if not unembed_out:
        return x, states
    logits = x @ params["embed"]["table"].T
    return logits.astype(jnp.float32), states


def encdec_loss(cfg, params, batch):
    from repro.models.transformer import chunked_cross_entropy
    tokens, frames = batch["tokens"], batch["frames"]
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    hidden, _ = encdec_forward(cfg, params, inputs, frames, unembed_out=False)
    return chunked_cross_entropy(hidden, params["embed"]["table"].T, targets,
                                 n_chunks=cfg.ce_chunks)


def init_encdec_decode_state(cfg, batch, max_seq, n_frames, dtype=jnp.bfloat16):
    """Per-layer: self-attn ring cache + precomputed cross K/V."""
    n, kv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    cache = init_kv_cache(cfg, batch, "attn", max_seq, dtype)
    return {
        "self": jax.tree.map(
            lambda l: jnp.broadcast_to(l, (n,) + l.shape).copy(), cache
        ),
        "cross_k": jnp.zeros((n, batch, n_frames, kv, hd), dtype),
        "cross_v": jnp.zeros((n, batch, n_frames, kv, hd), dtype),
    }


def encdec_state_logical_axes(cfg):
    c = cache_logical_axes()
    return {
        "self": jax.tree.map(lambda a: ("layers",) + tuple(a), c,
                             is_leaf=lambda x: isinstance(x, tuple)),
        "cross_k": ("layers", "batch", None, "kv_heads", "head_dim"),
        "cross_v": ("layers", "batch", None, "kv_heads", "head_dim"),
    }


def encdec_decode_step(cfg, params, token, state, pos):
    """token: (B,1); state from init_encdec_decode_state; pos: (B,)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    x = embed(params["embed"], token).astype(dtype)
    x = x + sinusoidal_for_positions(pos[:, None], cfg.d_model).astype(dtype)
    positions = pos[:, None]
    x, new_self = _decoder_layers(
        cfg, params, x, positions, mode="decode",
        states={"cache": state["self"]},
        cross_caches=(state["cross_k"], state["cross_v"]),
        pos=pos,
    )
    logits = x @ params["embed"]["table"].T
    new_state = dict(state)
    new_state["self"] = new_self["cache"]
    return logits.astype(jnp.float32), new_state
