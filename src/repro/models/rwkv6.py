"""RWKV6 'Finch' block [arXiv:2404.05892]: data-dependent decay WKV recurrence.

Time-mix with data-dependent token-shift interpolation (ddlerp, low-rank),
per-channel data-dependent decay w_t = exp(-exp(w0 + lora(x))), bonus u, and
the WKV state recurrence S_t = diag(w_t) S_{t-1} + k_t v_t^T per head.
Channel-mix is the standard RWKV squared-ReLU FFN with token shift.

Exposed as pre-norm sub-blocks (`time_mix`, `channel_mix`) composed by
repro.models.transformer with the usual residuals:
    x += time_mix(ln1(x));  x += channel_mix(ln2(x)).
Token shift operates on the *normed* streams; the shift carries store the
last normed token of each stream.

The sequence path is a lax.scan (reference); the TPU hot path is the chunked
Pallas kernel in repro.kernels.wkv6 (same math, tested allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import mk
from repro.sharding.rules import shard


def init_time_mix(key, cfg):
    d, r = cfg.d_model, cfg.decay_lora_rank
    h = d // cfg.wkv_head_dim
    ks = jax.random.split(key, 16)
    return {
        "mu_x": mk(ks[0], (d,), ("embed",), std=0.2),
        "mu_r": mk(ks[1], (d,), ("embed",), std=0.2),
        "mu_k": mk(ks[2], (d,), ("embed",), std=0.2),
        "mu_v": mk(ks[3], (d,), ("embed",), std=0.2),
        "mu_w": mk(ks[4], (d,), ("embed",), std=0.2),
        "mu_g": mk(ks[5], (d,), ("embed",), std=0.2),
        "lora_a": mk(ks[6], (d, r), ("embed_fsdp", None), std=0.01),
        "lora_w": mk(ks[7], (r, d), (None, "embed_fsdp"), std=0.01),
        "w0": mk(ks[8], (d,), ("embed",), std=0.5),
        "u": mk(ks[9], (h, cfg.wkv_head_dim), ("heads", "head_dim"), std=0.5),
        "wr": mk(ks[10], (d, d), ("embed_fsdp", "heads"), std=0.02),
        "wk": mk(ks[11], (d, d), ("embed_fsdp", "heads"), std=0.02),
        "wv": mk(ks[12], (d, d), ("embed_fsdp", "heads"), std=0.02),
        "wg": mk(ks[13], (d, d), ("embed_fsdp", "heads"), std=0.02),
        "wo": mk(ks[14], (d, d), ("heads", "embed_fsdp"),
                 std=0.02 / max(cfg.n_layers, 1) ** 0.5),
        "gn_scale": mk(ks[15], (d,), ("embed",), ones=True),
        "gn_bias": mk(ks[15], (d,), ("embed",), zeros=True),
    }


def init_channel_mix(key, cfg):
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    return {
        "mu_k": mk(ks[0], (d,), ("embed",), std=0.2),
        "mu_r": mk(ks[1], (d,), ("embed",), std=0.2),
        "wk": mk(ks[2], (d, cfg.d_ff), ("embed_fsdp", "ff"), std=0.02),
        "wv": mk(ks[3], (cfg.d_ff, d), ("ff", "embed_fsdp"),
                 std=0.02 / max(cfg.d_ff, 1) ** 0.5),
        "wr": mk(ks[4], (d, d), ("embed_fsdp", "heads"), std=0.02),
    }


def wkv_scan(r, k, v, w, u, state):
    """Reference WKV recurrence.

    r,k,v,w: (B,S,H,hd); u: (H,hd); state: (B,H,hd,hd) keyed [key_dim, val_dim].
    Returns (y (B,S,H,hd), final_state).
    """
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]            # (B,H,hd,hd)
        y = jnp.einsum("bhi,bhij->bhj", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, y

    seq = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, seq)
    return jnp.moveaxis(ys, 0, 1), state


def wkv_chunked(r, k, v, w, u, state, chunk: int = 64):
    """Chunked matmul-form WKV — the TPU-native formulation (§Perf).

    The naive scan updates the (B,H,D,D) state per token: S reads+writes
    stream through HBM every step (measured: the worst memory term in the
    whole roofline table). Chunking keeps the recurrence at chunk granularity
    (T/C scan steps) and turns intra-chunk work into MXU matmuls:

      P_t   = prod_{s<=t} w_s                  (cumulative decay, per key dim)
      inter = (r_t . P_t) @ S_0
      intra = ((R~ K~^T) . strict_lower) @ V,  R~ = r.P,  K~ = k/P
      bonus = (sum_i r_i u_i k_i) * v_t
      S_C   = diag(P_C) S_0 + ((K . P_C/P)^T) @ V

    Same math as wkv_scan (tested allclose); P is computed in log space and
    the chunk length bounds the dynamic range.
    """
    b, t, h, d = r.shape
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        r, k, v = (jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))) for x in (r, k, v))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    n = (t + pad) // chunk

    def resh(x):  # (B,T,H,D) -> (n, B, H, C, D)
        return x.reshape(b, n, chunk, h, d).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(w)
    logw = jnp.log(jnp.clip(wc, 1e-30, 1.0))
    logp = jnp.cumsum(logw, axis=3)                      # (n,B,H,C,D)
    p = jnp.exp(logp)
    p_last = p[..., -1:, :]                              # (n,B,H,1,D)
    mask = jnp.tril(jnp.ones((chunk, chunk)), -1)        # strict lower

    # y_t reads the state BEFORE w_t is applied, so its decay factor is the
    # EXCLUSIVE cumulative product P_{t-1} (= P_t / w_t).
    rdec = rc * jnp.exp(logp - logw)                     # r~ = r . P_{t-1}
    k_div = kc * jnp.exp(-logp)                          # k / P_s
    k_rem = kc * jnp.exp(logp[..., -1:, :] - logp)       # k . P_C/P_s

    def chunk_step(s, inp):
        r_i, rdec_i, kdiv_i, krem_i, v_i, k_i, plast_i = inp
        inter = jnp.einsum("bhcd,bhde->bhce", rdec_i, s)
        scores = jnp.einsum("bhcd,bhed->bhce", rdec_i, kdiv_i) * mask
        intra = jnp.einsum("bhce,bhed->bhcd", scores, v_i)
        bonus = jnp.einsum("bhcd,bhcd->bhc", r_i * u[None, :, None, :], k_i)
        y = inter + intra + bonus[..., None] * v_i
        s = plast_i[:, :, 0, :, None] * s + jnp.einsum(
            "bhcd,bhce->bhde", krem_i, v_i)
        return s, y

    s, ys = jax.lax.scan(
        chunk_step, state, (rc, rdec, k_div, k_rem, vc, kc, p_last))
    # ys: (n, B, H, C, D) -> (B, T, H, D)
    y = jnp.moveaxis(ys, 0, 1).transpose(0, 1, 3, 2, 4).reshape(b, t + pad, h, d)
    return y[:, :t], s


def _token_shift(x, prev):
    """Returns x_{t-1} sequence given x (B,S,d) and carry-in prev (B,d)."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def time_mix(p, xa, cfg, state, wkv_impl=None):
    """xa: normed input (B,S,d); state: {'shift': (B,d), 'wkv': (B,H,hd,hd)}."""
    b, s, d = xa.shape
    h, hd = d // cfg.wkv_head_dim, cfg.wkv_head_dim

    prev = _token_shift(xa, state["shift"])
    xx = prev - xa
    z = xa + xx * p["mu_x"]
    dd = jnp.tanh(z @ p["lora_a"]) @ p["lora_w"]             # (B,S,d)

    def ddlerp(mu):
        return xa + xx * (mu + dd)

    r = (ddlerp(p["mu_r"]) @ p["wr"]).reshape(b, s, h, hd)
    k = (ddlerp(p["mu_k"]) @ p["wk"]).reshape(b, s, h, hd)
    v = (ddlerp(p["mu_v"]) @ p["wv"]).reshape(b, s, h, hd)
    g = jax.nn.silu(ddlerp(p["mu_g"]) @ p["wg"])
    w_log = -jnp.exp(
        (p["w0"] + jnp.tanh(ddlerp(p["mu_w"]) @ p["lora_a"]) @ p["lora_w"])
        .astype(jnp.float32)
    )
    w = jnp.exp(w_log).reshape(b, s, h, hd)                  # decay in (0,1)

    r, k, v = (shard(t, "batch", "seq", "heads", "head_dim") for t in (r, k, v))
    scan_fn = wkv_impl or wkv_scan
    y, wkv_state = scan_fn(
        r.astype(jnp.float32), k.astype(jnp.float32),
        v.astype(jnp.float32), w.astype(jnp.float32),
        p["u"].astype(jnp.float32), state["wkv"],
    )
    # per-head group norm
    mu = y.mean(-1, keepdims=True)
    var = ((y - mu) ** 2).mean(-1, keepdims=True)
    y = ((y - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(b, s, d)
    y = y * p["gn_scale"] + p["gn_bias"]
    y = (y.astype(xa.dtype) * g) @ p["wo"]
    return y, {"shift": xa[:, -1], "wkv": wkv_state}


def channel_mix(p, xb, cfg, shift):
    """xb: normed input (B,S,d); shift: (B,d) carry. Returns (y, new_shift)."""
    prev = _token_shift(xb, shift)
    xx = prev - xb
    xk = xb + xx * p["mu_k"]
    xr = xb + xx * p["mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["wk"]))
    kk = shard(kk, "batch", "seq", "ff")
    y = jax.nn.sigmoid(xr @ p["wr"]) * (kk @ p["wv"])
    return y, xb[:, -1]


def init_wkv_state(cfg, batch, dtype=jnp.float32):
    d = cfg.d_model
    h, hd = d // cfg.wkv_head_dim, cfg.wkv_head_dim
    return {
        "tm": {
            "shift": jnp.zeros((batch, d), dtype),
            "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
        },
        "cm_shift": jnp.zeros((batch, d), dtype),
    }


def wkv_state_logical_axes():
    return {
        "tm": {
            "shift": ("batch", "embed"),
            "wkv": ("batch", "heads", "head_dim", None),
        },
        "cm_shift": ("batch", "embed"),
    }
