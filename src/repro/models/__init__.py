from repro.models.transformer import (
    decode_step,
    init_params,
    lm_loss,
    forward,
    init_decode_state,
    param_logical_axes,
    prefill,
)

__all__ = [
    "decode_step",
    "forward",
    "init_decode_state",
    "init_params",
    "lm_loss",
    "param_logical_axes",
    "prefill",
]
