"""Checkpointing: flat-key .npz pytree snapshots + JSON metadata.

No orbax offline; this supports the same contract the trainer needs:
save(step) / restore(latest) with exact pytree structure round-trip
(dict / list / tuple nesting, dtypes preserved, scalars included).
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np

_SEP = "/"


def _escape(key: str) -> str:
    """Make a dict key safe for the flat namespace.

    Keys are user data, the separator is structure: a literal ``/`` in a key
    would read back as a nesting boundary and silently corrupt the round
    trip. Percent-encode the two metacharacters (``%`` first, so unescaping
    in the reverse order is exact); everything else passes through, keeping
    existing checkpoints' flat keys byte-identical.
    """
    if not isinstance(key, str):
        raise TypeError(
            f"checkpoint: dict keys must be str, got {type(key).__name__}: "
            f"{key!r}"
        )
    if not key:
        raise ValueError("checkpoint: empty dict keys cannot round-trip")
    return key.replace("%", "%25").replace(_SEP, "%2F")


def _unescape(key: str) -> str:
    return key.replace("%2F", _SEP).replace("%25", "%")


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{_SEP}d:{_escape(k)}"))
    elif isinstance(tree, (list, tuple)):
        tag = "l" if isinstance(tree, list) else "t"
        out[f"{prefix}{_SEP}#{tag}"] = np.asarray(len(tree))
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{_SEP}{tag}:{i}"))
    else:
        out[f"{prefix}{_SEP}a"] = np.asarray(tree)
    return out


def _unflatten(flat: dict, prefix=""):
    if f"{prefix}{_SEP}a" in flat:
        return flat[f"{prefix}{_SEP}a"]
    for tag, ctor in (("l", list), ("t", tuple)):
        key = f"{prefix}{_SEP}#{tag}"
        if key in flat:
            n = int(flat[key])
            return ctor(_unflatten(flat, f"{prefix}{_SEP}{tag}:{i}") for i in range(n))
    # dict: find child keys (still escaped — the recursion path needs the
    # escaped form; only the reconstructed dict key is unescaped)
    pat = re.escape(prefix + _SEP) + r"d:([^/]+)"
    kids = sorted({m.group(1) for k in flat if (m := re.match(pat, k))})
    if not kids:
        raise ValueError(f"cannot reconstruct node at {prefix!r}")
    return {_unescape(k): _unflatten(flat, f"{prefix}{_SEP}d:{k}") for k in kids}


def save(ckpt_dir: str, step: int, tree: Any, metadata: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    flat = _flatten(host_tree)
    path = os.path.join(ckpt_dir, f"step_{step:010d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    meta = dict(metadata or {})
    meta["step"] = step
    with open(os.path.join(ckpt_dir, f"step_{step:010d}.json"), "w") as f:
        json.dump(meta, f)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for fn in os.listdir(ckpt_dir)
        if (m := re.match(r"step_(\d+)\.npz$", fn))
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: Optional[int] = None):
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}.npz")
    with np.load(path, allow_pickle=False) as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflatten(flat)
    meta_path = os.path.join(ckpt_dir, f"step_{step:010d}.json")
    meta = {}
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            meta = json.load(f)
    return tree, meta
