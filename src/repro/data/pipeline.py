"""Token data pipeline: synthetic Zipf streams + memmap-backed corpora.

Host-sharded: in a multi-host launch each process reads its slice of the
global batch (shard = process_index). Deterministic per (seed, step) so
federated agents resample identical distributions but disjoint streams —
matching the paper's IID-agents assumption while keeping per-agent data
independent (each agent's stream is seeded by its agent id).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    """Zipf-distributed token stream with a deterministic Markov flavor:
    next-token distribution is a mixture of a Zipf prior and a shifted copy of
    the current token, so models can actually reduce loss on it."""

    vocab_size: int
    seed: int = 0
    zipf_a: float = 1.2
    copy_prob: float = 0.35

    def batch(self, step: int, batch: int, seq: int, agent: int = 0) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, agent, step])
        )
        base = rng.zipf(self.zipf_a, size=(batch, seq)).astype(np.int64)
        base = np.minimum(base - 1, self.vocab_size - 1)
        # Markov copy channel: token_t = token_{t-1} + 1 with prob copy_prob
        copy = rng.random((batch, seq)) < self.copy_prob
        for t in range(1, seq):
            base[:, t] = np.where(
                copy[:, t], (base[:, t - 1] + 1) % self.vocab_size, base[:, t]
            )
        return base.astype(np.int32)


@dataclasses.dataclass
class MemmapTokens:
    """Flat binary token file (uint16/uint32); random crops per step."""

    path: str
    vocab_size: int
    dtype: str = "uint16"
    seed: int = 0

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=self.dtype, mode="r")

    def batch(self, step: int, batch: int, seq: int, agent: int = 0) -> np.ndarray:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, agent, step]))
        n = len(self._data) - seq - 1
        starts = rng.integers(0, max(n, 1), size=batch)
        out = np.stack([self._data[s : s + seq] for s in starts])
        return np.minimum(out.astype(np.int32), self.vocab_size - 1)


def make_batch_iterator(
    source,
    batch: int,
    seq: int,
    *,
    agent: int = 0,
    start_step: int = 0,
    process_index: int = 0,
    process_count: int = 1,
) -> Iterator[dict]:
    """Yields {'tokens': (batch_local, seq)} host shards forever."""
    if batch % process_count:
        raise ValueError("global batch must divide process count")
    local = batch // process_count
    step = start_step
    while True:
        full = source.batch(step, batch, seq, agent=agent)
        yield {"tokens": full[process_index * local : (process_index + 1) * local]}
        step += 1
