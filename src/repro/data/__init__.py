from repro.data.pipeline import (
    SyntheticLM,
    MemmapTokens,
    make_batch_iterator,
)

__all__ = ["MemmapTokens", "SyntheticLM", "make_batch_iterator"]
