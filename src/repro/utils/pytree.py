"""Small pytree algebra used across the framework (no optax available)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(s, tree):
    return jax.tree.map(lambda x: s * x, tree)


def tree_axpy(a, x, y):
    """a * x + y, elementwise over matching pytrees."""
    return jax.tree.map(lambda xi, yi: a * xi + yi, x, y)


def tree_dot(a, b):
    # NOTE: jnp.vdot flattens its operands; flattening a 2D-sharded array
    # forces GSPMD to all-gather it fully (measured: 3 GiB fp32 per stacked
    # weight in the 256-chip dry run). The elementwise multiply + sum below
    # partitions cleanly.
    leaves = jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b
    )
    return jax.tree.reduce(jnp.add, leaves, jnp.asarray(0.0))


def tree_l2_norm(tree):
    return jnp.sqrt(tree_dot(tree, tree).real)
