from repro.utils import compat
from repro.utils.pytree import (
    tree_add,
    tree_axpy,
    tree_dot,
    tree_l2_norm,
    tree_scale,
    tree_sub,
    tree_zeros_like,
)

__all__ = [
    "compat",
    "tree_add",
    "tree_axpy",
    "tree_dot",
    "tree_l2_norm",
    "tree_scale",
    "tree_sub",
    "tree_zeros_like",
]
