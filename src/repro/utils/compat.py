"""JAX version-compatibility shims.

Supported range: JAX 0.4.3x (this container ships 0.4.37) through the
0.5/0.6/0.7 line. Everything here is feature-detected at import from the
module surface only — no jax device state is touched at import time, so the
launch modules (which must set XLA_FLAGS before first device init) can import
this safely.

The two API cliffs we paper over:
  * ``jax.sharding.AxisType`` (and ``jax.make_mesh(..., axis_types=...)``)
    only exist on newer JAX; 0.4.x meshes are implicitly "auto" on every axis.
  * ``jax.make_mesh`` itself predates 0.4.35; older still means building a
    ``Mesh`` from ``mesh_utils.create_device_mesh`` by hand.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

try:  # JAX >= 0.5.x explicit-sharding API
    from jax.sharding import AxisType  # noqa: F401
    HAS_AXIS_TYPE = True
except ImportError:  # JAX 0.4.x: every mesh axis behaves as "auto"
    AxisType = None
    HAS_AXIS_TYPE = False

HAS_MAKE_MESH = hasattr(jax, "make_mesh")


def default_axis_types(n_axes: int):
    """(AxisType.Auto,) * n_axes on new JAX; None where the concept is absent."""
    if HAS_AXIS_TYPE:
        return (AxisType.Auto,) * n_axes
    return None


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    axis_types: Optional[tuple] = None,
    devices=None,
):
    """``jax.make_mesh`` across JAX versions.

    ``axis_types`` is forwarded only when the installed JAX understands it
    (0.4.x meshes are implicitly auto-sharded on every axis, which is exactly
    what ``AxisType.Auto`` requests on newer JAX, so dropping it is lossless).
    """
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    if HAS_MAKE_MESH:
        kwargs = {}
        if devices is not None:
            kwargs["devices"] = devices
        if HAS_AXIS_TYPE and axis_types is not None:
            kwargs["axis_types"] = axis_types
        try:
            return jax.make_mesh(axis_shapes, axis_names, **kwargs)
        except TypeError:
            # e.g. a 0.4.x make_mesh that rejects an axis_types kwarg
            kwargs.pop("axis_types", None)
            return jax.make_mesh(axis_shapes, axis_names, **kwargs)
    from jax.experimental import mesh_utils
    from jax.sharding import Mesh

    devs = mesh_utils.create_device_mesh(axis_shapes, devices=devices)
    return Mesh(devs, axis_names)
