"""Flat-buffer optimizers for the federated hot path.

``repro.optim.optimizers`` holds the pytree reference optimizers; this module
is their flat-carry counterpart: state lives as fp32 ``(m, n)`` accumulator
matrices next to the flat parameter carry, and the update is one fused pass
through ``repro.kernels.dispatch.flat_opt_update`` (Pallas on kernel
backends, fp32 jnp reference elsewhere). The within-period weight (variation
mask x decay, eq. 10) is an explicit argument folded into the gradient
*before* moment accumulation, so a masked agent's momentum does not advance —
the flat drivers pass it straight from ``AggregationStrategy.weight``.

A ``FlatOptimizer`` is a frozen hashable spec, so the drivers can close over
it inside jit without it becoming a traced value.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels import dispatch


@dataclasses.dataclass(frozen=True)
class FlatOptimizer:
    """Optimizer spec for flat (m, n) parameter buffers.

    kind: 'sgd' | 'momentum' | 'adam' (see ``dispatch.flat_opt_update`` for
    the exact update rules — they mirror ``repro.optim.optimizers``).
    ``block_n`` tiles the Pallas kernels; ignored on the jnp backend.
    """

    kind: str
    beta: float = 0.9          # momentum
    nesterov: bool = False     # momentum
    b1: float = 0.9            # adam
    b2: float = 0.95           # adam
    eps: float = 1e-8          # adam
    weight_decay: float = 0.0  # adam
    block_n: int = 4096

    def __post_init__(self):
        if self.kind not in dispatch.OPT_KINDS:
            raise ValueError(
                f"unknown optimizer kind {self.kind!r}; expected one of "
                f"{dispatch.OPT_KINDS}"
            )

    def init(self, flat) -> dict:
        """fp32 accumulator state for a flat (n,) or (m, n) parameter buffer."""
        z = lambda: jnp.zeros(flat.shape, jnp.float32)
        if self.kind == "sgd":
            return {}
        if self.kind == "momentum":
            return {"mu": z()}
        return {"mu": z(), "nu": z(), "t": jnp.zeros((), jnp.int32)}

    def update(self, params, g, w, state, lr, *, backend: str = "auto"):
        """One fused weighted step: returns ``(new_params, new_state)``."""
        return dispatch.flat_opt_update(
            params, g, w, state,
            kind=self.kind, lr=lr,
            beta=self.beta, nesterov=self.nesterov,
            b1=self.b1, b2=self.b2, eps=self.eps,
            weight_decay=self.weight_decay,
            backend=backend, block_n=self.block_n,
        )


def server_average_state(strat, opt_state):
    """Server-sync the fp32 accumulators alongside the params (FedAvg-style):
    every (m, n) moment matrix collapses to its row mean, re-broadcast;
    shared scalars (adam's t) pass through."""
    def avg(leaf):
        if leaf.ndim != 2:
            return leaf
        row = strat.flat_server_average(leaf)
        return jnp.broadcast_to(row[None, :], leaf.shape)

    return jax.tree.map(avg, opt_state)


def flat_sgd() -> FlatOptimizer:
    return FlatOptimizer(kind="sgd")


def flat_momentum(beta: float = 0.9, nesterov: bool = False) -> FlatOptimizer:
    return FlatOptimizer(kind="momentum", beta=beta, nesterov=nesterov)


def flat_adam(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
              weight_decay: float = 0.0) -> FlatOptimizer:
    return FlatOptimizer(kind="adam", b1=b1, b2=b2, eps=eps,
                         weight_decay=weight_decay)
