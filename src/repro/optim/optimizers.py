"""Pytree optimizers (optax is not available offline; same init/update API).

AdamW keeps fp32 moments regardless of the (possibly bf16) param dtype —
the dry-run memory analysis accounts for these states.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.utils.pytree import tree_l2_norm


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, lr) -> (updates, state)

    def apply(self, grads, state, params, lr):
        updates, state = self.update(grads, state, params, lr)
        params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates
        )
        return params, state


def sgd() -> Optimizer:
    return Optimizer(
        init=lambda params: (),
        update=lambda g, s, p, lr: (jax.tree.map(lambda gi: -lr * gi.astype(jnp.float32), g), s),
    )


def momentum(beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(g, s, p, lr):
        m = jax.tree.map(lambda mi, gi: beta * mi + gi.astype(jnp.float32), s["m"], g)
        if nesterov:
            upd = jax.tree.map(lambda mi, gi: -lr * (beta * mi + gi.astype(jnp.float32)), m, g)
        else:
            upd = jax.tree.map(lambda mi: -lr * mi, m)
        return upd, {"m": m}

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, state_dtype=jnp.float32) -> Optimizer:
    """state_dtype=bf16 halves optimizer memory (beyond-paper perf knob);
    the update math still runs in fp32."""
    def init(params):
        z = lambda p: jnp.zeros(p.shape, state_dtype)
        return {
            "m": jax.tree.map(z, params),
            "v": jax.tree.map(z, params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(g, s, p, lr):
        t = s["t"] + 1
        m = jax.tree.map(
            lambda mi, gi: (b1 * mi.astype(jnp.float32)
                            + (1 - b1) * gi.astype(jnp.float32)).astype(mi.dtype),
            s["m"], g)
        v = jax.tree.map(
            lambda vi, gi: (b2 * vi.astype(jnp.float32)
                            + (1 - b2) * jnp.square(gi.astype(jnp.float32))
                            ).astype(vi.dtype),
            s["v"], g)
        bc1 = 1 - b1**t.astype(jnp.float32)
        bc2 = 1 - b2**t.astype(jnp.float32)

        def upd(mi, vi, pi):
            step = (mi.astype(jnp.float32) / bc1) / (
                jnp.sqrt(vi.astype(jnp.float32) / bc2) + eps)
            if weight_decay:
                step = step + weight_decay * pi.astype(jnp.float32)
            return -lr * step

        return jax.tree.map(upd, m, v, p), {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def clip_by_global_norm(grads, max_norm: float):
    norm = tree_l2_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm
