"""Learning-rate schedules (callables step -> lr, jit-friendly)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_lr(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_lr(lr: float, total_steps: int, final_frac: float = 0.1):
    def f(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return lr * (final_frac + (1 - final_frac) * cos)
    return f


def warmup_cosine_lr(lr: float, warmup: int, total_steps: int, final_frac: float = 0.1):
    cos = cosine_lr(lr, max(total_steps - warmup, 1), final_frac)
    def f(step):
        w = jnp.clip(step / max(warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, lr * w, cos(step - warmup))
    return f
