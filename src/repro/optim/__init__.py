from repro.optim.optimizers import (
    Optimizer,
    adamw,
    clip_by_global_norm,
    sgd,
    momentum,
)
from repro.optim.flat import FlatOptimizer, flat_adam, flat_momentum, flat_sgd
from repro.optim.schedules import constant_lr, cosine_lr, warmup_cosine_lr

__all__ = [
    "FlatOptimizer",
    "Optimizer",
    "adamw",
    "clip_by_global_norm",
    "constant_lr",
    "cosine_lr",
    "flat_adam",
    "flat_momentum",
    "flat_sgd",
    "momentum",
    "sgd",
    "warmup_cosine_lr",
]
