from repro.optim.optimizers import (
    Optimizer,
    adamw,
    clip_by_global_norm,
    sgd,
    momentum,
)
from repro.optim.schedules import constant_lr, cosine_lr, warmup_cosine_lr

__all__ = [
    "Optimizer",
    "adamw",
    "clip_by_global_norm",
    "constant_lr",
    "cosine_lr",
    "momentum",
    "sgd",
    "warmup_cosine_lr",
]
