"""Micro-batching request queue + seeded open-loop client schedules.

The seam between "many concurrent clients, one observation each" and the
bucket-shaped batches the AOT engine serves (DESIGN.md §16). The queue is
deliberately host-side and deterministic: requests are coalesced strictly in
arrival order (FIFO, ties broken by enqueue sequence), and each drain takes
``min(pending, max_batch)`` requests — so a replayed seeded client schedule
produces the identical sequence of batch compositions, which with the
engine's seeded noise stream makes whole serving runs reproducible
bit-for-bit (pinned by ``tests/test_serve.py``).

The load generators here (:func:`poisson_arrivals`, :func:`simulate_clients`)
are shared by the determinism tests and ``benchmarks/serving_bench.py`` —
open-loop (arrival times drawn up front, independent of service times), which
is the honest way to measure a serving system: a closed loop would slow its
own offered load down whenever the server lags.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ObsRequest:
    """One client's decision request: an observation plus arrival metadata.

    ``t_arrival`` is in schedule time units (seconds for the bench's Poisson
    clock); ``seq`` is the queue-assigned enqueue sequence number used for
    deterministic FIFO tie-breaking and set by :meth:`MicroBatchQueue.push`.
    """

    client_id: int
    t_arrival: float
    obs: np.ndarray
    seq: int = -1


class MicroBatchQueue:
    """Coalesce pending requests into bucket-shaped observation batches.

    ``max_batch`` caps a single drain (the engine's largest bucket — bigger
    backlogs drain over several calls). The queue never pads: padding to the
    covering bucket is the engine's job, so the queue stays a pure
    arrival-order scheduler.
    """

    def __init__(self, max_batch: int, obs_dim: int):
        if max_batch < 1:
            raise ValueError(f"queue: max_batch must be >= 1, got {max_batch}")
        self.max_batch = int(max_batch)
        self.obs_dim = int(obs_dim)
        self._pending: Deque[ObsRequest] = deque()
        self._seq = 0

    def __len__(self) -> int:
        return len(self._pending)

    def push(self, req: ObsRequest) -> ObsRequest:
        obs = np.asarray(req.obs, np.float32)
        if obs.shape != (self.obs_dim,):
            raise ValueError(
                f"queue: obs must be ({self.obs_dim},), got {obs.shape}"
            )
        stamped = dataclasses.replace(req, obs=obs, seq=self._seq)
        self._seq += 1
        self._pending.append(stamped)
        return stamped

    def push_all(self, reqs: Sequence[ObsRequest]) -> None:
        for r in reqs:
            self.push(r)

    def next_batch(self) -> Optional[Tuple[np.ndarray, List[ObsRequest]]]:
        """Pop the next ``min(pending, max_batch)`` requests in FIFO order.

        Returns ``(obs_batch, requests)`` with ``obs_batch`` of shape
        ``(n, obs_dim)`` ready for ``ServeEngine.decide``, or ``None`` when
        the queue is empty.
        """
        if not self._pending:
            return None
        n = min(len(self._pending), self.max_batch)
        reqs = [self._pending.popleft() for _ in range(n)]
        obs = np.stack([r.obs for r in reqs])
        return obs, reqs


def poisson_arrivals(rate: float, horizon: float, *,
                     seed: int = 0) -> np.ndarray:
    """Open-loop Poisson arrival times on ``[0, horizon)``.

    Exponential inter-arrival gaps at ``rate`` per time unit, drawn up front
    from a seeded generator — the offered load is fixed before any service
    happens. Returns a sorted float64 vector (possibly empty).
    """
    if rate <= 0.0:
        raise ValueError(f"poisson_arrivals: rate must be > 0, got {rate}")
    if horizon <= 0.0:
        raise ValueError(
            f"poisson_arrivals: horizon must be > 0, got {horizon}"
        )
    rng = np.random.default_rng(seed)
    # Draw in chunks of the expected count until past the horizon.
    expected = max(16, int(rate * horizon * 1.2))
    times: List[np.ndarray] = []
    t = 0.0
    while t < horizon:
        gaps = rng.exponential(1.0 / rate, size=expected)
        chunk = t + np.cumsum(gaps)
        times.append(chunk)
        t = float(chunk[-1])
    all_t = np.concatenate(times)
    return all_t[all_t < horizon]


def simulate_clients(m: int, rate_per_client: float, horizon: float, *,
                     obs_dim: int, seed: int = 0) -> List[ObsRequest]:
    """A seeded fleet of ``m`` open-loop clients, each an independent Poisson
    process at ``rate_per_client``, each request carrying a fresh random
    observation. Returns requests sorted by ``(t_arrival, client_id)`` —
    the deterministic arrival order the queue will see.
    """
    if m < 1:
        raise ValueError(f"simulate_clients: m must be >= 1, got {m}")
    rng = np.random.default_rng(seed)
    # One merged Poisson stream at m * rate, with client ids assigned
    # uniformly — statistically identical to m independent streams and O(N)
    # instead of O(m) generator setups for the 10k-agent bench.
    t = poisson_arrivals(m * rate_per_client, horizon, seed=seed + 1)
    ids = rng.integers(0, m, size=t.shape[0])
    obs = rng.standard_normal((t.shape[0], obs_dim)).astype(np.float32)
    return [
        ObsRequest(client_id=int(ids[i]), t_arrival=float(t[i]), obs=obs[i])
        for i in range(t.shape[0])
    ]
