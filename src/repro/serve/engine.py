"""AOT-compiled policy-serving engine: bucketed batches, zero retraces.

The serving half of the ROADMAP north star (DESIGN.md §16): a trained fleet
policy (``repro.rl.policy``) turned into a decision service. The engine
AOT-compiles the fused inference step (``dispatch.policy_infer`` — obs
normalize -> policy MLP -> sample/mean) at a small set of *bucketed* batch
shapes via ``jax.jit(...).lower().compile()`` at construction time. Every
request batch is padded up to the smallest covering bucket and dispatched to
that bucket's precompiled executable — the hot path never traces, never
compiles, and never consults the jit cache (one XLA compile per bucket,
pinned by the retrace guard in tests and the serving bench).

Hot-path buffer discipline: the ``(bucket, act_dim)`` noise operand is dead
after the decision and is *donated* — it aliases the action output (the
jaxpr audit's JXA004 rule verifies the lowering honors it on the registered
``serve.engine_step`` entry). The padded observation buffer is built host-
side with numpy (no device round-trip until the single executable call), and
decisions come back as one host transfer per batch, never per request.

Restore path: :meth:`ServeEngine.from_checkpoint` loads the policy pytree
(and optional normalization stats) through ``repro.checkpoint.restore`` —
the same escaped flat-key .npz format the trainer writes — and
:func:`save_for_serving` is its writer twin. :meth:`load_params` hot-swaps
weights into a live engine without recompiling (same shapes, same
executables).
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch

DEFAULT_BUCKETS = (8, 64, 256, 1024)

MODES = ("mean", "sample")


@dataclasses.dataclass(frozen=True)
class ObsNorm:
    """Observation normalization stats: ``(obs - mean) / std``.

    ``std`` entries must be strictly positive (enforced at construction; the
    identity norm is mean 0 / std 1). Stored fp32 so serving normalizes
    exactly like an fp32 training-side normalizer would.
    """

    mean: np.ndarray
    std: np.ndarray

    def __post_init__(self):
        mean = np.asarray(self.mean, np.float32)
        std = np.asarray(self.std, np.float32)
        if mean.ndim != 1 or mean.shape != std.shape:
            raise ValueError(
                f"ObsNorm: mean/std must be matching (obs_dim,) vectors, "
                f"got {mean.shape} vs {std.shape}"
            )
        if not np.all(std > 0.0):
            raise ValueError("ObsNorm: std must be strictly positive")
        object.__setattr__(self, "mean", mean)
        object.__setattr__(self, "std", std)

    @classmethod
    def identity(cls, obs_dim: int) -> "ObsNorm":
        return cls(np.zeros(obs_dim, np.float32), np.ones(obs_dim, np.float32))

    @classmethod
    def from_obs(cls, obs, eps: float = 1e-6) -> "ObsNorm":
        """Fit stats from an ``(..., obs_dim)`` observation buffer (e.g. the
        training rollouts' trajectory observations)."""
        o = np.asarray(jax.device_get(obs), np.float32)
        flat = o.reshape(-1, o.shape[-1])
        return cls(flat.mean(axis=0), flat.std(axis=0) + eps)


def _policy_dims(pi) -> Tuple[int, int]:
    for name in ("w1", "w3"):
        if name not in pi:
            raise ValueError(
                f"serve: params['pi'] needs {name!r} (got {sorted(pi)})"
            )
    return int(pi["w1"].shape[0]), int(pi["w3"].shape[1])


class ServeEngine:
    """Bucketed AOT policy-forward engine over a trained fleet policy.

    ``params`` is the ``repro.rl.policy.init_policy`` pytree (or any tree
    with a matching ``"pi"`` head). ``mode`` picks the decision rule:
    ``"mean"`` (deterministic — the tanh policy mean) or ``"sample"``
    (mean + exp(log_std) * noise, noise from a seeded host-side generator so
    a replayed request schedule reproduces its decisions bit-for-bit).
    """

    def __init__(self, params, *, norm: Optional[ObsNorm] = None,
                 buckets: Tuple[int, ...] = DEFAULT_BUCKETS,
                 mode: str = "mean", backend: str = "auto", seed: int = 0):
        if mode not in MODES:
            raise ValueError(f"unknown serve mode {mode!r}; expected {MODES}")
        if "pi" not in params:
            raise ValueError(
                f"serve: params must carry the policy head under 'pi', "
                f"got keys {sorted(params)}"
            )
        buckets = tuple(sorted(set(int(b) for b in buckets)))
        if not buckets or buckets[0] < 1:
            raise ValueError(f"serve: buckets must be positive ints, got {buckets}")
        self.mode = mode
        self.backend = dispatch.resolve_backend(backend)
        self.buckets = buckets
        self.obs_dim, self.act_dim = _policy_dims(params["pi"])
        self.norm = norm if norm is not None else ObsNorm.identity(self.obs_dim)
        if self.norm.mean.shape != (self.obs_dim,):
            raise ValueError(
                f"serve: norm is for obs_dim {self.norm.mean.shape[0]}, "
                f"policy expects {self.obs_dim}"
            )
        self._pi = {k: jnp.asarray(v) for k, v in params["pi"].items()}
        self._nm = jnp.asarray(self.norm.mean, jnp.float32)
        self._ns = jnp.asarray(self.norm.std, jnp.float32)
        self._rng = np.random.default_rng(seed)
        self.n_decisions = 0
        self.n_padded = 0
        self.bucket_calls: Dict[int, int] = {b: 0 for b in buckets}
        # --- AOT compile: exactly one XLA compile per bucket, at init ------
        sample = mode == "sample"
        backend_r = self.backend

        def step(pi, nm, ns, obs, noise):
            return dispatch.policy_infer(
                obs, pi, nm, ns, noise, sample=sample, backend=backend_r
            )

        self._step_fn = step
        pi_struct = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self._pi
        )
        vec = lambda n: jax.ShapeDtypeStruct((n,), jnp.float32)
        self._compiled = {}
        jitted = jax.jit(step, donate_argnums=(4,))
        for b in buckets:
            lowered = jitted.lower(
                pi_struct, vec(self.obs_dim), vec(self.obs_dim),
                jax.ShapeDtypeStruct((b, self.obs_dim), jnp.float32),
                jax.ShapeDtypeStruct((b, self.act_dim), jnp.float32),
            )
            self._compiled[b] = lowered.compile()

    # --- checkpoint seam -------------------------------------------------------
    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, step: Optional[int] = None,
                        **kwargs) -> "ServeEngine":
        """Restore a serving engine through ``repro.checkpoint.restore``.

        Accepts either a :func:`save_for_serving` checkpoint (``{"params":
        ..., "obs_norm": {"mean", "std"}}``) or a bare policy pytree with a
        ``"pi"`` head. An explicit ``norm=`` kwarg overrides the stored one.
        """
        from repro.checkpoint import restore

        tree, _meta = restore(ckpt_dir, step)
        if "params" in tree:
            params = tree["params"]
            if "norm" not in kwargs and "obs_norm" in tree:
                kwargs["norm"] = ObsNorm(
                    tree["obs_norm"]["mean"], tree["obs_norm"]["std"]
                )
        elif "pi" in tree:
            params = tree
        else:
            raise ValueError(
                f"serve: checkpoint carries neither 'params' nor 'pi' "
                f"(got keys {sorted(tree)})"
            )
        return cls(params, **kwargs)

    def load_params(self, params) -> None:
        """Hot-swap policy weights without recompiling (same shapes)."""
        if "pi" not in params:
            raise ValueError("serve: params must carry the policy head under 'pi'")
        new = {k: jnp.asarray(v) for k, v in params["pi"].items()}
        for k, v in self._pi.items():
            if k not in new or new[k].shape != v.shape or new[k].dtype != v.dtype:
                raise ValueError(
                    f"serve: hot-swap params differ in structure at 'pi.{k}' "
                    f"— build a new engine instead"
                )
        self._pi = new

    # --- hot path --------------------------------------------------------------
    def bucket_for(self, n: int) -> int:
        """Smallest bucket covering ``n`` (the largest bucket caps ``n``)."""
        if n < 1:
            raise ValueError(f"serve: batch must be >= 1, got {n}")
        i = bisect.bisect_left(self.buckets, n)
        return self.buckets[min(i, len(self.buckets) - 1)]

    def max_batch(self) -> int:
        return self.buckets[-1]

    def decide(self, obs) -> np.ndarray:
        """Decisions for an ``(n, obs_dim)`` observation batch, ``n`` <= the
        largest bucket. Pads to the covering bucket, runs that bucket's
        precompiled executable, and slices the padding back off — padded rows
        never change a real row's decision (rows are independent; pinned by
        tests). Returns host ``(n, act_dim)`` float32 actions."""
        obs = np.asarray(obs, np.float32)
        if obs.ndim != 2 or obs.shape[1] != self.obs_dim:
            raise ValueError(
                f"serve: obs must be (n, {self.obs_dim}), got {obs.shape}"
            )
        n = obs.shape[0]
        if n > self.buckets[-1]:
            raise ValueError(
                f"serve: batch of {n} exceeds the largest bucket "
                f"{self.buckets[-1]}; split it (the queue does this)"
            )
        b = self.bucket_for(n)
        if n < b:
            padded = np.zeros((b, self.obs_dim), np.float32)
            padded[:n] = obs
            obs = padded
        if self.mode == "sample":
            noise = self._rng.standard_normal(
                (b, self.act_dim), dtype=np.float32
            )
        else:
            noise = np.zeros((b, self.act_dim), np.float32)
        act = self._compiled[b](self._pi, self._nm, self._ns, obs, noise)
        self.n_decisions += n
        self.n_padded += b - n
        self.bucket_calls[b] += 1
        return np.asarray(jax.device_get(act))[:n]


def save_for_serving(ckpt_dir: str, step: int, params,
                     norm: Optional[ObsNorm] = None,
                     metadata: Optional[dict] = None) -> str:
    """Write a serving checkpoint (``repro.checkpoint.save`` format).

    The tree layout is what :meth:`ServeEngine.from_checkpoint` reads back:
    ``{"params": <policy pytree>, "obs_norm": {"mean", "std"}}``.
    """
    from repro.checkpoint import save

    if "pi" not in params:
        raise ValueError("serve: params must carry the policy head under 'pi'")
    obs_dim, _ = _policy_dims(params["pi"])
    norm = norm if norm is not None else ObsNorm.identity(obs_dim)
    tree = {
        "params": params,
        "obs_norm": {"mean": norm.mean, "std": norm.std},
    }
    meta = dict(metadata or {})
    meta.setdefault("kind", "serve")
    return save(ckpt_dir, step, tree, metadata=meta)


# --- trace-safety audit registration (repro.analysis.jaxpr_audit) -------------

def _audit_engine_step() -> dispatch.HotPathEntry:
    """The per-bucket serving step exactly as the engine AOT-compiles it.

    Registered with ``donate_argnums=(4,)`` (the noise buffer) so the jaxpr
    audit's JXA004 rule verifies the lowering actually aliases the donated
    ``(bucket, act_dim)`` noise input to the action output — the engine's
    "donated buffers on the hot path" claim is checked, not asserted.
    """
    B, od, h, ad = 8, 6, 16, 2
    buf = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    pi = {
        "w1": buf(od, h), "b1": buf(h),
        "w2": buf(h, h), "b2": buf(h),
        "w3": buf(h, ad), "b3": buf(ad),
        "log_std": buf(ad),
    }
    return dispatch.HotPathEntry(
        fn=lambda p, nm, ns, obs, noise: dispatch.policy_infer(
            obs, p, nm, ns, noise, sample=True, backend="jnp"
        ),
        args=(pi, buf(od), buf(od), buf(B, od), buf(B, ad)),
        donate_argnums=(4,),
    )


dispatch.register_hot_path("serve.engine_step", _audit_engine_step)
