"""Policy-serving engine: AOT bucketed batches + micro-batching queue.

See DESIGN.md §16. The public surface:

- :class:`~repro.serve.engine.ServeEngine` — bucketed AOT policy-forward
  engine (one XLA compile per bucket, donated noise buffer on the hot path).
- :class:`~repro.serve.engine.ObsNorm` / :func:`~repro.serve.engine.save_for_serving`
  — observation-normalization stats and the checkpoint writer twin of
  ``ServeEngine.from_checkpoint``.
- :class:`~repro.serve.queue.MicroBatchQueue` / :class:`~repro.serve.queue.ObsRequest`
  — arrival-order request coalescing into bucket-shaped batches.
- :func:`~repro.serve.queue.poisson_arrivals` / :func:`~repro.serve.queue.simulate_clients`
  — seeded open-loop client schedules (tests + serving bench).
"""
from repro.serve.engine import (
    DEFAULT_BUCKETS,
    ObsNorm,
    ServeEngine,
    save_for_serving,
)
from repro.serve.queue import (
    MicroBatchQueue,
    ObsRequest,
    poisson_arrivals,
    simulate_clients,
)

__all__ = [
    "DEFAULT_BUCKETS",
    "MicroBatchQueue",
    "ObsNorm",
    "ObsRequest",
    "ServeEngine",
    "poisson_arrivals",
    "save_for_serving",
    "simulate_clients",
]
