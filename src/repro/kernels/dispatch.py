"""Backend dispatch: route the federated hot-path transforms to the kernels.

The within-period gradient transforms (variation mask / decay weighting /
consensus gossip), the local optimizer update, and the server averaging step
are the per-step work of Algorithms 1 & 2. This module is the single switch
that decides how they execute:

  * ``jnp``       — pure-jnp reference path (tree ops / matmul). Always
                    available; the allclose target for everything else.
  * ``pallas``    — compiled Pallas TPU kernels (``decay_accum_pallas``,
                    ``consensus_step_pallas``, ``consensus_gather_pallas``,
                    ``row_mean_pallas``, ``momentum_update_pallas`` /
                    ``adam_update_pallas``): one fused bandwidth-bound pass
                    over flat parameter buffers.
  * ``interpret`` — the same Pallas kernels in interpret mode. Runs the
                    kernel bodies as traced jax on CPU; used for parity tests
                    and CPU debugging of the kernel path.
  * ``auto``      — ``pallas`` when the default backend is TPU, else ``jnp``.

Strategies carry a ``backend=`` field (default ``auto``) so every existing
call site keeps working; the drivers resolve it once at trace time.

The kernel path works on flat ``(m, n)`` matrices — m agents by n parameters.
Since PR 2 the *drivers* also keep their scan carry in that form (ravel once
at run start, unravel only where user code needs trees), so the per-step cost
on kernel backends is one ravel of the gradients the user closure returns —
no params round-trip. ``stacked_ravel_spec`` hands out the cached
flatten/unflatten closures (full-stack and per-agent views); the cache is a
bounded LRU keyed on (treedef, per-agent shapes, dtypes) and can be emptied
with ``clear_caches()``.

Numerics: every dispatched primitive accumulates in fp32 on every backend
(inputs are upcast, outputs cast back to the input dtype), so bf16/fp16
gradient buffers stay bit-comparable between the jnp reference and the
kernel path, and a later bf16-buffer mode slots in without parity drift.

Weights are *operands*, never baked-in constants: the per-agent coefficient
``d``/``w`` of ``decay_accum``/``scale_rows``/``flat_opt_update`` and the
(mask-folded) ``mixing`` matrix of ``consensus_mix`` arrive as arguments on
every backend, so the traced variation masks of the sweep engine's ``taus``
axis (columns of an ``(S, m, tau)`` batched mask → ``(S, m)`` coefficients,
or folded per-run ``(S, m, m)`` mixing tables) batch through the same entry
points with no kernel changes (DESIGN.md §11).
"""
from __future__ import annotations

import collections
from typing import Callable, Dict, NamedTuple, Tuple

import jax
import jax.flatten_util
import jax.numpy as jnp

# NOTE: the Pallas kernel modules are imported lazily inside the kernel
# branches below — the jnp reference path (and hence repro.core) must stay
# importable on JAX builds where jax.experimental.pallas fails to import.

BACKENDS = ("auto", "jnp", "pallas", "interpret")

OPT_KINDS = ("sgd", "momentum", "adam")


# --- hot-path entry-point registry --------------------------------------------
#
# The trace-safety analyzer (repro.analysis.jaxpr_audit) audits whatever is
# registered here: each entry is a lazily-built (fn, abstract args) pair that
# make_jaxpr can lower without running anything. Modules that own a hot path
# (this one for the dispatch primitives, rl.fedrl / core.fmarl for the
# drivers, sweep.runner for the per-static-point batched fn) register at
# import time; the registry lives here because every one of those modules
# already imports dispatch, so there is exactly one import direction.


class HotPathEntry(NamedTuple):
    """One auditable entry point: ``fn`` plus abstract example arguments.

    ``args`` are ``jax.ShapeDtypeStruct``s (or concrete arrays) shaped like a
    *small* but structurally faithful invocation — the audit only needs the
    jaxpr, so tiny shapes keep lowering fast while exercising every primitive
    the real sizes hit. ``donate_argnums`` declares buffers the entry point
    intends to donate under jit; the auditor verifies the lowering actually
    aliases them (rule JXA004).
    """

    fn: Callable
    args: Tuple
    donate_argnums: Tuple[int, ...] = ()


_HOT_PATH_FACTORIES: "collections.OrderedDict[str, Callable[[], HotPathEntry]]" = (
    collections.OrderedDict()
)


def register_hot_path(name: str, factory: Callable[[], HotPathEntry]) -> None:
    """Register ``factory`` (called lazily by the audit) under ``name``.

    Re-registration under the same name overwrites (module reloads in tests);
    names are namespaced by convention, e.g. ``dispatch.row_mean[jnp]`` or
    ``rl.run_fedrl_core``.
    """
    _HOT_PATH_FACTORIES[name] = factory


def hot_path_factories() -> Dict[str, Callable[[], HotPathEntry]]:
    """Snapshot of the registered entry-point factories (name -> factory)."""
    return dict(_HOT_PATH_FACTORIES)


def resolve_backend(backend: str = "auto") -> str:
    """Collapse ``auto`` to a concrete backend for the current platform."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return backend


def is_kernel_backend(backend: str) -> bool:
    return resolve_backend(backend) in ("pallas", "interpret")


# --- flat <-> pytree plumbing -------------------------------------------------

class FlatSpec(NamedTuple):
    """Cached flatten/unflatten closures for one replica-pytree structure.

    ``unravel`` maps the full ``(m, n)`` matrix back to the stacked tree;
    ``unravel_one`` maps a single ``(n,)`` row to a per-agent tree (the view
    rollout/grad closures receive on the flat-carry path); ``ravel_one`` is
    its inverse for the gradients those closures return.
    """

    unravel: Callable
    unravel_one: Callable
    ravel_one: Callable


# Bounded LRU: keyed on live treedef objects, so an unbounded dict would
# pin every tree structure ever raveled (and grow across tests / long
# sessions). 64 distinct (treedef, shapes, dtypes) structures is far beyond
# what one process legitimately cycles through.
_UNRAVEL_CACHE_MAXSIZE = 64
_UNRAVEL_CACHE: "collections.OrderedDict" = collections.OrderedDict()


def clear_caches() -> None:
    """Drop all cached unravel closures (tests; releases treedef refs)."""
    _UNRAVEL_CACHE.clear()


def _ravel_one(tree) -> jnp.ndarray:
    return jax.flatten_util.ravel_pytree(tree)[0]


def _flat_spec(leaves, treedef) -> FlatSpec:
    key = (treedef, tuple((l.shape[1:], jnp.dtype(l.dtype).name) for l in leaves))
    spec = _UNRAVEL_CACHE.get(key)
    if spec is None:
        template = jax.tree.unflatten(
            treedef, [jnp.zeros(l.shape[1:], l.dtype) for l in leaves]
        )
        _, unravel_one = jax.flatten_util.ravel_pytree(template)
        spec = FlatSpec(
            unravel=jax.vmap(unravel_one),
            unravel_one=unravel_one,
            ravel_one=_ravel_one,
        )
        _UNRAVEL_CACHE[key] = spec
        if len(_UNRAVEL_CACHE) > _UNRAVEL_CACHE_MAXSIZE:
            _UNRAVEL_CACHE.popitem(last=False)
    else:
        _UNRAVEL_CACHE.move_to_end(key)
    return spec


def stacked_ravel_spec(tree_m):
    """Flatten an (m, ...)-leaved replica pytree to ``(flat, FlatSpec)``.

    ``flat`` is the ``(m, n)`` matrix; the spec carries the cached unflatten
    closures (see :class:`FlatSpec`). The cache key is (treedef, per-agent
    leaf shapes, dtypes) in a bounded LRU.
    """
    leaves, treedef = jax.tree.flatten(tree_m)
    if not leaves:
        raise ValueError("stacked_ravel: empty pytree")
    m = leaves[0].shape[0]
    for l in leaves:
        if l.ndim < 1 or l.shape[0] != m:
            raise ValueError(
                f"stacked_ravel: every leaf needs leading agent axis {m}, "
                f"got shape {l.shape}"
            )
    spec = _flat_spec(leaves, treedef)
    flat = jax.vmap(_ravel_one)(tree_m)
    return flat, spec


def compute_view(buf, storage_dtype):
    """fp32 compute view of a flat carry buffer.

    The single policy point for the reduced-precision buffer mode: when a
    storage dtype is set (e.g. bf16 carries), user-facing tree views upcast
    to fp32 before unraveling; with the default fp32 storage it is the
    identity. Both drivers route every unravel through this.
    """
    return buf.astype(jnp.float32) if storage_dtype is not None else buf


def stacked_ravel(tree_m):
    """Flatten an (m, ...)-leaved replica pytree to an ``(m, n)`` matrix.

    Returns ``(flat, unravel)`` where ``unravel`` maps an ``(m, n)`` matrix
    back to the original tree structure. See ``stacked_ravel_spec`` for the
    full set of cached views.
    """
    flat, spec = stacked_ravel_spec(tree_m)
    return flat, spec.unravel


# --- dispatched primitives ----------------------------------------------------
#
# Sweep axis: every flat primitive also accepts a leading sweep axis S on its
# buffers — ``(S, m, n)`` instead of ``(m, n)`` — by vmapping itself over axis
# 0 (the per-axis coefficient / mixing arguments gain a matching leading axis
# or broadcast). This is the shape the sweep engine (repro.sweep) produces
# when it vmaps a whole federated run over seeds/hyperparameters: one trace
# covers all S runs, no per-run retraces.


def decay_accum(acc, g, d, *, backend: str = "auto", block_n: int = 4096):
    """``acc + d * g`` — the fused FMA at the heart of the decay/SGD step.

    ``acc``/``g``: ``(n,)`` or ``(m, n)`` buffers, or ``(S, m, n)`` with a
    leading sweep axis; ``d``: scalar, or ``(m,)`` per-agent coefficients when
    the inputs are ``(m, n)`` (the kernel is vmapped over the agent axis), or
    additionally ``(S,)`` / ``(S, m)`` per-run coefficients on the sweep path.
    Accumulates in fp32 on every backend; the result is cast back to
    ``acc.dtype``.
    """
    b = resolve_backend(backend)
    if acc.ndim == 3:
        if acc.shape != g.shape:
            raise ValueError(
                f"decay_accum: acc/g must match on the sweep path, got "
                f"{acc.shape} vs {g.shape}"
            )
        d_arr = jnp.asarray(d, jnp.float32)
        S, m = acc.shape[0], acc.shape[1]
        if d_arr.ndim == 1 and S == m and d_arr.shape[0] == S:
            # A 1-D d could mean per-run (S,) or shared per-agent (m,) and
            # the two disagree numerically — refuse rather than guess.
            raise ValueError(
                f"decay_accum: 1-D d of length {S} is ambiguous on a sweep "
                f"path with S == m == {S}; pass (S, m) coefficients (tile "
                f"the shared/per-run vector) or a scalar"
            )
        if d_arr.ndim == 2 or (d_arr.ndim == 1 and d_arr.shape[0] == S):
            # per-run coefficients: (S,) or (S, m)
            return jax.vmap(
                lambda a, gi, di: decay_accum(a, gi, di, backend=b, block_n=block_n)
            )(acc, g, d_arr)
        return jax.vmap(
            lambda a, gi: decay_accum(a, gi, d_arr, backend=b, block_n=block_n)
        )(acc, g)
    if acc.ndim not in (1, 2) or acc.shape != g.shape:
        raise ValueError(
            f"decay_accum: acc/g must be matching (n,) or (m, n) buffers, "
            f"got {acc.shape} vs {g.shape}"
        )
    if acc.dtype != g.dtype:
        # Enforced on every backend so 'auto' behaves identically on CPU/TPU.
        raise ValueError(
            f"decay_accum: acc/g dtypes must match, got {acc.dtype} vs {g.dtype}"
        )
    d_arr = jnp.asarray(d, jnp.float32)
    if d_arr.ndim not in (0, 1) or (d_arr.ndim == 1 and acc.ndim != 2):
        raise ValueError(
            f"decay_accum: d must be scalar or (m,) with (m, n) inputs, "
            f"got d shape {d_arr.shape} for input shape {acc.shape}"
        )
    if b == "jnp":
        d_b = d_arr[:, None] if d_arr.ndim == 1 else d_arr
        out = acc.astype(jnp.float32) + d_b * g.astype(jnp.float32)
        return out.astype(acc.dtype)
    from repro.kernels.decay_accum import decay_accum_pallas

    interp = b == "interpret"
    if acc.ndim == 2:
        d_m = jnp.broadcast_to(d_arr, (acc.shape[0],))
        return jax.vmap(
            lambda a, gi, di: decay_accum_pallas(
                a, gi, di, block_n=block_n, interpret=interp
            )
        )(acc, g, d_m)
    return decay_accum_pallas(acc, g, d_arr, block_n=block_n, interpret=interp)


def scale_rows(g, w, *, backend: str = "auto", block_n: int = 4096):
    """Row-scale ``(m, n)`` grads by per-agent weights ``w``: out[i] = w[i]*g[i].

    On the kernel path this is ``decay_accum(g, g, w - 1)`` = g + (w-1)*g —
    both operands alias the same buffer, so no zeros accumulator is ever
    materialised. The drivers avoid even this pass by fusing the weight into
    the SGD coefficient (see ``AggregationStrategy.flat_update``); this
    standalone form backs ``transform`` when called outside the fused update.
    """
    b = resolve_backend(backend)
    if g.ndim == 3:
        w_arr = jnp.asarray(w, jnp.float32)
        if w_arr.ndim == 2:  # (S, m) per-run weights
            return jax.vmap(
                lambda gi, wi: scale_rows(gi, wi, backend=b, block_n=block_n)
            )(g, w_arr)
        if w_arr.ndim == 1 and g.shape[0] == g.shape[1]:
            # S == m: a 1-D w could be read as per-run or per-agent under
            # the sweep conventions — refuse rather than guess (matches
            # decay_accum's guard).
            raise ValueError(
                f"scale_rows: 1-D w of length {g.shape[1]} is ambiguous on a "
                f"sweep path with S == m == {g.shape[0]}; pass (S, m) weights"
            )
        return jax.vmap(
            lambda gi: scale_rows(gi, w_arr, backend=b, block_n=block_n)
        )(g)
    if g.ndim != 2:
        raise ValueError(f"scale_rows: g must be (m, n), got {g.shape}")
    w_arr = jnp.asarray(w, jnp.float32)
    if w_arr.shape != (g.shape[0],):
        raise ValueError(
            f"scale_rows: w must be ({g.shape[0]},) for g {g.shape}, "
            f"got {w_arr.shape}"
        )
    if b == "jnp":
        return (g.astype(jnp.float32) * w_arr[:, None]).astype(g.dtype)
    return decay_accum(g, g, w_arr - 1.0, backend=b, block_n=block_n)


def consensus_mix(g, mixing, *, backend: str = "auto", block_n: int = 2048):
    """One (possibly fused-E, possibly mask-folded) gossip mix: ``mixing @ g``.

    Both backends accumulate the matmul in fp32 at HIGHEST precision (the
    MXU's default fp32 path truncates operands to bf16 passes, which would
    drift from the CPU reference) and cast back to ``g.dtype``.
    """
    b = resolve_backend(backend)
    if g.ndim == 3:
        mixing = jnp.asarray(mixing)
        if mixing.ndim == 3:  # (S, m, m) per-run mixing matrices
            return jax.vmap(
                lambda gi, mi: consensus_mix(gi, mi, backend=b, block_n=block_n)
            )(g, mixing)
        return jax.vmap(
            lambda gi: consensus_mix(gi, mixing, backend=b, block_n=block_n)
        )(g)
    if g.ndim != 2:
        raise ValueError(f"consensus_mix: g must be (m, n), got {g.shape}")
    m = g.shape[0]
    if mixing.shape != (m, m):
        raise ValueError(
            f"consensus_mix: mixing must be ({m}, {m}) for g {g.shape}, "
            f"got {mixing.shape}"
        )
    if b == "jnp":
        out = jnp.matmul(
            mixing.astype(jnp.float32),
            g.astype(jnp.float32),
            precision=jax.lax.Precision.HIGHEST,
        )
        return out.astype(g.dtype)
    from repro.kernels.consensus_step import consensus_step_pallas

    return consensus_step_pallas(
        g, mixing, block_n=block_n, interpret=(b == "interpret")
    )


def consensus_gather(g, idx, w, *, backend: str = "auto", block_n: int = 2048):
    """One sparse neighbor-list gossip round: ``out[i] = sum_k w[i,k]*g[idx[i,k]]``.

    The O(m*k) twin of :func:`consensus_mix` for sparse topologies. ``g``:
    ``(m, n)`` flat grads (or ``(S, m, n)`` with a leading sweep axis);
    ``idx``: static ``(m, k_max)`` integer neighbor ids in the
    ``repro.core.topology.NeighborList`` layout (ascending valid prefix, self
    included, padding = own row); ``w``: ``(m, k_max)`` edge weights with
    padding slots exactly 0.0 (or ``(S, m, k_max)`` per-run weights — the eps
    sweep axis rebuilds them traced via ``neighbor_weights``).

    Numerics contract: fp32 accumulation on every backend, result cast back
    to ``g.dtype``. The jnp path states the sum as a *sequential* FMA chain
    in ascending-k order — in eager mode this is bit-identical to evaluating
    the dense ``P @ g`` row sum in index order with zero weights on the
    non-edges (adding ``0.0 * row`` is exact), which is the dense/sparse
    bitwise-parity contract pinned by the tests; under jit, XLA fusion may
    re-associate within 1 ulp (same caveat as every dispatched primitive).
    """
    b = resolve_backend(backend)
    idx = jnp.asarray(idx)
    if idx.ndim != 2 or not jnp.issubdtype(idx.dtype, jnp.integer):
        raise ValueError(
            f"consensus_gather: idx must be an (m, k_max) integer array, got "
            f"shape {idx.shape} dtype {idx.dtype}"
        )
    if g.ndim == 3:
        w_arr = jnp.asarray(w, jnp.float32)
        if w_arr.ndim == 3:  # (S, m, k_max) per-run edge weights
            return jax.vmap(
                lambda gi, wi: consensus_gather(
                    gi, idx, wi, backend=b, block_n=block_n
                )
            )(g, w_arr)
        return jax.vmap(
            lambda gi: consensus_gather(gi, idx, w_arr, backend=b, block_n=block_n)
        )(g)
    if g.ndim != 2:
        raise ValueError(f"consensus_gather: g must be (m, n), got {g.shape}")
    m = g.shape[0]
    if idx.shape[0] != m:
        raise ValueError(
            f"consensus_gather: idx must be ({m}, k_max) for g {g.shape}, "
            f"got {idx.shape}"
        )
    w_arr = jnp.asarray(w, jnp.float32)
    if w_arr.shape != idx.shape:
        raise ValueError(
            f"consensus_gather: w must match idx {idx.shape}, got {w_arr.shape}"
        )
    if b == "jnp":
        g32 = g.astype(jnp.float32)
        k_max = idx.shape[1]
        out = w_arr[:, 0, None] * jnp.take(g32, idx[:, 0], axis=0)
        for k in range(1, k_max):
            out = out + w_arr[:, k, None] * jnp.take(g32, idx[:, k], axis=0)
        return out.astype(g.dtype)
    from repro.kernels.consensus_gather import consensus_gather_pallas

    return consensus_gather_pallas(
        g, idx, w_arr, block_n=block_n, interpret=(b == "interpret")
    )


def row_mean(g, *, backend: str = "auto", block_n: int = 4096):
    """Server averaging (eq. 11) on the flat carry: mean over the agent axis.

    ``g``: ``(m, n)``. Returns the ``(n,)`` server row (broadcast it back over
    the agent axis to re-seed the replicas). Accumulates in fp32 on every
    backend and casts back to ``g.dtype``.
    """
    b = resolve_backend(backend)
    if g.ndim == 3:
        return jax.vmap(lambda gi: row_mean(gi, backend=b, block_n=block_n))(g)
    if g.ndim != 2:
        raise ValueError(f"row_mean: g must be (m, n), got {g.shape}")
    if b == "jnp":
        return jnp.mean(g.astype(jnp.float32), axis=0).astype(g.dtype)
    from repro.kernels.flat_update import row_mean_pallas

    return row_mean_pallas(g, block_n=block_n, interpret=(b == "interpret"))


def topk_scatter(x, thresh, *, backend: str = "auto", block_n: int = 4096):
    """Fused top-k select + scatter-accumulate: the compressed server reduction.

    ``x``: ``(m, n)`` payload rows (or ``(S, m, n)`` with a leading sweep
    axis); ``thresh``: ``(m,)`` (or ``(S, m)``) per-agent magnitude
    thresholds, normally ``repro.comm.topk_threshold(x, k)``. Selection is
    threshold form — keep ``|x| >= thresh`` with ties included — so the jnp
    reference and the Pallas kernel pick identical entries. Returns
    ``(sent_sum, residual)``: the ``(n,)`` sum of the selected entries over
    the agent axis (fp32 accumulation on every backend, cast back to
    ``x.dtype``) and the ``(m, n)`` unselected remainder (the error-feedback
    residual; ``sent + residual == x`` exactly, elementwise).

    The jnp path states the scatter-accumulate explicitly: the selected
    (value, column) pairs of every agent scatter-add into the server row via
    ``segment_sum`` over the flattened column ids.
    """
    b = resolve_backend(backend)
    if x.ndim == 3:
        thresh = jnp.asarray(thresh, jnp.float32)
        if thresh.shape != x.shape[:2]:
            raise ValueError(
                f"topk_scatter: thresh must be {x.shape[:2]} on the sweep "
                f"path, got {thresh.shape}"
            )
        return jax.vmap(
            lambda xi, ti: topk_scatter(xi, ti, backend=b, block_n=block_n)
        )(x, thresh)
    if x.ndim != 2:
        raise ValueError(f"topk_scatter: x must be (m, n), got {x.shape}")
    m, n = x.shape
    thresh = jnp.asarray(thresh, jnp.float32)
    if thresh.shape != (m,):
        raise ValueError(
            f"topk_scatter: thresh must be ({m},) for x {x.shape}, "
            f"got {thresh.shape}"
        )
    if b == "jnp":
        x32 = x.astype(jnp.float32)
        sent = jnp.where(jnp.abs(x32) >= thresh[:, None], x32, 0.0)
        cols = jnp.broadcast_to(jnp.arange(n)[None, :], (m, n))
        ssum = jax.ops.segment_sum(sent.ravel(), cols.ravel(), num_segments=n)
        return ssum.astype(x.dtype), (x32 - sent).astype(x.dtype)
    from repro.kernels.topk_scatter import topk_scatter_pallas

    return topk_scatter_pallas(
        x, thresh, block_n=block_n, interpret=(b == "interpret")
    )


def policy_infer(obs, pi, norm_mean, norm_std, noise, *, sample: bool = False,
                 backend: str = "auto", block_b: int = 256):
    """Fused serving inference: obs-normalize -> policy MLP -> sample/mean.

    The serving-side primitive (DESIGN.md §16): ``obs`` is a ``(B, obs_dim)``
    observation batch, ``pi`` the Gaussian policy head (the ``params["pi"]``
    subtree of ``repro.rl.policy.init_policy`` — w1/b1/w2/b2/w3/b3/log_std),
    ``norm_mean``/``norm_std`` the ``(obs_dim,)`` fp32 normalization stats and
    ``noise`` a ``(B, act_dim)`` standard-normal operand. Returns the
    ``(B, act_dim)`` actions: the tanh policy mean (``sample=False`` — the
    deterministic decision, the density argmax of the squashed Gaussian) or
    ``mean + exp(log_std) * noise`` (``sample=True``).

    Bitwise contract: the jnp path *is* eager ``rl.policy.policy_apply`` on
    the normalized batch — bit-identical to the training-side policy in eager
    mode, pinned by the serving bench and tests. ``noise`` is an operand in
    both modes so the serving engine can donate its buffer (it aliases the
    action output; JXA004-verified on the ``serve.engine_step`` entry). No
    leading sweep axis: serving batches are bucket-shaped, not swept.
    """
    b = resolve_backend(backend)
    if obs.ndim != 2:
        raise ValueError(f"policy_infer: obs must be (B, obs_dim), got {obs.shape}")
    for name in ("w1", "b1", "w2", "b2", "w3", "b3", "log_std"):
        if name not in pi:
            raise ValueError(f"policy_infer: pi needs {name!r} (got {sorted(pi)})")
    B, obs_dim = obs.shape
    act_dim = pi["w3"].shape[1]
    if pi["w1"].shape[0] != obs_dim:
        raise ValueError(
            f"policy_infer: w1 expects obs_dim {pi['w1'].shape[0]}, "
            f"obs has {obs_dim}"
        )
    if noise.shape != (B, act_dim):
        raise ValueError(
            f"policy_infer: noise must be ({B}, {act_dim}), got {noise.shape}"
        )
    nm = jnp.asarray(norm_mean, jnp.float32)
    ns = jnp.asarray(norm_std, jnp.float32)
    if nm.shape != (obs_dim,) or ns.shape != (obs_dim,):
        raise ValueError(
            f"policy_infer: norm stats must be ({obs_dim},), got "
            f"{nm.shape} / {ns.shape}"
        )
    if b == "jnp":
        from repro.rl.policy import policy_apply

        obsn = (obs.astype(jnp.float32) - nm) / ns
        mean, log_std = policy_apply({"pi": pi}, obsn)
        act = mean + jnp.exp(log_std) * noise.astype(jnp.float32) if sample else mean
        return act.astype(obs.dtype)
    from repro.kernels.policy_infer import policy_infer_pallas

    return policy_infer_pallas(
        obs, pi["w1"], pi["b1"], pi["w2"], pi["b2"], pi["w3"], pi["b3"],
        pi["log_std"], nm, ns, noise,
        sample=sample, block_b=block_b, interpret=(b == "interpret"),
    )


def _check_opt_state(state, required, params, kind):
    for name in required:
        buf = state.get(name)
        if buf is None:
            raise ValueError(f"flat_opt_update[{kind}]: state needs {name!r}")
        if name == "t":
            continue
        if buf.shape != params.shape:
            raise ValueError(
                f"flat_opt_update[{kind}]: state[{name!r}] shape {buf.shape} "
                f"must match params {params.shape}"
            )
        if buf.dtype != jnp.float32:
            raise ValueError(
                f"flat_opt_update[{kind}]: state[{name!r}] must be an fp32 "
                f"accumulator, got {buf.dtype}"
            )


def flat_opt_update(
    params,
    g,
    w,
    state,
    *,
    kind: str,
    lr,
    beta: float = 0.9,
    nesterov: bool = False,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    backend: str = "auto",
    block_n: int = 4096,
):
    """Fused within-period-weighted optimizer update on flat buffers.

    ``params``/``g``: matching ``(n,)`` or ``(m, n)`` buffers. ``w`` is the
    strategy's per-step weight (variation mask x decay; scalar or ``(m,)``),
    folded into the gradient *before* any moment accumulation — so a masked
    agent's momentum genuinely does not advance. ``state`` holds the fp32
    accumulators (see ``repro.optim.flat``):

      * ``sgd``      — ``{}``; delegates to the fused :func:`decay_accum` pass.
      * ``momentum`` — ``{"mu"}``; mu <- beta*mu + w*g, params -= lr*mu
                       (nesterov: params -= lr*(beta*mu_new + w*g)),
                       matching ``repro.optim.optimizers.momentum``.
      * ``adam``     — ``{"mu", "nu", "t"}``; bias-corrected Adam(W) matching
                       ``repro.optim.optimizers.adamw`` with fp32 state.

    Returns ``(new_params, new_state)``. All math runs in fp32; params are
    cast back to their own dtype (the moments stay fp32), so bf16 parameter /
    gradient buffers lose nothing in the accumulators.
    """
    if kind not in OPT_KINDS:
        raise ValueError(f"unknown optimizer kind {kind!r}; expected {OPT_KINDS}")
    b = resolve_backend(backend)
    if params.ndim not in (1, 2) or params.shape != g.shape:
        raise ValueError(
            f"flat_opt_update: params/g must be matching (n,) or (m, n) "
            f"buffers, got {params.shape} vs {g.shape}"
        )
    w_arr = jnp.asarray(w, jnp.float32)
    if w_arr.ndim not in (0, 1) or (w_arr.ndim == 1 and params.ndim != 2):
        raise ValueError(
            f"flat_opt_update: w must be scalar or (m,) with (m, n) inputs, "
            f"got w shape {w_arr.shape} for input shape {params.shape}"
        )

    if kind == "sgd":
        new_p = decay_accum(params, g, -lr * w_arr, backend=b, block_n=block_n)
        return new_p, state

    if kind == "momentum":
        _check_opt_state(state, ("mu",), params, kind)
        mu = state["mu"]
        if b == "jnp":
            w_b = w_arr[:, None] if w_arr.ndim == 1 else w_arr
            wg = w_b * g.astype(jnp.float32)
            new_mu = beta * mu + wg
            upd = beta * new_mu + wg if nesterov else new_mu
            new_p = (params.astype(jnp.float32) - lr * upd).astype(params.dtype)
            return new_p, dict(state, mu=new_mu)
        from repro.kernels.flat_update import momentum_update_pallas

        interp = b == "interpret"
        lr_arr = jnp.asarray(lr, jnp.float32)
        if params.ndim == 2:
            w_m = jnp.broadcast_to(w_arr, (params.shape[0],))
            new_p, new_mu = jax.vmap(
                lambda p, gi, mi, wi: momentum_update_pallas(
                    p, gi, mi, wi, lr_arr, beta,
                    nesterov=nesterov, block_n=block_n, interpret=interp,
                )
            )(params, g, mu, w_m)
        else:
            new_p, new_mu = momentum_update_pallas(
                params, g, mu, w_arr, lr_arr, beta,
                nesterov=nesterov, block_n=block_n, interpret=interp,
            )
        return new_p, dict(state, mu=new_mu)

    # kind == "adam"
    _check_opt_state(state, ("mu", "nu", "t"), params, kind)
    mu, nu = state["mu"], state["nu"]
    t = state["t"] + 1
    tf = t.astype(jnp.float32)
    bc1 = 1.0 - jnp.float32(b1) ** tf
    bc2 = 1.0 - jnp.float32(b2) ** tf
    if b == "jnp":
        w_b = w_arr[:, None] if w_arr.ndim == 1 else w_arr
        wg = w_b * g.astype(jnp.float32)
        new_mu = b1 * mu + (1.0 - b1) * wg
        new_nu = b2 * nu + (1.0 - b2) * jnp.square(wg)
        p32 = params.astype(jnp.float32)
        step = (new_mu / bc1) / (jnp.sqrt(new_nu / bc2) + eps)
        step = step + weight_decay * p32
        new_p = (p32 - lr * step).astype(params.dtype)
        return new_p, dict(state, mu=new_mu, nu=new_nu, t=t)
    from repro.kernels.flat_update import adam_update_pallas

    interp = b == "interpret"
    lr_arr = jnp.asarray(lr, jnp.float32)
    if params.ndim == 2:
        w_m = jnp.broadcast_to(w_arr, (params.shape[0],))
        new_p, new_mu, new_nu = jax.vmap(
            lambda p, gi, mi, vi, wi: adam_update_pallas(
                p, gi, mi, vi, wi, lr_arr, bc1, bc2,
                b1=b1, b2=b2, eps=eps, weight_decay=weight_decay,
                block_n=block_n, interpret=interp,
            )
        )(params, g, mu, nu, w_m)
    else:
        new_p, new_mu, new_nu = adam_update_pallas(
            params, g, mu, nu, w_arr, lr_arr, bc1, bc2,
            b1=b1, b2=b2, eps=eps, weight_decay=weight_decay,
            block_n=block_n, interpret=interp,
        )
    return new_p, dict(state, mu=new_mu, nu=new_nu, t=t)


# --- hot-path registrations ---------------------------------------------------

def _primitive_hot_path(prim: str, backend: str) -> Callable[[], HotPathEntry]:
    """Audit entry for one dispatched primitive on one backend.

    Shapes are tiny (the audit reads jaxprs, not timings) but keep the real
    structure: ``(m, n)`` buffers with per-agent coefficients, so the fp32
    accumulation contract is visible in the lowered equations.
    """

    def factory() -> HotPathEntry:
        m, n = 4, 96

        def buf(*shape):
            return jax.ShapeDtypeStruct(shape, jnp.float32)

        if prim == "decay_accum":
            return HotPathEntry(
                fn=lambda acc, g, d: decay_accum(acc, g, d, backend=backend),
                args=(buf(m, n), buf(m, n), buf(m)),
            )
        if prim == "scale_rows":
            return HotPathEntry(
                fn=lambda g, w: scale_rows(g, w, backend=backend),
                args=(buf(m, n), buf(m)),
            )
        if prim == "consensus_mix":
            return HotPathEntry(
                fn=lambda g, mix: consensus_mix(g, mix, backend=backend),
                args=(buf(m, n), buf(m, m)),
            )
        if prim == "consensus_gather":
            k_max = 3
            return HotPathEntry(
                fn=lambda g, idx, w: consensus_gather(g, idx, w, backend=backend),
                args=(
                    buf(m, n),
                    jax.ShapeDtypeStruct((m, k_max), jnp.int32),
                    buf(m, k_max),
                ),
            )
        if prim == "row_mean":
            return HotPathEntry(
                fn=lambda g: row_mean(g, backend=backend),
                args=(buf(m, n),),
            )
        if prim == "topk_scatter":
            return HotPathEntry(
                fn=lambda x, t: topk_scatter(x, t, backend=backend),
                args=(buf(m, n), buf(m)),
            )
        if prim == "policy_infer":
            B, od, h, ad = 8, 6, 16, 2
            pi = {
                "w1": buf(od, h), "b1": buf(h),
                "w2": buf(h, h), "b2": buf(h),
                "w3": buf(h, ad), "b3": buf(ad),
                "log_std": buf(ad),
            }
            return HotPathEntry(
                fn=lambda obs, p, nm, ns, z: policy_infer(
                    obs, p, nm, ns, z, sample=True, backend=backend
                ),
                args=(buf(B, od), pi, buf(od), buf(od), buf(B, ad)),
            )
        raise ValueError(f"unknown dispatch primitive {prim!r}")

    return factory


DISPATCH_PRIMITIVES = (
    "decay_accum", "scale_rows", "consensus_mix", "consensus_gather",
    "row_mean", "topk_scatter", "policy_infer",
)

# The pallas backend proper needs a TPU to lower; jnp + interpret cover both
# code paths (reference math and kernel bodies) on any host.
for _prim in DISPATCH_PRIMITIVES:
    for _backend in ("jnp", "interpret"):
        register_hot_path(
            f"dispatch.{_prim}[{_backend}]",
            _primitive_hot_path(_prim, _backend),
        )
