"""Backend dispatch: route the federated hot-path transforms to the kernels.

The within-period gradient transforms (variation mask / decay weighting /
consensus gossip) and the local SGD update are the per-step work of
Algorithms 1 & 2. This module is the single switch that decides how they
execute:

  * ``jnp``       — pure-jnp reference path (tree ops / matmul). Always
                    available; the allclose target for everything else.
  * ``pallas``    — compiled Pallas TPU kernels (``decay_accum_pallas``,
                    ``consensus_step_pallas``): one fused bandwidth-bound
                    pass over the flat parameter buffers.
  * ``interpret`` — the same Pallas kernels in interpret mode. Runs the
                    kernel bodies as traced jax on CPU; used for parity tests
                    and CPU debugging of the kernel path.
  * ``auto``      — ``pallas`` when the default backend is TPU, else ``jnp``.

Strategies carry a ``backend=`` field (default ``auto``) so every existing
call site keeps working; the drivers resolve it once at trace time.

The kernel path works on flat ``(m, n)`` matrices — m agents by n parameters.
``stacked_ravel`` flattens a replica pytree to that form (and back) with the
unravel closure cached per (treedef, shapes, dtypes), so the per-step cost is
one reshape+concatenate, not a re-derivation of the tree structure.
"""
from __future__ import annotations

import jax
import jax.flatten_util
import jax.numpy as jnp

# NOTE: the Pallas kernel modules are imported lazily inside the kernel
# branches below — the jnp reference path (and hence repro.core) must stay
# importable on JAX builds where jax.experimental.pallas fails to import.

BACKENDS = ("auto", "jnp", "pallas", "interpret")


def resolve_backend(backend: str = "auto") -> str:
    """Collapse ``auto`` to a concrete backend for the current platform."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return backend


def is_kernel_backend(backend: str) -> bool:
    return resolve_backend(backend) in ("pallas", "interpret")


# --- flat <-> pytree plumbing -------------------------------------------------

_UNRAVEL_CACHE: dict = {}


def stacked_ravel(tree_m):
    """Flatten an (m, ...)-leaved replica pytree to an ``(m, n)`` matrix.

    Returns ``(flat, unravel)`` where ``unravel`` maps an ``(m, n)`` matrix
    back to the original tree structure. The unravel closure depends only on
    (treedef, per-agent leaf shapes, dtypes) and is cached on that key.
    """
    leaves, treedef = jax.tree.flatten(tree_m)
    if not leaves:
        raise ValueError("stacked_ravel: empty pytree")
    m = leaves[0].shape[0]
    for l in leaves:
        if l.ndim < 1 or l.shape[0] != m:
            raise ValueError(
                f"stacked_ravel: every leaf needs leading agent axis {m}, "
                f"got shape {l.shape}"
            )
    key = (treedef, tuple((l.shape[1:], jnp.dtype(l.dtype).name) for l in leaves))
    if key not in _UNRAVEL_CACHE:
        template = jax.tree.unflatten(
            treedef, [jnp.zeros(l.shape[1:], l.dtype) for l in leaves]
        )
        _, unravel_one = jax.flatten_util.ravel_pytree(template)
        _UNRAVEL_CACHE[key] = jax.vmap(unravel_one)
    flat = jax.vmap(lambda t: jax.flatten_util.ravel_pytree(t)[0])(tree_m)
    return flat, _UNRAVEL_CACHE[key]


# --- dispatched primitives ----------------------------------------------------

def decay_accum(acc, g, d, *, backend: str = "auto", block_n: int = 4096):
    """``acc + d * g`` — the fused FMA at the heart of the decay/SGD step.

    ``acc``/``g``: ``(n,)`` or ``(m, n)``; ``d``: scalar, or ``(m,)`` per-agent
    coefficients when the inputs are ``(m, n)`` (the kernel is vmapped over
    the agent axis).
    """
    b = resolve_backend(backend)
    if acc.ndim not in (1, 2) or acc.shape != g.shape:
        raise ValueError(
            f"decay_accum: acc/g must be matching (n,) or (m, n) buffers, "
            f"got {acc.shape} vs {g.shape}"
        )
    if acc.dtype != g.dtype:
        # Enforced on every backend so 'auto' behaves identically on CPU/TPU.
        raise ValueError(
            f"decay_accum: acc/g dtypes must match, got {acc.dtype} vs {g.dtype}"
        )
    d_arr = jnp.asarray(d, acc.dtype)
    if d_arr.ndim not in (0, 1) or (d_arr.ndim == 1 and acc.ndim != 2):
        raise ValueError(
            f"decay_accum: d must be scalar or (m,) with (m, n) inputs, "
            f"got d shape {d_arr.shape} for input shape {acc.shape}"
        )
    if b == "jnp":
        d_b = d_arr[:, None] if d_arr.ndim == 1 else d_arr
        return acc + d_b * g
    from repro.kernels.decay_accum import decay_accum_pallas

    interp = b == "interpret"
    if acc.ndim == 2:
        d_m = jnp.broadcast_to(d_arr, (acc.shape[0],))
        return jax.vmap(
            lambda a, gi, di: decay_accum_pallas(
                a, gi, di, block_n=block_n, interpret=interp
            )
        )(acc, g, d_m)
    return decay_accum_pallas(acc, g, d_arr, block_n=block_n, interpret=interp)


def scale_rows(g, w, *, backend: str = "auto", block_n: int = 4096):
    """Row-scale ``(m, n)`` grads by per-agent weights ``w``: out[i] = w[i]*g[i].

    On the kernel path this is ``decay_accum(g, g, w - 1)`` = g + (w-1)*g —
    both operands alias the same buffer, so no zeros accumulator is ever
    materialised. The drivers avoid even this pass by fusing the weight into
    the SGD coefficient (see ``AggregationStrategy.flat_update``); this
    standalone form backs ``transform`` when called outside the fused update.
    """
    b = resolve_backend(backend)
    if g.ndim != 2:
        raise ValueError(f"scale_rows: g must be (m, n), got {g.shape}")
    w_arr = jnp.asarray(w, g.dtype)
    if w_arr.shape != (g.shape[0],):
        raise ValueError(
            f"scale_rows: w must be ({g.shape[0]},) for g {g.shape}, "
            f"got {w_arr.shape}"
        )
    if b == "jnp":
        return g * w_arr[:, None]
    return decay_accum(g, g, w_arr - 1.0, backend=b, block_n=block_n)


def consensus_mix(g, mixing, *, backend: str = "auto", block_n: int = 2048):
    """One (possibly fused-E, possibly mask-folded) gossip mix: ``mixing @ g``."""
    b = resolve_backend(backend)
    if g.ndim != 2:
        raise ValueError(f"consensus_mix: g must be (m, n), got {g.shape}")
    m = g.shape[0]
    if mixing.shape != (m, m):
        raise ValueError(
            f"consensus_mix: mixing must be ({m}, {m}) for g {g.shape}, "
            f"got {mixing.shape}"
        )
    if b == "jnp":
        return (mixing.astype(jnp.float32) @ g.astype(jnp.float32)).astype(g.dtype)
    from repro.kernels.consensus_step import consensus_step_pallas

    return consensus_step_pallas(
        g, mixing, block_n=block_n, interpret=(b == "interpret")
    )
