"""Pallas TPU kernel: fused top-k select + scatter-accumulate server reduction.

The compressed uplink sync receives each agent's top-k-sparsified payload row
and accumulates it into the server sum. Done naively that is three passes
(materialise the dense ``sent`` matrix, reduce it, subtract for the error
residual); this kernel fuses them into one bandwidth-bound sweep over the
``(m, n)`` payload: per n-block it selects by the precomputed per-agent
magnitude threshold (``|x| >= tau_i`` — ties included, matching the jnp
``segment_sum`` reference in ``repro.kernels.dispatch.topk_scatter``),
accumulates the selected values over the agent axis in fp32, and writes the
per-agent residual ``x - sent`` for the error-feedback carry.

The thresholds ride as an ``(m, 1)`` fp32 column in VMEM (broadcast against
every block); outputs are the ``(n,)`` selected-sum row and the ``(m, n)``
residual matrix.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_scatter_kernel(t_ref, x_ref, osum_ref, ores_ref):
    # fp32 select + accumulate regardless of the buffer dtype; only the
    # outputs are cast back, matching the jnp reference.
    t = t_ref[...]                                   # (m, 1) fp32
    x = x_ref[...].astype(jnp.float32)               # (m, block_n)
    sent = jnp.where(jnp.abs(x) >= t, x, 0.0)        # threshold top-k select
    osum_ref[...] = jnp.sum(sent, axis=0).astype(osum_ref.dtype)
    ores_ref[...] = (x - sent).astype(ores_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def topk_scatter_pallas(x, thresh, *, block_n: int = 4096,
                        interpret: bool = False):
    """x: (m, n) payloads; thresh: (m,) per-agent magnitude thresholds.

    Returns ``(sent_sum, residual)``: the ``(n,)`` fp32-accumulated sum of
    the selected entries over the agent axis (cast to ``x.dtype``) and the
    ``(m, n)`` unselected remainder.
    """
    if x.ndim != 2:
        raise ValueError(f"topk_scatter_pallas: x must be (m, n), got {x.shape}")
    m, n = x.shape
    if thresh.shape != (m,):
        raise ValueError(
            f"topk_scatter_pallas: thresh must be ({m},) for x {x.shape}, "
            f"got {thresh.shape}"
        )
    if block_n < 1:
        raise ValueError(
            f"topk_scatter_pallas: block_n must be >= 1, got {block_n}"
        )
    if n == 0:
        return jnp.zeros((0,), x.dtype), x
    block_n = min(block_n, n)
    pad = (-n) % block_n
    if pad:
        # zero padding is select-neutral: |0| >= tau keeps a 0, adding 0 to
        # the sum and leaving a 0 residual, even for all-zero rows (tau = 0).
        x = jnp.pad(x, ((0, 0), (0, pad)))
    np_ = x.shape[1]
    t_col = jnp.asarray(thresh, jnp.float32).reshape(m, 1)
    ssum, residual = pl.pallas_call(
        _topk_scatter_kernel,
        grid=(np_ // block_n,),
        in_specs=[
            pl.BlockSpec((m, 1), lambda i: (0, 0)),
            pl.BlockSpec((m, block_n), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((m, block_n), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), x.dtype),
            jax.ShapeDtypeStruct((m, np_), x.dtype),
        ],
        interpret=interpret,
    )(t_col, x)
    if pad:
        return ssum[:n], residual[:, :n]
    return ssum, residual
