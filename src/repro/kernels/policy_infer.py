"""Pallas TPU kernel: fused obs-normalize -> policy-MLP -> sample/mean inference.

The serving twin of the training kernels (DESIGN.md §16): one pass over a
bucket-shaped observation batch performs the whole decision — normalize the
raw observations with the fleet's running stats, run the Gaussian policy's
tanh MLP head (``repro.rl.policy.policy_apply``), and either emit the mode
(``sample=False`` — the deterministic serving decision, the density argmax of
the tanh-squashed Gaussian) or add ``exp(log_std) * noise`` for stochastic
serving. Done eagerly that is five kernel launches and four ``(B, hidden)``
temporaries; fused it is a single grid sweep over batch blocks with the
(tiny) weight matrices resident in VMEM and every matmul accumulating fp32
on the MXU (``preferred_element_type``), matching the dispatch fp32 contract.

The noise operand exists in both modes so the serving engine can donate it:
the ``(B, act_dim)`` buffer is dead after the decision and aliases the action
output (verified by the jaxpr audit's JXA004 rule on the registered
``serve.engine_step`` entry).

Shapes here are serving-sized, not MXU-sized (obs_dim ~6, hidden 64,
act_dim ~1): on a real TPU Mosaic pads the lanes to 128, so the kernel is
bandwidth- not FLOP-bound — which is exactly the point: one HBM sweep over
the observation batch instead of five.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _policy_infer_kernel(nm_ref, ns_ref, w1_ref, b1_ref, w2_ref, b2_ref,
                         w3_ref, b3_ref, ls_ref, obs_ref, noise_ref, act_ref,
                         *, sample: bool):
    # fp32 throughout regardless of buffer dtypes; only the action output is
    # cast back, matching the jnp reference path in dispatch.policy_infer.
    x = (obs_ref[...].astype(jnp.float32) - nm_ref[...]) / ns_ref[...]
    h = jnp.tanh(
        jnp.dot(x, w1_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32) + b1_ref[...]
    )
    h = jnp.tanh(
        jnp.dot(h, w2_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32) + b2_ref[...]
    )
    mean = jnp.tanh(
        jnp.dot(h, w3_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32) + b3_ref[...]
    )
    if sample:
        act = mean + jnp.exp(ls_ref[...]) * noise_ref[...].astype(jnp.float32)
    else:
        act = mean
    act_ref[...] = act.astype(act_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("sample", "block_b", "interpret")
)
def policy_infer_pallas(obs, w1, b1, w2, b2, w3, b3, log_std,
                        norm_mean, norm_std, noise, *,
                        sample: bool = False, block_b: int = 256,
                        interpret: bool = False):
    """obs: (B, obs_dim) observations; weights: the ``params["pi"]`` head.

    ``norm_mean``/``norm_std``: (obs_dim,) fp32 normalization stats;
    ``noise``: (B, act_dim) standard-normal draws (ignored unless ``sample``
    but always an operand — the serving engine donates it). Returns the
    ``(B, act_dim)`` actions in ``obs.dtype``.
    """
    if obs.ndim != 2:
        raise ValueError(f"policy_infer_pallas: obs must be (B, obs_dim), "
                         f"got {obs.shape}")
    B, obs_dim = obs.shape
    hidden = w1.shape[1]
    act_dim = w3.shape[1]
    if w1.shape != (obs_dim, hidden):
        raise ValueError(
            f"policy_infer_pallas: w1 must be ({obs_dim}, hidden), "
            f"got {w1.shape}"
        )
    if w2.shape != (hidden, hidden) or w3.shape[0] != hidden:
        raise ValueError(
            f"policy_infer_pallas: w2/w3 must chain from hidden={hidden}, "
            f"got {w2.shape} / {w3.shape}"
        )
    if noise.shape != (B, act_dim):
        raise ValueError(
            f"policy_infer_pallas: noise must be ({B}, {act_dim}), "
            f"got {noise.shape}"
        )
    for name, v, shape in (("b1", b1, (hidden,)), ("b2", b2, (hidden,)),
                           ("b3", b3, (act_dim,)),
                           ("log_std", log_std, (act_dim,)),
                           ("norm_mean", norm_mean, (obs_dim,)),
                           ("norm_std", norm_std, (obs_dim,))):
        if v.shape != shape:
            raise ValueError(
                f"policy_infer_pallas: {name} must be {shape}, got {v.shape}"
            )
    if block_b < 1:
        raise ValueError(
            f"policy_infer_pallas: block_b must be >= 1, got {block_b}"
        )
    if B == 0:
        return jnp.zeros((0, act_dim), obs.dtype)
    block_b = min(block_b, B)
    pad = (-B) % block_b
    if pad:
        # zero rows are decision-neutral: each batch row is independent, so
        # padded rows only produce extra (discarded) actions.
        obs = jnp.pad(obs, ((0, pad), (0, 0)))
        noise = jnp.pad(noise, ((0, pad), (0, 0)))
    Bp = obs.shape[0]
    full = lambda *shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))
    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    out = pl.pallas_call(
        functools.partial(_policy_infer_kernel, sample=sample),
        grid=(Bp // block_b,),
        in_specs=[
            full(obs_dim), full(obs_dim),                 # norm mean / std
            full(obs_dim, hidden), full(hidden),          # w1 / b1
            full(hidden, hidden), full(hidden),           # w2 / b2
            full(hidden, act_dim), full(act_dim),         # w3 / b3
            full(act_dim),                                # log_std
            pl.BlockSpec((block_b, obs_dim), lambda i: (i, 0)),
            pl.BlockSpec((block_b, act_dim), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, act_dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, act_dim), obs.dtype),
        interpret=interpret,
    )(f32(norm_mean), f32(norm_std), w1, b1, w2, b2, w3, b3,
      f32(log_std), obs, noise)
    return out[:B] if pad else out
