"""Pallas TPU kernel: fused decay-weighted gradient accumulation (T3/T4 inner loop).

acc <- acc + D(s) * g over flat parameter buffers. A single fused FMA pass
(instead of scale-then-add, which reads g twice and writes a temp); purely
bandwidth-bound, tiled 1-D through VMEM. The decay weight is a scalar operand
in SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _decay_accum_kernel(d_ref, acc_ref, g_ref, o_ref):
    # fp32 accumulation regardless of the buffer dtype (d rides in SMEM as
    # fp32); only the output is cast back, matching the jnp reference.
    d = d_ref[0]
    out = acc_ref[...].astype(jnp.float32) + d * g_ref[...].astype(jnp.float32)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def decay_accum_pallas(acc, g, d, *, block_n: int = 4096, interpret: bool = False):
    """acc, g: (n,) flat buffers; d: scalar decay weight. Returns acc + d*g."""
    if acc.ndim != 1 or acc.shape != g.shape:
        raise ValueError(
            f"decay_accum_pallas: acc and g must be identical (n,) buffers, "
            f"got acc {acc.shape} vs g {g.shape}"
        )
    if acc.dtype != g.dtype:
        raise ValueError(
            f"decay_accum_pallas: acc/g dtypes must match, got "
            f"{acc.dtype} vs {g.dtype}"
        )
    if jnp.ndim(d) != 0:
        raise ValueError(f"decay_accum_pallas: d must be a scalar, got shape {jnp.shape(d)}")
    if block_n < 1:
        raise ValueError(f"decay_accum_pallas: block_n must be >= 1, got {block_n}")
    n = acc.shape[0]
    if n == 0:
        return acc
    block_n = min(block_n, n)
    pad = (-n) % block_n
    if pad:
        acc = jnp.pad(acc, (0, pad))
        g = jnp.pad(g, (0, pad))
    np_ = acc.shape[0]
    d_arr = jnp.asarray(d, jnp.float32).reshape(1)
    out = pl.pallas_call(
        _decay_accum_kernel,
        grid=(np_ // block_n,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), acc.dtype),
        interpret=interpret,
    )(d_arr, acc, g)
    return out[:n] if pad else out
