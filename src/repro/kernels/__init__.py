"""Pallas kernel layer + backend dispatch.

Per-kernel modules hold the Pallas bodies; ``ops`` exposes jit'd wrappers
with CPU interpret-mode fallback; ``ref`` holds the pure-jnp oracles; and
``dispatch`` is the backend switch (jnp | pallas | interpret) that the
federated drivers route the hot-path transforms through.
"""
from repro.kernels import dispatch
from repro.kernels.dispatch import (
    BACKENDS,
    OPT_KINDS,
    clear_caches,
    consensus_mix,
    flat_opt_update,
    is_kernel_backend,
    resolve_backend,
    row_mean,
    scale_rows,
    stacked_ravel,
    stacked_ravel_spec,
)

# NOTE: dispatch.decay_accum is deliberately NOT re-exported here: the package
# attribute `repro.kernels.decay_accum` is claimed by the kernel submodule of
# the same name the moment it is imported, which would silently shadow the
# function. Use `dispatch.decay_accum`.

__all__ = [
    "BACKENDS",
    "OPT_KINDS",
    "clear_caches",
    "consensus_mix",
    "dispatch",
    "flat_opt_update",
    "is_kernel_backend",
    "resolve_backend",
    "row_mean",
    "scale_rows",
    "stacked_ravel",
    "stacked_ravel_spec",
]
