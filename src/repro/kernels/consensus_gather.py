"""Pallas TPU kernel: sparse neighbor-list gossip step (O(m*k), not O(m^2)).

One consensus round on a sparse topology: ``out[i] = sum_k w[i,k] * g[idx[i,k]]``
over agent i's padded closed neighborhood (``repro.core.topology.NeighborList``
layout — self included, padding gathers the agent's own row with weight exactly
0.0). The neighbor indices arrive via scalar prefetch so the BlockSpec index
map can gather arbitrary *rows* of ``g`` straight from HBM: the grid is
``(m, n_blocks, k_max)`` with k innermost, each step DMAs one ``(1, block_n)``
neighbor slice into VMEM and accumulates it fp32 into a VMEM scratch row, and
the accumulated row is flushed to the output on the last k step (output
revisiting across the innermost grid dim keeps the store cheap).

Per gossip round this reads ``m * (k_max+?) * block`` rows instead of running
an ``(m,m) x (m,n)`` matmul — at m=10k, k=8 that is ~1000x less work, and the
cost scales ~O(m*k*n) (the scale bench fits the exponent).

Accumulation order matches the jnp reference in ``dispatch.consensus_gather``
(ascending neighbor index, sequential adds), so interpret-mode parity against
the eager jnp path is bitwise; see DESIGN.md §14 for the parity contract.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, g_ref, w_ref, o_ref, acc_ref):
    k = pl.program_id(2)
    k_max = pl.num_programs(2)
    # (1, block_n) neighbor slice, weighted; fp32 accumulation throughout.
    row = g_ref[...].astype(jnp.float32) * w_ref[0, 0]

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = row

    @pl.when(k > 0)
    def _accum():
        acc_ref[...] += row

    @pl.when(k == k_max - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def consensus_gather_pallas(
    g, idx, w, *, block_n: int = 2048, interpret: bool = False
):
    """g: (m, n) flat grads; idx/w: (m, k_max) neighbor ids / edge weights.

    Returns the (m, n) post-gossip buffer in ``g.dtype``. ``idx`` must hold
    in-range row ids with padding pointing at the agent's own row, and ``w``
    must be exactly 0.0 on padding (the NeighborList weight contract) — the
    kernel gathers every slot unconditionally and relies on the zero weight.
    """
    if g.ndim != 2:
        raise ValueError(f"consensus_gather_pallas: g must be (m, n), got {g.shape}")
    m, n = g.shape
    if idx.ndim != 2 or idx.shape[0] != m:
        raise ValueError(
            f"consensus_gather_pallas: idx must be ({m}, k_max) for g {g.shape}, "
            f"got {idx.shape}"
        )
    if w.shape != idx.shape:
        raise ValueError(
            f"consensus_gather_pallas: w must match idx {idx.shape}, got {w.shape}"
        )
    if not jnp.issubdtype(idx.dtype, jnp.integer):
        raise ValueError(
            f"consensus_gather_pallas: idx must be integer, got {idx.dtype}"
        )
    if block_n < 1:
        raise ValueError(
            f"consensus_gather_pallas: block_n must be >= 1, got {block_n}"
        )
    if n == 0:
        return g
    k_max = idx.shape[1]
    block_n = min(block_n, n)
    pad = (-n) % block_n
    gp = jnp.pad(g, ((0, 0), (0, pad))) if pad else g
    np_ = gp.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m, np_ // block_n, k_max),
        in_specs=[
            # neighbor row slice: the scalar-prefetched idx picks the g row
            pl.BlockSpec((1, block_n), lambda i, j, k, idx_ref: (idx_ref[i, k], j)),
            # matching edge weight as a (1, 1) block
            pl.BlockSpec((1, 1), lambda i, j, k, idx_ref: (i, k)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i, j, k, idx_ref: (i, j)),
        scratch_shapes=[pltpu.VMEM((1, block_n), jnp.float32)],
    )
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, np_), g.dtype),
        interpret=interpret,
    )(jnp.asarray(idx, jnp.int32), gp, jnp.asarray(w, jnp.float32))
    return out[:, :n] if pad else out
