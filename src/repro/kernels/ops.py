"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs as traced Python, proving correctness; on TPU they compile to
Mosaic. `interpret=None` auto-detects from the default backend.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.consensus_step import consensus_step_pallas
from repro.kernels.decay_accum import decay_accum_pallas
from repro.kernels.swa_attention import swa_attention_pallas
from repro.kernels.wkv6 import wkv6_pallas


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def wkv6(r, k, v, w, u, state, *, chunk: int = 256, interpret: Optional[bool] = None):
    return wkv6_pallas(r, k, v, w, u, state, chunk=chunk,
                       interpret=_auto_interpret(interpret))


def swa_attention(q, k, v, *, window=None, causal=True, block_q=128, block_kv=128,
                  interpret: Optional[bool] = None):
    return swa_attention_pallas(
        q, k, v, window=window, causal=causal, block_q=block_q,
        block_kv=block_kv, interpret=_auto_interpret(interpret),
    )


def consensus_step(g, mixing, *, block_n=2048, interpret: Optional[bool] = None):
    return consensus_step_pallas(g, mixing, block_n=block_n,
                                 interpret=_auto_interpret(interpret))


def consensus_step_tree(grads_m, mixing, **kw):
    """Apply the gossip mix to a pytree whose leaves have leading agent axis."""
    leaves, treedef = jax.tree.flatten(grads_m)
    m = leaves[0].shape[0]
    flat = jnp.concatenate([l.reshape(m, -1) for l in leaves], axis=1)
    mixed = consensus_step(flat, mixing, **kw)
    out, off = [], 0
    for l in leaves:
        n = l[0].size
        out.append(mixed[:, off:off + n].reshape(l.shape))
        off += n
    return jax.tree.unflatten(treedef, out)


def decay_accum(acc, g, d, *, block_n=4096, interpret: Optional[bool] = None):
    return decay_accum_pallas(acc, g, d, block_n=block_n,
                              interpret=_auto_interpret(interpret))
