"""Pallas TPU kernel: chunked WKV6 recurrence (RWKV6 time-mix hot loop).

TPU adaptation of the (GPU-targeted) RWKV6 CUDA kernel: instead of one thread
per channel, we tile (batch*head) over the outer grid and stream the time axis
through VMEM in chunks, carrying the (D, D) state in a VMEM scratch across the
sequential chunk iterations (TPU grids execute minor-most-last sequentially,
so the scratch persists along the T dimension). Within a chunk the recurrence
is a serial fori_loop over time, but each step is a rank-1 update + matvec on
(D, D) = (64, 64) tiles that map onto the VPU/MXU.

Memory: per grid step the kernel touches 4 * chunk * D inputs + chunk * D
outputs + a D*D state — everything fits comfortably in VMEM (chunk=256, D=64:
~320 KiB fp32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref, state):
    t_idx = pl.program_id(1)
    n_t = pl.num_programs(1)

    @pl.when(t_idx == 0)
    def _init():
        state[...] = s0_ref[0]

    u = u_ref[0]                       # (D,)
    chunk = r_ref.shape[1]

    def step(i, _):
        r_t = r_ref[0, i]              # (D,)
        k_t = k_ref[0, i]
        v_t = v_ref[0, i]
        w_t = w_ref[0, i]
        kv = k_t[:, None] * v_t[None, :]            # (D, D)
        s = state[...]
        y = jnp.sum(r_t[:, None] * (s + u[:, None] * kv), axis=0)
        y_ref[0, i] = y
        state[...] = w_t[:, None] * s + kv
        return ()

    jax.lax.fori_loop(0, chunk, step, ())

    @pl.when(t_idx == n_t - 1)
    def _final():
        sT_ref[0] = state[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(r, k, v, w, u, state, *, chunk: int = 256, interpret: bool = False):
    """r,k,v,w: (B,T,H,D) fp32; u: (H,D); state: (B,H,D,D).

    Returns (y (B,T,H,D), final_state (B,H,D,D)).
    """
    b, t, h, d = r.shape
    if t % chunk:
        chunk = t  # degenerate: single chunk
    bh = b * h

    def flat(x):  # (B,T,H,D) -> (B*H, T, D)
        return x.transpose(0, 2, 1, 3).reshape(bh, t, d)

    rf, kf, vf, wf = (flat(x) for x in (r, k, v, w))
    uf = jnp.broadcast_to(u[None], (b, h, d)).reshape(bh, d)
    sf = state.reshape(bh, d, d)

    n_chunks = t // chunk
    grid = (bh, n_chunks)
    seq_spec = pl.BlockSpec((1, chunk, d), lambda i, j: (i, j, 0))
    y, s_out = pl.pallas_call(
        _wkv6_kernel,
        grid=grid,
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, d, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, d, d), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, uf, sf)

    y = y.reshape(b, h, t, d).transpose(0, 2, 1, 3)
    return y, s_out.reshape(b, h, d, d)
