"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u, state):
    """WKV6 recurrence oracle. r,k,v,w: (B,T,H,D); u: (H,D); state: (B,H,D,D).

    y_t[j] = sum_i r_t[i] * (S[i,j] + u[i] * k_t[i] * v_t[j])
    S     <- diag(w_t) S + k_t v_t^T
    Returns (y (B,T,H,D), final state).
    """
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhi,bhij->bhj", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, y

    seq = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, seq)
    return jnp.moveaxis(ys, 0, 1), state


def swa_attention_ref(q, k, v, *, window=None, causal=True):
    """Flash/SWA oracle. q: (B,Sq,H,D), k/v: (B,Sk,H,D) (KV already repeated).
    Softmax in fp32; sliding window counts strictly greater than (pos - window)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    qp = jnp.arange(sq)
    kp = jnp.arange(sk)
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * d**-0.5
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= kp[None, :] <= qp[:, None]
    if window is not None:
        ok &= kp[None, :] > qp[:, None] - window
    scores = jnp.where(ok[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", p.astype(q.dtype), v)


def consensus_step_ref(g, mixing):
    """One (or fused-E) consensus mix: out[i] = sum_l P[i,l] g[l].

    g: (m, n) flattened per-agent gradient buffers; mixing: (m, m).
    """
    return (mixing @ g.astype(jnp.float32)).astype(g.dtype)


def decay_accum_ref(acc, g, d):
    """Decay-weighted gradient accumulation: acc + d * g (d scalar)."""
    return acc + d * g.astype(acc.dtype)
