"""Pallas TPU kernel: fused consensus gossip update (paper eq. 23).

Computes out = P @ G where G is the (m, n) matrix of flattened per-agent
gradient buffers and P = (I - eps*La)^E is the (precomputed, tiny) fused
mixing matrix. On TPU the m axis is small (agents) while n is the full
parameter count, so we tile n over the grid and keep the whole (m, m) mixing
matrix resident in VMEM — each grid step is one (m,m)x(m,bn) matmul on the
MXU, streaming G through VMEM exactly once (the kernel is bandwidth-bound;
arithmetic intensity m flops/byte).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _consensus_kernel(p_ref, g_ref, o_ref):
    p = p_ref[...]                       # (m, m) fp32
    g = g_ref[...].astype(jnp.float32)   # (m, bn)
    # Full-fp32 accumulation: without preferred_element_type/HIGHEST the MXU
    # runs fp32 matmuls as truncated-bf16 passes, which drifts from the jnp
    # reference (and loses mantissa on bf16/fp16 gradient buffers).
    out = jax.lax.dot_general(
        p, g,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
        precision=jax.lax.Precision.HIGHEST,
    )
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def consensus_step_pallas(g, mixing, *, block_n: int = 2048, interpret: bool = False):
    """g: (m, n) per-agent flattened grads; mixing: (m, m). Returns (m, n)."""
    if g.ndim != 2:
        raise ValueError(f"consensus_step_pallas: g must be (m, n), got {g.shape}")
    m, n = g.shape
    # A larger-than-(m, m) mixing matrix would otherwise be silently cropped
    # to its top-left block by the BlockSpec tiling below.
    if mixing.shape != (m, m):
        raise ValueError(
            f"consensus_step_pallas: mixing must be ({m}, {m}) for g {g.shape}, "
            f"got {mixing.shape}"
        )
    if not jnp.issubdtype(mixing.dtype, jnp.floating):
        raise ValueError(
            f"consensus_step_pallas: mixing must be floating, got {mixing.dtype}"
        )
    if block_n < 1:
        raise ValueError(f"consensus_step_pallas: block_n must be >= 1, got {block_n}")
    if n == 0:
        return g
    block_n = min(block_n, n)
    pad = (-n) % block_n
    gp = jnp.pad(g, ((0, 0), (0, pad))) if pad else g
    np_ = gp.shape[1]
    out = pl.pallas_call(
        _consensus_kernel,
        grid=(np_ // block_n,),
        in_specs=[
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, np_), g.dtype),
        interpret=interpret,
    )(mixing.astype(jnp.float32), gp)
    return out[:, :n] if pad else out
