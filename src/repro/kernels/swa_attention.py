"""Pallas TPU kernel: flash-style causal attention with optional sliding window.

Standard flash schedule adapted to SWA: grid (B, H, n_q_blocks, n_kv_blocks)
with the kv-block axis minor (sequential), carrying the online-softmax
running max / denominator / accumulator in VMEM scratch. Out-of-window or
fully-future kv blocks are skipped entirely with pl.when, which is where the
sub-quadratic win comes from for long_500k-style shapes: only
ceil(window / block_kv) + 1 kv blocks are touched per q block.

Block sizes default to (128, 128) to align with the MXU; D (head_dim) rides
along whole.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _swa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                block_q, block_kv, window, causal, scale):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    n_k = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_kv

    # Block-level skip: any overlap with [q_pos - window + 1, q_pos]?
    q_lo, q_hi = q_start, q_start + block_q - 1
    k_lo = k_start
    needed = True
    if causal:
        needed = k_lo <= q_hi
    if window is not None:
        needed = jnp.logical_and(needed, (k_start + block_kv - 1) > q_lo - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = (q @ k.T) * scale                          # (bq, bk)
        qp = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kp = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            ok &= kp <= qp
        if window is not None:
            ok &= kp > qp - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + p @ v
        m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "causal", "block_q", "block_kv", "interpret")
)
def swa_attention_pallas(q, k, v, *, window=None, causal=True,
                         block_q: int = 128, block_kv: int = 128,
                         interpret: bool = False):
    """q: (B,Sq,H,D); k/v: (B,Sk,H,D) (KV repeated to H). Returns (B,Sq,H,D)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    block_q = min(block_q, sq)
    block_kv = min(block_kv, sk)
    if sq % block_q or sk % block_kv:
        raise ValueError("sequence lengths must divide block sizes")

    def to_bhsd(x):
        return x.transpose(0, 2, 1, 3)

    qb, kb, vb = to_bhsd(q), to_bhsd(k), to_bhsd(v)
    grid = (b, h, sq // block_q, sk // block_kv)
    kern = functools.partial(
        _swa_kernel, block_q=block_q, block_kv=block_kv,
        window=window, causal=causal, scale=d**-0.5,
    )
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, d), lambda bi, hi, qi, ki: (bi, hi, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, d), lambda bi, hi, qi, ki: (bi, hi, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qb, kb, vb)
    return out.transpose(0, 2, 1, 3)
