"""Pallas TPU kernels for the flat-carry federated loop: server averaging
(eq. 11) and the fused local optimizer updates.

All three kernels are bandwidth-bound single passes over flat parameter
buffers, tiled 1-D through VMEM like ``decay_accum_pallas``; scalars ride in
SMEM. Accumulation is fp32 throughout: inputs are upcast on load, moment
buffers are fp32 operands, and only the parameter output is cast back to the
parameter dtype — so bf16 parameter/gradient buffers keep fp32-quality
optimizer state (the prerequisite for the bf16-buffer mode on the roadmap).

  * ``row_mean_pallas``        — (m, n) -> (n,) mean over the agent axis:
                                 the server averaging reduction.
  * ``momentum_update_pallas`` — mu <- beta*mu + w*g; p <- p - lr*mu
                                 (optionally Nesterov), one fused pass.
  * ``adam_update_pallas``     — bias-corrected Adam(W) step with fp32
                                 mu/nu moments, one fused pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pad1(x, pad):
    return jnp.pad(x, (0, pad)) if pad else x


# --- server averaging ---------------------------------------------------------

def _row_mean_kernel(g_ref, o_ref):
    o_ref[...] = jnp.mean(g_ref[...].astype(jnp.float32), axis=0).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def row_mean_pallas(g, *, block_n: int = 4096, interpret: bool = False):
    """g: (m, n) flat replica buffers. Returns the (n,) mean over agents."""
    if g.ndim != 2:
        raise ValueError(f"row_mean_pallas: g must be (m, n), got {g.shape}")
    if block_n < 1:
        raise ValueError(f"row_mean_pallas: block_n must be >= 1, got {block_n}")
    m, n = g.shape
    if n == 0:
        return jnp.zeros((0,), g.dtype)
    block_n = min(block_n, n)
    pad = (-n) % block_n
    gp = jnp.pad(g, ((0, 0), (0, pad))) if pad else g
    np_ = gp.shape[1]
    out = pl.pallas_call(
        _row_mean_kernel,
        grid=(np_ // block_n,),
        in_specs=[pl.BlockSpec((m, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), g.dtype),
        interpret=interpret,
    )(gp)
    return out[:n] if pad else out


# --- fused momentum update ----------------------------------------------------

def _momentum_kernel(s_ref, p_ref, g_ref, mu_ref, op_ref, omu_ref, *, nesterov):
    w, lr, beta = s_ref[0], s_ref[1], s_ref[2]
    wg = w * g_ref[...].astype(jnp.float32)
    mu = beta * mu_ref[...] + wg
    upd = beta * mu + wg if nesterov else mu
    omu_ref[...] = mu
    op_ref[...] = (p_ref[...].astype(jnp.float32) - lr * upd).astype(op_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("beta", "nesterov", "block_n", "interpret")
)
def momentum_update_pallas(
    p, g, mu, w, lr, beta,
    *, nesterov: bool = False, block_n: int = 4096, interpret: bool = False,
):
    """One fused heavy-ball step on flat (n,) buffers.

    p/g: (n,) params and (already-transformed) grads; mu: (n,) fp32 momentum;
    w: scalar within-period weight folded into g; lr/beta: scalars.
    Returns (new_p, new_mu).
    """
    if p.ndim != 1 or p.shape != g.shape or p.shape != mu.shape:
        raise ValueError(
            f"momentum_update_pallas: p/g/mu must be identical (n,) buffers, "
            f"got {p.shape} / {g.shape} / {mu.shape}"
        )
    if p.dtype != g.dtype:
        raise ValueError(
            f"momentum_update_pallas: p/g dtypes must match, got "
            f"{p.dtype} vs {g.dtype}"
        )
    if mu.dtype != jnp.float32:
        raise ValueError(
            f"momentum_update_pallas: mu must be an fp32 accumulator, "
            f"got {mu.dtype}"
        )
    if jnp.ndim(w) != 0 or jnp.ndim(lr) != 0:
        raise ValueError("momentum_update_pallas: w and lr must be scalars")
    if block_n < 1:
        raise ValueError(
            f"momentum_update_pallas: block_n must be >= 1, got {block_n}"
        )
    n = p.shape[0]
    if n == 0:
        return p, mu
    block_n = min(block_n, n)
    pad = (-n) % block_n
    pp, gp, mup = _pad1(p, pad), _pad1(g, pad), _pad1(mu, pad)
    np_ = pp.shape[0]
    scal = jnp.stack(
        [jnp.asarray(w, jnp.float32), jnp.asarray(lr, jnp.float32),
         jnp.asarray(beta, jnp.float32)]
    )
    blk = pl.BlockSpec((block_n,), lambda i: (i,))
    new_p, new_mu = pl.pallas_call(
        functools.partial(_momentum_kernel, nesterov=nesterov),
        grid=(np_ // block_n,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), blk, blk, blk],
        out_specs=[blk, blk],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), p.dtype),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
        ],
        interpret=interpret,
    )(scal, pp, gp, mup)
    if pad:
        return new_p[:n], new_mu[:n]
    return new_p, new_mu


# --- fused Adam(W) update -----------------------------------------------------

def _adam_kernel(s_ref, p_ref, g_ref, mu_ref, nu_ref, op_ref, omu_ref, onu_ref):
    w, lr = s_ref[0], s_ref[1]
    b1, b2, eps, wd = s_ref[2], s_ref[3], s_ref[4], s_ref[5]
    bc1, bc2 = s_ref[6], s_ref[7]
    wg = w * g_ref[...].astype(jnp.float32)
    mu = b1 * mu_ref[...] + (1.0 - b1) * wg
    nu = b2 * nu_ref[...] + (1.0 - b2) * wg * wg
    p32 = p_ref[...].astype(jnp.float32)
    step = (mu / bc1) / (jnp.sqrt(nu / bc2) + eps) + wd * p32
    omu_ref[...] = mu
    onu_ref[...] = nu
    op_ref[...] = (p32 - lr * step).astype(op_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("b1", "b2", "eps", "weight_decay", "block_n", "interpret"),
)
def adam_update_pallas(
    p, g, mu, nu, w, lr, bc1, bc2,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    block_n: int = 4096,
    interpret: bool = False,
):
    """One fused bias-corrected Adam(W) step on flat (n,) buffers.

    p/g: (n,); mu/nu: (n,) fp32 moments; w: scalar within-period weight;
    lr: scalar; bc1/bc2: precomputed bias corrections 1-b^t (scalars — the
    step counter lives outside the kernel). Returns (new_p, new_mu, new_nu).
    """
    if p.ndim != 1 or not (p.shape == g.shape == mu.shape == nu.shape):
        raise ValueError(
            f"adam_update_pallas: p/g/mu/nu must be identical (n,) buffers, "
            f"got {p.shape} / {g.shape} / {mu.shape} / {nu.shape}"
        )
    if p.dtype != g.dtype:
        raise ValueError(
            f"adam_update_pallas: p/g dtypes must match, got "
            f"{p.dtype} vs {g.dtype}"
        )
    if mu.dtype != jnp.float32 or nu.dtype != jnp.float32:
        raise ValueError(
            f"adam_update_pallas: mu/nu must be fp32 accumulators, got "
            f"{mu.dtype} / {nu.dtype}"
        )
    for name, s in (("w", w), ("lr", lr), ("bc1", bc1), ("bc2", bc2)):
        if jnp.ndim(s) != 0:
            raise ValueError(f"adam_update_pallas: {name} must be a scalar")
    if block_n < 1:
        raise ValueError(f"adam_update_pallas: block_n must be >= 1, got {block_n}")
    n = p.shape[0]
    if n == 0:
        return p, mu, nu
    block_n = min(block_n, n)
    pad = (-n) % block_n
    pp, gp = _pad1(p, pad), _pad1(g, pad)
    mup, nup = _pad1(mu, pad), _pad1(nu, pad)
    np_ = pp.shape[0]
    scal = jnp.stack(
        [jnp.asarray(w, jnp.float32), jnp.asarray(lr, jnp.float32),
         jnp.float32(b1), jnp.float32(b2), jnp.float32(eps),
         jnp.float32(weight_decay), jnp.asarray(bc1, jnp.float32),
         jnp.asarray(bc2, jnp.float32)]
    )
    blk = pl.BlockSpec((block_n,), lambda i: (i,))
    new_p, new_mu, new_nu = pl.pallas_call(
        _adam_kernel,
        grid=(np_ // block_n,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), blk, blk, blk, blk],
        out_specs=[blk, blk, blk],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), p.dtype),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
        ],
        interpret=interpret,
    )(scal, pp, gp, mup, nup)
    if pad:
        return new_p[:n], new_mu[:n], new_nu[:n]
    return new_p, new_mu, new_nu
