"""Scenario registry: named traffic tasks + heterogeneous-fleet presets.

Each :class:`Scenario` pairs a static :class:`~repro.rl.env.EnvConfig` with a
default heterogeneity recipe — which :class:`~repro.rl.env.EnvParams` fields
a fleet perturbs per agent, and by how much. ``make_fleet`` turns a scenario
name into ``(EnvConfig, EnvParams)`` where the params pytree carries a
leading (m,) axis of per-agent MDPs, ready for ``repro.rl.rollout`` and the
``num_envs``/``env_params`` knobs on ``FedRLConfig``.

Registered scenarios (DESIGN.md §3):

* ``figure_eight``     — the paper's intersection analog (14 vehicles, 7 RL).
* ``merge``            — the paper's merge-friction ring (50 vehicles, 5 RL).
* ``ring_attenuation`` — classic platoon wave-attenuation: one RL vehicle
                         among 21 IDM cars on a plain ring (no slow zone);
                         heterogeneity perturbs the IDM constants and dt, so
                         every agent fights a different stop-and-go wave.
* ``mixed_vmax``       — a 16-vehicle ring where the fleet's heterogeneity
                         is concentrated in the speed limits (v_max, idm_v0
                         ±35% per agent): the mixed-capability fleet stress
                         case for the convergence-bound experiments.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from repro.rl.env import (
    FIGURE_EIGHT,
    HETERO_FIELDS,
    MERGE,
    EnvConfig,
    EnvParams,
    perturb_params,
)

RING_ATTENUATION = EnvConfig(
    name="ring_attenuation",
    n_vehicles=22,
    rl_indices=(0,),
    length=260.0,
    v_max=9.0,
    idm_v0=9.0,
)

MIXED_VMAX = EnvConfig(
    name="mixed_vmax",
    n_vehicles=16,
    rl_indices=tuple(range(0, 16, 4)),   # 4 RL vehicles
    length=250.0,
    v_max=9.0,
    idm_v0=9.0,
)


@dataclasses.dataclass(frozen=True)
class Scenario:
    cfg: EnvConfig
    hetero_scale: float            # default per-agent perturbation scale
    hetero_fields: Tuple[str, ...]  # which EnvParams fields vary per agent
    description: str


SCENARIOS: dict = {
    "figure_eight": Scenario(
        cfg=FIGURE_EIGHT,
        hetero_scale=0.2,
        hetero_fields=HETERO_FIELDS,
        description="intersection analog: slow zone on a 230m loop, 7 RL",
    ),
    "merge": Scenario(
        cfg=MERGE,
        hetero_scale=0.2,
        hetero_fields=HETERO_FIELDS,
        description="merge-friction zone on a 700m ring, 5 RL of 50",
    ),
    "ring_attenuation": Scenario(
        cfg=RING_ATTENUATION,
        hetero_scale=0.25,
        hetero_fields=("dt", "idm_T", "idm_a", "idm_b", "idm_v0"),
        description="platoon wave attenuation: 1 RL of 22, per-agent IDM/dt",
    ),
    "mixed_vmax": Scenario(
        cfg=MIXED_VMAX,
        hetero_scale=0.35,
        hetero_fields=("v_max", "idm_v0"),
        description="mixed-capability fleet: per-agent speed limits +/-35%",
    ),
}


def get_scenario(name: str) -> Scenario:
    if name not in SCENARIOS:
        raise ValueError(
            f"unknown scenario {name!r}; registered: {sorted(SCENARIOS)}"
        )
    return SCENARIOS[name]


def make_fleet(
    name: str,
    m: int,
    key,
    hetero: Optional[float] = None,
    fields: Optional[Sequence[str]] = None,
) -> Tuple[EnvConfig, EnvParams]:
    """Build an m-agent heterogeneous fleet for a registered scenario.

    ``hetero`` overrides the scenario's default perturbation scale (0 gives m
    identical MDPs); ``fields`` overrides which params vary. Returns the
    static config plus (m,)-stacked per-agent EnvParams.
    """
    sc = get_scenario(name)
    scale = sc.hetero_scale if hetero is None else hetero
    flds = tuple(fields) if fields is not None else sc.hetero_fields
    return sc.cfg, perturb_params(sc.cfg, key, m, scale, fields=flds)
