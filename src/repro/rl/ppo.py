"""Policy-gradient losses: PPO (paper's default), TRPO-as-KL-penalty, TAC.

The paper uses PPO [18] for Figs. 4-6, TRPO [17] for Fig. 8 and TAC [19] for
Fig. 9 purely to show the consensus method is optimizer-agnostic; we implement
TRPO as its KL-penalized trust-region form and TAC as Tsallis-entropy (q=2)
regularized PPO (loss-level fidelity; noted in DESIGN.md §8).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.rl.policy import (
    gaussian_entropy,
    gaussian_logp,
    policy_apply,
    policy_value,
    tsallis2_entropy,
)


def gae(rewards, values, last_value, *, gamma=0.99, lam=0.95):
    """rewards/values: (P,); returns (advantages (P,), returns (P,))."""
    def step(carry, inp):
        adv_next, v_next = carry
        r, v = inp
        delta = r + gamma * v_next - v
        adv = delta + gamma * lam * adv_next
        return (adv, v), adv

    (_, _), advs = jax.lax.scan(
        step, (jnp.zeros(()), last_value), (rewards, values), reverse=True
    )
    return advs, advs + values


def _policy_terms(params, traj):
    mean, log_std = policy_apply(params, traj["obs"])
    logp = gaussian_logp(traj["act"], mean, log_std)
    ratio = jnp.exp(logp - traj["logp_old"])
    adv = traj["adv"]
    adv = (adv - adv.mean()) / (adv.std() + 1e-8)
    v = policy_value(params, traj["obs"])
    vf = jnp.mean((v - traj["ret"]) ** 2)
    return ratio, adv, vf, log_std, logp


def ppo_loss(params, traj, *, clip=0.2, vf_coef=0.5, ent_coef=0.01):
    ratio, adv, vf, log_std, _ = _policy_terms(params, traj)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip, 1.0 + clip) * adv
    pg = -jnp.mean(jnp.minimum(unclipped, clipped))
    return pg + vf_coef * vf - ent_coef * gaussian_entropy(log_std)


def trpo_kl_loss(params, traj, *, kl_coef=1.0, vf_coef=0.5):
    """Trust-region as KL penalty: -E[ratio * A] + beta * E[KL(old || new)]."""
    ratio, adv, vf, log_std, logp = _policy_terms(params, traj)
    pg = -jnp.mean(ratio * adv)
    # KL(old||new) estimate from samples of old: E_old[logp_old - logp_new]
    kl = jnp.mean(traj["logp_old"] - logp)
    return pg + kl_coef * kl + vf_coef * vf


def tac_loss(params, traj, *, clip=0.2, vf_coef=0.5, tsallis_coef=0.01):
    """Tsallis actor-critic (q=2): PPO surrogate + Tsallis-2 entropy bonus."""
    ratio, adv, vf, log_std, _ = _policy_terms(params, traj)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip, 1.0 + clip) * adv
    pg = -jnp.mean(jnp.minimum(unclipped, clipped))
    return pg + vf_coef * vf - tsallis_coef * tsallis2_entropy(log_std)


LOSSES = {"ppo": ppo_loss, "trpo": trpo_kl_loss, "tac": tac_loss}


def minibatch_epoch_grad(loss_fn, params, data, key, *, epochs: int = 1,
                         n_minibatches: int = 1, lr: float = 1e-3):
    """PPO-style minibatch-epoch local optimization as a pseudo-gradient.

    ``data`` holds one agent's transition batch (leaves lead with D
    transitions). Runs ``epochs`` shuffled passes of SGD over
    ``n_minibatches`` minibatches — the classic PPO update loop — starting
    from ``params``, then reports the accumulated displacement as a gradient,
    ``g = (params - params_new) / lr``, so the federated strategies can
    weight/gossip/apply it exactly like a single-step gradient
    (``p - lr * g == params_new`` for the identity transform).

    With ``epochs == n_minibatches == 1`` this *is* ``value_and_grad`` — no
    shuffle, no inner loop — so the default config degenerates to the plain
    stochastic gradient of Algorithms 1 & 2. Returns ``(grad, mean_loss)``.
    """
    if epochs == 1 and n_minibatches == 1:
        loss, g = jax.value_and_grad(loss_fn)(params, data)
        return g, loss
    d = jax.tree.leaves(data)[0].shape[0]
    if d % n_minibatches:
        raise ValueError(
            f"minibatch_epoch_grad: {d} transitions do not split into "
            f"{n_minibatches} minibatches"
        )
    mb = d // n_minibatches

    def one_epoch(p, k):
        perm = jax.random.permutation(k, d)
        batches = jax.tree.map(
            lambda x: x[perm].reshape((n_minibatches, mb) + x.shape[1:]), data
        )

        def step(p, batch):
            loss, g = jax.value_and_grad(loss_fn)(p, batch)
            return jax.tree.map(lambda a, b: a - lr * b, p, g), loss

        return jax.lax.scan(step, p, batches)

    new_params, losses = jax.lax.scan(
        one_epoch, params, jax.random.split(key, epochs)
    )
    g = jax.tree.map(lambda a, b: (a - b) / lr, params, new_params)
    return g, losses.mean()
