"""Federated MARL driver: paper Algorithms 1 & 2 on the traffic envs.

Two rollout regimes share the same federated machinery:

* **Legacy shared env** (``num_envs=0``, the default): m federated agents =
  the RL-controlled vehicles of ONE environment. Every vehicle acts under its
  own current replica (agents interact through traffic while learning
  locally). This path is bit-identical to the original driver.
* **Heterogeneous fleet** (``num_envs >= 1`` or ``env_params`` set): agent i
  owns its *own* environment — an ``EnvParams`` row, possibly perturbed per
  agent (the paper's asynchronous/heterogeneous-MDP setting) — with B
  parallel rollout copies stepped by ``repro.rl.rollout``. Trajectory
  buffers come back shaped (m, B, P, ...), and each local update runs the
  PPO minibatch-epoch loop (``ppo_epochs`` x ``n_minibatches``) over the
  B*P*n_rl transitions, reported to the strategy as a pseudo-gradient.

Every P transitions each agent takes one local update on its own data; the
strategy applies variation masks / decay / consensus gossip; every tau local
updates the virtual agent averages the replicas (eq. 11). The whole run is
one jitted scan (epochs x updates x P env steps).

Carry layouts mirror ``repro.core.fmarl``: the jnp backend with plain SGD
keeps the original tree-space reference (bit-identical); kernel backends —
or any run with ``cfg.optimizer`` or ``cfg.buffer_dtype`` set — keep the
policy replicas as one flat ``(m, n)`` matrix across every scan. Each update
step unravels one cached tree view for the rollout/grad closures and ravels
only the gradients back; the local update, the periodic sync (``row_mean``),
and the optimizer accumulators all stay flat through the dispatch layer.
With ``buffer_dtype="bfloat16"`` the flat params/grad buffers are stored in
bf16 end to end (the dispatch primitives and optimizer moments still
accumulate in fp32; closures see an fp32 tree view).

Traced variation axis: both cores read the strategy's per-step weights
(variation mask x decay, mask-folded mixing) through ``jnp.asarray`` inside
the scan bodies, so a ``with_mask`` strategy copy whose mask is a tracer —
the sweep engine's ``taus`` axis — threads straight through as a scan-body
operand, and ``cfg.env_params`` built from a traced ``hetero_scale``
likewise. Under the sweep's vmap the mask batches to ``(S, m, tau)`` and
the env params to per-run pytrees; the period length ``tau`` stays static
(it is the inner scan length). See DESIGN.md §11.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accounting import CostLedger
from repro.core.fmarl import _use_flat_carry
from repro.core.strategies import AggregationStrategy
from repro.kernels import dispatch
from repro.optim.flat import FlatOptimizer, server_average_state
from repro.rl.env import (
    EnvConfig,
    EnvParams,
    broadcast_params,
    env_reset,
    env_step,
    get_obs,
)
from repro.rl.policy import init_policy, policy_value, sample_action
from repro.rl.ppo import LOSSES, gae, minibatch_epoch_grad
from repro.rl.env import OBS_DIM
from repro.rl.rollout import (
    fleet_flatten,
    fleet_gae,
    fleet_last_values,
    fleet_reset,
    fleet_rollout,
)
from repro.sharding import shard_agents
from repro.utils.pytree import tree_l2_norm


@dataclasses.dataclass(frozen=True)
class FedRLConfig:
    env: EnvConfig
    strategy: AggregationStrategy
    eta: float = 1e-3
    n_epochs: int = 100          # U
    epoch_len: int = 200         # T (env steps per epoch)
    minibatch: int = 25          # P (transitions per local update)
    algo: str = "ppo"            # ppo | trpo | tac
    gamma: float = 0.99
    lam: float = 0.95
    eval_seed: int = 1234
    optimizer: Optional[FlatOptimizer] = None  # None = plain SGD (reference)
    # --- heterogeneous fleet (repro.rl.rollout) ---
    num_envs: int = 0            # B parallel envs per agent; 0 = legacy shared env
    env_params: Optional[EnvParams] = None  # (m,)-stacked per-agent MDPs
    ppo_epochs: int = 1          # PPO epochs per local update (fleet path)
    n_minibatches: int = 1       # PPO minibatches per epoch (fleet path)
    # --- flat-carry storage dtype (None = fp32); e.g. "bfloat16" ---
    buffer_dtype: Optional[str] = None

    @property
    def fleet(self) -> bool:
        return self.num_envs > 0 or self.env_params is not None

    @property
    def B(self) -> int:
        return max(self.num_envs, 1)

    def __post_init__(self):
        if self.epoch_len % self.minibatch:
            raise ValueError("T must divide into P-sized steps")
        if self.fleet:
            if self.env_params is not None:
                m_p = jax.tree.leaves(self.env_params)[0].shape[0]
                if m_p != self.strategy.m:
                    raise ValueError(
                        f"env_params carries {m_p} agents, strategy m="
                        f"{self.strategy.m}"
                    )
            d = self.B * self.minibatch * self.env.n_rl
            if d % self.n_minibatches:
                raise ValueError(
                    f"{d} fleet transitions per update do not split into "
                    f"{self.n_minibatches} minibatches"
                )
        elif self.env.n_rl != self.strategy.m:
            raise ValueError(
                f"strategy m={self.strategy.m} must equal n_rl={self.env.n_rl}"
            )
        if self.buffer_dtype is not None:
            jnp.dtype(self.buffer_dtype)  # fail fast on typos


def _fleet_params(cfg: FedRLConfig) -> EnvParams:
    """The (m,)-stacked per-agent EnvParams (homogeneous broadcast if unset)."""
    if cfg.env_params is not None:
        return cfg.env_params
    return broadcast_params(cfg.env.default_params(), (cfg.strategy.m,))


def _rollout(cfg: FedRLConfig, params_m, env_state, key, n_steps: int):
    """Steps the shared env; every RL vehicle acts via its own replica.

    Returns (env_state, traj) with traj leaves shaped (m, n_steps, ...).
    """
    m = cfg.env.n_rl

    def step(carry, _):
        env_state, key = carry
        key, sub = jax.random.split(key)
        obs = get_obs(cfg.env, env_state)                     # (m, obs)
        keys = jax.random.split(sub, m)
        acts, logps = jax.vmap(sample_action)(params_m, obs, keys)
        vals = jax.vmap(policy_value)(params_m, obs)
        env_state, reward, _ = env_step(cfg.env, env_state, acts[:, 0])
        out = {
            "obs": obs, "act": acts, "logp_old": logps,
            "val": vals, "rew": jnp.broadcast_to(reward, (m,)),
        }
        return (env_state, key), out

    (env_state, _), traj = jax.lax.scan(step, (env_state, key), None, length=n_steps)
    traj = jax.tree.map(lambda x: jnp.moveaxis(x, 0, 1), traj)  # (m, P, ...)
    return env_state, traj


def _agent_grads(cfg: FedRLConfig, params_m, traj, env_state):
    """Per-agent PPO/TRPO/TAC gradient from its own P transitions."""
    loss_fn = LOSSES[cfg.algo]
    last_obs = get_obs(cfg.env, env_state)
    last_val = jax.vmap(policy_value)(params_m, last_obs)     # (m,)

    def one(params_i, traj_i, last_v):
        adv, ret = gae(traj_i["rew"], traj_i["val"], last_v,
                       gamma=cfg.gamma, lam=cfg.lam)
        t = dict(traj_i, adv=adv, ret=ret)
        loss, g = jax.value_and_grad(loss_fn)(params_i, t)
        return g, loss

    grads, losses = jax.vmap(one)(params_m, traj, last_val)
    return grads, losses


def _fleet_grads(cfg: FedRLConfig, params_m, env_params, traj, env_state, key,
                 *, epochs: int, n_minibatches: int):
    """Per-agent pseudo-gradients from the (m, B, P, ...) fleet trajectories.

    GAE runs per (env, vehicle) stream, the streams flatten to one
    B*P*n_rl transition batch per agent, and each agent's gradient is the
    PPO minibatch-epoch pseudo-gradient (plain gradient when 1x1).
    """
    loss_fn = LOSSES[cfg.algo]
    last_val = fleet_last_values(cfg.env, env_params, params_m, env_state)
    adv, ret = fleet_gae(traj["rew"], traj["val"], last_val,
                         gamma=cfg.gamma, lam=cfg.lam)
    data = fleet_flatten({
        "obs": traj["obs"], "act": traj["act"],
        "logp_old": traj["logp_old"], "adv": adv, "ret": ret,
    })
    keys = jax.random.split(key, cfg.strategy.m)

    def one(params_i, data_i, k):
        return minibatch_epoch_grad(
            loss_fn, params_i, data_i, k,
            epochs=epochs, n_minibatches=n_minibatches, lr=cfg.eta,
        )

    grads, losses = jax.vmap(one)(params_m, data, keys)
    return grads, losses


def _collect(cfg: FedRLConfig, env_params, params_m, env_state, key):
    """One local-update batch of experience + per-agent gradients.

    Returns ``(env_state, grads_m, losses, nas)``. The legacy shared-env
    branch reproduces the original key discipline exactly (one rollout key);
    the fleet branch additionally splits a minibatch-shuffle key.
    """
    if cfg.fleet:
        rk, gk = jax.random.split(key)
        env_state, traj = fleet_rollout(
            cfg.env, env_params, params_m, env_state, rk, cfg.minibatch
        )
        grads, losses = _fleet_grads(
            cfg, params_m, env_params, traj, env_state, gk,
            epochs=cfg.ppo_epochs, n_minibatches=cfg.n_minibatches,
        )
    else:
        env_state, traj = _rollout(cfg, params_m, env_state, key, cfg.minibatch)
        grads, losses = _agent_grads(cfg, params_m, traj, env_state)
    return env_state, grads, losses, jnp.mean(traj["rew"])


def _reset(cfg: FedRLConfig, env_params, key):
    if cfg.fleet:
        return fleet_reset(cfg.env, env_params, key, cfg.B)
    return env_reset(cfg.env, key)


def _eval_grad_norm(cfg: FedRLConfig, server_params, env_params=None):
    """Expected gradient norm ||grad F(theta_bar)||^2 on a fixed eval stream
    (Table II metric: fixed sample distribution, deterministic seed).

    The reset and rollout streams are decorrelated: reusing one key for both
    made the eval trajectory's action noise a deterministic function of the
    initial env state, biasing the fixed-sample estimate. On the fleet path
    the metric is the *plain* gradient over each agent's batch (no inner
    minibatch epochs — the metric estimates grad F, not a PPO displacement).
    """
    k_reset, k_roll = jax.random.split(jax.random.key(cfg.eval_seed))
    m = cfg.strategy.m if cfg.fleet else cfg.env.n_rl
    params_m = jax.tree.map(lambda l: jnp.broadcast_to(l, (m,) + l.shape),
                            server_params)
    if cfg.fleet:
        env_state = fleet_reset(cfg.env, env_params, k_reset, cfg.B)
        k_roll, gk = jax.random.split(k_roll)
        env_state, traj = fleet_rollout(
            cfg.env, env_params, params_m, env_state, k_roll, cfg.minibatch
        )
        grads, _ = _fleet_grads(cfg, params_m, env_params, traj, env_state,
                                gk, epochs=1, n_minibatches=1)
    else:
        env_state = env_reset(cfg.env, k_reset)
        env_state, traj = _rollout(cfg, params_m, env_state, k_roll,
                                   cfg.minibatch)
        grads, _ = _agent_grads(cfg, params_m, traj, env_state)
    g_mean = jax.tree.map(lambda x: jnp.mean(x, axis=0), grads)
    return tree_l2_norm(g_mean) ** 2


@functools.lru_cache(maxsize=1)
def policy_payload_elems() -> int:
    """Parameter count of one policy — the per-event payload size in elements.

    Shape-only (``jax.eval_shape``), so no device work; cached because every
    ledger call needs it and the policy architecture is fixed by ``OBS_DIM``.
    """
    shapes = jax.eval_shape(lambda: init_policy(jax.random.key(0), OBS_DIM))
    return int(sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes)))


def _finish_ledger(strat, n_updates: int,
                   payload_elems: Optional[int] = None) -> CostLedger:
    """Bill full periods plus any trailing partial one (the old
    ``n_updates // tau`` silently dropped the remainder's local updates)."""
    full, rem = divmod(n_updates, strat.tau)
    ledger = CostLedger()
    ledger.add_periods(strat, full, payload_elems)
    ledger.add_partial_period(strat, rem, payload_elems)
    return ledger


def fedrl_ledger(cfg: FedRLConfig) -> CostLedger:
    """The run's communication-cost ledger (host-side, config-only — the
    same for every seed, so sweep callers compute it once per config)."""
    return _finish_ledger(
        cfg.strategy, cfg.n_epochs * (cfg.epoch_len // cfg.minibatch),
        policy_payload_elems(),
    )


def fedrl_bytes_curve(cfg: FedRLConfig) -> np.ndarray:
    """Cumulative wire bytes after each epoch — the figures' bytes x-axis.

    Host-side and config-only like :func:`fedrl_ledger`: entry ``e`` is
    ``total_bytes()`` of a ledger billed for the first ``e + 1`` epochs
    (partial trailing periods included), so plotting a per-epoch metric
    against this axis reads "utility bought per byte on the wire".
    """
    upd = cfg.epoch_len // cfg.minibatch
    n = policy_payload_elems()
    return np.asarray(
        [
            _finish_ledger(cfg.strategy, (e + 1) * upd, n).total_bytes()
            for e in range(cfg.n_epochs)
        ],
        np.float64,
    )


def run_fedrl(cfg: FedRLConfig, key) -> tuple[Any, dict, CostLedger]:
    server, metrics = run_fedrl_core(cfg, key)
    metrics = jax.tree.map(np.asarray, jax.device_get(metrics))
    return server, metrics, fedrl_ledger(cfg)


def run_fedrl_core(cfg: FedRLConfig, key) -> tuple[Any, dict]:
    """Traced core of :func:`run_fedrl`: ``(server_params, metrics)`` only.

    Pure function of ``(cfg, key)`` with no host transfers — safe to wrap in
    ``jax.jit`` / ``jax.vmap`` (the sweep engine maps it over a seed axis and
    over traced hyperparameter overrides). The communication-cost ledger is
    host-side accounting and lives in the :func:`run_fedrl` wrapper.
    """
    if _use_flat_carry(cfg):  # the one carry-selection predicate, shared
        return _run_fedrl_flat(cfg, key)
    return _run_fedrl_tree(cfg, key)


def _run_fedrl_tree(cfg: FedRLConfig, key) -> tuple[Any, dict]:
    """Tree-space reference path (bit-identical to the original jnp driver)."""
    strat = cfg.strategy
    m, tau = strat.m, strat.tau
    updates_per_epoch = cfg.epoch_len // cfg.minibatch
    env_params = _fleet_params(cfg) if cfg.fleet else None

    key, pk = jax.random.split(key)
    init = init_policy(pk, OBS_DIM)
    params_m = jax.tree.map(lambda l: jnp.broadcast_to(l, (m,) + l.shape), init)

    def update(carry, _):
        params_m, env_state, k, key = carry
        key, rk = jax.random.split(key)
        env_state, grads, losses, nas = _collect(
            cfg, env_params, params_m, env_state, rk
        )
        offset = jnp.mod(k, tau)
        params_m = strat.local_update(params_m, grads, offset, cfg.eta)
        k = k + 1

        def do_sync(p):
            avg = strat.server_average(p)
            return jax.tree.map(lambda l: jnp.broadcast_to(l, (m,) + l.shape), avg)

        synced = jnp.equal(jnp.mod(k, tau), 0)
        params_m = jax.lax.cond(synced, do_sync, lambda p: p, params_m)
        return (params_m, env_state, k, key), {"nas": nas, "loss": losses.mean(),
                                               "synced": synced}

    def epoch(carry, _):
        params_m, k, key = carry
        key, ek = jax.random.split(key)
        env_state = _reset(cfg, env_params, ek)
        (params_m, _, k, key), ms = jax.lax.scan(
            update, (params_m, env_state, k, key), None, length=updates_per_epoch
        )
        server = strat.server_average(params_m)
        grad_sq = _eval_grad_norm(cfg, server, env_params)
        out = {
            "nas": ms["nas"].mean(),
            "loss": ms["loss"].mean(),
            "server_grad_sq_norm": grad_sq,
        }
        return (params_m, k, key), out

    carry = (params_m, jnp.zeros((), jnp.int32), key)
    (params_m, k, key), metrics = jax.lax.scan(
        epoch, carry, None, length=cfg.n_epochs
    )
    server = strat.server_average(params_m)
    return server, metrics


def _run_fedrl_flat(cfg: FedRLConfig, key) -> tuple[Any, dict]:
    """Flat-carry path: replicas live as one (m, n) matrix across all scans.

    ``cfg.buffer_dtype`` selects the storage dtype of the flat params/grad
    buffers (bf16 mode); the per-agent tree views handed to the rollout/grad
    closures are always fp32, and the dispatch primitives + optimizer moments
    accumulate in fp32 regardless.
    """
    strat = cfg.strategy
    m, tau = strat.m, strat.tau
    opt = cfg.optimizer
    dtype = jnp.dtype(cfg.buffer_dtype) if cfg.buffer_dtype is not None else None
    updates_per_epoch = cfg.epoch_len // cfg.minibatch
    if strat.is_async:
        strat.validate_horizon((cfg.n_epochs * updates_per_epoch) // tau)
    env_params = _fleet_params(cfg) if cfg.fleet else None

    key, pk = jax.random.split(key)
    init = init_policy(pk, OBS_DIM)
    flat, spec = dispatch.stacked_ravel_spec(
        jax.tree.map(lambda l: jnp.broadcast_to(l, (m,) + l.shape), init)
    )
    if dtype is not None:
        flat = flat.astype(dtype)
    opt_state = opt.init(flat) if opt is not None else {}
    comm_state = strat.init_comm_state(flat)

    def tree_view(f):
        """The closures' fp32 per-agent tree view of the flat carry."""
        return spec.unravel(dispatch.compute_view(f, dtype))

    def update(carry, _):
        flat, opt_state, comm_state, env_state, k, key = carry
        flat = shard_agents(flat)
        key, rk = jax.random.split(key)
        params_m = tree_view(flat)
        env_state, grads, losses, nas = _collect(
            cfg, env_params, params_m, env_state, rk
        )
        g_flat = jax.vmap(spec.ravel_one)(grads)
        if dtype is not None:
            g_flat = g_flat.astype(dtype)
        offset = jnp.mod(k, tau)
        flat, opt_state, comm_state = strat.flat_local_step(
            flat, g_flat, offset, cfg.eta, opt, opt_state, comm_state
        )
        k = k + 1

        # Boundary index of the sync `k` just completed (k is
        # post-increment, so update tau-1 closes period 0, etc.); only the
        # async schedule lookup consumes it.
        period = jnp.floor_divide(k, tau) - 1

        def do_sync(args):
            f, s, cs = args
            f, cs = strat.flat_sync(f, cs, period=period)
            if not strat.is_async:
                # Async boundaries sync only the arrived subset; optimizer
                # moments stay local (FedBuff keeps no server momentum).
                s = server_average_state(strat, s)
            return f, s, cs

        synced = jnp.equal(jnp.mod(k, tau), 0)
        flat, opt_state, comm_state = jax.lax.cond(
            synced, do_sync, lambda args: args, (flat, opt_state, comm_state)
        )
        return (flat, opt_state, comm_state, env_state, k, key), {
            "nas": nas, "loss": losses.mean(), "synced": synced,
        }

    def server_view(f):
        # Epoch evals land mid-period, where replicas are divergent even on
        # the synchronous path — the metric has always been the all-replica
        # poll (row_mean). Async keeps the same poll so utilities stay
        # comparable and the zero-delay run stays bitwise-identical.
        row = strat.flat_server_average(f)
        return spec.unravel_one(dispatch.compute_view(row, dtype))

    def epoch(carry, _):
        flat, opt_state, comm_state, k, key = carry
        key, ek = jax.random.split(key)
        env_state = _reset(cfg, env_params, ek)
        (flat, opt_state, comm_state, _, k, key), ms = jax.lax.scan(
            update, (flat, opt_state, comm_state, env_state, k, key), None,
            length=updates_per_epoch,
        )
        grad_sq = _eval_grad_norm(cfg, server_view(flat), env_params)
        out = {
            "nas": ms["nas"].mean(),
            "loss": ms["loss"].mean(),
            "server_grad_sq_norm": grad_sq,
        }
        return (flat, opt_state, comm_state, k, key), out

    carry = (flat, opt_state, comm_state, jnp.zeros((), jnp.int32), key)
    (flat, opt_state, comm_state, k, key), metrics = jax.lax.scan(
        epoch, carry, None, length=cfg.n_epochs
    )
    return server_view(flat), metrics


def expected_gradient_norm(metrics) -> float:
    """Table II metric: average ||grad F||^2 over the training run."""
    return float(np.mean(metrics["server_grad_sq_norm"]))


# --- trace-safety audit registration (repro.analysis.jaxpr_audit) -------------

def _audit_hot_path() -> dispatch.HotPathEntry:
    """Tiny-but-faithful ``run_fedrl_core`` entry for the jaxpr audit.

    FIGURE_EIGHT with a 2-step decay period and 2 local updates per epoch:
    every scan body, dispatch call, PRNG split, and eval branch of the
    production driver appears in the jaxpr — only the trip counts shrink,
    and trip counts do not change which equations the audit sees.
    """
    from repro.core import make_strategy
    from repro.rl.env import FIGURE_EIGHT

    cfg = FedRLConfig(
        env=FIGURE_EIGHT,
        strategy=make_strategy("decay", tau=2, m=7, backend="jnp"),
        n_epochs=1,
        epoch_len=4,
        minibatch=2,
    )
    return dispatch.HotPathEntry(
        fn=lambda seed: run_fedrl_core(cfg, jax.random.key(seed))[1],
        args=(jax.ShapeDtypeStruct((), jnp.int32),),
    )


dispatch.register_hot_path("rl.run_fedrl_core", _audit_hot_path)
