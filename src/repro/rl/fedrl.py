"""Federated MARL driver: paper Algorithms 1 & 2 on the traffic envs.

m federated agents = the RL-controlled vehicles. Each agent owns a policy
replica (leading axis m); one shared environment is stepped with every
vehicle acting under *its own* current replica (exactly the paper's setting —
agents interact through traffic while learning locally). Every P transitions
each agent takes one local SGD step on its own minibatch; the strategy applies
variation masks / decay / consensus gossip; every tau local updates the
virtual agent averages the replicas (eq. 11).

The whole run is one jitted scan (epochs x updates x P env steps), so the
paper-scale experiment runs in seconds-to-minutes on CPU.

Carry layouts mirror ``repro.core.fmarl``: the jnp backend with plain SGD
keeps the original tree-space reference (bit-identical); kernel backends —
or any run with ``cfg.optimizer`` set — keep the policy replicas as one flat
``(m, n)`` matrix across every scan. Each update step unravels one cached
tree view for the rollout/grad closures and ravels only the gradients back;
the local update, the periodic sync (``row_mean``), and the optimizer
accumulators all stay flat through the dispatch layer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accounting import CostLedger
from repro.core.strategies import AggregationStrategy
from repro.kernels import dispatch
from repro.optim.flat import FlatOptimizer, server_average_state
from repro.rl.env import EnvConfig, env_reset, env_step, get_obs
from repro.rl.policy import init_policy, policy_value, sample_action
from repro.rl.ppo import LOSSES, gae
from repro.rl.env import OBS_DIM
from repro.utils.pytree import tree_l2_norm


@dataclasses.dataclass(frozen=True)
class FedRLConfig:
    env: EnvConfig
    strategy: AggregationStrategy
    eta: float = 1e-3
    n_epochs: int = 100          # U
    epoch_len: int = 200         # T (env steps per epoch)
    minibatch: int = 25          # P (transitions per local update)
    algo: str = "ppo"            # ppo | trpo | tac
    gamma: float = 0.99
    lam: float = 0.95
    eval_seed: int = 1234
    optimizer: Optional[FlatOptimizer] = None  # None = plain SGD (reference)

    def __post_init__(self):
        if self.epoch_len % self.minibatch:
            raise ValueError("T must divide into P-sized steps")
        if self.env.n_rl != self.strategy.m:
            raise ValueError(
                f"strategy m={self.strategy.m} must equal n_rl={self.env.n_rl}"
            )


def _rollout(cfg: FedRLConfig, params_m, env_state, key, n_steps: int):
    """Steps the shared env; every RL vehicle acts via its own replica.

    Returns (env_state, traj) with traj leaves shaped (m, n_steps, ...).
    """
    m = cfg.env.n_rl

    def step(carry, _):
        env_state, key = carry
        key, sub = jax.random.split(key)
        obs = get_obs(cfg.env, env_state)                     # (m, obs)
        keys = jax.random.split(sub, m)
        acts, logps = jax.vmap(sample_action)(params_m, obs, keys)
        vals = jax.vmap(policy_value)(params_m, obs)
        env_state, reward, _ = env_step(cfg.env, env_state, acts[:, 0])
        out = {
            "obs": obs, "act": acts, "logp_old": logps,
            "val": vals, "rew": jnp.broadcast_to(reward, (m,)),
        }
        return (env_state, key), out

    (env_state, _), traj = jax.lax.scan(step, (env_state, key), None, length=n_steps)
    traj = jax.tree.map(lambda x: jnp.moveaxis(x, 0, 1), traj)  # (m, P, ...)
    return env_state, traj


def _agent_grads(cfg: FedRLConfig, params_m, traj, env_state):
    """Per-agent PPO/TRPO/TAC gradient from its own P transitions."""
    loss_fn = LOSSES[cfg.algo]
    last_obs = get_obs(cfg.env, env_state)
    last_val = jax.vmap(policy_value)(params_m, last_obs)     # (m,)

    def one(params_i, traj_i, last_v):
        adv, ret = gae(traj_i["rew"], traj_i["val"], last_v,
                       gamma=cfg.gamma, lam=cfg.lam)
        t = dict(traj_i, adv=adv, ret=ret)
        loss, g = jax.value_and_grad(loss_fn)(params_i, t)
        return g, loss

    grads, losses = jax.vmap(one)(params_m, traj, last_val)
    return grads, losses


def _eval_grad_norm(cfg: FedRLConfig, server_params):
    """Expected gradient norm ||grad F(theta_bar)||^2 on a fixed eval stream
    (Table II metric: fixed sample distribution, deterministic seed).

    The reset and rollout streams are decorrelated: reusing one key for both
    made the eval trajectory's action noise a deterministic function of the
    initial env state, biasing the fixed-sample estimate."""
    k_reset, k_roll = jax.random.split(jax.random.key(cfg.eval_seed))
    env_state = env_reset(cfg.env, k_reset)
    m = cfg.env.n_rl
    params_m = jax.tree.map(lambda l: jnp.broadcast_to(l, (m,) + l.shape),
                            server_params)
    env_state, traj = _rollout(cfg, params_m, env_state, k_roll, cfg.minibatch)
    grads, _ = _agent_grads(cfg, params_m, traj, env_state)
    g_mean = jax.tree.map(lambda x: jnp.mean(x, axis=0), grads)
    return tree_l2_norm(g_mean) ** 2


def _finish_ledger(strat, n_updates: int) -> CostLedger:
    """Bill full periods plus any trailing partial one (the old
    ``n_updates // tau`` silently dropped the remainder's local updates)."""
    full, rem = divmod(n_updates, strat.tau)
    ledger = CostLedger()
    ledger.add_periods(strat, full)
    ledger.add_partial_period(strat, rem)
    return ledger


def run_fedrl(cfg: FedRLConfig, key) -> tuple[Any, dict, CostLedger]:
    if (
        dispatch.is_kernel_backend(cfg.strategy.backend)
        or cfg.optimizer is not None
    ):
        return _run_fedrl_flat(cfg, key)
    return _run_fedrl_tree(cfg, key)


def _run_fedrl_tree(cfg: FedRLConfig, key) -> tuple[Any, dict, CostLedger]:
    """Tree-space reference path (bit-identical to the original jnp driver)."""
    strat = cfg.strategy
    m, tau = strat.m, strat.tau
    updates_per_epoch = cfg.epoch_len // cfg.minibatch

    key, pk = jax.random.split(key)
    init = init_policy(pk, OBS_DIM)
    params_m = jax.tree.map(lambda l: jnp.broadcast_to(l, (m,) + l.shape), init)

    def update(carry, _):
        params_m, env_state, k, key = carry
        key, rk = jax.random.split(key)
        env_state, traj = _rollout(cfg, params_m, env_state, rk, cfg.minibatch)
        grads, losses = _agent_grads(cfg, params_m, traj, env_state)
        offset = jnp.mod(k, tau)
        params_m = strat.local_update(params_m, grads, offset, cfg.eta)
        k = k + 1

        def do_sync(p):
            avg = strat.server_average(p)
            return jax.tree.map(lambda l: jnp.broadcast_to(l, (m,) + l.shape), avg)

        synced = jnp.equal(jnp.mod(k, tau), 0)
        params_m = jax.lax.cond(synced, do_sync, lambda p: p, params_m)
        nas = jnp.mean(traj["rew"])
        return (params_m, env_state, k, key), {"nas": nas, "loss": losses.mean(),
                                               "synced": synced}

    def epoch(carry, _):
        params_m, k, key = carry
        key, ek = jax.random.split(key)
        env_state = env_reset(cfg.env, ek)
        (params_m, _, k, key), ms = jax.lax.scan(
            update, (params_m, env_state, k, key), None, length=updates_per_epoch
        )
        server = strat.server_average(params_m)
        grad_sq = _eval_grad_norm(cfg, server)
        out = {
            "nas": ms["nas"].mean(),
            "loss": ms["loss"].mean(),
            "server_grad_sq_norm": grad_sq,
        }
        return (params_m, k, key), out

    carry = (params_m, jnp.zeros((), jnp.int32), key)
    (params_m, k, key), metrics = jax.lax.scan(
        epoch, carry, None, length=cfg.n_epochs
    )
    server = strat.server_average(params_m)

    ledger = _finish_ledger(strat, cfg.n_epochs * updates_per_epoch)
    return server, jax.tree.map(np.asarray, jax.device_get(metrics)), ledger


def _run_fedrl_flat(cfg: FedRLConfig, key) -> tuple[Any, dict, CostLedger]:
    """Flat-carry path: replicas live as one (m, n) matrix across all scans."""
    strat = cfg.strategy
    m, tau = strat.m, strat.tau
    opt = cfg.optimizer
    updates_per_epoch = cfg.epoch_len // cfg.minibatch

    key, pk = jax.random.split(key)
    init = init_policy(pk, OBS_DIM)
    flat, spec = dispatch.stacked_ravel_spec(
        jax.tree.map(lambda l: jnp.broadcast_to(l, (m,) + l.shape), init)
    )
    opt_state = opt.init(flat) if opt is not None else {}

    def update(carry, _):
        flat, opt_state, env_state, k, key = carry
        key, rk = jax.random.split(key)
        params_m = spec.unravel(flat)   # the rollout/grad closures' tree view
        env_state, traj = _rollout(cfg, params_m, env_state, rk, cfg.minibatch)
        grads, losses = _agent_grads(cfg, params_m, traj, env_state)
        g_flat = jax.vmap(spec.ravel_one)(grads)
        offset = jnp.mod(k, tau)
        if opt is None:
            flat = strat.flat_update(flat, g_flat, offset, cfg.eta)
        else:
            flat, opt_state = strat.flat_opt_step(
                flat, g_flat, offset, cfg.eta, opt, opt_state
            )
        k = k + 1

        def do_sync(args):
            f, s = args
            row = strat.flat_server_average(f)
            return (
                jnp.broadcast_to(row[None, :], f.shape),
                server_average_state(strat, s),
            )

        synced = jnp.equal(jnp.mod(k, tau), 0)
        flat, opt_state = jax.lax.cond(
            synced, do_sync, lambda args: args, (flat, opt_state)
        )
        nas = jnp.mean(traj["rew"])
        return (flat, opt_state, env_state, k, key), {
            "nas": nas, "loss": losses.mean(), "synced": synced,
        }

    def epoch(carry, _):
        flat, opt_state, k, key = carry
        key, ek = jax.random.split(key)
        env_state = env_reset(cfg.env, ek)
        (flat, opt_state, _, k, key), ms = jax.lax.scan(
            update, (flat, opt_state, env_state, k, key), None,
            length=updates_per_epoch,
        )
        server = spec.unravel_one(strat.flat_server_average(flat))
        grad_sq = _eval_grad_norm(cfg, server)
        out = {
            "nas": ms["nas"].mean(),
            "loss": ms["loss"].mean(),
            "server_grad_sq_norm": grad_sq,
        }
        return (flat, opt_state, k, key), out

    carry = (flat, opt_state, jnp.zeros((), jnp.int32), key)
    (flat, opt_state, k, key), metrics = jax.lax.scan(
        epoch, carry, None, length=cfg.n_epochs
    )
    server = spec.unravel_one(strat.flat_server_average(flat))

    ledger = _finish_ledger(strat, cfg.n_epochs * updates_per_epoch)
    return server, jax.tree.map(np.asarray, jax.device_get(metrics)), ledger


def expected_gradient_norm(metrics) -> float:
    """Table II metric: average ||grad F||^2 over the training run."""
    return float(np.mean(metrics["server_grad_sq_norm"]))
