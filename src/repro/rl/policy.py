"""Gaussian MLP actor-critic for the traffic MARL tasks (paper's DRL model)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_policy(key, obs_dim: int, hidden: int = 64, act_dim: int = 1):
    ks = jax.random.split(key, 6)
    g = jax.nn.initializers.orthogonal()
    return {
        "pi": {
            "w1": g(ks[0], (obs_dim, hidden)), "b1": jnp.zeros(hidden),
            "w2": g(ks[1], (hidden, hidden)), "b2": jnp.zeros(hidden),
            "w3": 0.01 * g(ks[2], (hidden, act_dim)), "b3": jnp.zeros(act_dim),
            "log_std": jnp.full((act_dim,), -0.5),
        },
        "vf": {
            "w1": g(ks[3], (obs_dim, hidden)), "b1": jnp.zeros(hidden),
            "w2": g(ks[4], (hidden, hidden)), "b2": jnp.zeros(hidden),
            "w3": g(ks[5], (hidden, 1)), "b3": jnp.zeros(1),
        },
    }


def _mlp(p, x):
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    h = jnp.tanh(h @ p["w2"] + p["b2"])
    return h @ p["w3"] + p["b3"]


def policy_apply(params, obs):
    """Returns (mean, log_std) of the Gaussian policy."""
    mean = jnp.tanh(_mlp(params["pi"], obs))
    return mean, params["pi"]["log_std"]


def policy_value(params, obs):
    return _mlp(params["vf"], obs)[..., 0]


def sample_action(params, obs, key):
    mean, log_std = policy_apply(params, obs)
    std = jnp.exp(log_std)
    eps = jax.random.normal(key, mean.shape)
    act = mean + std * eps
    logp = gaussian_logp(act, mean, log_std)
    return act, logp


def gaussian_logp(act, mean, log_std):
    var = jnp.exp(2.0 * log_std)
    return jnp.sum(
        -0.5 * ((act - mean) ** 2 / var + 2.0 * log_std + jnp.log(2.0 * jnp.pi)),
        axis=-1,
    )


def gaussian_entropy(log_std):
    return jnp.sum(log_std + 0.5 * jnp.log(2.0 * jnp.pi * jnp.e))


def tsallis2_entropy(log_std):
    """Tsallis entropy with entropic index q=2 for a diagonal Gaussian:
    S_2 = 1 - integral pi^2 = 1 - prod_i 1/(2 sqrt(pi) sigma_i)."""
    sigma = jnp.exp(log_std)
    return 1.0 - jnp.prod(1.0 / (2.0 * jnp.sqrt(jnp.pi) * sigma))
