from repro.rl.env import (
    EnvConfig,
    EnvParams,
    FIGURE_EIGHT,
    MERGE,
    broadcast_params,
    env_reset,
    env_step,
    get_obs,
    perturb_params,
    stack_params,
)
from repro.rl.policy import init_policy, policy_apply, policy_value
from repro.rl.ppo import gae, minibatch_epoch_grad, ppo_loss, tac_loss, trpo_kl_loss
from repro.rl.rollout import (
    fleet_flatten,
    fleet_gae,
    fleet_last_values,
    fleet_reset,
    fleet_rollout,
)
from repro.rl.scenarios import SCENARIOS, Scenario, get_scenario, make_fleet
from repro.rl.fedrl import FedRLConfig, run_fedrl

__all__ = [
    "EnvConfig",
    "EnvParams",
    "FIGURE_EIGHT",
    "FedRLConfig",
    "MERGE",
    "SCENARIOS",
    "Scenario",
    "broadcast_params",
    "env_reset",
    "env_step",
    "fleet_flatten",
    "fleet_gae",
    "fleet_last_values",
    "fleet_reset",
    "fleet_rollout",
    "gae",
    "get_obs",
    "get_scenario",
    "init_policy",
    "make_fleet",
    "minibatch_epoch_grad",
    "perturb_params",
    "policy_apply",
    "policy_value",
    "ppo_loss",
    "run_fedrl",
    "stack_params",
    "tac_loss",
    "trpo_kl_loss",
]
