from repro.rl.env import EnvConfig, FIGURE_EIGHT, MERGE, env_reset, env_step, get_obs
from repro.rl.policy import init_policy, policy_apply, policy_value
from repro.rl.ppo import gae, ppo_loss, trpo_kl_loss, tac_loss
from repro.rl.fedrl import FedRLConfig, run_fedrl

__all__ = [
    "EnvConfig",
    "FIGURE_EIGHT",
    "FedRLConfig",
    "MERGE",
    "env_reset",
    "env_step",
    "gae",
    "get_obs",
    "init_policy",
    "policy_apply",
    "policy_value",
    "ppo_loss",
    "run_fedrl",
    "tac_loss",
    "trpo_kl_loss",
]
