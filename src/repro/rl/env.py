"""Pure-JAX ring-road traffic MARL environments.

SUMO is unavailable offline; these are the jit-able analogs of the paper's
scenarios (documented in DESIGN.md §3):

* FIGURE_EIGHT — 14 vehicles on a closed loop with an intersection-like
  bottleneck zone; 7 RL-controlled (every other vehicle). The classic
  mixed-autonomy stabilization problem: background vehicles follow IDM (which
  produces stop-and-go waves); RL vehicles control acceleration in [-1, 1] to
  maximize the normalized average speed (NAS) of the whole team.
* MERGE — 50 vehicles on a longer ring with a periodic slow zone emulating
  merge friction; 5 RL-controlled.

Collisions (gap < min_gap) force a brake-slam on the offender and incur a
penalty, as in the paper's setup.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

OBS_DIM = 6


@dataclasses.dataclass(frozen=True)
class EnvConfig:
    name: str
    n_vehicles: int
    rl_indices: tuple          # which vehicles are RL-controlled
    length: float              # ring circumference (m)
    dt: float = 0.1
    v_max: float = 8.0
    a_max: float = 1.5         # RL acceleration scale (m/s^2)
    min_gap: float = 2.0       # collision threshold (m)
    crash_penalty: float = 1.0
    # IDM params for background vehicles
    idm_v0: float = 8.0
    idm_T: float = 1.0
    idm_a: float = 1.3
    idm_b: float = 2.0
    idm_s0: float = 2.0
    # bottleneck: [start, end) zone with reduced speed limit
    zone_start: float = 0.0
    zone_end: float = 0.0
    zone_vmax: float = 8.0

    @property
    def n_rl(self) -> int:
        return len(self.rl_indices)


FIGURE_EIGHT = EnvConfig(
    name="figure_eight",
    n_vehicles=14,
    rl_indices=tuple(range(0, 14, 2)),   # 7 RL vehicles, alternating
    length=230.0,
    zone_start=0.0,
    zone_end=15.0,
    zone_vmax=3.0,                        # intersection analog: slow zone
)

MERGE = EnvConfig(
    name="merge",
    n_vehicles=50,
    rl_indices=tuple(range(0, 50, 10)),  # 5 RL vehicles
    length=700.0,
    v_max=12.0,
    idm_v0=12.0,
    zone_start=0.0,
    zone_end=40.0,
    zone_vmax=4.0,                        # merge-friction zone
)


class EnvState(NamedTuple):
    x: jnp.ndarray        # (N,) positions
    v: jnp.ndarray        # (N,) speeds
    crashed: jnp.ndarray  # () bool


def env_reset(cfg: EnvConfig, key) -> EnvState:
    n = cfg.n_vehicles
    spacing = cfg.length / n
    jitter = jax.random.uniform(key, (n,), minval=-0.2, maxval=0.2) * spacing
    x = jnp.sort((jnp.arange(n) * spacing + jitter) % cfg.length)
    v = jnp.zeros(n) + 0.5
    return EnvState(x=x, v=v, crashed=jnp.zeros((), bool))


def _gaps(cfg: EnvConfig, x):
    """Leader gap per vehicle on the ring (order-preserving by construction)."""
    order = jnp.argsort(x)
    x_sorted = x[order]
    lead_sorted = jnp.roll(x_sorted, -1)
    gap_sorted = (lead_sorted - x_sorted) % cfg.length
    gaps = jnp.zeros_like(x).at[order].set(gap_sorted)
    leader = jnp.zeros(cfg.n_vehicles, jnp.int32).at[order].set(jnp.roll(order, -1))
    follower = jnp.zeros(cfg.n_vehicles, jnp.int32).at[order].set(jnp.roll(order, 1))
    return gaps, leader, follower


def _idm_accel(cfg: EnvConfig, v, gap, v_lead):
    dv = v - v_lead
    s_star = cfg.idm_s0 + v * cfg.idm_T + v * dv / (2.0 * jnp.sqrt(cfg.idm_a * cfg.idm_b))
    s_star = jnp.maximum(s_star, 0.0)
    return cfg.idm_a * (1.0 - (v / cfg.idm_v0) ** 4 - (s_star / jnp.maximum(gap, 0.1)) ** 2)


def _zone_limit(cfg: EnvConfig, x):
    inz = (x >= cfg.zone_start) & (x < cfg.zone_end)
    return jnp.where(inz, cfg.zone_vmax, cfg.v_max)


def get_obs(cfg: EnvConfig, state: EnvState) -> jnp.ndarray:
    """(n_rl, 6): [own pos/L, own v/vmax, lead gap/L, lead v/vmax, fol gap/L, fol v/vmax]."""
    gaps, leader, follower = _gaps(cfg, state.x)
    idx = jnp.asarray(cfg.rl_indices)
    fol_gap = gaps[follower][idx]
    return jnp.stack(
        [
            state.x[idx] / cfg.length,
            state.v[idx] / cfg.v_max,
            gaps[idx] / cfg.length,
            state.v[leader[idx]] / cfg.v_max,
            fol_gap / cfg.length,
            state.v[follower[idx]] / cfg.v_max,
        ],
        axis=-1,
    )


def env_step(cfg: EnvConfig, state: EnvState, rl_accel):
    """rl_accel: (n_rl,) in [-1, 1]. Returns (state, reward, crashed_now)."""
    gaps, leader, _ = _gaps(cfg, state.x)
    accel = _idm_accel(cfg, state.v, gaps, state.v[leader])
    idx = jnp.asarray(cfg.rl_indices)
    accel = accel.at[idx].set(jnp.clip(rl_accel, -1.0, 1.0) * cfg.a_max)

    # emergency brake if about to collide (paper: slam brakes before crash)
    ttc_brake = gaps < (cfg.min_gap + state.v * cfg.dt * 2.0)
    accel = jnp.where(ttc_brake, -cfg.idm_b * 2.0, accel)

    v = jnp.clip(state.v + accel * cfg.dt, 0.0, _zone_limit(cfg, state.x))
    x = (state.x + v * cfg.dt) % cfg.length

    new_gaps, _, _ = _gaps(cfg, x)
    crashed_now = jnp.any(new_gaps < cfg.min_gap * 0.5)
    crashed = state.crashed | crashed_now
    # NAS reward shared by the team, zeroed after a crash
    nas = jnp.mean(v) / cfg.v_max
    reward = jnp.where(crashed, -cfg.crash_penalty, nas)
    return EnvState(x=x, v=v, crashed=crashed), reward, crashed_now
