"""Pure-JAX ring-road traffic MARL environments.

SUMO is unavailable offline; these are the jit-able analogs of the paper's
scenarios (documented in DESIGN.md §3):

* FIGURE_EIGHT — 14 vehicles on a closed loop with an intersection-like
  bottleneck zone; 7 RL-controlled (every other vehicle). The classic
  mixed-autonomy stabilization problem: background vehicles follow IDM (which
  produces stop-and-go waves); RL vehicles control acceleration in [-1, 1] to
  maximize the normalized average speed (NAS) of the whole team.
* MERGE — 50 vehicles on a longer ring with a periodic slow zone emulating
  merge friction; 5 RL-controlled.

Further presets (ring attenuation / mixed-v_max fleets) live in
``repro.rl.scenarios``.

Collisions (gap < min_gap) force a brake-slam on the offender and incur a
penalty, as in the paper's setup.

Static/dynamic split
--------------------

``EnvConfig`` holds only *static structure* — scenario name, vehicle count,
which vehicles are RL-controlled — plus Python-float defaults for the
dynamics. The dynamics themselves live in :class:`EnvParams`, a pytree of jnp
scalars, so every env function vmaps over stacked parameter axes:

    params_m = perturb_params(cfg, key, m, scale=0.2)   # (m,) leaves
    reset = jax.vmap(lambda p, k: env_reset(cfg, k, params=p))

is a fleet of m *heterogeneous* MDPs (different ``zone_vmax``, IDM constants,
``dt`` — the paper's asynchronous-MDP knob), and a second vmap over a (B,)
axis gives B parallel rollout envs per agent (see ``repro.rl.rollout``).
All three entry points (``env_reset`` / ``env_step`` / ``get_obs``) take an
optional ``params``; omitting it uses ``cfg.default_params()`` so existing
single-env call sites are unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

OBS_DIM = 6


class EnvParams(NamedTuple):
    """Dynamic environment parameters: a pytree of jnp scalars (or stacked
    (m,)/(m, B) arrays under vmap). Everything the physics reads per step."""

    length: jnp.ndarray        # ring circumference (m)
    dt: jnp.ndarray
    v_max: jnp.ndarray
    a_max: jnp.ndarray         # RL acceleration scale (m/s^2)
    min_gap: jnp.ndarray       # collision threshold (m)
    crash_penalty: jnp.ndarray
    # IDM params for background vehicles
    idm_v0: jnp.ndarray
    idm_T: jnp.ndarray
    idm_a: jnp.ndarray
    idm_b: jnp.ndarray
    idm_s0: jnp.ndarray
    # bottleneck: [start, end) zone with reduced speed limit
    zone_start: jnp.ndarray
    zone_end: jnp.ndarray
    zone_vmax: jnp.ndarray


# EnvParams fields that make physical sense to perturb per agent when building
# a heterogeneous fleet (the asynchronous-MDP knob). Structure stays static.
HETERO_FIELDS = ("dt", "v_max", "idm_v0", "idm_T", "idm_a", "idm_b", "zone_vmax")


@dataclasses.dataclass(frozen=True)
class EnvConfig:
    """Static scenario structure + Python-float defaults for the dynamics.

    The fields below ``rl_indices`` are *defaults*: ``default_params()``
    packs them into an :class:`EnvParams` pytree, which is what the physics
    actually consumes (and what heterogeneous fleets perturb per agent).
    """

    name: str
    n_vehicles: int
    rl_indices: tuple          # which vehicles are RL-controlled
    length: float              # ring circumference (m)
    dt: float = 0.1
    v_max: float = 8.0
    a_max: float = 1.5         # RL acceleration scale (m/s^2)
    min_gap: float = 2.0       # collision threshold (m)
    crash_penalty: float = 1.0
    # IDM params for background vehicles
    idm_v0: float = 8.0
    idm_T: float = 1.0
    idm_a: float = 1.3
    idm_b: float = 2.0
    idm_s0: float = 2.0
    # bottleneck: [start, end) zone with reduced speed limit
    zone_start: float = 0.0
    zone_end: float = 0.0
    zone_vmax: float = 8.0

    @property
    def n_rl(self) -> int:
        return len(self.rl_indices)

    def default_params(self) -> EnvParams:
        """The scalar defaults as an EnvParams pytree of f32 jnp scalars."""
        return EnvParams(**{
            f: jnp.asarray(getattr(self, f), jnp.float32)
            for f in EnvParams._fields
        })


def _resolve(cfg: EnvConfig, params: Optional[EnvParams]) -> EnvParams:
    return params if params is not None else cfg.default_params()


def stack_params(params_list: Sequence[EnvParams]) -> EnvParams:
    """Stack per-agent EnvParams into one pytree with a leading (m,) axis."""
    return jax.tree.map(lambda *ls: jnp.stack(ls), *params_list)


def broadcast_params(params: EnvParams, shape: tuple) -> EnvParams:
    """Tile an EnvParams pytree along new leading axes (e.g. (m,) or (m, B))."""
    return jax.tree.map(
        lambda l: jnp.broadcast_to(l, tuple(shape) + l.shape), params
    )


def perturb_params(
    cfg: EnvConfig,
    key,
    m: int,
    scale: float,
    fields: Sequence[str] = HETERO_FIELDS,
) -> EnvParams:
    """Heterogeneous fleet builder: (m,)-stacked EnvParams, each listed field
    multiplied per agent by ``1 + scale * U(-1, 1)`` (floored at 0.1 so dt
    and IDM constants stay physical). ``scale=0`` returns m identical copies.

    ``scale`` may be a tracer (the sweep engine's ``hetero_scale`` axis): the
    perturbation *directions* are fixed by ``key`` while the magnitude
    traces, so the whole fleet-heterogeneity axis vmaps value-only. The
    concrete ``scale=0`` shortcut is host-only; a traced zero multiplies by
    exactly 1.0, which is value-identical.
    """
    base = cfg.default_params()
    fields = tuple(fields)
    unknown = set(fields) - set(EnvParams._fields)
    if unknown:
        raise ValueError(f"perturb_params: unknown fields {sorted(unknown)}")
    static_zero = isinstance(scale, (int, float)) and scale == 0
    keys = dict(zip(fields, jax.random.split(key, len(fields))))
    out = {}
    for f in EnvParams._fields:
        v = jnp.broadcast_to(getattr(base, f), (m,))
        if f in keys and not static_zero:
            u = jax.random.uniform(keys[f], (m,), minval=-1.0, maxval=1.0)
            v = v * jnp.maximum(1.0 + scale * u, 0.1)
        out[f] = v
    return EnvParams(**out)


FIGURE_EIGHT = EnvConfig(
    name="figure_eight",
    n_vehicles=14,
    rl_indices=tuple(range(0, 14, 2)),   # 7 RL vehicles, alternating
    length=230.0,
    zone_start=0.0,
    zone_end=15.0,
    zone_vmax=3.0,                        # intersection analog: slow zone
)

MERGE = EnvConfig(
    name="merge",
    n_vehicles=50,
    rl_indices=tuple(range(0, 50, 10)),  # 5 RL vehicles
    length=700.0,
    v_max=12.0,
    idm_v0=12.0,
    zone_start=0.0,
    zone_end=40.0,
    zone_vmax=4.0,                        # merge-friction zone
)


class EnvState(NamedTuple):
    x: jnp.ndarray        # (N,) positions
    v: jnp.ndarray        # (N,) speeds
    crashed: jnp.ndarray  # () bool


def env_reset(cfg: EnvConfig, key, params: Optional[EnvParams] = None) -> EnvState:
    p = _resolve(cfg, params)
    n = cfg.n_vehicles
    spacing = p.length / n
    jitter = jax.random.uniform(key, (n,), minval=-0.2, maxval=0.2) * spacing
    x = jnp.sort((jnp.arange(n) * spacing + jitter) % p.length)
    v = jnp.zeros(n) + 0.5
    return EnvState(x=x, v=v, crashed=jnp.zeros((), bool))


def _gaps(cfg: EnvConfig, p: EnvParams, x):
    """Leader gap per vehicle on the ring.

    Ring order is invariant by construction: ``env_reset`` sorts positions so
    vehicle i's leader is i+1 (mod n) forever — vehicles emergency-brake
    before they could cross. That makes the gap computation a static roll +
    modulo (no per-step argsort/scatter), which is what lets the fleet engine
    vectorize across thousands of batched envs; the values are identical to
    the former sort-based form whenever the order invariant holds.
    """
    n = cfg.n_vehicles
    idx = jnp.arange(n, dtype=jnp.int32)
    leader = jnp.roll(idx, -1)
    follower = jnp.roll(idx, 1)
    gaps = (x[leader] - x) % p.length
    return gaps, leader, follower


def _idm_accel(p: EnvParams, v, gap, v_lead):
    dv = v - v_lead
    s_star = p.idm_s0 + v * p.idm_T + v * dv / (2.0 * jnp.sqrt(p.idm_a * p.idm_b))
    s_star = jnp.maximum(s_star, 0.0)
    return p.idm_a * (1.0 - (v / p.idm_v0) ** 4 - (s_star / jnp.maximum(gap, 0.1)) ** 2)


def _zone_limit(p: EnvParams, x):
    inz = (x >= p.zone_start) & (x < p.zone_end)
    return jnp.where(inz, p.zone_vmax, p.v_max)


def get_obs(cfg: EnvConfig, state: EnvState,
            params: Optional[EnvParams] = None) -> jnp.ndarray:
    """(n_rl, 6): [own pos/L, own v/vmax, lead gap/L, lead v/vmax, fol gap/L, fol v/vmax]."""
    p = _resolve(cfg, params)
    gaps, leader, follower = _gaps(cfg, p, state.x)
    idx = jnp.asarray(cfg.rl_indices)
    fol_gap = gaps[follower][idx]
    return jnp.stack(
        [
            state.x[idx] / p.length,
            state.v[idx] / p.v_max,
            gaps[idx] / p.length,
            state.v[leader[idx]] / p.v_max,
            fol_gap / p.length,
            state.v[follower[idx]] / p.v_max,
        ],
        axis=-1,
    )


def env_step(cfg: EnvConfig, state: EnvState, rl_accel,
             params: Optional[EnvParams] = None):
    """rl_accel: (n_rl,) in [-1, 1]. Returns (state, reward, crashed_now)."""
    p = _resolve(cfg, params)
    gaps, leader, _ = _gaps(cfg, p, state.x)
    accel = _idm_accel(p, state.v, gaps, state.v[leader])
    idx = jnp.asarray(cfg.rl_indices)
    accel = accel.at[idx].set(jnp.clip(rl_accel, -1.0, 1.0) * p.a_max)

    # emergency brake if about to collide (paper: slam brakes before crash)
    ttc_brake = gaps < (p.min_gap + state.v * p.dt * 2.0)
    accel = jnp.where(ttc_brake, -p.idm_b * 2.0, accel)

    v = jnp.clip(state.v + accel * p.dt, 0.0, _zone_limit(p, state.x))
    # No-overtaking guard: cap speed so a vehicle cannot cross its leader in
    # one step — this makes the static ring order of _gaps an invariant
    # rather than an assumption. The bound only binds inside the crash band
    # (gap < ~v*dt), where the emergency brake has already fired.
    v = jnp.minimum(v, gaps / p.dt + v[leader])
    x = (state.x + v * p.dt) % p.length

    new_gaps, _, _ = _gaps(cfg, p, x)
    # A residual crossing is still possible when the leader itself was
    # clamped below its one-pass candidate speed; latch it as a crash (the
    # wrapped modulo gap would otherwise read ~length and hide it).
    crossed = gaps + (v[leader] - v) * p.dt < 0.0
    crashed_now = jnp.any(new_gaps < p.min_gap * 0.5) | jnp.any(crossed)
    crashed = state.crashed | crashed_now
    # NAS reward shared by the team, zeroed after a crash
    nas = jnp.mean(v) / p.v_max
    reward = jnp.where(crashed, -p.crash_penalty, nas)
    return EnvState(x=x, v=v, crashed=crashed), reward, crashed_now
