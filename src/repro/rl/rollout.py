"""Vectorized heterogeneous-fleet rollout engine.

The paper's setting is m *independent* agents undergoing heterogeneous,
asynchronous MDPs. This module realises it: agent i owns its own environment
instance — an :class:`~repro.rl.env.EnvParams` pytree row, possibly different
from every other agent's (``perturb_params`` / ``repro.rl.scenarios``) — and
B parallel rollout copies of it. One ``lax.scan`` over time, two ``vmap``
levels over (m, B), and every trajectory buffer comes out shaped
``(m, B, P, ...)``:

    obs       (m, B, P, n_rl, OBS_DIM)
    act       (m, B, P, n_rl, act_dim)
    logp_old  (m, B, P, n_rl)
    val       (m, B, P, n_rl)
    rew       (m, B, P)          — team NAS reward, shared within an env

Within an env the agent's single policy drives every RL vehicle (parameter
sharing), so richer envs just mean more transition streams per agent. The
key discipline is documented so a per-agent Python-loop reference can
reproduce the engine bit-for-bit (``tests/test_rollout_fleet.py``): each
scan step splits one subkey into ``m * B`` env keys (row-major: agent i, env
b gets ``keys[i * B + b]``), and each env splits its key into ``n_rl``
per-vehicle action keys.

Sharding: the ``(m, ...)`` agent axis of the scan carry is constrained to
the opt-in ``agents`` rule (``repro.sharding.fleet_rules``); outside a rules
context the constraint is the identity, so CPU/single-device runs are
untouched.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.rl.env import EnvConfig, EnvParams, env_reset, env_step, get_obs
from repro.rl.policy import policy_value, sample_action
from repro.rl.ppo import gae
from repro.sharding import shard_agents


def fleet_reset(cfg: EnvConfig, env_params: EnvParams, key, num_envs: int):
    """Reset an (m, B) fleet. ``env_params`` has (m,) leaves; returns an
    EnvState whose leaves carry leading (m, B) axes."""
    m = jax.tree.leaves(env_params)[0].shape[0]
    keys = jax.random.split(key, m * num_envs).reshape((m, num_envs))
    per_agent = jax.vmap(lambda p, k: env_reset(cfg, k, params=p),
                         in_axes=(None, 0))
    return jax.vmap(per_agent)(env_params, keys)


def fleet_rollout(cfg: EnvConfig, env_params: EnvParams, policy_m,
                  env_state, key, n_steps: int):
    """Roll the whole fleet forward ``n_steps``.

    ``env_params``: (m,)-leaved EnvParams; ``policy_m``: policy pytree with a
    leading (m,) replica axis; ``env_state``: (m, B)-leaved EnvState.
    Returns ``(env_state, traj)`` with traj buffers shaped (m, B, P, ...).
    """
    m, num_envs = env_state.x.shape[:2]
    n_rl = cfg.n_rl

    def one_env(pe, pol, state, k):
        obs = get_obs(cfg, state, params=pe)                     # (n_rl, obs)
        ks = jax.random.split(k, n_rl)
        acts, logps = jax.vmap(sample_action, in_axes=(None, 0, 0))(pol, obs, ks)
        vals = policy_value(pol, obs)                            # (n_rl,)
        state, reward, _ = env_step(cfg, state, acts[:, 0], params=pe)
        out = {"obs": obs, "act": acts, "logp_old": logps,
               "val": vals, "rew": reward}
        return state, out

    over_b = jax.vmap(one_env, in_axes=(None, None, 0, 0))
    over_mb = jax.vmap(over_b, in_axes=(0, 0, 0, 0))

    def step(carry, _):
        state, key = carry
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, m * num_envs).reshape((m, num_envs))
        state, out = over_mb(env_params, policy_m, state, keys)
        state = shard_agents(state)
        return (state, key), out

    (env_state, _), traj = jax.lax.scan(step, (env_state, key), None,
                                        length=n_steps)
    # time-major (P, m, B, ...) -> (m, B, P, ...)
    traj = jax.tree.map(lambda x: jnp.moveaxis(x, 0, 2), traj)
    return env_state, traj


def fleet_last_values(cfg: EnvConfig, env_params: EnvParams, policy_m,
                      env_state) -> jnp.ndarray:
    """Bootstrap values for GAE at the rollout horizon: (m, B, n_rl)."""
    def one(pol, pe, states):
        return jax.vmap(
            lambda s: policy_value(pol, get_obs(cfg, s, params=pe))
        )(states)

    return jax.vmap(one)(policy_m, env_params, env_state)


def fleet_gae(rew, val, last_val, *, gamma: float, lam: float):
    """GAE along the time axis of fleet buffers.

    ``rew``: (m, B, P) shared team reward; ``val``: (m, B, P, n_rl);
    ``last_val``: (m, B, n_rl). Returns (adv, ret), each (m, B, P, n_rl) —
    one advantage stream per (env, vehicle).
    """
    per_vehicle = jax.vmap(
        lambda r, v, lv: gae(r, v, lv, gamma=gamma, lam=lam),
        in_axes=(None, 1, 0), out_axes=1,
    )
    return jax.vmap(jax.vmap(per_vehicle))(rew, val, last_val)


def fleet_flatten(tree):
    """Collapse (m, B, P, n_rl, ...) buffers to per-agent transition batches
    (m, B*P*n_rl, ...) for the minibatch-epoch PPO update."""
    return jax.tree.map(
        lambda x: x.reshape((x.shape[0], -1) + x.shape[4:]), tree
    )
