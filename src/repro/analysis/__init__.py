"""Trace-safety analysis for the federated hot path (DESIGN.md §12).

Three layers, one CLI (``python -m repro.analysis``), one baseline:

  * :mod:`repro.analysis.lint` — AST lint (RPR001..RPR005): PRNG-key reuse,
    Python loops in scan bodies, host numpy on traced values, tracer
    concretization, jit retrace bait.
  * :mod:`repro.analysis.jaxpr_audit` — lowers the registered hot-path entry
    points and audits their jaxprs (JXA001..JXA004): sub-fp32 accumulation,
    callbacks in scan bodies, constant-folded literals, dead donation.
  * :mod:`repro.analysis.retrace` — runtime compile counter backing the
    ``assert_max_compiles`` pytest fixture and the bench compile report.

The pre-existing HLO tooling (:mod:`repro.analysis.hlo_stats`,
:mod:`repro.analysis.hlo_loops`, :mod:`repro.analysis.roofline`,
:mod:`repro.analysis.report`) shares the package: those inspect *performance*
structure of lowered code, the layers above gate *correctness* hygiene.

Keep this module import-light: the CLI and the retrace fixture import jax
lazily so ``--skip-jaxpr`` lint runs need no accelerator stack.
"""

from repro.analysis.findings import Finding  # noqa: F401  (public API)
from repro.analysis.retrace import (  # noqa: F401
    RetraceError,
    assert_max_compiles,
    count_compiles,
)
