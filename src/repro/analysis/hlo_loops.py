"""Loop-aware HLO cost analysis.

XLA's HloCostAnalysis (what ``compiled.cost_analysis()`` reports) counts each
``while`` body ONCE, so any lax.scan — our layer stacks, flash-attention
chunk loops, chunked CE — is massively under-counted. This module re-derives
three roofline inputs from the post-SPMD HLO text with trip-count
multipliers:

  * flops       — from ``dot`` instructions (2 * prod(out) * contraction),
                  multiplied along the while/fusion call chain;
  * hbm_bytes   — proxy: per *top-level* instruction, output bytes + operand
                  bytes (fusion internals excluded: they never hit HBM);
  * collectives — result bytes and ring-estimate wire bytes, trip-corrected.

Trip counts come from the largest integer constant in each while's condition
computation (lax.scan conditions compare the counter against the length).
This is exact for scan-generated loops, which are the only loops we emit.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\((.*?)\)\s*->")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPNAME = re.compile(r"^\(?[a-z0-9]+\[[0-9,]*\][^ ]*\s+([a-z\-]+)\(")
_TUPLE_OP = re.compile(r"^\((.*?)\)\s*([a-z\-]+)\(")
_CALLS = re.compile(r"(?:calls|to_apply)=%([\w.\-]+)")
_WHILE = re.compile(r"condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_OPERANDS = re.compile(r"\(((?:%[\w.\-]+(?:,\s*)?)+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "while", "conditional", "copy-start", "copy-done", "after-all",
    "opt-barrier",
}

_REPLICA_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_REPLICA_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _all_shapes_bytes(text: str) -> int:
    return sum(_shape_bytes(dt, dims) for dt, dims in _SHAPE.findall(text))


def _group_size(line: str) -> int:
    m = _REPLICA_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _REPLICA_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    lines: list
    params: dict            # name -> (dtype, dims) of first shape
    symbols: dict           # instr name -> list[(dtype, dims)]


def _parse_computations(text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        m = _COMP_HDR.match(line.strip())
        if m and line.rstrip().endswith("{"):
            params = {}
            for pm in re.finditer(r"([\w.\-]+):\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\]",
                                  m.group(3)):
                params[pm.group(1)] = (pm.group(2), pm.group(3))
            cur = Computation(m.group(2), bool(m.group(1)), [], params, {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        cur.lines.append(line)
        im = _INSTR.match(line)
        if im:
            shapes = _SHAPE.findall(im.group(2).split(" ", 1)[0] + " "
                                    + im.group(2))
            # first shape group(s) before the op name = output shape(s)
            head = im.group(2)
            op_split = re.match(r"^\(?(.*?)\)?\s[a-z\-]", head)
            out_shapes = _SHAPE.findall(head[: head.find("(")]) or shapes[:1]
            cur.symbols[im.group(1)] = out_shapes
    return comps


def _op_of(line: str) -> str | None:
    im = _INSTR.match(line)
    if not im:
        return None
    body = im.group(2)
    m = re.search(r"\s([a-z][a-z0-9\-]*)\(", " " + body)
    return m.group(1) if m else None


def _trip_count(comps: dict, cond_name: str) -> int:
    seen, stack, best = set(), [cond_name], 1
    while stack:
        c = stack.pop()
        if c in seen or c not in comps:
            continue
        seen.add(c)
        for line in comps[c].lines:
            for v in _CONST_INT.findall(line):
                best = max(best, int(v))
            cm = _CALLS.search(line)
            if cm:
                stack.append(cm.group(1))
    return best


def _multipliers(comps: dict) -> dict:
    """Effective execution count per computation, via DFS from ENTRY."""
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    mult = defaultdict(float)
    if entry is None:
        return mult
    mult[entry] = 1.0
    stack = [entry]
    visited_edges = set()
    while stack:
        name = stack.pop()
        comp = comps[name]
        m = mult[name]
        for line in comp.lines:
            wm = _WHILE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps, cond)
                for child, k in ((body, trips), (cond, trips + 1)):
                    edge = (name, child, k)
                    if edge in visited_edges:
                        continue
                    visited_edges.add(edge)
                    mult[child] += m * k
                    stack.append(child)
                continue
            bm = _BRANCHES.search(line)
            if bm:
                for child in re.findall(r"%([\w.\-]+)", bm.group(1)):
                    mult[child] += m
                    stack.append(child)
            cm = _CALLS.search(line)
            if cm:
                child = cm.group(1)
                edge = (name, child, 1)
                if edge in visited_edges:
                    continue
                visited_edges.add(edge)
                mult[child] += m
                stack.append(child)
    return mult


def _operand_shapes(comp: Computation, names: list):
    out = []
    for n in names:
        if n in comp.symbols and comp.symbols[n]:
            out.append(comp.symbols[n][0])
        elif n in comp.params:
            out.append(comp.params[n])
        else:
            out.append(None)
    return out


@dataclasses.dataclass
class LoopAwareStats:
    flops: float
    hbm_bytes: float
    collective_counts: dict
    collective_result_bytes: dict
    wire_bytes: float
    n_while: int


def analyze(text: str) -> LoopAwareStats:
    comps = _parse_computations(text)
    mult = _multipliers(comps)

    flops = 0.0
    hbm = 0.0
    coll_counts: dict = defaultdict(float)
    coll_bytes: dict = defaultdict(float)
    wire = 0.0
    n_while = 0

    # computations reachable only via fusion calls are "internal": their
    # instruction outputs never touch HBM. Track which comps are fusion-called.
    fusion_called = set()
    for comp in comps.values():
        for line in comp.lines:
            if " fusion(" in line or "kind=k" in line:
                cm = _CALLS.search(line)
                if cm:
                    fusion_called.add(cm.group(1))

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        internal = comp.name in fusion_called
        for line in comp.lines:
            im = _INSTR.match(line)
            if not im:
                continue
            op = _op_of(line)
            if op is None:
                continue
            if op == "while":
                n_while += 1

            # ---- dot flops (counted even inside fusions) ----
            if op == "dot":
                out_shapes = comp.symbols.get(im.group(1), [])
                out_elems = 1
                if out_shapes:
                    dims = out_shapes[0][1]
                    for d in dims.split(","):
                        if d:
                            out_elems *= int(d)
                opm = _OPERANDS.search(line[line.find("dot("):])
                contract = 1
                cm = _CONTRACT.search(line)
                if opm and cm is not None:
                    names = re.findall(r"%([\w.\-]+)", opm.group(1))
                    shapes = _operand_shapes(comp, names[:1])
                    if shapes and shapes[0]:
                        lhs_dims = [int(d) for d in shapes[0][1].split(",") if d]
                        for idx in cm.group(1).split(","):
                            if idx and int(idx) < len(lhs_dims):
                                contract *= lhs_dims[int(idx)]
                flops += m * 2.0 * out_elems * contract

            # ---- collective traffic ----
            if any(c == op or op.startswith(c) for c in _COLLECTIVES):
                kind = next(c for c in _COLLECTIVES
                            if c == op or op.startswith(c))
                if op.endswith("-done"):
                    continue
                # shapes appear between '=' and the op call; note the
                # instruction NAME also contains the op string, so slice
                # from '=' up to the op-call occurrence.
                eq = line.find("=")
                call = line.find(kind + "(", eq)
                if call < 0:
                    call = len(line)
                size = _all_shapes_bytes(line[eq:call])
                if size:
                    coll_counts[kind] += m
                    coll_bytes[kind] += m * size
                    n = max(_group_size(line), 2)
                    frac = (n - 1) / n
                    if kind == "all-reduce":
                        wire += m * 2 * size * frac
                    elif kind == "collective-permute":
                        wire += m * size
                    else:
                        wire += m * size * frac

            # ---- HBM proxy (top-level instructions only) ----
            if internal or op in _SKIP_BYTES_OPS:
                continue
            out_b = sum(_shape_bytes(dt, dims)
                        for dt, dims in comp.symbols.get(im.group(1), []))
            opm = _OPERANDS.search(line)
            in_b = 0
            if opm:
                names = re.findall(r"%([\w.\-]+)", opm.group(1))[:8]
                for sh in _operand_shapes(comp, names):
                    if sh:
                        in_b += _shape_bytes(*sh)
            hbm += m * (out_b + in_b)

    return LoopAwareStats(
        flops=flops,
        hbm_bytes=hbm,
        collective_counts=dict(coll_counts),
        collective_result_bytes=dict(coll_bytes),
        wire_bytes=wire,
        n_while=n_while,
    )
