"""Generate the EXPERIMENTS.md §Dry-run and §Roofline sections from the
dry-run artifacts (experiments/dryrun/*.json).

  PYTHONPATH=src python -m repro.analysis.report > experiments/roofline_report.md
"""
from __future__ import annotations

import glob
import json
import os

from repro.analysis.roofline import HBM_PER_CHIP

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str):
    recs = {}
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("mesh") == mesh:
            recs[(r["arch"], r["shape"])] = r
    return recs


def one_sentence(r) -> str:
    """What would move the dominant term down."""
    dom = r["roofline"]["dominant"]
    shape = r["shape"]
    if dom == "collective":
        if shape == "train_4k":
            return ("raise tau (amortize sync) or shrink FSDP gathers "
                    "(larger per-device shards / bf16 gathers)")
        return "shard KV/state over fewer axes or batch requests deeper"
    if dom == "memory":
        if "decode" in shape or shape == "long_500k":
            return "quantize KV cache (int8) and fuse the cache update"
        return "stronger remat / sequence parallelism to cut activation traffic"
    return "larger per-chip batch or fewer redundant (remat) FLOPs"


def section(mesh: str) -> str:
    recs = load(mesh)
    archs = sorted({a for a, _ in recs})
    out = [f"### Mesh `{mesh}`\n\n",
           "| arch | shape | prog | t_comp (s) | t_mem (s) | t_coll (s) | "
           "dominant | 6ND/HLO | peak GiB | fits 16 GiB | next lever |\n",
           "|---|---|---|---|---|---|---|---|---|---|\n"]
    for a in archs:
        for s in SHAPE_ORDER:
            r = recs.get((a, s))
            if r is None:
                continue
            if "skipped" in r:
                out.append(f"| {a} | {s} | — | — | — | — | skip | — | — | — | "
                           f"{r['skipped'][:48]} |\n")
                continue
            if not r.get("ok"):
                out.append(f"| {a} | {s} | — | — | — | — | **FAIL** | — | — | — |"
                           f" {r.get('error','')[:60]} |\n")
                continue
            rf = r["roofline"]
            prog_name = "train" if "local" in r else (
                "prefill" if "prefill" in r else "serve")
            prog = r.get("local") or r.get("prefill") or r.get("serve")
            ratio = r.get("useful_flops_ratio", float("nan"))
            out.append(
                f"| {a} | {s} | {prog_name} | {rf['t_compute_s']:.2e} | "
                f"{rf['t_memory_s']:.2e} | {rf['t_collective_s']:.2e} | "
                f"**{rf['dominant']}** | {ratio:.2f} | "
                f"{prog['peak_bytes_est']/2**30:.1f} | "
                f"{'✓' if prog['peak_bytes_est'] <= HBM_PER_CHIP else '✗'} | "
                f"{one_sentence(r)} |\n")
    return "".join(out)


def sync_table() -> str:
    """Cross-pod sync cost per strategy-relevant record (multi-pod train)."""
    recs = load("pod2x16x16")
    out = ["| arch | local wire B/step | sync wire B | sync colls | "
           "amortized coll term (tau=8) |\n|---|---|---|---|---|\n"]
    for (a, s), r in sorted(recs.items()):
        if s != "train_4k" or not r.get("ok"):
            continue
        lw = r["local"]["wire_bytes"]
        sw = r["sync"]["wire_bytes"]
        tau = r.get("tau", 8)
        amort = ((tau - 1) * lw + sw) / tau / 50e9
        out.append(f"| {a} | {lw:.3g} | {sw:.3g} | "
                   f"{r['sync']['collective_counts']} | {amort:.2e} s |\n")
    return "".join(out)


def main():
    print("## §Dry-run / §Roofline (auto-generated from experiments/dryrun)\n")
    print(section("pod16x16"))
    print("\n### Multi-pod (2x16x16): cross-pod sync cost per strategy\n")
    print(sync_table())


if __name__ == "__main__":
    main()
