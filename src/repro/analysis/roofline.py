"""Three-term roofline model from dry-run compiled artifacts (TPU v5e target).

  compute term    = HLO_FLOPs / peak_FLOPs            (per device)
  memory term     = HLO_bytes / HBM_bw                (per device)
  collective term = wire_bytes / ICI_bw               (per device)

cost_analysis() reports *per-device* FLOPs/bytes for SPMD modules; collective
wire bytes come from analysis.hlo_stats. MODEL_FLOPS uses 6*N*D (train) /
2*N*D (inference) with N = active params — the useful-compute yardstick.
"""
from __future__ import annotations

import dataclasses

# TPU v5e per-chip constants (from the assignment):
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (we assume 1 effective link;
                             # a 2D-torus axis would double this — noted)
HBM_PER_CHIP = 16 * 1024**3  # v5e: 16 GiB


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per-device HLO flops for the program
    hbm_bytes: float             # per-device bytes accessed
    wire_bytes: float            # per-device collective bytes (ring estimate)
    t_compute: float
    t_memory: float
    t_collective: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def total(self) -> float:
        # no-overlap upper bound on step time
        return self.t_compute + self.t_memory + self.t_collective

    @property
    def bound(self) -> float:
        # perfect-overlap lower bound
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "wire_bytes": self.wire_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
        }


def roofline(flops: float, hbm_bytes: float, wire_bytes: float) -> RooflineTerms:
    return RooflineTerms(
        flops=flops,
        hbm_bytes=hbm_bytes,
        wire_bytes=wire_bytes,
        t_compute=flops / PEAK_FLOPS,
        t_memory=hbm_bytes / HBM_BW,
        t_collective=wire_bytes / ICI_BW,
    )


def model_flops(cfg, shape, n_chips: int) -> float:
    """Useful FLOPs per device per step: 6ND train, 2ND decode/prefill."""
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.tokens
        per_token = 6 * n_active
    elif shape.kind == "prefill":
        tokens = shape.tokens
        per_token = 2 * n_active
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        per_token = 2 * n_active
    return per_token * tokens / n_chips


def amortized_period(local: RooflineTerms, sync: RooflineTerms, tau: int) -> dict:
    """Per-step averages over a period: (tau-1) local + 1 sync (the paper's
    communication amortization, eq. 7 instantiated with measured bytes)."""
    def avg(a, b):
        return ((tau - 1) * a + b) / tau

    return {
        "t_compute_s": avg(local.t_compute, sync.t_compute),
        "t_memory_s": avg(local.t_memory, sync.t_memory),
        "t_collective_s": avg(local.t_collective, sync.t_collective),
        "sync_wire_bytes": sync.wire_bytes,
        "local_wire_bytes": local.wire_bytes,
    }
