"""``python -m repro.analysis`` — the trace-safety analyzer CLI.

Runs the AST lint (RPR rules) over the Python sources and the jaxpr audit
(JXA rules) over the registered hot-path entry points, diffs the combined
findings against the committed baseline, and reports.

    python -m repro.analysis --check             # CI gate: exit 1 on NEW findings
    python -m repro.analysis --update-baseline   # re-freeze current findings
    python -m repro.analysis --skip-jaxpr ...    # lint-only (compat legs)
    python -m repro.analysis src/repro/rl        # narrow the linted paths

Exit codes: 0 clean (or informational run), 1 new findings under ``--check``,
2 internal error.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List

from repro.analysis.findings import (
    BASELINE_PATH,
    Finding,
    diff_baseline,
    load_baseline,
    save_baseline,
)

DEFAULT_PATHS = ("src/repro", "benchmarks", "examples")


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="trace-safety analyzer: RPR AST lint + JXA jaxpr audit",
    )
    p.add_argument("paths", nargs="*",
                   help=f"files/dirs to lint (default: {', '.join(DEFAULT_PATHS)})")
    p.add_argument("--check", action="store_true",
                   help="exit 1 if any finding is not in the baseline")
    p.add_argument("--update-baseline", action="store_true",
                   help="rewrite the baseline from the current findings")
    p.add_argument("--baseline", default=BASELINE_PATH,
                   help="baseline JSON path (default: the committed one)")
    p.add_argument("--skip-jaxpr", action="store_true",
                   help="skip the jaxpr audit (AST lint only; no jax import)")
    p.add_argument("--skip-lint", action="store_true",
                   help="skip the AST lint (jaxpr audit only)")
    p.add_argument("--only-entry", action="append", default=None,
                   metavar="NAME", help="audit only this hot-path entry "
                   "(repeatable; see dispatch.hot_path_factories)")
    return p


def main(argv: List[str] = None) -> int:
    args = _parser().parse_args(argv)
    findings: List[Finding] = []

    if not args.skip_lint:
        from repro.analysis.lint import lint_paths

        paths = args.paths or [p for p in DEFAULT_PATHS if os.path.exists(p)]
        findings.extend(lint_paths(paths))

    if not args.skip_jaxpr:
        from repro.analysis.jaxpr_audit import run_audit

        findings.extend(run_audit(only=args.only_entry))

    if args.update_baseline:
        save_baseline(findings, args.baseline)
        print(f"baseline updated: {args.baseline} "
              f"({len(findings)} findings frozen)")
        return 0

    baseline = load_baseline(args.baseline)
    new, resolved = diff_baseline(findings, baseline)
    known = len(findings) - len(new)

    for f in new:
        print(f.render())
    if resolved:
        print(f"note: {len(resolved)} baselined finding(s) no longer occur "
              f"— run --update-baseline to prune:", file=sys.stderr)
        for fp in resolved:
            print(f"  {fp}", file=sys.stderr)

    status = (
        f"{len(findings)} finding(s): {len(new)} new, {known} baselined"
    )
    print(status)
    if args.check and new:
        print("FAIL: new findings above are not in the baseline "
              "(fix them, # noqa them, or --update-baseline)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
