"""Parse collective traffic out of post-SPMD HLO text.

cost_analysis() has FLOPs and bytes-accessed but NOT collective bytes; we
regex the compiled module for all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instructions and sum their result-shape bytes
(per-device, since post-partitioning shapes are per-device).

Wire-byte estimates per op (ring algorithms, n = participating devices):
  all-reduce      2 * size * (n-1)/n      (reduce-scatter + all-gather phases)
  all-gather      size * (n-1)/n          (size = full output)
  reduce-scatter  size * (n-1)/n          (size = full input ~ output * n)
  all-to-all      size * (n-1)/n
  collective-permute  size                (point-to-point)
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.:  %all-gather.3 = bf16[2,512,1024]{2,1,0} all-gather(...)
_INSTR_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^a-z]*\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
# tuple-shaped collectives:  = (bf16[..], bf16[..]) all-to-all(
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_REPLICA_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_REPLICA_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _REPLICA_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _REPLICA_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    result_bytes: dict       # per collective kind, per-device result bytes
    wire_bytes: float        # ring-estimate bytes on the wire per device

    @property
    def total_result_bytes(self) -> float:
        return float(sum(self.result_bytes.values()))


def collective_stats(hlo_text: str) -> CollectiveStats:
    counts = defaultdict(int)
    result_bytes = defaultdict(float)
    wire = 0.0
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        m = _INSTR_RE.search(line)
        shapes = []
        kind = None
        if m:
            kind = m.group(3)
            shapes = [(m.group(1), m.group(2))]
        else:
            mt = _TUPLE_RE.search(line)
            if mt:
                kind = mt.group(2)
                shapes = _SHAPE_RE.findall(mt.group(1))
        if not kind or "-done" in line:
            continue
        size = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        if size == 0:
            continue
        counts[kind] += 1
        result_bytes[kind] += size
        n = max(_group_size(line), 2)
        frac = (n - 1) / n
        if kind == "all-reduce":
            wire += 2 * size * frac
        elif kind == "collective-permute":
            wire += size
        else:
            wire += size * frac
    return CollectiveStats(dict(counts), dict(result_bytes), wire)
