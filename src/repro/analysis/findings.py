"""Findings + baseline bookkeeping shared by the trace-safety analyzers.

A :class:`Finding` is one defect report from either analysis layer — an AST
lint rule (``RPR0xx``, ``repro.analysis.lint``) or a jaxpr-audit rule
(``JXA0xx``, ``repro.analysis.jaxpr_audit``). Findings are compared against a
committed baseline file (``src/repro/analysis/baseline.json``) so CI fails
only on *new* findings: pre-existing debt is frozen in the baseline and paid
down incrementally, while any fresh violation of a rule turns the lint job
red immediately.

Fingerprints deliberately exclude line numbers — they are
``rule :: path :: enclosing scope :: normalized source snippet`` — so
unrelated edits that shift code up or down do not churn the baseline; only
adding, removing, or editing the offending construct does. Identical
constructs in one scope are disambiguated by a count per fingerprint.
"""
from __future__ import annotations

import dataclasses
import json
import os
from collections import Counter
from typing import Dict, Iterable, List, Tuple

BASELINE_PATH = os.path.join(os.path.dirname(__file__), "baseline.json")

BASELINE_SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer finding.

    ``rule``: ``RPR001``..``RPR005`` (AST lint) or ``JXA001``..``JXA004``
    (jaxpr audit). ``path``: repo-relative file path for lint findings, the
    registered entry-point name for audit findings. ``scope``: enclosing
    function qualname (lint) or jaxpr location hint (audit). ``line`` is
    display-only and never part of the fingerprint.
    """

    rule: str
    path: str
    scope: str
    message: str
    snippet: str = ""
    line: int = 0

    @property
    def fingerprint(self) -> str:
        snip = " ".join(self.snippet.split())
        return f"{self.rule}::{self.path}::{self.scope}::{snip}"

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: {self.rule} [{self.scope}] {self.message}"


def fingerprint_counts(findings: Iterable[Finding]) -> Dict[str, int]:
    return dict(Counter(f.fingerprint for f in findings))


def load_baseline(path: str = BASELINE_PATH) -> Dict[str, int]:
    """The committed fingerprint->count map ({} when no baseline exists)."""
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        payload = json.load(f)
    if payload.get("schema_version") != BASELINE_SCHEMA_VERSION:
        raise ValueError(
            f"baseline {path}: unsupported schema "
            f"{payload.get('schema_version')!r}"
        )
    return dict(payload.get("findings", {}))


def save_baseline(findings: Iterable[Finding], path: str = BASELINE_PATH) -> str:
    payload = {
        "schema_version": BASELINE_SCHEMA_VERSION,
        "findings": dict(sorted(fingerprint_counts(findings).items())),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def diff_baseline(
    findings: List[Finding], baseline: Dict[str, int]
) -> Tuple[List[Finding], List[str]]:
    """Split current findings against the baseline.

    Returns ``(new, resolved)``: findings beyond the baselined count per
    fingerprint (the CI-failing set, in input order), and baselined
    fingerprints that no longer occur (stale debt — prune with
    ``--update-baseline``).
    """
    seen: Counter = Counter()
    new = []
    for f in findings:
        seen[f.fingerprint] += 1
        if seen[f.fingerprint] > baseline.get(f.fingerprint, 0):
            new.append(f)
    resolved = sorted(fp for fp, n in baseline.items() if seen.get(fp, 0) < n)
    return new, resolved
