"""Layer 1: jaxpr audit of the registered hot-path entry points.

Every module that owns a piece of the federated hot path registers a
:class:`~repro.kernels.dispatch.HotPathEntry` (the four dispatch primitives
on the jnp and interpret backends, ``run_fedrl_core``, ``run_fmarl_core``,
and the sweep runner's per-static-point batched fn). The audit lowers each
entry with ``jax.make_jaxpr`` over abstract arguments — nothing executes —
and walks the closed jaxpr recursively (scan/while/cond/pjit sub-jaxprs
included) to flag:

  JXA001  sub-fp32 accumulation: a ``reduce_sum``/``dot_general``/
          ``conv_general_dilated``/``cumsum`` whose *output* dtype is below
          fp32 — the ``preferred_element_type`` was dropped, so bf16/f16
          operands accumulate at operand precision and drift from the
          reference path.
  JXA002  host callback (``pure_callback``/``io_callback``/
          ``debug_callback``) inside a scan/while body: a device->host
          round-trip per step of the traced loop.
  JXA003  large constant-folded literal: a closed-over constant above
          ``LARGE_CONST_ELEMS`` elements baked into the jaxpr — the
          traced-mask-vs-literal divergence class (a mask folded as a
          constant retraces per value and bloats the executable).
  JXA004  declared-but-unused donation: the entry registers
          ``donate_argnums`` but the jit lowering aliases no input to an
          output, so the "in-place" carry silently double-buffers.
  JXA000  entry failed to lower at all (import/trace error) — always a
          finding, never silently skipped.

Findings use the entry name as ``path`` and the sub-jaxpr nesting chain
(e.g. ``scan>pjit``) as ``scope``, so fingerprints survive refactors that
only move source lines.
"""
from __future__ import annotations

import importlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding

LARGE_CONST_ELEMS = 16384

# Modules that register hot-path entries at import time. dispatch registers
# its own primitives; the drivers and the sweep runner add theirs.
ENTRY_MODULES = (
    "repro.kernels.dispatch",
    "repro.comm.transforms",
    "repro.rl.fedrl",
    "repro.core.fmarl",
    "repro.core.async_fed",
    "repro.sweep.runner",
    "repro.serve.engine",
)

_ACCUM_PRIMS = {"reduce_sum", "reduce_prod", "dot_general",
                "conv_general_dilated", "cumsum"}
_CALLBACK_PRIMS = {"pure_callback", "io_callback", "debug_callback"}
_LOOP_PRIMS = {"scan", "while"}


def collect_entries(
    only: Optional[Iterable[str]] = None,
) -> Tuple[Dict[str, object], List[Finding]]:
    """Import the registering modules and snapshot the registry.

    Returns ``(entries, findings)`` where ``entries`` maps name ->
    ``HotPathEntry`` factory output is *not* yet built (factories run in
    :func:`audit_entries` so one broken entry cannot hide the rest), and
    ``findings`` holds JXA000 reports for modules that failed to import.
    """
    findings: List[Finding] = []
    for mod in ENTRY_MODULES:
        try:
            importlib.import_module(mod)
        except Exception as e:  # pragma: no cover - env-dependent
            findings.append(Finding(
                rule="JXA000", path=mod, scope="<import>",
                message=f"hot-path module failed to import: {e!r}",
            ))
    from repro.kernels.dispatch import hot_path_factories

    factories = hot_path_factories()
    if only is not None:
        wanted = set(only)
        unknown = wanted - set(factories)
        for name in sorted(unknown):
            findings.append(Finding(
                rule="JXA000", path=name, scope="<registry>",
                message="no such registered hot-path entry",
            ))
        factories = {k: v for k, v in factories.items() if k in wanted}
    return factories, findings


def _float_bits(dtype) -> Optional[int]:
    import jax.numpy as jnp

    d = jnp.dtype(dtype)
    if jnp.issubdtype(d, jnp.floating):
        return jnp.finfo(d).bits
    return None


def _sub_jaxprs(eqn) -> List[object]:
    """All Jaxpr/ClosedJaxpr values hiding in an equation's params."""
    try:  # moved to jax.extend.core across JAX releases
        from jax.extend.core import ClosedJaxpr, Jaxpr
    except ImportError:  # pragma: no cover - version-dependent
        from jax.core import ClosedJaxpr, Jaxpr

    found: List[object] = []

    def visit(v):
        if isinstance(v, (Jaxpr, ClosedJaxpr)):
            found.append(v)
        elif isinstance(v, (tuple, list)):
            for x in v:
                visit(x)

    for v in eqn.params.values():
        visit(v)
    return found


def _walk(jaxpr, entry_name: str, chain: str, in_loop: bool,
          out: List[Finding]) -> None:
    closed = jaxpr
    inner = getattr(closed, "jaxpr", closed)  # ClosedJaxpr -> Jaxpr
    consts = getattr(closed, "consts", ())

    for c in consts:
        size = getattr(c, "size", 0)
        if size and size > LARGE_CONST_ELEMS:
            out.append(Finding(
                rule="JXA003", path=entry_name, scope=chain or "<top>",
                message=(
                    f"constant-folded literal of {size} elements "
                    f"(shape {getattr(c, 'shape', '?')}) baked into the "
                    f"jaxpr — pass it as an operand so it stays traced"
                ),
                snippet=f"const{tuple(getattr(c, 'shape', ()))}",
            ))

    for eqn in inner.eqns:
        name = eqn.primitive.name
        if name in _ACCUM_PRIMS:
            for var in eqn.outvars:
                bits = _float_bits(var.aval.dtype)
                if bits is not None and bits < 32:
                    out.append(Finding(
                        rule="JXA001", path=entry_name,
                        scope=chain or "<top>",
                        message=(
                            f"{name} accumulates at {var.aval.dtype} "
                            f"(< fp32) — set preferred_element_type / "
                            f"upcast the operands"
                        ),
                        snippet=f"{name}->{var.aval.dtype}",
                    ))
        if name in _CALLBACK_PRIMS and in_loop:
            cb = eqn.params.get("callback", "")
            out.append(Finding(
                rule="JXA002", path=entry_name, scope=chain or "<top>",
                message=(
                    f"host callback {name} inside a scan/while body — "
                    f"a device->host round-trip every step"
                ),
                snippet=f"{name}:{getattr(cb, '__name__', cb)}"[:80],
            ))
        subs = _sub_jaxprs(eqn)
        if subs:
            child_chain = f"{chain}>{name}" if chain else name
            child_in_loop = in_loop or name in _LOOP_PRIMS
            for sub in subs:
                _walk(sub, entry_name, child_chain, child_in_loop, out)


def audit_entry(name: str, entry) -> List[Finding]:
    """All JXA findings for one registered entry (built + lowered here)."""
    import jax

    out: List[Finding] = []
    try:
        closed = jax.make_jaxpr(entry.fn)(*entry.args)
    except Exception as e:
        return [Finding(
            rule="JXA000", path=name, scope="<trace>",
            message=f"entry failed to lower: {type(e).__name__}: {e}",
        )]
    _walk(closed, name, "", False, out)

    if entry.donate_argnums:
        try:
            lowered = jax.jit(
                entry.fn, donate_argnums=entry.donate_argnums
            ).lower(*entry.args)
            text = lowered.as_text()
        except Exception as e:
            out.append(Finding(
                rule="JXA000", path=name, scope="<donation>",
                message=f"donation lowering failed: {type(e).__name__}: {e}",
            ))
        else:
            if "tf.aliasing_output" not in text:
                out.append(Finding(
                    rule="JXA004", path=name, scope="<donation>",
                    message=(
                        "entry declares donate_argnums="
                        f"{tuple(entry.donate_argnums)} but the lowering "
                        "aliases no input to an output — the donated carry "
                        "double-buffers"
                    ),
                    snippet=f"donate{tuple(entry.donate_argnums)}",
                ))
    return out


def run_audit(only: Optional[Sequence[str]] = None) -> List[Finding]:
    """Audit every registered hot-path entry point (or the ``only`` subset)."""
    factories, findings = collect_entries(only)
    for name in sorted(factories):
        try:
            entry = factories[name]()
        except Exception as e:
            findings.append(Finding(
                rule="JXA000", path=name, scope="<factory>",
                message=f"entry factory raised: {type(e).__name__}: {e}",
            ))
            continue
        findings.extend(audit_entry(name, entry))
    return findings
