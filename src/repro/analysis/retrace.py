"""Layer 3: retrace guard — count XLA backend compilations at runtime.

The sweep engine's contract (PR 4/5) is one compile per static point: every
``(seed, hyperparam)`` combination that only varies *traced* values batches
through a single executable, and adding a sweep axis must not add compiles.
Nothing enforced that until now — a silently-static argument (a Python float
threaded into jit, an unhashed config object) turns O(1) compiles into
O(points) and the only symptom is a slow benchmark.

:class:`count_compiles` counts backend compilations via JAX's monitoring
events (``.../backend_compile...`` fires once per XLA compile; cached jit
hits fire nothing; an AOT ``.lower().compile()`` fires exactly once). It
nests: each ``with`` level sees the compiles of everything beneath it.

The pytest side lives in ``tests/conftest.py`` as the ``assert_max_compiles``
fixture; ``benchmarks/run.py`` prints the per-bench compile count with the
timings so a retrace regression is visible in CI bench logs too.
"""
from __future__ import annotations

import threading
from typing import List

_COMPILE_EVENT_SUBSTRING = "backend_compile"

_lock = threading.Lock()
_active: List["count_compiles"] = []
_listener_installed = False


def _on_event_duration(event: str, duration_secs: float, **kwargs) -> None:
    if _COMPILE_EVENT_SUBSTRING not in event:
        return
    with _lock:
        for counter in _active:
            counter.count += 1


def _ensure_listener() -> None:
    """Install the process-global monitoring listener once, lazily.

    Registration is permanent (jax.monitoring has no unregister that is
    stable across versions), so the listener stays a cheap no-op whenever no
    counter is active.
    """
    global _listener_installed
    with _lock:
        if _listener_installed:
            return
        import jax.monitoring

        jax.monitoring.register_event_duration_secs_listener(_on_event_duration)
        _listener_installed = True


class count_compiles:
    """Context manager counting XLA backend compiles in its dynamic extent.

    ::

        with count_compiles() as c:
            run_sweep(spec)
        assert c.count == n_static_points

    ``count`` is live while the block runs and frozen afterwards. Instances
    nest; each level observes all compiles under it. Thread-safe in the
    counting path (compiles from worker threads are attributed to every
    active counter).
    """

    def __init__(self) -> None:
        self.count = 0

    def __enter__(self) -> "count_compiles":
        _ensure_listener()
        with _lock:
            _active.append(self)
        return self

    def __exit__(self, *exc) -> None:
        with _lock:
            _active.remove(self)


def assert_max_compiles(max_compiles: int, fn, *args, **kwargs):
    """Run ``fn(*args, **kwargs)``; fail if it compiles more than allowed.

    Returns ``(result, n_compiles)``. Raises :class:`RetraceError` (an
    ``AssertionError`` subclass, so pytest renders it as a failure) when the
    budget is exceeded.
    """
    with count_compiles() as c:
        result = fn(*args, **kwargs)
    if c.count > max_compiles:
        raise RetraceError(
            f"{getattr(fn, '__name__', fn)!r} triggered {c.count} XLA "
            f"compilations (budget: {max_compiles}) — a static argument is "
            f"varying per call, or a jit cache miss crept into the hot path"
        )
    return result, c.count


class RetraceError(AssertionError):
    """Compile budget exceeded inside :func:`assert_max_compiles`."""


def warmup_jax(*arrays) -> None:
    """Absorb one-time tiny-op compiles (``jnp.asarray`` etc.) before
    counting, so budgets measure the entry point under test and not the
    interpreter's first-touch constants."""
    import jax.numpy as jnp

    for a in arrays if arrays else (0.0,):
        jnp.asarray(a).block_until_ready()


__all__ = [
    "count_compiles",
    "assert_max_compiles",
    "RetraceError",
    "warmup_jax",
]
