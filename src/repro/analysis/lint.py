"""Custom JAX trace-hygiene lint: the RPR rule set over Python ASTs.

Stock linters know nothing about trace discipline. These rules encode the
bug classes this codebase has actually shipped and fixed by hand (PRNG-key
reuse in ``_eval_grad_norm``, host round-trips, retrace bait) so they are
caught at lint time instead of at parity-test-divergence time:

  RPR001  PRNG key consumed by >= 2 consumers without an interleaved
          ``jax.random.split``/``fold_in`` (dataflow within a function
          body), including a key captured by a closure handed to a
          multi-invocation transform (``jax.tree.map`` — the correlated
          per-leaf-noise bug).
  RPR002  Python ``for``/``while`` inside a ``lax.scan``/``while_loop``/
          ``fori_loop``/``lax.map`` body: the loop unrolls into the trace
          (or fails on a traced bound) instead of staying a traced axis.
  RPR003  host ``numpy`` call on a value that flows from the parameters of
          a traced function (scan/vmap/jit/grad body): implicit device
          transfer, breaks under jit.
  RPR004  ``float()``/``int()``/``bool()``/``.item()``/``.tolist()`` on a
          potential tracer inside a traced function: concretization error
          under jit, silent host sync outside it.
  RPR005  retrace bait at ``jax.jit`` sites: jitted functions with mutable
          (dict/list/set) default arguments, or ``jax.jit`` called inside a
          Python loop (a fresh wrapper — and trace — per iteration).

Findings carry line-independent fingerprints (``repro.analysis.findings``)
and are gated against ``baseline.json``: CI fails only on findings that are
not in the committed baseline. Suppress a deliberate construct in place with
``# noqa: RPR00x`` on the offending line.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, Iterable, List, Optional, Set

from repro.analysis.findings import Finding

RULES = {
    "RPR001": "PRNG key reuse without an interleaved split",
    "RPR002": "Python loop inside a traced scan/loop body",
    "RPR003": "host numpy call on a traced value",
    "RPR004": "tracer concretization (float/int/bool/.item)",
    "RPR005": "retrace bait at a jax.jit call site",
}

# Canonical dotted names (after import-alias resolution).
_KEY_SOURCES = {
    "jax.random.key", "jax.random.PRNGKey", "jax.random.split",
    "jax.random.fold_in", "jax.random.clone", "jax.random.wrap_key_data",
}
_LOOP_FNS = {
    "jax.lax.scan", "jax.lax.while_loop", "jax.lax.fori_loop",
    "jax.lax.map", "jax.lax.associative_scan",
}
_TRACE_FNS = _LOOP_FNS | {
    "jax.jit", "jax.vmap", "jax.pmap", "jax.grad", "jax.value_and_grad",
    "jax.checkpoint", "jax.remat", "jax.lax.cond", "jax.lax.switch",
    "jax.custom_jvp", "jax.custom_vjp",
}
# Transforms that invoke a passed/capturing callable more than once per call.
_MULTI_INVOKE_FNS = _LOOP_FNS | {
    "jax.tree.map", "jax.tree_map", "jax.tree_util.tree_map",
    "jax.vmap", "jax.pmap",
}
# Function parameters with these names are assumed to be PRNG keys. Bare
# ``k`` is deliberately absent: in model code it names the attention key
# tensor far more often than a PRNG key (keys from jax.random assignments
# are tracked by dataflow regardless of name).
_KEY_PARAM_RE = re.compile(
    r"^(key|keys|rng|rngs|prng|prng_key|rng_key|sub|subkey|subkeys)$"
)
_CONCRETIZERS = {"float", "int", "bool", "complex"}
_NOQA_RE = re.compile(r"#\s*noqa(?::\s*(?P<rules>[A-Z0-9, ]+))?", re.I)


# --- import-alias resolution --------------------------------------------------

def _module_aliases(tree: ast.Module) -> Dict[str, str]:
    """Map local names to canonical dotted prefixes from the import table."""
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0]
                )
        elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
            for a in node.names:
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` expression -> ``"a.b.c"`` (None for anything fancier)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _canonical(node: ast.AST, aliases: Dict[str, str]) -> Optional[str]:
    dotted = _dotted(node)
    if dotted is None:
        return None
    head, _, rest = dotted.partition(".")
    base = aliases.get(head, head)
    return f"{base}.{rest}" if rest else base


# --- scope / traced-context analysis ------------------------------------------

_FuncNode = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


class _Scopes:
    """Function-scope index: qualnames, parents, local def tables, and the
    traced/loop-body context marks used by RPR002/3/4."""

    def __init__(self, tree: ast.Module, aliases: Dict[str, str]):
        self.aliases = aliases
        self.parent: Dict[ast.AST, Optional[ast.AST]] = {}
        self.qualname: Dict[ast.AST, str] = {}
        self.defs: Dict[Optional[ast.AST], Dict[str, ast.AST]] = {None: {}}
        self.traced: Set[ast.AST] = set()
        self.loop_body: Set[ast.AST] = set()
        self._index(tree, None, "")
        self._mark_contexts(tree)

    def _index(self, node: ast.AST, fn: Optional[ast.AST], prefix: str):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, _FuncNode):
                name = getattr(child, "name", "<lambda>")
                qual = f"{prefix}.{name}" if prefix else name
                self.parent[child] = fn
                self.qualname[child] = qual
                self.defs.setdefault(fn, {})[name] = child
                self.defs.setdefault(child, {})
                self._index(child, child, qual)
            else:
                self._index(child, fn, prefix)

    def enclosing(self, fn: Optional[ast.AST]) -> Iterable[ast.AST]:
        while fn is not None:
            yield fn
            fn = self.parent.get(fn)

    def resolve_local(self, name: str, fn: Optional[ast.AST]) -> Optional[ast.AST]:
        """Nearest lexically-enclosing def of ``name`` visible from ``fn``."""
        scope: Optional[ast.AST] = fn
        while True:
            found = self.defs.get(scope, {}).get(name)
            if found is not None:
                return found
            if scope is None:
                return None
            scope = self.parent.get(scope)

    def _owner_of(self, node: ast.AST, tree: ast.Module) -> Optional[ast.AST]:
        # Recompute lightweight expression ownership: walk functions, check
        # containment by span of the function subtree.
        return self._owners.get(node)

    def _mark_contexts(self, tree: ast.Module):
        # Map every node to its owning function for call-site resolution.
        self._owners: Dict[ast.AST, Optional[ast.AST]] = {}

        def walk(node, fn):
            for child in ast.iter_child_nodes(node):
                self._owners[child] = fn
                walk(child, child if isinstance(child, _FuncNode) else fn)

        self._owners[tree] = None
        walk(tree, None)

        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            canon = _canonical(node.func, self.aliases)
            if canon not in _TRACE_FNS:
                continue
            is_loop = canon in _LOOP_FNS
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                target = None
                if isinstance(arg, ast.Lambda):
                    target = arg
                elif isinstance(arg, ast.Name):
                    target = self.resolve_local(arg.id, self._owners.get(node))
                if target is None:
                    continue
                self._mark(target, loop=is_loop)
        # @jax.jit-style decorators
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    call = dec.func if isinstance(dec, ast.Call) else dec
                    canon = _canonical(call, self.aliases)
                    if canon in _TRACE_FNS:
                        self._mark(node, loop=False)
                    elif canon == "functools.partial" and isinstance(dec, ast.Call):
                        for a in dec.args[:1]:
                            if _canonical(a, self.aliases) in _TRACE_FNS:
                                self._mark(node, loop=False)

    def _mark(self, fn: ast.AST, *, loop: bool):
        stack = [fn]
        while stack:
            f = stack.pop()
            if loop:
                if f in self.loop_body:
                    continue
                self.loop_body.add(f)
            self.traced.add(f)
            stack.extend(self.defs.get(f, {}).values())
        if not loop:
            # nested defs of a traced fn are traced too
            for child in list(self.defs.get(fn, {}).values()):
                if child not in self.traced:
                    self._mark(child, loop=False)


# --- RPR001: PRNG key dataflow ------------------------------------------------

def _terminates(stmts: List[ast.stmt]) -> bool:
    """Whether a straight-line block surely leaves the enclosing scope."""
    return any(
        isinstance(s, (ast.Return, ast.Raise, ast.Break, ast.Continue))
        for s in stmts
    )


class _KeyState:
    __slots__ = ("counts",)

    def __init__(self, counts: Optional[Dict[str, int]] = None):
        self.counts = dict(counts or {})  # tracked key name -> consume count

    def copy(self) -> "_KeyState":
        return _KeyState(self.counts)

    def merge(self, *others: "_KeyState"):
        for o in others:
            for name, n in o.counts.items():
                self.counts[name] = max(self.counts.get(name, 0), n)


class _KeyLinter:
    """Order-aware key-consumption walker for one function body."""

    def __init__(self, rules_out: List[Finding], path: str, scope: str,
                 aliases: Dict[str, str]):
        self.out = rules_out
        self.path = path
        self.scope = scope
        self.aliases = aliases
        self.reported: Set[str] = set()

    # -- entry point
    def run(self, fn: ast.AST):
        state = _KeyState()
        for p in self._params(fn):
            if _KEY_PARAM_RE.match(p):
                state.counts[p] = 0
        body = fn.body if isinstance(fn.body, list) else [ast.Return(fn.body)]
        self._block(body, state)

    @staticmethod
    def _params(fn: ast.AST) -> List[str]:
        a = fn.args
        names = [x.arg for x in a.posonlyargs + a.args + a.kwonlyargs]
        if a.vararg:
            names.append(a.vararg.arg)
        if a.kwarg:
            names.append(a.kwarg.arg)
        return names

    # -- statements
    def _block(self, stmts: List[ast.stmt], state: _KeyState):
        for s in stmts:
            self._stmt(s, state)

    def _stmt(self, s: ast.stmt, state: _KeyState):
        if isinstance(s, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = s.value
            if value is not None:
                self._expr(value, state)
            targets = s.targets if isinstance(s, ast.Assign) else [s.target]
            fresh = value is not None and self._is_key_source(value)
            for t in targets:
                self._bind_target(t, state, fresh)
        elif isinstance(s, ast.If):
            self._expr(s.test, state)
            b1, b2 = state.copy(), state.copy()
            self._block(s.body, b1)
            self._block(s.orelse, b2)
            state.counts.clear()
            # A branch that cannot fall through (early return/raise) never
            # reaches the code after the if — its consumption counts must
            # not combine with the continuation's (``if c: return f(key)``
            # followed by ``return g(key)`` consumes the key exactly once).
            live = []
            if not _terminates(s.body):
                live.append(b1)
            if not _terminates(s.orelse):
                live.append(b2)
            state.merge(*live)  # both terminate -> continuation unreachable
        elif isinstance(s, (ast.For, ast.AsyncFor)):
            self._expr(s.iter, state)
            fresh_iter = self._is_key_source(s.iter)
            body_state = state.copy()
            for _pass in range(2):  # second pass models reuse across iters
                self._bind_target(s.target, body_state, fresh_iter)
                self._block(s.body, body_state)
            self._block(s.orelse, body_state)
            state.merge(body_state)
        elif isinstance(s, ast.While):
            body_state = state.copy()
            for _pass in range(2):
                self._expr(s.test, body_state)
                self._block(s.body, body_state)
            self._block(s.orelse, body_state)
            state.merge(body_state)
        elif isinstance(s, ast.Try):
            b = state.copy()
            self._block(s.body, b)
            branches = [b]
            for h in s.handlers:
                hb = state.copy()
                self._block(h.body, hb)
                branches.append(hb)
            state.counts.clear()
            state.merge(*branches)
            self._block(s.orelse, state)
            self._block(s.finalbody, state)
        elif isinstance(s, ast.With):
            for item in s.items:
                self._expr(item.context_expr, state)
            self._block(s.body, state)
        elif isinstance(s, ast.Return) and s.value is not None:
            self._expr(s.value, state)
        elif isinstance(s, ast.Expr):
            self._expr(s.value, state)
        elif isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # Closures over tracked keys: a local def may be invoked many
            # times (or handed to a transform) — treat captured-key
            # consumption as repeated.
            self._closure(s, state, multiplier=2)
        # other statements (pass, raise, import, ...) carry no key flow

    def _bind_target(self, t: ast.AST, state: _KeyState, fresh: bool):
        if isinstance(t, (ast.Tuple, ast.List)):
            for el in t.elts:
                self._bind_target(el, state, fresh)
        elif isinstance(t, ast.Starred):
            self._bind_target(t.value, state, fresh)
        elif isinstance(t, ast.Name):
            if fresh:
                state.counts[t.id] = 0
            elif t.id in state.counts:
                del state.counts[t.id]  # rebound to a non-key value

    # -- expressions
    def _is_key_source(self, node: ast.AST) -> bool:
        return (
            isinstance(node, ast.Call)
            and _canonical(node.func, self.aliases) in _KEY_SOURCES
        )

    def _expr(self, node: ast.AST, state: _KeyState, mult: int = 1):
        if isinstance(node, ast.Call):
            canon = _canonical(node.func, self.aliases) or ""
            multi = canon in _MULTI_INVOKE_FNS
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, ast.Name) and arg.id in state.counts:
                    self._consume(arg, state, mult)
                elif isinstance(arg, ast.Lambda):
                    self._closure(arg, state,
                                  multiplier=2 if multi else max(mult, 1))
                else:
                    self._expr(arg, state, mult)
            self._expr(node.func, state, mult)
        elif isinstance(node, ast.Lambda):
            self._closure(node, state, multiplier=max(mult, 1))
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self._closure(node, state, multiplier=2)
        elif isinstance(node, ast.IfExp):
            # Ternary arms are exclusive: max-merge like an if statement.
            self._expr(node.test, state, mult)
            b1, b2 = state.copy(), state.copy()
            self._expr(node.body, b1, mult)
            self._expr(node.orelse, b2, mult)
            state.counts.clear()
            state.merge(b1, b2)
        else:
            for child in ast.iter_child_nodes(node):
                self._expr(child, state, mult)

    def _closure(self, fn: ast.AST, state: _KeyState, *, multiplier: int):
        """Process a nested callable: its own params shadow the outer keys;
        consumption of *captured* tracked keys propagates to the caller's
        state, scaled by how often the callable may run."""
        inner = state.copy()
        shadowed = set(self._params(fn))
        for p in shadowed:
            inner.counts.pop(p, None)
            if _KEY_PARAM_RE.match(p):
                inner.counts[p] = 0
        body = fn.body if isinstance(fn.body, list) else [ast.Expr(fn.body)]
        before = {k: v for k, v in inner.counts.items() if k not in shadowed}
        self._block(body, inner)
        for name, n0 in before.items():
            n1 = inner.counts.get(name, n0)
            if n1 > n0 and name in state.counts:
                delta = (n1 - n0) * multiplier
                state.counts[name] += delta
                if state.counts[name] >= 2:
                    self._report(name, fn)

    def _consume(self, name_node: ast.Name, state: _KeyState, mult: int):
        state.counts[name_node.id] += max(mult, 1)
        if state.counts[name_node.id] >= 2:
            self._report(name_node.id, name_node)

    def _report(self, name: str, node: ast.AST):
        if name in self.reported:
            return
        self.reported.add(name)
        self.out.append(Finding(
            rule="RPR001",
            path=self.path,
            scope=self.scope,
            message=(
                f"PRNG key {name!r} reaches two consumers without an "
                f"interleaved jax.random.split/fold_in — streams correlate"
            ),
            snippet=f"key={name}",
            line=getattr(node, "lineno", 0),
        ))


# --- RPR003/RPR004 taint ------------------------------------------------------

def _taint_rules(fn: ast.AST, scopes: _Scopes, path: str,
                 out: List[Finding]):
    """Host-numpy (RPR003) and concretization (RPR004) inside traced fns."""
    aliases = scopes.aliases
    scope = scopes.qualname.get(fn, "<module>")
    tainted: Set[str] = set(_KeyLinter._params(fn))

    def has_taint(node: ast.AST) -> bool:
        return any(
            isinstance(n, ast.Name) and n.id in tainted
            for n in ast.walk(node)
        )

    body = fn.body if isinstance(fn.body, list) else [ast.Expr(fn.body)]
    for stmt in body:
        for node in ast.walk(stmt):
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                if node.value is not None and has_taint(node.value):
                    targets = (node.targets if isinstance(node, ast.Assign)
                               else [node.target])
                    for t in targets:
                        for n in ast.walk(t):
                            if isinstance(n, ast.Name):
                                tainted.add(n.id)
            elif isinstance(node, ast.Call):
                canon = _canonical(node.func, aliases) or ""
                args = list(node.args) + [kw.value for kw in node.keywords]
                if canon.startswith("numpy.") and any(
                    has_taint(a) for a in args
                ):
                    out.append(Finding(
                        rule="RPR003", path=path, scope=scope,
                        message=(
                            f"host numpy call {canon}() on a value flowing "
                            f"from traced parameters — device round-trip, "
                            f"breaks under jit"
                        ),
                        snippet=ast.unparse(node)[:80],
                        line=node.lineno,
                    ))
                elif (
                    isinstance(node.func, ast.Name)
                    and node.func.id in _CONCRETIZERS
                    and node.func.id not in tainted
                    and len(args) == 1 and has_taint(args[0])
                ):
                    out.append(Finding(
                        rule="RPR004", path=path, scope=scope,
                        message=(
                            f"{node.func.id}() on a potential tracer — "
                            f"concretization error under jit"
                        ),
                        snippet=ast.unparse(node)[:80],
                        line=node.lineno,
                    ))
                elif (
                    isinstance(node.func, ast.Attribute)
                    and node.func.attr in ("item", "tolist")
                    and has_taint(node.func.value)
                ):
                    out.append(Finding(
                        rule="RPR004", path=path, scope=scope,
                        message=(
                            f".{node.func.attr}() on a potential tracer — "
                            f"host sync / concretization under jit"
                        ),
                        snippet=ast.unparse(node)[:80],
                        line=node.lineno,
                    ))


# --- RPR005: retrace bait -----------------------------------------------------

def _jit_rules(tree: ast.Module, scopes: _Scopes, path: str,
               out: List[Finding]):
    aliases = scopes.aliases

    # (a) jitted functions with mutable default args
    jit_applied: Set[str] = set()
    jit_decorated: Set[ast.AST] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            if _canonical(node.func, aliases) == "jax.jit":
                for a in node.args[:1]:
                    if isinstance(a, ast.Name):
                        jit_applied.add(a.id)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                call = dec.func if isinstance(dec, ast.Call) else dec
                canon = _canonical(call, aliases)
                if canon == "jax.jit" or (
                    canon == "functools.partial"
                    and isinstance(dec, ast.Call)
                    and dec.args
                    and _canonical(dec.args[0], aliases) == "jax.jit"
                ):
                    jit_decorated.add(node)

    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if node not in jit_decorated and node.name not in jit_applied:
            continue
        defaults = list(node.args.defaults) + [
            d for d in node.args.kw_defaults if d is not None
        ]
        for d in defaults:
            if isinstance(d, (ast.Dict, ast.List, ast.Set)) or (
                isinstance(d, ast.Call)
                and isinstance(d.func, ast.Name)
                and d.func.id in ("dict", "list", "set")
            ):
                out.append(Finding(
                    rule="RPR005", path=path,
                    scope=scopes.qualname.get(node, node.name),
                    message=(
                        "jitted function has a dict/list default argument — "
                        "unhashable static, retrace (or TypeError) bait"
                    ),
                    snippet=ast.unparse(d)[:80],
                    line=node.lineno,
                ))

    # (b) jax.jit called inside a Python loop
    loop_stack: List[ast.AST] = []

    def visit(node: ast.AST, in_loop: bool):
        if isinstance(node, ast.Call) and in_loop:
            if _canonical(node.func, aliases) == "jax.jit":
                out.append(Finding(
                    rule="RPR005", path=path,
                    scope=scopes.qualname.get(
                        scopes._owners.get(node), "<module>"
                    ) if scopes._owners.get(node) is not None else "<module>",
                    message=(
                        "jax.jit inside a Python loop builds a fresh wrapper"
                        " (and cache entry) per iteration — hoist it"
                    ),
                    snippet=ast.unparse(node)[:80],
                    line=node.lineno,
                ))
        for child in ast.iter_child_nodes(node):
            child_in_loop = in_loop or isinstance(
                node, (ast.For, ast.AsyncFor, ast.While)
            )
            # a nested def resets loop context (deferred execution)
            if isinstance(child, _FuncNode):
                visit(child, False)
            else:
                visit(child, child_in_loop)

    visit(tree, False)


# --- driver -------------------------------------------------------------------

def lint_source(source: str, path: str) -> List[Finding]:
    """All RPR findings for one file's source text (noqa already applied)."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding(rule="RPR000", path=path, scope="<module>",
                        message=f"syntax error: {e}", line=e.lineno or 0)]
    aliases = _module_aliases(tree)
    scopes = _Scopes(tree, aliases)
    out: List[Finding] = []

    # RPR001 over every function (and lambdas) in the file
    for fn in scopes.qualname:
        _KeyLinter(out, path, scopes.qualname[fn], aliases).run(fn)

    # RPR002: Python loops inside scan/while/fori bodies
    for fn in scopes.loop_body:
        body = fn.body if isinstance(fn.body, list) else []
        for stmt in body:
            for node in ast.walk(stmt):
                if isinstance(node, _FuncNode):
                    continue  # nested defs are themselves in loop_body
                if isinstance(node, (ast.For, ast.AsyncFor, ast.While)):
                    kind = "while" if isinstance(node, ast.While) else "for"
                    out.append(Finding(
                        rule="RPR002", path=path,
                        scope=scopes.qualname.get(fn, "<module>"),
                        message=(
                            f"Python {kind!r} inside a scan/loop body "
                            f"unrolls into (or breaks) the trace — use "
                            f"lax.scan/fori_loop or a traced mask"
                        ),
                        snippet=ast.unparse(node).splitlines()[0][:80],
                        line=node.lineno,
                    ))

    # RPR003/RPR004 inside traced functions
    for fn in scopes.traced:
        _taint_rules(fn, scopes, path, out)

    # RPR005 module-wide
    _jit_rules(tree, scopes, path, out)

    return _apply_noqa(out, source)


def _apply_noqa(findings: List[Finding], source: str) -> List[Finding]:
    lines = source.splitlines()
    kept = []
    for f in findings:
        if 1 <= f.line <= len(lines):
            m = _NOQA_RE.search(lines[f.line - 1])
            if m:
                rules = m.group("rules")
                if rules is None or f.rule in {
                    r.strip().upper() for r in rules.split(",")
                }:
                    continue
        kept.append(f)
    return sorted(kept, key=lambda f: (f.path, f.line, f.rule))


def iter_py_files(paths: Iterable[str]) -> List[str]:
    files = []
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            files.append(p)
        elif os.path.isdir(p):
            for base, dirs, names in os.walk(p):
                dirs[:] = sorted(
                    d for d in dirs
                    if not d.startswith(".") and d != "__pycache__"
                )
                files.extend(
                    os.path.join(base, n) for n in sorted(names)
                    if n.endswith(".py")
                )
    return files


def lint_paths(paths: Iterable[str], root: Optional[str] = None
               ) -> List[Finding]:
    """Lint every ``.py`` under ``paths``; finding paths are relative to
    ``root`` (default: cwd) so fingerprints are machine-independent."""
    root = root or os.getcwd()
    out: List[Finding] = []
    for fp in iter_py_files(paths):
        rel = os.path.relpath(fp, root)
        with open(fp, encoding="utf-8") as f:
            src = f.read()
        out.extend(lint_source(src, rel))
    return out
