"""CPU/TPU training launcher: federated local-SGD over the model zoo.

On this CPU container it trains reduced configs for real (the ~100M
end-to-end example drives it); on a TPU mesh the same code path scales — the
mesh/rules wiring matches dryrun.py.

  PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b \
      --reduced --steps 200 --strategy consensus --tau 8 --agents 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save
from repro.configs import get_arch
from repro.data import SyntheticLM
from repro.launch.fedtrain import (
    FedTrainConfig,
    init_train_state,
    make_local_step,
    make_sync_step,
)
from repro.optim import adamw


def train(arch: str, *, reduced: bool, steps: int, fed: FedTrainConfig,
          n_agents: int, batch: int, seq: int, ckpt_dir: str | None = None,
          log_every: int = 10, seed: int = 0):
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    opt = adamw(weight_decay=0.01)
    state = init_train_state(cfg, jax.random.key(seed), n_agents, opt, fed)
    local_step = jax.jit(make_local_step(cfg, opt, fed, rules=None,
                                         n_agents=n_agents))
    sync_step = jax.jit(make_sync_step(cfg, fed, rules=None,
                                       n_agents=n_agents))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seed=seed)

    losses = []
    t0 = time.time()
    for step in range(steps):
        toks = np.stack([
            data.batch(step, batch, seq + 1, agent=a) for a in range(n_agents)
        ])
        batch_tree = {"tokens": jnp.asarray(toks)}
        if cfg.frontend == "vision":
            batch_tree = {
                "tokens": jnp.asarray(toks[:, :, : seq - cfg.n_frontend_tokens + 1]),
                "patch_embeds": 0.1 * jnp.ones(
                    (n_agents, batch, cfg.n_frontend_tokens, cfg.d_model),
                    jnp.dtype(cfg.compute_dtype)),
            }
        elif cfg.frontend == "audio":
            batch_tree["frames"] = 0.1 * jnp.ones(
                (n_agents, batch, cfg.n_frontend_tokens, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
        state, metrics = local_step(state, batch_tree)
        if (step + 1) % fed.tau == 0:
            state = sync_step(state)
        losses.append(float(metrics["loss"]))
        if (step + 1) % log_every == 0:
            rate = (step + 1) / (time.time() - t0)
            print(f"step {step+1:5d} | loss {losses[-1]:.4f} | "
                  f"{rate:.2f} steps/s | sync every {fed.tau}")
    if ckpt_dir:
        save(ckpt_dir, steps, jax.device_get(state),
             metadata={"arch": cfg.name, "strategy": fed.strategy})
    return state, losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--strategy", default="periodic",
                    choices=["sync", "periodic", "decay", "consensus"])
    ap.add_argument("--tau", type=int, default=8)
    ap.add_argument("--agents", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--outer-momentum", type=float, default=0.0)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    fed = FedTrainConfig(strategy=args.strategy, tau=args.tau, lr=args.lr,
                         outer_momentum=args.outer_momentum)
    _, losses = train(args.arch, reduced=args.reduced, steps=args.steps,
                      fed=fed, n_agents=args.agents, batch=args.batch,
                      seq=args.seq, ckpt_dir=args.ckpt)
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
