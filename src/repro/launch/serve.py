"""Serving steps: prefill (context ingest) and serve_step (one-token decode)."""
from __future__ import annotations

from typing import Optional


from repro.models.encdec import encdec_decode_step, encdec_forward
from repro.models.transformer import decode_step, prefill
from repro.sharding.rules import MeshRules, use_rules


def make_prefill_step(cfg, rules: Optional[MeshRules] = None):
    def prefill_step(params, batch):
        with use_rules(rules):
            if cfg.is_encoder_decoder:
                logits, states = encdec_forward(
                    cfg, params, batch["tokens"], batch["frames"], mode="prefill"
                )
            else:
                logits, states = prefill(
                    cfg, params, batch["tokens"],
                    embeds=batch.get("patch_embeds"),
                )
        return logits[:, -1:], states

    return prefill_step


def make_serve_step(cfg, rules: Optional[MeshRules] = None):
    def serve_step(params, token, states, pos):
        with use_rules(rules):
            if cfg.is_encoder_decoder:
                return encdec_decode_step(cfg, params, token, states, pos)
            return decode_step(cfg, params, token, states, pos)

    return serve_step
