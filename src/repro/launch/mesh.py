"""Production meshes. v5e pod = 16x16 = 256 chips; multi-pod = 2 pods = 512.

IMPORTANT: import-time must never touch jax device state — everything here is
a function. The dry-run entrypoint sets XLA_FLAGS for 512 host devices BEFORE
importing jax (see dryrun.py lines 1-2).
"""
from __future__ import annotations

from repro.sharding.rules import DEFAULT_RULES, MeshRules
from repro.utils.compat import default_axis_types, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=default_axis_types(len(axes)))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires >= prod(shape) local devices)."""
    return make_mesh(shape, axes, axis_types=default_axis_types(len(axes)))


def make_rules(mesh, overrides: dict | None = None) -> MeshRules:
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)
    return MeshRules(mesh=mesh, rules=rules)


def n_agents(mesh) -> int:
    """Federated agents = size of the 'pod' axis (1 on a single pod)."""
    return mesh.shape["pod"] if "pod" in mesh.axis_names else 1
