import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (arch x input-shape x mesh).

For train shapes this lowers BOTH programs of the federated trainer
(local_step without cross-pod collectives, sync_step with the strategy's
pod-axis collective); for inference shapes it lowers prefill / serve steps.
memory_analysis() proves per-device footprint; cost_analysis() + HLO
collective parsing feed the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all                 # full 40-pair sweep x 2 meshes
"""
import argparse
import json
import time
import traceback

import jax

from repro.analysis.roofline import HBM_PER_CHIP, model_flops, roofline
from repro.configs import get_arch, get_shape, list_archs, SHAPE_REGISTRY
from repro.launch.fedtrain import (
    FedTrainConfig,
    init_train_state,
    make_local_step,
    make_sync_step,
    train_state_axes,
)
from repro.launch.mesh import make_production_mesh, make_rules, n_agents
from repro.launch.serve import make_prefill_step, make_serve_step
from repro.launch.specs import attach, input_specs
from repro.models import param_logical_axes
from repro.optim import adamw


def _eligible(cfg, shape) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "full-attention arch: long_500k requires sub-quadratic attention (DESIGN.md)"
    return True, ""


def _analyze(name, lowered):
    from repro.analysis.hlo_loops import analyze as loop_analyze

    t0 = time.time()
    compiled = lowered.compile()
    dt = time.time() - t0
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    la = loop_analyze(txt)   # trip-count-corrected (XLA counts whiles once)
    per_dev_bytes = (
        ma.argument_size_in_bytes + ma.output_size_in_bytes + ma.temp_size_in_bytes
        - ma.alias_size_in_bytes
    )
    print(f"    [{name}] compile {dt:.1f}s | args {ma.argument_size_in_bytes/2**30:.2f} GiB"
          f" + temp {ma.temp_size_in_bytes/2**30:.2f} GiB per device"
          f" | flops {la.flops:.3g} (hlo-once {ca.get('flops', 0):.3g})"
          f" | colls {la.collective_counts}")
    return {
        "compile_s": dt,
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "peak_bytes_est": per_dev_bytes,
        "fits_hbm": bool(per_dev_bytes <= HBM_PER_CHIP),
        "flops": la.flops,
        "bytes_accessed": la.hbm_bytes,
        "flops_hlo_loop_once": float(ca.get("flops", 0.0)),
        "bytes_hlo_loop_once": float(ca.get("bytes accessed", 0.0)),
        "n_while_loops": la.n_while,
        "collective_counts": la.collective_counts,
        "collective_result_bytes": la.collective_result_bytes,
        "wire_bytes": la.wire_bytes,
    }


def run_one(arch: str, shape_name: str, *, multi_pod: bool, fed: FedTrainConfig,
            out_dir: str = "experiments/dryrun", seq_parallel: bool = True,
            cfg_overrides: dict | None = None,
            opt_state_dtype: str = "float32", tag: str = "",
            rule_overrides: dict | None = None) -> dict:
    import dataclasses as _dc

    cfg = get_arch(arch)
    if cfg_overrides:
        typed = {}
        for k, v in cfg_overrides.items():
            field_t = type(getattr(cfg, k))
            typed[k] = field_t(v) if field_t in (int, float, bool, str) else v
        cfg = _dc.replace(cfg, **typed)
    shape = get_shape(shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "strategy": fed.strategy, "tau": fed.tau, "ok": False,
        "seq_parallel": seq_parallel, "tag": tag,
        "cfg_overrides": cfg_overrides or {},
        "opt_state_dtype": opt_state_dtype,
    }
    ok, why = _eligible(cfg, shape)
    if not ok:
        record["skipped"] = why
        print(f"  SKIP {arch} x {shape_name}: {why}")
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    overrides = {"seq": ("model",)} if seq_parallel else {}
    if rule_overrides:
        overrides.update(rule_overrides)
    rules = make_rules(mesh, overrides or None)
    agents = n_agents(mesh)
    n_chips = mesh.size
    print(f"  {arch} x {shape_name} on {mesh_name} ({n_chips} chips, {agents} agents)")

    try:
        if shape.kind == "train":
            batch_specs = input_specs(cfg, shape, rules, n_agents=agents)
            axes = train_state_axes(cfg, fed)
            state_specs = jax.eval_shape(
                lambda: init_train_state(cfg, jax.random.key(0), agents,
                                         adamw(state_dtype=opt_state_dtype), fed)
            )
            state_specs = attach(state_specs, axes, rules)

            local_step = make_local_step(cfg, adamw(state_dtype=opt_state_dtype),
                                         fed, rules, agents)
            sync_step = make_sync_step(cfg, fed, rules, agents)
            with mesh:
                lowered_local = jax.jit(local_step).lower(state_specs, batch_specs)
                record["local"] = _analyze("local_step", lowered_local)
                lowered_sync = jax.jit(sync_step).lower(state_specs)
                record["sync"] = _analyze("sync_step", lowered_sync)
            flops = record["local"]["flops"]
            hbm = record["local"]["bytes_accessed"]
            wire = (
                (fed.tau - 1) * record["local"]["wire_bytes"]
                + record["sync"]["wire_bytes"]
            ) / fed.tau
            record["roofline"] = roofline(flops, hbm, wire).as_dict()
        else:
            if shape.kind == "prefill":
                batch_specs = input_specs(cfg, shape, rules)
                step = make_prefill_step(cfg, rules)
                params_specs = attach(
                    jax.eval_shape(lambda: _init_params_spec(cfg)),
                    param_logical_axes(cfg), rules,
                )
                with mesh:
                    lowered = jax.jit(step).lower(params_specs, batch_specs)
                    record["prefill"] = _analyze("prefill", lowered)
                r = record["prefill"]
            else:
                token, states, pos = input_specs(cfg, shape, rules)
                step = make_serve_step(cfg, rules)
                params_specs = attach(
                    jax.eval_shape(lambda: _init_params_spec(cfg)),
                    param_logical_axes(cfg), rules,
                )
                with mesh:
                    # donate the cache/state buffers: decode updates them in
                    # place (otherwise every step materializes a second cache)
                    lowered = jax.jit(step, donate_argnums=(2,)).lower(
                        params_specs, token, states, pos)
                    record["serve"] = _analyze("serve_step", lowered)
                r = record["serve"]
            record["roofline"] = roofline(
                r["flops"], r["bytes_accessed"], r["wire_bytes"]
            ).as_dict()

        record["model_flops_per_device"] = model_flops(cfg, shape, n_chips)
        if record["roofline"]["flops"]:
            record["useful_flops_ratio"] = (
                record["model_flops_per_device"] / record["roofline"]["flops"]
            )
        record["ok"] = True
    except Exception as e:  # noqa: BLE001 - report, keep sweeping
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
        print(f"    FAILED: {record['error']}")

    os.makedirs(out_dir, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    path = os.path.join(out_dir, f"{arch}_{shape_name}_{mesh_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=str)
    return record


def _init_params_spec(cfg):
    from repro.models import init_params
    return init_params(cfg, jax.random.key(0))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--strategy", default="periodic",
                    choices=["sync", "periodic", "decay", "consensus"])
    ap.add_argument("--tau", type=int, default=8)
    ap.add_argument("--no-seq-parallel", action="store_true",
                    help="baseline ruleset (no sequence parallelism) for §Perf")
    ap.add_argument("--set", action="append", default=[],
                    help="ModelConfig override key=value (repeatable)")
    ap.add_argument("--opt-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--rule", action="append", default=[],
                    help="sharding rule override logical=axis1[,axis2]|none")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    fed = FedTrainConfig(strategy=args.strategy, tau=args.tau)
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPE_REGISTRY) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if (args.all or args.both_meshes) else [args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                results.append(
                    run_one(arch, shape, multi_pod=mp, fed=fed, out_dir=args.out,
                            seq_parallel=not args.no_seq_parallel,
                            cfg_overrides=dict(kv.split("=", 1) for kv in args.set),
                            opt_state_dtype=args.opt_dtype, tag=args.tag,
                            rule_overrides={
                                k: (None if v == "none" else tuple(v.split(",")))
                                for k, v in (kv.split("=", 1) for kv in args.rule)
                            })
                )
    n_ok = sum(r["ok"] for r in results)
    n_skip = sum("skipped" in r for r in results)
    n_fail = len(results) - n_ok - n_skip
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skipped (documented), {n_fail} FAILED")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
