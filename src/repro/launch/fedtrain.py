"""Mesh-scale federated training: the paper's aggregation schemes as cross-pod
gradient-sync strategies (local-SGD / DiLoCo-style).

Agents = the 'pod' mesh axis. Every pytree in the train state carries a
leading agent axis A sharded over 'pod'; within an agent, params are
FSDP+TP sharded over ('data','model'). Two programs are lowered per config:

  * local_step — per-agent forward/backward + optimizer update. NO collectives
    over the pod axis (the communication the paper eliminates for tau-1 of
    every tau steps). Decay strategy scales the update by D(step mod tau).
  * sync_step  — the strategy's cross-pod collective, run every tau steps:
      - periodic / sync: psum-mean over 'pod' (eq. 11)
      - consensus: mixing matrix P^E over the agent axis (eq. 23, fused form)
      - optional beyond-paper outer Nesterov momentum on the sync delta
        (DiLoCo-style), applied to the averaged update.

The roofline amortizes (tau-1) * local + 1 * sync per period, making the
paper's communication saving directly measurable from the compiled HLO.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import init_params, lm_loss, param_logical_axes
from repro.optim import Optimizer, adamw, clip_by_global_norm
from repro.sharding.rules import MeshRules, use_rules


@dataclasses.dataclass(frozen=True)
class FedTrainConfig:
    strategy: str = "periodic"       # sync | periodic | decay | consensus
    tau: int = 8
    decay_lambda: float = 0.98       # for 'decay' (paper eq. 21)
    consensus_eps: float = 0.4       # for 'consensus' on the pod ring
    consensus_rounds: int = 1
    outer_momentum: float = 0.0      # beyond-paper: DiLoCo outer Nesterov
    grad_clip: float = 1.0
    lr: float = 3e-4


def _ring_mixing(n: int, eps: float, rounds: int) -> np.ndarray:
    """Fused mixing matrix P^E for the n-pod ring (chain for n=2)."""
    if n == 1:
        return np.ones((1, 1), np.float32)
    adj = np.zeros((n, n))
    for i in range(n):
        adj[i, (i + 1) % n] = adj[(i + 1) % n, i] = 1
    adj = np.minimum(adj, 1)
    la = np.diag(adj.sum(1)) - adj
    p = np.eye(n) - eps * la
    return np.linalg.matrix_power(p, rounds).astype(np.float32)


def init_train_state(cfg, key, n_agents: int, optimizer: Optimizer,
                     fed: FedTrainConfig):
    """State pytree with leading agent axis on params/opt."""
    params = init_params(cfg, key)
    opt = optimizer.init(params)

    def rep(tree):
        return jax.tree.map(
            lambda l: jnp.broadcast_to(l, (n_agents,) + l.shape).copy(), tree
        )

    state = {"params": rep(params), "opt": rep(opt),
             "step": jnp.zeros((), jnp.int32)}
    if fed.outer_momentum > 0:
        state["anchor"] = rep(params)  # server anchor for outer momentum
        state["outer_m"] = jax.tree.map(jnp.zeros_like, state["anchor"])
    return state


def train_state_axes(cfg, fed: FedTrainConfig, optimizer_name: str = "adamw"):
    """Logical axes tree matching init_train_state's structure."""
    p_axes = param_logical_axes(cfg)
    ag = lambda tree: jax.tree.map(
        lambda a: ("agents",) + tuple(a), tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )
    if optimizer_name == "adamw":
        opt_axes = {"m": p_axes, "v": p_axes, "t": ()}
    elif optimizer_name == "momentum":
        opt_axes = {"m": p_axes}
    else:
        opt_axes = ()
    axes = {"params": ag(p_axes), "opt": ag(opt_axes) if opt_axes != () else (),
            "step": ()}
    if fed.outer_momentum > 0:
        axes["anchor"] = ag(p_axes)
        axes["outer_m"] = ag(p_axes)
    return axes


def _decay_weights(fed: FedTrainConfig) -> jnp.ndarray:
    j = jnp.arange(fed.tau, dtype=jnp.float32)
    return jnp.power(fed.decay_lambda, j / 2.0)


def make_local_step(cfg, optimizer: Optimizer, fed: FedTrainConfig,
                    rules: Optional[MeshRules] = None, n_agents: int = 1):
    """Returns local_step(state, batch) -> (state, metrics). batch leaves have
    leading agent axis A; sharded over 'pod' when present."""
    spmd = "pod" if (rules and "pod" in rules.mesh.axis_names) else None
    decay_w = _decay_weights(fed)

    def agent_update(params, opt, batch, lr_scale):
        loss, grads = jax.value_and_grad(lambda p: lm_loss(cfg, p, batch))(params)
        grads, gnorm = clip_by_global_norm(grads, fed.grad_clip)
        params, opt = optimizer.apply(grads, opt, params, fed.lr * lr_scale)
        return params, opt, loss, gnorm

    def local_step(state, batch):
        offset = jnp.mod(state["step"], fed.tau)
        lr_scale = decay_w[offset] if fed.strategy == "decay" else jnp.float32(1)

        def run(params, opt, batch_a):
            return agent_update(params, opt, batch_a, lr_scale)

        with use_rules(rules):
            vm = jax.vmap(run, spmd_axis_name=spmd) if spmd else jax.vmap(run)
            params, opt, loss, gnorm = vm(state["params"], state["opt"], batch)
        new_state = dict(state, params=params, opt=opt, step=state["step"] + 1)
        return new_state, {"loss": loss.mean(), "grad_norm": gnorm.mean()}

    return local_step


def make_sync_step(cfg, fed: FedTrainConfig, rules: Optional[MeshRules] = None,
                   n_agents: int = 1):
    """Returns sync_step(state) -> state: the cross-pod strategy collective."""
    if fed.strategy == "consensus":
        mix = jnp.asarray(_ring_mixing(n_agents, fed.consensus_eps,
                                       fed.consensus_rounds))
    else:
        mix = None

    def communicate(params):
        if mix is not None:
            return jax.tree.map(
                lambda p: jnp.tensordot(mix, p, axes=1).astype(p.dtype), params
            )
        # periodic averaging (eq. 11): psum-mean over the agent axis
        return jax.tree.map(
            lambda p: jnp.broadcast_to(
                jnp.mean(p, axis=0, keepdims=True), p.shape
            ).astype(p.dtype),
            params,
        )

    def sync_step(state):
        with use_rules(rules):
            if fed.outer_momentum > 0:
                # DiLoCo-style outer Nesterov on the averaged delta (beyond-paper)
                avg = communicate(state["params"])
                delta = jax.tree.map(
                    lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                    state["anchor"], avg,
                )
                m = jax.tree.map(
                    lambda mi, d: fed.outer_momentum * mi + d,
                    state["outer_m"], delta,
                )
                new_anchor = jax.tree.map(
                    lambda a, mi, d: (
                        a.astype(jnp.float32) - (fed.outer_momentum * mi + d)
                    ),
                    state["anchor"], m, delta,
                )
                params = jax.tree.map(
                    lambda na, p: na.astype(p.dtype), new_anchor, state["params"]
                )
                return dict(state, params=params, outer_m=m,
                            anchor=jax.tree.map(
                                lambda na, a: na.astype(a.dtype), new_anchor,
                                state["anchor"]))
            params = communicate(state["params"])
        return dict(state, params=params)

    return sync_step
