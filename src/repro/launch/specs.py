"""ShapeDtypeStruct input specs for every (arch x input-shape x mode).

The specs carry NamedShardings (when rules are given) so jit.lower() picks up
in_shardings directly from the arguments — no allocation ever happens
(the shannon/kernels dry-run pattern).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models.transformer import (
    decode_state_logical_axes,
    init_decode_state,
)
from repro.models.encdec import (
    encdec_state_logical_axes,
    init_encdec_decode_state,
)
from repro.sharding.rules import MeshRules


def attach(specs, axes, rules: Optional[MeshRules]):
    """Attach NamedShardings from logical-axes trees to a spec pytree."""
    if rules is None:
        return specs
    return jax.tree.map(
        lambda s, a: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=rules.named_sharding(tuple(a), s.shape)
        ),
        specs,
        axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, shape: InputShape,
                      rules: Optional[MeshRules], n_agents: int = 1):
    """Batch pytree with leading agent axis (lm_loss consumes tokens[:, :-1])."""
    if shape.global_batch % n_agents:
        raise ValueError("global batch must divide agents")
    b = shape.global_batch // n_agents
    s = shape.seq_len
    emb = jnp.dtype(cfg.compute_dtype)
    specs, axes = {}, {}
    if cfg.frontend == "vision":
        f = cfg.n_frontend_tokens
        specs["tokens"] = _sds((n_agents, b, s - f + 1), jnp.int32)
        specs["patch_embeds"] = _sds((n_agents, b, f, cfg.d_model), emb)
        axes["tokens"] = ("agents", "batch", None)
        axes["patch_embeds"] = ("agents", "batch", None, "embed")
    elif cfg.frontend == "audio":
        specs["tokens"] = _sds((n_agents, b, s + 1), jnp.int32)
        specs["frames"] = _sds((n_agents, b, cfg.n_frontend_tokens, cfg.d_model), emb)
        axes["tokens"] = ("agents", "batch", None)
        axes["frames"] = ("agents", "batch", None, "embed")
    else:
        specs["tokens"] = _sds((n_agents, b, s + 1), jnp.int32)
        axes["tokens"] = ("agents", "batch", None)
    return attach(specs, axes, rules)


def prefill_specs(cfg: ModelConfig, shape: InputShape, rules: Optional[MeshRules]):
    b, s = shape.global_batch, shape.seq_len
    emb = jnp.dtype(cfg.compute_dtype)
    specs, axes = {}, {}
    if cfg.frontend == "vision":
        f = cfg.n_frontend_tokens
        specs["tokens"] = _sds((b, s - f), jnp.int32)
        specs["patch_embeds"] = _sds((b, f, cfg.d_model), emb)
        axes["tokens"] = ("batch", None)
        axes["patch_embeds"] = ("batch", None, "embed")
    elif cfg.frontend == "audio":
        specs["tokens"] = _sds((b, s), jnp.int32)
        specs["frames"] = _sds((b, cfg.n_frontend_tokens, cfg.d_model), emb)
        axes["tokens"] = ("batch", None)
        axes["frames"] = ("batch", None, "embed")
    else:
        specs["tokens"] = _sds((b, s), jnp.int32)
        axes["tokens"] = ("batch", None)
    return attach(specs, axes, rules)


def decode_specs(cfg: ModelConfig, shape: InputShape, rules: Optional[MeshRules]):
    """(token, states, pos) specs for serve_step; cache length = shape.seq_len."""
    b, s = shape.global_batch, shape.seq_len
    dtype = jnp.dtype(cfg.compute_dtype)
    if cfg.is_encoder_decoder:
        states = jax.eval_shape(
            lambda: init_encdec_decode_state(
                cfg, b, max_seq=s, n_frames=cfg.n_frontend_tokens, dtype=dtype
            )
        )
        st_axes = encdec_state_logical_axes(cfg)
    else:
        states = jax.eval_shape(
            lambda: init_decode_state(cfg, b, max_seq=s, dtype=dtype)
        )
        st_axes = decode_state_logical_axes(cfg)
    token = _sds((b, 1), jnp.int32)
    pos = _sds((b,), jnp.int32)
    if rules is not None:
        token = attach(token, ("batch", None), rules)
        pos = attach(pos, ("batch",), rules)
        states = attach(states, st_axes, rules)
    return token, states, pos


def input_specs(cfg: ModelConfig, shape: InputShape,
                rules: Optional[MeshRules] = None, n_agents: int = 1):
    """Dispatch on the shape kind; returns the spec pytree(s) for the step fn."""
    if shape.kind == "train":
        return train_batch_specs(cfg, shape, rules, n_agents)
    if shape.kind == "prefill":
        return prefill_specs(cfg, shape, rules)
    return decode_specs(cfg, shape, rules)
