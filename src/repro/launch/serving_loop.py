"""Batched serving driver: slot-based continuous batching (lite).

A fixed pool of B slots over a shared ring KV cache. Requests carry a prompt
and a token budget; free slots are refilled from the queue each cycle:
prompts are prefilled one slot at a time into the shared cache (per-slot
prefill keeps a single compiled shape), then all active slots decode in
lockstep with one serve_step per token. Finished slots are recycled without
disturbing neighbors — the scheduling pattern real serving systems use,
driving the same decode path the dry-run lowers at scale.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_decode_state
from repro.models.transformer import decode_state_logical_axes


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]


@dataclasses.dataclass
class _Slot:
    rid: Optional[int] = None
    pos: int = 0                  # absolute position of next write
    remaining: int = 0
    out: Optional[List[int]] = None


class ServingLoop:
    """Greedy decoding over a slot pool. Deterministic, jit-compiled steps."""

    def __init__(self, cfg, params, n_slots: int = 4, max_seq: int = 256):
        self.cfg, self.params = cfg, params
        self.n_slots, self.max_seq = n_slots, max_seq
        self.state = init_decode_state(cfg, n_slots, max_seq=max_seq,
                                       dtype=jnp.dtype(cfg.compute_dtype))
        # pristine per-slot state template: recycled slots must be reset
        # (recurrent SSM/LRU states would otherwise leak across requests;
        # attention caches need their pos rows back at -1)
        self._template = jax.tree.map(lambda x: x, self.state)
        self.slots = [_Slot() for _ in range(n_slots)]
        self._tok = jnp.zeros((n_slots, 1), jnp.int32)

        self._decode = jax.jit(
            lambda p, t, st, pos: decode_step(cfg, p, t, st, pos))
        # per-token prefill reuses the decode step so arbitrary prompt
        # lengths share one compiled shape
        self._prefill_tok = self._decode

    def _free(self):
        return [i for i, s in enumerate(self.slots) if s.rid is None]

    def _reset_slot_state(self, i: int):
        """Reset slot i on every state leaf along its 'batch' logical axis
        (leaves may carry a leading stacked-layers axis)."""
        axes_tree = decode_state_logical_axes(self.cfg)
        flat_cur, treedef = jax.tree.flatten(self.state)
        flat_init = jax.tree.leaves(self._template)
        flat_axes = jax.tree.flatten(
            axes_tree, is_leaf=lambda x: isinstance(x, tuple))[0]
        out = []
        for cur, init, axes in zip(flat_cur, flat_init, flat_axes):
            if "batch" in axes:
                b_dim = axes.index("batch")
                idx = tuple([slice(None)] * b_dim + [i])
                cur = cur.at[idx].set(init[idx])
            out.append(cur)
        self.state = jax.tree.unflatten(treedef, out)

    def _admit(self, req: Request, slot_idx: int):
        self._reset_slot_state(slot_idx)
        s = self.slots[slot_idx]
        s.rid, s.pos, s.remaining, s.out = req.rid, 0, req.max_new_tokens, []
        # feed all but the last prompt token through the decode path (fills
        # the slot's region of the shared cache); the last prompt token stays
        # in the token buffer so the next lockstep decode step consumes it —
        # its first generated token comes out of the same batched argmax as
        # everyone else's, with no per-request scalar sync at admit time
        for t in req.prompt[:-1]:
            tok = self._tok.at[slot_idx, 0].set(int(t))
            pos = jnp.asarray([sl.pos for sl in self.slots], jnp.int32)
            _, self.state = self._prefill_tok(self.params, tok,
                                              self.state, pos)
            s.pos += 1
        self._tok = self._tok.at[slot_idx, 0].set(int(req.prompt[-1]))

    def run(self, requests: Iterable[Request]) -> List[Completion]:
        queue = list(requests)
        done: List[Completion] = []
        while queue or any(s.rid is not None for s in self.slots):
            for i in self._free():
                if not queue:
                    break
                self._admit(queue.pop(0), i)
            active = [i for i, s in enumerate(self.slots) if s.rid is not None]
            if not active:
                continue
            pos = jnp.asarray([s.pos for s in self.slots], jnp.int32)
            logits, self.state = self._decode(self.params, self._tok,
                                              self.state, pos)
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            for i in active:
                s = self.slots[i]
                s.pos += 1
                if s.remaining > 0:
                    s.out.append(int(nxt[i]))
                    s.remaining -= 1
                    self._tok = self._tok.at[i, 0].set(int(nxt[i]))
                if s.remaining == 0 or s.pos >= self.max_seq - 1:
                    done.append(Completion(s.rid, s.out))
                    self.slots[i] = _Slot()
        return done
