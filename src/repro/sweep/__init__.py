"""repro.sweep — batched multi-seed / multi-hyperparameter experiment engine.

Runs many full federated training runs in ONE jitted computation: the seed
axis and value-only hyperparameters (eta, decay lambda, consensus eps,
per-agent tau_i schedules at fixed tau, fleet hetero_scale) vmap into a
single leading sweep axis — the drivers' flat ``(m, n)`` carry becomes
``(S, m, n)`` and the variation mask a batched ``(S, m, tau)`` operand —
while shape-changing statics (tau itself, topology, scenario) loop outside.
See DESIGN.md §10–§11 and ``repro.sweep.spec`` for the axis taxonomy.

    from repro.sweep import SweepAxis, SweepSpec, run_sweep

    spec = SweepSpec(
        name="fig5",
        base=FedRLConfig(env=FIGURE_EIGHT, strategy=decay_strategy, ...),
        seeds=(0, 1, 2, 3),
        vmapped=(SweepAxis("lam", (0.98, 0.95, 0.92)),),
    )
    result = run_sweep(spec)                # one vmapped computation
    mean, hw = result.seed_mean_ci("base", "server_grad_sq_norm")
    result.save("experiments/sweeps")       # versioned JSON + CSV
"""
from repro.sweep.overrides import (
    OVERRIDES,
    apply_overrides,
    compression_axis,
    override_eps,
    override_eta,
    override_hetero_scale,
    override_lam,
    override_taus,
    register_override,
)
from repro.sweep.results import SweepResult, mean_ci, t_critical
from repro.sweep.runner import run_sweep, run_sweep_loop, static_points
from repro.sweep.spec import StaticAxis, SweepAxis, SweepSpec

__all__ = [
    "OVERRIDES",
    "StaticAxis",
    "SweepAxis",
    "SweepSpec",
    "SweepResult",
    "apply_overrides",
    "compression_axis",
    "mean_ci",
    "override_eps",
    "override_eta",
    "override_hetero_scale",
    "override_lam",
    "override_taus",
    "register_override",
    "run_sweep",
    "run_sweep_loop",
    "static_points",
    "t_critical",
]
