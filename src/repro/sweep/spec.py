"""Sweep specifications: which experiment dimensions vmap, which stay static.

The paper's headline results are *grids* — decay lambda x tau (Fig. 5),
consensus eps x topology (Fig. 6), every cell averaged over seeds. A sweep
splits those grid dimensions into two kinds of axis:

* **vmapped axes** — seeds and any hyperparameter that only changes *values*
  flowing through the traced computation: the PRNG seed, the learning rate
  eta, the decay constant lambda (a ``(tau,)`` weight table), the consensus
  step size eps (an ``(m, m)`` mixing matrix), the per-agent tau_i schedule
  at fixed period length (an ``(m, tau)`` variation mask), the fleet
  heterogeneity scale (per-agent ``EnvParams`` magnitudes). All vmapped axes
  and the seed axis form one cartesian product that is flattened into a
  single leading sweep axis S, so one jitted vmap covers every cell — the
  flat ``(m, n)`` carry of the drivers becomes ``(S, m, n)`` and the
  dispatch primitives batch over it without per-run retraces. Axis points
  may be scalars or equal-length vectors (a tau_i schedule is a whole (m,)
  point); vector points reach their override as traced (m,) arrays.

* **static axes** — anything that changes *shapes or trace structure*: the
  period length tau (the variation mask is ``(m, tau)`` and the inner scan
  length is tau), the gossip topology (adjacency fixes the ``(m, m)``
  sparsity and the agent count), the scenario / environment structure, the
  backend. These run in an outer Python loop; each static point re-traces.

A :class:`SweepSpec` names the experiment, carries the base config, the seed
list, the vmapped hyperparameter axes, and the static axes (label +
config-transform pairs). ``repro.sweep.runner`` executes it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SweepAxis:
    """One vmapped hyperparameter axis.

    ``name`` must be a registered override (see ``repro.sweep.overrides``):
    the override maps ``(cfg, traced_value) -> cfg`` inside the traced
    computation, so every value of the axis shares one trace.

    Points are scalars (eta, lam, eps, hetero_scale) or equal-length vectors
    (a per-agent tau_i schedule, a per-agent lam vector); a vector point
    reaches the override as a traced 1-D array. Scalar and vector points
    cannot mix on one axis — the traced value must be shape-stable.
    """

    name: str
    values: Tuple

    def __post_init__(self):
        if not self.values:
            raise ValueError(f"vmapped axis {self.name!r} needs >= 1 value")
        norm, point_len = [], None
        for v in self.values:
            arr = np.asarray(v, dtype=np.float64)
            if arr.ndim == 0:
                cur, val = None, float(v)
            elif arr.ndim == 1 and arr.size:
                cur, val = arr.size, tuple(float(x) for x in arr)
            else:
                raise ValueError(
                    f"vmapped axis {self.name!r}: points must be scalars or "
                    f"non-empty 1-D vectors, got shape {arr.shape}"
                )
            if norm and cur != point_len:
                raise ValueError(
                    f"vmapped axis {self.name!r}: all points must share one "
                    f"shape (scalar or fixed-length vector); got a mix"
                )
            point_len = cur
            norm.append(val)
        object.__setattr__(self, "values", tuple(norm))

    @property
    def point_len(self) -> Optional[int]:
        """Vector-point length, or None for a scalar-valued axis."""
        first = np.asarray(self.values[0])
        return None if first.ndim == 0 else int(first.size)


@dataclasses.dataclass(frozen=True)
class StaticAxis:
    """One static (shape-changing) axis: labelled config transforms.

    Each point is ``(label, transform)`` where ``transform(cfg) -> cfg`` is
    applied *outside* the trace (it may swap strategies, taus, topologies,
    scenarios — anything). Multiple static axes combine by cartesian product,
    composing their transforms.
    """

    name: str
    points: Tuple[Tuple[str, Callable], ...]

    def __post_init__(self):
        if not self.points:
            raise ValueError(f"static axis {self.name!r} needs >= 1 point")


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A batched multi-seed experiment over one base config.

    Attributes:
      name: experiment name (used for the emitted JSON/CSV artifacts).
      base: the template config (``FedRLConfig`` by default; any object when
        ``run_fn`` is supplied).
      seeds: PRNG seeds — always a vmapped axis (the innermost one).
      vmapped: hyperparameter axes batched into the single jitted vmap.
      static: shape-changing axes looped in Python (cartesian product).
      run_fn: ``(cfg, key) -> metrics`` pytree of arrays; defaults to the
        metrics of ``repro.rl.fedrl.run_fedrl_core``. Must be traced-safe
        (no host transfers) — the runner vmaps and jits it.
    """

    name: str
    base: Any
    seeds: Tuple[int, ...]
    vmapped: Tuple[SweepAxis, ...] = ()
    static: Tuple[StaticAxis, ...] = ()
    run_fn: Optional[Callable] = None

    def __post_init__(self):
        if not self.seeds:
            raise ValueError("SweepSpec needs >= 1 seed")
        object.__setattr__(self, "seeds", tuple(int(s) for s in self.seeds))
        names = [a.name for a in self.vmapped]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate vmapped axis names: {names}")

    @property
    def grid_shape(self) -> Tuple[int, ...]:
        """Shape of the vmapped grid: (*axis lengths, n_seeds)."""
        return tuple(len(a.values) for a in self.vmapped) + (len(self.seeds),)

    @property
    def n_runs(self) -> int:
        """Full federated runs per static point (product of the grid)."""
        n = 1
        for s in self.grid_shape:
            n *= s
        return n
