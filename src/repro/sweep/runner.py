"""Batched sweep execution: one jitted vmap over the whole (hypers x seeds)
grid per static point, plus the equivalent Python-loop reference.

``run_sweep`` flattens the cartesian product of every vmapped axis and the
seed list into a single leading sweep axis S and vmaps the driver core over
it — the drivers' flat ``(m, n)`` scan carry becomes ``(S, m, n)`` and the
dispatch primitives batch over the extra axis inside one trace. Static axes
(tau, topology, scenario — anything shape-changing) run as an outer Python
loop, one trace each.

``run_sweep_loop`` executes the identical grid as S independent single-run
calls through one jitted single-run function (compiled once, reused).  It is
the determinism reference — on the jnp backend its metrics are bit-identical
to the vmapped sweep — and the wall-clock baseline the vmapped engine is
measured against in ``benchmarks/fig5_decay.py``.
"""
from __future__ import annotations

import itertools
import time
from typing import Callable, Iterator, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sweep.overrides import apply_overrides
from repro.sweep.results import SweepResult
from repro.sweep.spec import SweepAxis, SweepSpec


def _default_run_fn(cfg, key):
    """Metrics of one federated RL run (the figure-grid workload)."""
    from repro.rl.fedrl import run_fedrl_core

    return run_fedrl_core(cfg, key)[1]


def _flatten_metrics(tree) -> dict:
    """Flatten a metrics pytree to a flat dict with '/'-joined key paths."""
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(f"{prefix}/{k}" if prefix else str(k), node[k])
        else:
            flat[prefix] = node

    walk("", tree)
    return flat


def static_points(spec: SweepSpec) -> Iterator[Tuple[str, Callable]]:
    """Cartesian product of the static axes -> (label, composed transform).

    Labels key the result dicts, so a collision would silently overwrite a
    grid point's runs — raise instead.
    """
    if not spec.static:
        yield "base", lambda cfg: cfg
        return
    seen = set()
    for combo in itertools.product(*(ax.points for ax in spec.static)):
        label = "/".join(lab for lab, _ in combo if lab) or "base"
        if label in seen:
            raise ValueError(f"duplicate static-point label {label!r}")
        seen.add(label)

        def transform(cfg, fns=tuple(fn for _, fn in combo)):
            for fn in fns:
                cfg = fn(cfg)
            return cfg

        yield label, transform


def _grid_arrays(spec: SweepSpec) -> Tuple[List[np.ndarray], np.ndarray]:
    """Flatten the (axes x seeds) product into per-axis value arrays.

    Returns ``(axis_value_arrays, seed_vector)``, each with leading length
    ``spec.n_runs`` — row i holds grid cell i's coordinates (C-order over
    ``spec.grid_shape``, seeds innermost). A scalar-valued axis flattens to
    an ``(S,)`` vector; a vector-valued axis (e.g. tau_i schedules) to an
    ``(S, point_len)`` matrix, so vmap batches whole points per cell.
    """
    axes_vals = [np.asarray(a.values, np.float32) for a in spec.vmapped]
    seeds = np.asarray(spec.seeds, np.int32)
    mesh = np.meshgrid(
        *(np.arange(len(v)) for v in axes_vals), np.arange(len(seeds)),
        indexing="ij",
    )
    idx = [ix.reshape(-1) for ix in mesh]
    return (
        [v[ix] for v, ix in zip(axes_vals, idx[:-1])],
        seeds[idx[-1]].astype(np.int32),
    )


def _make_one(spec: SweepSpec, cfg) -> Callable:
    """The single-run function ``(seed, *axis_values) -> flat metrics dict``."""
    run_fn = spec.run_fn or _default_run_fn
    names = [a.name for a in spec.vmapped]

    def one(seed, *values):
        cfg_i = apply_overrides(cfg, names, values)
        return _flatten_metrics(run_fn(cfg_i, jax.random.key(seed)))

    return one


def _reshape(spec: SweepSpec, stacked: dict) -> dict:
    shape = spec.grid_shape
    return {
        k: np.asarray(v).reshape(shape + np.shape(v)[1:])
        for k, v in stacked.items()
    }


def run_sweep(spec: SweepSpec, *, use_jit: bool = True) -> SweepResult:
    """Execute the sweep: one jitted vmapped computation per static point.

    Every static point traces once; all ``spec.n_runs`` full federated runs
    of its grid execute inside that single computation. ``compile_s`` records
    the one-off trace+compile (AOT-lowered so it is separable), ``wall_s``
    the batched execution.
    """
    axis_vals, seeds = _grid_arrays(spec)
    metrics, wall_s, compile_s = {}, {}, {}
    for label, transform in static_points(spec):
        cfg = transform(spec.base)
        batched = jax.vmap(_make_one(spec, cfg))
        args = (jnp.asarray(seeds),) + tuple(jnp.asarray(v) for v in axis_vals)
        if use_jit:
            t0 = time.perf_counter()
            # One AOT compile per static point is the engine's contract
            # (shape-changing axes MUST retrace); the retrace guard pins
            # the count at exactly one per point.
            compiled = jax.jit(batched).lower(*args).compile()  # noqa: RPR005
            compile_s[label] = time.perf_counter() - t0
            batched = compiled
        t0 = time.perf_counter()
        out = jax.block_until_ready(batched(*args))
        wall_s[label] = time.perf_counter() - t0
        metrics[label] = _reshape(spec, jax.device_get(out))
    return SweepResult(
        name=spec.name,
        axes={a.name: list(a.values) for a in spec.vmapped},
        seeds=list(spec.seeds),
        metrics=metrics,
        wall_s=wall_s,
        compile_s=compile_s,
        mode="vmapped",
    )


def audit_batched_fn(spec: SweepSpec):
    """The first static point's vmapped fn + abstract args, for the audit.

    Exactly what :func:`run_sweep` jits per static point — ``vmap`` of the
    single-run fn over the flattened ``(axes x seeds)`` grid — handed out
    with ``ShapeDtypeStruct`` args so the analyzer can lower it without
    running a sweep.
    """
    axis_vals, seeds = _grid_arrays(spec)
    _, transform = next(static_points(spec))
    batched = jax.vmap(_make_one(spec, transform(spec.base)))
    args = (jax.ShapeDtypeStruct(seeds.shape, jnp.int32),) + tuple(
        jax.ShapeDtypeStruct(v.shape, jnp.float32) for v in axis_vals
    )
    return batched, args


def _audit_hot_path():
    """Per-static-point sweep fn over a tiny eta x seeds grid (jaxpr audit)."""
    from repro.core import make_strategy
    from repro.kernels.dispatch import HotPathEntry
    from repro.rl.env import FIGURE_EIGHT
    from repro.rl.fedrl import FedRLConfig

    base = FedRLConfig(
        env=FIGURE_EIGHT,
        strategy=make_strategy("decay", tau=2, m=7, backend="jnp"),
        n_epochs=1,
        epoch_len=4,
        minibatch=2,
    )
    spec = SweepSpec(
        name="audit",
        base=base,
        seeds=(0, 1),
        vmapped=(SweepAxis(name="eta", values=(1e-3, 3e-3)),),
    )
    batched, args = audit_batched_fn(spec)
    return HotPathEntry(fn=batched, args=args)


def run_sweep_loop(spec: SweepSpec, *, use_jit: bool = True) -> SweepResult:
    """The same grid as S independent runs through one reused jitted call.

    Semantically identical to :func:`run_sweep` (bit-identical metrics on the
    jnp backend); this is the Python seed-loop the vmapped engine replaces,
    kept as the determinism reference and wall-clock baseline.
    """
    axis_vals, seeds = _grid_arrays(spec)
    metrics, wall_s, compile_s = {}, {}, {}
    for label, transform in static_points(spec):
        cfg = transform(spec.base)
        one = _make_one(spec, cfg)
        args0 = (jnp.asarray(seeds[0]),) + tuple(
            jnp.asarray(v[0]) for v in axis_vals
        )
        if use_jit:
            t0 = time.perf_counter()
            # Same per-static-point AOT contract as run_sweep above.
            one = jax.jit(one).lower(*args0).compile()  # noqa: RPR005
            compile_s[label] = time.perf_counter() - t0
        t0 = time.perf_counter()
        per_run = []
        for i in range(len(seeds)):
            args = (jnp.asarray(seeds[i]),) + tuple(
                jnp.asarray(v[i]) for v in axis_vals
            )
            per_run.append(jax.block_until_ready(one(*args)))
        wall_s[label] = time.perf_counter() - t0
        stacked = {
            k: np.stack([np.asarray(r[k]) for r in per_run])
            for k in per_run[0]
        }
        metrics[label] = _reshape(spec, stacked)
    return SweepResult(
        name=spec.name,
        axes={a.name: list(a.values) for a in spec.vmapped},
        seeds=list(spec.seeds),
        metrics=metrics,
        wall_s=wall_s,
        compile_s=compile_s,
        mode="loop",
    )


from repro.kernels.dispatch import register_hot_path  # noqa: E402

register_hot_path("sweep.static_point_fn", _audit_hot_path)
