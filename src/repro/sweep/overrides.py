"""Traced hyperparameter overrides for the vmapped sweep axes.

An override rewrites a config *inside* the traced computation so one trace
serves every value of the axis: it receives the static base config and a
traced scalar, and returns a config whose affected fields hold traced arrays.
Strategy objects are frozen dataclasses whose precomputed tables (decay
weights, mixing matrices) the hot loop reads through ``jnp.asarray`` — so a
shallow copy with those fields replaced by traced equivalents drops straight
into the existing drivers.

Because the values are tracers, the eager validation that runs at strategy
construction (A3 monotonicity for decay, the 0 < eps < 1/Delta bound for
mixing) cannot run here — callers keep their sweep values inside the ranges
the paper's assumptions demand.

Built-in axes:

* ``eta`` — learning rate; any config with an ``eta`` field.
* ``lam`` — decay constant of the exponential family (eq. 21,
  ``D(j) = lam^{j/2}``); scalar points share one lambda, vector points give
  each agent its own (a traced ``(m, tau)`` weight table); requires a
  ``DecayStrategy``.
* ``eps`` — consensus step size; rebuilds ``P = I - eps*La`` and the fused /
  mask-folded powers; requires a ``ConsensusStrategy``.
* ``taus`` — per-agent tau_i schedule at *fixed* period length tau (A2,
  eq. 6): each point is a whole (m,) vector, retabulated inside the trace as
  the ``(m, tau)`` indicator mask (and the consensus strategies' mask-folded
  mixing tables) via ``AggregationStrategy.with_mask``. tau itself stays
  static — it fixes the mask shape and the inner scan length — so the
  variation axis is value-only and vmaps.
* ``delay`` — asynchronous-arrival axis: each point is a
  ``(dist_id, param)`` 2-vector (``repro.core.async_fed.DELAY_DISTRIBUTIONS``
  ids — float32 carries them exactly) and the override regenerates the
  ``AsyncStrategy``'s arrival/age schedule and staleness weights *inside the
  trace* from the traced draws. Shapes (m, n_periods, tau) stay static, so
  every delay distribution of the axis shares one trace; requires an
  ``AsyncStrategy`` base whose schedule fixes the horizon.
* ``k`` — FedBuff buffer-size axis: each point is a scalar K and the
  override re-selects the K freshest arrivals inside the trace
  (``repro.core.async_fed.kofm_arrivals`` — K enters only a rank
  comparison, so buffer-size sweeps are value-only and share one compile);
  requires an ``AsyncStrategy`` base on a ``kofm_schedule``.
* ``hetero_scale`` — fleet-heterogeneity magnitude: rebuilds the per-agent
  ``EnvParams`` with perturbation directions fixed by a PRNG key and the
  traced scale multiplying them (the asynchronous-MDP knob as a value-only
  axis). Points are scalars (one shared direction draw) or
  ``(scale, dir_seed)`` 2-vectors (per-cell direction draws). The base
  config should already be a fleet config (``num_envs >= 1``) so the trace
  structure matches the override.

``register_override`` adds custom axes.

Payload compression is the counter-example that must NOT be a vmapped axis:
a ``PayloadTransform`` changes the trace itself (the top-k kernel, the comm
state structure), so :func:`compression_axis` builds it as a *static* axis —
one compile per transform, looped in Python by the runner.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategies import ConsensusStrategy, DecayStrategy
from repro.core.topology import laplacian, neighbor_weights
from repro.core.variation import mask_from_taus, validate_a2


def _strategy_copy(strat, **fields):
    """Shallow-copy a frozen strategy dataclass with traced field overrides."""
    new = copy.copy(strat)
    for name, value in fields.items():
        object.__setattr__(new, name, value)
    return new


def override_eta(cfg, eta):
    """Learning-rate axis: works for FedRLConfig and FmarlConfig alike."""
    return dataclasses.replace(cfg, eta=eta)


def override_lam(cfg, lam):
    """Decay-constant axis: retabulates ``D(j) = lam^{j/2}`` (eq. 21) traced.

    A scalar point gives the shared ``(tau,)`` table; an (m,)-vector point
    gives every agent its own decay constant — a ``(m, tau)`` table that
    ``DecayStrategy.weight`` reads per agent (the per-agent variation of the
    decay family, vmappable alongside the ``taus`` mask axis).
    """
    strat = cfg.strategy
    if not isinstance(strat, DecayStrategy):
        raise TypeError(
            f"'lam' axis needs a DecayStrategy base, got {type(strat).__name__}"
        )
    offs = jnp.arange(strat.tau, dtype=jnp.float32)
    lam_arr = jnp.asarray(lam, jnp.float32)
    if lam_arr.ndim == 0:
        w = jnp.power(lam_arr, offs / 2.0)
    else:
        if lam_arr.shape != (strat.m,):
            raise ValueError(
                f"'lam' axis vector points must be ({strat.m},) for this "
                f"strategy, got shape {lam_arr.shape}"
            )
        w = jnp.power(lam_arr[:, None], offs[None, :] / 2.0)
    return dataclasses.replace(cfg, strategy=_strategy_copy(strat, decay_weights=w))


def override_eps(cfg, eps):
    """Consensus step-size axis: rebuilds P, P^E and the mask-folded tables.

    The topology (and hence every shape) stays static; only the matrix
    *values* trace. ``rounds`` is a static int, so the fused power unrolls.
    On a sparse-path strategy the only eps-dependent table is the ``(m,
    k_max)`` edge-weight array, retabulated traced via ``neighbor_weights``
    (same elementwise fp32 ops as the dense ``I - eps*La`` rebuild, gathered)
    — no dense matrix ever materialises.
    """
    strat = cfg.strategy
    if not isinstance(strat, ConsensusStrategy):
        raise TypeError(
            f"'eps' axis needs a ConsensusStrategy base, got {type(strat).__name__}"
        )
    if strat.sparse:
        strat = _strategy_copy(
            strat, nl_w=neighbor_weights(strat.nl, eps), eps=eps
        )
        return dataclasses.replace(cfg, strategy=strat)
    lap = jnp.asarray(laplacian(strat.topo), jnp.float32)
    p = jnp.eye(strat.m, dtype=jnp.float32) - jnp.asarray(eps, jnp.float32) * lap
    p_e = p
    for _ in range(strat.rounds - 1):
        p_e = jnp.matmul(p_e, p)
    mask_t = jnp.asarray(strat.mask).T[:, None, :]          # (tau, 1, m)
    strat = _strategy_copy(
        strat,
        p=p,
        p_e=p_e,
        p_masked=p[None, :, :] * mask_t,
        p_e_masked=p_e[None, :, :] * mask_t,
        eps=eps,
    )
    return dataclasses.replace(cfg, strategy=strat)


def override_taus(cfg, taus):
    """Variation axis: retabulate the ``(m, tau)`` indicator mask traced.

    ``taus`` is an (m,) point of the vector-valued ``taus`` axis (float32
    carries integer schedules exactly). The period length ``cfg.strategy.tau``
    stays static — it fixes the mask shape and the inner scan length — so
    every schedule of the axis shares one trace; only the mask values (and
    the consensus strategies' mask-folded tables, refolded by ``with_mask``)
    vary per cell. A2 validity (1 <= tau_i <= tau, non-increasing, pacing
    agent present) is enforced on *concrete* points (eager use) but cannot
    be checked on tracers, so points fed through the jitted runners must be
    valid by construction (``repro.core.variation.uniform_taus`` /
    ``tau_schedule`` emit such schedules).

    When the point is concrete the copy's static ``taus`` is refreshed too,
    so host-side comm accounting stays consistent.
    """
    strat = cfg.strategy
    taus = jnp.asarray(taus)
    if taus.ndim != 1 or taus.shape[0] != strat.m:
        raise ValueError(
            f"'taus' axis points must be ({strat.m},) vectors for this "
            f"strategy, got shape {taus.shape}"
        )
    mask = mask_from_taus(taus, strat.tau)
    try:
        static_taus = np.asarray(taus, int)  # concrete (eager) point
    except (jax.errors.TracerArrayConversionError, TypeError):
        static_taus = None                   # traced: accounting keeps base
    if static_taus is not None:
        validate_a2(static_taus, strat.tau)
    return dataclasses.replace(cfg, strategy=strat.with_mask(mask, static_taus))


def override_hetero_scale(cfg, point):
    """Fleet-heterogeneity axis: per-agent EnvParams magnitudes, traced.

    Rebuilds ``cfg.env_params`` via :func:`repro.rl.env.perturb_params` with
    perturbation *directions* fixed by a PRNG key (decorrelated from the
    training streams by a fold_in) and the traced scale multiplying them.
    Scale 0 is the homogeneous fleet.

    Two point shapes:

    * scalar ``scale`` — directions drawn once from ``cfg.eval_seed``; every
      cell of the axis shares one direction draw (the sweep moves only along
      the heterogeneity magnitude).
    * 2-vector ``(scale, dir_seed)`` — the direction key is additionally
      folded with the per-cell ``dir_seed``, so each cell perturbs along its
      *own* directions (float32 carries integer seeds exactly). Without this
      every cell of a multi-seed sweep shared a single direction draw, so
      "heterogeneity" measured one arbitrary perturbation instead of the
      distribution over perturbations.
    """
    from repro.rl.env import perturb_params

    point = jnp.asarray(point, jnp.float32)
    key = jax.random.fold_in(jax.random.key(cfg.eval_seed), 2026)
    if point.ndim == 0:
        scale = point
    elif point.shape == (2,):
        scale = point[0]
        key = jax.random.fold_in(key, point[1].astype(jnp.int32))
    else:
        raise ValueError(
            "'hetero_scale' axis points must be scalars or (scale, dir_seed) "
            f"2-vectors, got shape {point.shape}"
        )
    params = perturb_params(cfg.env, key, cfg.strategy.m, scale)
    return dataclasses.replace(cfg, env_params=params)


def override_delay(cfg, point):
    """Asynchronous-arrival axis: regenerate the delay schedule traced.

    ``point`` is a ``(dist_id, param)`` 2-vector. The override redraws the
    per-(agent, period) delays from :func:`repro.core.async_fed.delay_draws`
    (distribution selected by the *traced* id — pure arithmetic, no control
    flow), reruns the renewal-arrival scan, and refolds the staleness-decay
    weights, all on the existing schedule's static shape. The strategy's
    host-side accounting keeps the base schedule; benches rebuild the
    matching concrete schedule via ``make_schedule(..., seed=cfg.eval_seed)``
    (both sides draw from ``delay_axis_key``, so arrivals agree exactly).
    """
    from repro.core.async_fed import (
        AsyncStrategy,
        delay_axis_key,
        delay_draws,
        renewal_arrivals,
        sync_weight_table,
    )

    strat = cfg.strategy
    if not isinstance(strat, AsyncStrategy):
        raise TypeError(
            f"'delay' axis needs an AsyncStrategy base, got "
            f"{type(strat).__name__}"
        )
    point = jnp.asarray(point, jnp.float32)
    if point.shape != (2,):
        raise ValueError(
            "'delay' axis points must be (dist_id, param) 2-vectors, got "
            f"shape {point.shape}"
        )
    sched = strat.schedule
    delays = delay_draws(
        point[0], point[1], sched.m, sched.n_periods,
        delay_axis_key(getattr(cfg, "eval_seed", 0)),
    )
    arrive, age = renewal_arrivals(delays)
    weights = sync_weight_table(arrive, age, strat.stale_table)
    sched = dataclasses.replace(sched, arrive=arrive, age=age)
    strat = _strategy_copy(strat, schedule=sched, sync_weights=weights)
    return dataclasses.replace(cfg, strategy=strat)


def override_k(cfg, k):
    """FedBuff buffer-size axis: re-select the K freshest arrivals traced.

    ``k`` is a scalar point (float32 carries buffer sizes exactly). The
    override redraws the schedule's lag process inside the trace — same
    ``(dist, param)`` recorded on the base K-of-m schedule, same
    ``delay_axis_key(cfg.eval_seed)`` uniforms the host constructor used —
    then reruns the selection as :func:`repro.core.async_fed.kofm_arrivals`,
    where K enters only a rank *comparison*. All shapes stay static, so
    every buffer size of the axis shares one trace (retrace-pinned); callers
    keep points inside ``1 <= k <= m``, which cannot be checked on tracers.
    The strategy's host-side accounting keeps the base-K schedule; benches
    rebuild the matching concrete schedule via ``kofm_schedule(..., k=point,
    seed=cfg.eval_seed)``.
    """
    from repro.core.async_fed import (
        DELAY_DISTRIBUTIONS,
        AsyncStrategy,
        delay_axis_key,
        delay_draws,
        kofm_arrivals,
        sync_weight_table,
    )

    strat = cfg.strategy
    if not isinstance(strat, AsyncStrategy):
        raise TypeError(
            f"'k' axis needs an AsyncStrategy base, got {type(strat).__name__}"
        )
    sched = strat.schedule
    if sched.k is None or sched.dist is None:
        raise ValueError(
            "'k' axis needs a K-of-m base schedule that records its lag "
            "process — build it with kofm_schedule(...)"
        )
    k = jnp.asarray(k, jnp.float32)
    if k.ndim != 0:
        raise ValueError(
            f"'k' axis points must be scalars, got shape {k.shape}"
        )
    lag = delay_draws(
        DELAY_DISTRIBUTIONS[sched.dist], sched.param, sched.m,
        sched.n_periods, delay_axis_key(getattr(cfg, "eval_seed", 0)),
    )
    arrive, age = kofm_arrivals(lag, k)
    weights = sync_weight_table(arrive, age, strat.stale_table)
    sched = dataclasses.replace(sched, arrive=arrive, age=age)
    strat = _strategy_copy(strat, schedule=sched, sync_weights=weights)
    return dataclasses.replace(cfg, strategy=strat)


OVERRIDES: Dict[str, Callable] = {
    "eta": override_eta,
    "lam": override_lam,
    "eps": override_eps,
    "taus": override_taus,
    "delay": override_delay,
    "k": override_k,
    "hetero_scale": override_hetero_scale,
}


def register_override(name: str, fn: Callable) -> None:
    """Register a custom vmapped axis: ``fn(cfg, traced_value) -> cfg``."""
    if not callable(fn):
        raise TypeError("override must be callable")
    OVERRIDES[name] = fn


def compression_axis(points, name: str = "compression"):
    """Static sweep axis over payload transforms (``repro.comm``).

    ``points`` is a sequence of :class:`~repro.comm.PayloadTransform` objects
    (labelled by their ``label`` property) or explicit
    ``(label, transform)`` pairs. Each point becomes a
    ``StaticAxis`` entry whose config transform swaps the strategy's ``comm``
    via ``with_comm`` — static because the transform kind/k alter the traced
    computation (comm-state structure, top-k kernel), so the runner compiles
    exactly once per point.
    """
    from repro.comm.transforms import PayloadTransform
    from repro.sweep.spec import StaticAxis

    labelled = []
    for point in points:
        if isinstance(point, PayloadTransform):
            label, tr = point.label, point
        else:
            label, tr = point
            if not isinstance(tr, PayloadTransform):
                raise TypeError(
                    f"compression point {label!r} must carry a "
                    f"PayloadTransform, got {type(tr).__name__}"
                )

        def swap(cfg, _tr=tr):
            return dataclasses.replace(
                cfg, strategy=cfg.strategy.with_comm(_tr)
            )

        labelled.append((label, swap))
    return StaticAxis(name, tuple(labelled))


def algebraic_connectivity_axis(
    m: int,
    families=None,
    seed: int = 0,
    eps_frac: float = 0.5,
    name: str = "algebraic_connectivity",
    sparse=None,
):
    """Static sweep axis over graph families at fixed m: the lambda_2 figure.

    Each point builds one ``repro.core.topology.GRAPH_FAMILIES`` member
    (``families`` optionally restricts/orders the labels), labels it with its
    exact algebraic connectivity ``mu2``, and swaps the base config's
    ConsensusStrategy for one on that topology with ``eps = eps_frac / Delta``
    (the per-family step size that keeps the paper's 0 < eps < 1/Delta bound
    valid as the degree changes). Static, not vmapped: the neighbor-list
    shapes, mixing tables and even the dense/sparse path selection differ per
    family, so the runner compiles once per point. ``sparse`` forces the path
    (None = the strategy's density auto-rule per family).
    """
    from repro.core.topology import GRAPH_FAMILIES, mu2
    from repro.sweep.spec import StaticAxis

    if not (0.0 < eps_frac < 1.0):
        raise ValueError(f"eps_frac={eps_frac} must be in (0, 1)")
    labels = list(families) if families is not None else list(GRAPH_FAMILIES)
    points = []
    for label in labels:
        try:
            build = GRAPH_FAMILIES[label]
        except KeyError:
            raise KeyError(
                f"unknown graph family {label!r}; have {sorted(GRAPH_FAMILIES)}"
            ) from None
        topo = build(m, seed)
        lam2 = mu2(topo)

        def swap(cfg, _topo=topo, _sparse=sparse):
            strat = cfg.strategy
            if not isinstance(strat, ConsensusStrategy):
                raise TypeError(
                    "'algebraic_connectivity' axis needs a ConsensusStrategy "
                    f"base, got {type(strat).__name__}"
                )
            if strat.m != _topo.m:
                raise ValueError(
                    f"axis topology has m={_topo.m} but the base strategy "
                    f"has m={strat.m}"
                )
            new = ConsensusStrategy(
                tau=strat.tau,
                topo=_topo,
                eps=eps_frac / _topo.max_degree,
                rounds=strat.rounds,
                taus=strat.taus,
                fused=strat.fused,
                backend=strat.backend,
                sparse=_sparse,
            )
            if strat.comm.enabled:
                new = new.with_comm(strat.comm)
            return dataclasses.replace(cfg, strategy=new)

        points.append((f"{label}(mu2={lam2:.3f})", swap))
    return StaticAxis(name, tuple(points))


def apply_overrides(cfg, names, values):
    """Apply registered overrides in axis order (traced context)."""
    for name, value in zip(names, values):
        try:
            fn = OVERRIDES[name]
        except KeyError:
            raise KeyError(
                f"no override registered for vmapped axis {name!r}; "
                f"have {sorted(OVERRIDES)}"
            ) from None
        cfg = fn(cfg, value)
    return cfg
