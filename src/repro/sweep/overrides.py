"""Traced hyperparameter overrides for the vmapped sweep axes.

An override rewrites a config *inside* the traced computation so one trace
serves every value of the axis: it receives the static base config and a
traced scalar, and returns a config whose affected fields hold traced arrays.
Strategy objects are frozen dataclasses whose precomputed tables (decay
weights, mixing matrices) the hot loop reads through ``jnp.asarray`` — so a
shallow copy with those fields replaced by traced equivalents drops straight
into the existing drivers.

Because the values are tracers, the eager validation that runs at strategy
construction (A3 monotonicity for decay, the 0 < eps < 1/Delta bound for
mixing) cannot run here — callers keep their sweep values inside the ranges
the paper's assumptions demand.

Built-in axes:

* ``eta`` — learning rate; any config with an ``eta`` field.
* ``lam`` — decay constant of the exponential family (eq. 21,
  ``D(j) = lam^{j/2}``); requires a ``DecayStrategy``.
* ``eps`` — consensus step size; rebuilds ``P = I - eps*La`` and the fused /
  mask-folded powers; requires a ``ConsensusStrategy``.

``register_override`` adds custom axes.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Callable, Dict

import jax.numpy as jnp

from repro.core.strategies import ConsensusStrategy, DecayStrategy
from repro.core.topology import laplacian


def _strategy_copy(strat, **fields):
    """Shallow-copy a frozen strategy dataclass with traced field overrides."""
    new = copy.copy(strat)
    for name, value in fields.items():
        object.__setattr__(new, name, value)
    return new


def override_eta(cfg, eta):
    """Learning-rate axis: works for FedRLConfig and FmarlConfig alike."""
    return dataclasses.replace(cfg, eta=eta)


def override_lam(cfg, lam):
    """Decay-constant axis: retabulates ``D(j) = lam^{j/2}`` (eq. 21) traced."""
    strat = cfg.strategy
    if not isinstance(strat, DecayStrategy):
        raise TypeError(
            f"'lam' axis needs a DecayStrategy base, got {type(strat).__name__}"
        )
    offs = jnp.arange(strat.tau, dtype=jnp.float32)
    w = jnp.power(jnp.asarray(lam, jnp.float32), offs / 2.0)
    return dataclasses.replace(cfg, strategy=_strategy_copy(strat, decay_weights=w))


def override_eps(cfg, eps):
    """Consensus step-size axis: rebuilds P, P^E and the mask-folded tables.

    The topology (and hence every shape) stays static; only the matrix
    *values* trace. ``rounds`` is a static int, so the fused power unrolls.
    """
    strat = cfg.strategy
    if not isinstance(strat, ConsensusStrategy):
        raise TypeError(
            f"'eps' axis needs a ConsensusStrategy base, got {type(strat).__name__}"
        )
    lap = jnp.asarray(laplacian(strat.topo), jnp.float32)
    p = jnp.eye(strat.m, dtype=jnp.float32) - jnp.asarray(eps, jnp.float32) * lap
    p_e = p
    for _ in range(strat.rounds - 1):
        p_e = jnp.matmul(p_e, p)
    mask_t = jnp.asarray(strat.mask).T[:, None, :]          # (tau, 1, m)
    strat = _strategy_copy(
        strat,
        p=p,
        p_e=p_e,
        p_masked=p[None, :, :] * mask_t,
        p_e_masked=p_e[None, :, :] * mask_t,
        eps=eps,
    )
    return dataclasses.replace(cfg, strategy=strat)


OVERRIDES: Dict[str, Callable] = {
    "eta": override_eta,
    "lam": override_lam,
    "eps": override_eps,
}


def register_override(name: str, fn: Callable) -> None:
    """Register a custom vmapped axis: ``fn(cfg, traced_value) -> cfg``."""
    if not callable(fn):
        raise TypeError("override must be callable")
    OVERRIDES[name] = fn


def apply_overrides(cfg, names, values):
    """Apply registered overrides in axis order (traced context)."""
    for name, value in zip(names, values):
        try:
            fn = OVERRIDES[name]
        except KeyError:
            raise KeyError(
                f"no override registered for vmapped axis {name!r}; "
                f"have {sorted(OVERRIDES)}"
            ) from None
        cfg = fn(cfg, value)
    return cfg
