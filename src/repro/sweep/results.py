"""Sweep results: seed-axis reduction (mean / confidence interval) and the
versioned JSON/CSV artifacts under ``experiments/``.

Metric arrays come back from the runner shaped ``(*axis_lens, n_seeds,
*per_run)`` per static-point label. The reduction collapses the seed axis to
(mean, CI half-width) using a two-sided Student-t interval (small-seed-count
correct; normal fallback above the tabulated dfs), matching how the
seed-averaged curves in Xu et al. / Khodadadian et al. style figures are
reported.

Artifacts are versioned: the JSON payload carries ``schema_version`` and
``save()`` never overwrites — it allocates ``<name>.v<N>.json`` / ``.csv``
with the next free N, so a sweep's history accumulates in ``experiments/``.
"""
from __future__ import annotations

import csv
import dataclasses
import itertools
import json
import math
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

SCHEMA_VERSION = 1

# Two-sided Student-t critical values, df 1..30 (beyond: normal quantile).
_T_TABLE = {
    0.90: (6.314, 2.920, 2.353, 2.132, 2.015, 1.943, 1.895, 1.860, 1.833,
           1.812, 1.796, 1.782, 1.771, 1.761, 1.753, 1.746, 1.740, 1.734,
           1.729, 1.725, 1.721, 1.717, 1.714, 1.711, 1.708, 1.706, 1.703,
           1.701, 1.699, 1.697),
    0.95: (12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262,
           2.228, 2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101,
           2.093, 2.086, 2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052,
           2.048, 2.045, 2.042),
    0.99: (63.657, 9.925, 5.841, 4.604, 4.032, 3.707, 3.499, 3.355, 3.250,
           3.169, 3.106, 3.055, 3.012, 2.977, 2.947, 2.921, 2.898, 2.878,
           2.861, 2.845, 2.831, 2.819, 2.807, 2.797, 2.787, 2.779, 2.771,
           2.763, 2.756, 2.750),
}
_Z = {0.90: 1.645, 0.95: 1.960, 0.99: 2.576}


def t_critical(df: int, confidence: float = 0.95) -> float:
    """Two-sided Student-t critical value (tabulated 0.90/0.95/0.99)."""
    if confidence not in _T_TABLE:
        raise ValueError(
            f"confidence must be one of {sorted(_T_TABLE)}, got {confidence}"
        )
    if df < 1:
        raise ValueError("need df >= 1 (at least two seeds) for a CI")
    table = _T_TABLE[confidence]
    return table[df - 1] if df <= len(table) else _Z[confidence]


def mean_ci(
    arr: np.ndarray, axis: int, confidence: float = 0.95
) -> Tuple[np.ndarray, np.ndarray]:
    """Mean and CI half-width over one axis (t-interval; zero hw for n=1)."""
    arr = np.asarray(arr)
    n = arr.shape[axis]
    mean = arr.mean(axis=axis)
    if n < 2:
        return mean, np.zeros_like(mean)
    sd = arr.std(axis=axis, ddof=1)
    hw = t_critical(n - 1, confidence) * sd / math.sqrt(n)
    return mean, hw


def _next_version(out_dir: str, name: str) -> int:
    v = 1
    while os.path.exists(os.path.join(out_dir, f"{name}.v{v}.json")):
        v += 1
    return v


@dataclasses.dataclass
class SweepResult:
    """Raw per-run metric arrays for every static point, plus sweep metadata.

    ``metrics[label][metric]`` has shape ``(*axis_lens, n_seeds, *per_run)``
    (per_run is usually the per-epoch curve). ``wall_s[label]`` is the
    end-to-end wall-clock of that static point's batched computation and
    ``compile_s[label]`` its one-off trace+compile time; ``mode`` records
    whether the grid ran as one vmapped computation or a Python loop.
    """

    name: str
    axes: Dict[str, List[float]]
    seeds: List[int]
    metrics: Dict[str, Dict[str, np.ndarray]]
    wall_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    compile_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    mode: str = "vmapped"
    meta: Dict = dataclasses.field(default_factory=dict)

    @property
    def labels(self) -> List[str]:
        return list(self.metrics)

    @property
    def seed_axis(self) -> int:
        return len(self.axes)

    def seed_mean_ci(
        self, label: str, metric: str, confidence: float = 0.95
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(mean, CI half-width) over seeds: shape ``(*axis_lens, *per_run)``."""
        return mean_ci(self.metrics[label][metric], self.seed_axis, confidence)

    def summary(self, confidence: float = 0.95) -> dict:
        """JSON-ready payload: seed-reduced curves per label/metric."""
        labels = {}
        for label, md in self.metrics.items():
            entry = {}
            for metric, arr in md.items():
                mean, hw = mean_ci(arr, self.seed_axis, confidence)
                entry[metric] = {
                    "mean": mean.tolist(),
                    "ci_hw": hw.tolist(),
                }
            labels[label] = entry
        return {
            "schema_version": SCHEMA_VERSION,
            "name": self.name,
            "mode": self.mode,
            "confidence": confidence,
            "axes": self.axes,
            "seeds": list(self.seeds),
            "n_seeds": len(self.seeds),
            "wall_s": dict(self.wall_s),
            "compile_s": dict(self.compile_s),
            "meta": dict(self.meta),
            "labels": labels,
        }

    def rows(self, confidence: float = 0.95) -> List[dict]:
        """Long-format rows (one per grid cell x curve step) for CSV output."""
        axis_names = list(self.axes)
        out = []
        for label, md in self.metrics.items():
            for metric, arr in md.items():
                mean, hw = mean_ci(arr, self.seed_axis, confidence)
                lead = mean.shape[: len(axis_names)]
                trail = mean.shape[len(axis_names):]
                for idx in itertools.product(*(range(s) for s in lead)):
                    coords = {}
                    for n, i in zip(axis_names, idx):
                        val = self.axes[n][i]
                        if isinstance(val, (tuple, list)):
                            # vector-valued point (e.g. a tau_i schedule):
                            # one compact CSV cell instead of a raw tuple
                            val = "[" + ",".join(f"{x:g}" for x in val) + "]"
                        coords[n] = val
                    m_curve = mean[idx].reshape(trail)
                    h_curve = hw[idx].reshape(trail)
                    if m_curve.ndim == 0:
                        m_curve, h_curve = m_curve[None], h_curve[None]
                    flat_m = np.asarray(m_curve).reshape(-1)
                    flat_h = np.asarray(h_curve).reshape(-1)
                    for step, (mv, hv) in enumerate(zip(flat_m, flat_h)):
                        out.append({
                            "label": label,
                            **coords,
                            "metric": metric,
                            "step": step,
                            "mean": float(mv),
                            "ci_hw": float(hv),
                            "n_seeds": len(self.seeds),
                        })
        return out

    def save(
        self,
        out_dir: str = "experiments/sweeps",
        confidence: float = 0.95,
        version: Optional[int] = None,
    ) -> Tuple[str, str]:
        """Write versioned ``<name>.v<N>.json`` + ``.csv``; returns the paths."""
        os.makedirs(out_dir, exist_ok=True)
        v = version if version is not None else _next_version(out_dir, self.name)
        jpath = os.path.join(out_dir, f"{self.name}.v{v}.json")
        cpath = os.path.join(out_dir, f"{self.name}.v{v}.csv")
        payload = self.summary(confidence)
        payload["version"] = v
        with open(jpath, "w") as f:
            json.dump(payload, f, indent=2)
        rows = self.rows(confidence)
        if rows:
            fields = list(rows[0].keys())
            with open(cpath, "w", newline="") as f:
                w = csv.DictWriter(f, fieldnames=fields)
                w.writeheader()
                w.writerows(rows)
        return jpath, cpath
