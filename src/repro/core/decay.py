"""Decay functions D(s) for the decay-based method (paper §V-C, A3, eq. 21).

A3 requires: D is periodic with period tau, D(t0) = 1, and D monotonically
non-increasing over a period with values in [0, 1]. All families below satisfy
A3 (asserted in tests/property tests).

D takes the *within-period offset* j = (s - t0) in {0, ..., tau-1}.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

DecayFn = Callable[[jnp.ndarray], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class _Named:
    fn: DecayFn
    name: str

    def __call__(self, j):
        return self.fn(jnp.asarray(j, jnp.float32))


def exponential_decay(lam: float) -> DecayFn:
    """The paper's eq. (21): D(s) = lambda^{s/2} with s the period offset."""
    if not (0.0 < lam <= 1.0):
        raise ValueError(f"decay constant must be in (0, 1], got {lam}")
    return _Named(lambda j: jnp.power(lam, j / 2.0), f"exp(lam={lam})")


def linear_decay(tau: int, floor: float = 0.0) -> DecayFn:
    """D(j) = 1 - (1 - floor) * j / tau (never reaches floor inside a period)."""
    if tau < 1:
        raise ValueError("tau >= 1 required")
    return _Named(
        lambda j: jnp.clip(1.0 - (1.0 - floor) * j / float(tau), floor, 1.0),
        f"linear(tau={tau},floor={floor})",
    )


def cosine_decay(tau: int, floor: float = 0.0) -> DecayFn:
    """Half-cosine from 1 to floor over a period."""
    if tau < 1:
        raise ValueError("tau >= 1 required")

    def fn(j):
        frac = jnp.clip(j / float(max(tau, 1)), 0.0, 1.0)
        return floor + (1.0 - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))

    return _Named(fn, f"cosine(tau={tau},floor={floor})")


def step_decay(drop_at: int, low: float = 0.5) -> DecayFn:
    """D = 1 for j < drop_at else low."""
    if not (0.0 <= low <= 1.0):
        raise ValueError("low must be in [0, 1]")
    return _Named(lambda j: jnp.where(j < drop_at, 1.0, low), f"step({drop_at},{low})")


def no_decay() -> DecayFn:
    """Identity weight (reduces the decay-based method to plain periodic avg)."""
    return _Named(lambda j: jnp.ones_like(j), "none")


def decay_sq_prefix_sum(decay: DecayFn, j: int) -> float:
    """Z(j) = sum_{s=0}^{j-1} D^2(s)  (used by T4's closed form and tests)."""
    offs = jnp.arange(j)
    return float(jnp.sum(jnp.square(decay(offs))))
