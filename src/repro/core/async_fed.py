"""Asynchronous, staleness-aware federation: FedBuff-style buffered averaging.

The paper's motivating scenario is *asynchronous* MDPs across heterogeneous
agents, yet the base strategies sync every replica in lockstep. This module
relaxes that: arrival delays are modelled as a traced per-agent staleness
schedule — a ``(m, T)`` operand over the T period boundaries, same trick as
the PR-5 tau masks, with no Python loop in any scan body — and the server
performs buffered (FedBuff-style) aggregation over whichever replicas have
"arrived" at each boundary.

Pieces:

* :func:`delay_draws` — per-(agent, period) delay draws for three pluggable
  distribution families (deterministic lag / geometric / heavy-tail discrete
  Pareto), selected by a *traced* distribution id so a ``(dist_id, param)``
  2-vector sweeps as a value-only axis (``repro.sweep`` ``delay`` axis).
* :func:`renewal_arrivals` — turns the delay draws into the ``(m, T)``
  arrival mask and integer staleness ages via a renewal scan: an agent whose
  last sync was ``s`` periods ago arrives once ``s`` exceeds its current
  draw, and its contribution carries age ``s - 1`` (0 = fresh).
* :func:`kofm_schedule` — the buffered FedBuff variant: every period exactly
  the K *freshest* replicas (smallest effective staleness, ties by agent
  index) are admitted; host-side generator for static schedules.
* :func:`kofm_arrivals` — its traced twin (a rank comparison inside a scan),
  where even K may be a traced scalar — the ``k`` sweep axis
  (``repro.sweep`` ``override_k``) runs buffer-size sweeps in one compile.
* :func:`masked_server_step` — the masked ``row_mean``: the staleness-
  weighted mean over the arrived replicas, built from the existing
  ``scale_rows`` / ``row_mean`` dispatch primitives so every backend and the
  fp32-accumulation contract carry over.
* :class:`AsyncStrategy` — the strategy seam: at period boundary ``t`` the
  server averages the arrivals of schedule column ``t`` weighted by a
  staleness-decay table (the ``DecayStrategy`` weight machinery over ages
  instead of period offsets), arrived replicas rebase onto the new server
  reference, and non-arrivals keep training locally against their last-seen
  reference (the ``ref`` accumulator the comm layer already threads through
  the drivers' scan carry).

Bitwise sync-equivalence contract (CI-gated at exactly 0.0): a zero-delay
schedule makes every weight exactly 1.0 and the correction factor
``m / sum(w)`` exactly 1.0, so the async server step executes the synchronous
``row_mean`` bit-for-bit on the eager jnp path — see DESIGN.md §15.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.decay import DecayFn, no_decay
from repro.core.strategies import AggregationStrategy
from repro.core.variation import masked_update_counts, validate_a2
from repro.kernels import dispatch

# Distribution ids are part of the sweep-axis encoding: a `delay` axis point
# is the float 2-vector (dist_id, param) — float32 carries these small ints
# exactly, so the id can be a *traced* value selected by arithmetic.
DELAY_DISTRIBUTIONS = {"deterministic": 0, "geometric": 1, "heavytail": 2}

# fold_in tag decorrelating the delay-process uniforms from the training and
# hetero_scale streams (which use 2026); shared by the traced sweep axis and
# the concrete constructor so host accounting sees the axis's exact arrivals.
_DELAY_STREAM = 2027


def delay_axis_key(eval_seed: int):
    """The PRNG key fixing the delay-process randomness of one config."""
    return jax.random.fold_in(jax.random.key(eval_seed), _DELAY_STREAM)


def delay_draws(dist_id, param, m: int, n_periods: int, key):
    """Per-(agent, period) delay draws: ``(m, T)`` float32, values >= 0.

    ``dist_id`` selects the family (may be traced — the three candidates are
    computed from one shared uniform draw and combined with ``jnp.where``,
    so there is no control flow to retrace):

    * ``0`` deterministic — every draw is ``round(param)`` periods of lag;
    * ``1`` geometric — failures before first success at rate ``param``
      (``floor(log(1-u)/log(1-param))``, mean ``(1-p)/p``);
    * ``2`` heavy-tail — discrete Pareto ``floor(u**(-1/param)) - 1`` with
      tail index ``param`` (infinite variance for ``param <= 2``).

    Draws are clipped to ``n_periods``: a delay beyond the horizon never
    arrives within the run, so larger values are indistinguishable.
    """
    dist_id = jnp.asarray(dist_id)
    param = jnp.asarray(param, jnp.float32)
    u = jax.random.uniform(
        key, (m, n_periods), jnp.float32, minval=1e-6, maxval=1.0 - 1e-6
    )
    det = jnp.floor(param + 0.5) * jnp.ones_like(u)
    p = jnp.clip(param, 1e-4, 1.0 - 1e-4)
    geom = jnp.floor(jnp.log1p(-u) / jnp.log1p(-p))
    alpha = jnp.maximum(param, 1e-2)
    heavy = jnp.floor(jnp.power(u, -1.0 / alpha)) - 1.0
    out = jnp.where(
        jnp.equal(dist_id, DELAY_DISTRIBUTIONS["geometric"]), geom, det
    )
    out = jnp.where(
        jnp.equal(dist_id, DELAY_DISTRIBUTIONS["heavytail"]), heavy, out
    )
    return jnp.clip(out, 0.0, float(n_periods))


def renewal_arrivals(delays):
    """Delay draws -> ``(arrive, age)``, both ``(m, T)`` float32.

    Renewal process per agent: ``since`` counts period boundaries since the
    agent's last sync (every replica starts freshly broadcast). At boundary
    ``t`` the agent arrives iff ``since > delays[:, t]`` — a zero draw means
    it arrives every period, a draw of ``d`` makes it skip ``d`` boundaries.
    ``age[:, t] = since - 1`` is the staleness its contribution would carry
    (0 = fresh, i.e. it also arrived at the previous boundary). The scan is
    over the *precomputed* schedule, never inside the drivers' step bodies,
    and works on traced draws (the ``delay`` sweep axis).
    """
    delays = jnp.asarray(delays, jnp.float32)
    m = delays.shape[0]

    def step(c, d):
        since = c + 1.0
        arrive = (since > d).astype(jnp.float32)
        age = since - 1.0
        c = jnp.where(arrive > 0.0, 0.0, since)
        return c, (arrive, age)

    _, (arrive, age) = jax.lax.scan(step, jnp.zeros(m, jnp.float32), delays.T)
    return arrive.T, age.T


@dataclasses.dataclass(frozen=True)
class DelaySchedule:
    """A precomputed arrival schedule over ``n_periods`` boundaries.

    ``arrive``/``age`` are ``(m, n_periods)`` float32 arrays (numpy when
    constructed concretely; tracers on a ``delay`` sweep-axis copy — the hot
    path reads them through ``jnp.asarray``). ``k`` records the FedBuff
    buffer size for K-of-m schedules (None for renewal schedules).
    """

    arrive: object
    age: object
    n_periods: int
    label: str
    k: Optional[int] = None
    # the lag process that generated this schedule, when known — the traced
    # sweep axes (delay, k) redraw the identical lag inside the trace from
    # (dist, param, delay_axis_key(eval_seed)), so host accounting and the
    # vmapped cells see the same arrival process
    dist: Optional[str] = None
    param: Optional[float] = None

    @property
    def m(self) -> int:
        return int(np.shape(self.arrive)[0])

    def arrivals_per_period(self) -> np.ndarray:
        """(n_periods,) int arrival counts — host accounting, concrete only."""
        try:
            arrive = np.asarray(self.arrive)
        except jax.errors.TracerArrayConversionError:
            raise ValueError(
                "arrival accounting needs a concrete schedule; traced "
                "delay-axis copies are billed from the equivalent "
                "make_schedule(..., seed=cfg.eval_seed) schedule"
            ) from None
        return arrive.sum(axis=0).astype(int)

    def total_arrivals(self, start: int = 0, n: Optional[int] = None) -> int:
        counts = self.arrivals_per_period()
        n = len(counts) - start if n is None else n
        return int(counts[start:start + n].sum())


def make_schedule(
    dist: str, param: float, m: int, n_periods: int, *, seed: int = 0
) -> DelaySchedule:
    """Concrete (host-side) schedule for one named delay distribution.

    ``seed`` should be the run config's ``eval_seed`` when the schedule must
    mirror a traced ``delay``-axis cell (both derive their uniforms from
    :func:`delay_axis_key`). ``dist='deterministic', param=0`` is the
    zero-delay schedule: every agent arrives at every boundary with age 0 —
    the synchronous-equivalence anchor.
    """
    try:
        dist_id = DELAY_DISTRIBUTIONS[dist]
    except KeyError:
        raise KeyError(
            f"unknown delay distribution {dist!r}; "
            f"have {sorted(DELAY_DISTRIBUTIONS)}"
        ) from None
    delays = delay_draws(
        dist_id, param, m, n_periods, delay_axis_key(seed)
    )
    arrive, age = renewal_arrivals(delays)
    return DelaySchedule(
        arrive=np.asarray(jax.device_get(arrive), np.float32),
        age=np.asarray(jax.device_get(age), np.float32),
        n_periods=int(n_periods),
        label=f"{dist}({param:g})",
        dist=dist,
        param=float(param),
    )


def kofm_arrivals(lag, k):
    """Traced twin of the :func:`kofm_schedule` selection loop.

    ``lag`` is the ``(m, T)`` per-(agent, period) delay draws (traced on a
    sweep axis); ``k`` the buffer size, which may itself be a *traced* scalar
    — the selection is a rank comparison, not a shape change, so a ``k``
    sweep axis is value-only and vmaps in one compile. Replays the host
    loop's renewal recurrence exactly: per boundary, effective staleness
    ``eff = since - 1 + lag``, the ``k`` smallest-``eff`` agents arrive (ties
    by agent index — ``jnp.argsort`` is stable, matching the host lexsort),
    their clocks reset, and the recorded age is ``eff`` for everyone. Returns
    ``(arrive, age)``, both ``(m, T)`` float32, bitwise-equal to the numpy
    constructor on concrete inputs (pinned by ``tests/test_async_fed.py``).
    """
    lag = jnp.asarray(lag, jnp.float32)
    m = lag.shape[0]
    k = jnp.asarray(k, jnp.float32)

    def step(c, lag_t):
        since = c + 1.0
        eff = since - 1.0 + lag_t
        order = jnp.argsort(eff)
        ranks = jnp.zeros(m, jnp.float32).at[order].set(
            jnp.arange(m, dtype=jnp.float32)
        )
        arrive = (ranks < k).astype(jnp.float32)
        c = jnp.where(arrive > 0.0, 0.0, since)
        return c, (arrive, eff)

    _, (arrive, age) = jax.lax.scan(step, jnp.zeros(m, jnp.float32), lag.T)
    return arrive.T, age.T


def kofm_schedule(
    m: int,
    n_periods: int,
    k: int,
    *,
    dist: str = "geometric",
    param: float = 0.5,
    seed: int = 0,
) -> DelaySchedule:
    """FedBuff buffered schedule: the K freshest replicas arrive each period.

    Each agent carries an effective staleness ``eff = since - 1 + lag`` at
    every boundary — periods since its last sync plus this period's delay
    draw (its slowness). The server admits exactly the ``k`` agents with the
    smallest ``eff`` (ties broken by agent index — a stable lexsort), resets
    their renewal clocks, and everyone else keeps training locally. With
    ``k = m`` and zero lag this degenerates to the synchronous schedule.
    The recorded ``age`` is ``eff`` itself, so the staleness-decay weights
    and the K-freshest selection agree — the hypothesis property suite pins
    ``max(age[arrived]) <= min(age[not arrived])`` per period.
    """
    if not 1 <= k <= m:
        raise ValueError(f"need 1 <= k <= m, got k={k} m={m}")
    lag = np.asarray(
        jax.device_get(
            delay_draws(
                DELAY_DISTRIBUTIONS[dist], param, m, n_periods,
                delay_axis_key(seed),
            )
        ),
        np.float32,
    )
    c = np.zeros(m, np.float32)
    arrive = np.zeros((m, n_periods), np.float32)
    age = np.zeros((m, n_periods), np.float32)
    for t in range(n_periods):
        since = c + 1.0
        eff = since - 1.0 + lag[:, t]
        sel = np.lexsort((np.arange(m), eff))[:k]
        arrive[sel, t] = 1.0
        age[:, t] = eff
        c = since
        c[sel] = 0.0
    return DelaySchedule(
        arrive=arrive,
        age=age,
        n_periods=int(n_periods),
        label=f"fedbuff(k={k},{dist}({param:g}))",
        k=int(k),
        dist=dist,
        param=float(param),
    )


def stale_weight_table(decay: Optional[DecayFn], n_periods: int) -> np.ndarray:
    """Staleness-decay lookup table ``D(age)`` for ages ``0..n_periods``.

    Reuses the ``DecayStrategy`` weight families (``repro.core.decay``) over
    *ages* instead of period offsets, under the same A3-style contract:
    ``D(0) = 1`` (a fresh arrival is never down-weighted — this is what makes
    the zero-delay schedule bitwise-synchronous), non-increasing, >= 0.
    """
    decay = decay or no_decay()
    w = np.asarray(
        jax.device_get(decay(jnp.arange(n_periods + 1))), np.float32
    )
    if w[0] != 1.0 or np.any(np.diff(w) > 1e-7) or np.any(w < -1e-7):
        raise ValueError(
            "staleness decay must satisfy D(0)=1, non-increasing, >= 0 "
            "over the schedule horizon (A3 over ages)"
        )
    return w


def sync_weight_table(arrive, age, table):
    """Per-boundary server weights: ``arrive * D(age)``, shape ``(m, T)``.

    Traced-safe (the ``delay`` axis regenerates this inside the trace); on
    concrete inputs the result is concrete. The zero-delay schedule yields
    exactly 1.0 everywhere — ``1.0 * D(0)`` with ``D(0) == 1.0`` — keeping
    the bitwise sync-equivalence contract independent of the decay choice.
    """
    table = jnp.asarray(table, jnp.float32)
    idx = jnp.clip(
        jnp.asarray(age).astype(jnp.int32), 0, table.shape[0] - 1
    )
    return jnp.asarray(arrive, jnp.float32) * table[idx]


def masked_server_step(flat, w, *, backend: str = "auto"):
    """FedBuff server row: staleness-weighted mean over the arrived replicas.

    ``flat`` is the ``(m, n)`` carry, ``w`` the ``(m,)`` weights (zero for
    non-arrivals). Computed as ``row_mean(scale_rows(flat, w)) * m/sum(w)``
    — i.e. ``sum_i w_i x_i / sum_i w_i`` — on the dispatched primitives, so
    fp32 accumulation and every backend carry over. The zero-delay case is
    *bitwise* the synchronous ``row_mean``: scaling by 1.0 is exact and the
    correction factor ``m / m`` is exactly 1.0.

    Returns ``(row, denom)``. When nothing arrived (``denom == 0``) the row
    is non-finite; the caller keeps its previous server reference instead.
    """
    m = flat.shape[0]
    w = jnp.asarray(w, jnp.float32)
    scaled = dispatch.scale_rows(flat, w, backend=backend)
    mean = dispatch.row_mean(scaled, backend=backend)
    denom = jnp.sum(w)
    row = (mean.astype(jnp.float32) * (m / denom)).astype(flat.dtype)
    return row, denom


@dataclasses.dataclass(frozen=True)
class AsyncStrategy(AggregationStrategy):
    """Asynchronous staleness-aware federation (FedBuff-style buffering).

    At period boundary ``t`` the server averages the replicas of schedule
    column ``t`` (:func:`masked_server_step`) with staleness-decay weights,
    arrived replicas rebase onto the new server reference, and non-arrivals
    keep training locally against their last-seen reference (``ref`` in the
    comm state — the same carry slot the compressed-uplink path uses).
    Within periods the per-agent tau_i variation masks compose unchanged.

    Server *reads* (the drivers' epoch evals and final readout) poll every
    replica exactly like the synchronous driver — that keeps the zero-delay
    run bitwise-identical end to end and the utility metric comparable
    across sync/async configs; the ledger bills those reads identically too.
    Optimizer moments stay local across boundaries (no cross-replica moment
    averaging: only the arrived subset synchronizes, and FedBuff keeps no
    server momentum), so the bitwise contract is pinned on the plain-SGD
    path. Compressed uplinks are not supported yet.
    """

    schedule: DelaySchedule = None
    stale_table: np.ndarray = None   # (n_periods + 1,) D(age) lookup
    sync_weights: object = None      # (m, n_periods) arrive * D(age)

    is_async = True
    uniform_sync = False

    def __init__(
        self,
        tau: int,
        schedule: DelaySchedule,
        taus=None,
        m: Optional[int] = None,
        stale_decay: Optional[DecayFn] = None,
        backend: str = "auto",
    ):
        if not isinstance(schedule, DelaySchedule):
            raise TypeError(
                f"AsyncStrategy needs a DelaySchedule, got "
                f"{type(schedule).__name__}"
            )
        m_s = schedule.m
        if m is not None and int(m) != m_s:
            raise ValueError(f"m={m} but the schedule carries m={m_s} agents")
        if taus is None:
            taus = np.full(m_s, tau, int)
        taus = np.asarray(taus, int)
        if len(taus) != m_s:
            raise ValueError(
                f"taus carries {len(taus)} agents, schedule m={m_s}"
            )
        validate_a2(taus, tau)
        table = stale_weight_table(stale_decay, schedule.n_periods)
        weights = np.asarray(
            jax.device_get(
                sync_weight_table(schedule.arrive, schedule.age, table)
            ),
            np.float32,
        )
        AggregationStrategy.__init__(
            self,
            name=f"async({schedule.label},tau={tau})",
            tau=tau,
            taus=taus,
            mask=self._build_mask(taus, tau),
            backend=backend,
        )
        object.__setattr__(self, "schedule", schedule)
        object.__setattr__(self, "stale_table", table)
        object.__setattr__(self, "sync_weights", weights)

    # --- driver seams ----------------------------------------------------------
    def validate_horizon(self, n_periods: int) -> None:
        """Fail fast (host-side) when a run outlives the schedule."""
        if self.schedule.n_periods < n_periods:
            raise ValueError(
                f"delay schedule covers {self.schedule.n_periods} periods "
                f"but the run has {n_periods}"
            )

    def with_comm(self, comm) -> "AsyncStrategy":
        if getattr(comm, "enabled", False):
            raise NotImplementedError(
                "compressed uplinks are not supported on the async path yet"
            )
        return super().with_comm(comm)

    def init_comm_state(self, flat) -> dict:
        """The fp32 server reference non-arrivals keep training against.

        Same ``ref`` carry slot the compressed-uplink path threads through
        the drivers (all replicas start broadcast, so row 0 is the server).
        """
        return {"ref": flat[0].astype(jnp.float32)}

    def flat_sync(self, flat, comm_state, *, period=None,
                  backend: Optional[str] = None):
        """Buffered aggregation at boundary ``period`` (traced index).

        Reads column ``period`` of the precomputed ``(m, T)`` schedule — a
        dynamic slice, no Python loop — weights the arrivals by staleness
        decay, and rebases *only* the arrived replicas onto the new server
        reference. If nothing arrived the reference is kept as-is.
        """
        if period is None:
            raise ValueError(
                "AsyncStrategy.flat_sync needs the period index; the flat "
                "drivers pass it from their period scans"
            )
        b = backend if backend is not None else self.backend
        w = jnp.asarray(self.sync_weights)[:, period]
        arrive = jnp.asarray(self.schedule.arrive)[:, period]
        row, denom = masked_server_step(flat, w, backend=b)
        ref = jnp.where(denom > 0.0, row.astype(jnp.float32),
                        comm_state["ref"])
        flat = jnp.where(
            arrive[:, None] > 0.0, ref[None, :].astype(flat.dtype), flat
        )
        return flat, dict(comm_state, ref=ref)

    def server_row(self, flat, comm_state, *, backend: Optional[str] = None):
        """The buffered server reference (replicas are not re-broadcast)."""
        del backend
        return comm_state["ref"].astype(flat.dtype)

    # --- accounting ------------------------------------------------------------
    def comm_events_per_period(self) -> dict:
        raise NotImplementedError(
            "async arrivals are non-uniform across periods; the ledger "
            "bills them via comm_events_span"
        )

    def comm_events_span(self, start: int, n_periods: int) -> dict:
        """Totals over boundaries ``[start, start + n_periods)``.

        C1 uplinks are the *arrivals* of those boundaries — only an arrived
        replica puts its payload on the wire — while every agent keeps
        training locally, so C2 stays ``sum(tau_i)`` per period.
        """
        if start < 0 or start + n_periods > self.schedule.n_periods:
            raise ValueError(
                f"period span [{start}, {start + n_periods}) outside the "
                f"schedule horizon {self.schedule.n_periods}"
            )
        return {
            "c1": self.schedule.total_arrivals(start, n_periods),
            "c2": int(np.sum(self.taus)) * n_periods,
            "w1": 0,
            "w2": 0,
        }

    def comm_events_partial_period(self, n_offsets: int) -> dict:
        """A trailing partial period reaches no boundary: zero uplinks.

        Under buffered aggregation no server event fires mid-period, so the
        partial tail bills only its local updates — total async wire bytes
        are exactly ``total arrivals x payload_bytes`` (pinned by the
        hypothesis ledger property). The uniform base class instead bills a
        final every-replica poll here; that assumption is what the
        arrival-aware ledger path fixes for async strategies.
        """
        n_offsets = int(n_offsets)
        if not 0 <= n_offsets < self.tau:
            raise ValueError(
                f"partial period must satisfy 0 <= n_offsets < tau="
                f"{self.tau}, got {n_offsets}"
            )
        return {
            "c1": 0,
            "c2": int(masked_update_counts(self.taus, n_offsets).sum()),
            "w1": 0,
            "w2": 0,
        }


# --- trace-safety audit registration (repro.analysis.jaxpr_audit) -------------

def _audit_masked_server(backend: str):
    """masked_server_step on one CPU-executable backend, for the jaxpr audit."""

    def factory() -> dispatch.HotPathEntry:
        m, n = 7, 512
        return dispatch.HotPathEntry(
            fn=lambda flat, w: masked_server_step(flat, w, backend=backend),
            args=(
                jax.ShapeDtypeStruct((m, n), jnp.float32),
                jax.ShapeDtypeStruct((m,), jnp.float32),
            ),
        )

    return factory


def _audit_delay_axis() -> dispatch.HotPathEntry:
    """The ``delay``-axis static-point fn, exactly as ``run_sweep`` jits it.

    A tiny async FedRL sweep over two (dist_id, param) points x one seed:
    the schedule-regenerating override, the renewal scan, the masked server
    step and both driver scans all land in the audited jaxpr. One static
    point == one compile (the retrace guard pins this in the test suite).
    """
    from repro.rl.env import FIGURE_EIGHT
    from repro.rl.fedrl import FedRLConfig
    from repro.sweep.runner import audit_batched_fn
    from repro.sweep.spec import SweepAxis, SweepSpec

    sched = make_schedule("deterministic", 0.0, 7, 1, seed=1234)
    base = FedRLConfig(
        env=FIGURE_EIGHT,
        strategy=AsyncStrategy(tau=2, schedule=sched, backend="jnp"),
        n_epochs=1,
        epoch_len=4,
        minibatch=2,
    )
    spec = SweepSpec(
        name="audit-delay",
        base=base,
        seeds=(0,),
        vmapped=(SweepAxis(name="delay", values=((0.0, 1.0), (1.0, 0.5))),),
    )
    batched, args = audit_batched_fn(spec)
    return dispatch.HotPathEntry(fn=batched, args=args)


def _audit_k_axis() -> dispatch.HotPathEntry:
    """The ``k``-axis static-point fn, exactly as ``run_sweep`` jits it.

    A tiny async FedRL sweep over two buffer sizes x one seed: the
    lag-redrawing override, the traced K-of-m selection scan
    (:func:`kofm_arrivals`), the masked server step and both driver scans
    all land in the audited jaxpr. One static point == one compile (the
    retrace guard pins this in the test suite).
    """
    from repro.rl.env import FIGURE_EIGHT
    from repro.rl.fedrl import FedRLConfig
    from repro.sweep.runner import audit_batched_fn
    from repro.sweep.spec import SweepAxis, SweepSpec

    sched = kofm_schedule(7, 1, 3, dist="geometric", param=0.5, seed=1234)
    base = FedRLConfig(
        env=FIGURE_EIGHT,
        strategy=AsyncStrategy(tau=2, schedule=sched, backend="jnp"),
        n_epochs=1,
        epoch_len=4,
        minibatch=2,
    )
    spec = SweepSpec(
        name="audit-k",
        base=base,
        seeds=(0,),
        vmapped=(SweepAxis(name="k", values=(2.0, 5.0)),),
    )
    batched, args = audit_batched_fn(spec)
    return dispatch.HotPathEntry(fn=batched, args=args)


for _b in ("jnp", "interpret"):
    dispatch.register_hot_path(
        f"async_fed.masked_server_step[{_b}]", _audit_masked_server(_b)
    )
dispatch.register_hot_path("async_fed.delay_axis_fn", _audit_delay_axis)
dispatch.register_hot_path("async_fed.k_axis_fn", _audit_k_axis)
