"""Agent-network topologies for the consensus-based method (paper §V-D, A4).

The paper requires G strongly connected and undirected (A4). We provide the
standard families used in its experiments (random k-regular-ish graphs with
mu2 = 1.4384 / 2.5188 analogues, adjacent-chain for "Merge" with mu2 = 0.3820)
plus ring / torus / star / fully-connected, the graph Laplacian (eq. 55), its
algebraic connectivity mu2, and the consensus mixing matrix P = I - eps * La.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Topology:
    """Undirected agent graph with adjacency matrix ``adj`` (0/1, zero diag)."""

    name: str
    adj: np.ndarray  # (m, m) symmetric 0/1

    def __post_init__(self):
        a = np.asarray(self.adj)
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError("adjacency must be square")
        if not np.array_equal(a, a.T):
            raise ValueError("A4 requires an undirected graph (symmetric adj)")
        if np.any(np.diag(a) != 0):
            raise ValueError("no self loops")

    @property
    def m(self) -> int:
        return self.adj.shape[0]

    @property
    def degrees(self) -> np.ndarray:
        return self.adj.sum(axis=1)

    @property
    def max_degree(self) -> int:
        """Delta := max_i |Omega_i| + 1 per the paper's step-size bound."""
        return int(self.degrees.max()) + 1

    @property
    def n_edges(self) -> int:
        return int(self.adj.sum()) // 2

    def neighbors(self, i: int) -> np.ndarray:
        return np.nonzero(self.adj[i])[0]

    def is_connected(self) -> bool:
        m = self.m
        seen = np.zeros(m, bool)
        stack = [0]
        seen[0] = True
        while stack:
            v = stack.pop()
            for u in np.nonzero(self.adj[v])[0]:
                if not seen[u]:
                    seen[u] = True
                    stack.append(int(u))
        return bool(seen.all())


def laplacian(topo: Topology) -> np.ndarray:
    """Graph Laplacian La per eq. (55): deg on diag, -1 for edges."""
    return np.diag(topo.degrees) - topo.adj


def mu2(topo: Topology) -> float:
    """Algebraic connectivity: second-smallest eigenvalue of La."""
    eig = np.linalg.eigvalsh(laplacian(topo).astype(np.float64))
    return float(np.sort(eig)[1])


def mixing_matrix(topo: Topology, eps: float) -> np.ndarray:
    """P = I - eps * La; doubly stochastic for undirected G, rows sum to 1.

    Validity: 0 < eps < 1/Delta (paper's condition). We check and raise.
    """
    if not (0.0 < eps < 1.0 / topo.max_degree):
        raise ValueError(
            f"step size eps={eps} must be in (0, 1/Delta) = (0, {1.0 / topo.max_degree:.4f})"
        )
    return np.eye(topo.m) - eps * laplacian(topo)


def spectral_gap_factor(topo: Topology, eps: float, rounds: int) -> float:
    """The T5 contraction factor (1 - eps*mu2(La))^{2E}."""
    return float((1.0 - eps * mu2(topo)) ** (2 * rounds))


def density(topo: Topology) -> float:
    """Edge density 2|E| / (m(m-1)) in [0, 1]; the sparse-path selector input."""
    m = topo.m
    if m < 2:
        return 0.0
    return 2.0 * topo.n_edges / (m * (m - 1))


# ----------------------------------------------------------------------------
# Sparse neighbor-list representation (the O(m*k) consensus layout)
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NeighborList:
    """Padded static neighbor-index layout for the sparse gossip step.

    ``idx[i]`` holds agent i's closed neighborhood (self included) sorted
    ascending, padded out to ``k_max`` with i's *own* index; ``valid`` is
    False exactly on the padding. The gossip kernels gather ``x[idx[i, k]]``
    and weight by an ``(m, k_max)`` edge-weight table whose padding entries
    are exactly 0.0, so padded slots gather the agent's own row and
    contribute exactly nothing (adding ``0.0 * row`` is a floating-point
    no-op). Keeping valid entries ascending makes the sequential fp32
    accumulation order match a full (k_max = m) list evaluated in index
    order — the basis of the dense/sparse bitwise-parity contract
    (DESIGN.md §14).
    """

    name: str
    idx: np.ndarray      # (m, k_max) int32, ascending valid prefix, pad = own row
    valid: np.ndarray    # (m, k_max) bool, False on padding
    degrees: np.ndarray  # (m,) int32 true neighbor counts (self excluded)

    def __post_init__(self):
        idx = np.asarray(self.idx)
        valid = np.asarray(self.valid)
        deg = np.asarray(self.degrees)
        if idx.ndim != 2 or valid.shape != idx.shape:
            raise ValueError("idx/valid must be matching (m, k_max) arrays")
        m = idx.shape[0]
        if deg.shape != (m,):
            raise ValueError(f"degrees must be ({m},), got {deg.shape}")
        rows = np.arange(m)[:, None]
        if not np.all(idx[~valid] == np.broadcast_to(rows, idx.shape)[~valid]):
            raise ValueError("padding entries must gather the agent's own row")
        if np.any(valid[:, 1:] & ~valid[:, :-1]):
            raise ValueError("valid entries must form a per-row prefix")
        d = np.diff(np.where(valid, idx, idx.shape[0] + idx[:, :1]), axis=1)
        if np.any((d <= 0) & valid[:, 1:]):
            raise ValueError("valid neighbor indices must be strictly ascending")
        if not np.all(valid.sum(axis=1) == deg + 1):
            raise ValueError("valid counts must equal degree + 1 (self included)")

    @property
    def m(self) -> int:
        return self.idx.shape[0]

    @property
    def k_max(self) -> int:
        return self.idx.shape[1]

    @property
    def max_degree(self) -> int:
        """Delta := max_i |Omega_i| + 1, as on :class:`Topology`."""
        return int(self.degrees.max()) + 1


def neighbor_list(topo: Topology, k_max: int | None = None) -> NeighborList:
    """Export ``topo``'s adjacency as a padded static :class:`NeighborList`.

    ``k_max`` defaults to the tightest fit (max closed-neighborhood size);
    passing a larger value pads every row further — useful to hold k_max
    static across a topology sweep.
    """
    m = topo.m
    deg = topo.degrees.astype(np.int32)
    need = int(deg.max()) + 1
    if k_max is None:
        k_max = need
    if k_max < need:
        raise ValueError(f"k_max={k_max} < max closed neighborhood {need}")
    idx = np.tile(np.arange(m, dtype=np.int32)[:, None], (1, k_max))
    valid = np.zeros((m, k_max), bool)
    for i in range(m):
        nbrs = np.sort(np.append(np.nonzero(topo.adj[i])[0], i)).astype(np.int32)
        idx[i, : nbrs.size] = nbrs
        valid[i, : nbrs.size] = True
    return NeighborList(f"nl[{topo.name}]", idx, valid, deg)


def knn_ring_neighbors(m: int, k: int) -> NeighborList:
    """Analytic k-NN ring neighbor list — never materialises (m, m) storage.

    The 10k-agent scale path: builds the padded ``(m, k+1)`` layout directly
    (every row is full, so there is no padding) in O(m*k) memory.
    """
    if k % 2 or k < 2 or k >= m:
        raise ValueError(f"knn ring needs even k with 2 <= k < m, got k={k}, m={m}")
    half = k // 2
    offsets = np.r_[np.arange(-half, 0), 0, np.arange(1, half + 1)]
    idx = np.sort((np.arange(m)[:, None] + offsets[None, :]) % m, axis=1)
    return NeighborList(
        f"nl[knn_ring({m},k={k})]",
        idx.astype(np.int32),
        np.ones((m, k + 1), bool),
        np.full(m, k, np.int32),
    )


def mu2_knn_ring(m: int, k: int) -> float:
    """Closed-form algebraic connectivity of the k-NN ring (circulant La).

    The Laplacian eigenvalues are ``k - 2 * sum_{s=1..k/2} cos(2*pi*j*s/m)``
    for j = 0..m-1; mu2 is the smallest over j >= 1. O(m*k) — no eigensolve,
    so it works at the 10k scale where ``mu2`` (dense eigvalsh) cannot.
    """
    if k % 2 or k < 2 or k >= m:
        raise ValueError(f"knn ring needs even k with 2 <= k < m, got k={k}, m={m}")
    j = np.arange(1, m, dtype=np.float64)
    s = np.arange(1, k // 2 + 1, dtype=np.float64)
    lam = k - 2.0 * np.cos(2.0 * np.pi * np.outer(j, s) / m).sum(axis=1)
    return float(lam.min())


def neighbor_weights(nl: NeighborList, eps):
    """Traced ``(m, k_max)`` gossip weight table: ``(I - eps*La)`` gathered.

    Self slots get ``1 - eps*deg_i``, neighbor slots ``eps``, padding exactly
    ``0.0``. Computed with jnp so a traced ``eps`` (the sweep engine's eps
    axis) flows through; elementwise ops match the dense traced rebuild
    ``eye(m) - eps * La`` bit-for-bit entry-by-entry in fp32.
    """
    import jax.numpy as jnp

    idx = jnp.asarray(nl.idx)
    valid = jnp.asarray(nl.valid)
    is_self = (idx == jnp.arange(nl.m, dtype=idx.dtype)[:, None]) & valid
    deg = jnp.asarray(nl.degrees, jnp.float32)[:, None]
    eps32 = jnp.asarray(eps, jnp.float32)
    w = jnp.where(is_self, 1.0 - eps32 * deg, eps32)
    return jnp.where(valid, w, 0.0).astype(jnp.float32)


def neighbor_weights_from_matrix(nl: NeighborList, p: np.ndarray) -> np.ndarray:
    """Gather an ``(m, k_max)`` weight table out of a dense mixing matrix.

    Used by the strategy layer so the sparse path's weights are *the same
    float64 entries* as the dense ``mixing_matrix`` cast to fp32 — the
    bitwise dense/sparse parity contract needs identical weights, not just
    close ones. Padding is forced to exactly 0.0.
    """
    p = np.asarray(p)
    if p.shape != (nl.m, nl.m):
        raise ValueError(f"mixing must be ({nl.m}, {nl.m}), got {p.shape}")
    w = p[np.arange(nl.m)[:, None], nl.idx] * nl.valid
    return np.ascontiguousarray(w, dtype=np.float32)


# ----------------------------------------------------------------------------
# Graph families
# ----------------------------------------------------------------------------

def ring(m: int) -> Topology:
    if m < 3:
        raise ValueError("ring needs m >= 3")
    adj = np.zeros((m, m), int)
    for i in range(m):
        adj[i, (i + 1) % m] = adj[(i + 1) % m, i] = 1
    return Topology(f"ring({m})", adj)


def chain(m: int) -> Topology:
    """Adjacent-vehicle chain — the paper's 'Merge' topology (mu2=0.3820 at m=5)."""
    if m < 2:
        raise ValueError("chain needs m >= 2")
    adj = np.zeros((m, m), int)
    for i in range(m - 1):
        adj[i, i + 1] = adj[i + 1, i] = 1
    return Topology(f"chain({m})", adj)


def fully_connected(m: int) -> Topology:
    adj = np.ones((m, m), int) - np.eye(m, dtype=int)
    return Topology(f"full({m})", adj)


def star(m: int) -> Topology:
    adj = np.zeros((m, m), int)
    adj[0, 1:] = adj[1:, 0] = 1
    return Topology(f"star({m})", adj)


def torus2d(rows: int, cols: int) -> Topology:
    """2-D torus — matches TPU ICI mesh neighborhoods (beyond-paper topology)."""
    m = rows * cols
    adj = np.zeros((m, m), int)

    def idx(r, c):
        return (r % rows) * cols + (c % cols)

    for r in range(rows):
        for c in range(cols):
            i = idx(r, c)
            for j in (idx(r + 1, c), idx(r, c + 1)):
                if i != j:
                    adj[i, j] = adj[j, i] = 1
    return Topology(f"torus({rows}x{cols})", adj)


def knn_ring(m: int, k: int) -> Topology:
    """k-NN ring: each agent wired to its k/2 nearest on each side (k even).

    The canonical sparse family — connected for any even 2 <= k < m, constant
    degree k, and its circulant mu2 has the closed form ``mu2_knn_ring``.
    """
    if k % 2 or k < 2 or k >= m:
        raise ValueError(f"knn ring needs even k with 2 <= k < m, got k={k}, m={m}")
    adj = np.zeros((m, m), int)
    for s in range(1, k // 2 + 1):
        for i in range(m):
            j = (i + s) % m
            adj[i, j] = adj[j, i] = 1
    return Topology(f"knn_ring({m},k={k})", adj)


def _draw_connected(family: str, m: int, seed: int, draw, max_retries: int = 1000):
    """Shared bounded reseed-retry for the random families.

    ``draw(seed)`` must return a freshly drawn :class:`Topology`; disconnected
    draws bump the seed and retry (so the successful topology's name records
    the seed that actually produced it). A4 needs a connected graph — after
    ``max_retries`` failures we raise with enough context to fix the density.
    """
    first = seed
    for _attempt in range(max_retries):
        topo = draw(seed)
        if topo.is_connected():
            return topo
        seed += 1
    raise RuntimeError(
        f"{family}: no connected draw for m={m} in {max_retries} reseed "
        f"retries (seeds {first}..{seed - 1}). A4 requires a connected graph "
        f"— increase the edge density (k / p) or the retry budget."
    )


def random_regularish(m: int, k_lo: int, k_hi: int, seed: int = 0) -> Topology:
    """Random graph with each node wired to ~k in [k_lo, k_hi] others.

    Mirrors the paper's 'constructed by 3~4 (or 4~6) random connections from
    each learning agent to others' (Fig. 6). Re-draws until connected
    (bounded; see ``_draw_connected``).
    """

    def draw(s: int) -> Topology:
        rng = np.random.default_rng(s)
        adj = np.zeros((m, m), int)
        for i in range(m):
            k = int(rng.integers(k_lo, k_hi + 1))
            need = max(0, k - int(adj[i].sum()))
            cand = [j for j in range(m) if j != i and adj[i, j] == 0]
            rng.shuffle(cand)
            for j in cand[:need]:
                adj[i, j] = adj[j, i] = 1
        return Topology(f"rand{k_lo}-{k_hi}(m={m},seed={s})", adj)

    return _draw_connected(f"rand{k_lo}-{k_hi}", m, seed, draw)


def watts_strogatz(m: int, k: int, beta: float, seed: int = 0) -> Topology:
    """Small-world graph: k-NN ring with each edge rewired with prob beta.

    beta=0 is the k-NN ring (high clustering, small mu2); beta→1 approaches a
    random graph (mu2 grows at the same degree budget) — the interesting
    middle of the lambda_2 sweep axis. Re-draws until connected (large beta
    can disconnect a rewired node).
    """
    if not (0.0 <= beta <= 1.0):
        raise ValueError(f"rewiring probability beta={beta} must be in [0, 1]")
    base = knn_ring(m, k)  # validates m/k once, outside the retry loop

    def draw(s: int) -> Topology:
        rng = np.random.default_rng(s)
        adj = base.adj.copy()
        for step in range(1, k // 2 + 1):
            for i in range(m):
                j = (i + step) % m
                if adj[i, j] and rng.random() < beta:
                    cand = np.nonzero((adj[i] == 0) & (np.arange(m) != i))[0]
                    if cand.size:
                        t = int(rng.choice(cand))
                        adj[i, j] = adj[j, i] = 0
                        adj[i, t] = adj[t, i] = 1
        return Topology(f"ws({m},k={k},beta={beta:g},seed={s})", adj)

    return _draw_connected(f"ws(k={k},beta={beta:g})", m, seed, draw)


def erdos_renyi(m: int, p: float, seed: int = 0) -> Topology:
    """G(m, p): each pair wired independently with prob p.

    Re-draws until connected (bounded) — below the ln(m)/m connectivity
    threshold the retry budget runs out with a clear error rather than
    silently handing a disconnected graph to the consensus layer.
    """
    if not (0.0 < p <= 1.0):
        raise ValueError(f"edge probability p={p} must be in (0, 1]")

    def draw(s: int) -> Topology:
        rng = np.random.default_rng(s)
        upper = np.triu(rng.random((m, m)) < p, k=1).astype(int)
        return Topology(f"er({m},p={p:g},seed={s})", upper + upper.T)

    return _draw_connected(f"er(p={p:g})", m, seed, draw)


REGISTRY = {
    "ring": ring,
    "chain": chain,
    "full": fully_connected,
    "star": star,
}

# Sparse graph families for the lambda_2 (algebraic-connectivity) sweep axis:
# label -> builder(m, seed) at fixed m. Ordered roughly by increasing mu2 so
# sweep figures read left-to-right along the connectivity axis.
GRAPH_FAMILIES = {
    "chain": lambda m, seed=0: chain(m),
    "ring": lambda m, seed=0: ring(m),
    "knn4": lambda m, seed=0: knn_ring(m, 4),
    "ws4": lambda m, seed=0: watts_strogatz(m, 4, 0.3, seed),
    "knn8": lambda m, seed=0: knn_ring(m, 8),
    "er25": lambda m, seed=0: erdos_renyi(m, 0.25, seed),
    "full": lambda m, seed=0: fully_connected(m),
}
