"""Agent-network topologies for the consensus-based method (paper §V-D, A4).

The paper requires G strongly connected and undirected (A4). We provide the
standard families used in its experiments (random k-regular-ish graphs with
mu2 = 1.4384 / 2.5188 analogues, adjacent-chain for "Merge" with mu2 = 0.3820)
plus ring / torus / star / fully-connected, the graph Laplacian (eq. 55), its
algebraic connectivity mu2, and the consensus mixing matrix P = I - eps * La.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Topology:
    """Undirected agent graph with adjacency matrix ``adj`` (0/1, zero diag)."""

    name: str
    adj: np.ndarray  # (m, m) symmetric 0/1

    def __post_init__(self):
        a = np.asarray(self.adj)
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError("adjacency must be square")
        if not np.array_equal(a, a.T):
            raise ValueError("A4 requires an undirected graph (symmetric adj)")
        if np.any(np.diag(a) != 0):
            raise ValueError("no self loops")

    @property
    def m(self) -> int:
        return self.adj.shape[0]

    @property
    def degrees(self) -> np.ndarray:
        return self.adj.sum(axis=1)

    @property
    def max_degree(self) -> int:
        """Delta := max_i |Omega_i| + 1 per the paper's step-size bound."""
        return int(self.degrees.max()) + 1

    @property
    def n_edges(self) -> int:
        return int(self.adj.sum()) // 2

    def neighbors(self, i: int) -> np.ndarray:
        return np.nonzero(self.adj[i])[0]

    def is_connected(self) -> bool:
        m = self.m
        seen = np.zeros(m, bool)
        stack = [0]
        seen[0] = True
        while stack:
            v = stack.pop()
            for u in np.nonzero(self.adj[v])[0]:
                if not seen[u]:
                    seen[u] = True
                    stack.append(int(u))
        return bool(seen.all())


def laplacian(topo: Topology) -> np.ndarray:
    """Graph Laplacian La per eq. (55): deg on diag, -1 for edges."""
    return np.diag(topo.degrees) - topo.adj


def mu2(topo: Topology) -> float:
    """Algebraic connectivity: second-smallest eigenvalue of La."""
    eig = np.linalg.eigvalsh(laplacian(topo).astype(np.float64))
    return float(np.sort(eig)[1])


def mixing_matrix(topo: Topology, eps: float) -> np.ndarray:
    """P = I - eps * La; doubly stochastic for undirected G, rows sum to 1.

    Validity: 0 < eps < 1/Delta (paper's condition). We check and raise.
    """
    if not (0.0 < eps < 1.0 / topo.max_degree):
        raise ValueError(
            f"step size eps={eps} must be in (0, 1/Delta) = (0, {1.0 / topo.max_degree:.4f})"
        )
    return np.eye(topo.m) - eps * laplacian(topo)


def spectral_gap_factor(topo: Topology, eps: float, rounds: int) -> float:
    """The T5 contraction factor (1 - eps*mu2(La))^{2E}."""
    return float((1.0 - eps * mu2(topo)) ** (2 * rounds))


# ----------------------------------------------------------------------------
# Graph families
# ----------------------------------------------------------------------------

def ring(m: int) -> Topology:
    if m < 3:
        raise ValueError("ring needs m >= 3")
    adj = np.zeros((m, m), int)
    for i in range(m):
        adj[i, (i + 1) % m] = adj[(i + 1) % m, i] = 1
    return Topology(f"ring({m})", adj)


def chain(m: int) -> Topology:
    """Adjacent-vehicle chain — the paper's 'Merge' topology (mu2=0.3820 at m=5)."""
    if m < 2:
        raise ValueError("chain needs m >= 2")
    adj = np.zeros((m, m), int)
    for i in range(m - 1):
        adj[i, i + 1] = adj[i + 1, i] = 1
    return Topology(f"chain({m})", adj)


def fully_connected(m: int) -> Topology:
    adj = np.ones((m, m), int) - np.eye(m, dtype=int)
    return Topology(f"full({m})", adj)


def star(m: int) -> Topology:
    adj = np.zeros((m, m), int)
    adj[0, 1:] = adj[1:, 0] = 1
    return Topology(f"star({m})", adj)


def torus2d(rows: int, cols: int) -> Topology:
    """2-D torus — matches TPU ICI mesh neighborhoods (beyond-paper topology)."""
    m = rows * cols
    adj = np.zeros((m, m), int)

    def idx(r, c):
        return (r % rows) * cols + (c % cols)

    for r in range(rows):
        for c in range(cols):
            i = idx(r, c)
            for j in (idx(r + 1, c), idx(r, c + 1)):
                if i != j:
                    adj[i, j] = adj[j, i] = 1
    return Topology(f"torus({rows}x{cols})", adj)


def random_regularish(m: int, k_lo: int, k_hi: int, seed: int = 0) -> Topology:
    """Random graph with each node wired to ~k in [k_lo, k_hi] others.

    Mirrors the paper's 'constructed by 3~4 (or 4~6) random connections from
    each learning agent to others' (Fig. 6). Re-draws until connected.
    """
    rng = np.random.default_rng(seed)
    for _attempt in range(1000):
        adj = np.zeros((m, m), int)
        for i in range(m):
            k = int(rng.integers(k_lo, k_hi + 1))
            need = max(0, k - int(adj[i].sum()))
            cand = [j for j in range(m) if j != i and adj[i, j] == 0]
            rng.shuffle(cand)
            for j in cand[:need]:
                adj[i, j] = adj[j, i] = 1
        topo = Topology(f"rand{k_lo}-{k_hi}(m={m},seed={seed})", adj)
        if topo.is_connected():
            return topo
        seed += 1
        rng = np.random.default_rng(seed)
    raise RuntimeError("failed to draw a connected graph")


REGISTRY = {
    "ring": ring,
    "chain": chain,
    "full": fully_connected,
    "star": star,
}
