"""Beyond-paper aggregation strategies engaging the paper's own roadmap.

1. HierarchicalStrategy — the paper's FUTURE-WORK section verbatim: "multiple
   virtual central agents ... their organization tends to be hierarchical".
   Agents are partitioned into clusters; clusters average locally every
   tau_local periods (cheap intra-cluster link, cost W1-like), and the global
   virtual agent averages cluster means every tau_global (expensive C1 link).
   On the TPU mapping: cluster = pod, global = DCN.

2. QuantizedSyncStrategy — the related-work axis the paper contrasts against
   (QSGD/signSGD, refs [25]-[31]): uniform int8 quantization of the synced
   deltas WITH error feedback, so the utility function (eq. 13) can compare
   "send less often" (the paper) vs "send smaller" (compression) vs both.

3. ElasticAveragingStrategy — EASGD [52], whose convergence the paper calls
   an open question; agents are pulled toward the anchor elastically instead
   of hard-reset to the mean. Empirical bench rows let us *measure* what the
   paper could not bound.

All three compose with the variation masks (A2) exactly like the built-ins.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategies import AggregationStrategy
from repro.core.variation import validate_a2


@dataclasses.dataclass(frozen=True)
class HierarchicalStrategy(AggregationStrategy):
    """Two-level periodic averaging. Period structure (in local updates):
    every tau -> intra-cluster average; every tau * global_every -> global.

    The driver calls server_average at every tau boundary as usual; this
    strategy keeps a period counter in the params pytree? No — the drivers
    are functional, so the level is derived from the step count embedded in
    the schedule: server_average_level(k) picks the level.
    """

    clusters: tuple = ()          # tuple of tuples of agent indices
    global_every: int = 2         # global sync every this many periods

    def __init__(self, tau: int, clusters, global_every: int = 2,
                 taus=None, m=None):
        m = m if m is not None else sum(len(c) for c in clusters)
        if taus is None:
            taus = np.full(m, tau, int)
        taus = np.asarray(taus, int)
        validate_a2(taus, tau)
        object.__setattr__(self, "clusters", tuple(tuple(c) for c in clusters))
        object.__setattr__(self, "global_every", int(global_every))
        ids = sorted(i for c in clusters for i in c)
        if ids != list(range(m)):
            raise ValueError("clusters must partition agents 0..m-1")
        AggregationStrategy.__init__(
            self, name=f"hierarchical(tau={tau},g={global_every})", tau=tau,
            taus=taus, mask=self._build_mask(taus, tau),
        )

    def _cluster_mean_matrix(self) -> np.ndarray:
        p = np.zeros((self.m, self.m))
        for c in self.clusters:
            for i in c:
                p[i, list(c)] = 1.0 / len(c)
        return p

    def server_average(self, params_m, period_idx=None):
        """Cluster-mean by default; full mean on global periods."""
        if period_idx is None:
            return AggregationStrategy.server_average(self, params_m)
        p_local = jnp.asarray(self._cluster_mean_matrix(), jnp.float32)

        def local_avg(t):
            return jax.tree.map(lambda l: jnp.tensordot(p_local, l, axes=1)
                                .astype(l.dtype), t)

        is_global = jnp.equal(jnp.mod(period_idx + 1, self.global_every), 0)
        return jax.lax.cond(
            is_global,
            lambda t: jax.tree.map(
                lambda l: jnp.broadcast_to(jnp.mean(l, 0, keepdims=True),
                                           l.shape).astype(l.dtype), t),
            local_avg,
            params_m,
        )

    def comm_events_per_period(self) -> dict:
        base = AggregationStrategy.comm_events_per_period(self)
        # global upload (C1) only every global_every periods; local cluster
        # exchange billed like gossip (W1) the rest of the time.
        base["c1"] = self.m // self.global_every
        base["w1"] = self.m - base["c1"]
        base["w2"] = base["w1"]
        return base


def _quantize_int8(x, axis=None):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


@dataclasses.dataclass(frozen=True)
class QuantizedSyncStrategy(AggregationStrategy):
    """Periodic averaging whose *synced quantity* is int8-quantized with
    error feedback: each agent keeps the quantization residual and adds it
    back next period (EF-SGD), so compression error doesn't accumulate.

    transform() is the identity (local updates untouched); the quantization
    lives in server_average — matching where the bytes cross the wire.
    comm accounting: C1 events count 1/4 (8-bit vs 32-bit payload).
    """

    bits: int = 8

    def __init__(self, tau: int, taus=None, m=None, bits: int = 8):
        if taus is None:
            if m is None:
                raise ValueError("need taus or m")
            taus = np.full(m, tau, int)
        taus = np.asarray(taus, int)
        validate_a2(taus, tau)
        object.__setattr__(self, "bits", bits)
        AggregationStrategy.__init__(
            self, name=f"quantized(tau={tau},b={bits})", tau=tau, taus=taus,
            mask=self._build_mask(taus, tau),
        )

    def server_average(self, params_m, anchor=None, errors=None):
        """Quantize per-agent deltas from the anchor, average the dequantized
        deltas. Returns (new_params_m, new_errors) when anchor given."""
        if anchor is None:
            return AggregationStrategy.server_average(self, params_m)

        def leaf(pm, a, e):
            delta = pm.astype(jnp.float32) - a.astype(jnp.float32)[None] + e
            q, scale = jax.vmap(_quantize_int8)(delta.reshape(pm.shape[0], -1))
            deq = (q.astype(jnp.float32) * scale[:, None]).reshape(delta.shape)
            new_e = delta - deq
            avg = a.astype(jnp.float32) + jnp.mean(deq, axis=0)
            return jnp.broadcast_to(avg, pm.shape).astype(pm.dtype), new_e

        flat_p, treedef = jax.tree.flatten(params_m)
        flat_a = jax.tree.leaves(anchor)
        flat_e = jax.tree.leaves(errors)
        outs = [leaf(p, a, e) for p, a, e in zip(flat_p, flat_a, flat_e)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_e = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return new_p, new_e

    def comm_events_per_period(self) -> dict:
        base = AggregationStrategy.comm_events_per_period(self)
        base["c1_bytes_factor"] = self.bits / 32.0
        return base


@dataclasses.dataclass(frozen=True)
class ElasticAveragingStrategy(AggregationStrategy):
    """EASGD [52]: x_i <- x_i - alpha (x_i - x_anchor); anchor moves toward
    the agent mean. The paper notes its bound is an open question — we
    measure it empirically instead (benchmarks)."""

    alpha: float = 0.5

    def __init__(self, tau: int, taus=None, m=None, alpha: float = 0.5):
        if taus is None:
            if m is None:
                raise ValueError("need taus or m")
            taus = np.full(m, tau, int)
        taus = np.asarray(taus, int)
        validate_a2(taus, tau)
        object.__setattr__(self, "alpha", float(alpha))
        AggregationStrategy.__init__(
            self, name=f"elastic(tau={tau},a={alpha})", tau=tau, taus=taus,
            mask=self._build_mask(taus, tau),
        )

    def server_average(self, params_m, anchor=None):
        """Without anchor: plain mean (degenerate). With anchor: elastic pull;
        returns (new_params_m, new_anchor)."""
        if anchor is None:
            return AggregationStrategy.server_average(self, params_m)
        a = self.alpha

        def pull(pm, anc):
            pm32 = pm.astype(jnp.float32)
            anc32 = anc.astype(jnp.float32)
            new_pm = pm32 - a * (pm32 - anc32[None])
            new_anc = anc32 + a * jnp.mean(pm32 - anc32[None], axis=0)
            return new_pm.astype(pm.dtype), new_anc.astype(anc.dtype)

        flat_p, treedef = jax.tree.flatten(params_m)
        flat_a, treedef_a = jax.tree.flatten(anchor)
        outs = [pull(p, anc) for p, anc in zip(flat_p, flat_a)]
        return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
                jax.tree.unflatten(treedef_a, [o[1] for o in outs]))
