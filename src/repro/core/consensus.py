"""Consensus gossip operators (paper Alg. 2, eq. 23).

Two realizations of the same math g <- (I - eps*La) g applied E times:

* ``consensus_rounds_dense`` — exact dense mixing over a leading replica axis
  (used by the host-level FMARL driver where all m agents live on one device
  as vmapped replicas). This is the paper-faithful reference.
* ``consensus_rounds_matrix`` — same, expressed as an einsum with a
  precomputed mixing matrix P^E (one fused matmul instead of E rounds);
  a beyond-paper optimization exploiting P being constant within a period.

The mesh-scale (shard_map + collective_permute) form lives in
``repro.launch.fedtrain`` because it needs a mesh axis; the Pallas-fused
single-buffer update is ``repro.kernels.consensus_step``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import Topology, mixing_matrix


def _mix_leaf(p: jnp.ndarray, leaf: jnp.ndarray) -> jnp.ndarray:
    """Apply (m, m) mixing matrix over the leading replica axis of ``leaf``."""
    flat = leaf.reshape(leaf.shape[0], -1)
    return (p @ flat).reshape(leaf.shape)


def consensus_rounds_dense(grads, topo: Topology, eps: float, rounds: int):
    """E explicit gossip rounds of eq. (23) on a replicated pytree.

    ``grads``: pytree whose leaves have leading axis m (one slice per agent).
    Returns the pytree after E rounds; each round is
    g_i += eps * sum_{l in Omega_i} (g_l - g_i), i.e. g <- (I - eps*La) g.
    """
    p = jnp.asarray(mixing_matrix(topo, eps), jnp.float32)

    def one_round(g, _):
        return jax.tree.map(lambda leaf: _mix_leaf(p, leaf), g), None

    out, _ = jax.lax.scan(one_round, grads, None, length=rounds)
    return out


def consensus_rounds_matrix(grads, topo: Topology, eps: float, rounds: int):
    """Fused form: apply P^E once. Mathematically identical to E rounds."""
    p = np.linalg.matrix_power(mixing_matrix(topo, eps), rounds)
    p = jnp.asarray(p, jnp.float32)
    return jax.tree.map(lambda leaf: _mix_leaf(p, leaf), grads)


def disagreement(grads) -> jnp.ndarray:
    """Frobenius disagreement ||G (I - J)||_F^2 across the replica axis.

    This is the quantity the T5 proof contracts by (1 - eps*mu2)^{2E}; used in
    tests to verify the contraction rate empirically.
    """
    def leaf_dis(leaf):
        mean = jnp.mean(leaf, axis=0, keepdims=True)
        return jnp.sum(jnp.square(leaf - mean))

    leaves = [leaf_dis(l) for l in jax.tree.leaves(grads)]
    return jnp.sum(jnp.stack(leaves))
