"""FMARL training driver (paper Algorithms 1 & 2), host-level replica form.

All m agents live as a leading replica axis on pytrees; local rollouts /
gradient computations are vmapped; the strategy supplies the per-step mask,
decay weighting or consensus gossip; the virtual server performs the periodic
averaging of eq. (11) at period boundaries.

The driver is task-generic: ``local_grad_fn(params_i, key, agent_idx, step)``
returns (grads_i, aux_i). RL tasks (repro.rl) wrap a rollout + policy-gradient
loss into this signature; supervised tasks wrap a mini-batch loss.

The full run is a single jitted lax.scan over periods (inner scan over the
tau offsets), so even the paper-scale experiment (U=500 epochs) runs in
seconds on CPU for MLP policies.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accounting import CostLedger
from repro.core.strategies import AggregationStrategy
from repro.utils.pytree import tree_l2_norm


class FmarlState(NamedTuple):
    params_m: Any          # pytree, leading axis m (per-agent replicas)
    server_params: Any     # pytree, the virtual agent's averaged model
    step: jnp.ndarray      # global iteration counter k
    key: jax.Array


@dataclasses.dataclass(frozen=True)
class FmarlConfig:
    strategy: AggregationStrategy
    eta: float
    n_periods: int
    eval_every: int = 1          # evaluate server grad-norm every this many periods


def _broadcast(server_params, m: int):
    return jax.tree.map(
        lambda leaf: jnp.broadcast_to(leaf, (m,) + leaf.shape), server_params
    )


def run_fmarl(
    cfg: FmarlConfig,
    init_params,
    local_grad_fn: Callable,
    key: jax.Array,
    eval_grad_fn: Optional[Callable] = None,
):
    """Run Algorithm 1 (or 2, if the strategy gossips) for cfg.n_periods periods.

    Returns (final FmarlState, metrics dict of stacked per-period arrays,
    CostLedger).
    """
    strat = cfg.strategy
    m, tau = strat.m, strat.tau
    params_m = _broadcast(init_params, m)
    state = FmarlState(
        params_m=params_m,
        server_params=init_params,
        step=jnp.zeros((), jnp.int32),
        key=key,
    )

    agent_ids = jnp.arange(m)

    def local_step(carry, offset):
        params_m, step, key = carry
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, m)
        grads_m, aux = jax.vmap(
            lambda p, k, i: local_grad_fn(p, k, i, step)
        )(params_m, keys, agent_ids)
        # Transform + SGD; on kernel backends this runs the fused
        # decay_accum_pallas / consensus_step_pallas flat path.
        params_m = strat.local_update(params_m, grads_m, offset, cfg.eta)
        return (params_m, step + 1, key), aux

    def period(state: FmarlState, _):
        (params_m, step, key), aux = jax.lax.scan(
            local_step,
            (state.params_m, state.step, state.key),
            jnp.arange(tau),
        )
        server = strat.server_average(params_m)
        params_m = _broadcast(server, m)

        metrics = {"mean_aux": jax.tree.map(jnp.mean, aux)}
        if eval_grad_fn is not None:
            key, sub = jax.random.split(key)
            g = eval_grad_fn(server, sub)
            metrics["server_grad_sq_norm"] = tree_l2_norm(g) ** 2
        new_state = FmarlState(params_m, server, step, key)
        return new_state, metrics

    final_state, metrics = jax.lax.scan(period, state, None, length=cfg.n_periods)

    ledger = CostLedger()
    ledger.add_periods(strat, cfg.n_periods)
    return final_state, metrics, ledger


def expected_gradient_norm(metrics) -> float:
    """Table II metric: mean of ||grad F(theta_bar_k)||^2 over the run."""
    vals = np.asarray(metrics["server_grad_sq_norm"])
    return float(vals.mean())
