"""FMARL training driver (paper Algorithms 1 & 2), host-level replica form.

All m agents live as a leading replica axis on pytrees; local rollouts /
gradient computations are vmapped; the strategy supplies the per-step mask,
decay weighting or consensus gossip; the virtual server performs the periodic
averaging of eq. (11) at period boundaries.

The driver is task-generic: ``local_grad_fn(params_i, key, agent_idx, step)``
returns (grads_i, aux_i). RL tasks (repro.rl) wrap a rollout + policy-gradient
loss into this signature; supervised tasks wrap a mini-batch loss.

The full run is a single jitted lax.scan over periods (inner scan over the
tau offsets), so even the paper-scale experiment (U=500 epochs) runs in
seconds on CPU for MLP policies.

Two carry layouts:

  * jnp backend, plain SGD — the original tree-space reference path,
    bit-for-bit unchanged.
  * kernel backends (pallas/interpret), or any run with ``cfg.optimizer``
    set — the **flat carry**: params are raveled to one ``(m, n)`` matrix at
    run start and stay flat across both scans. Each local step unravels a
    cached per-agent *view* for the user's grad closure and ravels only the
    returned grads; the transform + optimizer update and the server
    averaging (``row_mean``) all run on the flat buffers through the
    dispatch layer. No per-step params ravel/unravel round-trip survives in
    the scan body — the win PR 1 left on the table.

Both layouts read the strategy's per-step weights through ``jnp.asarray``
in the scan bodies, so ``with_mask`` strategy copies with *traced* variation
masks (the sweep engine's ``taus`` axis) flow through as operands — the mask
batches to ``(S, m, tau)`` under the sweep's vmap while tau itself stays the
static inner scan length (DESIGN.md §11).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accounting import CostLedger
from repro.core.strategies import AggregationStrategy
from repro.kernels import dispatch
from repro.optim.flat import FlatOptimizer, server_average_state
from repro.utils.pytree import tree_l2_norm


class FmarlState(NamedTuple):
    params_m: Any          # pytree, leading axis m (per-agent replicas)
    server_params: Any     # pytree, the virtual agent's averaged model
    step: jnp.ndarray      # global iteration counter k
    key: jax.Array


@dataclasses.dataclass(frozen=True)
class FmarlConfig:
    strategy: AggregationStrategy
    eta: float
    n_periods: int
    eval_every: int = 1          # evaluate server grad-norm every this many periods
    optimizer: Optional[FlatOptimizer] = None  # None = plain SGD (reference)
    # storage dtype of the flat params/grad buffers (None = fp32); e.g.
    # "bfloat16" halves carry bandwidth — dispatch primitives and optimizer
    # moments still accumulate in fp32, closures see an fp32 tree view.
    buffer_dtype: Optional[str] = None

    def __post_init__(self):
        if self.buffer_dtype is not None:
            jnp.dtype(self.buffer_dtype)  # fail fast on typos


def _broadcast(server_params, m: int):
    return jax.tree.map(
        lambda leaf: jnp.broadcast_to(leaf, (m,) + leaf.shape), server_params
    )


def _use_flat_carry(cfg) -> bool:
    """Flat (m, n) carry on kernel backends and whenever an optimizer, a
    non-default buffer dtype, or a compressed payload transform is set (the
    fused optimizer updates, the bf16 storage mode, and the comm layer's
    error-feedback state only exist on flat buffers — the jnp backend then
    runs the fp32 flat reference ops)."""
    return (
        dispatch.is_kernel_backend(cfg.strategy.backend)
        or cfg.optimizer is not None
        or cfg.buffer_dtype is not None
        or cfg.strategy.comm.enabled
        or cfg.strategy.is_async
    )


def run_fmarl(
    cfg: FmarlConfig,
    init_params,
    local_grad_fn: Callable,
    key: jax.Array,
    eval_grad_fn: Optional[Callable] = None,
):
    """Run Algorithm 1 (or 2, if the strategy gossips) for cfg.n_periods periods.

    Returns (final FmarlState, metrics dict of stacked per-period arrays,
    CostLedger).
    """
    state, metrics = run_fmarl_core(
        cfg, init_params, local_grad_fn, key, eval_grad_fn
    )
    payload_elems = int(
        sum(np.prod(np.shape(l)) for l in jax.tree.leaves(init_params))
    )
    ledger = CostLedger()
    ledger.add_periods(cfg.strategy, cfg.n_periods, payload_elems)
    return state, metrics, ledger


def run_fmarl_core(
    cfg: FmarlConfig,
    init_params,
    local_grad_fn: Callable,
    key: jax.Array,
    eval_grad_fn: Optional[Callable] = None,
):
    """Traced core of :func:`run_fmarl`: ``(FmarlState, metrics)`` only.

    Pure function of its arguments with no host transfers — safe under
    ``jax.jit`` / ``jax.vmap`` (the sweep engine maps it over a seed axis).
    The CostLedger is host-side accounting and lives in the wrapper.
    """
    if _use_flat_carry(cfg):
        return _run_fmarl_flat(cfg, init_params, local_grad_fn, key, eval_grad_fn)
    return _run_fmarl_tree(cfg, init_params, local_grad_fn, key, eval_grad_fn)


def _run_fmarl_tree(cfg, init_params, local_grad_fn, key, eval_grad_fn):
    """Pure-jnp tree-space reference path (bit-identical to the original)."""
    strat = cfg.strategy
    m, tau = strat.m, strat.tau
    params_m = _broadcast(init_params, m)
    state = FmarlState(
        params_m=params_m,
        server_params=init_params,
        step=jnp.zeros((), jnp.int32),
        key=key,
    )

    agent_ids = jnp.arange(m)

    def local_step(carry, offset):
        params_m, step, key = carry
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, m)
        grads_m, aux = jax.vmap(
            lambda p, k, i: local_grad_fn(p, k, i, step)
        )(params_m, keys, agent_ids)
        params_m = strat.local_update(params_m, grads_m, offset, cfg.eta)
        return (params_m, step + 1, key), aux

    def period(state: FmarlState, _):
        (params_m, step, key), aux = jax.lax.scan(
            local_step,
            (state.params_m, state.step, state.key),
            jnp.arange(tau),
        )
        server = strat.server_average(params_m)
        params_m = _broadcast(server, m)

        metrics = {"mean_aux": jax.tree.map(jnp.mean, aux)}
        if eval_grad_fn is not None:
            key, sub = jax.random.split(key)
            g = eval_grad_fn(server, sub)
            metrics["server_grad_sq_norm"] = tree_l2_norm(g) ** 2
        new_state = FmarlState(params_m, server, step, key)
        return new_state, metrics

    final_state, metrics = jax.lax.scan(period, state, None, length=cfg.n_periods)
    return final_state, metrics


def _run_fmarl_flat(cfg, init_params, local_grad_fn, key, eval_grad_fn):
    """Flat-carry path: the scan state is one (m, n) matrix (+ fp32 opt
    accumulators); trees only materialise as the per-agent closure view and
    at period-boundary evals."""
    strat = cfg.strategy
    m, tau = strat.m, strat.tau
    if strat.is_async:
        strat.validate_horizon(cfg.n_periods)
    opt = cfg.optimizer
    dtype = jnp.dtype(cfg.buffer_dtype) if cfg.buffer_dtype is not None else None
    flat, spec = dispatch.stacked_ravel_spec(_broadcast(init_params, m))
    if dtype is not None:
        flat = flat.astype(dtype)
    opt_state = opt.init(flat) if opt is not None else {}
    comm_state = strat.init_comm_state(flat)
    agent_ids = jnp.arange(m)

    def view_one(row):
        """fp32 per-agent tree view of one flat carry row."""
        return spec.unravel_one(dispatch.compute_view(row, dtype))

    def local_step(carry, offset):
        flat, opt_state, comm_state, step, key = carry
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, m)

        def one(row, k, i):
            g_tree, aux = local_grad_fn(view_one(row), k, i, step)
            return spec.ravel_one(g_tree), aux

        g_flat, aux = jax.vmap(one)(flat, keys, agent_ids)
        if dtype is not None:
            g_flat = g_flat.astype(dtype)
        flat, opt_state, comm_state = strat.flat_local_step(
            flat, g_flat, offset, cfg.eta, opt, opt_state, comm_state
        )
        return (flat, opt_state, comm_state, step + 1, key), aux

    def period(carry, p):
        (flat, opt_state, comm_state, step, key), aux = jax.lax.scan(
            local_step, carry, jnp.arange(tau)
        )
        flat, comm_state = strat.flat_sync(flat, comm_state, period=p)
        # Sync strategies re-broadcast (row 0 is the server row); the async
        # path keeps non-arrived replicas divergent and reads the buffered
        # reference out of comm_state instead.
        row = strat.server_row(flat, comm_state)
        if opt is not None and not strat.is_async:
            # Async boundaries sync only the arrived subset; moments stay
            # local (FedBuff keeps no server momentum).
            opt_state = server_average_state(strat, opt_state)

        metrics = {"mean_aux": jax.tree.map(jnp.mean, aux)}
        if eval_grad_fn is not None:
            key, sub = jax.random.split(key)
            g = eval_grad_fn(view_one(row), sub)
            metrics["server_grad_sq_norm"] = tree_l2_norm(g) ** 2
        return (flat, opt_state, comm_state, step, key), metrics

    carry = (flat, opt_state, comm_state, jnp.zeros((), jnp.int32), key)
    (flat, opt_state, comm_state, step, key), metrics = jax.lax.scan(
        period, carry, jnp.arange(cfg.n_periods)
    )

    flat32 = dispatch.compute_view(flat, dtype)
    server_row = dispatch.compute_view(
        strat.server_row(flat, comm_state), dtype
    )
    final_state = FmarlState(
        params_m=spec.unravel(flat32),
        server_params=spec.unravel_one(server_row),
        step=step,
        key=key,
    )
    return final_state, metrics


def expected_gradient_norm(metrics) -> float:
    """Table II metric: mean of ||grad F(theta_bar_k)||^2 over the run."""
    vals = np.asarray(metrics["server_grad_sq_norm"])
    return float(vals.mean())


# --- trace-safety audit registration (repro.analysis.jaxpr_audit) -------------

def _audit_hot_path() -> dispatch.HotPathEntry:
    """Toy ``run_fmarl_core`` entry for the jaxpr audit.

    A noisy-quadratic ``local_grad_fn`` over a two-leaf pytree with a decay
    strategy: both scans, the strategy's masked update, the server average,
    and the eval branch all land in the jaxpr with tiny trip counts. The
    grad closure follows the per-leaf key discipline (one ``fold_in`` per
    leaf) that RPR001 enforces in user code.
    """
    from repro.core.strategies import make_strategy

    cfg = FmarlConfig(
        strategy=make_strategy("decay", tau=2, m=4, backend="jnp"),
        eta=0.05,
        n_periods=2,
    )

    def local_grad_fn(params, key, agent_idx, step):
        leaves = jax.tree.leaves(params)
        noisy = [
            leaf + 0.1 * jax.random.normal(jax.random.fold_in(key, j),
                                           leaf.shape)
            for j, leaf in enumerate(leaves)
        ]
        g = jax.tree.unflatten(jax.tree.structure(params), noisy)
        return g, {"loss": tree_l2_norm(params) ** 2}

    def eval_grad_fn(params, key):
        return params  # grad of the quadratic at its minimum shift

    def fn(seed):
        init = {"w": jnp.zeros((8,)), "b": jnp.zeros((2,))}
        _, metrics = run_fmarl_core(
            cfg, init, local_grad_fn, jax.random.key(seed), eval_grad_fn
        )
        return metrics

    return dispatch.HotPathEntry(
        fn=fn, args=(jax.ShapeDtypeStruct((), jnp.int32),)
    )


dispatch.register_hot_path("core.run_fmarl_core", _audit_hot_path)
