"""Variation-aware local-update schedules (paper §IV, eq. 6; A2).

Agents spend heterogeneous wall-clock time per step; agent i performs
tau_i = floor(tau * E[x_1] / E[x_i]) local updates in a period. On a
synchronous TPU mesh we *simulate* this with per-agent indicator masks
I(tau_i > s - t0) that zero the gradient contributions of agents which have
already exhausted their budget for the period — exactly the accumulation the
paper analyzes in eqs. (11)/(16).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def tau_schedule(tau: int, mean_times: np.ndarray) -> np.ndarray:
    """Eq. (6): tau_i = floor(tau * E[x_1] / E[x_i]) with E[x] sorted ascending."""
    t = np.asarray(mean_times, np.float64)
    if np.any(t <= 0):
        raise ValueError("mean step times must be positive")
    if np.any(np.diff(t) < 0):
        raise ValueError("paper orders agents by E[x_1] <= ... <= E[x_N]")
    # epsilon guards fp rounding: floor(7 * 0.1/0.1) must be 7, not 6
    taus = np.floor(tau * t[0] / t + 1e-9).astype(int)
    return np.maximum(taus, 1)  # tau_i in N^+ (A2.1 lower end)


def uniform_taus(tau_lo: int, tau_hi: int, m: int, seed: int = 0) -> np.ndarray:
    """The paper's 'tau = a~b' notation: tau_i ~ Uniform{a..b}, tau_1 = b.

    A2.3 requires at least one agent with tau_i = tau (the pacing agent), so we
    pin agent 0 to tau_hi and sort descending per A2.2.
    """
    rng = np.random.default_rng(seed)
    taus = rng.integers(tau_lo, tau_hi + 1, size=m)
    taus[0] = tau_hi
    return np.sort(taus)[::-1].copy()


def tau_stats(taus: np.ndarray) -> tuple[float, float]:
    """(nu, omega^2): mean and variance of {tau_i} (A2.4/A2.5)."""
    taus = np.asarray(taus, np.float64)
    return float(taus.mean()), float(taus.var())


def indicator_mask(taus, period_offsets) -> jnp.ndarray:
    """I(tau_i > s - t0) as an (m, len(offsets)) float mask.

    ``taus`` may be a concrete array *or* a tracer (the sweep engine's
    ``taus`` axis hands in a traced (m,) vector): the comparison lowers to
    elementwise jnp ops, so at fixed period length the mask is shape-stable
    and the whole variation axis vmaps.
    """
    taus = jnp.asarray(taus)[:, None]
    offs = jnp.asarray(period_offsets)[None, :]
    return (taus > offs).astype(jnp.float32)


def mask_from_taus(taus, tau: int) -> jnp.ndarray:
    """The strategy-shaped (m, tau) variation mask from a tau_i vector.

    Traced-safe counterpart of ``AggregationStrategy._build_mask`` (the
    static numpy constructor): ``tau`` is the static period length (fixes the
    mask shape and the inner scan length), ``taus`` may be traced. Integer
    schedules carried as float32 stay exact (tau_i <= tau << 2**24), so the
    traced mask is value-identical to the static one.
    """
    return indicator_mask(taus, jnp.arange(tau))


def masked_update_counts(taus, n_offsets: int) -> np.ndarray:
    """Per-agent local-update counts within the first ``n_offsets`` offsets.

    ``sum_j I(tau_i > j) for j < n_offsets  ==  min(tau_i, n_offsets)`` —
    the closed form the comm accounting uses (C2 events), equal to summing
    the corresponding mask columns. ``n_offsets = tau`` gives the full-period
    counts, i.e. ``sum(taus)`` in total.
    """
    return np.minimum(np.asarray(taus), int(n_offsets))


def validate_a2(taus: np.ndarray, tau: int) -> None:
    """Assert the A2 conditions; raises ValueError on violation."""
    taus = np.asarray(taus)
    if np.any((taus < 1) | (taus > tau)):
        raise ValueError("A2.1: tau_i in {1..tau}")
    if np.any(np.diff(taus) > 0):
        raise ValueError("A2.2: tau_i sorted non-increasing")
    if not np.any(taus == tau):
        raise ValueError("A2.3: at least one agent with tau_i = tau")
