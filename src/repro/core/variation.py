"""Variation-aware local-update schedules (paper §IV, eq. 6; A2).

Agents spend heterogeneous wall-clock time per step; agent i performs
tau_i = floor(tau * E[x_1] / E[x_i]) local updates in a period. On a
synchronous TPU mesh we *simulate* this with per-agent indicator masks
I(tau_i > s - t0) that zero the gradient contributions of agents which have
already exhausted their budget for the period — exactly the accumulation the
paper analyzes in eqs. (11)/(16).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def tau_schedule(tau: int, mean_times: np.ndarray) -> np.ndarray:
    """Eq. (6): tau_i = floor(tau * E[x_1] / E[x_i]) with E[x] sorted ascending."""
    t = np.asarray(mean_times, np.float64)
    if np.any(t <= 0):
        raise ValueError("mean step times must be positive")
    if np.any(np.diff(t) < 0):
        raise ValueError("paper orders agents by E[x_1] <= ... <= E[x_N]")
    # epsilon guards fp rounding: floor(7 * 0.1/0.1) must be 7, not 6
    taus = np.floor(tau * t[0] / t + 1e-9).astype(int)
    return np.maximum(taus, 1)  # tau_i in N^+ (A2.1 lower end)


def uniform_taus(tau_lo: int, tau_hi: int, m: int, seed: int = 0) -> np.ndarray:
    """The paper's 'tau = a~b' notation: tau_i ~ Uniform{a..b}, tau_1 = b.

    A2.3 requires at least one agent with tau_i = tau (the pacing agent), so we
    pin agent 0 to tau_hi and sort descending per A2.2.
    """
    rng = np.random.default_rng(seed)
    taus = rng.integers(tau_lo, tau_hi + 1, size=m)
    taus[0] = tau_hi
    return np.sort(taus)[::-1].copy()


def tau_stats(taus: np.ndarray) -> tuple[float, float]:
    """(nu, omega^2): mean and variance of {tau_i} (A2.4/A2.5)."""
    taus = np.asarray(taus, np.float64)
    return float(taus.mean()), float(taus.var())


def indicator_mask(taus, period_offsets) -> jnp.ndarray:
    """I(tau_i > s - t0) as an (m, len(offsets)) float mask."""
    taus = jnp.asarray(taus)[:, None]
    offs = jnp.asarray(period_offsets)[None, :]
    return (taus > offs).astype(jnp.float32)


def validate_a2(taus: np.ndarray, tau: int) -> None:
    """Assert the A2 conditions; raises ValueError on violation."""
    taus = np.asarray(taus)
    if np.any((taus < 1) | (taus > tau)):
        raise ValueError("A2.1: tau_i in {1..tau}")
    if np.any(np.diff(taus) > 0):
        raise ValueError("A2.2: tau_i sorted non-increasing")
    if not np.any(taus == tau):
        raise ValueError("A2.3: at least one agent with tau_i = tau")
