"""Aggregation strategies: the paper's methods as composable JAX modules.

A strategy owns (a) the within-period gradient transform applied at each local
update (identity / decay weighting / consensus gossip), (b) the variation
masks I(tau_i > s - t0), and (c) the period length tau. The server averaging
step itself (eq. 11) is the same for every strategy: average the replica axis.

All per-step data (masks, decay weights, fused mixing matrices) is precomputed
into arrays so strategies are jit-stable and can be closed over by lax.scan.

The per-step tables are read through ``jnp.asarray`` inside the trace, so a
strategy copy whose tables hold *tracers* drops straight into the drivers:
``with_mask`` returns such a copy with the variation mask (and every table it
folds into) replaced — the mechanism behind the sweep engine's traced ``taus``
axis (``repro.sweep.overrides.override_taus``), where the ``(m, tau)`` mask
becomes a batched operand instead of a baked-in constant. The period length
``tau`` itself stays static: it fixes the mask shape and the inner scan
length, so only the mask *values* vary across a vmapped sweep.

Execution backend: every strategy carries a ``backend`` field (see
``repro.kernels.dispatch.BACKENDS``). ``jnp`` keeps the original pure-jnp
tree-map path as the reference; ``pallas``/``interpret`` route the hot-path
transforms through the fused Pallas kernels (``decay_accum_pallas``,
``consensus_step_pallas``) on flat ``(m, n)`` buffers —
``flat_transform`` applies the within-period transform, and ``flat_update``
additionally fuses the SGD step (the decay/mask weight folds into the accum
coefficient, so a masked-decay local update is ONE bandwidth pass over the
parameters). ``auto`` (default) picks ``pallas`` on TPU and ``jnp`` elsewhere,
so every pre-existing call site keeps its exact behaviour on CPU.
"""
from __future__ import annotations

import collections
import copy
import dataclasses
import hashlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.transforms import IDENTITY, PayloadTransform
from repro.core.decay import DecayFn, no_decay
from repro.core.topology import (
    NeighborList,
    Topology,
    density,
    mixing_matrix,
    neighbor_list,
    neighbor_weights_from_matrix,
)
from repro.core.variation import masked_update_counts, validate_a2
from repro.kernels import dispatch


# --- mixing-matrix power cache -----------------------------------------------
#
# ConsensusStrategy construction needs P = I - eps*La (cheap) and, on the
# dense path, P^E via np.linalg.matrix_power (O(m^3 log E) — the cost a
# static sweep over eps/topology points used to pay once per *point per
# rebuild*). Keyed by (adjacency digest, m, eps, rounds) in a bounded LRU;
# P^E is filled lazily so sparse strategies never pay the matrix power at
# all. Cache hits return the *same* ndarray objects, so repeated
# constructions feed jit identical constants and the retrace guard sees no
# extra compiles (pinned by tests/test_sparse_consensus.py).

_POWER_CACHE_MAXSIZE = 32
_POWER_CACHE: "collections.OrderedDict" = collections.OrderedDict()


def _topology_digest(topo: Topology) -> str:
    return hashlib.sha1(
        np.ascontiguousarray(topo.adj, np.int8).tobytes()
    ).hexdigest()


def clear_power_cache() -> None:
    """Drop all cached mixing-matrix powers (tests)."""
    _POWER_CACHE.clear()


def mixing_powers(topo: Topology, eps: float, rounds: int, *,
                  need_power: bool = True):
    """Cached ``(P_float64, P_fp32, P^rounds_fp32)`` for one consensus config.

    ``P^rounds`` is ``None`` until some caller passes ``need_power=True``
    (the dense fused path); the fp32 power is computed from the fp32 ``P``
    exactly as ConsensusStrategy always did, so cached and uncached
    constructions are bit-identical.
    """
    key = (_topology_digest(topo), topo.m, float(eps), int(rounds))
    entry = _POWER_CACHE.get(key)
    if entry is None:
        p64 = mixing_matrix(topo, eps)
        entry = {"p64": p64, "p": p64.astype(np.float32), "p_e": None}
        _POWER_CACHE[key] = entry
        if len(_POWER_CACHE) > _POWER_CACHE_MAXSIZE:
            _POWER_CACHE.popitem(last=False)
    else:
        _POWER_CACHE.move_to_end(key)
    if need_power and entry["p_e"] is None:
        entry["p_e"] = np.linalg.matrix_power(entry["p"], rounds).astype(
            np.float32
        )
    return entry["p64"], entry["p"], entry["p_e"]


# Sparse-path auto selection: gather beats the dense matmul once the graph is
# genuinely sparse AND the agent count is big enough for O(m*k) vs O(m^2) to
# matter. The m floor keeps every pre-existing small-m config (paper figures,
# CI-pinned benches — all far below 64 agents) on the dense path bit-for-bit.
SPARSE_DENSITY_THRESHOLD = 0.25
SPARSE_MIN_AGENTS = 64


@dataclasses.dataclass(frozen=True)
class AggregationStrategy:
    """Variation-aware periodic averaging (the paper's base method, T2).

    Attributes:
      tau: local updates per period for the pacing agent (period length).
      taus: per-agent tau_i (A2); shape (m,).
      mask: (m, tau) float indicator I(tau_i > j) for period offset j.
      backend: execution backend ('auto' | 'jnp' | 'pallas' | 'interpret').
      comm: payload transform applied to what the strategy communicates
        (``repro.comm``): uplink deltas at the period sync and, on the
        consensus path, the gossip payloads. The identity default keeps the
        exact pre-comm-layer behaviour; compressed transforms route the
        flat-carry drivers through :meth:`flat_sync` / :meth:`flat_local_step`
        with per-agent error-feedback state in the scan carry.
    """

    name: str
    tau: int
    taus: np.ndarray
    mask: np.ndarray
    backend: str = "auto"
    comm: PayloadTransform = IDENTITY

    # Class-level flags (not dataclass fields): the synchronous strategies
    # sync every replica at every period boundary, so the ledger may bill
    # periods by closed-form multiplication. AsyncStrategy flips both — its
    # arrivals vary per boundary and its flat_sync needs the period index.
    is_async = False
    uniform_sync = True

    def __post_init__(self):
        if self.backend not in dispatch.BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; expected one of "
                f"{dispatch.BACKENDS}"
            )

    # --- construction helpers -------------------------------------------------
    @staticmethod
    def _build_mask(taus: np.ndarray, tau: int) -> np.ndarray:
        offs = np.arange(tau)[None, :]
        return (np.asarray(taus)[:, None] > offs).astype(np.float32)

    def with_mask(self, mask, taus=None) -> "AggregationStrategy":
        """Copy with a replacement ``(m, tau)`` variation mask (may be traced).

        The traced-variation entry point: the copy's hot-path tables hold the
        new mask (subclasses also refold it into their fused tables), while
        shape-defining statics (``tau``, topology, backend) are untouched, so
        the copy is drop-in for the drivers and vmappable over a leading
        sweep axis. ``taus`` optionally refreshes the static per-agent
        schedule used by the *host-side* comm accounting — when the new mask
        is a tracer the accounting keeps the previous schedule (the sweep
        core never reads it; the ledger lives in the host wrappers).
        """
        new = copy.copy(self)
        object.__setattr__(new, "mask", mask)
        if taus is not None:
            object.__setattr__(new, "taus", np.asarray(taus, int))
        return new

    def with_comm(self, comm: PayloadTransform) -> "AggregationStrategy":
        """Copy with a replacement payload transform (static swap).

        ``comm`` changes wire sizes and the comm-state structure, never
        array shapes of the training math itself, but the transform *kind*
        and ``k`` alter the trace — so sweeping compression is a static axis
        (``repro.sweep.overrides.compression_axis``), one compile per point.
        """
        if not isinstance(comm, PayloadTransform):
            raise TypeError(
                f"with_comm expects a PayloadTransform, got {type(comm).__name__}"
            )
        new = copy.copy(self)
        object.__setattr__(new, "comm", comm)
        return new

    @property
    def m(self) -> int:
        return len(self.taus)

    def resolved_backend(self) -> str:
        """Concrete backend for the current platform (resolves 'auto')."""
        return dispatch.resolve_backend(self.backend)

    # --- hooks -----------------------------------------------------------------
    def weight(self, offset) -> jnp.ndarray:
        """Per-agent weight vector at period offset (mask only by default)."""
        return jnp.asarray(self.mask)[:, offset]

    def transform(self, grads_m, offset):
        """Apply the within-period transform to the stacked (m, ...) pytree.

        Dispatches on ``backend``: the jnp reference path stays in tree space;
        the kernel path flattens once (cached ravel), runs the fused kernel,
        and unflattens.
        """
        if self.resolved_backend() == "jnp":
            return self._transform_tree(grads_m, offset)
        flat, unravel = dispatch.stacked_ravel(grads_m)
        return unravel(self.flat_transform(flat, offset))

    def _transform_tree(self, grads_m, offset):
        """Pure-jnp reference: mask (+ subclass behaviour) via tree.map."""
        w = self.weight(offset)

        def apply(leaf):
            return leaf * w.reshape((-1,) + (1,) * (leaf.ndim - 1))

        return jax.tree.map(apply, grads_m)

    # --- flat (m, n) hot path --------------------------------------------------
    def flat_transform(self, g, offset, *, backend: Optional[str] = None):
        """Within-period transform on the flat (m, n) gradient matrix."""
        b = backend if backend is not None else self.backend
        return dispatch.scale_rows(g, self.weight(offset), backend=b)

    def flat_update(self, params, g, offset, eta, *, backend: Optional[str] = None):
        """Fused transform + local SGD step: params <- params - eta*T(g).

        For mask/decay strategies the weight folds into the accumulation
        coefficient, so the whole local update is a single decay_accum_pallas
        pass per agent (no separately materialised scaled gradient).
        """
        b = backend if backend is not None else self.backend
        return dispatch.decay_accum(params, g, -eta * self.weight(offset), backend=b)

    def local_update(self, params_m, grads_m, offset, eta):
        """One local step on the stacked replica pytrees: transform + SGD.

        The single entry point the drivers call each iteration. The jnp
        reference backend stays in tree space; the kernel backends ravel both
        pytrees once (cached) and run the fused flat update through
        decay_accum_pallas / consensus_step_pallas via the dispatch layer.
        """
        if self.resolved_backend() == "jnp":
            g = self._transform_tree(grads_m, offset)
            return jax.tree.map(lambda p, gg: p - eta * gg, params_m, g)
        g_flat, _ = dispatch.stacked_ravel(grads_m)
        p_flat, unravel = dispatch.stacked_ravel(params_m)
        return unravel(self.flat_update(p_flat, g_flat, offset, eta))

    def flat_opt_step(self, params, g, offset, eta, opt, opt_state, *,
                      backend: Optional[str] = None):
        """Fused transform + optimizer update on the flat (m, n) carry.

        The within-period weight (mask x decay) folds into the gradient
        before moment accumulation (see ``dispatch.flat_opt_update``), so the
        whole weighted momentum/Adam local step is one bandwidth pass.
        Returns ``(params, opt_state)``.
        """
        b = backend if backend is not None else self.backend
        return opt.update(params, g, self.weight(offset), opt_state, eta,
                          backend=b)

    def flat_server_average(self, flat, *, backend: Optional[str] = None):
        """Eq. (11) on the flat carry: the (n,) mean over the agent axis.

        Broadcast the returned server row back over axis 0 to re-seed the
        replicas; ``dispatch.row_mean`` accumulates in fp32 on every backend.
        """
        b = backend if backend is not None else self.backend
        return dispatch.row_mean(flat, backend=b)

    def server_average(self, params_m):
        """Eq. (11): periodic averaging = mean over the replica axis."""
        avg = jax.tree.map(lambda leaf: jnp.mean(leaf, axis=0), params_m)
        return avg

    # --- comm layer (payload transforms + error feedback) ----------------------
    def init_comm_state(self, flat) -> dict:
        """Comm-layer carry for a flat ``(m, n)`` run: ``{}`` when dense.

        With a compressed ``comm``: ``ref`` is the fp32 server reference the
        uplink deltas are taken against (all replicas start broadcast, so row
        0 is the server row), plus the ``(m, n)`` fp32 ``err_up`` uplink
        error-feedback accumulator when enabled. Lives in the drivers' scan
        carry next to the optimizer moments.
        """
        if not self.comm.enabled:
            return {}
        state = {"ref": flat[0].astype(jnp.float32)}
        if self.comm.error_feedback:
            state["err_up"] = jnp.zeros(flat.shape, jnp.float32)
        return state

    def flat_local_step(self, flat, g, offset, eta, opt, opt_state, comm_state,
                        *, backend: Optional[str] = None):
        """One local step on the flat carry, comm state threaded through.

        The single seam both flat drivers call per iteration: plain SGD
        (``opt is None``) or the fused optimizer step. The base strategies
        communicate nothing within a period, so ``comm_state`` passes
        through untouched; :class:`ConsensusStrategy` overrides this to
        compress the gossip payload. Returns
        ``(flat, opt_state, comm_state)``.
        """
        b = backend if backend is not None else self.backend
        if opt is None:
            flat = self.flat_update(flat, g, offset, eta, backend=b)
        else:
            flat, opt_state = self.flat_opt_step(
                flat, g, offset, eta, opt, opt_state, backend=b
            )
        return flat, opt_state, comm_state

    def flat_sync(self, flat, comm_state, *, period=None,
                  backend: Optional[str] = None):
        """Period-boundary server sync on the flat carry, compression-aware.

        Dense (identity comm): eq. (11) exactly as before — ``row_mean`` and
        broadcast, bit-identical to the legacy path. Compressed: each agent
        uplinks ``encode(flat_i - ref + err_i)``; the server accumulates the
        reconstructions in fp32 (``PayloadTransform.reduce_mean`` — the
        fused top-k scatter kernel on kernel backends), advances the shared
        reference by the mean payload, and the unsent remainder becomes the
        next error-feedback residual. Returns ``(flat, comm_state)`` with
        ``flat`` already re-broadcast (``flat[0]`` is the server row).

        ``period`` is the (possibly traced) index of the boundary being
        synced; the synchronous strategies behave identically at every
        boundary and ignore it, AsyncStrategy requires it.
        """
        del period
        b = backend if backend is not None else self.backend
        if not self.comm.enabled:
            row = self.flat_server_average(flat, backend=b)
            return jnp.broadcast_to(row[None, :], flat.shape), comm_state
        ref = comm_state["ref"]
        delta = flat.astype(jnp.float32) - ref[None, :]
        if self.comm.error_feedback:
            delta = delta + comm_state["err_up"]
        mean_sent, residual = self.comm.reduce_mean(delta, backend=b)
        row = ref + mean_sent
        new_state = dict(comm_state, ref=row)
        if self.comm.error_feedback:
            new_state["err_up"] = residual
        flat = jnp.broadcast_to(row[None, :].astype(flat.dtype), flat.shape)
        return flat, new_state

    def server_row(self, flat, comm_state, *, backend: Optional[str] = None):
        """The server's current parameter row after a ``flat_sync``.

        The synchronous strategies re-broadcast at every sync, so any row is
        the server row — ``flat[0]`` by convention (what the drivers always
        read). AsyncStrategy keeps replicas divergent and overrides this to
        read the buffered reference out of ``comm_state``.
        """
        del comm_state, backend
        return flat[0]

    # --- accounting ------------------------------------------------------------
    def comm_bytes_per_event(self, payload_elems: int) -> dict:
        """Wire bytes of one C1 uplink / one W1 gossip receive of
        ``payload_elems`` parameters under this strategy's payload transform
        (``repro.comm.PayloadTransform.payload_bytes``)."""
        per = self.comm.payload_bytes(payload_elems)
        return {"c1": per, "w1": per}

    def comm_events_per_period(self) -> dict:
        """Event counts in units of C1/C2/W1/W2 for one period (per eq. 7/27)."""
        return {
            "c1": self.m,                      # each agent uploads once per period
            "c2": int(np.sum(self.taus)),      # tau_i local updates each
            "w1": 0,
            "w2": 0,
        }

    def comm_events_partial_period(self, n_offsets: int) -> dict:
        """Event counts for a trailing partial period of ``n_offsets`` steps.

        Only the first ``n_offsets`` mask columns of local updates run (C2);
        the final server read still aggregates every replica, so it bills the
        per-agent upload (C1) exactly like a full-period sync. The C2 count
        uses the closed form ``sum_i min(tau_i, n_offsets)`` (equal to the
        mask-column sum) so the accounting stays host-computable even on a
        ``with_mask`` copy whose mask is a tracer.
        """
        n_offsets = int(n_offsets)
        if not 0 <= n_offsets < self.tau:
            raise ValueError(
                f"partial period must satisfy 0 <= n_offsets < tau={self.tau}, "
                f"got {n_offsets}"
            )
        return {
            "c1": self.m if n_offsets else 0,
            "c2": int(masked_update_counts(self.taus, n_offsets).sum()),
            "w1": 0,
            "w2": 0,
        }


class SyncStrategy(AggregationStrategy):
    """tau = 1: classic federated SGD (eq. 4) — the paper's communication-heavy baseline."""

    def __init__(self, m: int, backend: str = "auto"):
        taus = np.ones(m, int)
        super().__init__(
            name="sync", tau=1, taus=taus, mask=self._build_mask(taus, 1),
            backend=backend,
        )


class PeriodicStrategy(AggregationStrategy):
    """Variation-aware periodic averaging (Alg. 1 / T2). tau_i = tau gives T1."""

    def __init__(
        self,
        tau: int,
        taus: Optional[np.ndarray] = None,
        m: Optional[int] = None,
        backend: str = "auto",
    ):
        if taus is None:
            if m is None:
                raise ValueError("need taus or m")
            taus = np.full(m, tau, int)
        taus = np.asarray(taus, int)
        validate_a2(taus, tau)
        super().__init__(
            name=f"periodic(tau={tau})",
            tau=tau,
            taus=taus,
            mask=self._build_mask(taus, tau),
            backend=backend,
        )


@dataclasses.dataclass(frozen=True)
class DecayStrategy(AggregationStrategy):
    """Decay-based method (T3/T4): weight local grads by D(offset)."""

    decay_weights: np.ndarray = dataclasses.field(default=None)  # (tau,)

    def __init__(self, tau: int, taus=None, m=None, decay: DecayFn = None,
                 backend: str = "auto"):
        if taus is None:
            if m is None:
                raise ValueError("need taus or m")
            taus = np.full(m, tau, int)
        taus = np.asarray(taus, int)
        validate_a2(taus, tau)
        decay = decay or no_decay()
        w = np.asarray(jax.device_get(decay(jnp.arange(tau))), np.float32)
        if w[0] != 1.0 or np.any(np.diff(w) > 1e-7) or np.any(w < -1e-7):
            raise ValueError("decay function violates A3 over this period")
        object.__setattr__(self, "decay_weights", w)
        AggregationStrategy.__init__(
            self,
            name=f"decay(tau={tau})",
            tau=tau,
            taus=taus,
            mask=self._build_mask(taus, tau),
            backend=backend,
        )

    def weight(self, offset):
        # decay_weights is (tau,) shared or (m, tau) per-agent (the sweep's
        # vector-valued lam axis); `[..., offset]` indexes the offset axis of
        # either, yielding a scalar or an (m,) per-agent decay factor.
        d = jnp.asarray(self.decay_weights)[..., offset]
        return jnp.asarray(self.mask)[:, offset] * d


@dataclasses.dataclass(frozen=True)
class ConsensusStrategy(AggregationStrategy):
    """Consensus-based method (Alg. 2 / T5): E gossip rounds before each update.

    The gossip is fused into a single precomputed mixing matrix P^E (exactly
    equivalent; P is constant). ``fused=False`` keeps the paper's explicit
    E-round loop for fidelity checks.

    For the kernel path the variation mask is folded into the mixing matrix:
    P^E @ diag(mask[:, j]) is precomputed per period offset j (``p_e_masked``,
    shape (tau, m, m)), so the masked gossip is ONE consensus_step_pallas call.

    Sparse path (DESIGN.md §14): when the topology is sparse enough
    (``density <= SPARSE_DENSITY_THRESHOLD`` and ``m >= SPARSE_MIN_AGENTS``,
    or ``sparse=True`` explicitly) the strategy skips the dense tables
    entirely — no ``P^E`` matrix power, no ``(tau, m, m)`` folded tables —
    and realises each transform as mask ``scale_rows`` + E
    ``consensus_gather`` rounds over the padded ``(m, k_max)`` neighbor list,
    O(m*k) per round instead of O(m^2). ``nl_w`` gathers its edge weights out
    of the *float64* mixing matrix so the sparse path sees the same fp32
    weight values as the dense one.
    """

    p_e: np.ndarray = dataclasses.field(default=None)   # (m, m) = P^E (dense)
    p: np.ndarray = dataclasses.field(default=None)     # (m, m) = P
    p_e_masked: np.ndarray = dataclasses.field(default=None)  # (tau, m, m)
    p_masked: np.ndarray = dataclasses.field(default=None)    # (tau, m, m)
    rounds: int = 1
    fused: bool = True
    topo: Topology = None
    eps: float = 0.0
    sparse: bool = False
    nl: NeighborList = None                             # sparse neighbor layout
    nl_w: np.ndarray = None                             # (m, k_max) P gathered

    def __init__(
        self,
        tau: int,
        topo: Topology,
        eps: float,
        rounds: int = 1,
        taus=None,
        m: Optional[int] = None,
        fused: bool = True,
        backend: str = "auto",
        sparse: Optional[bool] = None,
    ):
        m = m if m is not None else topo.m
        if taus is None:
            taus = np.full(m, tau, int)
        taus = np.asarray(taus, int)
        validate_a2(taus, tau)
        if topo.m != m:
            raise ValueError("topology size must match agent count")
        if sparse is None:
            sparse = (
                density(topo) <= SPARSE_DENSITY_THRESHOLD
                and m >= SPARSE_MIN_AGENTS
            )
        p64, p, p_e = mixing_powers(topo, eps, rounds, need_power=not sparse)
        mask = self._build_mask(taus, tau)
        object.__setattr__(self, "p", p)
        object.__setattr__(self, "p_e", p_e)
        object.__setattr__(self, "sparse", bool(sparse))
        if sparse:
            nl = neighbor_list(topo)
            object.__setattr__(self, "nl", nl)
            object.__setattr__(self, "nl_w", neighbor_weights_from_matrix(nl, p64))
            object.__setattr__(self, "p_e_masked", None)
            object.__setattr__(self, "p_masked", None)
        else:
            # mask-folded mixing per offset:
            # (P^E @ diag(w_j))[i, l] = P^E[i, l]*w_j[l]
            object.__setattr__(self, "nl", None)
            object.__setattr__(self, "nl_w", None)
            object.__setattr__(
                self, "p_e_masked", p_e[None, :, :] * mask.T[:, None, :]
            )
            object.__setattr__(self, "p_masked", p[None, :, :] * mask.T[:, None, :])
        object.__setattr__(self, "rounds", rounds)
        object.__setattr__(self, "fused", fused)
        object.__setattr__(self, "topo", topo)
        object.__setattr__(self, "eps", eps)
        AggregationStrategy.__init__(
            self,
            name=(
                f"consensus(tau={tau},E={rounds},eps={eps:.3f}"
                + (",sparse)" if sparse else ")")
            ),
            tau=tau,
            taus=taus,
            mask=mask,
            backend=backend,
        )

    def with_mask(self, mask, taus=None) -> "ConsensusStrategy":
        """Mask copy that also refolds the per-offset masked mixing tables.

        ``p`` / ``p_e`` stay as built (they depend only on topology, eps and
        rounds); the mask-folded ``p_masked`` / ``p_e_masked`` are recomputed
        from them against the new mask, tracing through when the mask (or a
        prior ``eps`` override's matrices) is a tracer. The sparse path folds
        the mask at transform time (``scale_rows`` before the gathers), so
        its copy just swaps the mask — no tables to refold.
        """
        new = AggregationStrategy.with_mask(self, mask, taus)
        if self.sparse:
            return new
        mask_t = jnp.asarray(mask).T[:, None, :]              # (tau, 1, m)
        object.__setattr__(new, "p_masked", jnp.asarray(self.p)[None] * mask_t)
        object.__setattr__(
            new, "p_e_masked", jnp.asarray(self.p_e)[None] * mask_t
        )
        return new

    def _gossip(self, x, backend: str):
        """E sparse gossip rounds over the neighbor list (O(m*k) each).

        The rounds unroll as a Python loop (E is a small static int) rather
        than a lax.scan: in eager mode every round then runs op-by-op, which
        keeps the sequential-FMA bitwise-parity contract of
        ``dispatch.consensus_gather`` intact across rounds too.
        """
        idx = jnp.asarray(self.nl.idx)
        w = jnp.asarray(self.nl_w)
        out = x
        for _ in range(self.rounds):
            out = dispatch.consensus_gather(out, idx, w, backend=backend)
        return out

    def _transform_tree(self, grads_m, offset):
        masked = AggregationStrategy._transform_tree(self, grads_m, offset)
        if self.sparse:

            def mix_leaf(leaf):
                flat = leaf.reshape(leaf.shape[0], -1)
                return self._gossip(flat, "jnp").reshape(leaf.shape)

            return jax.tree.map(mix_leaf, masked)
        if self.fused:
            mix = jnp.asarray(self.p_e)
            return jax.tree.map(
                lambda leaf: jnp.tensordot(mix, leaf, axes=1), masked
            )
        mix = jnp.asarray(self.p)

        def one_round(g, _):
            return jax.tree.map(lambda leaf: jnp.tensordot(mix, leaf, axes=1), g), None

        out, _ = jax.lax.scan(one_round, masked, None, length=self.rounds)
        return out

    def flat_transform(self, g, offset, *, backend: Optional[str] = None):
        b = backend if backend is not None else self.backend
        if self.sparse:
            # Mask first (diag(w_j) commutes out of the product), then E
            # O(m*k) gather rounds — the fused dense table never exists.
            x = dispatch.scale_rows(g, self.weight(offset), backend=b)
            return self._gossip(x, b)
        if self.fused:
            mix = jnp.asarray(self.p_e_masked)[offset]
            return dispatch.consensus_mix(g, mix, backend=b)
        out = dispatch.consensus_mix(g, jnp.asarray(self.p_masked)[offset], backend=b)
        if self.rounds > 1:
            p = jnp.asarray(self.p)

            def one_round(g_, _):
                return dispatch.consensus_mix(g_, p, backend=b), None

            out, _ = jax.lax.scan(one_round, out, None, length=self.rounds - 1)
        return out

    def flat_update(self, params, g, offset, eta, *, backend: Optional[str] = None):
        b = backend if backend is not None else self.backend
        mixed = self.flat_transform(g, offset, backend=b)
        return dispatch.decay_accum(params, mixed, -eta, backend=b)

    def flat_opt_step(self, params, g, offset, eta, opt, opt_state, *,
                      backend: Optional[str] = None):
        """Masked gossip mix (mask folded into P^E) then the optimizer pass."""
        b = backend if backend is not None else self.backend
        mixed = self.flat_transform(g, offset, backend=b)
        return opt.update(params, mixed, 1.0, opt_state, eta, backend=b)

    def init_comm_state(self, flat) -> dict:
        """Adds the ``(m, n)`` fp32 gossip error-feedback accumulator.

        The consensus path communicates every local step (the gossip mix),
        so with a compressed ``comm`` each agent also carries the residual of
        its last gossip broadcast next to the uplink one.
        """
        state = AggregationStrategy.init_comm_state(self, flat)
        if self.comm.enabled and self.comm.error_feedback:
            state["err_gossip"] = jnp.zeros(flat.shape, jnp.float32)
        return state

    def flat_local_step(self, flat, g, offset, eta, opt, opt_state, comm_state,
                        *, backend: Optional[str] = None):
        """Gossip step with the broadcast payload compressed.

        Each agent masks/weights its gradient, folds in its gossip
        error-feedback residual, *encodes once*, and broadcasts the encoded
        payload; the neighbours mix the reconstructions through the fused
        ``P^E`` (compress-then-gossip — one encode per agent per step
        regardless of E, matching the fused-mixing semantics of the dense
        path). The unsent remainder becomes the next residual. Identity comm
        delegates to the base fused step unchanged.
        """
        if not self.comm.enabled:
            return AggregationStrategy.flat_local_step(
                self, flat, g, offset, eta, opt, opt_state, comm_state,
                backend=backend,
            )
        b = backend if backend is not None else self.backend
        g32 = dispatch.scale_rows(
            g.astype(jnp.float32), self.weight(offset), backend=b
        )
        x = g32
        if self.comm.error_feedback:
            x = x + comm_state["err_gossip"]
        payload, residual = self.comm.encode(x, backend=b)
        if self.sparse:
            mixed = self._gossip(payload, b)
        else:
            mixed = dispatch.consensus_mix(payload, jnp.asarray(self.p_e), backend=b)
        if self.comm.error_feedback:
            comm_state = dict(comm_state, err_gossip=residual)
        mixed = mixed.astype(flat.dtype)
        if opt is None:
            flat = dispatch.decay_accum(flat, mixed, -eta, backend=b)
        else:
            flat, opt_state = opt.update(flat, mixed, 1.0, opt_state, eta,
                                         backend=b)
        return flat, opt_state, comm_state

    def comm_events_partial_period(self, n_offsets: int) -> dict:
        base = AggregationStrategy.comm_events_partial_period(self, n_offsets)
        gossip = int(self.topo.degrees.sum()) * self.rounds * int(n_offsets)
        base["w1"] = gossip
        base["w2"] = gossip
        return base

    def comm_events_per_period(self) -> dict:
        base = AggregationStrategy.comm_events_per_period(self)
        # Every local iteration (tau of them, all agents listen even when their
        # own g is masked to zero — Alg. 2 lines 14-17) costs |Omega_i| receives
        # per round.
        gossip = int(self.topo.degrees.sum()) * self.rounds * self.tau
        base["w1"] = gossip
        base["w2"] = gossip
        return base


def make_strategy(kind: str, **kw) -> AggregationStrategy:
    backend = kw.get("backend", "auto")
    comm = kw.get("comm")
    if kind == "sync":
        strat = SyncStrategy(m=kw["m"], backend=backend)
    elif kind == "periodic":
        strat = PeriodicStrategy(
            tau=kw["tau"], taus=kw.get("taus"), m=kw.get("m"), backend=backend
        )
    elif kind == "decay":
        strat = DecayStrategy(
            tau=kw["tau"], taus=kw.get("taus"), m=kw.get("m"),
            decay=kw.get("decay"), backend=backend,
        )
    elif kind == "consensus":
        strat = ConsensusStrategy(
            tau=kw["tau"],
            topo=kw["topo"],
            eps=kw["eps"],
            rounds=kw.get("rounds", 1),
            taus=kw.get("taus"),
            m=kw.get("m"),
            fused=kw.get("fused", True),
            backend=backend,
            sparse=kw.get("sparse"),
        )
    elif kind == "async":
        # Lazy import: repro.core.async_fed imports this module.
        from repro.core.async_fed import AsyncStrategy

        strat = AsyncStrategy(
            tau=kw["tau"],
            schedule=kw["schedule"],
            taus=kw.get("taus"),
            m=kw.get("m"),
            stale_decay=kw.get("stale_decay"),
            backend=backend,
        )
    else:
        raise ValueError(f"unknown strategy kind: {kind}")
    if comm is not None:
        strat = strat.with_comm(comm)
    return strat
