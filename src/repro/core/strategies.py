"""Aggregation strategies: the paper's methods as composable JAX modules.

A strategy owns (a) the within-period gradient transform applied at each local
update (identity / decay weighting / consensus gossip), (b) the variation
masks I(tau_i > s - t0), and (c) the period length tau. The server averaging
step itself (eq. 11) is the same for every strategy: average the replica axis.

All per-step data (masks, decay weights, fused mixing matrices) is precomputed
into arrays so strategies are jit-stable and can be closed over by lax.scan.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.decay import DecayFn, no_decay
from repro.core.topology import Topology, mixing_matrix
from repro.core.variation import validate_a2


@dataclasses.dataclass(frozen=True)
class AggregationStrategy:
    """Variation-aware periodic averaging (the paper's base method, T2).

    Attributes:
      tau: local updates per period for the pacing agent (period length).
      taus: per-agent tau_i (A2); shape (m,).
      mask: (m, tau) float indicator I(tau_i > j) for period offset j.
    """

    name: str
    tau: int
    taus: np.ndarray
    mask: np.ndarray

    # --- construction helpers -------------------------------------------------
    @staticmethod
    def _build_mask(taus: np.ndarray, tau: int) -> np.ndarray:
        offs = np.arange(tau)[None, :]
        return (np.asarray(taus)[:, None] > offs).astype(np.float32)

    @property
    def m(self) -> int:
        return len(self.taus)

    # --- hooks -----------------------------------------------------------------
    def weight(self, offset) -> jnp.ndarray:
        """Per-agent weight vector at period offset (mask only by default)."""
        return jnp.asarray(self.mask)[:, offset]

    def transform(self, grads_m, offset):
        """Apply mask (+ subclass behaviour) to the stacked (m, ...) gradients."""
        w = self.weight(offset)

        def apply(leaf):
            return leaf * w.reshape((-1,) + (1,) * (leaf.ndim - 1))

        return jax.tree.map(apply, grads_m)

    def server_average(self, params_m):
        """Eq. (11): periodic averaging = mean over the replica axis."""
        avg = jax.tree.map(lambda leaf: jnp.mean(leaf, axis=0), params_m)
        return avg

    # --- accounting ------------------------------------------------------------
    def comm_events_per_period(self) -> dict:
        """Event counts in units of C1/C2/W1/W2 for one period (per eq. 7/27)."""
        return {
            "c1": self.m,                      # each agent uploads once per period
            "c2": int(np.sum(self.taus)),      # tau_i local updates each
            "w1": 0,
            "w2": 0,
        }


class SyncStrategy(AggregationStrategy):
    """tau = 1: classic federated SGD (eq. 4) — the paper's communication-heavy baseline."""

    def __init__(self, m: int):
        taus = np.ones(m, int)
        super().__init__(
            name="sync", tau=1, taus=taus, mask=self._build_mask(taus, 1)
        )


class PeriodicStrategy(AggregationStrategy):
    """Variation-aware periodic averaging (Alg. 1 / T2). tau_i = tau gives T1."""

    def __init__(self, tau: int, taus: Optional[np.ndarray] = None, m: Optional[int] = None):
        if taus is None:
            if m is None:
                raise ValueError("need taus or m")
            taus = np.full(m, tau, int)
        taus = np.asarray(taus, int)
        validate_a2(taus, tau)
        super().__init__(
            name=f"periodic(tau={tau})",
            tau=tau,
            taus=taus,
            mask=self._build_mask(taus, tau),
        )


@dataclasses.dataclass(frozen=True)
class DecayStrategy(AggregationStrategy):
    """Decay-based method (T3/T4): weight local grads by D(offset)."""

    decay_weights: np.ndarray = dataclasses.field(default=None)  # (tau,)

    def __init__(self, tau: int, taus=None, m=None, decay: DecayFn = None):
        if taus is None:
            if m is None:
                raise ValueError("need taus or m")
            taus = np.full(m, tau, int)
        taus = np.asarray(taus, int)
        validate_a2(taus, tau)
        decay = decay or no_decay()
        w = np.asarray(jax.device_get(decay(jnp.arange(tau))), np.float32)
        if w[0] != 1.0 or np.any(np.diff(w) > 1e-7) or np.any(w < -1e-7):
            raise ValueError("decay function violates A3 over this period")
        object.__setattr__(self, "decay_weights", w)
        AggregationStrategy.__init__(
            self,
            name=f"decay(tau={tau})",
            tau=tau,
            taus=taus,
            mask=self._build_mask(taus, tau),
        )

    def weight(self, offset):
        d = jnp.asarray(self.decay_weights)[offset]
        return jnp.asarray(self.mask)[:, offset] * d


@dataclasses.dataclass(frozen=True)
class ConsensusStrategy(AggregationStrategy):
    """Consensus-based method (Alg. 2 / T5): E gossip rounds before each update.

    The gossip is fused into a single precomputed mixing matrix P^E (exactly
    equivalent; P is constant). ``fused=False`` keeps the paper's explicit
    E-round loop for fidelity checks.
    """

    p_e: np.ndarray = dataclasses.field(default=None)   # (m, m) = P^E
    p: np.ndarray = dataclasses.field(default=None)     # (m, m) = P
    rounds: int = 1
    fused: bool = True
    topo: Topology = None
    eps: float = 0.0

    def __init__(
        self,
        tau: int,
        topo: Topology,
        eps: float,
        rounds: int = 1,
        taus=None,
        m: Optional[int] = None,
        fused: bool = True,
    ):
        m = m if m is not None else topo.m
        if taus is None:
            taus = np.full(m, tau, int)
        taus = np.asarray(taus, int)
        validate_a2(taus, tau)
        if topo.m != m:
            raise ValueError("topology size must match agent count")
        p = mixing_matrix(topo, eps)
        object.__setattr__(self, "p", p.astype(np.float32))
        object.__setattr__(self, "p_e", np.linalg.matrix_power(p, rounds).astype(np.float32))
        object.__setattr__(self, "rounds", rounds)
        object.__setattr__(self, "fused", fused)
        object.__setattr__(self, "topo", topo)
        object.__setattr__(self, "eps", eps)
        AggregationStrategy.__init__(
            self,
            name=f"consensus(tau={tau},E={rounds},eps={eps:.3f})",
            tau=tau,
            taus=taus,
            mask=self._build_mask(taus, tau),
        )

    def transform(self, grads_m, offset):
        masked = AggregationStrategy.transform(self, grads_m, offset)
        if self.fused:
            mix = jnp.asarray(self.p_e)
            return jax.tree.map(
                lambda leaf: jnp.tensordot(mix, leaf, axes=1), masked
            )
        mix = jnp.asarray(self.p)

        def one_round(g, _):
            return jax.tree.map(lambda leaf: jnp.tensordot(mix, leaf, axes=1), g), None

        out, _ = jax.lax.scan(one_round, masked, None, length=self.rounds)
        return out

    def comm_events_per_period(self) -> dict:
        base = AggregationStrategy.comm_events_per_period(self)
        # Every local iteration (tau of them, all agents listen even when their
        # own g is masked to zero — Alg. 2 lines 14-17) costs |Omega_i| receives
        # per round.
        gossip = int(self.topo.degrees.sum()) * self.rounds * self.tau
        base["w1"] = gossip
        base["w2"] = gossip
        return base


def make_strategy(kind: str, **kw) -> AggregationStrategy:
    if kind == "sync":
        return SyncStrategy(m=kw["m"])
    if kind == "periodic":
        return PeriodicStrategy(tau=kw["tau"], taus=kw.get("taus"), m=kw.get("m"))
    if kind == "decay":
        return DecayStrategy(
            tau=kw["tau"], taus=kw.get("taus"), m=kw.get("m"), decay=kw.get("decay")
        )
    if kind == "consensus":
        return ConsensusStrategy(
            tau=kw["tau"],
            topo=kw["topo"],
            eps=kw["eps"],
            rounds=kw.get("rounds", 1),
            taus=kw.get("taus"),
            m=kw.get("m"),
            fused=kw.get("fused", True),
        )
    raise ValueError(f"unknown strategy kind: {kind}")
