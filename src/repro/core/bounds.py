"""Closed-form convergence bounds and the utility function (paper §IV-§V).

These are the executable oracles for T1, T2, T3 (numeric), T4, T5, the
learning-rate condition (14), the resource costs (7)/(27), and the system
utility (13). Benchmarks and tests check the paper's qualitative claims
against these forms (monotonicity in tau, nu, omega^2, lambda, eps*mu2, E).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.decay import DecayFn, decay_sq_prefix_sum
from repro.core.topology import Topology, spectral_gap_factor


@dataclasses.dataclass(frozen=True)
class SgdConstants:
    """A1 constants + run geometry shared by every bound."""

    L: float            # Lipschitz smoothness
    sigma2: float       # gradient-variance constant sigma^2
    beta: float         # gradient-variance slope beta
    eta: float          # learning rate
    K: int              # total iterations
    m: int              # participating agents
    f0_minus_finf: float  # F(theta_0) - F_inf


def eta_condition(c: SgdConstants, tau: int) -> float:
    """LHS of eq. (14); feasible iff <= 0."""
    eL = c.eta * c.L
    return (
        eL * (c.beta / c.m + 1.0)
        - 1.0
        + 2.0 * eL * eL * tau * c.beta
        + eL * eL * tau * (tau + 1.0)
    )


def max_feasible_eta(c: SgdConstants, tau: int, tol: float = 1e-12) -> float:
    """Largest eta satisfying (14) (bisection; the LHS is increasing in eta)."""
    lo, hi = 0.0, 1.0 / max(c.L, 1e-30)
    base = dataclasses.asdict(c)
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        base["eta"] = mid
        if eta_condition(SgdConstants(**base), tau) <= 0.0:
            lo = mid
        else:
            hi = mid
        if hi - lo < tol:
            break
    return lo


def _common_terms(c: SgdConstants) -> float:
    """First two RHS terms shared by (15), (17), (22), (26)."""
    return 2.0 * c.f0_minus_finf / (c.eta * c.K) + c.eta * c.L * c.sigma2 / c.m


def periodic_bound_t1(c: SgdConstants, tau: int) -> float:
    """Eq. (15): psi_1 under classic periodic averaging (tau_i = tau)."""
    return _common_terms(c) + (c.eta * c.L) ** 2 * c.sigma2 * (tau + 1.0)


def variation_bound_t2(c: SgdConstants, tau: int, nu: float, omega2: float) -> float:
    """Eq. (17): psi_1 under variation-aware periodic averaging."""
    if not (1.0 <= nu <= tau):
        raise ValueError(f"A2 implies 1 <= nu <= tau, got nu={nu}, tau={tau}")
    bracket = -(nu**2) + (2.0 * tau + 1.0) * nu - omega2
    return _common_terms(c) + (c.eta * c.L) ** 2 * c.sigma2 / tau * bracket


def variation_bound_t2_empirical(c: SgdConstants, tau: int, taus) -> float:
    """Finite-m version of (17) from the proof: (1/m)sum(tau_i + 2*tau*tau_i - tau_i^2)/tau."""
    taus = np.asarray(taus, np.float64)
    bracket = float(np.mean(taus + 2.0 * tau * taus - taus**2))
    return _common_terms(c) + (c.eta * c.L) ** 2 * c.sigma2 / tau * bracket


def decay_bound_numeric(c: SgdConstants, tau: int, taus, decay: DecayFn) -> float:
    """T3's psi_3 evaluated numerically for an arbitrary A3 decay function.

    Third term = (2 eta^2 L^2 sigma^2 / (m tau)) * sum_i sum_{j=1..tau}
    min{Z(tau_i), Z(j)} with Z(j) = sum_{s<j} D^2(s)  (proof of T3/T4).
    """
    taus = np.asarray(taus, int)
    z = np.array([decay_sq_prefix_sum(decay, j) for j in range(tau + 1)])
    tot = 0.0
    for ti in taus:
        for j in range(1, tau + 1):
            tot += min(z[ti], z[j])
    third = 2.0 * (c.eta * c.L) ** 2 * c.sigma2 / (len(taus) * tau) * tot
    return _common_terms(c) + third


def decay_bound_t4(c: SgdConstants, tau: int, lam: float) -> float:
    """Eq. (22): psi_3 for D(s) = lam^{s/2} with tau_i ~ Uniform{1..tau}."""
    if not (0.0 < lam < 1.0):
        raise ValueError("T4 closed form needs lam in (0,1); lam=1 reduces to T2")
    one = 1.0 - lam
    bracket = (
        tau / one
        - 2.0 * lam / one**2
        + lam * (lam + 1.0) * (1.0 - lam**tau) / (tau * one**3)
    )
    return _common_terms(c) + 2.0 * (c.eta * c.L) ** 2 * c.sigma2 / tau * bracket


def consensus_bound_t5(
    c: SgdConstants, tau: int, topo: Topology, eps: float, rounds: int
) -> float:
    """Eq. (26): psi_1 scaled by the gossip contraction (1 - eps*mu2)^{2E}."""
    factor = spectral_gap_factor(topo, eps, rounds)
    return _common_terms(c) + (c.eta * c.L) ** 2 * c.sigma2 * (tau + 1.0) * factor


# ----------------------------------------------------------------------------
# Resource cost and utility (eqs. 7, 27, 13)
# ----------------------------------------------------------------------------

def resource_cost_periodic(
    *, m: int, taus, tau: int, T: int, U: int, P: int, c1: float, c2: float
) -> float:
    """Eq. (7): psi_0 = sum_i [C1*T*U/(tau*P) + C2*tau_i*T*U/(tau*P)]."""
    taus = np.asarray(taus, np.float64)
    if len(taus) != m:
        raise ValueError("need one tau_i per agent")
    rounds = T * U / (tau * P)
    return float(np.sum(c1 * rounds + c2 * taus * rounds))


def resource_cost_consensus(
    *,
    m: int,
    taus,
    tau: int,
    T: int,
    U: int,
    P: int,
    c1: float,
    c2: float,
    topo: Topology,
    rounds: int,
    w1: float,
    w2: float,
) -> float:
    """Eq. (27): psi_4 = psi_0 + sum_i |Omega_i| (W1+W2) E T U / P."""
    base = resource_cost_periodic(m=m, taus=taus, tau=tau, T=T, U=U, P=P, c1=c1, c2=c2)
    degs = topo.degrees.astype(np.float64)
    extra = float(np.sum(degs * (w1 + w2) * rounds * T * U / P))
    return base + extra


def utility(*, psi1: float, psi2: float, psi0: float, alpha: float = 1.0) -> float:
    """Eq. (13): alpha * (psi2 - psi1) / psi0 — convergence gain per unit cost."""
    if psi0 <= 0:
        raise ValueError("resource cost must be positive")
    return alpha * (psi2 - psi1) / psi0
