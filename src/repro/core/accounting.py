"""Resource-cost ledger in units of C1/C2/W1/W2 (paper eqs. 7, 27; Table II).

C1: one agent->server gradient upload.       C2: one local SGD update.
W1: one neighbor->agent gossip receive.      W2: one gossip combine.

The ledger counts *events* and, when told the payload size, *wire bytes*:
each communication event (C1 uplink, W1 gossip receive) carries one encoded
payload whose size comes from the strategy's payload transform
(``repro.comm.PayloadTransform.payload_bytes`` via
``AggregationStrategy.comm_bytes_per_event``). With compression off that is
exactly ``events * payload_elems * 4`` — dense fp32 — which is pinned by a
tier-1 test. Partial trailing periods bill bytes the same way they bill
events. Multiply the event counts by measured per-event FLOP costs (e.g.
from the dry-run HLO) to get the remaining physical overheads — this is how
the mesh runtime instantiates the paper's symbolic costs with real numbers.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class CostLedger:
    c1_events: int = 0
    c2_events: int = 0
    w1_events: int = 0
    w2_events: int = 0
    c1_bytes: int = 0
    w1_bytes: int = 0
    # Period boundaries billed so far. Uniform strategies never need it, but
    # non-uniform (async/buffered) arrival schedules must know *which*
    # periods a call covers — billing "n more periods" by multiplying a
    # per-period average would mis-count their arrivals.
    periods_billed: int = 0

    def _add_events(self, per: dict, strategy,
                    payload_elems: int | None) -> None:
        self.c1_events += per["c1"]
        self.c2_events += per["c2"]
        self.w1_events += per["w1"]
        self.w2_events += per["w2"]
        if payload_elems is not None:
            per_b = strategy.comm_bytes_per_event(payload_elems)
            self.c1_bytes += per["c1"] * per_b["c1"]
            self.w1_bytes += per["w1"] * per_b["w1"]

    def add_periods(self, strategy, n_periods: int,
                    payload_elems: int | None = None) -> None:
        """Bill ``n_periods`` further full periods.

        Uniform strategies (every agent syncs each boundary) bill by the
        closed-form per-period counts; strategies with non-uniform arrivals
        (``uniform_sync = False``, i.e. the async path) are billed over the
        concrete span ``[periods_billed, periods_billed + n_periods)`` of
        their schedule, so sequential calls cover disjoint spans and sum to
        exactly the schedule's arrival total.
        """
        if getattr(strategy, "uniform_sync", True):
            per = strategy.comm_events_per_period()
            per = {k: v * n_periods for k, v in per.items()}
        else:
            per = strategy.comm_events_span(self.periods_billed, n_periods)
        self._add_events(per, strategy, payload_elems)
        self.periods_billed += n_periods

    def add_partial_period(self, strategy, n_offsets: int,
                           payload_elems: int | None = None) -> None:
        """Bill a trailing partial period of ``n_offsets`` local steps.

        Runs whose total update count is not a multiple of tau still pay for
        the local updates (and gossip) of the unfinished period — plus, on
        the uniform strategies, the final every-replica aggregation read.
        Non-uniform strategies supply their own counts: a buffered schedule
        reaches no boundary mid-period, so its partial tail carries no
        uplinks (the old uniform assumption billed ``m`` here regardless of
        how many replicas actually synced). A no-op when ``n_offsets`` is 0.
        """
        if n_offsets == 0:
            return
        per = strategy.comm_events_partial_period(n_offsets)
        self._add_events(per, strategy, payload_elems)

    def total_bytes(self) -> int:
        """Total wire bytes across the federated links (uplink + gossip)."""
        return self.c1_bytes + self.w1_bytes

    def psi0(self, c1: float, c2: float, w1: float = 0.0, w2: float = 0.0) -> float:
        """Total resource cost; equals eq. (7) (or (27) with gossip events)."""
        return (
            c1 * self.c1_events
            + c2 * self.c2_events
            + w1 * self.w1_events
            + w2 * self.w2_events
        )

    def table_row(self) -> dict:
        """Table II columns (symbolic units) plus the wire-byte totals."""
        return {
            "communication_overheads_C1": self.c1_events,
            "computation_overheads_C2": self.c2_events,
            "inter_communication_W1": self.w1_events,
            "inter_computation_W2": self.w2_events,
            "uplink_bytes_C1": self.c1_bytes,
            "gossip_bytes_W1": self.w1_bytes,
            "total_bytes": self.total_bytes(),
        }
