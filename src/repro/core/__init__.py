"""Core: the paper's contribution (FMARL communication-efficient aggregation).

Exports the three aggregation strategies (periodic / decay / consensus) on top
of variation-aware periodic averaging, the convergence-bound oracles (T1-T5),
the utility function (eq. 13), and the resource-cost ledger (eqs. 7, 27).
"""
from repro.core.decay import (
    DecayFn,
    cosine_decay,
    exponential_decay,
    linear_decay,
    no_decay,
    step_decay,
)
from repro.core.topology import (
    GRAPH_FAMILIES,
    NeighborList,
    Topology,
    density,
    erdos_renyi,
    knn_ring,
    knn_ring_neighbors,
    laplacian,
    mixing_matrix,
    mu2,
    mu2_knn_ring,
    neighbor_list,
    neighbor_weights,
    neighbor_weights_from_matrix,
    watts_strogatz,
)
from repro.core.variation import (
    indicator_mask,
    tau_schedule,
    tau_stats,
    uniform_taus,
    validate_a2,
)
from repro.core.bounds import (
    consensus_bound_t5,
    decay_bound_t4,
    eta_condition,
    periodic_bound_t1,
    resource_cost_consensus,
    resource_cost_periodic,
    utility,
    variation_bound_t2,
)
from repro.core.consensus import consensus_rounds_dense, consensus_rounds_matrix
from repro.core.strategies import (
    AggregationStrategy,
    ConsensusStrategy,
    DecayStrategy,
    PeriodicStrategy,
    SyncStrategy,
    make_strategy,
)
from repro.core.fmarl import FmarlConfig, FmarlState, run_fmarl
from repro.core.accounting import CostLedger
from repro.core.async_fed import (
    AsyncStrategy,
    DelaySchedule,
    kofm_schedule,
    make_schedule,
)

__all__ = [
    "AggregationStrategy",
    "AsyncStrategy",
    "ConsensusStrategy",
    "CostLedger",
    "DecayFn",
    "DecayStrategy",
    "DelaySchedule",
    "FmarlConfig",
    "FmarlState",
    "GRAPH_FAMILIES",
    "NeighborList",
    "PeriodicStrategy",
    "SyncStrategy",
    "Topology",
    "consensus_bound_t5",
    "consensus_rounds_dense",
    "consensus_rounds_matrix",
    "cosine_decay",
    "decay_bound_t4",
    "density",
    "erdos_renyi",
    "eta_condition",
    "exponential_decay",
    "indicator_mask",
    "knn_ring",
    "knn_ring_neighbors",
    "kofm_schedule",
    "laplacian",
    "linear_decay",
    "make_schedule",
    "make_strategy",
    "mixing_matrix",
    "mu2",
    "mu2_knn_ring",
    "neighbor_list",
    "neighbor_weights",
    "neighbor_weights_from_matrix",
    "no_decay",
    "periodic_bound_t1",
    "resource_cost_consensus",
    "resource_cost_periodic",
    "run_fmarl",
    "step_decay",
    "tau_schedule",
    "tau_stats",
    "uniform_taus",
    "utility",
    "validate_a2",
    "variation_bound_t2",
    "watts_strogatz",
]
